from repro.utils import synthetic  # noqa: F401
