"""Synthetic LLM-like weights/activations for tests and paper-figure benches.

Real LLM weight matrices are Gaussian-bulk + per-channel outliers (LLM.int8
[17]); under per-channel absmax INT8 quantization, the outliers pin the scale
and push the bulk into low magnitudes, which is exactly what produces the
paper's Fig. 8(c) bit-plane sparsity profile (planes 3–7 ≥ 65% zero, average
bit sparsity ≈ 0.70 vs value sparsity ≈ 0.05).

``synthetic_llm_weight`` is calibrated against that profile (validated in
tests/test_core_bitslice.py) so op-count/compression benchmarks run on
paper-faithful statistics without shipping model checkpoints.
"""

from __future__ import annotations

import numpy as np


def synthetic_llm_weight(
    rng: np.random.Generator,
    shape: tuple[int, int],
    sigma: float = 0.02,
    outliers_per_channel: int = 2,
    outlier_scale: float = 12.0,
) -> np.ndarray:
    """float32 (out_channels, in_features) Gaussian bulk + channel outliers."""
    out_ch, in_f = shape
    w = rng.normal(size=shape).astype(np.float32) * sigma
    n_out = min(outliers_per_channel, in_f)
    if n_out > 0:
        cols = np.stack([rng.choice(in_f, n_out, replace=False) for _ in range(out_ch)])
        rows = np.repeat(np.arange(out_ch)[:, None], n_out, axis=1)
        w[rows, cols] *= outlier_scale
    return w


def synthetic_llm_weight_int8(
    rng: np.random.Generator, shape: tuple[int, int], **kw
) -> tuple[np.ndarray, np.ndarray]:
    """(int8 weights, per-channel scale) via per-channel symmetric quant."""
    w = synthetic_llm_weight(rng, shape, **kw)
    absmax = np.abs(w).max(axis=1)
    scale = np.maximum(absmax, 1e-8) / 127.0
    q = np.clip(np.round(w / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale.astype(np.float32)


def synthetic_activation(
    rng: np.random.Generator, shape: tuple[int, ...], sigma: float = 1.0
) -> np.ndarray:
    """Post-layernorm-like activations (zero-mean Gaussian, mild outliers)."""
    x = rng.normal(size=shape).astype(np.float32) * sigma
    mask = rng.random(shape) < 0.001
    return np.where(mask, x * 8.0, x).astype(np.float32)
