"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), in seconds:

  compute    = device_FLOPs / peak_FLOPs
  memory     = device_bytes / HBM_bw
  collective = Σ collective operand bytes / link_bw

Sources: ``compiled.cost_analysis()`` yields per-device (post-SPMD) flops
and bytes; collective bytes are parsed from ``compiled.as_text()`` by
summing operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (the partitioned module's shapes are
already per-device).  Hardware constants are the v5e targets given in the
brief.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, Optional

# --- hardware model (TPU v5e targets from the brief) ----------------------


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link (~)
    hbm_per_chip: float = 16e9  # v5e HBM capacity


V5E = HW()

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Operand bytes of every collective, by kind, × while-loop trip counts.

    Thin wrapper over :class:`repro.analysis.hlo.HloModule` (which resolves
    operand shapes by name and loop multipliers from condition constants /
    known_trip_count annotations).
    """
    from repro.analysis.hlo import HloModule

    return HloModule(hlo_text).collective_bytes()


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    device_flops: float
    device_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    model_flops: float  # analytic "useful" flops (global)
    peak_memory_bytes: Optional[float]
    xla_flops: float = 0.0  # cost_analysis (loop bodies counted once)
    xla_bytes: float = 0.0
    loop_mults: Optional[Dict[str, float]] = None
    # measured serve-time weight traffic per decode step (the scheduler's
    # weight_read counter / WeightPlan total; 0.0 when not supplied)
    weight_read_bytes: float = 0.0

    hw: HW = V5E

    # --- the three terms (seconds) -----------------------------------
    @property
    def t_compute(self) -> float:
        return self.device_flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.device_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (device_flops × chips): remat/redundancy waste."""
        total = self.device_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How much of the bound time is useful compute — the perf score.

        = (model_flops/chips/peak) / max(term): 1.0 means the dominant
        roofline term is fully useful compute.
        """
        t_useful = self.model_flops / self.chips / self.hw.peak_flops
        return t_useful / self.t_bound if self.t_bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "device_flops": self.device_flops,
            "device_bytes": self.device_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": self.collective_by_kind,
            "model_flops": self.model_flops,
            "peak_memory_bytes": self.peak_memory_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
            "loop_mults": self.loop_mults,
            "weight_read_bytes": self.weight_read_bytes,
        }


def bgpp_kernel_traffic(
    S: int, D: int, rounds: int = 4, keep_ratio: float = 0.25, nbits: int = 7
) -> Dict[str, float]:
    """Analytic per-(query, kv-head) HBM bytes of the BGPP *kernel* path.

    The jnp fallback in the serving engine materializes unpacked bit planes
    (8× blow-up) and is slower than dense int8 — exactly mirroring the
    paper's own GPU result (Fig. 20: software-only MCBP = 1.03×).  The
    validated Pallas kernel (``repro.kernels.bgpp_score``) consumes the
    packed planes in VMEM; its traffic is structurally determined:

      sign plane (once)      S · D/8
      round r plane          k_r · D/8,   k_0 = S, k_r = max(k_max, S/2^r)
      formal compute         k_max · (nbits·D/8 + D/8 + D + 8)
                             (re-fetch the survivor's packed planes + sign
                             to reconstruct K, read its int8 V row, two
                             f32 scales — the exact per-head row the
                             serving counter ``kv_cache._token_row_bytes``
                             prices, so measured/modeled gates at ~1.0)

    vs the dense int8 baseline 2·S·D (K+V).  The f32 output write is NOT
    part of ``bgpp_kernel_bytes`` (the cache counter never charges it);
    it is reported separately as ``output_write_bytes``.  Returns bytes +
    the ratio.
    """
    # ceil, matching THE serving plan (repro.serving.kv_cache
    # .bgpp_decode_plan) so measured-vs-modeled comparisons never carry a
    # silent rounding mismatch in k_max
    k_max = max(1, math.ceil(S * keep_ratio))
    bytes_ = S * D / 8.0  # sign
    k_r = S
    for r in range(rounds):
        bytes_ += k_r * D / 8.0
        k_r = max(k_max, S >> (r + 1))
    bytes_ += k_max * (nbits * D / 8.0 + D / 8.0 + D + 8)
    dense = 2.0 * S * D
    return {
        "bgpp_kernel_bytes": bytes_,
        "dense_int8_bytes": dense,
        "reduction": dense / bytes_,
        "k_max": k_max,
        "output_write_bytes": D * 4.0,
    }


def bstc_weight_traffic(
    in_dim: int,
    out_dim: int,
    m: int = 4,
    nbits: int = 7,
    col_sparsity=None,
    dtype_bytes: int = 2,
) -> Dict[str, float]:
    """Closed-form serve-time HBM bytes of ONE ``(in, out)`` projection
    under the BSTC two-state weight coding (paper §4.1).

    Per magnitude plane ``p`` with ``m``-bit column sparsity ``sc_p`` the
    coded stream is ``in·out / CR(m, sc_p)`` bits
    (:func:`repro.core.bstc.compression_ratio_closed_form`); the sign
    plane is always raw (``in·out`` bits) and the f32 output-channel
    scales add ``4·out`` bytes.  ``col_sparsity`` is a per-plane sequence
    — ``None`` entries mean the encoder kept that plane raw (sparsity
    below threshold or coding would not shrink it), matching
    ``encode_weight``'s per-plane decision, so feeding the MEASURED column
    sparsities reproduces the measured stream to within byte rounding
    (the ±10% reconciliation gate in the serving bench rides on this).
    Omitting ``col_sparsity`` prices every plane raw — plain int8.

    Returns coded bytes plus the int8/bf16 baselines and reductions.
    """
    from repro.core.bstc import compression_ratio_closed_form

    if col_sparsity is None:
        col_sparsity = [None] * nbits
    if len(col_sparsity) != nbits:
        raise ValueError(
            f"col_sparsity has {len(col_sparsity)} entries, expected "
            f"nbits={nbits}"
        )
    n = float(in_dim) * float(out_dim)
    bits = n  # sign plane, always raw
    for sc in col_sparsity:
        if sc is None:
            bits += n
        else:
            bits += n / compression_ratio_closed_form(m, float(sc))
    coded = bits / 8.0 + 4.0 * out_dim
    int8 = n + 4.0 * out_dim
    bf16 = dtype_bytes * n
    return {
        "bstc_bytes": coded,
        "int8_bytes": int8,
        "bf16_bytes": bf16,
        "reduction_vs_int8": int8 / coded,
        "reduction_vs_bf16": bf16 / coded,
    }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell (global, per step).

    train: 6·N·D (fwd+bwd); prefill: 2·N·D; decode: 2·N_active per token ×
    batch (+ attention KV term for decode, which dominates long contexts).
    """
    n_active = cfg.active_params()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * B * S
    if shape.kind == "prefill":
        return 2.0 * n_active * B * S
    # decode: one token; include the KV-attention matvec flops
    attn_layers = sum(
        1 for i in range(cfg.num_layers) if cfg.layer_is_attention(i)
    )
    kv_flops = 0.0
    for i in range(cfg.num_layers):
        if not cfg.layer_is_attention(i):
            continue
        kind, w = cfg.layer_attn_window(i)
        span = min(S, w) if (kind in ("sliding", "chunked") and w > 0) else S
        kv_flops += 2.0 * 2.0 * cfg.num_heads * cfg.head_dim * span  # QK^T + PV
    return (2.0 * n_active + kv_flops) * B


def roofline_from_compiled(
    compiled, arch: str, shape, mesh_name: str, chips: int, cfg
) -> RooflineReport:
    """Three-term roofline from the compiled artifact.

    The text-level HLO model (``repro.analysis.hlo``) supplies the terms
    because XLA's ``cost_analysis()`` visits each while (scan) body once —
    a ~num_layers× undercount on the train/prefill graphs.  The text model
    multiplies loop bodies by their recovered trip counts; it matches
    cost_analysis on loop-free decode graphs (validated in tests).  XLA's
    numbers are retained in the report for reference.
    """
    from repro import compat
    from repro.analysis.hlo import HloModule

    cost = compat.cost_analysis_dict(compiled)
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    mod = HloModule(compiled.as_text())
    flops = mod.dot_flops()
    bytes_ = mod.traffic_bytes()
    coll = mod.collective_bytes()
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    except Exception:  # pragma: no cover - backend-dependent
        peak = None
    return RooflineReport(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        device_flops=max(flops, xla_flops),
        device_bytes=bytes_,
        collective_bytes=coll["total"],
        collective_by_kind={k: v for k, v in coll.items() if v and k != "total"},
        model_flops=model_flops_for(cfg, shape),
        peak_memory_bytes=peak,
        xla_flops=xla_flops,
        xla_bytes=xla_bytes,
        loop_mults=mod.while_summary(),
    )
