from repro.analysis.roofline import (  # noqa: F401
    HW,
    RooflineReport,
    collective_bytes_from_hlo,
    roofline_from_compiled,
)
