"""Summarize dry-run JSON results into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m repro.analysis.summarize [--dir experiments/dryrun/16x16] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            rows.append(json.load(fh))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    return rows


def fmt_row(r, md=False):
    sep = " | " if md else "  "
    if r["status"] != "ok":
        cells = [f"{r['arch']:<24}", f"{r['shape']:<12}", "SKIP", r.get("reason", "")]
        return sep.join(cells)
    m = r.get("memory_analysis") or {}
    cells = [
        f"{r['arch']:<24}",
        f"{r['shape']:<12}",
        f"{r['t_compute_s']:.3e}",
        f"{r['t_memory_s']:.3e}",
        f"{r['t_collective_s']:.3e}",
        f"{r['bottleneck']:<10}",
        f"{r['useful_flops_ratio']:.2f}",
        f"{r['roofline_fraction']:.3f}",
        f"{m.get('per_device_gb', '?')}",
    ]
    return sep.join(str(c) for c in cells)


HEADER = [
    "arch", "shape", "t_compute", "t_memory", "t_collect", "bottleneck",
    "useful", "roofline", "mem_GB",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun/16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.dir)
    sep = " | " if args.md else "  "
    hdr = sep.join(
        h.ljust(w)
        for h, w in zip(HEADER, (24, 12, 9, 9, 9, 10, 6, 8, 6))
    )
    if args.md:
        print("| " + hdr + " |")
        print("|" + "---|" * len(HEADER))
        for r in rows:
            print("| " + fmt_row(r, md=True) + " |")
    else:
        print(hdr)
        for r in rows:
            print(fmt_row(r))


if __name__ == "__main__":
    main()
