"""Text-level HLO cost model with while-loop (scan) trip multipliers.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE, so any
scan-over-layers graph undercounts FLOPs/bytes by ~num_layers×.  This module
parses the post-optimization HLO text, recovers each loop's trip count from
its condition computation (counter < constant), propagates multipliers
through nesting, and accumulates:

  * ``dot_flops``  — 2 · prod(result dims) · prod(contracting dims), the MXU
    term of the roofline (validated against cost_analysis on loop-free
    decode graphs in tests);
  * ``bytes``      — operand+result bytes of every top-level op (fusion
    internals excluded — a fusion's traffic is its boundary), the HBM term.

Collective accounting lives in ``repro.analysis.roofline`` and reuses the
same multiplier logic.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s2|u2|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|token)\[([0-9,]*)\]"
)
_DEF_RE = re.compile(r"^(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+)$")
_NAME_RE = re.compile(r"%[\w\.\-]+")


def _split_type_op(rhs: str):
    """'(s32[], f32[..] /*index=5*/ ...) while(%x), ...' ->
    (type_str, opcode, rest_after_open_paren) or None."""
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    type_str = rhs[: i + 1]
                    rest = rhs[i + 1 :].lstrip()
                    break
        else:
            return None
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp + 1 :].lstrip()
    m = re.match(r"([a-z][a-z0-9\-]*)\(", rest)
    if not m:
        return None
    return type_str, m.group(1), rest[m.end() :]

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "conditional", "call",
    "copy-done", "all-reduce-done", "all-gather-done", "collective-permute-done",
}


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    attrs: str
    line: str

    @property
    def result_bytes(self) -> int:
        return sum(
            _DTYPE_BYTES[d] * _prod(dims) for d, dims in self.result_shapes
        )


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for d, dims in _SHAPE_RE.findall(text):
        t = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((d, t))
    return out


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_entry: bool = False


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, Computation] = {}
        self.op_index: Dict[str, Op] = {}
        self._parse(text)
        self._resolve_multipliers()

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        comp: Optional[Computation] = None
        for raw in text.splitlines():
            s = raw.strip()
            if not s or s.startswith("//"):
                continue
            # computation headers: "%name (params) -> type {" with no " = "
            if " = " not in s and s.endswith("{"):
                m = re.match(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\(", s)
                if m:
                    comp = Computation(m.group(2), [], is_entry=bool(m.group(1)))
                    self.computations[comp.name] = comp
                    continue
            md = _DEF_RE.match(s)
            if md and comp is not None and " = " in s:
                name, rhs = md.group(1), md.group(2)
                parts = _split_type_op(rhs)
                if parts is None:
                    continue
                type_str, opcode, args = parts
                # operand list ends at the first top-level ')'
                depth = 1
                for i, ch in enumerate(args):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            attrs = args[i + 1 :]
                            args = args[:i]
                            break
                else:
                    attrs = ""
                op = Op(
                    name=name,
                    opcode=opcode,
                    result_shapes=_parse_shapes(type_str),
                    operands=_NAME_RE.findall(args),
                    attrs=attrs,
                    line=s,
                )
                comp.ops.append(op)
                self.op_index[name] = op

    # --------------------------------------------------- loop multipliers
    def _trip_count(self, cond_name: str) -> int:
        cond = self.computations.get(cond_name)
        if cond is None:
            return 1
        consts = {
            o.name: int(m.group(1))
            for o in cond.ops
            if o.opcode == "constant"
            and (m := re.search(r"constant\((\d+)\)", o.line))
        }
        # ROOT op's constant operand is the bound (counter < bound)
        root = cond.ops[-1]
        for nm in root.operands:
            if nm in consts:
                return consts[nm]
            # wrapped_compare fusion: look one level in
            inner = self.op_index.get(nm)
            if inner is not None:
                for nm2 in inner.operands:
                    if nm2 in consts:
                        return consts[nm2]
        return max(consts.values(), default=1)

    def _resolve_multipliers(self):
        self.mult: Dict[str, float] = {}
        self.fused: set = set()
        entry = next(
            (c.name for c in self.computations.values() if c.is_entry), None
        )
        if entry is None and self.computations:
            entry = next(iter(self.computations))
        # computations referenced as fusion/reduce bodies are "inline"
        for c in self.computations.values():
            for op in c.ops:
                for key in ("calls=", "to_apply="):
                    if key in op.attrs:
                        for nm in _NAME_RE.findall(op.attrs.split(key, 1)[1].split(",")[0]):
                            self.fused.add(nm)

        seen = set()

        def visit(name: str, k: float):
            self.mult[name] = self.mult.get(name, 0.0) + k
            if name in seen:
                return
            seen.add(name)
            comp = self.computations.get(name)
            if comp is None:
                return
            for op in comp.ops:
                if op.opcode == "while":
                    body = re.search(r"body=(%[\w\.\-]+)", op.attrs)
                    cond = re.search(r"condition=(%[\w\.\-]+)", op.attrs)
                    tm = re.search(r'known_trip_count[":{\\]+n[":\\]+(\d+)', op.attrs)
                    if tm:
                        trip = int(tm.group(1))
                    else:
                        trip = self._trip_count(cond.group(1)) if cond else 1
                    if body:
                        visit(body.group(1), self.mult[name] * trip)
                elif op.opcode in ("call", "conditional", "async-start"):
                    for nm in _NAME_RE.findall(op.attrs):
                        if nm in self.computations and nm not in self.fused:
                            visit(nm, self.mult[name])

        if entry:
            visit(entry, 1.0)

    # ------------------------------------------------------------- costs
    # ops that don't move HBM bytes when fused into a consumer: dtype
    # converts and layout relabels.  transpose/copy are NOT here — those
    # materialize on TPU too (see §Perf A1, which removed one at the source).
    _CAST_OPS = {"convert", "bitcast", "reshape",
                 "parameter", "tuple", "get-tuple-element"}

    def _fusion_comp(self, op: Op) -> Optional[Computation]:
        m = re.search(r"calls=(%[\w\.\-]+)", op.attrs)
        return self.computations.get(m.group(1)) if m else None

    def _is_pure_cast(self, op: Op) -> bool:
        """Fusion that only converts dtype / relabels layout / slices.  The
        CPU backend materializes these (e.g. it upcasts int8 dot operands to
        s32/f32); a TPU feeds the MXU in-flight — charge the bytes actually
        read (slice sizes at source dtype) instead."""
        if op.opcode in ("convert", "bitcast", "reshape"):
            return True
        if op.opcode != "fusion":
            return False
        comp = self._fusion_comp(op)
        if comp is None:
            return False
        allowed = self._CAST_OPS | {"slice", "dynamic-slice"}
        return all(o.opcode in allowed for o in comp.ops)

    def _operand_bytes(self, name: str) -> float:
        """Bytes a consumer actually pulls for this operand: see through
        pure-cast producers to what they actually read."""
        src = self.op_index.get(name)
        if src is None or src.opcode == "constant":
            return 0.0
        if self._is_pure_cast(src):
            comp = self._fusion_comp(src) if src.opcode == "fusion" else None
            if comp is not None:
                slices = [
                    o for o in comp.ops if o.opcode in ("slice", "dynamic-slice")
                ]
                if slices:
                    return float(sum(s.result_bytes for s in slices))
            return float(sum(self._operand_bytes(nm) for nm in src.operands))
        return float(src.result_bytes)

    def _fusion_param_charges(self, comp: Computation) -> Dict[int, float]:
        """parameter index -> byte charge multiplier source.

        A fused parameter consumed ONLY by (dynamic-)slice ops is charged at
        the slice sizes (a real TPU reads only the slice), not the full
        operand — the python-loop per-layer cache reads hit this.  Returns
        {param_index: bytes or -1.0 for 'full operand'}.
        """
        if not hasattr(self, "_fp_cache"):
            self._fp_cache: Dict[str, Dict[int, float]] = {}
        if comp.name in self._fp_cache:
            return self._fp_cache[comp.name]
        params: Dict[str, int] = {}
        for o in comp.ops:
            if o.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", o.line)
                if m:
                    params[o.name] = int(m.group(1))
        charges: Dict[int, float] = {}
        for pname, idx in params.items():
            consumers = [o for o in comp.ops if pname in o.operands]
            if consumers and all(
                c.opcode in ("slice", "dynamic-slice") for c in consumers
            ):
                charges[idx] = float(sum(c.result_bytes for c in consumers))
            else:
                charges[idx] = -1.0
        self._fp_cache[comp.name] = charges
        return charges

    def _op_traffic(self, op: Op) -> float:
        """HBM bytes attributed to one top-level op (in-place/slice/cast
        aware — see the per-case comments)."""
        if op.opcode in _SKIP_BYTES_OPS:
            return 0.0
        if op.opcode == "dynamic-update-slice":
            upd = self.op_index.get(op.operands[1]) if len(op.operands) > 1 else None
            return 2.0 * (upd.result_bytes if upd else 0)
        if op.opcode in ("dynamic-slice", "slice"):
            return 2.0 * op.result_bytes
        if op.opcode == "broadcast":
            return float(op.result_bytes)
        if self._is_pure_cast(op):
            return 0.0  # charged at the consumer via _operand_bytes
        if op.opcode == "fusion":
            comp = self._fusion_comp(op)
            root = comp.ops[-1] if comp and comp.ops else None
            charges = self._fusion_param_charges(comp) if comp else {}
            in_place_dus = root is not None and root.opcode == "dynamic-update-slice"
            if in_place_dus:
                # in-place cache write: the big buffer aliases through; only
                # the update slice (+ index math) actually moves
                sizes = [
                    self._operand_bytes(nm)
                    for nm in op.operands
                    if nm in self.op_index
                    and self.op_index[nm].opcode != "constant"
                ]
                big = max(sizes, default=0)
                return 2.0 * max(sum(sizes) - big, 0)
            b = float(op.result_bytes)
            for i, nm in enumerate(op.operands):
                src = self.op_index.get(nm)
                if src is None or src.opcode == "constant":
                    continue
                c = charges.get(i, -1.0)
                b += self._operand_bytes(nm) if c < 0 else c
            return b
        b = float(op.result_bytes)
        for nm in op.operands:
            b += self._operand_bytes(nm)
        return b

    def dot_flops(self) -> float:
        total = 0.0
        for cname, comp in self.computations.items():
            k = self.mult.get(cname, 0.0)
            if k == 0.0 and cname in self.fused:
                # dots rarely live in fusions on CPU; attribute ×1 if found
                k = 1.0
            if k == 0.0:
                continue
            for op in comp.ops:
                if op.opcode != "dot":
                    continue
                m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
                cdims = (
                    tuple(int(x) for x in m.group(1).split(",") if x) if m else ()
                )
                lhs = self.op_index.get(op.operands[0])
                kdim = 1
                if lhs is not None and lhs.result_shapes:
                    ldims = lhs.result_shapes[0][1]
                    for c in cdims:
                        if c < len(ldims):
                            kdim *= ldims[c]
                total += k * 2.0 * _prod(op.result_shapes[0][1]) * kdim
        return total

    def traffic_bytes(self) -> float:
        total = 0.0
        for cname, comp in self.computations.items():
            if cname in self.fused:
                continue  # fusion internals: traffic is the fusion boundary
            k = self.mult.get(cname, 0.0)
            if k == 0.0:
                continue
            for op in comp.ops:
                total += k * self._op_traffic(op)
        return total

    def while_summary(self) -> Dict[str, float]:
        return {
            c: m for c, m in self.mult.items()
            if m > 1.0 and c in self.computations
        }

    def top_ops_by_bytes(self, n: int = 20):
        """(bytes×mult, opcode, op name, comp) — traffic hot spots."""
        rows = []
        for cname, comp in self.computations.items():
            if cname in self.fused:
                continue
            k = self.mult.get(cname, 0.0)
            if k == 0.0:
                continue
            for op in comp.ops:
                b = self._op_traffic(op)
                if b:
                    rows.append((k * b, op.opcode, op.name, cname))
        rows.sort(reverse=True)
        return rows[:n]

    def collective_bytes(self) -> Dict[str, float]:
        """Operand bytes of collectives, by kind, × loop multipliers."""
        kinds = (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute", "collective-broadcast", "ragged-all-to-all",
        )
        out = {k: 0.0 for k in kinds}
        out["total"] = 0.0
        for cname, comp in self.computations.items():
            k = self.mult.get(cname, 0.0)
            if k == 0.0:
                continue
            for op in comp.ops:
                base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
                if base not in kinds or op.opcode.endswith("-done"):
                    continue
                b = 0.0
                for nm in op.operands:
                    src = self.op_index.get(nm)
                    if src is not None:
                        b += src.result_bytes
                out[base] += k * b
                out["total"] += k * b
        return out
