"""Gradient compression for data-parallel sync (bit-themed, like the paper).

``compressed_psum_mean`` runs inside ``shard_map``: each data shard
quantizes its local gradient to int8 (per-leaf absmax scale), the int8
payload is all-reduced (sum) over the data axis, and the result is
dequantized — 4× less cross-pod traffic than f32 (2× vs bf16) at the cost of
bounded quantization noise.  The scales themselves are psum'd (tiny).

``make_compressed_dp_grad_fn`` wraps a loss into an explicit-DP gradient
function with the compressed sync — used where the cross-pod links are the
bottleneck (§Perf knob); inside a pod, the partitioner's native reduce
stays f32.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

Tree = Any


def _q8_leaf(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_mean(grads: Tree, axis_name: str) -> Tree:
    """int8-compressed mean-allreduce over ``axis_name`` (inside shard_map).

    Uses a *shared* scale: the per-leaf absmax is pmax'd first (a scalar
    collective, negligible traffic), every shard quantizes against it, the
    int8 payloads are summed in int32, and the result is dequantized.  The
    quantization error is then bounded by the global absmax regardless of
    shard-to-shard gradient scale skew.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g):
        g32 = g.astype(jnp.float32)
        local_max = jnp.max(jnp.abs(g32))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        # sum int8 payloads in int32 (no overflow for n <= 2^23 shards)
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (qsum.astype(jnp.float32) * scale / n).astype(g.dtype)

    return jax.tree.map(one, grads)


def make_compressed_dp_grad_fn(
    loss_fn: Callable[[Tree, Tree], jax.Array],
    mesh,
    data_axis: str = "data",
) -> Callable[[Tree, Tree], Tree]:
    """Explicit data-parallel value+grad with int8 gradient sync.

    params replicated, batch sharded over ``data_axis``.  Returns
    f(params, batch) -> (loss, grads) with grads mean-reduced via the
    compressed collective.
    """

    def local(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = compressed_psum_mean(grads, data_axis)
        loss = jax.lax.pmean(loss, data_axis)
        return loss, grads

    return compat.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P(data_axis)),
        out_specs=(P(), P()),
        check_vma=False,
    )
