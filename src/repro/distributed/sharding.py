"""Logical-axis sharding rules → NamedSharding/PartitionSpec.

Every parameter leaf in the model zoo is annotated with a tuple of *logical*
axis names (see ``models/*.py: param_specs``).  This module maps them onto
the physical mesh axes:

  single pod : mesh ("data", "model") = (16, 16)
  multi-pod  : mesh ("pod", "data", "model") = (2, 16, 16)

Default rules are megatron-style tensor parallelism over "model" and batch
parallelism over "data" (+"pod").  Strategy knobs:

  fsdp_axes  — logical axes additionally sharded over "data" (ZeRO-3 style
               per-layer all-gather; required to fit jamba-398B),
  seq_shard  — shard the KV-cache sequence axis over "data" for the
               long_500k batch=1 cells (the distattention pattern).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

# logical axis vocabulary used by the model zoo
BATCH = "batch"
SEQ = "seq"  # activation sequence axis (sequence parallelism / long-ctx KV)
TOKENS = "tokens"  # flattened B*S: all axes that shard tokens (MoE groups)
VOCAB = "vocab"
D_MODEL = "d_model"
HEADS = "heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
FF = "ff"
EXPERT = "expert"
LAYERS = "layers"  # stacked-scan leading dim: never sharded
CONV = "conv"
STATE = "state"
VISION = "vision"
NONE = None


class ShardingFallbackWarning(UserWarning):
    """A logical axis could not shard its dim and was silently replicated.

    Raised (as a warning, not an error) by :meth:`ShardingRules
    .spec_for_shape` so a mis-sized tensor — e.g. a KV pool whose head axis
    does not divide the ``"model"`` mesh axis — shows up in logs instead of
    masquerading as a correctly sharded one.  Divisibility fallback remains
    the *behaviour*; the warning only adds the missing signal."""


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis name -> mesh axis (or None = replicated)."""

    batch_axes: Tuple[str, ...] = ("data",)  # ("pod","data") on multi-pod
    model_axis: str = "model"
    # logical -> mesh; anything absent is replicated
    fsdp_axes: Tuple[str, ...] = ()  # logical axes to also shard over data
    seq_shard: bool = False  # shard KV seq over data (long-context decode)
    sp: bool = False  # sequence parallelism: activations' seq over model
    # concrete mesh for in-graph constraints ("with mesh:" alone does NOT
    # make PartitionSpec constraints resolvable inside jit)
    mesh: Optional[Mesh] = None

    def mesh_axis(self, logical: Optional[str]):
        if logical is None:
            return None
        if logical == BATCH:
            if self.seq_shard:
                return None  # long-context decode: data axis belongs to SEQ
            ax = tuple(self.batch_axes)
            return ax if len(ax) > 1 else ax[0]
        if logical in (VOCAB, HEADS, KV_HEADS, FF, EXPERT):
            return self.model_axis
        if logical == SEQ:
            if self.seq_shard:
                return tuple(self.batch_axes)
            return self.model_axis if self.sp else None
        if logical == TOKENS:
            # token groups shard over the batch axes ONLY: the "model" axis
            # belongs to the TP-sharded expert FF dim, and claiming it here
            # forces the partitioner to replicate expert compute (§Perf B3)
            ax = tuple(self.batch_axes)
            return ax if len(ax) > 1 else (ax[0] if ax else None)
        if logical in self.fsdp_axes:
            # ZeRO-3: weight's d_model (or ff) axis sharded over data too
            return tuple(self.batch_axes)
        return None

    def token_groups(self, n_tokens: int) -> int:
        """Number of shard-aligned groups the flattened token dim splits
        into (MoE group-local dispatch).  1 when no mesh is attached."""
        import math as _math

        if self.mesh is None:
            return 1
        sizes = dict(self.mesh.shape)
        g = 1
        for a in self.batch_axes:
            g *= sizes.get(a, 1)
        if self.sp:
            g *= sizes.get(self.model_axis, 1)
        return _math.gcd(n_tokens, g)

    def group_sizes(self, batch: int, seq: int):
        """(Gb, Gs): shard-aligned group factors along batch and seq.

        A single flatten of (B, S) across two sharded mesh axes is NOT
        expressible in GSPMD (reshape would split within shards); factoring
        per-dim keeps every reshape aligned with exactly one axis
        (§Perf iteration B3).
        """
        import math as _math

        if self.mesh is None:
            return 1, 1
        sizes = dict(self.mesh.shape)
        gb = 1
        for a in self.batch_axes:
            gb *= sizes.get(a, 1)
        gb = _math.gcd(batch, gb)
        # Gs stays 1: the MoE block is a sequence-parallel REGION BOUNDARY
        # (megatron-SP style) — S is all-gathered entering the expert FFN so
        # token groups never claim the model axis (§Perf B3/B4).
        return gb, 1

    def spec(self, logical_axes: Tuple[Optional[str], ...]) -> P:
        used = set()
        out = []
        for ax in logical_axes:
            phys = self.mesh_axis(ax)
            # a mesh axis may appear at most once in a PartitionSpec
            key = tuple(phys) if isinstance(phys, tuple) else (phys,)
            if phys is None or any(k in used for k in key if k is not None):
                out.append(None)
            else:
                used.update(k for k in key if k is not None)
                out.append(phys)
        return P(*out)

    def spec_for_shape(self, mesh: Mesh, logical_axes, shape) -> P:
        """Like :meth:`spec` but duplicate-axis and divisibility handling are
        joint: an axis that can't shard a dim (kv_heads=8 on model=16) stays
        AVAILABLE for a later logical dim (e.g. the KV sequence) — this is
        what turns few-head decode caches into flash-decode seq sharding
        instead of replication."""
        sizes = dict(mesh.shape)  # works for Mesh and AbstractMesh
        used = set()
        out = []
        for dim, logical in zip(shape, tuple(logical_axes) + (None,) * len(shape)):
            phys = self.mesh_axis(logical)
            if phys is None:
                out.append(None)
                continue
            axes = phys if isinstance(phys, tuple) else (phys,)
            total = 1
            for a in axes:
                total *= sizes[a]
            if any(a in used for a in axes):
                out.append(None)
                continue
            if dim % total != 0:
                # dim == 1 is "nothing to shard" (B=1 chunk prefill, squeezed
                # axes) — only a real size mismatch warrants the signal
                if dim > 1:
                    warnings.warn(
                        f"logical axis {logical!r} (dim {dim}) is not "
                        f"divisible by mesh axes {tuple(axes)} (size {total})"
                        "; replicating instead",
                        ShardingFallbackWarning,
                        stacklevel=2,
                    )
                out.append(None)
                continue
            used.update(axes)
            out.append(phys)
        return P(*out)

    def tree_specs(self, logical_tree) -> jax.tree_util.PyTreeDef:
        """Map a pytree of logical-axis tuples to PartitionSpecs."""
        return jax.tree.map(
            lambda axes: self.spec(tuple(axes)),
            logical_tree,
            is_leaf=lambda x: isinstance(x, tuple),
        )

    def tree_shardings(self, mesh: Mesh, logical_tree, struct_tree=None):
        """NamedShardings for a spec tree; with ``struct_tree`` (matching
        pytree of shaped values) the specs become divisibility-safe."""
        is_leaf = lambda x: isinstance(x, tuple)
        if struct_tree is None:
            return jax.tree.map(
                lambda axes: NamedSharding(mesh, self.spec(tuple(axes))),
                logical_tree,
                is_leaf=is_leaf,
            )
        flat_specs, treedef = jax.tree_util.tree_flatten(logical_tree, is_leaf=is_leaf)
        flat_structs = treedef.flatten_up_to(struct_tree)
        out = [
            NamedSharding(mesh, self.spec_for_shape(mesh, ax, s.shape))
            for ax, s in zip(flat_specs, flat_structs)
        ]
        return jax.tree_util.tree_unflatten(treedef, out)


def rules_for_mesh(mesh: Mesh, **kw) -> ShardingRules:
    """Default rules for a production mesh (adds 'pod' to batch axes)."""
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes)
    return ShardingRules(batch_axes=batch or ("data",), mesh=mesh, **kw)


def constrain(x: jax.Array, rules: ShardingRules, logical_axes) -> jax.Array:
    """with_sharding_constraint via logical axes (no-op outside jit/mesh).

    Divisibility-safe: axes that don't divide the corresponding dim are
    dropped (few-head archs like gemma3-1b replicate heads instead of
    forcing an invalid 16-way split).  Uses the rules' concrete mesh when
    present (a plain ``with mesh:`` does not make PartitionSpec constraints
    resolvable inside jit); falls back to the ambient abstract mesh.
    """
    try:
        mesh = rules.mesh if rules.mesh is not None else compat.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        spec = rules.spec_for_shape(mesh, tuple(logical_axes), x.shape)
        if isinstance(mesh, Mesh):
            # concrete mesh (rules-attached, or the ambient ``with mesh:``
            # form): bare PartitionSpec constraints don't resolve inside
            # jit there, so wrap in a NamedSharding
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
