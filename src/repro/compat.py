"""Version-portability shims for the JAX / Pallas surface this repo uses.

The kernels and the sharding layer were written against a moving JAX API;
this module resolves every version-drifted symbol ONCE so the rest of the
tree imports stable names.  Supported range: JAX >= 0.4.37 (the pinned
toolchain) through current releases.  Anything older raises immediately
with an explicit minimum-version error instead of failing deep inside a
``pallas_call``.

Resolved surface:

  ``tpu_compiler_params(**kw)``  pltpu.CompilerParams (new) vs.
                                 pltpu.TPUCompilerParams (<= 0.4.x)
  ``get_abstract_mesh()``        jax.sharding.get_abstract_mesh (new) vs.
                                 the ambient ``with mesh:`` thread resource
  ``shard_map(...)``             jax.shard_map (new, ``check_vma=``) vs.
                                 jax.experimental.shard_map (``check_rep=``)
  ``cost_analysis_dict(c)``      compiled.cost_analysis() returns a dict
                                 (new) vs. a per-device list (<= 0.4.x)
"""

from __future__ import annotations

import re
from typing import Any, Dict

import jax
from jax.experimental.pallas import tpu as pltpu

MIN_JAX_VERSION = (0, 4, 37)


def jax_version() -> tuple:
    """The running JAX version as an int tuple (pre-release tags dropped).

    Only the LEADING digit run of each component counts: '4rc5' is patch 4,
    not 45 — concatenating would falsely clear the minimum-version floor.
    """
    parts = []
    for p in jax.__version__.split(".")[:3]:
        m = re.match(r"\d+", p)
        parts.append(int(m.group()) if m else 0)
    return tuple(parts)


def require_min_jax(feature: str, minimum: tuple = MIN_JAX_VERSION) -> None:
    """Raise with an explicit floor when the running JAX is too old."""
    if jax_version() < minimum:
        raise RuntimeError(
            f"{feature} requires JAX >= {'.'.join(map(str, minimum))}; "
            f"found {jax.__version__}. Upgrade jax/jaxlib."
        )


# --------------------------------------------------------------------------
# Pallas TPU compiler params: renamed TPUCompilerParams -> CompilerParams.
# --------------------------------------------------------------------------
_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)


def tpu_compiler_params(**kwargs) -> Any:
    """Build the TPU compiler-params object under either pallas API name."""
    if _COMPILER_PARAMS_CLS is None:  # pragma: no cover - very old jax
        require_min_jax("pallas TPU compiler params")
        raise RuntimeError("jax.experimental.pallas.tpu has no CompilerParams")
    return _COMPILER_PARAMS_CLS(**kwargs)


# --------------------------------------------------------------------------
# Ambient mesh discovery: jax.sharding.get_abstract_mesh landed after 0.4.x;
# on the pinned toolchain the ``with mesh:`` context lives in thread
# resources instead.
# --------------------------------------------------------------------------
def get_abstract_mesh():
    """The ambient mesh (abstract or concrete), or None when there is none.

    Callers must accept either a concrete ``jax.sharding.Mesh`` (the
    ``with mesh:`` form — build a NamedSharding from it) or an AbstractMesh
    (bare PartitionSpec constraints resolve against it on new JAX).  An
    axis-less mesh counts as "none": new JAX's get_abstract_mesh returns an
    empty AbstractMesh rather than None outside any ``use_mesh`` scope.
    """
    try:
        from jax._src import mesh as _mesh_lib
    except ImportError:  # pragma: no cover - far-future jax
        _mesh_lib = None
    fn = getattr(jax.sharding, "get_abstract_mesh", None) or getattr(
        _mesh_lib, "get_abstract_mesh", None
    )
    if fn is not None:
        try:
            am = fn()
        except Exception:
            am = None
        if am is not None and getattr(am, "axis_names", ()):
            return am
    # fall through to the ambient ``with mesh:`` thread resource
    tr = getattr(_mesh_lib, "thread_resources", None)
    if tr is not None:
        pm = tr.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    return None


# --------------------------------------------------------------------------
# shard_map: promoted to jax.shard_map with check_rep renamed check_vma.
# --------------------------------------------------------------------------
def shard_map(f, mesh=None, in_specs=None, out_specs=None, check_vma=None, **kw):
    """``jax.shard_map`` under both the new and the 0.4.x API.

    Accepts the new-style ``check_vma`` kwarg and translates it to
    ``check_rep`` on toolchains that predate the rename.
    """
    new = getattr(jax, "shard_map", None)
    if new is not None:
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# --------------------------------------------------------------------------
# compiled.cost_analysis(): dict on new JAX, list of per-device dicts on
# the pinned 0.4.x toolchain.
# --------------------------------------------------------------------------
def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` to one flat dict (device 0)."""
    try:
        cost = compiled.cost_analysis()
    except Exception:  # pragma: no cover - backend-dependent
        return {}
    if cost is None:
        return {}
    if isinstance(cost, dict):
        return cost
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost and isinstance(cost[0], dict) else {}
    return {}


# --------------------------------------------------------------------------
# Backend detection (used by the kernel dispatch layer).
# --------------------------------------------------------------------------
def default_backend() -> str:
    try:
        return jax.default_backend()
    except Exception:  # pragma: no cover - uninitialized runtime
        return "cpu"


def is_tpu_backend() -> bool:
    return default_backend() == "tpu"


def interpret_default() -> bool:
    """True when pallas kernels need interpret mode on this host."""
    return not is_tpu_backend()


require_min_jax("repro.compat", MIN_JAX_VERSION)
