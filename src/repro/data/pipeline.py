"""Deterministic, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step) via numpy Philox streams, so:
  * restarts reproduce the exact token stream (fault-tolerance requirement —
    a restored step re-sees its original batch);
  * each host can generate only its slice (process_index-aware) — no data
    redistribution collective at scale;
  * a background prefetch thread hides generation latency.

The "corpus" is a Zipf-distributed token stream with locally-coherent spans,
which exercises embedding gathers realistically (hot vocab rows) without
shipping a dataset.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLMDataset:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        zipf_a: float = 1.2,
        modality: Optional[Dict[str, tuple]] = None,  # extra float inputs
    ):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.zipf_a = zipf_a
        self.modality = modality or {}

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.Philox(key=self.seed, counter=step)
        )

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # zipf over a shuffled alias of the vocab; clipped into range
        raw = rng.zipf(self.zipf_a, size=(B, S + 1)).astype(np.int64)
        toks = (raw * 2654435761) % V  # hash spreads hot ids across the table
        # locally-coherent spans: repeat the previous token with p=0.2
        rep = rng.random((B, S + 1)) < 0.2
        for j in range(1, S + 1):
            toks[:, j] = np.where(rep[:, j], toks[:, j - 1], toks[:, j])
        out = {
            "tokens": toks[:, :S].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        for name, shape in self.modality.items():
            out[name] = rng.normal(size=(B,) + shape).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of host batches (+ optional device put)."""

    def __init__(self, dataset: SyntheticLMDataset, depth: int = 2,
                 start_step: int = 0, shardings=None):
        self._ds = dataset
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._shardings = shardings
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._ds.batch(step)
            if self._shardings is not None:
                batch = {
                    k: jax.device_put(v, self._shardings.get(k))
                    for k, v in batch.items()
                }
            self._q.put((step, batch))
            step += 1

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass


def make_batch_specs(cfg, shape, dtype_tokens=jnp.int32):
    """ShapeDtypeStructs for a (cfg, shape) training batch — dry-run input."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), dtype_tokens),
        "labels": jax.ShapeDtypeStruct((B, S), dtype_tokens),
    }
    if cfg.family == "vlm":
        specs["vision"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_vision), jnp.float32
        )
    if cfg.family == "enc_dec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_audio), jnp.float32
        )
    return specs
