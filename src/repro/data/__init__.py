from repro.data.pipeline import SyntheticLMDataset, Prefetcher, make_batch_specs  # noqa: F401
