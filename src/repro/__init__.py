"""repro — MCBP (MICRO 2025) bit-slice LLM framework on JAX + Pallas.

Layers: ``core`` (paper algorithms), ``kernels`` (Pallas TPU), ``models``
(10-arch zoo), ``distributed``/``optim``/``training``/``serving``/``data``/
``checkpoint``/``runtime`` (substrates), ``configs`` + ``launch`` (entry
points, multi-pod dry-run).
"""

__version__ = "0.1.0"
