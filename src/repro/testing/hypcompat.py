"""Hypothesis compatibility layer for the property-test suites.

``from repro.testing.hypcompat import given, settings, st`` resolves to the
real `hypothesis <https://hypothesis.readthedocs.io>`_ package when it is
installed (the declared test extra), and otherwise to a small deterministic
fallback implementing the subset this repo's suites use:

  ``@given(st.integers(...), st.floats(...), st.sampled_from(...))``
  ``@settings(max_examples=N, deadline=None)``

The fallback draws ``max_examples`` pseudo-random examples per test from a
seed derived from the test's qualified name (stable across runs and
machines — CPython seeds ``random.Random`` from a string via sha512), always
including the strategy boundary values first.  It has no shrinking and no
example database; it exists so the property suites still RUN as randomized
round-trip checks on hosts where hypothesis cannot be installed, rather
than being skipped wholesale.
"""

from __future__ import annotations

try:  # the real thing, when available
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # deterministic fallback
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        """One drawable value source; ``boundaries`` are emitted first."""

        def __init__(self, draw, boundaries=()):
            self._draw = draw
            self.boundaries = tuple(boundaries)

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2**63) if min_value is None else min_value
            hi = 2**63 - 1 if max_value is None else max_value
            return _Strategy(
                lambda rng: rng.randint(lo, hi),
                boundaries=(lo, hi, 0) if lo <= 0 <= hi else (lo, hi),
            )

        @staticmethod
        def floats(min_value=None, max_value=None, **_kw):
            lo = 0.0 if min_value is None else float(min_value)
            hi = 1.0 if max_value is None else float(max_value)
            return _Strategy(
                lambda rng: rng.uniform(lo, hi), boundaries=(lo, hi)
            )

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            if not seq:
                raise ValueError("sampled_from requires a non-empty sequence")
            # every element is a boundary: small pools get full coverage
            return _Strategy(lambda rng: rng.choice(seq), boundaries=seq)

        @staticmethod
        def booleans():
            return _Strategy(
                lambda rng: bool(rng.getrandbits(1)), boundaries=(False, True)
            )

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Record the example budget; deadline/database knobs are no-ops."""

        def apply(func):
            func._hypcompat_max_examples = max_examples
            return func

        return apply

    def given(*strategies):
        """Run the test once per drawn example tuple, boundaries first."""

        def decorate(func):
            n_strats = len(strategies)

            @functools.wraps(func)
            def wrapper(*args, **kwargs):
                max_examples = getattr(
                    wrapper, "_hypcompat_max_examples", _DEFAULT_MAX_EXAMPLES
                )
                seed = f"{func.__module__}.{func.__qualname__}"
                rng = random.Random(seed)
                # boundary sweep: i-th example takes each strategy's i-th
                # boundary (cycling), so min/max/every-pool-element appear
                n_boundary = min(
                    max(len(s.boundaries) for s in strategies), max_examples
                )
                for i in range(max_examples):
                    if i < n_boundary:
                        drawn = tuple(
                            s.boundaries[i % len(s.boundaries)]
                            for s in strategies
                        )
                    else:
                        drawn = tuple(s.example(rng) for s in strategies)
                    try:
                        func(*args, *drawn, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example ({i + 1}/{max_examples}) "
                            f"for {func.__qualname__}: args={drawn!r}"
                        ) from e

            # hide the strategy-bound parameters from pytest's fixture
            # resolution: expose only the leading (self / fixture) params
            params = list(inspect.signature(func).parameters.values())
            wrapper.__signature__ = inspect.Signature(params[: -n_strats or None])
            del wrapper.__wrapped__
            return wrapper

        return decorate
