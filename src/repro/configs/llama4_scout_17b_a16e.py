"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert, chunked local attention
(8192) 3:1 local:global (iRoPE), early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        num_experts=16,
        experts_per_token=1,
        moe_shared_ff=8192,
        chunk_attention=8192,
        global_every=4,  # 3 chunked-local : 1 global
        rope_theta=500_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        family="moe",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        moe_capacity_factor=8.0,
        experts_per_token=1,
        moe_shared_ff=128,
        chunk_attention=16,
        global_every=4,
        rope_theta=500_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=False,
        dtype="float32",
    )


register("llama4-scout-17b-a16e", full, smoke)
