"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216
— SigLIP + gemma backbone.  The SigLIP frontend is a STUB per the brief:
input_specs() supplies 256 precomputed patch embeddings (d_vision=1152).
[arXiv:2407.07726; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257_216,
        rope_theta=10_000.0,
        activation="geglu",
        embed_scale=True,
        norm="rms",
        tie_embeddings=True,
        vision_tokens=256,
        d_vision=1152,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        rope_theta=10_000.0,
        activation="geglu",
        embed_scale=True,
        norm="rms",
        tie_embeddings=True,
        vision_tokens=16,
        d_vision=32,
        dtype="float32",
    )


register("paligemma-3b", full, smoke)
