"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144,
5:1 local:global sliding attention, 128k (32k for the 1b) context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        num_layers=26,
        d_model=1152,
        num_heads=4,
        num_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab_size=262_144,
        sliding_window=512,
        global_every=6,
        rope_theta=1_000_000.0,
        rope_theta_local=10_000.0,
        activation="geglu",
        qk_norm=True,
        embed_scale=True,
        post_norms=True,
        norm="rms",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-smoke",
        family="dense",
        num_layers=6,
        d_model=48,
        num_heads=2,
        num_kv_heads=1,
        head_dim=24,
        d_ff=96,
        vocab_size=512,
        sliding_window=16,
        global_every=6,
        rope_theta=1_000_000.0,
        rope_theta_local=10_000.0,
        activation="geglu",
        qk_norm=True,
        embed_scale=True,
        post_norms=True,
        norm="rms",
        tie_embeddings=True,
        dtype="float32",
    )


register("gemma3-1b", full, smoke)
