"""Assigned input shapes (every arch × these four = the 40-cell matrix).

``train_4k``/``prefill_32k`` lower train/prefill steps; ``decode_32k``/
``long_500k`` lower ``serve_step`` (one new token against a seq_len KV
cache).  ``long_500k`` requires sub-quadratic attention — the skip table in
``applicable`` mirrors DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: List[ShapeConfig] = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]
SHAPE_BY_NAME = {s.name: s for s in SHAPES}

# archs with a sub-quadratic long-context story (see DESIGN.md §4)
_LONG_OK = {
    "gemma3-4b",       # 5:1 local:global sliding window
    "gemma3-1b",
    "mixtral-8x22b",   # SWA
    "llama4-scout-17b-a16e",  # chunked local 3:1
    "mamba2-1.3b",     # O(1) state
    "jamba-1.5-large-398b",   # 1:7 attn:mamba
}


def applicable(arch: str, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; otherwise the documented skip reason."""
    if shape.name == "long_500k" and arch not in _LONG_OK:
        return "pure full-attention arch: long_500k needs sub-quadratic attention"
    return None


def get_shape(name: str) -> ShapeConfig:
    return SHAPE_BY_NAME[name]
