"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global sliding attention, 128k context.
[hf:google/gemma-3-4b-pt; unverified]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262_144,
        sliding_window=1024,
        global_every=6,  # 5 local : 1 global
        rope_theta=1_000_000.0,
        rope_theta_local=10_000.0,
        activation="geglu",
        qk_norm=True,
        embed_scale=True,
        post_norms=True,
        norm="rms",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-smoke",
        family="dense",
        num_layers=6,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sliding_window=16,
        global_every=6,
        rope_theta=1_000_000.0,
        rope_theta_local=10_000.0,
        activation="geglu",
        qk_norm=True,
        embed_scale=True,
        post_norms=True,
        norm="rms",
        tie_embeddings=True,
        dtype="float32",
    )


register("gemma3-4b", full, smoke)
