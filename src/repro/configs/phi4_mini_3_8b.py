"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA. [arXiv:2412.08905; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200_064,
        rope_theta=10_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        rope_theta=10_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=True,
        dtype="float32",
    )


register("phi4-mini-3.8b", full, smoke)
