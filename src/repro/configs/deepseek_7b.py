"""deepseek-7b [dense]: 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400 — llama-arch. [arXiv:2401.02954; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        family="dense",
        num_layers=30,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=11008,
        vocab_size=102_400,
        rope_theta=10_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        rope_theta=10_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=False,
        dtype="float32",
    )


register("deepseek-7b", full, smoke)
