"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768
MoE 8 experts top-2, SWA (window 4096).  [arXiv:2401.04088; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32_768,
        num_experts=8,
        experts_per_token=2,
        sliding_window=4096,
        rope_theta=1_000_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        moe_capacity_factor=8.0,
        experts_per_token=2,
        sliding_window=16,
        rope_theta=1_000_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=False,
        dtype="float32",
    )


register("mixtral-8x22b", full, smoke)
