"""whisper-medium [audio]: 24L(enc)+24L(dec) d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865 — enc-dec; conv frontend is a STUB (input_specs()
supplies 1500 precomputed post-conv frame embeddings).  Decoder positions are
sinusoidal-extended beyond the checkpoint's 448 so decode_32k lowers
mechanically (DESIGN.md §4).  [arXiv:2212.04356; unverified]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="enc_dec",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51_865,
        encoder_layers=24,
        encoder_seq=1500,
        d_audio=1024,
        activation="gelu",
        norm="ln",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="enc_dec",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        encoder_layers=2,
        encoder_seq=32,
        d_audio=64,
        activation="gelu",
        norm="ln",
        tie_embeddings=True,
        dtype="float32",
    )


register("whisper-medium", full, smoke)
