"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave (attention at
layer i where i % 8 == 4), MoE every other layer.  [arXiv:2403.19887; hf]

Requires FSDP weight sharding + int8/ZeRO optimizer states to fit 16 GB/chip
(DESIGN.md §4).  The mamba mixer uses our SSD (mamba2) block — recorded as an
adaptation since Jamba ships Mamba-1 internals."""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65_536,
        num_experts=16,
        experts_per_token=2,
        moe_every=2,
        moe_offset=1,
        attn_every=8,
        attn_offset=4,
        ssm_state=128,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=128,
        rope_theta=10_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        num_layers=8,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        moe_capacity_factor=8.0,
        experts_per_token=2,
        moe_every=2,
        moe_offset=1,
        attn_every=8,
        attn_offset=4,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=32,
        rope_theta=10_000.0,
        activation="swiglu",
        norm="rms",
        tie_embeddings=False,
        dtype="float32",
    )


register("jamba-1.5-large-398b", full, smoke)
