"""mamba2-1.3b [ssm]: 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  BGPP is inapplicable (no KV
cache, DESIGN.md §6); BRCR/BSTC apply to all projections.
[arXiv:2405.21060; unverified]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        ssm_state=128,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=64,
        norm="rms",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=512,
        ssm_state=16,
        ssm_conv=4,
        ssm_expand=2,
        ssm_head_dim=32,
        norm="rms",
        tie_embeddings=True,
        dtype="float32",
    )


register("mamba2-1.3b", full, smoke)
