"""Model configuration schema + architecture registry.

One ``ModelConfig`` drives every family in the zoo (dense/GQA transformer,
MoE, SSM, hybrid, encoder-decoder, VLM).  Each assigned architecture file
registers its exact published config plus a reduced ``smoke`` variant used by
the CPU smoke tests (the full config is only ever lowered via the dry-run's
ShapeDtypeStructs — no allocation).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Optional, Tuple

# --------------------------------------------------------------------------
# MCBP feature switches (the paper's three techniques).
# --------------------------------------------------------------------------

# serve-time weight numerics (repro.serving.weights consumes the knob at
# make_serve_step build time; REPRO_WEIGHT_FORMAT overrides for CI matrices)
WEIGHT_FORMATS = ("bf16", "int8", "bstc")


@dataclasses.dataclass(frozen=True)
class MCBPOptions:
    enabled: bool = False
    # BRCR
    group_size: int = 4  # paper §5.2 DSE: m=4
    weight_bits: int = 8  # INT8 weights (7 magnitude bits + sign)
    # BSTC
    # deprecated: bstc_weights=True is shimmed to weight_format="bstc" in
    # __post_init__ (the two knobs used to be able to contradict each other)
    bstc_weights: bool = False
    bstc_threshold: float = 0.65
    # BGPP
    bgpp_attention: bool = False  # progressive bit-grained top-k on decode
    bgpp_rounds: int = 4
    bgpp_alpha: float = 0.55  # paper §6: 0.5-0.6
    bgpp_radius: float = 3.0
    bgpp_keep_ratio: float = 0.25  # k_max = ceil(ratio * S) for static gather
    # weight numerics for serving: "bf16" | "int8" | "bstc" — resolved once
    # at make_serve_step build (see repro.serving.weights)
    weight_format: str = "bf16"
    # global-layer decode attend routing: "auto" | "jnp" | "interpret" |
    # "kernel" — auto = compiled Pallas kernel on TPU backends, legacy jnp
    # attend elsewhere (see repro.serving.kernel_decode)
    decode_kernel: str = "auto"
    # speculative decoding (repro.serving.spec_decode): propose draft_gamma
    # tokens per slot with a truncated-bit-plane forward, verify batched
    # through serve_step, accept/rollback per slot.  Greedy output is
    # bit-identical to non-speculative decode; REPRO_SPEC_DECODE /
    # REPRO_DRAFT_GAMMA / REPRO_DRAFT_PLANES override for CI matrices.
    spec_decode: bool = False
    draft_gamma: int = 4
    # MSB magnitude bit-planes the draft weights keep (1..8 of int8's 7
    # magnitude bits + sign; >= 7 keeps full int8 precision)
    draft_planes: int = 4

    def __post_init__(self):
        if self.bstc_weights:
            warnings.warn(
                "MCBPOptions.bstc_weights is deprecated — set "
                "weight_format='bstc' instead (bstc_weights=True is mapped "
                "to it; an explicit non-bf16 weight_format wins)",
                DeprecationWarning,
                stacklevel=2,
            )
            if self.weight_format == "bf16":
                object.__setattr__(self, "weight_format", "bstc")
        if self.weight_format not in WEIGHT_FORMATS:
            raise ValueError(
                f"weight_format={self.weight_format!r} is not one of "
                f"{WEIGHT_FORMATS} (config mcbp.weight_format or "
                f"$REPRO_WEIGHT_FORMAT)"
            )
        if not 1 <= int(self.draft_gamma):
            raise ValueError(
                f"draft_gamma={self.draft_gamma!r} must be >= 1 (tokens "
                f"drafted per speculative round)"
            )
        if not 1 <= int(self.draft_planes) <= 8:
            raise ValueError(
                f"draft_planes={self.draft_planes!r} must be in 1..8 (MSB "
                f"magnitude bit-planes the draft weights keep)"
            )


def apply_decode_kernel_override(cfg, mode: Optional[str] = None):
    """Return ``cfg`` with its ``decode_kernel`` knob replaced (``None``
    keeps the config's value) — the one code path behind every CLI's
    ``--decode-kernel`` flag."""
    if mode is None:
        return cfg
    return dataclasses.replace(
        cfg, mcbp=dataclasses.replace(cfg.mcbp, decode_kernel=str(mode))
    )


def apply_weight_format_override(cfg, fmt: Optional[str] = None):
    """Return ``cfg`` with its ``weight_format`` knob replaced (``None``
    keeps the config's value) — the one code path behind every CLI's
    ``--weight-format`` flag.  Validation happens in
    :meth:`MCBPOptions.__post_init__`, so a typo raises here, at config
    time."""
    if fmt is None:
        return cfg
    return dataclasses.replace(
        cfg, mcbp=dataclasses.replace(cfg.mcbp, weight_format=str(fmt))
    )


def apply_spec_decode_overrides(cfg, enabled: Optional[bool] = None,
                                gamma: Optional[int] = None,
                                planes: Optional[int] = None):
    """Return ``cfg`` with its speculative-decoding knobs replaced
    (``None`` keeps the config's value) — the one code path behind every
    CLI's ``--spec-decode`` / ``--draft-gamma`` / ``--draft-planes``
    flags.  Validation happens in :meth:`MCBPOptions.__post_init__`."""
    if enabled is None and gamma is None and planes is None:
        return cfg
    mo = dataclasses.replace(
        cfg.mcbp,
        spec_decode=cfg.mcbp.spec_decode if enabled is None else bool(enabled),
        draft_gamma=cfg.mcbp.draft_gamma if gamma is None else int(gamma),
        draft_planes=cfg.mcbp.draft_planes if planes is None else int(planes),
    )
    return dataclasses.replace(cfg, mcbp=mo)


def apply_bgpp_overrides(cfg, rounds: Optional[int] = None,
                         keep_ratio: Optional[float] = None):
    """Return ``cfg`` with its BGPP decode knobs replaced (``None`` keeps
    the config's value) — the one code path behind every CLI's
    ``--bgpp-rounds`` / ``--bgpp-keep-ratio`` flags."""
    if rounds is None and keep_ratio is None:
        return cfg
    mo = dataclasses.replace(
        cfg.mcbp,
        bgpp_rounds=cfg.mcbp.bgpp_rounds if rounds is None else int(rounds),
        bgpp_keep_ratio=cfg.mcbp.bgpp_keep_ratio if keep_ratio is None
        else float(keep_ratio),
    )
    return dataclasses.replace(cfg, mcbp=mo)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | enc_dec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention structure
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0  # gemma3: local layers use a different base
    sliding_window: int = 0  # window for local layers (0 = none)
    global_every: int = 0  # layer i is global iff (i+1) % global_every == 0
    chunk_attention: int = 0  # llama4 chunked-local size (0 = off)
    attn_logit_softcap: float = 0.0
    qk_norm: bool = False
    embed_scale: bool = False  # gemma: multiply embeddings by sqrt(d_model)
    post_norms: bool = False  # gemma3 sandwich norms

    # FFN / MoE
    activation: str = "swiglu"  # swiglu | geglu | gelu
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # layer i is MoE iff num_experts>0 and i % moe_every == moe_offset
    moe_offset: int = 0
    moe_shared_ff: int = 0  # llama4 shared expert width (0 = none)
    moe_capacity_factor: float = 1.25  # GShard capacity (smokes use dropless)

    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0  # hybrid: layer i is attention iff i % attn_every == attn_offset
    attn_offset: int = 0

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder length (1500 frames post-conv)
    d_audio: int = 0  # stub frontend embedding width

    # VLM (paligemma)
    vision_tokens: int = 0
    d_vision: int = 0

    norm: str = "rms"  # rms | ln
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    mcbp: MCBPOptions = MCBPOptions()

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_is_global_attn(self, i: int) -> bool:
        if self.sliding_window <= 0:
            return self.chunk_attention <= 0  # chunked archs: global_every rule
        if self.global_every <= 0:
            return False
        return (i + 1) % self.global_every == 0

    def layer_attn_window(self, i: int) -> Tuple[str, int]:
        """(mask_kind, window) for layer i."""
        if self.chunk_attention > 0:
            if self.global_every > 0 and (i + 1) % self.global_every == 0:
                return ("causal", 0)
            return ("chunked", self.chunk_attention)
        if self.sliding_window > 0:
            if self.global_every > 0 and (i + 1) % self.global_every == 0:
                return ("causal", 0)
            return ("sliding", self.sliding_window)
        return ("causal", 0)

    def layer_is_moe(self, i: int) -> bool:
        return self.num_experts > 0 and i % self.moe_every == self.moe_offset

    def layer_is_attention(self, i: int) -> bool:
        """hybrid archs: attention vs mamba mixer."""
        if self.family != "hybrid":
            return self.family != "ssm"
        return self.attn_every > 0 and i % self.attn_every == self.attn_offset

    def active_params(self) -> int:
        """Approximate active (per-token) parameter count."""
        return _param_count(self, active_only=True)

    def total_params(self) -> int:
        return _param_count(self, active_only=False)


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
    return mult * cfg.d_model * d_ff


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d  # embeddings
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d
    for i in range(cfg.num_layers):
        if cfg.layer_is_attention(i):
            total += d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        else:  # mamba mixer
            d_in = cfg.ssm_expand * d
            nheads = d_in // cfg.ssm_head_dim
            total += d * (2 * d_in + 2 * cfg.ssm_state + nheads) + d_in * d
        if cfg.family == "ssm":
            continue  # mamba2 interleaves mixers only, no separate FFN
        if cfg.layer_is_moe(i):
            e = cfg.experts_per_token if active_only else cfg.num_experts
            total += e * _ffn_params(cfg, cfg.d_ff) + d * cfg.num_experts
            if cfg.moe_shared_ff:
                total += _ffn_params(cfg, cfg.moe_shared_ff)
        else:
            total += _ffn_params(cfg, cfg.d_ff)
    # encoder (whisper) roughly mirrors decoder self-attn + ffn
    for _ in range(cfg.encoder_layers):
        total += 4 * cfg.d_model * cfg.q_dim + _ffn_params(cfg, cfg.d_ff)
    return total


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

ARCH_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
SMOKE_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig], smoke: Callable[[], ModelConfig]):
    ARCH_REGISTRY[name] = full
    SMOKE_REGISTRY[name] = smoke


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    reg = SMOKE_REGISTRY if smoke else ARCH_REGISTRY
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]()
