from repro.configs.base import (  # noqa: F401
    ARCH_REGISTRY,
    MCBPOptions,
    ModelConfig,
    WEIGHT_FORMATS,
    apply_bgpp_overrides,
    apply_decode_kernel_override,
    apply_spec_decode_overrides,
    apply_weight_format_override,
    get_config,
)
from repro.configs import shapes  # noqa: F401

# import for registry side effects
from repro.configs import (  # noqa: F401
    deepseek_7b,
    gemma3_1b,
    gemma3_4b,
    jamba_1_5_large_398b,
    llama4_scout_17b_a16e,
    mamba2_1_3b,
    mixtral_8x22b,
    paligemma_3b,
    phi4_mini_3_8b,
    whisper_medium,
)
