"""Training step: loss, grads, AdamW update — built per (config, rules).

Supports remat (blocked attention already checkpoints its KV scan; the layer
scans are rematerialized via jax.checkpoint when ``remat=True``), gradient
accumulation (microbatching over the leading batch dim), and the int8/ZeRO
optimizer.  The returned function is pure and pjit-compatible — the dry-run
lowers it directly for the train_4k / prefill_32k cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.models import model_zoo
from repro.optim import AdamWConfig, adamw_init, adamw_update

Tree = Any


@dataclasses.dataclass
class TrainState:
    params: Tree
    opt: Tree

    @classmethod
    def create(cls, params: Tree, opt_cfg: AdamWConfig) -> "TrainState":
        return cls(params=params, opt=adamw_init(params, opt_cfg))


def _as_tree(state: TrainState) -> Tree:
    return {"params": state.params, "opt": state.opt}


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; labels < 0 are masked out.

    Written as ``lse(logits) - logits[label]`` so the (B,S,V) tensor is only
    consumed by fused reductions/gathers — no f32 log-softmax copy is ever
    materialized (matters at vocab 200k+: that copy alone is ~4 GB/device
    on the train_4k cells).
    """
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(
        jnp.sum(jnp.exp(shifted.astype(jnp.float32)), axis=-1)
    )
    gold = jnp.take_along_axis(shifted, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def make_loss_fn(cfg, rules: sh.ShardingRules, fwd_kwargs: Optional[Dict] = None):
    fwd_kwargs = fwd_kwargs or {}

    def loss_fn(params, batch):
        logits, aux = model_zoo.forward(params, cfg, batch, rules, **fwd_kwargs)
        labels = batch["labels"]
        logits = logits[:, -labels.shape[1] :]  # drop VLM prefix positions
        ce = cross_entropy(logits, labels)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(
    cfg,
    rules: sh.ShardingRules,
    opt_cfg: AdamWConfig,
    fwd_kwargs: Optional[Dict] = None,
    grad_accum: int = 1,
    param_specs: Optional[Tree] = None,
) -> Callable[[Tree, Dict[str, jax.Array]], Tuple[Tree, Dict[str, jax.Array]]]:
    """Returns train_step(state_tree, batch) -> (state_tree, metrics).

    ``state_tree`` = {"params": ..., "opt": ...} (a plain pytree so the
    dry-run can build ShapeDtypeStructs for it).  ``param_specs`` (logical
    axes) keeps the grad-accumulation scan carry sharded — GSPMD's while
    propagation otherwise replicates it (≈ a full param copy per device).
    """
    loss_fn = make_loss_fn(cfg, rules, fwd_kwargs)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_grads(tree: Tree) -> Tree:
        if param_specs is None:
            return tree
        is_leaf = lambda x: isinstance(x, tuple)
        flat_s, treedef = jax.tree_util.tree_flatten(param_specs, is_leaf=is_leaf)
        flat_t = treedef.flatten_up_to(tree)
        return jax.tree_util.tree_unflatten(
            treedef,
            [sh.constrain(t, rules, ax) for t, ax in zip(flat_t, flat_s)],
        )

    def one_grad(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(state: Tree, batch: Dict[str, jax.Array]):
        params = state["params"]
        if grad_accum > 1:
            # microbatch over the leading batch dim (static split)
            def micro(carry, mb):
                loss, metrics, grads = one_grad(params, mb)
                acc_loss, acc_grads = carry
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_loss + loss, constrain_grads(acc_grads)), metrics

            mbs = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
                batch,
            )
            zero = constrain_grads(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (loss, grads), metrics = jax.lax.scan(micro, (0.0, zero), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = one_grad(params, batch)

        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state["opt"], opt_cfg
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg, rules: sh.ShardingRules, fwd_kwargs: Optional[Dict] = None):
    """Inference prefill: forward only, returns last-position logits.

    This is what the prefill_32k cells lower: the full forward at 32k with
    blocked attention, no gradient state.
    """
    fwd_kwargs = fwd_kwargs or {}

    def prefill_step(params, batch):
        logits, _ = model_zoo.forward(params, cfg, batch, rules, **fwd_kwargs)
        return logits[:, -1:]

    return prefill_step
