from repro.training.train_step import TrainState, make_train_step, make_loss_fn  # noqa: F401
