"""Fault tolerance for long multi-pod runs.

Pieces (each independently testable; composed by ``run_resilient`` and the
training loop):

  Heartbeat        — per-host liveness file the cluster agent watches; a
                     stale heartbeat triggers external restart (the standard
                     TPU-pod pattern: the *scheduler* replaces hardware, the
                     job just has to checkpoint + restart fast).
  StragglerMonitor — EMA step-time watchdog; flags steps slower than
                     k × median.  On TPU SPMD a straggler is indistinguishable
                     from a slow host, so mitigation = report + (optionally)
                     trigger a checkpoint so the scheduler can evict it.
  run_resilient    — retry harness around the step loop: on failure, restore
                     the latest checkpoint and continue, with bounded retries
                     and exponential backoff.  Deterministic data (pipeline
                     is a pure f(step)) makes the replay exact.
  elastic rescale  — rebuilding the mesh with fewer/more hosts and restoring
                     the (unsharded-on-disk) checkpoint under new shardings;
                     see Checkpointer.restore(shardings=...).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, List, Optional

import numpy as np


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 5.0, payload: Optional[dict] = None):
        self.path = path
        self.interval_s = interval_s
        self.payload = payload or {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def beat(self, **extra):
        data = {"time": time.time(), **self.payload, **extra}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(data, f)
        os.replace(tmp, self.path)

    def _run(self):
        while not self._stop.is_set():
            self.beat()
            self._stop.wait(self.interval_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2 * self.interval_s)

    @staticmethod
    def is_alive(path: str, timeout_s: float) -> bool:
        try:
            with open(path) as f:
                t = json.load(f)["time"]
            return (time.time() - t) < timeout_s
        except (OSError, ValueError, KeyError):
            return False


class StragglerMonitor:
    """Flags steps slower than ``threshold ×`` the rolling median."""

    def __init__(self, threshold: float = 2.0, window: int = 50, min_steps: int = 8):
        self.threshold = threshold
        self.window = window
        self.min_steps = min_steps
        self.times: List[float] = []
        self.flags: List[int] = []

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        self.times = self.times[-self.window :]
        if len(self.times) < self.min_steps:
            return False
        med = float(np.median(self.times))
        if seconds > self.threshold * med:
            self.flags.append(step)
            return True
        return False

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


def run_resilient(
    step_fn: Callable[[int], None],
    start_step: int,
    num_steps: int,
    restore_fn: Callable[[], int],
    max_failures: int = 3,
    backoff_s: float = 0.1,
    on_failure: Optional[Callable[[int, Exception], None]] = None,
) -> int:
    """Run ``step_fn(step)`` for steps [start, start+num); on exception,
    call ``restore_fn() -> restored_step`` and resume from there.

    Returns the number of failures survived.  Raises after ``max_failures``.
    """
    failures = 0
    step = start_step
    end = start_step + num_steps
    while step < end:
        try:
            step_fn(step)
            step += 1
        except Exception as e:  # noqa: BLE001 — the harness must catch all
            failures += 1
            if on_failure is not None:
                on_failure(step, e)
            if failures > max_failures:
                raise
            time.sleep(backoff_s * (2 ** (failures - 1)))
            step = restore_fn()
    return failures
