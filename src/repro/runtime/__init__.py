from repro.runtime.fault_tolerance import (  # noqa: F401
    Heartbeat,
    StragglerMonitor,
    run_resilient,
)
