"""Mixture-of-Experts FFN (mixtral 8e/top-2, llama4-scout 16e/top-1 + shared
expert, jamba 16e/top-2) with capacity-based GShard dispatch.

Dispatch/combine are expressed as one-hot einsums so the SPMD partitioner can
choose collectives; two sharding modes:

  * ``tp`` (default) — every expert's FFN is tensor-parallel over "model"
    (works for any expert count, incl. mixtral's 8 < |model|).
  * ``ep`` — the expert dim is sharded over "model" (requires E % |model| == 0
    or |model| % E == 0); dispatch becomes an all-to-all-shaped collective.
    A §Perf knob for the collective-bound hillclimb cells.

Aux losses: switch load-balance loss + router z-loss (returned to train_step).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.models import layers


def moe_specs(cfg, ep: bool = False):
    expert_axis = sh.EXPERT if ep else None
    ff_axis = None if ep else sh.FF
    specs = {
        "router": (sh.D_MODEL, None),
        "gate": (expert_axis, sh.D_MODEL, ff_axis),
        "up": (expert_axis, sh.D_MODEL, ff_axis),
        "down": (expert_axis, ff_axis, sh.D_MODEL),
    }
    if cfg.moe_shared_ff:
        specs["shared"] = layers.mlp_specs(cfg.activation)
    return specs


def moe_init(key, cfg, dtype, ep: bool = False):
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    params = {
        "router": layers.dense_init(ks[0], d, E, dtype, scale=s),
        "gate": jax.random.normal(ks[1], (E, d, f), dtype) * jnp.asarray(s, dtype),
        "up": jax.random.normal(ks[2], (E, d, f), dtype) * jnp.asarray(s, dtype),
        "down": jax.random.normal(ks[3], (E, f, d), dtype)
        * jnp.asarray(1.0 / math.sqrt(f), dtype),
    }
    if cfg.moe_shared_ff:
        params["shared"], _ = layers.mlp_init(
            ks[4], d, cfg.moe_shared_ff, cfg.activation, dtype
        )
    return params, moe_specs(cfg, ep)


def moe_apply(
    params,
    x: jax.Array,  # (B, S, D)
    cfg,
    capacity_factor: Optional[float] = None,
    rules: Optional[sh.ShardingRules] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar).

    Dispatch is *group-local*: tokens are split into shard-aligned groups
    (``rules.token_groups``), the capacity/slot space lives per group, and
    the scatter/gather never crosses a shard boundary — without this, the
    partitioner all-reduces the whole (E, C, D) capacity buffer over the
    data axis every MoE layer (§Perf iteration B2: 5.3 TB/step on
    mixtral-8x22b train_4k).
    """
    B, S, D = x.shape
    E = cfg.num_experts
    k = cfg.experts_per_token
    T = B * S
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    # group factors aligned per mesh axis: reshapes only ever split ONE
    # sharded dim, so GSPMD keeps everything group-local (B3)
    Gb, Gs = rules.group_sizes(B, S) if rules is not None else (1, 1)
    G = Gb * Gs
    Tg = T // G
    def _pin(t, axes):
        return sh.constrain(t, rules, axes) if rules is not None else t

    xg = x.reshape(Gb, B // Gb, Gs, S // Gs, D)
    xg = jnp.transpose(xg, (0, 2, 1, 3, 4)).reshape(G, Tg, D)
    # SP region boundary: tokens all-gather their seq shards here and stay
    # group(data)-sharded through dispatch/experts/combine
    xg = _pin(xg, (sh.TOKENS, None, None))

    logits = (xg @ params["router"]).astype(jnp.float32)  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (G, Tg, k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    capacity = int(max(1, math.ceil(Tg * k / E * capacity_factor)))
    capacity = min(capacity, Tg * k)

    # position of each (token, slot) within its expert queue (per group)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # (G, Tg, k, E)
    flat = onehot.reshape(G, Tg * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1  # (G, Tg*k, E)
    pos_in_expert = jnp.sum(pos * flat, axis=-1).reshape(G, Tg, k)
    keep = pos_in_expert < capacity

    # scatter/gather dispatch: slot = expert*C + position (never materializes
    # the O(T·E·C) one-hot dispatch tensor — that's terabytes at 65k tokens)
    slot = expert_idx * capacity + pos_in_expert  # (G, Tg, k)
    slot = jnp.where(keep, slot, E * capacity).reshape(G, Tg * k)
    token_ids = jnp.broadcast_to(
        jnp.arange(Tg)[:, None], (Tg, k)
    ).reshape(-1)
    # pin shardings on every intermediate: the scatter/gather ops (and
    # their BACKWARD transposes) otherwise lose the group (data) sharding
    # and the partitioner replicates or partial-sums the expert activations
    # across shards (§Perf iterations B5/B6)
    x_rep = _pin(xg[:, token_ids], (sh.TOKENS, None, None))  # (G, Tg*k, D)
    xe_flat = jnp.zeros((G, E * capacity + 1, D), x.dtype)
    xe_flat = xe_flat.at[jnp.arange(G)[:, None], slot].add(
        x_rep, mode="drop", unique_indices=False
    )
    xe_flat = _pin(xe_flat, (sh.TOKENS, None, None))
    xe = xe_flat[:, : E * capacity].reshape(G, E, capacity, D)
    xe = _pin(xe, (sh.TOKENS, None, None, None))
    g = jnp.einsum("gecd,edf->gecf", xe, params["gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, params["up"])
    h = _pin(layers.glu_act(cfg.activation, g) * u, (sh.TOKENS, None, None, sh.FF))
    ye = jnp.einsum("gecf,efd->gecd", h, params["down"])  # (G, E, C, D)
    ye = _pin(ye, (sh.TOKENS, None, None, None))

    # combine: gather each token's k expert outputs, weight by the gate
    ye_flat = jnp.concatenate(
        [ye.reshape(G, E * capacity, D), jnp.zeros((G, 1, D), ye.dtype)], axis=1
    )
    gathered = jnp.take_along_axis(ye_flat, slot[..., None], axis=1)
    gathered = _pin(gathered.reshape(G, Tg * k, D), (sh.TOKENS, None, None))
    gathered = gathered.reshape(G, Tg, k, D)
    w = (gate_vals * keep.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("gtk,gtkd->gtd", w, gathered)
    y = y.reshape(Gb, Gs, B // Gb, S // Gs, D)
    y = jnp.transpose(y, (0, 2, 1, 3, 4)).reshape(B, S, D)

    if "shared" in params:
        y = y + layers.mlp_apply(params["shared"], x, cfg.activation)

    # aux: switch load-balance + z-loss
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    lb_loss = E * jnp.sum(density * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = lb_loss + 1e-3 * z_loss
    return y, aux
