"""Jamba-style hybrid LM: Mamba+attention 1:7 interleave with alternating
MoE/MLP FFNs (jamba-1.5-large: attention at i%8==4, MoE at odd i).

The layer pattern repeats with period ``attn_every`` (8), so the model scans
over *super-blocks*: params are stacked (num_layers/period, ...) per
in-block position, the block body is python-unrolled (heterogeneous kinds),
and the scan amortizes compile cost across the 9 blocks.  Attention layers
use no RoPE (position comes from the mamba mixers, as in Jamba).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import attention
from repro.distributed import sharding as sh
from repro.models import layers, mamba2, moe

Params = Dict[str, Any]


def _period(cfg) -> int:
    assert cfg.attn_every > 0 and cfg.num_layers % cfg.attn_every == 0
    assert cfg.attn_every % cfg.moe_every == 0
    return cfg.attn_every


def _pos_kinds(cfg, j: int) -> Tuple[bool, bool]:
    """(is_attention, is_moe) for in-block position j."""
    return (
        j % cfg.attn_every == cfg.attn_offset,
        cfg.num_experts > 0 and j % cfg.moe_every == cfg.moe_offset,
    )


def _pos_specs(cfg, j: int) -> Params:
    is_attn, is_moe = _pos_kinds(cfg, j)
    s: Params = {"norm1": layers.norm_specs(cfg.norm)}
    if is_attn:
        s["attn"] = layers.attention_specs()
    else:
        s["mamba"] = mamba2.mixer_specs()
    s["norm2"] = layers.norm_specs(cfg.norm)
    if is_moe:
        s["moe"] = moe.moe_specs(cfg)
    else:
        s["mlp"] = layers.mlp_specs(cfg.activation)
    return s


def param_specs(cfg) -> Params:
    period = _period(cfg)
    specs: Params = {"embed": (sh.VOCAB, sh.D_MODEL)}
    specs["blocks"] = {
        f"pos{j}": jax.tree.map(
            lambda axes: (sh.LAYERS,) + tuple(axes), _pos_specs(cfg, j),
            is_leaf=lambda x: isinstance(x, tuple),
        )
        for j in range(period)
    }
    specs["final_norm"] = layers.norm_specs(cfg.norm)
    if not cfg.tie_embeddings:
        specs["lm_head"] = (sh.D_MODEL, sh.VOCAB)
    return specs


def _pos_init(key, cfg, dtype, j: int):
    is_attn, is_moe = _pos_kinds(cfg, j)
    ks = jax.random.split(key, 2)
    p: Params = {}
    p["norm1"], _ = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    if is_attn:
        p["attn"], _ = layers.attention_init(
            ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
        )
    else:
        p["mamba"], _ = mamba2.mixer_init(ks[0], cfg, dtype)
    p["norm2"], _ = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    if is_moe:
        p["moe"], _ = moe.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"], _ = layers.mlp_init(
            ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dtype
        )
    return p, _pos_specs(cfg, j)


def init(key, cfg) -> Tuple[Params, Params]:
    dtype = layers._dtype(cfg.dtype)
    period = _period(cfg)
    n_blocks = cfg.num_layers // period
    k_embed, k_blocks, k_head = jax.random.split(key, 3)

    params: Params = {
        "embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype)
    }
    pos_keys = jax.random.split(k_blocks, period)
    params["blocks"] = {
        f"pos{j}": jax.vmap(lambda k, jj=j: _pos_init(k, cfg, dtype, jj)[0])(
            jax.random.split(pos_keys[j], n_blocks)
        )
        for j in range(period)
    }
    params["final_norm"], _ = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params, param_specs(cfg)


def _attn_layer(p, cfg, x, rules, block_q, block_k):
    h = layers.apply_norm(x, p["norm1"], cfg.norm)
    q, k, v = layers.qkv_project(
        p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
        positions=None, rope_theta=cfg.rope_theta,
    )
    q = sh.constrain(q, rules, (sh.BATCH, None, sh.HEADS, None))
    out = attention.blocked_attend(
        q, k, v, mask_kind="causal", block_q=block_q, block_k=block_k
    )
    B, S, _, _ = out.shape
    return out.reshape(B, S, -1) @ p["attn"]["wo"]


def _ffn_layer(p, cfg, x, rules=None):
    h = layers.apply_norm(x, p["norm2"], cfg.norm)
    if "moe" in p:
        return moe.moe_apply(p["moe"], h, cfg, rules=rules)
    return layers.mlp_apply(p["mlp"], h, cfg.activation), 0.0


def forward(
    params, cfg, tokens, rules=sh.ShardingRules(),
    block_q: int = 512, block_k: int = 1024, ssd_chunk: int = 256,
    remat: bool = False,
):
    dtype = layers._dtype(cfg.dtype)
    period = _period(cfg)
    x = params["embed"][tokens].astype(dtype)
    x = sh.constrain(x, rules, (sh.BATCH, sh.SEQ, None))

    def body(carry, block):
        x, aux = carry
        for j in range(period):
            p = block[f"pos{j}"]
            is_attn, _ = _pos_kinds(cfg, j)
            if is_attn:
                x = x + _attn_layer(p, cfg, x, rules, block_q, block_k)
            else:
                h = layers.apply_norm(x, p["norm1"], cfg.norm)
                x = x + mamba2.mixer_apply(p["mamba"], cfg, h, chunk=ssd_chunk)
            f, aux_l = _ffn_layer(p, cfg, x, rules)
            x = x + f
            aux = aux + aux_l
            x = sh.constrain(x, rules, (sh.BATCH, sh.SEQ, None))
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["blocks"]
    )
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    head = params.get("lm_head")
    logits = x @ (head if head is not None else params["embed"].T.astype(dtype))
    logits = sh.constrain(logits, rules, (sh.BATCH, sh.SEQ, sh.VOCAB))
    return logits, aux
