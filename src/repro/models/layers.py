"""Shared neural layers (functional, pytree-parameterized, shard-annotated).

Every init function returns ``(params, specs)`` where ``specs`` mirrors the
param pytree with tuples of *logical* axis names consumed by
``repro.distributed.sharding.ShardingRules``.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = (1.0 / math.sqrt(in_dim)) if scale is None else scale
    return jax.random.normal(key, (in_dim, out_dim), dtype) * jnp.asarray(
        scale, dtype
    )


def embed_init(key, vocab: int, dim: int, dtype):
    return jax.random.normal(key, (vocab, dim), dtype) * jnp.asarray(0.02, dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layer_norm(
    x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, params, kind: str):
    if kind == "rms":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


def norm_specs(kind: str):
    if kind == "rms":
        return {"scale": (sh.D_MODEL,)}
    return {"scale": (sh.D_MODEL,), "bias": (sh.D_MODEL,)}


def norm_init(dim: int, kind: str, dtype):
    if kind == "rms":
        return {"scale": jnp.zeros((dim,), dtype)}, norm_specs(kind)
    return (
        {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
        norm_specs(kind),
    )


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array,  # (B, S, H, D)
    positions: jax.Array,  # (B, S) int32
    theta: float,
) -> jax.Array:
    freqs = rope_frequencies(x.shape[-1], theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_pos: int, dim: int) -> jax.Array:
    """Extended sinusoidal table (whisper decoder beyond 448 — DESIGN.md §4)."""
    pos = jnp.arange(num_pos, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim)
    )
    pe = jnp.zeros((num_pos, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# --------------------------------------------------------------------------
# Weight contraction (raw leaf or quantized serve record)
# --------------------------------------------------------------------------


def wdot(x: jax.Array, w) -> jax.Array:
    """``x @ w`` for a raw weight leaf OR a serve-time quantized record.

    ``repro.serving.weights.prepare_serve_params`` replaces projection
    leaves with ``{"q": int8 (in, out), "scale": f32 (out,)}`` records when
    ``weight_format`` is int8/bstc; this helper dequantizes the record to
    the dense reconstruction (the parity oracle) and contracts in the
    activation dtype.  Raw arrays take the plain matmul — the bf16 default
    path is byte-for-byte the old ``x @ w``.
    """
    if isinstance(w, dict) and "q" in w:
        dq = w["q"].astype(jnp.float32) * w["scale"][..., None, :].astype(
            jnp.float32
        )
        return x @ dq.astype(x.dtype)
    return x @ w


# --------------------------------------------------------------------------
# Activations / MLP
# --------------------------------------------------------------------------


def glu_act(kind: str, gate: jax.Array) -> jax.Array:
    if kind == "swiglu":
        return jax.nn.silu(gate)
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True)
    raise ValueError(kind)


def mlp_specs(activation: str):
    if activation in ("swiglu", "geglu"):
        return {
            "gate": (sh.D_MODEL, sh.FF),
            "up": (sh.D_MODEL, sh.FF),
            "down": (sh.FF, sh.D_MODEL),
        }
    return {
        "up": (sh.D_MODEL, sh.FF),
        "up_b": (sh.FF,),
        "down": (sh.FF, sh.D_MODEL),
        "down_b": (sh.D_MODEL,),
    }


def mlp_init(key, d_model: int, d_ff: int, activation: str, dtype):
    ks = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        params = {
            "gate": dense_init(ks[0], d_model, d_ff, dtype),
            "up": dense_init(ks[1], d_model, d_ff, dtype),
            "down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    else:  # plain gelu MLP (whisper)
        params = {
            "up": dense_init(ks[0], d_model, d_ff, dtype),
            "up_b": jnp.zeros((d_ff,), dtype),
            "down": dense_init(ks[1], d_ff, d_model, dtype),
            "down_b": jnp.zeros((d_model,), dtype),
        }
    return params, mlp_specs(activation)


def mlp_apply(params, x: jax.Array, activation: str) -> jax.Array:
    if activation in ("swiglu", "geglu"):
        gate = wdot(x, params["gate"])
        up = wdot(x, params["up"])
        return wdot(glu_act(activation, gate) * up, params["down"])
    h = jax.nn.gelu(wdot(x, params["up"]) + params["up_b"], approximate=True)
    return wdot(h, params["down"]) + params["down_b"]


# --------------------------------------------------------------------------
# Attention projections
# --------------------------------------------------------------------------


def attention_specs(qk_norm: bool = False):
    specs = {
        "wq": (sh.D_MODEL, sh.HEADS),
        "wk": (sh.D_MODEL, sh.KV_HEADS),
        "wv": (sh.D_MODEL, sh.KV_HEADS),
        "wo": (sh.HEADS, sh.D_MODEL),
    }
    if qk_norm:
        specs["q_norm"] = {"scale": (None,)}
        specs["k_norm"] = {"scale": (None,)}
    return specs


def attention_init(
    key,
    d_model: int,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    dtype,
    qk_norm: bool = False,
    norm_kind: str = "rms",
):
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        params["q_norm"] = {"scale": jnp.zeros((head_dim,), dtype)}
        params["k_norm"] = {"scale": jnp.zeros((head_dim,), dtype)}
    return params, attention_specs(qk_norm)


def qkv_project(
    params,
    x: jax.Array,  # (B, S, D)
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    positions: Optional[jax.Array],
    rope_theta: float,
    qk_norm: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    q = wdot(x, params["wq"]).reshape(B, S, num_heads, head_dim)
    k = wdot(x, params["wk"]).reshape(B, S, num_kv_heads, head_dim)
    v = wdot(x, params["wv"]).reshape(B, S, num_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, params["q_norm"]["scale"])
        k = rms_norm(k, params["k_norm"]["scale"])
    if positions is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v
