"""Whisper-medium encoder-decoder backbone (audio frontend stubbed).

Per the brief, the conv frontend is a STUB: ``input_specs()`` supplies
precomputed post-conv frame embeddings (B, 1500, d_model-ish).  The encoder
is bidirectional full attention over those frames; the decoder interleaves
causal self-attention, cross-attention to the encoder memory, and GELU MLPs.
Positions are sinusoidal and *extended* past the checkpoint's 448 decoder
slots so the assigned decode_32k cell lowers mechanically (DESIGN.md §4).
Biases are omitted from projections (uniform with the rest of the zoo; a
fidelity note in DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import attention
from repro.distributed import sharding as sh
from repro.models import layers

Params = Dict[str, Any]


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["norm1"], s["norm1"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    p["attn"], s["attn"] = layers.attention_init(
        ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
    )
    p["norm2"], s["norm2"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    p["mlp"], s["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype)
    return p, s


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    p, s = _enc_layer_init(key, cfg, dtype)
    p["norm_x"], s["norm_x"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    p["xattn"], s["xattn"] = layers.attention_init(
        ks[2], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
    )
    return p, s


def _enc_layer_specs(cfg) -> Params:
    return {
        "norm1": layers.norm_specs(cfg.norm),
        "attn": layers.attention_specs(),
        "norm2": layers.norm_specs(cfg.norm),
        "mlp": layers.mlp_specs("gelu"),
    }


def _dec_layer_specs(cfg) -> Params:
    s = _enc_layer_specs(cfg)
    s["norm_x"] = layers.norm_specs(cfg.norm)
    s["xattn"] = layers.attention_specs()
    return s


def param_specs(cfg) -> Params:
    stack = lambda s: jax.tree.map(
        lambda axes: (sh.LAYERS,) + tuple(axes), s,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return {
        "embed": (sh.VOCAB, sh.D_MODEL),
        "audio_proj": (None, sh.D_MODEL),
        "encoder": stack(_enc_layer_specs(cfg)),
        "decoder": stack(_dec_layer_specs(cfg)),
        "enc_final_norm": layers.norm_specs(cfg.norm),
        "final_norm": layers.norm_specs(cfg.norm),
    }


def init(key, cfg) -> Tuple[Params, Params]:
    dtype = layers._dtype(cfg.dtype)
    ke, kd, kemb, kproj = jax.random.split(key, 4)

    params: Params = {
        "embed": layers.embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
        "audio_proj": layers.dense_init(kproj, cfg.d_audio, cfg.d_model, dtype),
    }
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    params["encoder"] = jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype)[0])(enc_keys)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    params["decoder"] = jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype)[0])(dec_keys)
    params["enc_final_norm"], _ = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    params["final_norm"], _ = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    return params, param_specs(cfg)


def _self_attn(p, cfg, x, mask_kind, rules, block_q, block_k):
    q, k, v = layers.qkv_project(
        p["attn"], x, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
        positions=None, rope_theta=cfg.rope_theta,
    )
    q = sh.constrain(q, rules, (sh.BATCH, None, sh.HEADS, None))
    out = attention.blocked_attend(
        q, k, v, mask_kind=mask_kind, block_q=block_q, block_k=block_k
    )
    B, S, _, _ = out.shape
    return out.reshape(B, S, -1) @ p["attn"]["wo"]


def encode(params, cfg, frames, rules=sh.ShardingRules(), block_q=512, block_k=512):
    """frames: (B, encoder_seq, d_audio) stub embeddings -> (B, S_enc, D)."""
    dtype = layers._dtype(cfg.dtype)
    x = frames.astype(dtype) @ params["audio_proj"]
    x = x + layers.sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtype)[None]
    x = sh.constrain(x, rules, (sh.BATCH, None, None))

    def body(x, p):
        h = layers.apply_norm(x, p["norm1"], cfg.norm)
        x = x + _self_attn(p, cfg, h, "full", rules, block_q, block_k)
        h = layers.apply_norm(x, p["norm2"], cfg.norm)
        x = x + layers.mlp_apply(p["mlp"], h, "gelu")
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.apply_norm(x, params["enc_final_norm"], cfg.norm)


def decode_train(
    params, cfg, tokens, memory, rules=sh.ShardingRules(),
    block_q=512, block_k=1024, remat=False,
):
    """Teacher-forced decoder pass.  memory: (B, S_enc, D)."""
    dtype = layers._dtype(cfg.dtype)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(dtype)
    x = x + layers.sinusoidal_positions(S, cfg.d_model).astype(dtype)[None]
    x = sh.constrain(x, rules, (sh.BATCH, sh.SEQ, None))

    def body(x, p):
        h = layers.apply_norm(x, p["norm1"], cfg.norm)
        x = x + _self_attn(p, cfg, h, "causal", rules, block_q, block_k)
        h = layers.apply_norm(x, p["norm_x"], cfg.norm)
        # cross attention: kv from encoder memory
        q = (h @ p["xattn"]["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
        km = (memory @ p["xattn"]["wk"]).reshape(
            B, -1, cfg.num_kv_heads, cfg.head_dim
        )
        vm = (memory @ p["xattn"]["wv"]).reshape(
            B, -1, cfg.num_kv_heads, cfg.head_dim
        )
        xa = attention.blocked_attend(
            q, km, vm, mask_kind="full", block_q=block_q, block_k=block_k
        )
        x = x + xa.reshape(B, S, -1) @ p["xattn"]["wo"]
        h = layers.apply_norm(x, p["norm2"], cfg.norm)
        x = x + layers.mlp_apply(p["mlp"], h, "gelu")
        x = sh.constrain(x, rules, (sh.BATCH, sh.SEQ, None))
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = x @ params["embed"].T.astype(dtype)
    return sh.constrain(logits, rules, (sh.BATCH, sh.SEQ, sh.VOCAB))


def forward(params, cfg, tokens, frames, rules=sh.ShardingRules(), **kw):
    """Full enc-dec pass -> (logits, aux)."""
    memory = encode(params, cfg, frames, rules)
    logits = decode_train(params, cfg, tokens, memory, rules, **kw)
    return logits, jnp.zeros((), jnp.float32)
