"""Mamba2 (SSD — state-space duality) block and attention-free LM.

SSD forward (train/prefill) uses the chunked dual form: within a chunk the
output is a masked (decay-weighted) attention-like contraction; across chunks
a recurrent state (B, nheads, head_dim, state) is carried by a scan — O(S)
work, O(1) state, which is what makes the mamba2/jamba ``long_500k`` cells
runnable (DESIGN.md §4).

Decode is the pure recurrence: h = a·h + dt·x·Bᵀ ; y = C·h + D·x, plus a
rolling conv window.  BGPP is inapplicable (no KV cache); BRCR/BSTC apply to
in/out projections (DESIGN.md §6).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.models import layers

Params = Dict[str, Any]


def mixer_specs():
    return {
        "in_proj": (sh.D_MODEL, sh.FF),
        "conv_w": (sh.CONV, sh.FF),
        "conv_b": (sh.FF,),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": {"scale": (sh.FF,)},
        "out_proj": (sh.FF, sh.D_MODEL),
    }


def mixer_init(key, cfg, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nheads = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 4)
    # in_proj emits [z, x, B, C, dt]
    proj_out = 2 * d_in + 2 * N + nheads
    params = {
        "in_proj": layers.dense_init(ks[0], d, proj_out, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, d_in + 2 * N), dtype)
        * jnp.asarray(1.0 / math.sqrt(cfg.ssm_conv), dtype),
        "conv_b": jnp.zeros((d_in + 2 * N,), dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm": {"scale": jnp.zeros((d_in,), dtype)},
        "out_proj": layers.dense_init(ks[2], d_in, d, dtype),
    }
    return params, mixer_specs()


def _split_proj(cfg, zxbcdt):
    d_in = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    nheads = d_in // cfg.ssm_head_dim
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in : 2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N :]
    return z, xBC, dt, d_in, N, nheads


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv along S.  xBC: (B, S, C)."""
    K = conv_w.shape[0]
    if conv_state is not None:  # decode: (B, K-1, C) rolling window
        window = jnp.concatenate([conv_state, xBC], axis=1)  # (B, K, C)
        out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                         conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
        return jax.nn.silu(out)[:, None, :].astype(xBC.dtype), window[:, 1:]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    stacked = jnp.stack(
        [pad[:, i : i + xBC.shape[1]] for i in range(K)], axis=2
    )  # (B, S, K, C)
    out = jnp.einsum("bskc,kc->bsc", stacked.astype(jnp.float32),
                     conv_w.astype(jnp.float32)) + conv_b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xBC.dtype), None


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) softplus'd step
    A: jax.Array,  # (H,) negative decay rate
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    chunk: int = 256,
    return_state: bool = False,
):
    """Chunked SSD scan.  Returns (B, S, H, P)[, final state (B, H, P, N)]."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk

    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    a = dtc * A[None, None, None, :]  # (B, nc, L, H) log-decay per step (<=0)
    a_cum = jnp.cumsum(a, axis=2)  # within-chunk cumulative log decay

    # intra-chunk (dual/attention form): y[t] += sum_{s<=t} C_t·B_s dt_s
    #   * exp(a_cum[t] - a_cum[s]) * x[s]
    scores = jnp.einsum("bcln,bcmn->bclm", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))  # (B,nc,L,L)
    decay = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (B,nc,L,L,H)
    li = jnp.arange(chunk)
    causal = (li[:, None] >= li[None, :])[None, None, :, :, None]
    gamma = jnp.where(causal, jnp.exp(decay), 0.0)  # (B,nc,L,L,H)
    y_intra = jnp.einsum(
        "bclm,bclmh,bcmh,bcmhp->bclhp",
        scores, gamma, dtc.astype(jnp.float32), xc.astype(jnp.float32),
    )

    # chunk-final states: sum_s exp(a_cum[L-1]-a_cum[s]) dt_s B_s x_s
    seg = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B,nc,L,H)
    states = jnp.einsum(
        "bclh,bclh,bcln,bclhp->bchpn",
        seg, dtc.astype(jnp.float32), Bc.astype(jnp.float32),
        xc.astype(jnp.float32),
    )  # (B,nc,H,P,N)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (B,nc,H) total decay of chunk

    def carry_fn(h, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h  # emit state *entering* the chunk

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_final, h_in = jax.lax.scan(
        carry_fn,
        h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk contribution: y[t] += C_t · (exp(a_cum[t]) * h_in)
    y_inter = jnp.einsum(
        "bcln,bclh,bchpn->bclhp",
        Cc.astype(jnp.float32), jnp.exp(a_cum), h_in,
    )
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    if return_state:
        return y.astype(x.dtype), h_final
    return y.astype(x.dtype)


def mixer_apply(
    p: Params,
    cfg,
    x: jax.Array,  # (B, S, D)
    chunk: int = 256,
    return_state: bool = False,
):
    """Train/prefill SSD mixer.  With return_state, also emits the decode
    continuation state {"h", "conv"} (serving prefill)."""
    B, S, _ = x.shape
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt, d_in, N, nheads = _split_proj(cfg, zxbcdt)
    xBC_raw = xBC
    xBC, _ = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xin = xBC[..., :d_in].reshape(B, S, nheads, cfg.ssm_head_dim)
    Bm = xBC[..., d_in : d_in + N]
    Cm = xBC[..., d_in + N :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative
    res = ssd_chunked(xin, dt, A, Bm, Cm, chunk=chunk, return_state=return_state)
    y, h_final = res if return_state else (res, None)
    y = y + xin * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, d_in)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["norm"]["scale"])
    out = y @ p["out_proj"]
    if return_state:
        K = p["conv_w"].shape[0]
        conv_tail = xBC_raw[:, -(K - 1):, :]  # pre-activation window
        return out, {"h": h_final, "conv": conv_tail}
    return out


def mixer_decode_step(
    p: Params,
    cfg,
    x: jax.Array,  # (B, 1, D)
    state: Dict[str, jax.Array],  # {"h": (B,H,P,N) f32, "conv": (B,K-1,C)}
    rules: "sh.ShardingRules | None" = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt, d_in, N, nheads = _split_proj(cfg, zxbcdt)
    xBC, conv_state = _causal_conv(
        xBC, p["conv_w"], p["conv_b"], conv_state=state["conv"]
    )
    xin = xBC[..., :d_in].reshape(B, nheads, cfg.ssm_head_dim)
    Bm = xBC[:, 0, d_in : d_in + N]  # (B, N)
    Cm = xBC[:, 0, d_in + N :]
    dt_s = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt_s * A[None, :])  # (B,H)
    h = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt_s, xin.astype(jnp.float32), Bm.astype(jnp.float32)
    )
    if rules is not None:
        # pin the state-update outer product: without it the partitioner
        # drops the head (model) sharding and each of jamba's 63 mamba
        # layers materializes an unsharded (B,H,P,N) f32 temp
        h = sh.constrain(h, rules, (sh.BATCH, sh.FF, None, None))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + xin.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                        p["norm"]["scale"])
    return y @ p["out_proj"], {"h": h, "conv": conv_state}


def init_mixer_state(cfg, batch: int, dtype) -> Dict[str, jax.Array]:
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return {
        "h": jnp.zeros((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in + 2 * cfg.ssm_state), dtype),
    }


# --------------------------------------------------------------------------
# Full attention-free LM (mamba2-1.3b)
# --------------------------------------------------------------------------


def param_specs(cfg) -> Params:
    s_layer = {"norm": layers.norm_specs(cfg.norm), "mixer": mixer_specs()}
    return {
        "embed": (sh.VOCAB, sh.D_MODEL),
        "layers": jax.tree.map(
            lambda axes: (sh.LAYERS,) + tuple(axes), s_layer,
            is_leaf=lambda x: isinstance(x, tuple),
        ),
        "final_norm": layers.norm_specs(cfg.norm),
    }


def init(key, cfg) -> Tuple[Params, Params]:
    dtype = layers._dtype(cfg.dtype)
    k_embed, k_layers = jax.random.split(key)
    params: Params = {
        "embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype)
    }

    def one(k):
        p = {}
        p["norm"], _ = layers.norm_init(cfg.d_model, cfg.norm, dtype)
        p["mixer"], _ = mixer_init(k, cfg, dtype)
        return p

    keys = jax.random.split(k_layers, cfg.num_layers)
    params["layers"] = jax.vmap(one)(keys)
    params["final_norm"], _ = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    return params, param_specs(cfg)


def forward(params, cfg, tokens, rules=sh.ShardingRules(), chunk=256, remat=False):
    dtype = layers._dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    x = sh.constrain(x, rules, (sh.BATCH, sh.SEQ, None))

    def body(x, p):
        h = layers.apply_norm(x, p["norm"], cfg.norm)
        x = x + mixer_apply(p["mixer"], cfg, h, chunk=chunk)
        x = sh.constrain(x, rules, (sh.BATCH, sh.SEQ, None))
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    logits = x @ params["embed"].T.astype(dtype)
    logits = sh.constrain(logits, rules, (sh.BATCH, sh.SEQ, sh.VOCAB))
    return logits, jnp.zeros((), jnp.float32)
