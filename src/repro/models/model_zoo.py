"""Family dispatch for the 10 assigned architectures."""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax

from repro.distributed import sharding as sh
from repro.models import hybrid, mamba2, transformer, whisper

Params = Dict[str, Any]


def init(key, cfg) -> Tuple[Params, Params]:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.init(key, cfg)
    if cfg.family == "ssm":
        return mamba2.init(key, cfg)
    if cfg.family == "hybrid":
        return hybrid.init(key, cfg)
    if cfg.family == "enc_dec":
        return whisper.init(key, cfg)
    raise ValueError(cfg.family)


def init_params(key, cfg) -> Params:
    """Params only — safe to wrap in jax.eval_shape (dry-run path)."""
    return init(key, cfg)[0]


def param_specs(cfg) -> Params:
    """Logical-axis specs, computed without allocating anything."""
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.param_specs(cfg)
    if cfg.family == "ssm":
        return mamba2.param_specs(cfg)
    if cfg.family == "hybrid":
        return hybrid.param_specs(cfg)
    if cfg.family == "enc_dec":
        return whisper.param_specs(cfg)
    raise ValueError(cfg.family)


def forward(
    params: Params,
    cfg,
    batch: Dict[str, jax.Array],
    rules: sh.ShardingRules = sh.ShardingRules(),
    **kw,
):
    """batch: {"tokens": (B,S)} + {"frames": ...} (audio) or {"vision": ...}.

    Returns (logits, aux_loss).
    """
    tokens = batch["tokens"]
    if cfg.family in ("dense", "moe"):
        return transformer.forward(params, cfg, tokens, rules, **kw)
    if cfg.family == "vlm":
        return transformer.forward(
            params, cfg, tokens, rules, vision_embeds=batch["vision"], **kw
        )
    if cfg.family == "ssm":
        return mamba2.forward(params, cfg, tokens, rules, **kw)
    if cfg.family == "hybrid":
        return hybrid.forward(params, cfg, tokens, rules, **kw)
    if cfg.family == "enc_dec":
        return whisper.forward(params, cfg, tokens, batch["frames"], rules, **kw)
    raise ValueError(cfg.family)


def extra_inputs(cfg) -> Tuple[str, ...]:
    """Modality-stub inputs beyond tokens (the brief's input_specs contract)."""
    if cfg.family == "vlm":
        return ("vision",)
    if cfg.family == "enc_dec":
        return ("frames",)
    return ()
