"""Decoder-only transformer (dense GQA / MoE) — gemma3-{1b,4b}, deepseek-7b,
phi4-mini, mixtral-8x22b, llama4-scout, and the paligemma backbone.

Heterogeneous local/global attention layers (gemma3 5:1, mixtral SWA, llama4
chunked 3:1) share ONE scanned layer body: the mask kind is a static string
per model while the per-layer window/chunk size and RoPE base are traced
(L,)-arrays fed through the scan — window 0 means full causal.  This keeps
the dry-run compile cost O(1) in depth.

Training/prefill use the blocked flash-equivalent attention; decode uses the
KV-cache paths in ``repro.serving``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention
from repro.distributed import sharding as sh
from repro.models import layers, moe

Params = Dict[str, Any]


def _mask_kind(cfg) -> str:
    if cfg.family == "vlm":
        return "prefix_causal"
    if cfg.chunk_attention > 0:
        return "chunked"
    if cfg.sliding_window > 0:
        return "sliding"
    return "causal"


def layer_windows(cfg) -> np.ndarray:
    """(L,) per-layer window/chunk size (0 = full causal)."""
    out = []
    for i in range(cfg.num_layers):
        kind, w = cfg.layer_attn_window(i)
        out.append(w if kind in ("sliding", "chunked") else 0)
    return np.asarray(out, np.int32)


def layer_thetas(cfg) -> np.ndarray:
    out = []
    for i in range(cfg.num_layers):
        kind, _ = cfg.layer_attn_window(i)
        local = kind in ("sliding", "chunked") and cfg.rope_theta_local > 0
        out.append(cfg.rope_theta_local if local else cfg.rope_theta)
    return np.asarray(out, np.float32)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _layer_specs(cfg):
    s: Params = {
        "attn_norm": layers.norm_specs(cfg.norm),
        "attn": layers.attention_specs(cfg.qk_norm),
        "mlp_norm": layers.norm_specs(cfg.norm),
    }
    if cfg.num_experts > 0:
        s["moe"] = moe.moe_specs(cfg)
    else:
        s["mlp"] = layers.mlp_specs(cfg.activation)
    if cfg.post_norms:
        s["post_attn_norm"] = layers.norm_specs(cfg.norm)
        s["post_mlp_norm"] = layers.norm_specs(cfg.norm)
    return s


def param_specs(cfg) -> Params:
    """Logical-axis specs without allocating any parameters (dry-run path)."""
    specs: Params = {"embed": (sh.VOCAB, sh.D_MODEL)}
    specs["layers"] = jax.tree.map(
        lambda axes: (sh.LAYERS,) + tuple(axes), _layer_specs(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )
    specs["final_norm"] = layers.norm_specs(cfg.norm)
    if not cfg.tie_embeddings:
        specs["lm_head"] = (sh.D_MODEL, sh.VOCAB)
    if cfg.family == "vlm":
        specs["vision_proj"] = (None, sh.D_MODEL)
    return specs


def _layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p: Params = {}
    s: Params = {}
    p["attn_norm"], s["attn_norm"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    p["attn"], s["attn"] = layers.attention_init(
        ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
        dtype, qk_norm=cfg.qk_norm, norm_kind=cfg.norm,
    )
    p["mlp_norm"], s["mlp_norm"] = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    if cfg.num_experts > 0:
        p["moe"], s["moe"] = moe.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"], s["mlp"] = layers.mlp_init(
            ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dtype
        )
    if cfg.post_norms:
        p["post_attn_norm"], s["post_attn_norm"] = layers.norm_init(
            cfg.d_model, cfg.norm, dtype
        )
        p["post_mlp_norm"], s["post_mlp_norm"] = layers.norm_init(
            cfg.d_model, cfg.norm, dtype
        )
    return p, s


def init(key, cfg) -> Tuple[Params, Params]:
    """Returns (params, logical-axis specs).  Layer params are stacked (L, ...)."""
    if cfg.num_experts > 0:
        assert cfg.moe_every == 1, "mixed MoE/dense stacks live in hybrid.py"
    dtype = layers._dtype(cfg.dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)

    params: Params = {"embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype)}
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    params["layers"] = jax.vmap(lambda k: _layer_init(k, cfg, dtype)[0])(layer_keys)
    params["final_norm"], _ = layers.norm_init(cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            k_head, cfg.d_model, cfg.vocab_size, dtype
        )
    if cfg.family == "vlm":
        params["vision_proj"] = layers.dense_init(
            k_head, cfg.d_vision, cfg.d_model, dtype
        )
    return params, param_specs(cfg)


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------


def _attention_block(
    p, cfg, x, positions, theta, window, mask_kind, rules, block_q, block_k,
    return_kv=False,
):
    h = layers.apply_norm(x, p["attn_norm"], cfg.norm)
    q, k, v = layers.qkv_project(
        p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
        positions, theta, qk_norm=cfg.qk_norm,
    )
    q = sh.constrain(q, rules, (sh.BATCH, None, sh.HEADS, None))
    k = sh.constrain(k, rules, (sh.BATCH, None, sh.KV_HEADS, None))
    v = sh.constrain(v, rules, (sh.BATCH, None, sh.KV_HEADS, None))
    attn = attention.blocked_attend(
        q, k, v, mask_kind=mask_kind, window=window,
        block_q=block_q, block_k=block_k,
    )
    B, S, _, _ = attn.shape
    out = attn.reshape(B, S, -1) @ p["attn"]["wo"]
    if cfg.post_norms:
        out = layers.apply_norm(out, p["post_attn_norm"], cfg.norm)
    if return_kv:
        return out, (k, v)
    return out, None


def _ffn_block(p, cfg, x, rules=None):
    h = layers.apply_norm(x, p["mlp_norm"], cfg.norm)
    if cfg.num_experts > 0:
        out, aux = moe.moe_apply(p["moe"], h, cfg, rules=rules)
    else:
        out, aux = layers.mlp_apply(p["mlp"], h, cfg.activation), 0.0
    if cfg.post_norms:
        out = layers.apply_norm(out, p["post_mlp_norm"], cfg.norm)
    return out, aux


def forward(
    params: Params,
    cfg,
    tokens: jax.Array,  # (B, S) int32
    rules: sh.ShardingRules = sh.ShardingRules(),
    vision_embeds: Optional[jax.Array] = None,  # (B, Tv, d_vision) VLM stub
    block_q: int = 512,
    block_k: int = 1024,
    return_kv: bool = False,
    remat: bool = False,
):
    """Returns (logits, aux_loss[, stacked (k, v)])."""
    B, S_text = tokens.shape
    dtype = layers._dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    prefix = 0
    if cfg.family == "vlm":
        assert vision_embeds is not None
        vis = (vision_embeds.astype(dtype) @ params["vision_proj"]).astype(dtype)
        x = jnp.concatenate([vis, x], axis=1)
        prefix = vis.shape[1]
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = sh.constrain(x, rules, (sh.BATCH, sh.SEQ, None))

    mask_kind = _mask_kind(cfg)
    windows = jnp.asarray(layer_windows(cfg))
    if mask_kind == "prefix_causal":
        windows = jnp.full_like(windows, prefix)
    thetas = jnp.asarray(layer_thetas(cfg))

    def body(carry, scanned):
        x, aux = carry
        p, window, theta = scanned
        a, kv = _attention_block(
            p, cfg, x, positions, theta, window, mask_kind, rules,
            block_q, block_k, return_kv=return_kv,
        )
        x = x + a
        f, aux_l = _ffn_block(p, cfg, x, rules)
        x = x + f
        x = sh.constrain(x, rules, (sh.BATCH, sh.SEQ, None))
        return (x, aux + aux_l), kv

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), kvs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["layers"], windows, thetas)
    )
    x = layers.apply_norm(x, params["final_norm"], cfg.norm)
    head = params.get("lm_head")
    if head is None:
        logits = x @ params["embed"].T.astype(dtype)
    else:
        logits = x @ head
    logits = sh.constrain(logits, rules, (sh.BATCH, sh.SEQ, sh.VOCAB))
    if return_kv:
        return logits, aux, kvs
    return logits, aux
