"""AdamW with optional 8-bit quantized moments and ZeRO state partitioning.

The int8 moment store continues MCBP's bit-level theme into training: both
moments are kept as int8 with per-row (leading-axis) absmax scales; the
second moment is quantized in sqrt-space to tame its dynamic range
(bitsandbytes-style).  Cuts optimizer-state HBM from 8 to 2 bytes/param —
required (with ZeRO over "data") to fit jamba-398B's train_4k cell in
16 GB/chip (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "fp32"  # fp32 | int8
    zero_partition: bool = False  # shard moments over "data" (ZeRO-1)
    warmup_steps: int = 100
    decay_steps: int = 10_000


# ---------------------------------------------------------------------------
# int8 moment codec (per-row absmax; v in sqrt-space)
# ---------------------------------------------------------------------------


def _q8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    # reduce over all-but-leading axes WITHOUT reshaping: a 2-D reshape of a
    # sharded tensor makes GSPMD replicate it (catastrophic for 100B+ states)
    if x.ndim <= 1:
        scale = jnp.maximum(jnp.max(jnp.abs(x), keepdims=True), 1e-12) / 127.0
        bcast = scale
    else:
        axes = tuple(range(1, x.ndim))
        scale = jnp.maximum(jnp.max(jnp.abs(x), axis=axes), 1e-12) / 127.0
        bcast = scale.reshape((-1,) + (1,) * (x.ndim - 1))
    q = jnp.clip(jnp.round(x / bcast), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q: jax.Array, scale: jax.Array) -> jax.Array:
    if q.ndim <= 1:
        return q.astype(jnp.float32) * scale
    bcast = scale.reshape((-1,) + (1,) * (q.ndim - 1))
    return q.astype(jnp.float32) * bcast


def _encode_m(m):
    return dict(zip(("q", "s"), _q8(m)))


def _decode_m(e):
    return _dq8(e["q"], e["s"])


def _encode_v(v):
    return dict(zip(("q", "s"), _q8(jnp.sqrt(jnp.maximum(v, 0.0)))))


def _decode_v(e):
    r = _dq8(e["q"], e["s"])
    return r * r


# ---------------------------------------------------------------------------


def adamw_init(params: Tree, cfg: AdamWConfig) -> Tree:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    if cfg.state_dtype == "int8":
        m = jax.tree.map(_encode_m, zeros)
        v = jax.tree.map(_encode_v, zeros)
    else:
        m, v = zeros, jax.tree.map(jnp.copy, zeros)
    return {"step": jnp.zeros((), jnp.int32), "m": m, "v": v}


def global_norm(tree: Tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Tree,
    grads: Tree,
    state: Tree,
    cfg: AdamWConfig,
    lr: Optional[jax.Array] = None,
) -> Tuple[Tree, Tree, Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    from repro.optim.schedules import warmup_cosine

    step = state["step"] + 1
    if lr is None:
        lr = warmup_cosine(step, cfg.peak_lr, cfg.warmup_steps, cfg.decay_steps)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * clip, grads)

    int8 = cfg.state_dtype == "int8"
    is_enc = lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "s"}
    m_f = jax.tree.map(_decode_m, state["m"], is_leaf=is_enc) if int8 else state["m"]
    v_f = jax.tree.map(_decode_v, state["v"], is_leaf=is_enc) if int8 else state["v"]

    m_new = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, m_f, grads)
    v_new = jax.tree.map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g), v_f, grads
    )
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        u = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m_new, v_new)
    new_state = {
        "step": step,
        "m": jax.tree.map(_encode_m, m_new) if int8 else m_new,
        "v": jax.tree.map(_encode_v, v_new) if int8 else v_new,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


def opt_state_specs(param_specs: Tree, cfg: AdamWConfig) -> Tree:
    """Logical-axis specs for the optimizer state.

    ZeRO-1: moments are additionally sharded over "data" via the fsdp rule on
    their leading logical axis (ShardingRules.fsdp_axes handles the mapping);
    here we simply mirror the param specs — the rules object chosen by the
    launcher decides whether "data" participates.
    """

    def moment_spec(axes):
        axes = tuple(axes)
        if cfg.state_dtype == "int8":
            # scale is (rows,) for >=2-d params, (1,) for 1-d (never sharded)
            lead = axes[0] if len(axes) > 1 else None
            return {"q": axes, "s": (lead,)}
        return axes

    is_leaf = lambda x: isinstance(x, tuple)
    m_specs = jax.tree.map(moment_spec, param_specs, is_leaf=is_leaf)
    return {
        "step": (),
        "m": m_specs,
        "v": jax.tree.map(moment_spec, param_specs, is_leaf=is_leaf),
    }
