"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(
    step,
    peak_lr: float,
    warmup_steps: int = 1000,
    decay_steps: int = 100_000,
    end_lr_ratio: float = 0.1,
):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
    t = jnp.clip((step - warmup_steps) / max(decay_steps - warmup_steps, 1), 0.0, 1.0)
    cos = end_lr_ratio + (1 - end_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)
