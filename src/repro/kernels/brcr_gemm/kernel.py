"""BRCR GEMM Pallas kernel (paper §3.1 + Fig. 14, TPU-adapted per DESIGN.md §2).

Dataflow per (row-tile i, col-tile j) output block, iterating signed planes p
and K-tiles kt on the inner ("arbitrary") grid dims:

  1. load the group-pattern tile ``idx`` (TG × TK, TG = TM/m group rows) —
     this is the CAM content; patterns are the search keys;
  2. *match + merge*: one-hot(idx) forms the (TG·2^m × TK) indicator the MXU
     contracts against the activation tile → MAV ``Z`` (TG × 2^m × TN).
     The MXU enumerates all 2^m search keys at once — the paper's CAM sweep;
  3. *reconstruct*: ``E @ Z`` (E = m × 2^m enumeration matrix, fixed operand
     kept in VMEM — the RU's fixed datapath);
  4. accumulate ``±2^p``-weighted results into the f32 VMEM accumulator.

Tile-level sparsity: a host-precomputed ``tile_any`` bitmap marks (p, i, kt)
tiles whose patterns are all zero (pattern 0 contributes nothing because
E[:, 0] = 0); those tiles skip the MXU work entirely via ``pl.when`` — the
MXU-compatible form of the paper's zero-column elimination.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(
    idx_ref,  # (1, TG, TK) uint8 group patterns for plane p
    pw_ref,  # (1, 1) f32 plane weight ±2^p   (SMEM)
    any_ref,  # (1, 1, 1) int32 tile-nonzero flag (SMEM)
    x_ref,  # (TK, TN) activations
    out_ref,  # (TM, TN)
    acc_ref,  # scratch (TM, TN) f32
    *,
    m: int,
    n_planes: int,
    k_tiles: int,
):
    p = pl.program_id(2)
    kt = pl.program_id(3)

    @pl.when((p == 0) & (kt == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(any_ref[0, 0, 0] != 0)
    def _compute():
        idx = idx_ref[0].astype(jnp.int32)  # (TG, TK)
        tg, tk = idx.shape
        nbins = 2**m
        # one-hot over bins: (TG, 2^m, TK) — the CAM match bitmaps
        bins = jax.lax.broadcasted_iota(jnp.int32, (tg, nbins, tk), 1)
        onehot = (idx[:, None, :] == bins).astype(x_ref.dtype)
        # MAV: merge activations per pattern (addition-merge units)
        z = jax.lax.dot_general(
            onehot.reshape(tg * nbins, tk),
            x_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (TG*2^m, TN)
        # reconstruction: Y_g = E @ Z_g  (fixed-datapath RU).  E is built
        # in-register from iota: E[j, c] = bit j of c.
        cc = jax.lax.broadcasted_iota(jnp.int32, (m, nbins), 1)
        jj = jax.lax.broadcasted_iota(jnp.int32, (m, nbins), 0)
        e = ((cc >> jj) & 1).astype(x_ref.dtype)  # (m, 2^m)
        z = z.reshape(tg, nbins, -1)
        y = jax.lax.dot_general(
            z,
            e,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (TG, TN, m)
        y = jnp.transpose(y, (0, 2, 1)).reshape(acc_ref.shape)  # (TM, TN)
        acc_ref[...] += pw_ref[0, 0] * y

    @pl.when((p == n_planes - 1) & (kt == k_tiles - 1))
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def brcr_gemm_pallas(
    group_idx: jax.Array,  # (P, G, H) uint8
    plane_weights: jax.Array,  # (P,) f32
    tile_any: jax.Array,  # (P, M//TM, H//TK) int32
    x: jax.Array,  # (H, N)
    *,
    m: int,
    tile_m: int = 128,
    tile_k: int = 256,
    tile_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    P, G, H = group_idx.shape
    M = G * m
    N = x.shape[1]
    assert M % tile_m == 0 and H % tile_k == 0 and N % tile_n == 0, (M, H, N)
    tg = tile_m // m
    grid = (M // tile_m, N // tile_n, P, H // tile_k)

    kernel = functools.partial(
        _kernel, m=m, n_planes=P, k_tiles=H // tile_k
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tg, tile_k), lambda i, j, p, kt: (p, i, kt)),
            pl.BlockSpec(
                (1, 1), lambda i, j, p, kt: (p, 0), memory_space=pltpu.SMEM
            ),
            pl.BlockSpec(
                (1, 1, 1),
                lambda i, j, p, kt: (p, i, kt),
                memory_space=pltpu.SMEM,
            ),
            pl.BlockSpec((tile_k, tile_n), lambda i, j, p, kt: (kt, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, p, kt: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(group_idx, plane_weights.reshape(P, 1), tile_any, x)


