"""Pure-jnp oracle for the BRCR GEMM kernel.

Computes ``w_q @ x`` through exactly the factorization the kernel uses:
per signed bit-plane, group indices -> one-hot MAV -> enumeration-matrix
reconstruction -> shift-weighted accumulation.  Numerically identical to the
dense product for integer-valued ``x`` (and to f32 matmul up to reassociation
for float ``x``).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitslice


def brcr_gemm_ref(
    group_idx: jnp.ndarray,  # (P, G, H) uint8 patterns per signed plane
    plane_weights: jnp.ndarray,  # (P,) f32 = ±2^p
    x: jnp.ndarray,  # (H, N)
    m: int,
) -> jnp.ndarray:
    """Returns (G*m, N) f32."""
    P, G, H = group_idx.shape
    N = x.shape[1]
    e = bitslice.enumeration_matrix(m, dtype=jnp.float32)  # (m, 2^m)
    onehot = jnp.asarray(
        group_idx[..., None] == jnp.arange(2**m, dtype=group_idx.dtype),
        jnp.float32,
    )  # (P, G, H, 2^m)
    z = jnp.einsum("pghc,hn->pgcn", onehot, x.astype(jnp.float32))
    y = jnp.einsum("jc,pgcn->pgjn", e, z)  # (P, G, m, N)
    y = y * plane_weights[:, None, None, None]
    return jnp.sum(y, axis=0).reshape(G * m, N)


def dense_ref(w_q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """The ultimate oracle: plain dense product in f32."""
    return w_q.astype(jnp.float32) @ x.astype(jnp.float32)
