from repro.kernels.brcr_gemm.ops import brcr_gemm, prepare_brcr_operands  # noqa: F401
