"""Public jit'd wrapper for the BRCR GEMM kernel + offline operand prep."""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitslice
from repro.kernels import dispatch
from repro.kernels.brcr_gemm.kernel import brcr_gemm_pallas
from repro.kernels.brcr_gemm.ref import brcr_gemm_ref


class BRCROperands(NamedTuple):
    """Offline-prepared kernel operands for one int8 weight (M, H).

    group_idx:     (P, M//m, H) uint8 — signed-plane column patterns
                   (P = 2*nbits: positive planes LSB→MSB, then negative).
    plane_weights: (P,) f32 = [+1, +2, ..., +2^(k-1), -1, ..., -2^(k-1)].
    m, nbits, shape bookkeeping for the wrapper.
    """

    group_idx: jax.Array
    plane_weights: jax.Array
    m: int
    nbits: int
    M: int
    H: int


def prepare_brcr_operands(
    w_q, m: int = 4, nbits: int = bitslice.WEIGHT_MAG_BITS
) -> BRCROperands:
    """Host/offline: int8 weight -> signed bit-plane group patterns."""
    w = np.asarray(w_q).astype(np.int32)
    M, H = w.shape
    if M % m:
        raise ValueError(f"M={M} not divisible by group size m={m}")
    parts = (np.maximum(w, 0), np.maximum(-w, 0))
    idx = np.empty((2 * nbits, M // m, H), np.uint8)
    shift = np.arange(m, dtype=np.uint32)[None, :, None]
    for s, part in enumerate(parts):
        for p in range(nbits):
            plane = ((part >> p) & 1).astype(np.uint32).reshape(M // m, m, H)
            idx[s * nbits + p] = (plane << shift).sum(axis=1).astype(np.uint8)
    pw = np.concatenate(
        [2.0 ** np.arange(nbits), -(2.0 ** np.arange(nbits))]
    ).astype(np.float32)
    return BRCROperands(
        group_idx=jnp.asarray(idx),
        plane_weights=jnp.asarray(pw),
        m=m,
        nbits=nbits,
        M=M,
        H=H,
    )


def tile_nonzero_map(
    group_idx: jax.Array, m: int, tile_m: int, tile_k: int
) -> jax.Array:
    """(P, M//TM, H//TK) int32: 1 where the tile has any non-zero pattern."""
    P, G, H = group_idx.shape
    tg = tile_m // m
    t = group_idx.reshape(P, G // tg, tg, H // tile_k, tile_k)
    return jnp.any(t != 0, axis=(2, 4)).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("m", "tile_m", "tile_k", "tile_n", "interpret"),
)
def _brcr_gemm_jit(
    group_idx, plane_weights, x, *, m, tile_m, tile_k, tile_n, interpret
):
    tile_any = tile_nonzero_map(group_idx, m, tile_m, tile_k)
    return brcr_gemm_pallas(
        group_idx,
        plane_weights,
        tile_any,
        x,
        m=m,
        tile_m=tile_m,
        tile_k=tile_k,
        tile_n=tile_n,
        interpret=interpret,
    )


def _brcr_pallas_path(ops, x, *, tile_m, tile_k, tile_n, interpret):
    H, N = x.shape
    tile_m = min(tile_m, ops.M)
    tile_k = min(tile_k, H)
    n_pad = (-N) % tile_n
    if n_pad:
        x = jnp.pad(x, ((0, 0), (0, n_pad)))
    y = _brcr_gemm_jit(
        ops.group_idx,
        ops.plane_weights,
        x,
        m=ops.m,
        tile_m=tile_m,
        tile_k=tile_k,
        tile_n=min(tile_n, x.shape[1]),
        interpret=interpret,
    )
    return y[:, :N]


def _brcr_ref_path(ops, x, *, tile_m, tile_k, tile_n):
    del tile_m, tile_k, tile_n  # the oracle is tiling-free
    return brcr_gemm_ref(ops.group_idx, ops.plane_weights, x, ops.m)


def brcr_gemm(
    ops: BRCROperands,
    x: jax.Array,
    *,
    tile_m: int = 128,
    tile_k: int = 256,
    tile_n: int = 128,
    interpret: bool = False,
    mode: str | None = None,
) -> jax.Array:
    """Compute ``w_q @ x`` from prepared BRCR operands.  x: (H, N) -> (M, N).

    Pads N up to the tile size (M and H must already be tile-aligned — true
    for every assigned architecture's projection dims).  Routing between
    compiled / interpret / ref is governed by :mod:`repro.kernels.dispatch`.
    """
    assert x.shape[0] == ops.H, (x.shape[0], ops.H)
    return dispatch.pallas_dispatch(
        "brcr_gemm",
        _brcr_pallas_path,
        _brcr_ref_path,
        ops,
        x,
        tile_m=tile_m,
        tile_k=tile_k,
        tile_n=tile_n,
        mode=mode,
        interpret=interpret,
    )
