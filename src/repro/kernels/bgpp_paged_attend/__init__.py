"""Fused two-phase BGPP paged decode: plane scan + top-k + int8 attend."""

from repro.kernels.bgpp_paged_attend.ops import bgpp_paged_attend

__all__ = ["bgpp_paged_attend"]
