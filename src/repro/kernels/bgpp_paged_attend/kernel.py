"""Pallas body for the fused two-phase BGPP paged decode.

One launch per decode step, grid ``(B, Hk)``: each (slot, kv-head) cell
runs the WHOLE two-phase pipeline device-locally —

  1. quantize + MSB-truncate its g query rows (engine
     ``_bgpp_quant_query``);
  2. round 0: gather the packed sign + MSB magnitude plane of every
     logical position through the scalar-prefetched ``phys`` map, unpack,
     and score ``qf @ ((1-2*sign) * plane)^T * 2^(NBITS-1)``;
  3. progressive rounds: iterative-argmax top-k keeps ``survivors[r]``
     candidates (bitwise the same selection as ``lax.top_k`` — first-
     occurrence argmax reproduces its lower-index tie-break, and the
     plane scores are integer-exact f32), then gathers ONLY the
     survivors' next plane and accumulates ``* 2^(NBITS-1-r)``;
  4. the final ``k_max`` survivors' full rows (all NBITS planes + sign +
     scales + int8 V) are gathered compacted, K is reconstructed from its
     bit planes, and the engine's exact int8 A2/A3 attend runs on the
     ``(g, k_max)`` score row.

Nothing wider than ``k_max`` full rows is ever materialized, matching the
kv-read counter's claim at the kernel level.  The pool blocks arrive
whole-axis per head (``(n_tok, 1, ...)``); the in-kernel row gathers are
dynamic (``pool[rows]``), which interpret mode executes exactly and a
Mosaic lowering would turn into per-row DMA — compiled-mode throughput is
untuned; interpret parity on CPU CI is the correctness bar this repo pins.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30  # matches repro.core.attention.NEG_INF


def _unpack_bits_i32(packed: jax.Array) -> jax.Array:
    """(..., D/8) uint8 -> (..., D) int32 bits (little-endian in the byte —
    the bgpp_score kernel's idiom, matching ``bitslice.unpack_bits``)."""
    x = packed.astype(jnp.int32)
    shape = x.shape[:-1] + (x.shape[-1], 8)
    shifts = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    bits = (x[..., None] >> shifts) & 1
    return bits.reshape(x.shape[:-1] + (x.shape[-1] * 8,))


def _topk_iter(score: jax.Array, k: int) -> jax.Array:
    """First-occurrence iterative argmax — ``lax.top_k``'s descending order
    and lowest-index tie-break.  Taken lanes drop to -inf, strictly below
    the NEG_INF invalid-lane sentinel, so they can't be re-selected."""

    def body(i, st):
        s, out = st
        j = jnp.argmax(s).astype(jnp.int32)
        return s.at[j].set(-jnp.inf), out.at[i].set(j)

    _, out = jax.lax.fori_loop(
        0, k, body, (score, jnp.zeros((k,), jnp.int32))
    )
    return out


def _plane_dot(qf, plane_bits, sign_bits):
    """qf (g, D) f32 x signed plane rows (n, D) -> (g, n) f32 (engine's
    ``plane_scores`` einsum per cell)."""
    signed = jnp.where(sign_bits.astype(bool), -1.0, 1.0) * plane_bits
    return jax.lax.dot_general(
        qf, signed, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _body(phys_ref, pos_ref, q_ref, planes_ref, sign_ref, kscale_ref,
          v_ref, vscale_ref, out_ref, *, rounds: int, k_max: int,
          survivors: Tuple[int, ...], nbits: int, query_bits: int,
          scale: float):
    b = pl.program_id(0)
    rows_all = phys_ref[b]  # (S,) pool rows of this slot's logical lane s
    posb = pos_ref[b]
    S = rows_all.shape[0]
    planes = planes_ref[:, :, 0, :]  # (NBITS, n_tok, D/8)
    signs = sign_ref[:, 0, :]  # (n_tok, D/8)

    # ---- phase 1, step 0: quantize + MSB-truncate the g query rows ------
    qb = q_ref[0, 0].astype(jnp.float32)  # (g, D)
    dq = jnp.maximum(jnp.max(jnp.abs(qb), axis=-1, keepdims=True), 1e-8) / 127.0
    q_int = jnp.clip(jnp.round(qb / dq), -127, 127).astype(jnp.int32)
    shift = max(nbits - query_bits, 0)  # core.bgpp._truncate_query
    qf = (jnp.sign(q_int) * ((jnp.abs(q_int) >> shift) << shift)).astype(
        jnp.float32
    )

    # ---- round 0: sign + MSB plane of EVERY logical lane ----------------
    sign_s = _unpack_bits_i32(signs[rows_all])  # (S, D)
    plane0 = _unpack_bits_i32(planes[nbits - 1][rows_all]).astype(jnp.float32)
    partial = _plane_dot(qf, plane0, sign_s) * float(2 ** (nbits - 1))  # (g,S)
    valid = jnp.arange(S, dtype=jnp.int32) <= posb
    score = jnp.where(valid, jnp.max(partial, axis=0), NEG_INF)

    # ---- progressive rounds over the shrinking candidate set ------------
    cur_idx = None
    for r in range(1, rounds):
        li = _topk_iter(score, survivors[r])
        cur_idx = li if cur_idx is None else cur_idx[li]
        partial = partial[:, li]
        p_r = nbits - 1 - r
        rows_r = rows_all[cur_idx]
        plane_r = _unpack_bits_i32(planes[p_r][rows_r]).astype(jnp.float32)
        sign_r = _unpack_bits_i32(signs[rows_r])
        partial = partial + _plane_dot(qf, plane_r, sign_r) * float(2**p_r)
        score = jnp.where(valid[cur_idx], jnp.max(partial, axis=0), NEG_INF)

    li = _topk_iter(score, k_max)
    idx = li if cur_idx is None else cur_idx[li]  # (k_max,) logical lanes
    idx_valid = valid[idx]

    # ---- phase 2: compacted full-row gather + exact int8 attend ---------
    rows_k = rows_all[idx]  # (k_max,) pool rows
    plane_bits = _unpack_bits_i32(planes[:, rows_k])  # (NBITS, k, D)
    mag = jnp.zeros_like(plane_bits[0])  # (k, D) int32
    for p in range(nbits):  # static unroll — no captured weight constant
        mag = mag + plane_bits[p] * (2**p)
    sign_k = _unpack_bits_i32(signs[rows_k])
    k_q = jnp.where(sign_k != 0, -mag, mag).astype(jnp.int8)
    ks = kscale_ref[:, 0][rows_k]  # (k,) f32
    vs = vscale_ref[:, 0][rows_k]
    v_k = v_ref[:, 0, :][rows_k]  # (k, D) int8

    q_scale = jnp.maximum(jnp.max(jnp.abs(qb), axis=-1, keepdims=True), 1e-8) / 127.0
    q_q = jnp.clip(jnp.round(qb / q_scale), -127, 127).astype(jnp.int8)
    logits_i = jax.lax.dot_general(
        q_q, k_q, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int32
    )  # (g, k)
    logits = logits_i.astype(jnp.float32) * q_scale * ks[None, :] * scale
    logits = jnp.where(idx_valid[None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    w = probs * vs[None, :]
    w_scale = jnp.maximum(jnp.max(w, axis=-1, keepdims=True), 1e-20) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale), 0, 127).astype(jnp.int8)
    out = jax.lax.dot_general(
        w_q, v_k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[0, 0] = out * w_scale


def bgpp_paged_attend_pallas(
    q: jax.Array,  # (B, Hk, g, D) f32 RAW grouped decode query
    k_planes: jax.Array,  # (NBITS, n_tok, Hk, D/8) uint8
    k_sign: jax.Array,  # (n_tok, Hk, D/8) uint8
    k_scale: jax.Array,  # (n_tok, Hk) f32
    v: jax.Array,  # (n_tok, Hk, D) int8
    v_scale: jax.Array,  # (n_tok, Hk) f32
    phys: jax.Array,  # (B, S) int32
    pos: jax.Array,  # (B,) int32
    *,
    rounds: int,
    k_max: int,
    survivors: Tuple[int, ...],
    query_bits: int = 4,
    interpret: bool = False,
) -> jax.Array:
    """Launch the fused BGPP decode kernel -> f32 ``(B, Hk, g, D)``."""
    B, Hk, g, D = q.shape
    nbits, n_tok, _, Dp = k_planes.shape
    cellmap = lambda b, h, phys_, pos_: (b, h, 0, 0)
    poolmap3 = lambda b, h, phys_, pos_: (0, h, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hk),
        in_specs=[
            pl.BlockSpec((1, 1, g, D), cellmap),
            pl.BlockSpec(
                (nbits, n_tok, 1, Dp), lambda b, h, phys_, pos_: (0, 0, h, 0)
            ),
            pl.BlockSpec((n_tok, 1, Dp), poolmap3),
            pl.BlockSpec((n_tok, 1), lambda b, h, phys_, pos_: (0, h)),
            pl.BlockSpec((n_tok, 1, D), poolmap3),
            pl.BlockSpec((n_tok, 1), lambda b, h, phys_, pos_: (0, h)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, D), cellmap),
    )
    body = functools.partial(
        _body, rounds=rounds, k_max=k_max, survivors=tuple(survivors),
        nbits=nbits, query_bits=query_bits, scale=D**-0.5,
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, g, D), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(phys.astype(jnp.int32), pos.astype(jnp.int32), q.astype(jnp.float32),
      k_planes, k_sign, k_scale, v, v_scale)
