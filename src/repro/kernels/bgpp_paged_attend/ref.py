"""Pure-jnp oracle for the fused two-phase BGPP paged decode.

A verbatim port of the serving engine's pipeline onto RAW pool operands —
``_bgpp_quant_query`` -> ``_bgpp_topk_indices`` (progressive MSB-first
plane scoring with early termination) -> compacted survivor gather ->
``_bgpp_formal_attend`` (exact int8 A2/A3 formal compute) — with the
paged gathers (``paged_plane`` / ``paged_sign`` / ``paged_plane_rows`` /
``paged_topk_entry``) inlined as plain ``take``/``vmap`` so the family has
no import edge into ``repro.serving``.  Every float op keeps the engine's
order, so the selected candidate sets AND the final logits are
bit-identical to the engine's jnp path (phase-1 scores are integer-exact
plane sums; selection is order-invariant by construction).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bgpp as bgpp_mod, bitslice

NEG_INF = -1e30  # matches repro.core.attention.NEG_INF
NBITS = bitslice.WEIGHT_MAG_BITS  # 7 magnitude planes + sign


def _quant_query(q: jax.Array) -> jax.Array:
    """(B, Hk, g, D) f32 raw query -> quantized+MSB-truncated f32 (engine
    ``_bgpp_quant_query`` on the already-grouped layout)."""
    qg = q.astype(jnp.float32)
    dq = jnp.maximum(jnp.max(jnp.abs(qg), axis=-1, keepdims=True), 1e-8) / 127.0
    q_int = jnp.clip(jnp.round(qg / dq), -127, 127).astype(jnp.int32)
    q_int = bgpp_mod._truncate_query(
        q_int, NBITS, bgpp_mod.DEFAULT_QUERY_BITS
    )
    return q_int.astype(jnp.float32)


def _rows_per_head(pool: jax.Array, rows: jax.Array, planar: bool):
    """Per-(slot, head) compacted pool gather (``kvc._gather_rows_per_head``):
    pool ``(n_tok, Hk, ...)`` (planar: leading NBITS), rows ``(B, Hk, k)``
    -> ``(B, Hk, k, ...)`` (planar: leading NBITS)."""
    heads = jnp.arange(rows.shape[1])
    if planar:
        return jax.vmap(
            lambda r, h: pool[:, r, h], in_axes=(1, 0), out_axes=2
        )(rows, heads)
    return jax.vmap(
        lambda r, h: pool[r, h], in_axes=(1, 0), out_axes=1
    )(rows, heads)


def _topk_indices(qf, plane0, sign_full, plane_at, valid,
                  rounds: int, k_max: int, survivors: Tuple[int, ...]):
    """Engine ``_bgpp_topk_indices`` verbatim, with the plan passed in."""
    B, Hk, g, Dh = qf.shape
    S = valid.shape[1]

    def plane_scores(plane_bits, sign_bits, qf_):
        signed = jnp.where(sign_bits.astype(bool), -1.0, 1.0) * plane_bits
        return jnp.einsum("bhgd,bhsd->bhgs", qf_, signed)

    p0 = NBITS - 1
    plane = bitslice.unpack_bits(plane0, axis=-1).astype(jnp.float32)
    sign = bitslice.unpack_bits(sign_full, axis=-1)
    partial = plane_scores(plane, sign, qf) * float(2**p0)
    score_h = jnp.max(partial, axis=2)
    score_h = jnp.where(valid[:, None, :], score_h, NEG_INF)

    cur_idx = None
    for r in range(1, rounds):
        k_r = survivors[r]
        _, li = jax.lax.top_k(score_h, k_r)
        cur_idx = li if cur_idx is None else jnp.take_along_axis(cur_idx, li, axis=2)
        partial = jnp.take_along_axis(partial, li[:, :, None, :], axis=3)
        p_r = NBITS - 1 - r
        plane_g = bitslice.unpack_bits(
            plane_at(p_r, cur_idx), axis=-1
        ).astype(jnp.float32)
        sign_g = bitslice.unpack_bits(
            jnp.take_along_axis(sign_full, cur_idx[..., None], axis=2), axis=-1
        )
        partial = partial + plane_scores(plane_g, sign_g, qf) * float(2**p_r)
        score_h = jnp.max(partial, axis=2)
        score_h = jnp.where(
            jnp.take_along_axis(
                jnp.broadcast_to(valid[:, None, :], (B, Hk, S)), cur_idx, axis=2
            ),
            score_h, NEG_INF,
        )

    _, li = jax.lax.top_k(score_h, k_max)
    idx = li if cur_idx is None else jnp.take_along_axis(cur_idx, li, axis=2)
    idx_valid = jnp.take_along_axis(
        jnp.broadcast_to(valid[:, None, :], (B, Hk, S)), idx, axis=2
    )
    return idx, idx_valid


def _formal_attend(q, k_q, k_scale, v, v_scale, idx_valid, scale):
    """Engine ``_bgpp_formal_attend``'s int8 attend (``_cache_attend`` with
    fmt=int8, Q=1, valid=ones, head_mask=idx_valid) on the grouped query."""
    qg = q[:, :, :, None, :].astype(jnp.float32)  # (B, Hk, g, Q=1, D)
    # engine mask: all-ones lane validity AND the per-(b,h) candidate mask
    mask = idx_valid[:, :, None, None, :]  # (B, Hk, 1, Q=1, k)
    q_scale = jnp.maximum(jnp.max(jnp.abs(qg), axis=-1, keepdims=True), 1e-8) / 127.0
    q_q = jnp.clip(jnp.round(qg / q_scale), -127, 127).astype(jnp.int8)
    logits_i = jnp.einsum(
        "bhgqd,bhsd->bhgqs", q_q, k_q, preferred_element_type=jnp.int32
    )
    logits = (
        logits_i.astype(jnp.float32)
        * q_scale
        * k_scale[:, :, None, None, :]
        * scale
    )
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    w = probs * v_scale[:, :, None, None, :]
    w_scale = jnp.maximum(jnp.max(w, axis=-1, keepdims=True), 1e-20) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale), 0, 127).astype(jnp.int8)
    out = jnp.einsum(
        "bhgqs,bhsd->bhgqd", w_q, v, preferred_element_type=jnp.float32
    )
    return (out * w_scale)[:, :, :, 0]


def bgpp_paged_attend_ref(
    q: jax.Array,  # (B, Hk, g, D) f32 RAW grouped decode query
    k_planes: jax.Array,  # (NBITS, n_tok, Hk, D/8) uint8 packed planes
    k_sign: jax.Array,  # (n_tok, Hk, D/8) uint8 packed sign plane
    k_scale: jax.Array,  # (n_tok, Hk) f32
    v: jax.Array,  # (n_tok, Hk, D) int8
    v_scale: jax.Array,  # (n_tok, Hk) f32
    phys: jax.Array,  # (B, S) int32 logical->pool row gather map
    pos: jax.Array,  # (B,) int32 — keys at logical s <= pos[b] are valid
    *,
    rounds: int,
    k_max: int,
    survivors: Tuple[int, ...],
) -> jax.Array:
    """Fused two-phase BGPP decode -> f32 ``(B, Hk, g, D)``.

    The ``(rounds, k_max, survivors)`` plan comes from the caller
    (``kv_cache.bgpp_decode_plan`` in the serving engine), so the kernel
    reads exactly the bytes the kv-read counter prices.
    """
    B, Hk, g, D = q.shape
    S = phys.shape[1]
    valid = jnp.arange(S)[None, :] <= pos[:, None]  # (B, S)
    qf = _quant_query(q)

    def heads_major(pool, plane=None):
        # paged_plane / paged_sign: phys (B,S) -> heads-major (B,Hk,S,D/8)
        a = pool if plane is None else pool[plane]
        return jnp.moveaxis(a[phys], 2, 1)

    def plane_at(p, idx):
        rows = jnp.take_along_axis(
            phys, idx.reshape(B, Hk * idx.shape[2]), axis=1
        ).reshape(idx.shape)
        return _rows_per_head(k_planes[p], rows, False)

    idx, idx_valid = _topk_indices(
        qf, heads_major(k_planes, NBITS - 1), heads_major(k_sign), plane_at,
        valid, rounds, k_max, survivors,
    )

    rows = jnp.take_along_axis(
        phys, idx.reshape(B, Hk * k_max), axis=1
    ).reshape(B, Hk, k_max)
    planes_g = _rows_per_head(k_planes, rows, True)  # (NBITS, B, Hk, k, D/8)
    sign_g = _rows_per_head(k_sign, rows, False)
    k_q = bitslice.from_sign_magnitude(
        bitslice.unpack_bits(sign_g, axis=-1),
        bitslice.from_bitplanes(bitslice.unpack_bits(planes_g, axis=-1)),
    ).astype(jnp.int8)
    return _formal_attend(
        q, k_q,
        _rows_per_head(k_scale, rows, False),
        _rows_per_head(v, rows, False),
        _rows_per_head(v_scale, rows, False),
        idx_valid, D**-0.5,
    )
