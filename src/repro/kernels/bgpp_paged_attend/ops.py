"""Public wrapper + dispatch routing for the fused BGPP paged decode.

Build-time validation lives here (ISSUE-7 satellite: GQA/plan/shape
mistakes must raise actionable errors at the call boundary, not surface as
Pallas lowering failures deep inside Mosaic).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels import dispatch
from repro.kernels.bgpp_paged_attend.kernel import bgpp_paged_attend_pallas
from repro.kernels.bgpp_paged_attend.ref import NBITS, bgpp_paged_attend_ref


@functools.partial(
    jax.jit, static_argnames=("rounds", "k_max", "survivors", "interpret")
)
def _bgpp_pallas_path(
    q, k_planes, k_sign, k_scale, v, v_scale, phys, pos, *,
    rounds: int, k_max: int, survivors: Tuple[int, ...],
    interpret: bool = False,
):
    return bgpp_paged_attend_pallas(
        q, k_planes, k_sign, k_scale, v, v_scale, phys, pos,
        rounds=rounds, k_max=k_max, survivors=survivors,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("rounds", "k_max", "survivors")
)
def _bgpp_ref_jit(q, k_planes, k_sign, k_scale, v, v_scale, phys, pos, *,
                  rounds, k_max, survivors):
    return bgpp_paged_attend_ref(
        q, k_planes, k_sign, k_scale, v, v_scale, phys, pos,
        rounds=rounds, k_max=k_max, survivors=survivors,
    )


def _bgpp_ref_path(q, k_planes, k_sign, k_scale, v, v_scale, phys, pos, *,
                   rounds, k_max, survivors):
    return _bgpp_ref_jit(
        q, k_planes, k_sign, k_scale, v, v_scale, phys, pos,
        rounds=rounds, k_max=k_max, survivors=survivors,
    )


def _validate(q, k_planes, k_sign, k_scale, v, v_scale, phys, pos,
              rounds, k_max, survivors):
    if q.ndim != 4:
        raise ValueError(
            f"bgpp_paged_attend: q must be grouped (B, Hk, g, D), got shape "
            f"{q.shape} — reshape (B, Hq, D) queries with "
            f"g = num_heads // num_kv_heads first"
        )
    B, Hk, g, D = q.shape
    if D % 8:
        raise ValueError(
            f"bgpp_paged_attend: head_dim={D} is not a multiple of 8 — "
            f"packed bit planes need whole bytes per row"
        )
    if k_planes.ndim != 4 or k_planes.shape[0] != NBITS:
        raise ValueError(
            f"bgpp_paged_attend: k_planes must be (NBITS={NBITS}, n_tok, Hk, "
            f"D/8) packed magnitude planes; got {k_planes.shape}"
        )
    nbits, n_tok, hk_p, Dp = k_planes.shape
    if hk_p != Hk:
        raise ValueError(
            f"bgpp_paged_attend: q carries Hk={Hk} kv heads but the pool "
            f"carries {hk_p} — under shard_map both operands must be the "
            f"SAME device-local head shard"
        )
    if Dp != D // 8:
        raise ValueError(
            f"bgpp_paged_attend: packed plane width {Dp} != head_dim/8 = "
            f"{D // 8}"
        )
    if k_sign.shape != (n_tok, Hk, Dp):
        raise ValueError(
            f"bgpp_paged_attend: k_sign must be (n_tok, Hk, D/8) = "
            f"({n_tok}, {Hk}, {Dp}); got {k_sign.shape}"
        )
    if k_scale.shape != (n_tok, Hk) or v_scale.shape != (n_tok, Hk):
        raise ValueError(
            f"bgpp_paged_attend: scales must be (n_tok={n_tok}, Hk={Hk}); "
            f"got k_scale {k_scale.shape} / v_scale {v_scale.shape}"
        )
    if v.shape != (n_tok, Hk, D):
        raise ValueError(
            f"bgpp_paged_attend: v must be (n_tok, Hk, D) int8; got {v.shape}"
        )
    if phys.ndim != 2 or phys.shape[0] != B or pos.shape != (B,):
        raise ValueError(
            f"bgpp_paged_attend: phys must be (B={B}, S) and pos (B,); got "
            f"{phys.shape} / {pos.shape}"
        )
    S = phys.shape[1]
    survivors = tuple(int(s) for s in survivors)
    if len(survivors) != rounds:
        raise ValueError(
            f"bgpp_paged_attend: plan has rounds={rounds} but "
            f"{len(survivors)} survivor widths {survivors} — pass the tuple "
            f"from kv_cache.bgpp_decode_plan unmodified"
        )
    if survivors[0] != S:
        raise ValueError(
            f"bgpp_paged_attend: survivors[0]={survivors[0]} must equal the "
            f"logical context S={S} (round 0 scans every position)"
        )
    if any(survivors[i] < survivors[i + 1] for i in range(rounds - 1)):
        raise ValueError(
            f"bgpp_paged_attend: survivor widths must be non-increasing; "
            f"got {survivors}"
        )
    if not (1 <= k_max <= S) or k_max > survivors[-1]:
        raise ValueError(
            f"bgpp_paged_attend: k_max={k_max} must satisfy 1 <= k_max <= "
            f"min(S={S}, survivors[-1]={survivors[-1]})"
        )


def bgpp_paged_attend(
    q: jax.Array,  # (B, Hk, g, D) f32 RAW grouped decode query
    k_planes: jax.Array,  # (NBITS, n_tok, Hk, D/8) uint8 packed planes
    k_sign: jax.Array,  # (n_tok, Hk, D/8) uint8 packed sign plane
    k_scale: jax.Array,  # (n_tok, Hk) f32
    v: jax.Array,  # (n_tok, Hk, D) int8
    v_scale: jax.Array,  # (n_tok, Hk) f32
    phys: jax.Array,  # (B, S) int32 logical -> pool row map
    pos: jax.Array,  # (B,) int32 last valid logical position per slot
    *,
    rounds: int,
    k_max: int,
    survivors: Tuple[int, ...],
    interpret: bool = False,
    mode: Optional[str] = None,
) -> jax.Array:
    """Fused two-phase BGPP paged decode -> f32 ``(B, Hk, g, D)``.

    ``(rounds, k_max, survivors)`` is the static progressive plan from
    :func:`repro.serving.kv_cache.bgpp_decode_plan`.  Routing between
    compiled / interpret / ref is governed by :mod:`repro.kernels.dispatch`.
    """
    survivors = tuple(int(s) for s in survivors)
    _validate(q, k_planes, k_sign, k_scale, v, v_scale, phys, pos,
              rounds, k_max, survivors)
    return dispatch.pallas_dispatch(
        "bgpp_paged_attend",
        _bgpp_pallas_path,
        _bgpp_ref_path,
        q, k_planes, k_sign, k_scale, v, v_scale, phys, pos,
        rounds=rounds,
        k_max=k_max,
        survivors=survivors,
        mode=mode,
        interpret=interpret,
    )
