"""BSTC plane-decode Pallas kernel (paper §4.4, TPU-adapted).

The ASIC's serial SIPO decoder becomes a fully-vectorized three-step pipeline
per (group-row-tile, H-tile):

  1. unpack the two-state indicator bitmap (1 bit per m-bit column) from its
     8:1 byte packing;
  2. prefix-sum addressing: position of column h's pattern in the packed
     non-zero stream = (host-precomputed tile base offset) + within-tile
     cumsum − 1 — the vector equivalent of the paper's segmented layout with
     per-sub-weight start addresses (Fig. 15c);
  3. gather the patterns and mask zero columns.

Output is the (G, H) *group pattern* tensor — exactly the BRCR kernel's
input, realizing the paper's "coding and computation at the same group
granularity" (no re-layout between decode and compute).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _unpack_bits_i32(packed: jax.Array) -> jax.Array:
    """(..., B) uint8 -> (..., 8B) int32 {0,1}; little-endian within bytes."""
    x = packed.astype(jnp.int32)
    shape = x.shape[:-1] + (x.shape[-1], 8)
    shifts = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    bits = (x[..., None] >> shifts) & 1
    return bits.reshape(x.shape[:-1] + (x.shape[-1] * 8,))


def _kernel(bitmap_ref, offs_ref, patterns_ref, out_ref):
    bits = _unpack_bits_i32(bitmap_ref[...])  # (TG, TK)
    pos = jnp.cumsum(bits, axis=1) - 1 + offs_ref[...]  # (TG, TK)
    pos = jnp.clip(pos, 0, patterns_ref.shape[1] - 1)
    vals = jnp.take_along_axis(patterns_ref[...].astype(jnp.int32), pos, axis=1)
    out_ref[...] = jnp.where(bits != 0, vals, 0).astype(out_ref.dtype)


def bstc_decode_pallas(
    bitmap: jax.Array,  # (G, H//8) uint8 packed indicators
    tile_offsets: jax.Array,  # (G, H//TK) int32 stream base per tile
    patterns: jax.Array,  # (G, cap) uint8 packed non-zero patterns
    *,
    tile_g: int = 8,
    tile_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    G, Hp = bitmap.shape
    H = Hp * 8
    assert H % tile_k == 0 and G % tile_g == 0, (G, H, tile_g, tile_k)
    cap = patterns.shape[1]
    grid = (G // tile_g, H // tile_k)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_g, tile_k // 8), lambda g, kt: (g, kt)),
            pl.BlockSpec((tile_g, 1), lambda g, kt: (g, kt)),
            pl.BlockSpec((tile_g, cap), lambda g, kt: (g, 0)),
        ],
        out_specs=pl.BlockSpec((tile_g, tile_k), lambda g, kt: (g, kt)),
        out_shape=jax.ShapeDtypeStruct((G, H), jnp.uint8),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bitmap, tile_offsets, patterns)
