"""Pure-jnp oracle for BSTC plane decoding (patterns, not expanded rows)."""

from __future__ import annotations

import jax.numpy as jnp


def decode_patterns_ref(
    bitmap_bits: jnp.ndarray,  # (G, H) uint8 {0,1}
    patterns: jnp.ndarray,  # (G, cap) uint8
) -> jnp.ndarray:
    """Prefix-sum addressed gather -> (G, H) uint8 column patterns."""
    pos = jnp.cumsum(bitmap_bits.astype(jnp.int32), axis=1) - 1
    pos = jnp.clip(pos, 0, patterns.shape[1] - 1)
    vals = jnp.take_along_axis(patterns, pos, axis=1)
    return jnp.where(bitmap_bits != 0, vals, 0).astype(jnp.uint8)
