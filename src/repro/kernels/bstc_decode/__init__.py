from repro.kernels.bstc_decode.ops import (  # noqa: F401
    bstc_decode_patterns,
    prepare_encoded_plane,
)
