"""jit'd wrapper + operand prep for the BSTC decode kernel."""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitslice
from repro.core.bstc import EncodedPlane
from repro.kernels import dispatch
from repro.kernels.bstc_decode.kernel import bstc_decode_pallas
from repro.kernels.bstc_decode.ref import decode_patterns_ref


class EncodedPlaneOperands(NamedTuple):
    """Device-ready compressed plane: packed bitmap + padded patterns +
    per-H-tile stream offsets (the segmented-layout start addresses)."""

    bitmap: jax.Array  # (G, H//8) uint8
    tile_offsets: jax.Array  # (G, H//tile_k) int32
    patterns: jax.Array  # (G, cap) uint8
    H: int
    m: int

    @property
    def compressed_bytes(self) -> int:
        return int(
            self.bitmap.size + np.ceil(self.patterns.size * self.m / 8)
        )


def prepare_encoded_plane(enc: EncodedPlane, tile_k: int = 512) -> EncodedPlaneOperands:
    """Host-side: EncodedPlane -> kernel operands with tile stream offsets."""
    G, H = enc.bitmap.shape
    assert H % tile_k == 0, (H, tile_k)
    bitmap = _pack8(enc.bitmap)
    csum = np.cumsum(enc.bitmap, axis=1)
    # exclusive prefix count at each tile start
    starts = np.arange(0, H, tile_k)
    tile_offsets = np.concatenate(
        [np.zeros((G, 1), np.int64), csum[:, starts[1:] - 1]], axis=1
    ).astype(np.int32)
    cap = max(int(enc.nnz.max()), 1)
    cap = -(-cap // 8) * 8  # pad for clean byte math
    patterns = np.zeros((G, cap), np.uint8)
    patterns[:, : enc.patterns.shape[1]] = enc.patterns
    return EncodedPlaneOperands(
        bitmap=jnp.asarray(bitmap),
        tile_offsets=jnp.asarray(tile_offsets),
        patterns=jnp.asarray(patterns),
        H=H,
        m=enc.m,
    )


@functools.partial(jax.jit, static_argnames=("tile_g", "tile_k", "interpret"))
def _decode_jit(bitmap, tile_offsets, patterns, *, tile_g, tile_k, interpret):
    return bstc_decode_pallas(
        bitmap, tile_offsets, patterns,
        tile_g=tile_g, tile_k=tile_k, interpret=interpret,
    )


def _decode_pallas_path(ops, *, tile_g, interpret):
    G = ops.bitmap.shape[0]
    tile_k = ops.H // ops.tile_offsets.shape[1]
    return _decode_jit(
        ops.bitmap,
        ops.tile_offsets,
        ops.patterns,
        tile_g=min(tile_g, G),
        tile_k=tile_k,
        interpret=interpret,
    )


def _decode_ref_path(ops, *, tile_g):
    del tile_g  # the oracle is tiling-free
    return decode_patterns_ref(bitslice.unpack_bits(ops.bitmap), ops.patterns)


def bstc_decode_patterns(
    ops: EncodedPlaneOperands,
    *,
    tile_g: int = 8,
    interpret: bool = False,
    mode: str | None = None,
) -> jax.Array:
    """Decode to (G, H) uint8 group patterns (BRCR kernel input format).

    The H-tile size is pinned by the prepared per-tile stream offsets.
    Routing between compiled / interpret / ref is governed by
    :mod:`repro.kernels.dispatch`.
    """
    return dispatch.pallas_dispatch(
        "bstc_decode",
        _decode_pallas_path,
        _decode_ref_path,
        ops,
        tile_g=tile_g,
        mode=mode,
        interpret=interpret,
    )


def _pack8(bits: np.ndarray) -> np.ndarray:
    *lead, n = bits.shape
    assert n % 8 == 0
    b = bits.reshape(*lead, n // 8, 8).astype(np.uint32)
    return (b * (1 << np.arange(8, dtype=np.uint32))).sum(axis=-1).astype(np.uint8)
