"""Dispatch-routed wrapper for one BGPP scoring round over a bit-planar
key cache."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.bgpp_score.ref import bgpp_score_round_ref


@functools.partial(jax.jit, static_argnames=("tile_s", "interpret"))
def _bgpp_pallas_path(
    q: jax.Array,  # (D,) int32 (already MSB-truncated per paper)
    plane_packed: jax.Array,  # (S, D//8) uint8 — magnitude plane p
    sign_packed: jax.Array,  # (S, D//8) uint8
    alive: jax.Array,  # (S,) bool
    *,
    tile_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    from repro.kernels.bgpp_score.kernel import bgpp_score_pallas

    S = plane_packed.shape[0]
    tile_s = min(tile_s, S)
    pad = (-S) % tile_s
    if pad:
        plane_packed = jnp.pad(plane_packed, ((0, pad), (0, 0)))
        sign_packed = jnp.pad(sign_packed, ((0, pad), (0, 0)))
        alive = jnp.pad(alive, (0, pad))
    tile_any = jnp.any(
        alive.reshape(-1, tile_s), axis=1
    ).astype(jnp.int32)
    alive_i = alive.astype(jnp.int32)[:, None]
    out = bgpp_score_pallas(
        q.astype(jnp.int32)[None, :],
        plane_packed,
        sign_packed,
        alive_i,
        tile_any,
        tile_s=tile_s,
        interpret=interpret,
    )
    return out[:S, 0]


@jax.jit
def _bgpp_ref_jit(q, plane_packed, sign_packed, alive):
    from repro.core.bitslice import unpack_bits

    return bgpp_score_round_ref(
        q.astype(jnp.int32),
        unpack_bits(plane_packed),
        unpack_bits(sign_packed),
        alive,
    )


def _bgpp_ref_path(q, plane_packed, sign_packed, alive, *, tile_s=256):
    del tile_s  # the oracle is tiling-free; keep it out of the jit cache key
    return _bgpp_ref_jit(q, plane_packed, sign_packed, alive)


def bgpp_score_round(
    q: jax.Array,  # (D,) int32
    plane_packed: jax.Array,  # (S, D//8) uint8
    sign_packed: jax.Array,  # (S, D//8) uint8
    alive: jax.Array,  # (S,) bool
    *,
    tile_s: int = 256,
    interpret: bool = False,
    mode: str | None = None,
) -> jax.Array:
    """(S,) int32 masked plane scores (without the 2^p weighting).

    Routing between compiled / interpret / ref is governed by
    :mod:`repro.kernels.dispatch`.
    """
    return dispatch.pallas_dispatch(
        "bgpp_score",
        _bgpp_pallas_path,
        _bgpp_ref_path,
        q,
        plane_packed,
        sign_packed,
        alive,
        tile_s=tile_s,
        mode=mode,
        interpret=interpret,
    )
