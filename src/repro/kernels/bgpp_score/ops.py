"""jit'd wrapper for one BGPP scoring round over a bit-planar key cache."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("tile_s", "interpret"))
def bgpp_score_round(
    q: jax.Array,  # (D,) int32 (already MSB-truncated per paper)
    plane_packed: jax.Array,  # (S, D//8) uint8 — magnitude plane p
    sign_packed: jax.Array,  # (S, D//8) uint8
    alive: jax.Array,  # (S,) bool
    *,
    tile_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """(S,) int32 masked plane scores (without the 2^p weighting)."""
    from repro.kernels.bgpp_score.kernel import bgpp_score_pallas

    S = plane_packed.shape[0]
    tile_s = min(tile_s, S)
    pad = (-S) % tile_s
    if pad:
        plane_packed = jnp.pad(plane_packed, ((0, pad), (0, 0)))
        sign_packed = jnp.pad(sign_packed, ((0, pad), (0, 0)))
        alive = jnp.pad(alive, (0, pad))
    tile_any = jnp.any(
        alive.reshape(-1, tile_s), axis=1
    ).astype(jnp.int32)
    alive_i = alive.astype(jnp.int32)[:, None]
    out = bgpp_score_pallas(
        q.astype(jnp.int32)[None, :],
        plane_packed,
        sign_packed,
        alive_i,
        tile_any,
        tile_s=tile_s,
        interpret=interpret,
    )
    return out[:S, 0]
