"""Oracle for one BGPP scoring round."""

from __future__ import annotations

import jax.numpy as jnp


def bgpp_score_round_ref(
    q: jnp.ndarray,  # (D,) int32
    plane_bits: jnp.ndarray,  # (S, D) uint8 {0,1} — magnitude plane p
    sign_bits: jnp.ndarray,  # (S, D) uint8
    alive: jnp.ndarray,  # (S,) bool
) -> jnp.ndarray:
    """(S,) int32 = (plane ⊙ sign) · q for alive keys, 0 otherwise."""
    signed = jnp.where(sign_bits.astype(bool), -1, 1) * plane_bits.astype(jnp.int32)
    contrib = signed @ q.astype(jnp.int32)
    return jnp.where(alive, contrib, 0).astype(jnp.int32)
