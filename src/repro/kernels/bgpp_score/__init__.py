from repro.kernels.bgpp_score.ops import bgpp_score_round  # noqa: F401
