"""BGPP round-scoring Pallas kernel (paper §4.5's bit-serial adder trees).

One BGPP round = a masked bit-plane inner product: for every still-alive key,
score += q · ((1 − 2·sign) ⊙ plane_bits).  Keys are tiled along S; a tile
whose alive-count is zero skips both the HBM plane fetch *and* the compute —
the kernel-level realization of the paper's early termination (rejected keys'
remaining planes are never touched) and the clock-gating of idle adder trees.

The plane/sign inputs are the bit-planar packed KV cache (1 bit per element,
8:1 in uint8), so the per-round HBM traffic is exactly the paper's model:
D/8 bytes per alive key per round.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _unpack_bits_i32(packed: jax.Array) -> jax.Array:
    x = packed.astype(jnp.int32)
    shape = x.shape[:-1] + (x.shape[-1], 8)
    shifts = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    bits = (x[..., None] >> shifts) & 1
    return bits.reshape(x.shape[:-1] + (x.shape[-1] * 8,))


def _kernel(q_ref, plane_ref, sign_ref, alive_ref, any_ref, out_ref):
    @pl.when(any_ref[0] == 0)
    def _skip():  # whole tile rejected earlier: no fetch, no adds
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(any_ref[0] != 0)
    def _score():
        bits = _unpack_bits_i32(plane_ref[...])  # (TS, D)
        sign = _unpack_bits_i32(sign_ref[...])
        signed = jnp.where(sign != 0, -bits, bits)
        q = q_ref[0].astype(jnp.int32)  # (D,)
        contrib = jnp.sum(signed * q[None, :], axis=1, keepdims=True)  # (TS,1)
        alive = alive_ref[...]  # (TS, 1) int32
        out_ref[...] = jnp.where(alive != 0, contrib, 0).astype(out_ref.dtype)


def bgpp_score_pallas(
    q: jax.Array,  # (1, D) int32
    plane_packed: jax.Array,  # (S, D//8) uint8
    sign_packed: jax.Array,  # (S, D//8) uint8
    alive: jax.Array,  # (S, 1) int32
    tile_any: jax.Array,  # (S//TS,) int32 — per-tile alive flags
    *,
    tile_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    S, Dp = plane_packed.shape
    assert S % tile_s == 0
    grid = (S // tile_s,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Dp * 8), lambda s: (0, 0)),
            pl.BlockSpec((tile_s, Dp), lambda s: (s, 0)),
            pl.BlockSpec((tile_s, Dp), lambda s: (s, 0)),
            pl.BlockSpec((tile_s, 1), lambda s: (s, 0)),
            pl.BlockSpec((1,), lambda s: (s,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((tile_s, 1), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, 1), jnp.int32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(q, plane_packed, sign_packed, alive, tile_any)
