"""Public wrapper + dispatch routing for the paged flash decode family.

Build-time validation lives here (ISSUE-7 satellite: shape/divisibility
mistakes must raise actionable errors at the call boundary, not surface as
Pallas lowering failures deep inside Mosaic).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import dispatch
from repro.kernels.paged_flash_decode.kernel import paged_flash_decode_pallas
from repro.kernels.paged_flash_decode.ref import paged_flash_decode_ref


@functools.partial(jax.jit, static_argnames=("page_size", "interpret"))
def _paged_flash_pallas_path(
    q, k, v, page_ids, pos, *,
    page_size: int,
    k_scale=None, v_scale=None,
    interpret: bool = False,
):
    return paged_flash_decode_pallas(
        q, k, v, page_ids, pos, page_size=page_size,
        k_scale=k_scale, v_scale=v_scale, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("page_size",))
def _paged_flash_ref_jit(q, k, v, page_ids, pos, *, page_size,
                         k_scale=None, v_scale=None):
    return paged_flash_decode_ref(
        q, k, v, page_ids, pos, page_size=page_size,
        k_scale=k_scale, v_scale=v_scale,
    )


def _paged_flash_ref_path(q, k, v, page_ids, pos, *, page_size,
                          k_scale=None, v_scale=None):
    return _paged_flash_ref_jit(
        q, k, v, page_ids, pos, page_size=page_size,
        k_scale=k_scale, v_scale=v_scale,
    )


def _validate(q, k, v, page_ids, pos, page_size, k_scale, v_scale):
    if q.ndim != 4:
        raise ValueError(
            f"paged_flash_decode: q must be grouped (B, Hk, g, D), got "
            f"shape {q.shape} — reshape (B, Hq, D) queries with "
            f"g = num_heads // num_kv_heads first"
        )
    B, Hk, g, D = q.shape
    if k.ndim != 3 or k.shape != v.shape:
        raise ValueError(
            f"paged_flash_decode: pools must be token-major (n_tok, Hk, D); "
            f"got k {k.shape} vs v {v.shape}"
        )
    n_tok = k.shape[0]
    if k.shape[1] != Hk:
        raise ValueError(
            f"paged_flash_decode: q carries Hk={Hk} kv heads but the pool "
            f"carries {k.shape[1]} — under shard_map both operands must be "
            f"the SAME device-local head shard"
        )
    if k.shape[2] != D:
        raise ValueError(
            f"paged_flash_decode: head_dim mismatch q D={D} vs pool "
            f"D={k.shape[2]}"
        )
    if page_size < 1 or n_tok % page_size:
        raise ValueError(
            f"paged_flash_decode: pool of {n_tok} token rows is not a whole "
            f"number of pages of page_size={page_size}"
        )
    if page_ids.ndim != 2 or page_ids.shape[0] != B or pos.shape != (B,):
        raise ValueError(
            f"paged_flash_decode: page_ids must be (B={B}, pages_per_slot) "
            f"and pos (B,); got {page_ids.shape} / {pos.shape}"
        )
    if (k_scale is None) != (v_scale is None):
        raise ValueError(
            "paged_flash_decode: int8 pools need BOTH k_scale and v_scale "
            "(n_tok, Hk) — got exactly one"
        )
    if k_scale is not None and k_scale.shape != (n_tok, Hk):
        raise ValueError(
            f"paged_flash_decode: scales must be (n_tok={n_tok}, Hk={Hk}); "
            f"got {k_scale.shape}"
        )


def paged_flash_decode(
    q: jax.Array,  # (B, Hk, g, D) f32 grouped decode query
    k: jax.Array,  # (n_tok, Hk, D)
    v: jax.Array,  # (n_tok, Hk, D)
    page_ids: jax.Array,  # (B, pages_per_slot) int32, -1 = unmapped
    pos: jax.Array,  # (B,) int32 last valid logical position per slot
    *,
    page_size: int,
    k_scale: Optional[jax.Array] = None,
    v_scale: Optional[jax.Array] = None,
    interpret: bool = False,
    mode: Optional[str] = None,
) -> jax.Array:
    """Page-table-aware single-token flash decode -> f32 ``(B, Hk, g, D)``.

    bf16/f32 pools run the dense attend; passing ``k_scale``/``v_scale``
    selects the int8 A2/A3 path.  Routing between compiled / interpret /
    ref is governed by :mod:`repro.kernels.dispatch`.
    """
    _validate(q, k, v, page_ids, pos, page_size, k_scale, v_scale)
    return dispatch.pallas_dispatch(
        "paged_flash_decode",
        _paged_flash_pallas_path,
        _paged_flash_ref_path,
        q, k, v, page_ids, pos,
        page_size=page_size,
        k_scale=k_scale,
        v_scale=v_scale,
        mode=mode,
        interpret=interpret,
    )
