"""Pure-jnp oracle for the page-table-aware flash decode kernel.

Operates on the RAW paged operands — token-major pools ``(n_tok, Hk, ...)``
plus a ``(B, pages_per_slot)`` page table — and reproduces the serving
engine's decode attend (``engine._cache_attend`` at Q=1) op for op over the
gathered heads-major view.  That makes this file the single numerical
contract both the Pallas body and the engine's jnp path are tested against:
the einsum strings, the masking order, the softmax, and the int8
quantize/dot/rescale sequence are copied verbatim from the engine.

No serving imports: the oracle stands alone so the kernel family has no
dependency cycle with ``repro.serving``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # matches repro.core.attention.NEG_INF


def gather_phys(page_ids: jax.Array, page_size: int, seq_len: int) -> jax.Array:
    """Logical->physical gather map: ``(B, pp)`` page ids -> ``(B, S)`` pool
    rows (unmapped ``-1`` pages clamp to row 0; callers mask by position —
    the same convention as ``kv_cache.phys_table``)."""
    pos = jnp.arange(seq_len)
    pid = page_ids[:, pos // page_size]  # (B, S)
    return jnp.where(pid >= 0, pid * page_size + (pos % page_size)[None], 0)


def paged_flash_decode_ref(
    q: jax.Array,  # (B, Hk, g, D) f32 grouped decode query
    k: jax.Array,  # (n_tok, Hk, D) bf16/f32, or int8 with k_scale
    v: jax.Array,  # (n_tok, Hk, D) bf16/f32, or int8 with v_scale
    page_ids: jax.Array,  # (B, pages_per_slot) int32, -1 = unmapped
    pos: jax.Array,  # (B,) int32 — keys at logical s <= pos[b] are valid
    *,
    page_size: int,
    k_scale: Optional[jax.Array] = None,  # (n_tok, Hk) f32 (int8 format)
    v_scale: Optional[jax.Array] = None,  # (n_tok, Hk) f32 (int8 format)
) -> jax.Array:
    """Single-token paged attend -> f32 ``(B, Hk, g, D)``.

    The attended sequence length is ``pages_per_slot * page_size`` (every
    lane a page table row can address); lanes past ``pos[b]`` are masked to
    ``NEG_INF`` exactly like the engine's position mask, so garbage rows
    behind unmapped pages can never contribute probability mass.
    """
    B, Hk, g, D = q.shape
    S = page_ids.shape[1] * page_size
    scale = D**-0.5
    phys = gather_phys(page_ids, page_size, S)  # (B, S)

    def view(pool):  # (n_tok, Hk, ...) -> heads-major (B, Hk, S, ...)
        return jnp.moveaxis(pool[phys], 2, 1)

    qg = q[:, :, :, None, :].astype(jnp.float32)  # (B, Hk, g, Q=1, D)
    valid = (jnp.arange(S)[None, :] <= pos[:, None])[:, None]  # (B, Q=1, S)
    mask = valid[:, None, None]  # (B, 1, 1, Q, S)

    if k_scale is None:
        logits = jnp.einsum(
            "bhgqd,bhsd->bhgqs", qg, view(k).astype(jnp.float32)
        ) * scale
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqs,bhsd->bhgqd", probs, view(v).astype(jnp.float32))
        return out[:, :, :, 0]

    # int8 path: the engine's A2 (8-bit QK^T) + A3 (8-bit PV) sequence
    q_scale = jnp.maximum(jnp.max(jnp.abs(qg), axis=-1, keepdims=True), 1e-8) / 127.0
    q_q = jnp.clip(jnp.round(qg / q_scale), -127, 127).astype(jnp.int8)
    logits_i = jnp.einsum(
        "bhgqd,bhsd->bhgqs", q_q, view(k), preferred_element_type=jnp.int32
    )
    ks = jnp.moveaxis(k_scale[phys], 2, 1)  # (B, Hk, S)
    vs = jnp.moveaxis(v_scale[phys], 2, 1)
    logits = (
        logits_i.astype(jnp.float32)
        * q_scale
        * ks[:, :, None, None, :]
        * scale
    )
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    w = probs * vs[:, :, None, None, :]
    w_scale = jnp.maximum(jnp.max(w, axis=-1, keepdims=True), 1e-20) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale), 0, 127).astype(jnp.int8)
    out = jnp.einsum(
        "bhgqs,bhsd->bhgqd", w_q, view(v), preferred_element_type=jnp.float32
    )
    out = out * w_scale
    return out[:, :, :, 0]
