"""Pallas body for the page-table-aware flash decode kernel.

Grid ``(B, Hk, pages_per_slot)``: each (slot, kv-head) cell walks its page
list via scalar-prefetched page ids — the K/V BlockSpec index maps read
``page_ids[b, j]`` directly, so the DMA engine gathers physical pages
on the fly and no contiguous ``(B, S, Hk, D)`` slot view ever exists in
HBM or VMEM.

Numerics are DEFERRED-softmax, not online-softmax: page steps only write
partial score rows (and stage the V page) into VMEM scratch; the last page
step masks by position, runs one exact softmax and one ``(g, S) @ (S, D)``
PV dot — the same operation order as the serving engine's jnp attend
(``engine._cache_attend``), which is what keeps kernel/ref/engine parity
bit-tight.  A classic online accumulation could not be bit-identical:
``exp(s - m_j) * exp(m_j - m)`` differs from ``exp(s - m)`` in float.

The int8 variant mirrors the engine's A2/A3 sequence: per-(g-row) query
quantization, int8×int8 QK^T with int32 accumulation, f32 rescale by
``q_scale * k_scale * D^-0.5``, then v_scale-folded prob quantization and
an int8 PV dot with f32 accumulation.

Scale rows ride in VMEM scratch ``(1, S)``; compiled-mode lowering keeps
the score row f32 (int32 for the int8 QK) at ``(g, S)`` — small ``g``
under-fills TPU sublanes, which is the documented cost of bit-exactness
over throughput for this family (interpret mode is the correctness bar on
CPU CI).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30  # matches repro.core.attention.NEG_INF


def _softmax(logits):
    """Exact ``jax.nn.softmax`` expansion (max-shift, exp, normalize)."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def _qk_dot(a, b, prefer):
    """(g, D) x (P, D) -> (g, P), contracting D (the engine einsum's axes)."""
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=prefer
    )


def _pv_dot(p, v, prefer):
    """(g, S) x (S, D) -> (g, D)."""
    return jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=prefer
    )


def _bf16_body(pids_ref, pos_ref, q_ref, k_ref, v_ref, out_ref,
               scores_ref, v_buf, *, scale: float, page_size: int):
    b, j = pl.program_id(0), pl.program_id(2)
    P = page_size
    kj = k_ref[0, :, 0, :].astype(jnp.float32)  # (P, D)
    qb = q_ref[0, 0].astype(jnp.float32)  # (g, D)
    scores_ref[:, pl.ds(j * P, P)] = _qk_dot(qb, kj, jnp.float32) * scale
    v_buf[pl.ds(j * P, P), :] = v_ref[0, :, 0, :]

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        g, S = scores_ref.shape
        lane = jax.lax.broadcasted_iota(jnp.int32, (g, S), 1)
        logits = jnp.where(lane <= pos_ref[b], scores_ref[...], NEG_INF)
        probs = _softmax(logits)
        out_ref[0, 0] = _pv_dot(
            probs, v_buf[...].astype(jnp.float32), jnp.float32
        )


def _int8_body(pids_ref, pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
               out_ref, scores_ref, v_buf, ks_buf, vs_buf, qq_buf, qs_buf,
               *, scale: float, page_size: int):
    b, j = pl.program_id(0), pl.program_id(2)
    P = page_size

    @pl.when(j == 0)
    def _quantize_query():  # engine: per-(b,h,g) row absmax/127, clip +-127
        qb = q_ref[0, 0].astype(jnp.float32)  # (g, D)
        qs = jnp.maximum(
            jnp.max(jnp.abs(qb), axis=-1, keepdims=True), 1e-8
        ) / 127.0
        qs_buf[...] = qs
        qq_buf[...] = jnp.clip(jnp.round(qb / qs), -127, 127).astype(jnp.int8)

    scores_ref[:, pl.ds(j * P, P)] = _qk_dot(
        qq_buf[...], k_ref[0, :, 0, :], jnp.int32
    )
    v_buf[pl.ds(j * P, P), :] = v_ref[0, :, 0, :]
    ks_buf[0, pl.ds(j * P, P)] = ks_ref[0, :, 0]
    vs_buf[0, pl.ds(j * P, P)] = vs_ref[0, :, 0]

    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        g, S = scores_ref.shape
        # rescale in the engine's multiply order: (i32 * q_scale) * k_scale
        # * D^-0.5 — elementwise, so the order is value-preserving anyway
        logits = (
            scores_ref[...].astype(jnp.float32) * qs_buf[...]
            * ks_buf[...] * scale
        )
        lane = jax.lax.broadcasted_iota(jnp.int32, (g, S), 1)
        logits = jnp.where(lane <= pos_ref[b], logits, NEG_INF)
        probs = _softmax(logits)
        w = probs * vs_buf[...]
        w_scale = jnp.maximum(
            jnp.max(w, axis=-1, keepdims=True), 1e-20
        ) / 127.0
        w_q = jnp.clip(jnp.round(w / w_scale), 0, 127).astype(jnp.int8)
        out_ref[0, 0] = _pv_dot(w_q, v_buf[...], jnp.float32) * w_scale


def paged_flash_decode_pallas(
    q: jax.Array,  # (B, Hk, g, D) f32
    k: jax.Array,  # (n_tok, Hk, D)
    v: jax.Array,  # (n_tok, Hk, D)
    page_ids: jax.Array,  # (B, pages_per_slot) int32
    pos: jax.Array,  # (B,) int32
    *,
    page_size: int,
    k_scale: Optional[jax.Array] = None,  # (n_tok, Hk) f32
    v_scale: Optional[jax.Array] = None,
    interpret: bool = False,
) -> jax.Array:
    """Launch the paged decode kernel -> f32 ``(B, Hk, g, D)``."""
    B, Hk, g, D = q.shape
    n_tok = k.shape[0]
    P = page_size
    pp = page_ids.shape[1]
    S = pp * P
    scale = D**-0.5
    # free reshape of the token-major pool into (n_pages, P, Hk, D) so one
    # BlockSpec block is exactly one physical page of one head
    kp = k.reshape(n_tok // P, P, Hk, D)
    vp = v.reshape(n_tok // P, P, Hk, D)
    # unmapped pages (-1) clamp to page 0; garbage lanes die at the pos mask
    pids = jnp.maximum(page_ids, 0).astype(jnp.int32)

    qmap = lambda b, h, j, pids_, pos_: (b, h, 0, 0)
    pagemap = lambda b, h, j, pids_, pos_: (pids_[b, j], 0, h, 0)
    in_specs = [
        pl.BlockSpec((1, 1, g, D), qmap),
        pl.BlockSpec((1, P, 1, D), pagemap),
        pl.BlockSpec((1, P, 1, D), pagemap),
    ]
    scratch = [
        pltpu.VMEM((g, S), jnp.float32),
        pltpu.VMEM((S, D), v.dtype),
    ]
    operands = [q.astype(jnp.float32), kp, vp]
    body = functools.partial(_bf16_body, scale=scale, page_size=P)

    if k_scale is not None:
        smap = lambda b, h, j, pids_, pos_: (pids_[b, j], 0, h)
        in_specs += [
            pl.BlockSpec((1, P, 1), smap),
            pl.BlockSpec((1, P, 1), smap),
        ]
        operands += [
            k_scale.reshape(n_tok // P, P, Hk),
            v_scale.reshape(n_tok // P, P, Hk),
        ]
        scratch = [
            pltpu.VMEM((g, S), jnp.int32),
            pltpu.VMEM((S, D), v.dtype),
            pltpu.VMEM((1, S), jnp.float32),
            pltpu.VMEM((1, S), jnp.float32),
            pltpu.VMEM((g, D), jnp.int8),
            pltpu.VMEM((g, 1), jnp.float32),
        ]
        body = functools.partial(_int8_body, scale=scale, page_size=P)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hk, pp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, D), qmap),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hk, g, D), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pids, pos.astype(jnp.int32), *operands)
