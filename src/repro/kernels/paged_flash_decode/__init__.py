"""Page-table-aware flash decode: single-token attention over paged pools."""

from repro.kernels.paged_flash_decode.ops import paged_flash_decode

__all__ = ["paged_flash_decode"]
