"""Public flash-attention wrapper with the model-zoo (B, S, H, D) layout."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=(
        "mask_kind", "window", "q_offset", "scale", "tile_q", "tile_k", "interpret",
    ),
)
def _flash_pallas_path(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hk, D)
    v: jax.Array,  # (B, Sk, Hk, D)
    *,
    mask_kind: str = "causal",
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    tile_q: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    group = Hq // Hk
    scale = (D**-0.5) if scale is None else scale
    tile_q = min(tile_q, Sq)
    tile_k = min(tile_k, Sk)

    qf = jnp.transpose(q, (0, 2, 1, 3)).reshape(B * Hq, Sq, D)
    kf = jnp.transpose(k, (0, 2, 1, 3)).reshape(B * Hk, Sk, D)
    vf = jnp.transpose(v, (0, 2, 1, 3)).reshape(B * Hk, Sk, D)
    out = flash_attention_pallas(
        qf, kf, vf,
        group=group, scale=scale, mask_kind=mask_kind, window=window,
        q_offset=q_offset, tile_q=tile_q, tile_k=tile_k, interpret=interpret,
    )
    return jnp.transpose(out.reshape(B, Hq, Sq, D), (0, 2, 1, 3))


@functools.partial(
    jax.jit, static_argnames=("mask_kind", "window", "q_offset", "scale")
)
def _flash_ref_jit(q, k, v, *, mask_kind, window, q_offset, scale):
    return flash_attention_ref(
        q, k, v, mask_kind=mask_kind, window=window, q_offset=q_offset,
        scale=scale,
    ).astype(q.dtype)


def _flash_ref_path(
    q, k, v, *,
    mask_kind="causal", window=0, q_offset=0, scale=None,
    tile_q=128, tile_k=128,
):
    del tile_q, tile_k  # the oracle is tiling-free; keep out of the jit key
    return _flash_ref_jit(
        q, k, v, mask_kind=mask_kind, window=window, q_offset=q_offset,
        scale=scale,
    )


def flash_attention(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hk, D)
    v: jax.Array,  # (B, Sk, Hk, D)
    *,
    mask_kind: str = "causal",
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    tile_q: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
    mode: Optional[str] = None,
) -> jax.Array:
    """Tiled online-softmax attention over the model-zoo layout.

    Routing between compiled / interpret / ref is governed by
    :mod:`repro.kernels.dispatch`.
    """
    return dispatch.pallas_dispatch(
        "flash_attention",
        _flash_pallas_path,
        _flash_ref_path,
        q,
        k,
        v,
        mask_kind=mask_kind,
        window=window,
        q_offset=q_offset,
        scale=scale,
        tile_q=tile_q,
        tile_k=tile_k,
        mode=mode,
        interpret=interpret,
    )
