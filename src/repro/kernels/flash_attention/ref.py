"""Oracle for the flash-attention kernel: materialized-softmax attention."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import attention


def flash_attention_ref(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hk, D)
    v: jnp.ndarray,  # (B, Sk, Hk, D)
    mask_kind: str = "causal",
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    mask = attention.make_mask(mask_kind, q.shape[1], k.shape[1], window, q_offset)
    return attention.attend(q, k, v, mask=mask, scale=scale)
