"""Tiled online-softmax (flash) attention Pallas kernel.

Needed by the 32k/500k shapes: XLA cannot fuse the S×S logits away on its
own, and the MCBP serving engine needs the sliding/chunked mask families of
the assigned archs (gemma3 local layers, mixtral SWA, llama4 chunked).

Grid: (B·Hq, Sq/TQ, Sk/TK), K-tiles innermost ("arbitrary"); VMEM carries the
running max/denominator/accumulator between K-tiles.  Fully-masked K-tiles
are skipped via ``pl.when`` on an index-range predicate — with a sliding
window this turns the quadratic sweep into O(Sq·window) work, the structural
analogue of MCBP's prediction-driven KV skipping for the *static* mask part.
GQA is handled in the index maps (query head h reads KV head h // group).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, TQ, D)
    k_ref,  # (1, TK, D)
    v_ref,  # (1, TK, D)
    out_ref,  # (1, TQ, D)
    m_ref,  # scratch (TQ, 128) f32
    l_ref,  # scratch (TQ, 128) f32
    acc_ref,  # scratch (TQ, D) f32
    *,
    scale: float,
    mask_kind: str,
    window: int,
    q_offset: int,
    tile_q: int,
    tile_k: int,
    k_tiles: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level mask predicate: does this (iq, ik) tile contain any
    # unmasked entry?  q rows are offset by q_offset (cache continuation).
    q_lo = iq * tile_q + q_offset
    q_hi = q_lo + tile_q - 1
    k_lo = ik * tile_k
    k_hi = k_lo + tile_k - 1
    if mask_kind == "full":
        live = jnp.bool_(True)
    elif mask_kind == "causal":
        live = k_lo <= q_hi
    elif mask_kind == "sliding":
        live = (k_lo <= q_hi) & (k_hi >= q_lo - window + 1)
    elif mask_kind == "chunked":
        live = (k_lo <= q_hi) & (k_hi // window >= q_lo // window)
    else:
        raise ValueError(mask_kind)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (TQ, D)
        k = k_ref[0].astype(jnp.float32)  # (TK, D)
        s = jax.lax.dot_general(
            q, k, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (TQ, TK)

        qi = q_lo + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_k), 0)
        kj = k_lo + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_k), 1)
        if mask_kind == "causal":
            mask = kj <= qi
        elif mask_kind == "sliding":
            mask = (kj <= qi) & (qi - kj < window)
        elif mask_kind == "chunked":
            mask = (kj <= qi) & (qi // window == kj // window)
        else:
            mask = jnp.ones((tile_q, tile_k), bool)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (TQ, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (TQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (TQ, TK)
        correction = jnp.exp(m_prev - m_new)  # (TQ, 1)
        l_new = l_prev * correction + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[0],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == k_tiles - 1)
    def _flush():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        out_ref[0, ...] = (acc_ref[...] / l).astype(out_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (BHq, Sq, D)  — batch*query-heads flattened
    k: jax.Array,  # (BHk, Sk, D)
    v: jax.Array,  # (BHk, Sk, D)
    *,
    group: int,  # Hq // Hk
    scale: float,
    mask_kind: str = "causal",
    window: int = 0,
    q_offset: int = 0,
    tile_q: int = 128,
    tile_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    assert Sq % tile_q == 0 and Sk % tile_k == 0, (Sq, Sk, tile_q, tile_k)
    grid = (BH, Sq // tile_q, Sk // tile_k)
    kernel = functools.partial(
        _kernel,
        scale=scale,
        mask_kind=mask_kind,
        window=window,
        q_offset=q_offset,
        tile_q=tile_q,
        tile_k=tile_k,
        k_tiles=Sk // tile_k,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_q, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, tile_k, D), lambda bh, iq, ik: (bh // group, ik, 0)),
            pl.BlockSpec((1, tile_k, D), lambda bh, iq, ik: (bh // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q, 128), jnp.float32),
            pltpu.VMEM((tile_q, 128), jnp.float32),
            pltpu.VMEM((tile_q, D), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
