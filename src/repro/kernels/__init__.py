"""Pallas TPU kernels for MCBP's compute hot spots.

Each kernel package ships three files:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
  ops.py    — dispatch-routed public wrapper (+ offline data preparation)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Every public wrapper routes through :mod:`repro.kernels.dispatch`, which
selects compiled-TPU vs. interpret vs. pure-JAX ref execution from the
backend, the ``REPRO_KERNEL_DISPATCH`` env var, or an explicit ``mode=``
argument — the same call sites run on CPU CI and real TPUs.

Kernels:
  brcr_gemm       — bit-plane group GEMM via the enumeration factorization
                    (MAV as a one-hot MXU contraction; paper §3.1 / Fig. 14)
  bstc_decode     — two-state-coded plane decompression (bitmap + prefix-sum
                    + gather; paper §4.4), emits BRCR group patterns
  bstc_matmul     — fused BSTC-decompress → dense int8 MXU matmul (the
                    TPU-native decode-stage path; DESIGN.md §2)
  bgpp_score      — masked bit-plane key scoring for one BGPP round
                    (paper §4.5 adder trees)
  flash_attention — tiled online-softmax attention (causal / sliding /
                    chunked masks) for the 32k/500k shapes
  paged_flash_decode — page-table-aware single-token flash decode on the
                    token-major paged KV pool (bf16 + int8 A2/A3); the
                    BlockSpec index map gathers physical pages directly,
                    so no contiguous per-slot KV view is ever built
  bgpp_paged_attend — fused two-phase BGPP paged decode: progressive
                    plane scan + top-k prediction + compacted survivor
                    gather + exact int8 attend in one launch (paper §3.3)
"""

from repro.kernels.dispatch import (  # noqa: F401
    MODE_COMPILED,
    MODE_INTERPRET,
    MODE_REF,
    MODES,
    dispatch_mode,
    pallas_dispatch,
    resolve_mode,
    set_default_mode,
)
