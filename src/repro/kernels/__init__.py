"""Pallas TPU kernels for MCBP's compute hot spots.

Each kernel package ships three files:
  kernel.py — ``pl.pallas_call`` + explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (+ offline data preparation)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  brcr_gemm       — bit-plane group GEMM via the enumeration factorization
                    (MAV as a one-hot MXU contraction; paper §3.1 / Fig. 14)
  bstc_decode     — two-state-coded plane decompression (bitmap + prefix-sum
                    + gather; paper §4.4), emits BRCR group patterns
  bstc_matmul     — fused BSTC-decompress → dense int8 MXU matmul (the
                    TPU-native decode-stage path; DESIGN.md §2)
  bgpp_score      — masked bit-plane key scoring for one BGPP round
                    (paper §4.5 adder trees)
  flash_attention — tiled online-softmax attention (causal / sliding /
                    chunked masks) for the 32k/500k shapes
"""
