"""Unified kernel dispatch: compiled-TPU vs. interpret vs. pure-JAX ref.

Every kernel family (``brcr_gemm``, ``bstc_matmul``, ``bstc_decode``,
``bgpp_score``, ``flash_attention``) routes its public wrapper through
:func:`pallas_dispatch`, so the SAME call sites work on CPU CI hosts and
real TPUs.  Three modes:

  ``compiled``   real ``pallas_call`` lowered through Mosaic — TPU only
  ``interpret``  ``pallas_call(..., interpret=True)`` — runs the identical
                 kernel body on any backend (the CPU-CI correctness path)
  ``ref``        the family's pure-jnp ``ref.py`` oracle — no pallas at
                 all (fallback for hosts where even interpret mode is
                 unavailable, and the cross-check oracle in tests)

Resolution order, first hit wins:

  1. explicit ``mode=`` argument on the call
  2. the legacy ``interpret=True`` flag (kept for source compat)
  3. a process-wide override installed via :func:`set_default_mode`
  4. the ``REPRO_KERNEL_DISPATCH`` environment variable
  5. backend detection: ``compiled`` on TPU, ``interpret`` elsewhere
"""

from __future__ import annotations

import contextlib
import os
from typing import Callable, Optional

from repro import compat

MODE_COMPILED = "compiled"
MODE_INTERPRET = "interpret"
MODE_REF = "ref"
MODES = (MODE_COMPILED, MODE_INTERPRET, MODE_REF)

ENV_VAR = "REPRO_KERNEL_DISPATCH"

_default_mode: Optional[str] = None


def _validate(mode: str) -> str:
    mode = mode.strip().lower()
    if mode not in MODES:
        raise ValueError(
            f"unknown kernel dispatch mode {mode!r}; expected one of {MODES} "
            f"(set via mode=, set_default_mode(), or ${ENV_VAR})"
        )
    return mode


def set_default_mode(mode: Optional[str]) -> None:
    """Install a process-wide dispatch override (None clears it)."""
    global _default_mode
    _default_mode = None if mode is None else _validate(mode)


def get_default_mode() -> Optional[str]:
    return _default_mode


@contextlib.contextmanager
def dispatch_mode(mode: Optional[str]):
    """Scoped dispatch override — NOT jit-traceable state; wrap whole calls."""
    prev = _default_mode
    set_default_mode(mode)
    try:
        yield
    finally:
        set_default_mode(prev)


def resolve_mode(
    mode: Optional[str] = None, *, interpret: bool = False
) -> str:
    """Resolve the effective dispatch mode (see module docstring order)."""
    if mode is not None:
        return _validate(mode)
    if interpret:
        return MODE_INTERPRET
    if _default_mode is not None:
        return _default_mode
    env = os.environ.get(ENV_VAR)
    if env:
        return _validate(env)
    return MODE_COMPILED if compat.is_tpu_backend() else MODE_INTERPRET


def pallas_dispatch(
    name: str,
    pallas_fn: Callable,
    ref_fn: Optional[Callable],
    *args,
    mode: Optional[str] = None,
    interpret: bool = False,
    **kwargs,
):
    """Run one kernel-family call under the resolved dispatch mode.

    ``pallas_fn(*args, interpret=<bool>, **kwargs)`` is the family's jit'd
    pallas path; ``ref_fn(*args, **kwargs)`` is an adapter with the SAME
    signature that evaluates the family's ``ref.py`` oracle.
    """
    resolved = resolve_mode(mode, interpret=interpret)
    if resolved == MODE_REF:
        if ref_fn is None:
            raise NotImplementedError(
                f"kernel family {name!r} has no ref-fallback path"
            )
        return ref_fn(*args, **kwargs)
    if resolved == MODE_COMPILED and not compat.is_tpu_backend():
        raise RuntimeError(
            f"kernel family {name!r}: compiled dispatch requested on "
            f"backend {compat.default_backend()!r}; use mode='interpret' "
            f"or 'ref' (or unset ${ENV_VAR}) on non-TPU hosts"
        )
    return pallas_fn(*args, interpret=resolved == MODE_INTERPRET, **kwargs)
