"""Wrapper + operand prep for the fused BSTC matmul kernel."""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bstc
from repro.core.bitslice import unpack_bits
from repro.kernels import dispatch
from repro.kernels.bstc_decode.ref import decode_patterns_ref
from repro.kernels.bstc_matmul.kernel import bstc_matmul_pallas
from repro.kernels.bstc_matmul.ref import bstc_matmul_ref


class BSTCMatmulOperands(NamedTuple):
    """Per-plane compressed arrays (each encoded plane keeps its own pattern
    capacity): ``enc`` is a flat tuple [bitmap_p, offsets_p, patterns_p]*."""

    enc: Tuple[jax.Array, ...]
    raw: Tuple[jax.Array, ...]  # (M, H//8) uint8 per raw plane
    sign_bits: jax.Array  # (M, H//8) uint8
    scale: Optional[jax.Array]  # (M,) f32 or None
    enc_planes: Tuple[int, ...]
    raw_planes: Tuple[int, ...]
    m: int
    M: int
    H: int

    @property
    def hbm_bytes(self) -> int:
        """Traffic of the compressed representation (what HBM actually moves)."""
        b = self.sign_bits.size + sum(r.size for r in self.raw)
        for e in range(len(self.enc_planes)):
            bitmap, _, patterns = self.enc[3 * e : 3 * e + 3]
            b += bitmap.size + int(np.ceil(patterns.size * self.m / 8))
        return int(b)

    @property
    def dense_bytes(self) -> int:
        return self.M * self.H  # int8 weight

    @property
    def compression_ratio(self) -> float:
        return self.dense_bytes / self.hbm_bytes


def prepare_bstc_matmul_operands(
    w_q: np.ndarray,
    scale: Optional[np.ndarray] = None,
    m: int = 4,
    nbits: int = 7,
    tile_k: int = 512,
    threshold: float = bstc.DEFAULT_SPARSITY_THRESHOLD,
) -> BSTCMatmulOperands:
    """Offline: int8 weight -> BSTC-compressed kernel operands."""
    bw = bstc.encode_weight(
        np.asarray(w_q), np.zeros(w_q.shape[0]) if scale is None else scale,
        m=m, nbits=nbits, threshold=threshold,
    )
    M, H = bw.shape
    assert H % tile_k == 0, (H, tile_k)
    enc_planes = tuple(p for p in range(nbits) if bw.encoded[p] is not None)
    raw_planes = tuple(p for p in range(nbits) if bw.encoded[p] is None)
    G = M // m

    enc: list[jax.Array] = []
    for p in enc_planes:
        e = bw.encoded[p]
        csum = np.cumsum(e.bitmap, axis=1)
        starts = np.arange(0, H, tile_k)
        offsets = np.concatenate(
            [np.zeros((G, 1), np.int64), csum[:, starts[1:] - 1]], axis=1
        ).astype(np.int32)
        cap = -(-max(int(e.nnz.max()), 1) // 8) * 8
        patterns = np.zeros((G, cap), np.uint8)
        patterns[:, : e.patterns.shape[1]] = e.patterns
        enc += [
            jnp.asarray(_pack8(e.bitmap)),
            jnp.asarray(offsets),
            jnp.asarray(patterns),
        ]

    raw = tuple(jnp.asarray(bw.raw_planes[p]) for p in raw_planes)
    return BSTCMatmulOperands(
        enc=tuple(enc),
        raw=raw,
        sign_bits=jnp.asarray(bw.sign),
        scale=None if scale is None else jnp.asarray(scale, jnp.float32),
        enc_planes=enc_planes,
        raw_planes=raw_planes,
        m=m,
        M=M,
        H=H,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "enc_planes", "raw_planes", "m", "M", "tile_m", "tile_n", "interpret",
    ),
)
def _bstc_matmul_jit(
    enc, raw, sign_bits, x, scale,
    *, enc_planes, raw_planes, m, M, tile_m, tile_n, interpret,
):
    y = bstc_matmul_pallas(
        enc, raw, sign_bits, x,
        enc_planes=enc_planes, raw_planes=raw_planes, m=m, M=M,
        tile_m=tile_m, tile_n=tile_n, interpret=interpret,
    )
    if scale is not None:
        y = y * scale[:, None]
    return y


def reconstruct_dense_weight(ops: BSTCMatmulOperands) -> jax.Array:
    """Losslessly rebuild the int weight (M, H) from compressed operands.

    Pure-jnp inverse of :func:`prepare_bstc_matmul_operands` — the ref
    dispatch path and the round-trip property tests both lean on it.
    """
    mag = jnp.zeros((ops.M, ops.H), jnp.int32)
    for i, p in enumerate(ops.enc_planes):
        bitmap, _, patterns = ops.enc[3 * i : 3 * i + 3]
        patt = decode_patterns_ref(unpack_bits(bitmap), patterns)  # (G, H)
        rows = bstc.expand_patterns(patt, ops.m)  # (M, H)
        mag = mag + (rows.astype(jnp.int32) << p)
    for i, p in enumerate(ops.raw_planes):
        mag = mag + (unpack_bits(ops.raw[i]).astype(jnp.int32) << p)
    sign = unpack_bits(ops.sign_bits).astype(jnp.int32)
    return (1 - 2 * sign) * mag


def _bstc_matmul_pallas_path(ops, x, *, tile_m, tile_n, apply_scale, interpret):
    H, N = x.shape
    n_pad = (-N) % tile_n
    if n_pad:
        x = jnp.pad(x, ((0, 0), (0, n_pad)))
    y = _bstc_matmul_jit(
        ops.enc, ops.raw, ops.sign_bits, x,
        ops.scale if apply_scale else None,
        enc_planes=ops.enc_planes, raw_planes=ops.raw_planes, m=ops.m,
        M=ops.M, tile_m=min(tile_m, ops.M), tile_n=min(tile_n, x.shape[1]),
        interpret=interpret,
    )
    return y[:, :N]


@functools.partial(
    jax.jit, static_argnames=("enc_planes", "raw_planes", "m", "M", "H")
)
def _bstc_ref_jit(
    enc, raw, sign_bits, x, scale, *, enc_planes, raw_planes, m, M, H
):
    ops = BSTCMatmulOperands(
        enc, raw, sign_bits, None, enc_planes, raw_planes, m, M, H
    )
    return bstc_matmul_ref(reconstruct_dense_weight(ops), x, scale)


def _bstc_matmul_ref_path(ops, x, *, tile_m, tile_n, apply_scale):
    del tile_m, tile_n  # the oracle is tiling-free; keep out of the jit key
    return _bstc_ref_jit(
        ops.enc, ops.raw, ops.sign_bits, x,
        ops.scale if apply_scale else None,
        enc_planes=ops.enc_planes, raw_planes=ops.raw_planes,
        m=ops.m, M=ops.M, H=ops.H,
    )


def bstc_matmul(
    ops: BSTCMatmulOperands,
    x: jax.Array,
    *,
    tile_m: int = 128,
    tile_n: int = 128,
    apply_scale: bool = False,
    interpret: bool = False,
    mode: str | None = None,
) -> jax.Array:
    """``w_q @ x`` (optionally × per-channel scale) from compressed weights.

    Routing between compiled / interpret / ref is governed by
    :mod:`repro.kernels.dispatch`.
    """
    assert x.shape[0] == ops.H
    return dispatch.pallas_dispatch(
        "bstc_matmul",
        _bstc_matmul_pallas_path,
        _bstc_matmul_ref_path,
        ops,
        x,
        tile_m=tile_m,
        tile_n=tile_n,
        apply_scale=apply_scale,
        mode=mode,
        interpret=interpret,
    )


def _pack8(bits: np.ndarray) -> np.ndarray:
    *lead, n = bits.shape
    assert n % 8 == 0
    b = bits.reshape(*lead, n // 8, 8).astype(np.uint32)
    return (b * (1 << np.arange(8, dtype=np.uint32))).sum(axis=-1).astype(np.uint8)
