"""Oracle for the fused BSTC-decompress -> dense matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp


def bstc_matmul_ref(w_q: jnp.ndarray, x: jnp.ndarray, scale=None) -> jnp.ndarray:
    """Dense f32 product of the *losslessly reconstructed* weight."""
    y = w_q.astype(jnp.float32) @ x.astype(jnp.float32)
    if scale is not None:
        y = y * scale[:, None]
    return y
