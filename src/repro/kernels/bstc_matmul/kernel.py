"""Fused BSTC-decompress → dense int8 MXU matmul (the TPU-native path).

This kernel is the *beyond-paper* TPU realization of BSTC (DESIGN.md §2):
weights live in HBM in two-state-coded bit-plane form (the traffic win), a
weight tile is reconstructed to int8 inside VMEM, and a single dense MXU
matmul consumes it (the compute win — the MXU runs at full rate on dense
int8, unlike the ASIC's adder arrays which profit from skipped adds).

Per (i, j, kt) tile:
  mag  = Σ_p  decode_p(tile) << p      p over encoded planes (prefix-sum
                                       gather, same as bstc_decode) and raw
                                       planes (bit unpack)
  w    = (1 − 2·sign) · mag            sign-magnitude, |w| ≤ 127
  acc += w @ x_tile                    MXU, f32 accumulation

Each encoded plane keeps its own pattern capacity (padded to its max row
nnz), so HBM traffic per weight tile ≈ compressed bytes (bitmap + patterns)
instead of TM·TK int8 bytes — decode-stage GEMV time ÷ CR when memory-bound.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _unpack_bits_i32(packed: jax.Array) -> jax.Array:
    x = packed.astype(jnp.int32)
    shape = x.shape[:-1] + (x.shape[-1], 8)
    shifts = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    bits = (x[..., None] >> shifts) & 1
    return bits.reshape(x.shape[:-1] + (x.shape[-1] * 8,))


def _decode_tile(bitmap_tile, offs_tile, patterns_tile, m: int, tile_m: int):
    """Two-state decode -> expanded rows: (TM, TK) int32 bits of this plane."""
    bits = _unpack_bits_i32(bitmap_tile)  # (TGr, TK)
    pos = jnp.cumsum(bits, axis=1) - 1 + offs_tile  # (TGr, TK)
    pos = jnp.clip(pos, 0, patterns_tile.shape[1] - 1)
    vals = jnp.take_along_axis(patterns_tile.astype(jnp.int32), pos, axis=1)
    patt = jnp.where(bits != 0, vals, 0)  # (TGr, TK)
    tgr, tk = patt.shape
    # expand the m-bit column pattern back to m weight rows
    shifts = jax.lax.broadcasted_iota(jnp.int32, (tgr, m, tk), 1)
    rows = (patt[:, None, :] >> shifts) & 1
    return rows.reshape(tile_m, tk)


def _make_kernel(
    enc_planes: Sequence[int],
    raw_planes: Sequence[int],
    m: int,
    tile_m: int,
    k_tiles: int,
):
    n_enc = len(enc_planes)
    n_raw = len(raw_planes)

    def kernel(*refs):
        enc_refs = refs[: 3 * n_enc]
        raw_refs = refs[3 * n_enc : 3 * n_enc + n_raw]
        sign_ref, x_ref, out_ref, acc_ref = refs[3 * n_enc + n_raw :]
        kt = pl.program_id(2)

        @pl.when(kt == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        mag = jnp.zeros((tile_m, x_ref.shape[0]), jnp.int32)
        for e, p in enumerate(enc_planes):
            bm, offs, patt = enc_refs[3 * e : 3 * e + 3]
            rows = _decode_tile(bm[...], offs[...], patt[...], m, tile_m)
            mag += rows << p
        for r, p in enumerate(raw_planes):
            mag += _unpack_bits_i32(raw_refs[r][...]) << p
        sign = _unpack_bits_i32(sign_ref[...])
        w = jnp.where(sign != 0, -mag, mag).astype(x_ref.dtype)  # (TM, TK)
        acc_ref[...] += jax.lax.dot_general(
            w,
            x_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(kt == k_tiles - 1)
        def _flush():
            out_ref[...] = acc_ref[...].astype(out_ref.dtype)

    return kernel


def bstc_matmul_pallas(
    enc_operands: Sequence[jax.Array],  # flat [bitmap_p, offsets_p, patterns_p]*
    raw_operands: Sequence[jax.Array],  # [(M, H//8) uint8] per raw plane
    sign_bits: jax.Array,  # (M, H//8) uint8
    x: jax.Array,  # (H, N)
    *,
    enc_planes: Sequence[int],
    raw_planes: Sequence[int],
    m: int,
    M: int,
    tile_m: int = 128,
    tile_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    H = sign_bits.shape[1] * 8
    N = x.shape[1]
    n_enc = len(enc_planes)
    if n_enc:
        tile_k = H // enc_operands[1].shape[1]
    else:
        tile_k = min(H, 512)
    assert M % tile_m == 0 and N % tile_n == 0 and H % tile_k == 0
    tgr = tile_m // m
    grid = (M // tile_m, N // tile_n, H // tile_k)

    in_specs = []
    for e in range(n_enc):
        cap = enc_operands[3 * e + 2].shape[1]
        in_specs += [
            pl.BlockSpec((tgr, tile_k // 8), lambda i, j, kt: (i, kt)),
            pl.BlockSpec((tgr, 1), lambda i, j, kt: (i, kt)),
            pl.BlockSpec((tgr, cap), lambda i, j, kt: (i, 0)),
        ]
    for _ in raw_planes:
        in_specs.append(pl.BlockSpec((tile_m, tile_k // 8), lambda i, j, kt: (i, kt)))
    in_specs.append(pl.BlockSpec((tile_m, tile_k // 8), lambda i, j, kt: (i, kt)))
    in_specs.append(pl.BlockSpec((tile_k, tile_n), lambda i, j, kt: (kt, j)))

    kernel = _make_kernel(
        tuple(enc_planes), tuple(raw_planes), m, tile_m, H // tile_k
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kt: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*enc_operands, *raw_operands, sign_bits, x)
