from repro.kernels.bstc_matmul.ops import (  # noqa: F401
    bstc_matmul,
    prepare_bstc_matmul_operands,
)
