"""Sharded, async, elastic checkpointing.

Layout per step: ``<dir>/step_<n>/{manifest.json, arrays.npz}`` with leaves
keyed by pytree path.  Restore accepts a *different* mesh/shardings than the
save (elastic rescale): arrays are saved unsharded (gathered) and re-placed
with ``jax.device_put(x, NamedSharding)`` on load — correct for any mesh
whose axis sizes divide the array dims.  Saves run on a background thread
(async) with an atomic rename commit, and a retention policy prunes old
steps.  ``save_sharded=True`` writes one npz per host shard instead (the
1000-node layout) — both paths round-trip in the tests.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Tree = Any

_SEP = "|"


def _flatten(tree: Tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Tree, flat: Dict[str, np.ndarray]) -> Tree:
    def fill(path, leaf):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        return arr

    return jax.tree_util.tree_map_with_path(fill, template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, state: Tree, metadata: Optional[Dict] = None):
        flat = _flatten(state)  # host copies happen on the caller thread
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, metadata or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, metadata or {})

    def _write(self, step: int, flat: Dict[str, np.ndarray], metadata: Dict):
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in flat.items()},
            **metadata,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: Tree,
        step: Optional[int] = None,
        shardings: Optional[Tree] = None,
    ) -> Tuple[int, Tree]:
        """Restore into the template's structure; re-shard if asked.

        ``shardings`` may target a different mesh than the one that saved —
        the elastic-rescale path.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return step, state
