"""Production serving launcher: slot-level continuous batching over the MCBP
engine (per-slot positions, int8 / bgpp KV caches, request scheduler).

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \\
        --kv-format int8 --requests 8 --slots 4 --seed 0 \\
        [--admission chunked|eager] [--chunk-budget 16] \\
        [--kv-layout slot|paged] [--page-size 8] [--shared-prefix 16] \\
        [--bgpp-rounds 4] [--bgpp-keep-ratio 0.25] \\
        [--weight-format bf16|int8|bstc] \\
        [--spec-decode] [--draft-gamma 4] [--draft-planes 4] \\
        [--server] [--disconnect-every 3] [--disconnect-after 1] \\
        [--trace-out trace.json] [--mesh 2,4 | --data 1 --model 1]

Requests arrive on a Poisson-ish trace with distinct prompt lengths and
decode budgets.  With the default ``--admission chunked`` the scheduler
feeds each arriving prompt through fixed-shape, bucketed prefill chunks
(jitted once per bucket, cache donated) interleaved with the batched decode
step, so a long prompt never stalls in-flight decoders for more than
``--chunk-budget`` prefill tokens; ``--admission eager`` keeps the
whole-prompt B=1 admission as the reference baseline.  ``--kv-layout
paged`` swaps the dense per-slot KV rows for pooled pages behind a page
table (host allocator with refcounts): requests sharing a system prompt
(``--shared-prefix``) reuse each other's resident prompt pages instead of
re-prefilling them, bit-identically to the slot layout.  ``--kv-format
bgpp`` decodes two-phase — bit-plane top-k prediction first
(``--bgpp-rounds``), then a full-precision gather of only the surviving
``--bgpp-keep-ratio`` fraction of keys — and the KV bytes each step read
are reported (``kv_read`` in the stats/trace).  ``--weight-format``
flips the decode projections onto the serve-time weight path
(``repro.serving.weights``): int8/bstc quantized records with the
``weight_read`` byte counter priced from the BSTC coded layout, bf16 the
bit-for-bit raw default.  ``--spec-decode`` turns on bit-plane
speculative decoding (``repro.serving.spec_decode``): a
``--draft-planes``-truncated copy of the serve weights drafts
``--draft-gamma`` tokens per slot per round, a batched verify chain
accepts/rolls back, and the printed stats gain an accepted-tokens/step
acceptance line — the generated tokens stay bit-identical to the
non-speculative run.  ``--trace-out`` dumps
per-request latency/queue-wait plus TTFT/ITL p50/p95 and aggregate
throughput as JSON so runs are reproducible (``--seed``) and comparable
across PRs.

``--server`` routes the same trace through the asyncio front door
(``repro.serving.server``) with simulated clients instead of the offline
replay loop: tiers rotate interactive/batch (interactive preempts batch
chunked prefills), and every ``--disconnect-every``-th client hangs up
after ``--disconnect-after`` streamed tokens — a mid-flight cancellation
that must evict the slot and free its pages.  The printed stats grow
cancellation / preemption / per-tier TTFT+ITL lines (the async-server CI
smoke greps them), and the per-step ``PageAllocator.check()`` leak gate
runs throughout.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro.configs import (ARCH_REGISTRY, WEIGHT_FORMATS,
                           apply_bgpp_overrides,
                           apply_decode_kernel_override,
                           apply_spec_decode_overrides,
                           apply_weight_format_override, get_config)
from repro.distributed import sharding as sh
from repro.launch.mesh import make_debug_mesh
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving import sharded as shd
from repro.serving.request import poisson_trace
from repro.serving.scheduler import Scheduler


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCH_REGISTRY),
                    default="phi4-mini-3.8b")
    ap.add_argument("--kv-format", default="int8",
                    choices=["bf16", "int8", "bgpp"])
    ap.add_argument("--kv-layout", default="slot", choices=["slot", "paged"],
                    help="paged: pooled KV pages + per-slot page table with "
                         "hash-based prefix reuse (bit-identical to slot)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens to "
                         "every request (exercises paged prefix reuse)")
    ap.add_argument("--bgpp-rounds", type=int, default=None,
                    help="progressive-prediction rounds for --kv-format "
                         "bgpp (default: the config's, usually 4)")
    ap.add_argument("--decode-kernel", default=None,
                    choices=["auto", "jnp", "interpret", "kernel"],
                    help="global-layer decode attend path: jnp (legacy), "
                         "interpret/kernel (Pallas paged-attention "
                         "families), auto = kernel on TPU (default: "
                         "config's; env REPRO_DECODE_KERNEL overrides)")
    ap.add_argument("--bgpp-keep-ratio", type=float, default=None,
                    help="fraction of keys fetched at full precision by "
                         "the bgpp top-k decode (default: the config's, "
                         "usually 0.25)")
    ap.add_argument("--weight-format", default=None,
                    choices=sorted(WEIGHT_FORMATS),
                    help="serve-time weight numerics for the decode "
                         "projections: bf16 (raw leaves, bit-for-bit "
                         "default), int8, or bstc (two-state coded pricing "
                         "in weight_read) (default: config's; env "
                         "REPRO_WEIGHT_FORMAT overrides)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="bit-plane speculative decoding: truncated-plane "
                         "draft weights propose --draft-gamma tokens per "
                         "slot per round, verified and rolled back in one "
                         "batched chain (bit-identical output; env "
                         "REPRO_SPEC_DECODE overrides)")
    ap.add_argument("--draft-gamma", type=int, default=None,
                    help="draft tokens per slot per speculative round "
                         "(default: the config's, usually 4)")
    ap.add_argument("--draft-planes", type=int, default=None,
                    help="MSB magnitude bit-planes kept in the draft "
                         "weights, 1-8; >= 7 keeps every bit (default: the "
                         "config's, usually 4)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--admission", default="chunked",
                    choices=["chunked", "eager"],
                    help="chunked: bucketed jitted prefill interleaved with "
                         "decode; eager: whole-prompt B=1 admission")
    ap.add_argument("--chunk-budget", type=int, default=16,
                    help="max prefill tokens between consecutive batched "
                         "decode steps (chunked admission)")
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="mean inter-arrival gap in decode steps")
    ap.add_argument("--seed", type=int, default=0,
                    help="request-trace RNG seed (reproducible runs)")
    ap.add_argument("--server", action="store_true",
                    help="drive the asyncio front door with simulated "
                         "tiered streaming clients (interactive/batch "
                         "rotation, mid-stream disconnects) instead of the "
                         "offline replay loop")
    ap.add_argument("--disconnect-every", type=int, default=3,
                    help="--server: every Nth client disconnects mid-stream "
                         "(0 disables)")
    ap.add_argument("--disconnect-after", type=int, default=1,
                    help="--server: disconnecting clients hang up after "
                         "this many streamed tokens")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request latency/throughput JSON here")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--mesh", default=None,
                    help="DATA,MODEL device-mesh shape (e.g. 2,4); overrides "
                         "--data/--model.  Needs data*model visible devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                         "on CPU)")
    args = ap.parse_args()
    if args.mesh:
        args.data, args.model = shd.parse_mesh_arg(args.mesh)

    cfg = apply_bgpp_overrides(
        get_config(args.arch, smoke=True),
        rounds=args.bgpp_rounds, keep_ratio=args.bgpp_keep_ratio,
    )
    cfg = apply_decode_kernel_override(cfg, args.decode_kernel)
    cfg = apply_weight_format_override(cfg, args.weight_format)
    cfg = apply_spec_decode_overrides(cfg, enabled=args.spec_decode or None,
                                      gamma=args.draft_gamma,
                                      planes=args.draft_planes)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit("continuous batching driver covers transformer "
                         "families; ssm/hybrid/enc-dec decode in tests/")
    mesh = make_debug_mesh(args.data, args.model)
    rules = sh.rules_for_mesh(mesh)
    rng = np.random.default_rng(args.seed)
    params, _ = model_zoo.init(jax.random.key(0), cfg)

    layout = kvc.layout_for(cfg, args.slots, args.max_seq,
                            kv_format=args.kv_format,
                            layout=args.kv_layout, page_size=args.page_size)
    sched = Scheduler(params, cfg, layout, rules,
                      admission=args.admission,
                      chunk_budget=args.chunk_budget,
                      prefill_kw=dict(block_q=16, block_k=32))
    max_prompt = min(23, args.max_seq - 2 - args.shared_prefix)
    assert max_prompt >= 1, "--shared-prefix leaves no room for prompts"
    reqs = poisson_trace(rng, args.requests, cfg.vocab_size,
                         args.max_new, args.arrival_rate,
                         max_prompt=max_prompt,
                         shared_prefix=args.shared_prefix)

    t0 = time.perf_counter()
    if args.server:
        from repro.serving.server import simulate_clients
        with mesh:
            stats = simulate_clients(
                sched, reqs, disconnect_every=args.disconnect_every,
                disconnect_after=args.disconnect_after,
            )
        dt = time.perf_counter() - t0
        stats["wall_s"] = round(dt, 3)
        stats["tokens_per_s"] = round(stats["decoded_tokens"] / dt, 2) \
            if dt > 0 else None
    else:
        for req in reqs:
            sched.submit(req)
        done = 0
        with mesh:
            while sched.num_pending:
                sched.step()
                if len(sched.finished) != done:
                    done = len(sched.finished)
                    print(f"[serve] {done}/{args.requests} requests "
                          f"({sched.decoded_tokens} tokens, "
                          f"step {sched.step_count})")
        dt = time.perf_counter() - t0
        stats = sched.stats(dt)
    print(f"[serve] arch={cfg.name} kv={args.kv_format} "
          f"admission={args.admission}: "
          f"{stats['finished_requests']} requests, "
          f"{stats['decoded_tokens']} tokens in {dt:.1f}s "
          f"({stats['tokens_per_s']:.1f} tok/s CPU smoke, "
          f"mean occupancy {stats['mean_occupancy']:.2f})")
    print(f"[serve] ttft_s p50={stats['ttft_s']['p50']} "
          f"p95={stats['ttft_s']['p95']}  "
          f"itl_s p50={stats['itl_s']['p50']} p95={stats['itl_s']['p95']}  "
          f"max prefill tokens/step={stats['max_prefill_tokens_per_step']}")
    if args.server:
        pages = (f" pages_in_use={stats['paged']['pages_in_use']}"
                 if "paged" in stats else "")
        print(f"[serve] server: cancelled={stats['cancelled_requests']} "
              f"shed={stats['shed_requests']} "
              f"preemptions={stats['preemptions']} "
              f"disconnects="
              f"{sum(c['disconnected'] for c in stats['clients'])}{pages}")
        for tier, t in stats["tiers"].items():
            print(f"[serve] tier {tier}: finished={t['finished']} "
                  f"cancelled={t['cancelled']} shed={t['shed']} "
                  f"preemptions={t['preemptions']} "
                  f"ttft_s p50={t['ttft_s']['p50']} "
                  f"itl_s p50={t['itl_s']['p50']} p95={t['itl_s']['p95']}")
    kv = stats["kv_read"]
    print(f"[serve] kv read: {kv['decode_bytes']/1e6:.2f} MB decode + "
          f"{kv['prefill_bytes']/1e6:.2f} MB prefill; "
          f"{kv['decode_bytes_per_step']/1e3:.1f} kB/decode-step "
          f"(bf16-equivalent {kv['decode_bf16_equiv_bytes_per_step']/1e3:.1f}"
          f" kB, {kv['decode_bytes_reduction_vs_bf16']}x reduction)")
    print(f"[serve] mesh {kv['mesh']['data']}x{kv['mesh']['model']} "
          f"({kv['kv_shards']} kv shards): "
          f"{kv['decode_bytes_per_device_per_step']/1e3:.1f} kB/device/step, "
          f"interconnect {kv['interconnect_bytes_per_step']/1e3:.2f} kB/step "
          f"({kv['interconnect_bytes']/1e6:.2f} MB total: attend all-gather "
          f"{kv['interconnect']['attend_allgather']/1e3:.2f} kB/step + paged "
          f"write bcast {kv['interconnect']['paged_write_bcast']/1e3:.2f})")
    wr = stats["weight_read"]
    print(f"[serve] weight read ({wr['weight_format']}): "
          f"{wr['decode_bytes']/1e6:.2f} MB decode + "
          f"{wr['prefill_bytes']/1e6:.2f} MB prefill; "
          f"{wr['decode_bytes_per_step']/1e3:.1f} kB/decode-step "
          f"(bf16-equivalent "
          f"{wr['decode_bf16_equiv_bytes_per_step']/1e3:.1f} kB, "
          f"{wr['decode_bytes_reduction_vs_bf16']}x reduction, "
          f"measured/modeled {wr['measured_over_modeled']})")
    if "spec" in stats:
        sp = stats["spec"]
        print(f"[serve] spec decode (gamma={sp['gamma']}, "
              f"planes={sp['draft_planes']}, source={sp['draft_source']}): "
              f"accepted/step={sp['accepted_tokens_per_step']:.3f} "
              f"({sp['accepted_tokens']} tokens, {sp['rounds']} rounds, "
              f"{sp['accepted_tokens_per_round']:.2f}/round, draft hit rate "
              f"{sp['draft_hit_rate']:.2f})")
        print(f"[serve] spec bytes/accepted-token: "
              f"kv {sp['kv_bytes_per_accepted_token']/1e3:.1f} kB, "
              f"weight {sp['weight_bytes_per_accepted_token']/1e3:.1f} kB "
              f"(modeled bit-plane draft "
              f"{sp['modeled_weight_bytes_per_accepted_token']/1e3:.1f} kB)")
    if "bgpp" in kv:
        bg = kv["bgpp"]
        print(f"[serve] bgpp two-phase: {bg['rounds']} rounds, "
              f"{bg['full_rows_per_slot']} full-precision rows per "
              f"(slot, layer) per step; per-step bytes = "
              f"sign {bg['sign_bytes']/1e3:.1f} kB + planes "
              f"{bg['plane_bytes']/1e3:.1f} kB + top-k full "
              f"{bg['topk_full_bytes']/1e3:.1f} kB")
    if "paged" in stats:
        pg = stats["paged"]
        print(f"[serve] paged: prefix hit rate {pg['prefix_hit_rate']:.3f} "
              f"({pg['prefix_hit_tokens']} tokens over {pg['prefix_hits']} "
              f"hits), resident KV peak {pg['resident_kv_bytes_peak']/1e3:.1f}"
              f" kB vs {pg['slot_resident_kv_bytes']/1e3:.1f} kB slot-dense, "
              f"pages_in_use={pg['pages_in_use']}")
    if args.trace_out:
        stats["config"] = {
            "arch": cfg.name, "kv_format": args.kv_format,
            "kv_layout": args.kv_layout, "page_size": args.page_size,
            "shared_prefix": args.shared_prefix,
            "slots": args.slots, "max_seq": args.max_seq,
            "requests": args.requests, "max_new": args.max_new,
            "admission": args.admission, "chunk_budget": args.chunk_budget,
            "arrival_rate": args.arrival_rate, "seed": args.seed,
            "mesh": [args.data, args.model],
            "bgpp_rounds": cfg.mcbp.bgpp_rounds,
            "bgpp_keep_ratio": cfg.mcbp.bgpp_keep_ratio,
            "decode_kernel": cfg.mcbp.decode_kernel,
            "weight_format": sched.weight_format,
            "spec_decode": sched.spec.enabled,
            "draft_gamma": sched.spec.gamma,
            "draft_planes": sched.spec.planes,
            "server": args.server,
            "disconnect_every": args.disconnect_every,
            "disconnect_after": args.disconnect_after,
        }
        with open(args.trace_out, "w") as f:
            json.dump(stats, f, indent=2)
        print(f"[serve] trace -> {args.trace_out}")


if __name__ == "__main__":
    main()
