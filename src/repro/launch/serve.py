"""Production serving launcher: continuous-batching decode over the MCBP
engine (prefill + serve_step with int8 / bgpp KV caches).

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b \\
        --kv-format int8 --requests 8 --max-new 32 [--data 1 --model 1]

Requests arrive with distinct prompt lengths and are decoded together; a
finished slot (here: a fixed budget per request) is immediately refilled —
the scheduling skeleton of a production server on the same serve_step the
decode_32k / long_500k dry-run cells lower.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_REGISTRY, get_config
from repro.distributed import sharding as sh
from repro.launch.mesh import make_debug_mesh
from repro.models import model_zoo
from repro.serving import engine, kv_cache as kvc


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCH_REGISTRY),
                    default="phi4-mini-3.8b")
    ap.add_argument("--kv-format", default="int8",
                    choices=["bf16", "int8", "bgpp"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit("continuous batching driver covers transformer "
                         "families; ssm/hybrid/enc-dec decode in tests/")
    mesh = make_debug_mesh(args.data, args.model)
    rules = sh.rules_for_mesh(mesh)
    rng = np.random.default_rng(0)
    params, _ = model_zoo.init(jax.random.key(0), cfg)

    # request queue: random prompts of varying length
    queue = [
        jnp.asarray(rng.integers(0, cfg.vocab_size, (int(n),)), jnp.int32)
        for n in rng.integers(8, 24, size=args.requests)
    ]
    layout = kvc.layout_for(cfg, args.slots, args.max_seq,
                            kv_format=args.kv_format)
    serve_step = jax.jit(engine.make_serve_step(cfg, layout, rules))

    done = 0
    t0 = time.perf_counter()
    decoded_tokens = 0
    while queue:
        # fill a batch of slots (continuous batching: pad to common length,
        # prefill together; production would use per-slot paged prefill)
        batch = [queue.pop(0) for _ in range(min(args.slots, len(queue)))]
        width = max(len(p) for p in batch)
        prompts = jnp.stack([
            jnp.pad(p, (width - len(p), 0), constant_values=0) for p in batch
        ])
        if len(batch) < args.slots:
            prompts = jnp.pad(prompts, ((0, args.slots - len(batch)), (0, 0)))
        with mesh:
            logits, cache = engine.prefill(
                params, cfg, layout, prompts, rules, block_q=16, block_k=32
            )
            cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            for _ in range(args.max_new):
                logits, cache = serve_step(params, cache, cur)
                cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
                decoded_tokens += len(batch)
        done += len(batch)
        print(f"[serve] {done}/{args.requests} requests "
              f"({decoded_tokens} tokens)")
    dt = time.perf_counter() - t0
    print(f"[serve] arch={cfg.name} kv={args.kv_format}: {done} requests, "
          f"{decoded_tokens} tokens in {dt:.1f}s "
          f"({decoded_tokens/dt:.1f} tok/s CPU smoke)")


if __name__ == "__main__":
    main()
