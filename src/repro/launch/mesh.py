"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets ``--xla_force_host_platform_device_count=512``
before any jax import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    n = data * model
    assert n <= jax.device_count(), (n, jax.device_count())
    return jax.make_mesh((data, model), ("data", "model"))
