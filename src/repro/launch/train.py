"""Production training launcher: mesh + sharded state + data pipeline +
checkpoint/restore + heartbeat + straggler monitoring + resilient loop.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --smoke \\
        --steps 100 --batch 8 --seq-len 128 [--data 1 --model 1] \\
        [--ckpt-dir /tmp/mcbp_train] [--int8-opt] [--fsdp]

On the CPU container this runs the smoke configs on a debug mesh; on a real
cluster the same entry point takes the production mesh (launch/mesh.py) —
every component (rules, train_step, checkpointer, pipeline) is identical to
what the dry-run lowers for 16×16 / 2×16×16.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import ARCH_REGISTRY, get_config
from repro.data import Prefetcher, SyntheticLMDataset
from repro.distributed import sharding as sh
from repro.launch.mesh import make_debug_mesh
from repro.models import model_zoo
from repro.optim import AdamWConfig, adamw_init, opt_state_specs
from repro.runtime import Heartbeat, StragglerMonitor, run_resilient
from repro.training import make_train_step


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCH_REGISTRY), default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--int8-opt", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/mcbp_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--heartbeat", default="/tmp/mcbp_train_heartbeat.json")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_debug_mesh(args.data, args.model)
    rules = sh.rules_for_mesh(
        mesh, fsdp_axes=(sh.D_MODEL,) if args.fsdp else (), sp=args.model > 1
    )
    opt_cfg = AdamWConfig(
        peak_lr=3e-4, warmup_steps=min(50, args.steps // 4),
        decay_steps=args.steps,
        state_dtype="int8" if args.int8_opt else "fp32",
    )

    params, p_specs = model_zoo.init(jax.random.key(0), cfg)
    state = {"params": params, "opt": adamw_init(params, opt_cfg)}
    state_specs = {"params": p_specs, "opt": opt_state_specs(p_specs, opt_cfg)}
    state = jax.device_put(state, rules.tree_shardings(mesh, state_specs, state))

    fwd_kw = dict(block_q=64, block_k=128, remat=True)
    if cfg.family == "ssm":
        fwd_kw = dict(chunk=64, remat=True)
    elif cfg.family == "hybrid":
        fwd_kw["ssd_chunk"] = 64
    step_fn = jax.jit(
        make_train_step(cfg, rules, opt_cfg, fwd_kw,
                        grad_accum=args.grad_accum, param_specs=p_specs),
        donate_argnums=(0,),
    )

    modality = {}
    if cfg.family == "vlm":
        modality["vision"] = (cfg.vision_tokens, cfg.d_vision)
    if cfg.family == "enc_dec":
        modality["frames"] = (cfg.encoder_seq, cfg.d_audio)
    ds = SyntheticLMDataset(
        cfg.vocab_size, args.seq_len, args.batch, seed=0, modality=modality
    )
    ckpt = Checkpointer(args.ckpt_dir, keep=3)
    hb = Heartbeat(args.heartbeat, interval_s=10.0,
                   payload={"arch": cfg.name}).start()
    monitor = StragglerMonitor(threshold=8.0)

    start = ckpt.latest_step() or 0
    if start:
        start, state = ckpt.restore(state)
        print(f"[train] restored step {start} from {args.ckpt_dir}")
    holder = {"state": state}
    pf = Prefetcher(ds, depth=2, start_step=start)

    def train_one(step):
        got_step, batch = pf.next()
        assert got_step == step, (got_step, step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.perf_counter()
        with mesh:
            holder["state"], metrics = step_fn(holder["state"], batch)
        dt = time.perf_counter() - t0
        monitor.record(step, dt)
        if step % 10 == 0:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):8.4f} "
                  f"lr {float(metrics['lr']):.2e} ({dt*1e3:.0f} ms)")
        if step and step % args.ckpt_every == 0:
            ckpt.save(step + 1, holder["state"])
        hb.beat(step=step)

    def restore():
        nonlocal pf
        step, holder["state"] = ckpt.restore(holder["state"])
        pf.close()
        pf = Prefetcher(ds, depth=2, start_step=step)
        return step

    try:
        failures = run_resilient(train_one, start, args.steps - start, restore)
        print(f"[train] done ({failures} failures survived); "
              f"median step {monitor.median*1e3:.0f} ms")
        ckpt.save(args.steps, holder["state"])
        ckpt.wait()
    finally:
        pf.close()
        hb.stop()


if __name__ == "__main__":
    main()
