import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and extract the roofline raw data.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell this builds the *real* step function (train_step with optimizer
state / prefill_step / serve_step with KV cache), ShapeDtypeStruct inputs
(zero allocation — jamba's 398B params never materialize), NamedShardings
from the logical-axis specs, then ``jit(...).lower(...).compile()`` for the
16×16 pod (and 2×16×16 multi-pod, which proves the "pod" axis shards).
``memory_analysis()`` / ``cost_analysis()`` / the partitioned HLO feed
EXPERIMENTS.md §Dry-run and §Roofline via ``repro.analysis.roofline``.

Results land in ``experiments/dryrun/<mesh>/<arch>__<shape>.json``.
"""

import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.analysis import roofline as rl
from repro.configs import ARCH_REGISTRY, get_config, shapes as shp
from repro.data.pipeline import make_batch_specs
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo
from repro.optim import AdamWConfig, adamw_init, opt_state_specs
from repro.serving import engine, kv_cache as kvc
from repro.training.train_step import make_prefill_step, make_train_step

Tree = Any

# archs whose weights exceed 16 GB/chip under 16-way TP alone: FSDP the
# d_model dim over "data" too (ZeRO-3-style per-layer all-gather)
FSDP_ARCHS = {"jamba-1.5-large-398b", "mixtral-8x22b", "llama4-scout-17b-a16e"}

# optimizer: int8 moments for the monster archs (DESIGN.md §4)
INT8_OPT_ARCHS = FSDP_ARCHS


def rules_for(arch: str, shape: shp.ShapeConfig, mesh) -> sh.ShardingRules:
    fsdp = (sh.D_MODEL,) if arch in FSDP_ARCHS else ()
    seq_shard = shape.name == "long_500k"
    # sequence parallelism: train/prefill shard activation seq over
    # "model"; decode shards the KV-cache seq over "model" whenever the
    # kv-head count can't use it (flash-decode / distattention)
    sp = True
    return sh.rules_for_mesh(mesh, fsdp_axes=fsdp, seq_shard=seq_shard, sp=sp)


# microbatch counts for the giant archs' train cells (activation peak / N)
GRAD_ACCUM = {"jamba-1.5-large-398b": 8, "mixtral-8x22b": 8,
              "llama4-scout-17b-a16e": 4}


def fwd_kwargs_for(cfg, shape: shp.ShapeConfig) -> Dict:
    if cfg.family == "ssm":
        return dict(chunk=256, remat=shape.kind == "train")
    kw = dict(block_q=512, block_k=1024, remat=shape.kind == "train")
    if cfg.family == "hybrid":
        kw["ssd_chunk"] = 256
    return kw


def _struct(tree: Tree) -> Tree:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def build_cell(arch: str, shape: shp.ShapeConfig, mesh, rules, kv_format: str = "int8"):
    """Returns (fn, arg_structs, in_shardings) for the cell."""
    cfg = get_config(arch)
    opt_cfg = AdamWConfig(
        state_dtype="int8" if arch in INT8_OPT_ARCHS else "fp32"
    )
    param_structs = jax.eval_shape(
        functools.partial(model_zoo.init_params, cfg=cfg), jax.random.key(0)
    )
    p_specs = model_zoo.param_specs(cfg)
    p_shard = rules.tree_shardings(mesh, p_specs, param_structs)

    batch_structs = make_batch_specs(cfg, shape)
    b_shard = {
        k: jax.sharding.NamedSharding(
            mesh,
            rules.spec_for_shape(
                mesh, (sh.BATCH,) + (None,) * (len(v.shape) - 1), v.shape
            ),
        )
        for k, v in batch_structs.items()
    }

    if shape.kind == "train":
        opt_structs = jax.eval_shape(
            functools.partial(adamw_init, cfg=opt_cfg), param_structs
        )
        o_specs = opt_state_specs(p_specs, opt_cfg)
        state_structs = {"params": param_structs, "opt": opt_structs}
        state_shard = {
            "params": p_shard,
            "opt": rules.tree_shardings(mesh, o_specs, opt_structs),
        }
        fn = make_train_step(
            cfg, rules, opt_cfg, fwd_kwargs_for(cfg, shape),
            grad_accum=GRAD_ACCUM.get(arch, 1), param_specs=p_specs,
        )
        return fn, (state_structs, batch_structs), (state_shard, b_shard)

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, rules, fwd_kwargs_for(cfg, shape))
        return fn, (param_structs, batch_structs), (p_shard, b_shard)

    # decode: serve_step(params, cache, tokens)
    # baseline cells: the paper's INT8 (Atom-style) KV cache; the bgpp
    # format is the §Perf MCBP variant (--kv-format bgpp)
    layout = kvc.layout_for(
        cfg, shape.global_batch, shape.seq_len, kv_format=kv_format
    )
    cache_structs = jax.eval_shape(
        functools.partial(kvc.init_cache_arrays, cfg, layout)
    )
    c_shard = rules.tree_shardings(mesh, kvc.cache_specs(cfg, layout), cache_structs)
    tok_structs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_shard = jax.sharding.NamedSharding(
        mesh, rules.spec_for_shape(mesh, (sh.BATCH, None), tok_structs.shape)
    )
    fn = engine.make_serve_step(cfg, layout, rules)
    return fn, (param_structs, cache_structs, tok_structs), (p_shard, c_shard, t_shard)


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    out_dir: str = "experiments/dryrun",
    verbose: bool = True,
    kv_format: str = "int8",
) -> Optional[Dict]:
    shape = shp.get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    skip = shp.applicable(arch, shape)
    variant = "" if kv_format == "int8" else f"__{kv_format}"
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kv_format": kv_format,
    }
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        _write(out_dir, mesh_name, arch, shape_name + variant, result)
        if verbose:
            print(f"[dryrun] {arch:26s} {shape_name:12s} {mesh_name}: SKIP ({skip})")
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(arch, shape, mesh)
    cfg = get_config(arch)
    t0 = time.time()
    fn, structs, shardings = build_cell(arch, shape, mesh, rules, kv_format)
    # donate the mutable aggregate (train state / KV cache) so outputs alias
    donate = (0,) if shape.kind == "train" else (1,) if shape.kind == "decode" else ()
    with mesh:
        lowered = jax.jit(
            fn, in_shardings=shardings, donate_argnums=donate
        ).lower(*structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    report = rl.roofline_from_compiled(
        compiled, arch, shape, mesh_name, chips=mesh.size, cfg=cfg
    )
    mem = compiled.memory_analysis()
    result.update(report.to_dict())
    result.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory_analysis=_mem_dict(mem),
    )
    _write(out_dir, mesh_name, arch, shape_name + variant, result)
    if verbose:
        hbm_gb = (result.get("memory_analysis") or {}).get("per_device_gb")
        print(
            f"[dryrun] {arch:26s} {shape_name:12s} {mesh_name}: OK "
            f"flops/dev={report.device_flops:.3e} bytes/dev={report.device_bytes:.3e} "
            f"coll={report.collective_bytes:.3e} bound={report.bottleneck} "
            f"frac={report.roofline_fraction:.3f} hbm={hbm_gb}GB "
            f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)"
        )
    return result


def _mem_dict(mem) -> Optional[Dict]:
    if mem is None:
        return None
    try:
        args = float(mem.argument_size_in_bytes)
        out = float(mem.output_size_in_bytes)
        tmp = float(mem.temp_size_in_bytes)
        alias = float(mem.alias_size_in_bytes)
        total = args + out + tmp - alias
        return {
            "argument_bytes": args,
            "output_bytes": out,
            "temp_bytes": tmp,
            "alias_bytes": alias,
            "per_device_gb": round(total / 1e9, 3),
            "fits_16gb": total < 16e9,
        }
    except Exception:  # pragma: no cover
        return {"repr": str(mem)}


def _write(out_dir, mesh_name, arch, shape_name, result):
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{arch}__{shape_name}.json"), "w") as f:
        json.dump(result, f, indent=1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCH_REGISTRY), default=None)
    ap.add_argument("--shape", choices=[s.name for s in shp.SHAPES], default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--kv-format", default="int8", choices=["bf16", "int8", "bgpp"])
    args = ap.parse_args()

    cells = []
    if args.all:
        cells = [(a, s.name) for a in sorted(ARCH_REGISTRY) for s in shp.SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        try:
            run_cell(arch, shape_name, args.multi_pod, args.out_dir,
                     kv_format=args.kv_format)
        except Exception as e:  # noqa: BLE001
            failures.append((arch, shape_name, repr(e)))
            print(f"[dryrun] {arch:26s} {shape_name:12s}: FAIL {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print(f"[dryrun] all {len(cells)} cells passed on "
          f"{'2x16x16' if args.multi_pod else '16x16'}")


if __name__ == "__main__":
    main()
