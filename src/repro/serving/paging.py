"""Host-side page allocator + hash-based prefix-reuse index for the paged
KV cache (``CacheLayout.layout == "paged"``).

The device side (``kv_cache``/``engine``) only ever sees a ``(B,
pages_per_slot)`` int32 page table; everything dynamic lives here, in plain
python/numpy, mirroring the device-graph-static / scheduling-dynamic split
the scheduler already uses:

  * a free list + per-page refcounts — a physical page may back the same
    logical prefix of several slots at once (prefix reuse maps it
    copy-on-write: refcount++, never an actual copy, because shared pages
    are always *full* prompt pages that no slot writes again);
  * per-page generation counters — bumped when a page's refcount hits zero,
    so stale prefix-index entries can never resurrect freed contents;
  * the prefix index: sha1(prompt token ids of each fully-written,
    page-aligned prompt prefix) -> the physical pages backing it.  A new
    request whose prompt matches a resident entry adopts those pages
    instead of re-prefilling them.  Lookup caps reuse at ``prompt_len - 1``
    tokens (the last prompt token must run through the chunk path to
    produce the first-token logits) and is only offered for global-only
    layouts: sliding-window ring stacks discard prefix positions as they
    decode, so a reused slot could never rebuild its window without
    recomputing the very tokens reuse skips.

The scheduler drives the lifecycle: ``ensure_range`` before every chunk /
decode write, ``register_prefix`` after chunks land, ``lookup_prefix`` +
``adopt_prefix`` at admission, ``release_slot`` at eviction (the returned
freed ids are scrubbed on device via :func:`repro.serving.kv_cache
.zero_pages` — eviction only *frees* a page when its refcount hits zero).

Pages can additionally be **pinned** (``pin_pages`` / ``unpin_pages``): a
pin is a refcount held by no slot — the async server's chat sessions use
it to keep a finished turn's prompt+history pages (and their prefix-index
entries) resident between turns, so the next turn's prompt adopts them
instead of re-prefilling.  ``check()`` accounts pins explicitly: table
reachability + pins must equal the refcount exactly.
"""

from __future__ import annotations

import hashlib
from typing import Dict, List, Tuple

import numpy as np

from repro.serving import kv_cache as kvc


class PageAllocator:
    """Free-list page allocator with refcounts and a weak prefix index."""

    def __init__(self, layout: kvc.CacheLayout):
        assert layout.layout == "paged" and layout.page_size >= 1
        self.layout = layout
        self.page_size = layout.page_size
        self.pages_per_slot = layout.pages_per_slot
        self.num_pages = layout.num_pages
        self.table = np.full(
            (layout.batch, self.pages_per_slot), -1, np.int32
        )
        self.refcount = np.zeros(self.num_pages, np.int32)
        self.generation = np.zeros(self.num_pages, np.int64)
        # refcounts held by pins (session keep-alives) rather than by a
        # slot's table row; check() reconciles them separately
        self.pins = np.zeros(self.num_pages, np.int32)
        # pop() hands out low ids first (cosmetic, but makes traces stable)
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        # digest -> (prefix tokens, page ids, generations at registration)
        self._prefix: Dict[bytes, Tuple[int, Tuple[int, ...], Tuple[int, ...]]] = {}
        # page id -> digests referencing it, so freeing a page prunes its
        # index entries immediately (the index stays bounded by live pages
        # instead of growing with every prompt ever admitted)
        self._page_digests: Dict[int, set] = {}
        # per-slot high-water mark of registered prefix tokens: the
        # scheduler calls register_prefix after every chunk advance, so
        # without it each call would re-hash every boundary from page 1
        # (quadratic in prompt pages)
        self._registered = np.zeros(layout.batch, np.int64)
        self.dirty = True  # device table needs a sync
        self.alloc_count = 0
        self.peak_pages = 0

    # ------------------------------------------------------------------
    # physical pages
    # ------------------------------------------------------------------

    @property
    def pages_in_use(self) -> int:
        """Physical pages currently mapped by at least one slot."""
        return self.num_pages - len(self._free)

    def _alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"page pool exhausted ({self.num_pages} pages of "
                f"{self.page_size} tokens); raise num_pages in layout_for"
            )
        p = self._free.pop()
        assert self.refcount[p] == 0, f"free list held live page {p}"
        self.refcount[p] = 1
        self.alloc_count += 1
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return p

    def ensure_range(self, slot: int, lo: int, hi: int) -> List[int]:
        """Map fresh pages so logical positions ``[lo, hi)`` (``[lo, lo]``
        when hi <= lo) of ``slot`` are writable; already-mapped pages
        (including adopted shared ones) are left alone.  Returns the newly
        allocated page ids."""
        hi = max(hi, lo + 1)
        new = []
        for pi in range(lo // self.page_size, (hi - 1) // self.page_size + 1):
            if self.table[slot, pi] < 0:
                self.table[slot, pi] = new_page = self._alloc()
                new.append(new_page)
        if new:
            self.dirty = True
        return new

    def release_slot(self, slot: int) -> List[int]:
        """Evict ``slot``: decref every mapped page, unmap the row.  Only
        pages whose refcount hits zero are freed (and returned for device
        zeroing) — prefix sharers keep theirs alive."""
        freed = []
        for pi in range(self.pages_per_slot):
            p = int(self.table[slot, pi])
            if p < 0:
                continue
            assert self.refcount[p] > 0, f"double free of page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.generation[p] += 1
                self._free.append(p)
                freed.append(p)
                for d in self._page_digests.pop(p, ()):
                    self._prefix.pop(d, None)
            self.table[slot, pi] = -1
            self.dirty = True
        self._registered[slot] = 0
        return freed

    def rewind_slot(self, slot: int, keep_tokens: int) -> List[int]:
        """Roll ``slot`` back so only logical positions ``[0, keep_tokens)``
        stay valid — the speculative-decode rollback path.  Pages entirely
        past the kept frontier are decref'd/unmapped (freed at refcount 0:
        generation bumped, prefix entries pruned, returned for device
        zeroing); the page the frontier straddles stays mapped but has ALL
        its prefix-index digests deregistered — its tail rows held
        speculative garbage, so a later prompt matching the stale hash must
        never adopt it (the cross-page-boundary rollback bugfix,
        tests/test_paging.py).  The slot's registration high-water mark is
        clamped so later ``register_prefix`` calls re-hash from the kept
        frontier."""
        freed = []
        for pi in range(self.pages_per_slot):
            p = int(self.table[slot, pi])
            if p < 0:
                continue
            if pi * self.page_size >= keep_tokens:
                # page fully past the accepted frontier: give it back
                assert self.refcount[p] > 0, f"double free of page {p}"
                self.refcount[p] -= 1
                if self.refcount[p] == 0:
                    self.generation[p] += 1
                    self._free.append(p)
                    freed.append(p)
                    for d in self._page_digests.pop(p, ()):
                        self._prefix.pop(d, None)
                self.table[slot, pi] = -1
                self.dirty = True
            elif (pi + 1) * self.page_size > keep_tokens:
                # frontier page: kept mapped (its head rows are valid), but
                # rewound tail rows invalidate every prefix that covered it.
                # Speculative slots never share their frontier page (shared
                # pages are full prompt pages nobody writes again).
                assert self.refcount[p] == int(self.pins[p]) + 1, (
                    f"rewinding shared page {p} would corrupt its sharers"
                )
                for d in self._page_digests.pop(p, ()):
                    self._prefix.pop(d, None)
        self._registered[slot] = min(
            int(self._registered[slot]),
            (keep_tokens // self.page_size) * self.page_size,
        )
        return freed

    # ------------------------------------------------------------------
    # prefix reuse
    # ------------------------------------------------------------------

    @staticmethod
    def _digest(tokens, n: int) -> bytes:
        return hashlib.sha1(
            np.ascontiguousarray(tokens[:n], np.int32).tobytes()
        ).digest()

    def register_prefix(self, slot: int, tokens, upto: int) -> None:
        """Index every page-aligned prompt prefix of ``slot`` that is fully
        written (``boundary <= upto``) and made purely of prompt tokens.
        Incremental: boundaries at or below the slot's last registration
        (including adopted pages — the donor already indexed those) are
        skipped, so repeated calls while a prompt chunks stay linear."""
        limit = min(int(upto), len(tokens))
        start = int(self._registered[slot]) // self.page_size + 1
        for k in range(start, limit // self.page_size + 1):
            ids = tuple(int(p) for p in self.table[slot, :k])
            if any(p < 0 for p in ids):  # unmapped => nothing to share
                break
            d = self._digest(tokens, k * self.page_size)
            self._prefix[d] = (
                k * self.page_size, ids,
                tuple(int(self.generation[p]) for p in ids),
            )
            for p in ids:
                self._page_digests.setdefault(p, set()).add(d)
            self._registered[slot] = k * self.page_size

    def lookup_prefix(self, tokens) -> Tuple[int, Tuple[int, ...]]:
        """Longest indexed, still-resident prefix of ``tokens`` covering at
        most ``len(tokens) - 1`` of them.  Stale entries (a backing page
        was freed — generation moved on) are pruned on sight.  Returns
        ``(n_tokens, page_ids)`` (``(0, ())`` on miss).

        The three legality rules of prefix reuse (each enforced here or by
        the scheduler, property-tested in tests/test_paging.py):

        * **full pages only** — only page-aligned, fully-written prompt
          prefixes are ever indexed (``register_prefix``), so a shared
          page is never written again by any adopter;
        * **resident donor** — every backing page must still be refcounted
          at its registration generation; freed pages can never resurrect;
        * **global-only stacks** — the scheduler offers reuse only when
          the layout has no sliding-window ring layers, which discard the
          very positions a reused slot would need.
        """
        for k in range((len(tokens) - 1) // self.page_size, 0, -1):
            d = self._digest(tokens, k * self.page_size)
            hit = self._prefix.get(d)
            if hit is None:
                continue
            _, ids, gens = hit
            if all(self.refcount[p] > 0 and self.generation[p] == g
                   for p, g in zip(ids, gens)):
                return k * self.page_size, ids
            del self._prefix[d]
        return 0, ()

    def adopt_prefix(self, slot: int, ids: Tuple[int, ...]) -> None:
        """Map shared prefix pages into ``slot`` (refcount++ each); the
        slot must be freshly evicted (its row unmapped)."""
        for pi, p in enumerate(ids):
            assert self.table[slot, pi] < 0, f"slot {slot} page {pi} mapped"
            self.refcount[p] += 1
            self.table[slot, pi] = p
        if ids:
            # the donor already indexed these boundaries
            self._registered[slot] = len(ids) * self.page_size
            self.dirty = True

    # ------------------------------------------------------------------
    # pins (session keep-alives)
    # ------------------------------------------------------------------

    def pin_pages(self, ids) -> None:
        """Hold ``ids`` resident without a slot mapping (refcount++ each).
        Every page must currently be live — a pin extends residency, it
        cannot resurrect a freed page."""
        for p in ids:
            p = int(p)
            assert self.refcount[p] > 0, f"cannot pin freed page {p}"
            self.refcount[p] += 1
            self.pins[p] += 1

    def unpin_pages(self, ids) -> List[int]:
        """Drop pins on ``ids``; pages whose refcount hits zero are freed
        (generation bumped, prefix entries pruned) and returned for device
        zeroing — exactly ``release_slot``'s free path, minus the table."""
        freed = []
        for p in ids:
            p = int(p)
            assert self.pins[p] > 0, f"page {p} is not pinned"
            self.pins[p] -= 1
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.generation[p] += 1
                self._free.append(p)
                freed.append(p)
                for d in self._page_digests.pop(p, ()):
                    self._prefix.pop(d, None)
        return freed

    # ------------------------------------------------------------------
    # accounting / invariants
    # ------------------------------------------------------------------

    def check(self) -> None:
        """Assert the bookkeeping invariants the property tests lean on:
        refcounts == table reachability + pins, free list disjoint from
        the table and duplicate-free, every page accounted for."""
        counts = np.zeros(self.num_pages, np.int64)
        for p in self.table.ravel():
            if p >= 0:
                counts[p] += 1
        assert np.array_equal(counts + self.pins, self.refcount), (
            f"refcount drift: table+pins say "
            f"{(counts + self.pins).nonzero()[0]}, "
            f"refcount says {self.refcount.nonzero()[0]}"
        )
        assert np.all(self.pins >= 0), "negative pin count"
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        mapped = {int(p) for p in self.table.ravel() if p >= 0}
        mapped |= {int(p) for p in np.nonzero(self.pins)[0]}
        assert not (free & mapped), f"pages both free and mapped: {free & mapped}"
        assert len(free) + len(mapped) == self.num_pages, (
            "pages leaked: every page must be exactly one of free/mapped"
        )
        # the prefix index is pruned when a backing page is freed, so every
        # entry references live pages at their registration generation —
        # the index is bounded by live pages, not by prompts ever admitted
        for ntok, ids, gens in self._prefix.values():
            for p, g in zip(ids, gens):
                assert self.refcount[p] > 0 and self.generation[p] == g, (
                    f"prefix index holds freed page {p} ({ntok}-token entry)"
                )
