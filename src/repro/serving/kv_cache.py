"""KV-cache containers for the serving engine.

Three storage formats (MCBPOptions.weight_format governs weights; the cache
format here is chosen by ``kv_format``):

  bf16 — dense baseline.
  int8 — per-token/head symmetric INT8 K and V (+f32 scales) — the paper's
         Atom-style 8-bit KV baseline; halves the decode memory term.
  bgpp — K magnitudes stored as packed bit-planes (+ sign plane, + scale)
         so the BGPP predictor fetches one plane per round; V stays int8.

Mixed local/global attention stacks (gemma3, mixtral SWA, llama4 chunked)
keep two stacks: local layers get a ring buffer of ``window`` slots, global
layers the full sequence — this is what makes gemma3/llama4 ``long_500k``
memory-feasible.  Logical-axis specs accompany every array so the dry-run
can shard caches ((pod,)data over batch, or sequence for long_500k).

Two physical layouts for the *global* stacks (``CacheLayout.layout``):

  slot  — one dense ``(B, S_max)`` row per batch slot (the default; every
          oracle baseline).
  paged — vLLM-style pools: each layer stores ``num_pages * page_size``
          token rows with no batch dim, and a per-slot page table
          ``(B, S_max // page_size)`` of physical page ids maps logical
          positions to pool rows.  Writes translate logical → physical with
          the same OOB-scatter-drop convention (unmapped page or padded
          lane => dropped); reads gather the slot's logical row back into
          the exact heads-major view the slot layout serves, so attention
          consumes bit-identical values (verified by the serving fuzz
          oracle).  Page lifecycle (free lists, refcounts, prefix reuse)
          is host-side: :mod:`repro.serving.paging`.  Local ring stacks,
          mamba state, and cross memory stay slot-major — rings are
          already fixed-width per-slot pages by construction.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitslice
from repro.distributed import sharding as sh

Tree = Dict[str, Any]

NBITS = bitslice.WEIGHT_MAG_BITS  # 7 magnitude planes + sign

# scatter target for padded chunk lanes: far out of every seq axis, so JAX's
# drop-out-of-bounds scatter semantics discard the write (never clamps)
OOB_INDEX = 1 << 30


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """Static description of one model's decode cache."""

    arch: str
    family: str
    batch: int
    max_seq: int
    kv_format: str  # bf16 | int8 | bgpp
    global_layers: Tuple[int, ...] = ()
    local_layers: Tuple[int, ...] = ()
    local_window: int = 0
    mamba_layers: Tuple[int, ...] = ()
    has_cross: bool = False  # whisper encoder memory
    layout: str = "slot"  # slot | paged (global stacks only)
    page_size: int = 0  # tokens per page (paged layout)
    num_pages: int = 0  # physical pages in each layer's pool (paged layout)

    @property
    def pages_per_slot(self) -> int:
        """Logical pages needed to map one slot's full ``max_seq`` row."""
        return -(-self.max_seq // self.page_size) if self.page_size else 0


def layout_for(cfg, batch: int, max_seq: int, kv_format: str = "int8",
               layout: str = "slot", page_size: int = 8,
               num_pages: Optional[int] = None) -> CacheLayout:
    """Derive a :class:`CacheLayout` from a model config: classify every
    layer as global (full-sequence stack), local (ring buffer of the
    sliding/chunked window), or mamba state, and — for
    ``layout="paged"`` — size the shared page pool (default capacity
    equals the dense allocation, so admission can never exhaust it;
    pass a smaller ``num_pages`` to oversubscribe via prefix sharing)."""
    glob, loc, mamba = [], [], []
    window = 0
    for i in range(cfg.num_layers):
        if not cfg.layer_is_attention(i):
            mamba.append(i)
            continue
        kind, w = cfg.layer_attn_window(i)
        if kind in ("sliding",) and w > 0:
            loc.append(i)
            window = w
        elif kind == "chunked" and w > 0:
            # chunked attention never needs more than the chunk in cache
            loc.append(i)
            window = w
        else:
            glob.append(i)
    assert layout in ("slot", "paged"), layout
    if layout == "paged":
        assert page_size >= 1, "paged layout needs page_size >= 1"
        pages_per_slot = -(-max_seq // page_size)
        # default capacity == the dense allocation, so admission can never
        # exhaust the pool; smaller pools oversubscribe (prefix sharing)
        num_pages = batch * pages_per_slot if num_pages is None else num_pages
    else:
        page_size, num_pages = 0, 0
    return CacheLayout(
        arch=cfg.name,
        family=cfg.family,
        batch=batch,
        max_seq=max_seq,
        kv_format=kv_format,
        global_layers=tuple(glob),
        local_layers=tuple(loc),
        local_window=min(window, max_seq) if window else 0,
        mamba_layers=tuple(mamba),
        has_cross=cfg.family == "enc_dec",
        layout=layout,
        page_size=page_size,
        num_pages=num_pages,
    )


# --------------------------------------------------------------------------
# allocation
# --------------------------------------------------------------------------


def _kv_stack(n_layers, B, S, Hk, Dh, kv_format, dtype):
    # heads-major (B, Hk, S, D) layout: decode attention needs no transpose,
    # so the int8->f32 dequant fuses into the QK/PV dots instead of
    # materializing f32 copies of the cache (§Perf iteration A1)
    p: Tree = {}
    if n_layers == 0:
        return p
    if kv_format == "bf16":
        p["k"] = jnp.zeros((n_layers, B, Hk, S, Dh), dtype)
        p["v"] = jnp.zeros((n_layers, B, Hk, S, Dh), dtype)
    elif kv_format == "int8":
        for n in ("k", "v"):
            p[n] = jnp.zeros((n_layers, B, Hk, S, Dh), jnp.int8)
            p[f"{n}_scale"] = jnp.zeros((n_layers, B, Hk, S), jnp.float32)
    elif kv_format == "bgpp":
        assert Dh % 8 == 0
        p["k_planes"] = jnp.zeros((n_layers, NBITS, B, Hk, S, Dh // 8), jnp.uint8)
        p["k_sign"] = jnp.zeros((n_layers, B, Hk, S, Dh // 8), jnp.uint8)
        p["k_scale"] = jnp.zeros((n_layers, B, Hk, S), jnp.float32)
        p["v"] = jnp.zeros((n_layers, B, Hk, S, Dh), jnp.int8)
        p["v_scale"] = jnp.zeros((n_layers, B, Hk, S), jnp.float32)
    else:
        raise ValueError(kv_format)
    return p


def _kv_pool(n_layers, n_tok, Hk, Dh, kv_format, dtype):
    """Paged pool: token-major ``(L, n_tok, Hk, ...)`` per-layer stores with
    NO batch dim — ``n_tok = num_pages * page_size`` physical rows shared by
    every slot through the page table.  Token-major (vs the slot layout's
    heads-major) lets page gathers/scatters address one contiguous row
    axis; reads restore the heads-major view (:func:`paged_entry`)."""
    p: Tree = {}
    if n_layers == 0:
        return p
    if kv_format == "bf16":
        p["k"] = jnp.zeros((n_layers, n_tok, Hk, Dh), dtype)
        p["v"] = jnp.zeros((n_layers, n_tok, Hk, Dh), dtype)
    elif kv_format == "int8":
        for n in ("k", "v"):
            p[n] = jnp.zeros((n_layers, n_tok, Hk, Dh), jnp.int8)
            p[f"{n}_scale"] = jnp.zeros((n_layers, n_tok, Hk), jnp.float32)
    elif kv_format == "bgpp":
        assert Dh % 8 == 0
        p["k_planes"] = jnp.zeros((n_layers, NBITS, n_tok, Hk, Dh // 8), jnp.uint8)
        p["k_sign"] = jnp.zeros((n_layers, n_tok, Hk, Dh // 8), jnp.uint8)
        p["k_scale"] = jnp.zeros((n_layers, n_tok, Hk), jnp.float32)
        p["v"] = jnp.zeros((n_layers, n_tok, Hk, Dh), jnp.int8)
        p["v_scale"] = jnp.zeros((n_layers, n_tok, Hk), jnp.float32)
    else:
        raise ValueError(kv_format)
    return p


def _kv_pool_specs(kv_format):
    # pool token rows are randomly assigned to slots, so neither BATCH nor
    # SEQ sharding applies to the token axis; heads-shard only.  The page
    # table is host-owned (the allocator mutates it every admission) and
    # stays replicated: every device needs every slot's logical→physical
    # map to gather its own head shard of any row.
    if kv_format == "bf16":
        ax = (sh.LAYERS, None, sh.KV_HEADS, None)
        return {"k": ax, "v": ax}
    if kv_format == "int8":
        s = {}
        for n in ("k", "v"):
            s[n] = (sh.LAYERS, None, sh.KV_HEADS, None)
            s[f"{n}_scale"] = (sh.LAYERS, None, sh.KV_HEADS)
        return s
    if kv_format == "bgpp":
        return {
            "k_planes": (sh.LAYERS, None, None, sh.KV_HEADS, None),
            "k_sign": (sh.LAYERS, None, sh.KV_HEADS, None),
            "k_scale": (sh.LAYERS, None, sh.KV_HEADS),
            "v": (sh.LAYERS, None, sh.KV_HEADS, None),
            "v_scale": (sh.LAYERS, None, sh.KV_HEADS),
        }
    raise ValueError(kv_format)


def _kv_stack_specs(kv_format):
    if kv_format == "bf16":
        ax = (sh.LAYERS, sh.BATCH, sh.KV_HEADS, sh.SEQ, None)
        return {"k": ax, "v": ax}
    if kv_format == "int8":
        s = {}
        for n in ("k", "v"):
            s[n] = (sh.LAYERS, sh.BATCH, sh.KV_HEADS, sh.SEQ, None)
            s[f"{n}_scale"] = (sh.LAYERS, sh.BATCH, sh.KV_HEADS, sh.SEQ)
        return s
    if kv_format == "bgpp":
        # NOTE: no SEQ sharding — the progressive top-k uses global indices,
        # and gathers across a sharded seq dim degenerate into per-round
        # all-gathers of the whole plane arrays.  The scalable design is
        # shard-local top-k + a small merge collective (distattention-style),
        # which belongs to the Pallas kernel path (DESIGN.md §2); the jnp
        # dry-run variant shards batch/heads only.
        return {
            "k_planes": (sh.LAYERS, None, sh.BATCH, sh.KV_HEADS, None, None),
            "k_sign": (sh.LAYERS, sh.BATCH, sh.KV_HEADS, None, None),
            "k_scale": (sh.LAYERS, sh.BATCH, sh.KV_HEADS, None),
            "v": (sh.LAYERS, sh.BATCH, sh.KV_HEADS, None, None),
            "v_scale": (sh.LAYERS, sh.BATCH, sh.KV_HEADS, None),
        }
    raise ValueError(kv_format)


def cache_specs(cfg, layout: CacheLayout) -> Tree:
    """Logical-axis specs for the cache — pure (no allocation, dry-run path)."""
    specs: Tree = {"pos": (sh.BATCH,)}
    if layout.global_layers:
        if layout.layout == "paged":
            specs["global"] = _kv_pool_specs(layout.kv_format)
            specs["page_table"] = (None, None)
        else:
            specs["global"] = _kv_stack_specs(layout.kv_format)
    if layout.local_layers:
        fmt = "int8" if layout.kv_format == "bgpp" else layout.kv_format
        s = _kv_stack_specs(fmt)
        s["abs_pos"] = (sh.LAYERS, sh.BATCH, None)
        specs["local"] = s
    if layout.mamba_layers:
        specs["mamba"] = {
            "h": (sh.LAYERS, sh.BATCH, sh.FF, None, None),
            "conv": (sh.LAYERS, sh.BATCH, None, sh.FF),
        }
    if layout.has_cross:
        specs["cross_k"] = (sh.LAYERS, sh.BATCH, sh.KV_HEADS, None, None)
        specs["cross_v"] = (sh.LAYERS, sh.BATCH, sh.KV_HEADS, None, None)
    return specs


def init_cache_arrays(cfg, layout: CacheLayout) -> Tree:
    """Cache pytree (zeros).  Safe under jax.eval_shape for the dry-run."""
    B, S = layout.batch, layout.max_seq
    dtype = _dt(cfg.dtype)
    # per-slot decode positions: slot b of the batch holds its own sequence,
    # so requests of different lengths can coexist (continuous batching)
    cache: Tree = {"pos": jnp.zeros((B,), jnp.int32)}
    if layout.global_layers:
        if layout.layout == "paged":
            cache["global"] = _kv_pool(
                len(layout.global_layers), layout.num_pages * layout.page_size,
                cfg.num_kv_heads, cfg.head_dim, layout.kv_format, dtype,
            )
            # -1 == unmapped: writes through the table drop, reads clamp to
            # row 0 and rely on the caller's position masks
            cache["page_table"] = jnp.full(
                (B, layout.pages_per_slot), -1, jnp.int32
            )
        else:
            cache["global"] = _kv_stack(
                len(layout.global_layers), B, S, cfg.num_kv_heads, cfg.head_dim,
                layout.kv_format, dtype,
            )
    if layout.local_layers:
        # local ring buffers stay dense (int8): windows are small, and BGPP
        # targets the big global/full caches (paper's long-context case)
        fmt = "int8" if layout.kv_format == "bgpp" else layout.kv_format
        p = _kv_stack(
            len(layout.local_layers), B, layout.local_window,
            cfg.num_kv_heads, cfg.head_dim, fmt, dtype,
        )
        # ring buffers hold absolute positions for RoPE-correct reuse
        p["abs_pos"] = jnp.full(
            (len(layout.local_layers), B, layout.local_window), -1, jnp.int32
        )
        cache["local"] = p
    if layout.mamba_layers:
        d_in = cfg.ssm_expand * cfg.d_model
        nheads = d_in // cfg.ssm_head_dim
        cache["mamba"] = {
            "h": jnp.zeros(
                (len(layout.mamba_layers), B, nheads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32,
            ),
            "conv": jnp.zeros(
                (len(layout.mamba_layers), B, cfg.ssm_conv - 1,
                 d_in + 2 * cfg.ssm_state),
                dtype,
            ),
        }
    if layout.has_cross:
        cache["cross_k"] = jnp.zeros(
            (cfg.num_layers, B, cfg.num_kv_heads, cfg.encoder_seq, cfg.head_dim),
            dtype,
        )
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def init_cache(cfg, layout: CacheLayout) -> Tuple[Tree, Tree]:
    """Returns (cache pytree, logical-axis specs)."""
    return init_cache_arrays(cfg, layout), cache_specs(cfg, layout)


def constrain_cache(cache: Tree, specs: Tree, rules) -> Tree:
    """Pin every cache leaf to its logical-axis sharding inside a jitted
    step.  A no-op when ``rules`` carries no mesh, so single-device paths
    compile identical programs.  Applied at the end of serve_step / chunk
    so scatter-updated pools keep their heads-parallel placement and donated
    buffers are reused in place instead of resharded."""
    if getattr(rules, "mesh", None) is None:
        return cache
    is_leaf = lambda x: isinstance(x, tuple)
    flat_specs, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_leaf)
    flat = treedef.flatten_up_to(cache)
    out = [sh.constrain(a, rules, ax) for ax, a in zip(flat_specs, flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_bytes(cache: Tree) -> int:
    """Total bytes resident across every leaf of a cache pytree."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


# --------------------------------------------------------------------------
# quantized read/write helpers
# --------------------------------------------------------------------------


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-vector int8 quantization over the trailing head dim.

    Rank-polymorphic contract: ``x`` is ``(..., Hk, Dh)`` — decode passes
    single tokens ``(B, 1, Hk, Dh)``, prefill whole prompts
    ``(B, S, Hk, Dh)``.  The scale is computed per leading index (one
    absmax per ``(..., Hk)`` row), so both ranks share one code path.
    Returns ``(int8 values (..., Hk, Dh), f32 scales (..., Hk))``.
    """
    assert x.ndim >= 2, f"quantize_kv wants (..., Hk, Dh), got {x.shape}"
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(absmax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`quantize_kv` (tests/oracles; decode never calls
    this — the int8 cache is consumed directly by the int8 MXU dots)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def k_to_bitplanes(k_q: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8 K (B, 1, Hk, Dh) -> (planes (NBITS,B,1,Hk,Dh/8), sign (B,1,Hk,Dh/8))."""
    sign, mag = bitslice.to_sign_magnitude(k_q)
    planes = bitslice.bitplanes(mag, NBITS)
    return bitslice.pack_bits(planes, axis=-1), bitslice.pack_bits(sign, axis=-1)


def bitplanes_to_k(planes: jax.Array, sign: jax.Array) -> jax.Array:
    """Inverse (used by the exact formal-compute stage) -> int32 values."""
    mag = bitslice.from_bitplanes(bitslice.unpack_bits(planes, axis=-1))
    return bitslice.from_sign_magnitude(bitslice.unpack_bits(sign, axis=-1), mag)


# --------------------------------------------------------------------------
# paged addressing — logical position <-> physical pool row
# --------------------------------------------------------------------------
#
# The page table is ``(B, pages_per_slot)`` int32; entry ``-1`` = unmapped.
# Physical row of logical position p in slot b:
#     page_table[b, p // page_size] * page_size + p % page_size
# Write translation preserves the OOB-drop convention (OOB_INDEX lanes and
# unmapped pages scatter nowhere); read translation clamps unmapped pages to
# row 0 — every consumer masks those lanes by position anyway.


def _tok_dim(name: str) -> int:
    # pool token axis after the layer dim; the bgpp plane array interposes
    # its plane dim: (layer, plane, token, ...)
    return 2 if name == "k_planes" else 1


def phys_table(page_table: jax.Array, page_size: int, max_seq: int):
    """Gather map: ``(B, S_max)`` physical rows for every logical position
    (unmapped pages clamp to row 0 — callers mask by position)."""
    pos = jnp.arange(max_seq)
    pid = page_table[:, pos // page_size]  # (B, S)
    return jnp.where(pid >= 0, pid * page_size + (pos % page_size)[None], 0)


def _phys_write(page_table: jax.Array, tpos: jax.Array, page_size: int,
                max_seq: int, slot=None) -> jax.Array:
    """Scatter map: physical rows for logical write targets ``tpos``
    (``OOB_INDEX`` where the lane is padded / OOB / its page unmapped).

    ``slot=None``: per-slot targets — tpos ``(B,)``, one row per batch slot.
    ``slot=b`` (traced ok): tpos ``(S,)`` lanes of one slot's chunk.
    """
    n = page_table.shape[-1]
    page = jnp.clip(tpos // page_size, 0, n - 1)
    if slot is None:
        pid = page_table[jnp.arange(page_table.shape[0]), page]
    else:
        pid = jnp.take(page_table, slot, axis=0)[page]
    ok = (tpos >= 0) & (tpos < max_seq) & (pid >= 0)
    return jnp.where(ok, pid * page_size + tpos % page_size, OOB_INDEX)


def paged_entry(store: Tree, idx, phys: jax.Array) -> Tree:
    """Gather layer ``idx`` of a paged pool back into the slot layout's
    heads-major view: phys ``(B, S)`` -> entries ``(B, Hk, S, ...)`` (and
    ``(NBITS, B, Hk, S, D/8)`` for bgpp planes).  The gathered values are
    exactly the dense row's values, which is what keeps paged attention
    bit-identical to the slot layout."""
    out: Tree = {}
    for n, a in store.items():
        if n == "k_planes":
            g = a[idx][:, phys]  # (NBITS, B, S, Hk, D/8)
            out[n] = jnp.moveaxis(g, 3, 2)
        else:
            g = a[idx][phys]  # (B, S, Hk, ...)
            out[n] = jnp.moveaxis(g, 2, 1)
    return out


def paged_sign(store: Tree, idx, phys: jax.Array) -> jax.Array:
    """Gather the packed sign plane of layer ``idx`` for every logical
    position: phys ``(B, S)`` -> ``(B, Hk, S, D/8)`` heads-major.

    Phase 1 of the two-phase BGPP decode: the sign plane is fetched once
    for all keys (1/8 of the int8 K bytes), before any full-precision row.
    """
    return jnp.moveaxis(store["k_sign"][idx][phys], 2, 1)


def paged_plane(store: Tree, idx, plane: int, phys: jax.Array) -> jax.Array:
    """Gather ONE packed magnitude bit-plane of layer ``idx`` for every
    logical position: phys ``(B, S)`` -> ``(B, Hk, S, D/8)`` heads-major.

    Phase 1 of the two-phase BGPP decode (round 0): only the MSB plane is
    fetched at full sequence width — 1/8 of the int8 K bytes and ~1/16 of
    a bf16 row — so the progressive predictor never touches the rest of
    the pool.
    """
    return jnp.moveaxis(store["k_planes"][idx, plane][phys], 2, 1)


def paged_rows_at(phys: jax.Array, idx: jax.Array) -> jax.Array:
    """Translate per-head logical indices through the gather map: phys
    ``(B, S)``, idx ``(B, Hk, k)`` logical positions -> ``(B, Hk, k)``
    physical pool rows (unmapped positions were already clamped to row 0
    by :func:`phys_table`; callers mask those lanes by validity)."""
    B, Hk, k = idx.shape
    return jnp.take_along_axis(phys, idx.reshape(B, Hk * k), axis=1).reshape(
        B, Hk, k
    )


def _gather_rows_per_head(al: jax.Array, rows: jax.Array, planar: bool):
    """Compacted per-(slot, head) pool gather: for each KV head ``h``,
    fetch ONLY head ``h``'s slice of pool rows ``rows[:, h]`` — the
    surviving-token fetch of BGPP phase 2, which reads ``k`` token-rows'
    worth of bytes total rather than ``k`` whole-head rows per head.

    al: ``(n_tok, Hk, ...)`` (or ``(NBITS, n_tok, Hk, ...)`` when
    ``planar``); rows: ``(B, Hk, k)`` physical rows.  Returns
    ``(B, Hk, k, ...)`` (planar: ``(NBITS, B, Hk, k, ...)``).
    """
    heads = jnp.arange(rows.shape[1])
    if planar:
        return jax.vmap(
            lambda r, h: al[:, r, h], in_axes=(1, 0), out_axes=2
        )(rows, heads)
    return jax.vmap(
        lambda r, h: al[r, h], in_axes=(1, 0), out_axes=1
    )(rows, heads)


def paged_plane_rows(store: Tree, idx, plane: int, rows: jax.Array) -> jax.Array:
    """Gather ONE packed magnitude plane at surviving physical rows only:
    rows ``(B, Hk, k)`` -> ``(B, Hk, k, D/8)``.

    Phase-1 progressive rounds r >= 1: each later round fetches the next
    plane for the shrinking candidate set (paper's early termination) —
    the plane bytes read scale with survivors, not the cache width.
    """
    return _gather_rows_per_head(store["k_planes"][idx, plane], rows, False)


def paged_topk_entry(store: Tree, idx, rows: jax.Array) -> Tree:
    """Phase-2 gather: the surviving tokens' FULL-precision bgpp rows,
    compacted.  rows ``(B, Hk, k)`` physical pool rows -> a heads-major
    entry ``{k_planes (NBITS, B, Hk, k, D/8), k_sign (B, Hk, k, D/8),
    k_scale (B, Hk, k), v (B, Hk, k, D), v_scale (B, Hk, k)}``.

    This is the only point of paged BGPP decode that touches full-precision
    K/V, and it reads exactly ``k = ceil(keep_ratio * S)`` token-rows per
    slot (each of the ``Hk`` per-head gathers fetches 1/Hk of a row).  The
    gathered values are bit-identical to slicing the same logical indices
    out of :func:`paged_entry`'s full view, which is what keeps the
    two-phase attend's logits equal to the full-gather path
    (tests/test_bgpp_gather.py).
    """
    return {
        n: _gather_rows_per_head(a[idx], rows, n == "k_planes")
        for n, a in store.items()
    }


def identity_page_table(layout: CacheLayout) -> jax.Array:
    """Slot-major mapping (slot b, page j) -> physical page b*n+j — the
    trivial table whole-batch prefill uses when no allocator is driving."""
    B, n = layout.batch, layout.pages_per_slot
    assert B * n <= layout.num_pages, "identity table exceeds the pool"
    return jnp.arange(B * n, dtype=jnp.int32).reshape(B, n)


def zero_pages(store: Tree, page_ids: jax.Array, page_size: int) -> Tree:
    """Scrub physical pages (freed by the allocator) across EVERY pool leaf
    — k/v bodies, int8 scales, bgpp bit/sign planes — in every layer.
    ``page_ids`` may be padded with ``-1`` (dropped), so one jit serves any
    eviction size."""
    tok = page_ids[:, None] * page_size + jnp.arange(page_size)[None]
    tok = jnp.where(page_ids[:, None] >= 0, tok, OOB_INDEX).reshape(-1)
    store = dict(store)
    for n, a in store.items():
        store[n] = a.at[(slice(None),) * _tok_dim(n) + (tok,)].set(0)
    return store


def zero_token_range(store: Tree, tpos: jax.Array, *, page_table=None,
                     page_size: int = 0, max_seq: int = 0) -> Tree:
    """Zero per-slot logical token positions across EVERY leaf of a global
    KV store — the speculative-decode rollback scrub.

    ``tpos`` is ``(B, N)`` int32: for each batch slot, up to ``N``
    positions whose rows held speculative writes past the accepted
    frontier; unused lanes carry :data:`OOB_INDEX` (or any out-of-range
    value) and drop, so ONE jitted scrub serves every accept pattern.

    ``page_table`` selects the paged-pool path: positions are translated
    through each slot's table row (unmapped pages — e.g. pages the
    allocator already freed wholesale — drop; :func:`zero_pages` scrubs
    those).  The slot path scatters into the per-slot ``(B, ..., S, ...)``
    stacks, covering k/v bodies, int8 scales, and bgpp sign/magnitude
    planes alike — no leaf ever keeps rolled-back contents.
    """
    safe = jnp.where((tpos >= 0) & (tpos < max_seq), tpos, OOB_INDEX)
    store = dict(store)
    if page_table is not None:
        page = jnp.clip(tpos // page_size, 0, page_table.shape[-1] - 1)
        pid = jnp.take_along_axis(page_table, page, axis=1)  # (B, N)
        ok = (tpos >= 0) & (tpos < max_seq) & (pid >= 0)
        phys = jnp.where(
            ok, pid * page_size + tpos % page_size, OOB_INDEX
        ).reshape(-1)
        for n, a in store.items():
            store[n] = a.at[(slice(None),) * _tok_dim(n) + (phys,)].set(0)
        return store
    bidx = jnp.arange(tpos.shape[0])[:, None]  # (B, 1) against (B, N) lanes
    for n, a in store.items():
        if n == "k_planes":  # (L, NBITS, B, Hk, S, D/8)
            store[n] = a.at[:, :, bidx, :, safe].set(0)
        else:  # (L, B, Hk, S, ...)
            store[n] = a.at[:, bidx, :, safe].set(0)
    return store


def page_bytes(store: Tree, page_size: int) -> int:
    """Bytes one physical page occupies across every leaf of a pool (host
    arithmetic from shapes — the allocator's resident-KV accounting)."""
    total = 0
    for n, a in store.items():
        n_tok = a.shape[_tok_dim(n)]
        total += a.size * a.dtype.itemsize * page_size // n_tok
    return total


# --------------------------------------------------------------------------
# KV-read accounting — bytes the jitted steps gather from the KV stores
# --------------------------------------------------------------------------
#
# Host-side mirrors of the device gathers, computed from the SAME static
# shapes the jitted steps address (B rows × layer stacks × the per-format
# row bytes; for bgpp, the two-phase plan: sign + MSB plane at full width,
# one shrinking survivor plane per progressive round, then ceil(keep·S)
# full-precision rows).  The scheduler accumulates these per executed step
# into ``Scheduler.stats()["kv_read"]`` — the counter the serving
# benchmarks and launchers report, and the one the acceptance assert
# (paged bgpp reads bit-planes + at most k_max full rows) checks against.


def _cache_dtype_bytes(cfg) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def _token_row_bytes(cfg, fmt: str) -> float:
    """Bytes one token's KV row (all ``Hk`` heads, K and V sides plus any
    scales) occupies in a stack of format ``fmt``."""
    Hk, Dh = cfg.num_kv_heads, cfg.head_dim
    if fmt == "bf16":
        return Hk * Dh * _cache_dtype_bytes(cfg) * 2.0
    if fmt == "int8":
        return Hk * (2.0 * Dh + 8.0)  # int8 K+V, two f32 scales
    if fmt == "bgpp":
        # packed magnitude planes + sign plane + f32 k_scale + int8 V + f32
        # v_scale — the FULL row phase 2 fetches per surviving token
        return Hk * (NBITS * Dh / 8.0 + Dh / 8.0 + 4.0 + Dh + 4.0)
    raise ValueError(fmt)


def mesh_shard_factors(layout: CacheLayout, cfg, mesh_shape) -> Tuple[int, int]:
    """``(d_eff, m_eff)``: how many ways a ``(data, model)`` mesh actually
    splits the serve_step KV reads.  Mirrors :meth:`ShardingRules
    .spec_for_shape` divisibility fallback — a ``"model"`` axis that does
    not divide BOTH head counts replicates (q must shard alongside k/v for
    the attend to stay device-local), and a ``"data"`` axis that does not
    divide the batch replicates."""
    d, m = int(mesh_shape[0]), int(mesh_shape[1])
    Hq, Hk = cfg.num_heads, cfg.num_kv_heads
    m_eff = m if m >= 1 and Hk and Hq and Hk % m == 0 and Hq % m == 0 else 1
    d_eff = d if d >= 1 and layout.batch % d == 0 else 1
    return d_eff, m_eff


def _interconnect_decode(layout: CacheLayout, cfg, d_eff: int,
                         m_eff: int) -> Dict[str, float]:
    """Collective bytes ONE batched serve_step moves between devices.

    Two collectives are priced — the only ones the bit-exact sharding
    layout allows (contractions are never split, so there is no psum):

    * ``attend_allgather`` — per attention layer, the f32 per-head attend
      outputs ``(B, Hq, Dh)`` are all-gathered across ``"model"`` before
      the (replicated) ``wo`` projection.  Each of the ``m_eff`` shards
      sends its ``1/m_eff`` slice to the other ``m_eff - 1`` peers.
    * ``paged_write_bcast`` — paged pools have no batch axis, so they are
      replicated across ``"data"``; the B decode-token KV rows (computed
      batch-sharded) must reach every data replica of the pool.
    """
    B = layout.batch
    ng, nl = len(layout.global_layers), len(layout.local_layers)
    attend = (m_eff - 1) * B * cfg.num_heads * cfg.head_dim * 4.0 * (ng + nl)
    paged_w = 0.0
    if layout.layout == "paged" and ng:
        paged_w = (d_eff - 1) * B * ng * _token_row_bytes(cfg, layout.kv_format)
    return {
        "attend_allgather": attend,
        "paged_write_bcast": paged_w,
        "total": attend + paged_w,
    }


def bgpp_decode_plan(S: int, cfg) -> Tuple[int, int, Tuple[int, ...]]:
    """Static shapes of one two-phase BGPP decode attend over ``S`` cache
    lanes, per (row, layer): ``(rounds, k_max, survivors)`` with
    ``survivors[r]`` the candidate-set width whose plane round ``r``
    fetches (``S`` at round 0, then ``max(k_max, S >> r)``).

    This is THE definition of the plan: ``engine._bgpp_topk_indices``
    takes its round/top-k widths from here, and :func:`decode_read_bytes`
    prices the same tuple — so the reported counter can never drift from
    the shapes the engine actually gathers."""
    mo = cfg.mcbp
    if S < 1:
        raise ValueError(
            f"bgpp_decode_plan: cache width S={S} must be >= 1 — was the "
            f"layout built with max_seq=0?"
        )
    if mo.bgpp_rounds < 1:
        raise ValueError(
            f"bgpp_decode_plan: bgpp_rounds={mo.bgpp_rounds} must be >= 1 "
            f"(round 0 always scans the MSB plane)"
        )
    if not (0.0 < mo.bgpp_keep_ratio <= 1.0):
        raise ValueError(
            f"bgpp_decode_plan: bgpp_keep_ratio={mo.bgpp_keep_ratio} must "
            f"be in (0, 1] — it sizes the surviving candidate set"
        )
    rounds = max(1, min(mo.bgpp_rounds, NBITS))
    k_max = max(1, min(S, int(math.ceil(mo.bgpp_keep_ratio * S))))
    survivors = (S,) + tuple(max(k_max, S >> r) for r in range(1, rounds))
    return rounds, k_max, survivors


def decode_read_bytes(layout: CacheLayout, cfg,
                      mesh_shape: Tuple[int, int] = (1, 1)) -> Dict[str, Any]:
    """KV bytes ONE batched ``serve_step`` gathers, at its static shapes.

    All ``layout.batch`` rows and every cached layer are counted (the
    jitted step gathers them regardless of slot liveness — static shapes).
    Global bf16/int8 stacks read the full ``(S_max,)`` row; local rings
    read their ``window``; bgpp global stacks follow the two-phase plan:
    sign + MSB plane everywhere, shrinking survivor planes, then exactly
    ``k_max = ceil(bgpp_keep_ratio * S_max)`` full-precision token rows
    per (slot, layer) — reported under ``"bgpp"`` so callers can assert
    the full-row fetch never exceeds the keep ratio.  ``"bf16_equiv"`` is
    what a bf16 cache of the same geometry would read — the reduction
    denominator the benchmarks report.

    With a ``(data, model)`` ``mesh_shape``, two extra sections appear:
    ``"per_device"`` (the same counters divided by the effective shard
    count — reads are batch-sharded over ``"data"`` and head-sharded over
    ``"model"``, so each device gathers ``total / (d_eff * m_eff)`` bytes)
    and ``"interconnect"`` (see :func:`_interconnect_decode`).  At 1×1
    per-device equals total and interconnect is zero.
    """
    B, S, W = layout.batch, layout.max_seq, layout.local_window
    ng, nl = len(layout.global_layers), len(layout.local_layers)
    out: Dict[str, Any] = {"global": 0.0, "local": 0.0}
    if ng:
        if layout.kv_format == "bgpp":
            rounds, k_max, survivors = bgpp_decode_plan(S, cfg)
            plane_row = cfg.num_kv_heads * cfg.head_dim / 8.0
            sign = S * plane_row
            planes = float(sum(survivors)) * plane_row
            topk_full = k_max * _token_row_bytes(cfg, "bgpp")
            out["bgpp"] = {
                "rounds": rounds,
                "full_rows_per_slot": k_max,
                "sign_bytes": B * ng * sign,
                "plane_bytes": B * ng * planes,
                "topk_full_bytes": B * ng * topk_full,
            }
            out["global"] = B * ng * (sign + planes + topk_full)
        else:
            out["global"] = B * ng * S * _token_row_bytes(cfg, layout.kv_format)
    if nl:
        fmt_l = "int8" if layout.kv_format == "bgpp" else layout.kv_format
        out["local"] = B * nl * W * _token_row_bytes(cfg, fmt_l)
    out["total"] = out["global"] + out["local"]
    out["bf16_equiv"] = (B * ng * S + B * nl * W) * _token_row_bytes(cfg, "bf16")
    d_eff, m_eff = mesh_shard_factors(layout, cfg, mesh_shape)
    shards = d_eff * m_eff
    out["per_device"] = {
        "global": out["global"] / shards,
        "local": out["local"] / shards,
        "total": out["total"] / shards,
        "shards": shards,
    }
    out["interconnect"] = _interconnect_decode(layout, cfg, d_eff, m_eff)
    return out


def chunk_read_bytes(layout: CacheLayout, cfg,
                     mesh_shape: Tuple[int, int] = (1, 1),
                     chunk_width: int = 1) -> Dict[str, Any]:
    """KV bytes ONE chunked-prefill step reads from the live cache (one
    slot): global layers attend the full ``(S_max,)`` row at full precision
    — BGPP's progressive prediction is a decode-time saving; prefill
    reconstructs exact int8 K from every plane — and local ring layers
    gather their ``window``.  Eager admission reads nothing (the B=1
    forward self-attends without touching the cache)."""
    S, W = layout.max_seq, layout.local_window
    ng, nl = len(layout.global_layers), len(layout.local_layers)
    fmt_l = "int8" if layout.kv_format == "bgpp" else layout.kv_format
    g = ng * S * _token_row_bytes(cfg, layout.kv_format)
    loc = nl * W * _token_row_bytes(cfg, fmt_l)
    out: Dict[str, Any] = {"global": g, "local": loc, "total": g + loc}
    # chunks run at B=1, so only the "model" head shard splits the reads;
    # the attend all-gather moves the chunk's Hq*Dh lanes at cache dtype
    d_eff, m_eff = mesh_shard_factors(layout, cfg, mesh_shape)
    out["per_device"] = {"total": out["total"] / m_eff, "shards": m_eff}
    attend = ((m_eff - 1) * chunk_width * cfg.num_heads * cfg.head_dim
              * _cache_dtype_bytes(cfg) * (ng + nl))
    # no paged write broadcast here: a B=1 chunk is replicated across
    # "data" (batch of one cannot shard), so every data replica computes
    # the chunk redundantly and writes its own pool copy locally
    del d_eff
    out["interconnect"] = {
        "attend_allgather": attend,
        "paged_write_bcast": 0.0,
        "total": attend,
    }
    return out


# --------------------------------------------------------------------------
# stack writes — the ONE code path for bf16 / int8 / bgpp stores
# --------------------------------------------------------------------------
#
# The storage format is inferred from the store's keys (``k_planes`` => bgpp,
# ``k_scale`` => int8, else bf16), so decode layers and both prefill paths
# (whole-batch and single-slot admission) never branch on format themselves.


def write_token(store: Tree, idx: int, k: jax.Array, v: jax.Array,
                tpos: jax.Array, *, page_table=None, page_size: int = 0,
                max_seq: int = 0) -> Tree:
    """Write one decode token into layer ``idx`` of a KV stack, per slot.

    k/v: fresh projections ``(B, 1, Hk, Dh)`` (seq-major).
    tpos: ``(B,)`` int32 per-slot target index along the stack's seq axis —
    the absolute position for global stacks, ``pos % window`` for local
    ring buffers.  Every batch row scatters to its own index, which is what
    lets staggered requests share one cache.

    ``page_table`` selects the paged-pool path: tpos is translated through
    the slot's table row (unmapped page => dropped write) and the scatter
    targets the token-major pool.
    """
    B = k.shape[0]
    if page_table is not None:
        # (B, 1) targets broadcast against the (B, 1, Hk, ...) projections,
        # so the shared paged scatter tail serves decode writes too
        phys = _phys_write(page_table, tpos, page_size, max_seq)
        return _scatter_paged_kv(store, idx, phys[:, None], k, v)
    bidx = jnp.arange(B)
    if "k_planes" in store:  # bgpp: bit-planed K magnitudes + int8 V
        kq, ks = quantize_kv(k)
        planes, sign = k_to_bitplanes(kq)  # (NBITS,B,1,Hk,D/8), (B,1,Hk,D/8)
        store["k_planes"] = store["k_planes"].at[idx, :, bidx, :, tpos].set(
            jnp.moveaxis(planes[:, :, 0], 0, 1))  # (B,NBITS,Hk,D/8)
        store["k_sign"] = store["k_sign"].at[idx, bidx, :, tpos].set(sign[:, 0])
        store["k_scale"] = store["k_scale"].at[idx, bidx, :, tpos].set(ks[:, 0])
        vq, vs = quantize_kv(v)
        store["v"] = store["v"].at[idx, bidx, :, tpos].set(vq[:, 0])
        store["v_scale"] = store["v_scale"].at[idx, bidx, :, tpos].set(vs[:, 0])
    elif "k_scale" in store:  # int8
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        store["k"] = store["k"].at[idx, bidx, :, tpos].set(kq[:, 0])
        store["v"] = store["v"].at[idx, bidx, :, tpos].set(vq[:, 0])
        store["k_scale"] = store["k_scale"].at[idx, bidx, :, tpos].set(ks[:, 0])
        store["v_scale"] = store["v_scale"].at[idx, bidx, :, tpos].set(vs[:, 0])
    else:  # bf16
        store["k"] = store["k"].at[idx, bidx, :, tpos].set(
            k[:, 0].astype(store["k"].dtype))
        store["v"] = store["v"].at[idx, bidx, :, tpos].set(
            v[:, 0].astype(store["v"].dtype))
    return store


def _scatter_chunk_kv(store: Tree, idx: int, slot, tpos, k, v) -> Tree:
    """Quantize-and-scatter one chunk's K/V rows (int8 or bf16 stores) into
    seq indices ``tpos`` of batch row ``slot`` — the shared tail of both
    chunked write paths (``.at[idx, slot, :, tpos]`` selects ``(S, Hk, ...)``
    advanced-dims-first; OOB lanes drop)."""
    if "k_scale" in store:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        store["k"] = store["k"].at[idx, slot, :, tpos].set(kq[0])
        store["v"] = store["v"].at[idx, slot, :, tpos].set(vq[0])
        store["k_scale"] = store["k_scale"].at[idx, slot, :, tpos].set(ks[0])
        store["v_scale"] = store["v_scale"].at[idx, slot, :, tpos].set(vs[0])
    else:
        store["k"] = store["k"].at[idx, slot, :, tpos].set(
            k[0].astype(store["k"].dtype))
        store["v"] = store["v"].at[idx, slot, :, tpos].set(
            v[0].astype(store["v"].dtype))
    return store


def _scatter_paged_kv(store: Tree, idx, phys, k, v) -> Tree:
    """Quantize-and-scatter K/V token rows into pool rows ``phys`` (any
    shape matching k/v's leading batch/seq dims; OOB rows drop).  Values
    stay token-major — the pool's native order, so no transposes."""
    if "k_planes" in store:
        kq, ks = quantize_kv(k)
        planes, sign = k_to_bitplanes(kq)  # (NBITS, *phys.shape, Hk, D/8)
        store["k_planes"] = store["k_planes"].at[idx, :, phys].set(
            jnp.moveaxis(planes, 0, phys.ndim))
        store["k_sign"] = store["k_sign"].at[idx, phys].set(sign)
        store["k_scale"] = store["k_scale"].at[idx, phys].set(ks)
        vq, vs = quantize_kv(v)
        store["v"] = store["v"].at[idx, phys].set(vq)
        store["v_scale"] = store["v_scale"].at[idx, phys].set(vs)
    elif "k_scale" in store:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        store["k"] = store["k"].at[idx, phys].set(kq)
        store["v"] = store["v"].at[idx, phys].set(vq)
        store["k_scale"] = store["k_scale"].at[idx, phys].set(ks)
        store["v_scale"] = store["v_scale"].at[idx, phys].set(vs)
    else:
        store["k"] = store["k"].at[idx, phys].set(k.astype(store["k"].dtype))
        store["v"] = store["v"].at[idx, phys].set(v.astype(store["v"].dtype))
    return store


def write_prefill(store: Tree, idx: int, k: jax.Array, v: jax.Array,
                  *, slot: Optional[int] = None, offset=None,
                  length=None, page_table=None, page_size: int = 0,
                  max_seq: int = 0) -> Tree:
    """Write a whole prompt's K/V into positions ``[0, S)`` of a global stack.

    k/v: ``(B, S, Hk, Dh)``.  ``slot=None`` writes every batch row (fresh
    whole-batch prefill); ``slot=b`` writes row ``b`` only — admission of one
    prompt (``B == 1``) into a single slot of a *live* cache.

    ``offset``/``length`` select the chunked-admission path: the ``S`` lanes
    are a fixed-shape prefill chunk whose first ``length`` lanes are valid
    prompt tokens landing at positions ``[offset, offset+length)``; padded
    lanes scatter to :data:`OOB_INDEX` and are dropped.  ``slot``/``offset``/
    ``length`` may all be traced scalars, so one jitted chunk step serves
    every slot and token offset (compiled once per chunk width ``S``).

    ``page_table`` selects the paged-pool path: every logical target is
    translated through the table (same OOB-drop convention; writes to
    unmapped pages vanish) and scattered token-major into the pool.
    """
    S = k.shape[1]
    if page_table is not None:
        if offset is not None:
            assert slot is not None and k.shape[0] == 1, \
                "chunked writes admit one prompt into one slot"
            length = S if length is None else length
            lane = jnp.arange(S)
            tpos = jnp.where(lane < length, offset + lane, OOB_INDEX)
            phys = _phys_write(page_table, tpos, page_size, max_seq, slot=slot)
            return _scatter_paged_kv(store, idx, phys, k[0], v[0])
        lanes = jnp.arange(S)
        if slot is None:
            pid = page_table[:, lanes // page_size]  # (B, S)
            phys = jnp.where(pid >= 0,
                             pid * page_size + (lanes % page_size)[None],
                             OOB_INDEX)
            return _scatter_paged_kv(store, idx, phys, k, v)
        assert k.shape[0] == 1, "slot admission writes one prompt at a time"
        phys = _phys_write(page_table, lanes, page_size, max_seq, slot=slot)
        return _scatter_paged_kv(store, idx, phys, k[0], v[0])
    if offset is not None:
        assert slot is not None and k.shape[0] == 1, \
            "chunked writes admit one prompt into one slot"
        length = S if length is None else length
        lane = jnp.arange(S)
        tpos = jnp.where(lane < length, offset + lane, OOB_INDEX)
        if "k_planes" in store:
            kq, ks = quantize_kv(k)
            planes, sign = k_to_bitplanes(kq)  # (NBITS,1,S,Hk,D/8)
            # .at[idx, :, slot, :, tpos] selects (S, NBITS, Hk, D/8)
            store["k_planes"] = store["k_planes"].at[idx, :, slot, :, tpos].set(
                jnp.moveaxis(planes[:, 0], 0, 1))
            store["k_sign"] = store["k_sign"].at[idx, slot, :, tpos].set(sign[0])
            store["k_scale"] = store["k_scale"].at[idx, slot, :, tpos].set(ks[0])
            vq, vs = quantize_kv(v)
            store["v"] = store["v"].at[idx, slot, :, tpos].set(vq[0])
            store["v_scale"] = store["v_scale"].at[idx, slot, :, tpos].set(vs[0])
            return store
        return _scatter_chunk_kv(store, idx, slot, tpos, k, v)
    if slot is None:
        bsel: Any = slice(None)
        tr = lambda a: jnp.swapaxes(a, 1, 2)  # (B,S,Hk,...) -> (B,Hk,S,...)
    else:
        assert k.shape[0] == 1, "slot admission writes one prompt at a time"
        bsel = slot
        tr = lambda a: jnp.swapaxes(a, 1, 2)[0]  # -> (Hk,S,...)
    if "k_planes" in store:
        kq, ks = quantize_kv(k)
        planes, sign = k_to_bitplanes(kq)  # (NBITS,B,S,Hk,D/8)
        ptr = (lambda a: jnp.swapaxes(a, 2, 3)) if slot is None else (
            lambda a: jnp.swapaxes(a, 2, 3)[:, 0])
        store["k_planes"] = store["k_planes"].at[idx, :, bsel, :, :S].set(ptr(planes))
        store["k_sign"] = store["k_sign"].at[idx, bsel, :, :S].set(tr(sign))
        store["k_scale"] = store["k_scale"].at[idx, bsel, :, :S].set(tr(ks))
        vq, vs = quantize_kv(v)
        store["v"] = store["v"].at[idx, bsel, :, :S].set(tr(vq))
        store["v_scale"] = store["v_scale"].at[idx, bsel, :, :S].set(tr(vs))
    elif "k_scale" in store:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        store["k"] = store["k"].at[idx, bsel, :, :S].set(tr(kq))
        store["v"] = store["v"].at[idx, bsel, :, :S].set(tr(vq))
        store["k_scale"] = store["k_scale"].at[idx, bsel, :, :S].set(tr(ks))
        store["v_scale"] = store["v_scale"].at[idx, bsel, :, :S].set(tr(vs))
    else:
        store["k"] = store["k"].at[idx, bsel, :, :S].set(
            tr(k).astype(store["k"].dtype))
        store["v"] = store["v"].at[idx, bsel, :, :S].set(
            tr(v).astype(store["v"].dtype))
    return store


def write_prefill_local(store: Tree, idx: int, k: jax.Array, v: jax.Array,
                        window: int, *, slot: Optional[int] = None,
                        offset=None, length=None) -> Tree:
    """Ring-write the last ``min(window, S)`` prompt positions of a local
    stack (slot ``pos % window``), recording absolute positions for
    RoPE-correct reuse.  ``slot`` selects one batch row as in
    :func:`write_prefill`.

    ``offset``/``length`` (traced ok) select the chunked-admission path:
    lanes are chunk tokens at positions ``[offset, offset+length)``.  Only
    the last ``min(length, window)`` valid lanes are written — the earlier
    ones would be ring-evicted by them anyway, and masking them keeps the
    kept lanes' ring slots unique so the scatter has no write races.
    """
    B, S = k.shape[:2]
    if offset is not None:
        assert slot is not None and B == 1, \
            "chunked writes admit one prompt into one slot"
        length = S if length is None else length
        lane = jnp.arange(S)
        keep = (lane < length) & (lane >= length - window)
        tpos = jnp.where(keep, jnp.mod(offset + lane, window), OOB_INDEX)
        store = _scatter_chunk_kv(store, idx, slot, tpos, k, v)
        store["abs_pos"] = store["abs_pos"].at[idx, slot, tpos].set(offset + lane)
        return store
    take = min(window, S)
    pos_abs = jnp.arange(S - take, S)
    slots = jnp.mod(pos_abs, window)
    k, v = k[:, -take:], v[:, -take:]
    if slot is None:
        bsel: Any = slice(None)
        # .at[idx, :, :, slots] targets (take, B, Hk, D) — advanced dim first
        tr = lambda a: jnp.swapaxes(a, 0, 1)
    else:
        assert B == 1
        bsel = slot
        # .at[idx, slot, :, slots] targets (take, Hk, D)
        tr = lambda a: a[0]
    if "k_scale" in store:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        store["k"] = store["k"].at[idx, bsel, :, slots].set(tr(kq))
        store["v"] = store["v"].at[idx, bsel, :, slots].set(tr(vq))
        store["k_scale"] = store["k_scale"].at[idx, bsel, :, slots].set(tr(ks))
        store["v_scale"] = store["v_scale"].at[idx, bsel, :, slots].set(tr(vs))
    else:
        store["k"] = store["k"].at[idx, bsel, :, slots].set(
            tr(k).astype(store["k"].dtype))
        store["v"] = store["v"].at[idx, bsel, :, slots].set(
            tr(v).astype(store["v"].dtype))
    if slot is None:
        store["abs_pos"] = store["abs_pos"].at[idx, :, slots].set(
            jnp.broadcast_to(pos_abs, (B, take)).T)
    else:
        store["abs_pos"] = store["abs_pos"].at[idx, slot, slots].set(pos_abs)
    return store


# --------------------------------------------------------------------------
# slot lifecycle
# --------------------------------------------------------------------------


def _batch_dim(stack: str, name: str) -> int:
    # all stacks put batch at dim 1 except the bgpp plane array, whose
    # leading dims are (layer, plane, batch, ...)
    return 2 if name == "k_planes" else 1


def reset_slot(cache: Tree, layout: CacheLayout, slot: int) -> Tree:
    """Clear one batch row across every stack without touching live
    neighbors: KV rows to zero, ring ``abs_pos`` to -1 (nothing valid),
    mamba state to zero, ``pos[slot]`` to 0.  This is eviction; admission is
    ``engine.prefill_into_slot`` (which calls this first, so stale ring
    positions from the previous occupant can never alias into the new
    request's valid window).

    Paged layouts: the global pool has no batch rows — page lifecycle
    (decref, free, zero) belongs to :class:`repro.serving.paging
    .PageAllocator`, and the device page table is synced from its host
    copy, so this clears only the slot-major state (local rings, mamba,
    cross, pos).
    """

    def _clear(a, bdim, fill=0):
        return a.at[(slice(None),) * bdim + (slot,)].set(fill)

    cache = dict(cache)
    stacks = ("local",) if layout.layout == "paged" else ("global", "local")
    for stack in stacks:
        if stack not in cache:
            continue
        st = dict(cache[stack])
        for n, a in st.items():
            st[n] = _clear(a, _batch_dim(stack, n),
                           fill=-1 if n == "abs_pos" else 0)
        cache[stack] = st
    if "mamba" in cache:
        cache["mamba"] = {
            n: _clear(a, 1) for n, a in cache["mamba"].items()
        }
    for n in ("cross_k", "cross_v"):
        if n in cache:
            cache[n] = _clear(cache[n], 1)
    cache["pos"] = cache["pos"].at[slot].set(0)
    return cache
