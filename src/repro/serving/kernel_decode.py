"""Route the serving engine's global-layer decode attend onto the Pallas
paged-attention kernel families.

The engine's decode hot path (``engine._attn_decode_layer``, global
branch) has two kernel-backed formats:

* ``paged_flash_decode`` — bf16 / int8 dense attend.  The paged layout
  passes its token-major pools and the ``(B, S_max/page_size)`` page
  table STRAIGHT into the kernel (the BlockSpec index map gathers
  physical pages; ``kv_cache.paged_entry``'s contiguous per-slot view is
  never built).  The slot layout pool-ifies its heads-major stacks with
  free transposes and an identity page table, so one kernel serves both.
* ``bgpp_paged_attend`` — the fused two-phase BGPP decode (plane scan,
  progressive top-k, compacted survivor gather, exact int8 attend) in
  one launch.

Mode resolution happens ONCE at ``make_serve_step`` build time
(:func:`resolve`): the ``decode_kernel`` config knob (or the
``REPRO_DECODE_KERNEL`` env var) picks ``jnp`` (legacy engine paths,
bit-for-bit the pre-kernel behavior), ``interpret`` (Pallas interpret —
the CPU CI parity mode), ``kernel`` (compiled Mosaic), or ``auto``
(kernel on TPU backends, jnp elsewhere).

Sharding: with a mesh attached and a non-trivial model axis the attend is
wrapped in ``shard_map`` exactly like the engine's
``_bgpp_paged_decode_attend_sharded`` — each device runs the kernel on
its own (batch, head) shard of the pool, no collective introduced.  When
the head counts don't divide the model axis, :func:`decode_attend`
returns ``None`` and the engine falls back to its jnp path (the same
divisibility fallback the cache placement applies).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.distributed import sharding as sh
from repro.kernels import MODE_COMPILED, MODE_INTERPRET
from repro.kernels.bgpp_paged_attend import bgpp_paged_attend
from repro.kernels.paged_flash_decode import paged_flash_decode
from repro.serving import kv_cache as kvc

Tree = Dict[str, Any]

ENV_VAR = "REPRO_DECODE_KERNEL"
MODES = ("auto", "jnp", "interpret", "kernel")
# internal execution modes after resolution
_EXEC = {"jnp": "jnp", "interpret": MODE_INTERPRET, "kernel": MODE_COMPILED}


def resolve(cfg) -> str:
    """Resolve the ``decode_kernel`` knob to an execution mode.

    Returns ``"jnp"`` (legacy engine attend), ``"interpret"`` or
    ``"compiled"`` (kernel dispatch modes).  ``REPRO_DECODE_KERNEL``
    overrides the config so CI matrices can flip the path without
    touching configs; ``auto`` picks the compiled kernel on TPU backends
    and the jnp path everywhere else (CPU default behavior is therefore
    bit-for-bit unchanged).
    """
    knob = os.environ.get(ENV_VAR, "").strip() or getattr(
        cfg.mcbp, "decode_kernel", "auto"
    )
    if knob not in MODES:
        raise ValueError(
            f"decode_kernel={knob!r} is not one of {MODES} (config "
            f"mcbp.decode_kernel or ${ENV_VAR})"
        )
    if knob == "auto":
        knob = "kernel" if compat.is_tpu_backend() else "jnp"
    return _EXEC[knob]


def _slot_page_size(max_seq: int) -> int:
    """Largest of 8/4/2/1 dividing ``max_seq`` — the identity-page-table
    page size used to pool-ify slot stacks (always succeeds; 1 divides)."""
    for p in (8, 4, 2, 1):
        if max_seq % p == 0:
            return p
    raise AssertionError("unreachable: 1 divides everything")


def validate(cfg, layout) -> None:
    """Raise actionable errors for configs the kernel path cannot serve.

    Called once at ``make_serve_step`` build time when the resolved mode
    is not ``jnp`` — shape/divisibility mistakes surface here with a
    config-level message instead of failing inside Pallas lowering.
    """
    if cfg.num_heads % cfg.num_kv_heads:
        raise ValueError(
            f"decode_kernel: num_heads={cfg.num_heads} is not a multiple of "
            f"num_kv_heads={cfg.num_kv_heads} — the GQA group size must be "
            f"integral for the grouped (B, Hk, g, Dh) kernel query layout"
        )
    # NOTE: max_seq need not be page-aligned — the flash kernel attends the
    # full page-covered span (pages_per_slot * page_size lanes) and masks
    # past pos exactly like the engine, and the bgpp phys map is row-level.
    if layout.kv_format == "bgpp":
        if cfg.head_dim % 8:
            raise ValueError(
                f"decode_kernel: head_dim={cfg.head_dim} is not a multiple "
                f"of 8 — bgpp packs bit planes bytewise"
            )
        rounds, k_max, survivors = kvc.bgpp_decode_plan(layout.max_seq, cfg)
        if survivors[0] != layout.max_seq or k_max > layout.max_seq:
            raise ValueError(
                f"decode_kernel: bgpp plan (rounds={rounds}, k_max={k_max}, "
                f"survivors={survivors}) is inconsistent with "
                f"max_seq={layout.max_seq} — check bgpp_rounds / "
                f"bgpp_keep_ratio"
            )


def _pool_views(store: Tree, gi: int, fmt: str, slot_layout: bool) -> Tree:
    """Layer ``gi``'s token-major pool leaves.

    Paged stores already hold token-major pools — this just indexes the
    layer.  Slot stores are heads-major ``(B, Hk, S, ...)`` stacks; the
    transposes below re-lay them as ``(B*S, Hk, ...)`` pools whose row
    ``b*S + s`` is slot ``b``'s logical position ``s`` (an identity page
    table / phys map addresses them), so both layouts feed one kernel.
    """
    if not slot_layout:
        if fmt == "bgpp":
            return {n: store[n][gi] for n in
                    ("k_planes", "k_sign", "k_scale", "v", "v_scale")}
        names = ("k", "v") if fmt == "bf16" else ("k", "v", "k_scale", "v_scale")
        return {n: store[n][gi] for n in names}
    out: Tree = {}
    for n in store:
        a = store[n][gi]
        if n == "k_planes":  # (NBITS, B, Hk, S, D/8) -> (NBITS, B*S, Hk, D/8)
            nb, B, Hk, S, Dp = a.shape
            out[n] = a.transpose(0, 1, 3, 2, 4).reshape(nb, B * S, Hk, Dp)
        elif a.ndim == 4:  # (B, Hk, S, D) -> (B*S, Hk, D)
            B, Hk, S, D = a.shape
            out[n] = a.transpose(0, 2, 1, 3).reshape(B * S, Hk, D)
        else:  # scales (B, Hk, S) -> (B*S, Hk)
            B, Hk, S = a.shape
            out[n] = a.transpose(0, 2, 1).reshape(B * S, Hk)
    return out


def _attend_local(q1, pool: Tree, pos, table, cfg, layout, mode: str):
    """Run the kernel family on device-local operands -> ``(B, Hq, Dh)``.

    ``table`` is the page table (non-bgpp) or the phys map (bgpp) — for
    the slot layout the caller passes ``None`` and identity maps are built
    here from the LOCAL batch size, so the same body serves the
    ``shard_map``-wrapped and unsharded calls.
    """
    B, Hq, Dh = q1.shape
    g = cfg.num_heads // cfg.num_kv_heads  # ratio: shard-invariant
    Hk = Hq // g
    qg = q1.reshape(B, Hk, g, Dh).astype(jnp.float32)
    fmt = layout.kv_format
    slot = layout.layout != "paged"
    S = layout.max_seq

    if fmt == "bgpp":
        if table is None:  # slot: identity logical->pool row map
            table = (jnp.arange(B, dtype=jnp.int32)[:, None] * S
                     + jnp.arange(S, dtype=jnp.int32)[None, :])
        rounds, k_max, survivors = kvc.bgpp_decode_plan(S, cfg)
        out = bgpp_paged_attend(
            qg, pool["k_planes"], pool["k_sign"], pool["k_scale"],
            pool["v"], pool["v_scale"], table, pos,
            rounds=rounds, k_max=k_max, survivors=survivors, mode=mode,
        )
    else:
        if table is None:  # slot: identity page table over the B*S pool
            P = _slot_page_size(S)
            pp = S // P
            table = (jnp.arange(B, dtype=jnp.int32)[:, None] * pp
                     + jnp.arange(pp, dtype=jnp.int32)[None, :])
            page_size = P
        else:
            page_size = layout.page_size
        scales = (
            {} if fmt == "bf16"
            else {"k_scale": pool["k_scale"], "v_scale": pool["v_scale"]}
        )
        out = paged_flash_decode(
            qg, pool["k"], pool["v"], table, pos,
            page_size=page_size, mode=mode, **scales,
        )
    # (B, Hk, g, Dh) -> (B, Hq, Dh): same axis order as the engine's
    # transpose/reshape epilogue (verified bitwise in the parity tests)
    return out.reshape(B, Hq, Dh)


def decode_attend(q1, store: Tree, gi: int, pos, cfg, layout, rules,
                  mode: str, phys=None, page_table=None):
    """Kernel-backed global-layer decode attend, or ``None`` to fall back.

    q1 ``(B, Hq, Dh)``; ``store`` is ``cache["global"]``; ``pos`` the
    per-slot positions ``(B,)``.  Paged layouts pass ``phys`` (bgpp) and
    ``page_table`` (dense formats); the slot layout passes neither.
    Returns f32 ``(B, Hq, Dh)`` matching the engine's jnp attend, or
    ``None`` when ``mode == "jnp"`` or the mesh's model axis doesn't
    divide the head counts (the engine then runs its legacy path).
    """
    if mode == "jnp":
        return None
    fmt = layout.kv_format
    slot = layout.layout != "paged"
    table = None if slot else (phys if fmt == "bgpp" else page_table)
    pos = pos.astype(jnp.int32)

    mesh = getattr(rules, "mesh", None)
    m = dict(mesh.shape).get(rules.model_axis, 1) if mesh is not None else 1
    if mesh is None or m <= 1:
        pool = _pool_views(store, gi, fmt, slot)
        return _attend_local(q1, pool, pos, table, cfg, layout, mode)
    if cfg.num_kv_heads % m or cfg.num_heads % m:
        return None  # heads don't shard: engine jnp fallback (replicated)
    if slot and (getattr(rules, "seq_shard", False) or getattr(rules, "sp", False)):
        return None  # seq-sharded slot stacks break the identity pool maps
    from jax.experimental.shard_map import shard_map

    def run(q_, store_, pos_, table_):
        pool = _pool_views(store_, gi, fmt, slot)
        t = table_ if not slot else None
        return _attend_local(q_, pool, pos_, t, cfg, layout, mode)

    spec = lambda axes, x: rules.spec_for_shape(mesh, axes, x.shape)
    store_spec = jax.tree.map(
        lambda axes, x: spec(tuple(axes), x),
        kvc.cache_specs(cfg, layout)["global"], store,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    if table is None:  # slot layout: feed a dummy all-devices scalar map
        table = jnp.zeros((q1.shape[0], 1), jnp.int32)
    return shard_map(
        run, mesh=mesh,
        in_specs=(
            spec((sh.BATCH, sh.HEADS, None), q1),
            store_spec,
            spec((sh.BATCH,), pos),
            spec((sh.BATCH, None), table),
        ),
        out_specs=spec((sh.BATCH, sh.HEADS, None), q1),
        check_rep=False,
    )(q1, store, pos, table)
