"""Async streaming front door over the continuous-batching Scheduler.

``launch/serve.py`` replays offline traces; real traffic streams tokens,
disconnects mid-flight, and carries SLO tiers.  This module is that front
door, deliberately **stdlib-only** (asyncio + json): the serving stack's
dependency surface stays jax+numpy, the transport is swappable (the TCP
layer below is ~80 lines over the in-process core), and every test can
drive it without fixture servers or extra pip installs.

Three layers, all on ONE event loop (no locks — the scheduler is plain
host-side python, and the pump yields to clients between device steps):

* :class:`AsyncServer` — the in-process core.  ``submit()`` wires a
  :class:`~repro.serving.request.Request` onto the scheduler with its
  ``on_token``/``on_finish`` hooks bridged to an :class:`asyncio.Queue`;
  the :meth:`AsyncServer.run` pump drives ``Scheduler.step()`` while work
  is pending and sleeps on an event otherwise.  Client disconnect maps to
  ``Scheduler.cancel`` — slot evicted, pages decrefed/zeroed, survivors
  bit-exact (the cancellation fuzz oracle's contract).
* :class:`TokenStream` — one request's async iterator of generated token
  ids; ``cancel()`` is the disconnect path.
* :class:`ChatSession` + :meth:`AsyncServer.chat` — multi-turn sessions:
  each finished turn pins its written history's page-aligned prefix
  (``Request.keep_prefix_resident``) so the NEXT turn's prompt hits the
  sha1 prefix index and adopts the resident pages instead of
  re-prefilling them.  Closing the session unpins (and the pool drains
  back to zero — ``PageAllocator.check()`` holds throughout).

:class:`TCPFrontDoor` exposes the core over a real socket with a
newline-delimited JSON protocol (one request per connection; client EOF
mid-stream cancels server-side).  ``simulate_clients`` is the shared
harness behind the launchers' ``--server`` mode: tiered clients, a
deterministic subset of which disconnect mid-stream.
"""

from __future__ import annotations

import asyncio
import dataclasses
import itertools
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request, priority_rank
from repro.serving.scheduler import Scheduler

_DONE = object()  # TokenStream sentinel: the request left the scheduler


class TokenStream:
    """Async iterator over one request's generated token ids.

    Tokens arrive as the scheduler's batched decode steps produce them
    (the ``on_token`` hook enqueues; iteration dequeues).  When the
    request finishes, is cancelled, or is shed, iteration stops and
    :attr:`request` holds the final :class:`Request` (check
    ``.cancelled`` / ``.shed`` to tell which exit it took).
    """

    def __init__(self, server: "AsyncServer", rid: int):
        self._server = server
        self.rid = rid
        self._q: asyncio.Queue = asyncio.Queue()
        self.request: Optional[Request] = None  # set at finish/cancel

    def _push(self, tok: int) -> None:
        self._q.put_nowait(tok)

    def _close(self, req: Request) -> None:
        self.request = req
        self._q.put_nowait(_DONE)

    def __aiter__(self) -> "TokenStream":
        """Return self (async-iterator protocol)."""
        return self

    async def __anext__(self) -> int:
        """Next generated token id; stops when the request exits."""
        item = await self._q.get()
        if item is _DONE:
            raise StopAsyncIteration
        return item

    async def cancel(self) -> None:
        """Client disconnect: evict the request server-side (slot freed,
        pages decrefed — shared pages survive for their other holders)
        and close the stream.  Idempotent; a no-op after finish."""
        self._server.cancel(self.rid)
        # the on_finish hook pushed the sentinel; yield so a same-task
        # iterator observes it
        await asyncio.sleep(0)


@dataclasses.dataclass
class ChatSession:
    """One multi-turn conversation: accumulated token history plus the
    page pins keeping that history's KV resident between turns."""

    sid: str
    history: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32)
    )
    pinned: Tuple[int, ...] = ()
    turns: int = 0


class AsyncServer:
    """In-process asyncio front door over one :class:`Scheduler`.

    Run :meth:`run` as a background task; ``submit``/``chat`` from any
    coroutine on the same loop.  The pump executes one blocking device
    step at a time and yields between steps, so submissions and
    cancellations interleave at step granularity — the same boundary the
    scheduler's host-side bookkeeping already assumes.
    """

    def __init__(self, scheduler: Scheduler, check_invariants: bool = False):
        self.sched = scheduler
        # per-step PageAllocator.check() — the leak gate the server tests
        # and the --server launcher smoke run with
        self.check_invariants = check_invariants
        self._rids = itertools.count()
        self._streams: Dict[int, TokenStream] = {}
        self.sessions: Dict[str, ChatSession] = {}
        self._closed = False
        self._work = asyncio.Event()
        self.steps_pumped = 0

    # ------------------------------------------------------------------
    # submission / streaming
    # ------------------------------------------------------------------

    def submit(
        self,
        prompt,
        max_new_tokens: int,
        priority: str = "interactive",
        eos_id: Optional[int] = None,
        deadline_steps: Optional[int] = None,
        forced_tokens=None,
        session_id: Optional[str] = None,
        arrival_step: Optional[int] = None,
    ) -> TokenStream:
        """Queue one request; returns its :class:`TokenStream`.

        ``priority`` is the SLO tier (``interactive`` preempts ``batch``
        chunked prefills and jumps the admission queue);
        ``deadline_steps`` sheds the request if still queued that many
        steps after arrival.  ``session_id`` routes through
        :meth:`chat` semantics: the prompt is prepended with the
        session's history and the finished turn's pages stay pinned for
        the next turn.  ``arrival_step`` defaults to the scheduler's
        current step (live traffic); trace replays pass their own.
        """
        priority_rank(priority)  # validate at the API boundary
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        session = None
        if session_id is not None:
            session = self.sessions.setdefault(
                session_id, ChatSession(sid=session_id)
            )
            prompt = np.concatenate([session.history, prompt])
        rid = next(self._rids)
        stream = TokenStream(self, rid)
        self._streams[rid] = stream

        def on_token(req: Request, tok: int) -> None:
            stream._push(tok)

        def on_finish(req: Request) -> None:
            if session is not None and not req.cancelled:
                self._advance_session(session, req)
            self._streams.pop(rid, None)
            stream._close(req)

        req = Request(
            rid=rid,
            prompt=prompt,
            max_new_tokens=int(max_new_tokens),
            arrival_step=(self.sched.step_count if arrival_step is None
                          else int(arrival_step)),
            eos_id=eos_id,
            forced_tokens=forced_tokens,
            priority=priority,
            deadline_steps=deadline_steps,
            on_token=on_token,
            on_finish=on_finish,
            keep_prefix_resident=session is not None,
        )
        self.sched.submit(req)
        self._work.set()
        return stream

    def chat(self, session_id: str, user_tokens, max_new_tokens: int,
             **kw) -> TokenStream:
        """One conversation turn: ``user_tokens`` appended to the
        session's history becomes the prompt.  On a paged global-only
        layout, turn 2+ adopts the previous turns' pinned pages through
        the prefix index instead of re-prefilling the history."""
        return self.submit(user_tokens, max_new_tokens,
                           session_id=session_id, **kw)

    def _advance_session(self, session: ChatSession, req: Request) -> None:
        """Fold a finished turn into the session: history grows by the
        response, the new pin supersedes the old one (unpin after pin, so
        shared pages never transit refcount zero)."""
        session.history = np.concatenate([
            np.asarray(req.prompt, np.int32),
            np.asarray(req.generated, np.int32),
        ])
        old = session.pinned
        session.pinned = req.pinned_pages
        session.turns += 1
        if old:
            self.sched.unpin_pages(old)

    def cancel(self, rid: int) -> bool:
        """Evict request ``rid`` at any lifecycle state (queued /
        prefilling / decoding); returns False if it already exited."""
        return self.sched.cancel(rid)

    def close_session(self, session_id: str) -> None:
        """Drop a session's history pins; its pages (if nobody else
        shares them) are zeroed and returned to the free pool."""
        session = self.sessions.pop(session_id, None)
        if session is not None and session.pinned:
            self.sched.unpin_pages(session.pinned)

    # ------------------------------------------------------------------
    # pump / lifecycle
    # ------------------------------------------------------------------

    async def run(self) -> None:
        """Pump loop: drive ``Scheduler.step()`` while requests are
        pending, yield to clients between steps, park on an event when
        idle.  Ends after :meth:`close`."""
        while not self._closed:
            if self.sched.num_pending:
                self.sched.step()
                self.steps_pumped += 1
                if self.check_invariants and self.sched.pager is not None:
                    self.sched.pager.check()
                # step boundary: let clients submit / cancel / consume
                await asyncio.sleep(0)
            else:
                self._work.clear()
                await self._work.wait()

    async def drain(self) -> None:
        """Wait until every submitted request has exited the scheduler."""
        while self.sched.num_pending:
            await asyncio.sleep(0)

    def close(self) -> None:
        """Shut down: cancel everything still live, unpin every session,
        and stop the pump (after its current step)."""
        for rid in list(self._streams):
            self.sched.cancel(rid)
        for sid in list(self.sessions):
            self.close_session(sid)
        self._closed = True
        self._work.set()

    def stats(self) -> Dict:
        """Scheduler stats plus server-level columns."""
        out = self.sched.stats()
        out["server"] = {
            "steps_pumped": self.steps_pumped,
            "open_streams": len(self._streams),
            "open_sessions": len(self.sessions),
        }
        return out


# --------------------------------------------------------------------------
# TCP transport (newline-delimited JSON, one request per connection)
# --------------------------------------------------------------------------


class TCPFrontDoor:
    """Socket transport over an :class:`AsyncServer`.

    Protocol (newline-delimited JSON): the client sends one line ::

        {"prompt": [1, 2, 3], "max_new_tokens": 8,
         "priority": "interactive", "session": "abc"}

    and receives one ``{"token": t}`` line per generated token followed
    by ``{"done": true, "rid": r, "tokens": n, "cancelled": false}``.
    Client EOF (disconnect) before the stream ends cancels the request
    server-side — the slot is evicted and its pages are freed.
    """

    def __init__(self, server: AsyncServer, host: str = "127.0.0.1",
                 port: int = 0):
        self.server = server
        self.host = host
        self.port = port  # 0 = ephemeral; .start() fills the bound port
        self._tcp: Optional[asyncio.AbstractServer] = None

    async def start(self) -> None:
        """Bind and start accepting connections (port 0 = ephemeral)."""
        self._tcp = await asyncio.start_server(
            self._handle, self.host, self.port
        )
        self.port = self._tcp.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        """Stop accepting and close the listening socket."""
        if self._tcp is not None:
            self._tcp.close()
            await self._tcp.wait_closed()
            self._tcp = None

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            line = await reader.readline()
            if not line:
                return
            spec = json.loads(line)
            stream = self.server.submit(
                np.asarray(spec["prompt"], np.int32),
                int(spec.get("max_new_tokens", 16)),
                priority=spec.get("priority", "interactive"),
                eos_id=spec.get("eos_id"),
                deadline_steps=spec.get("deadline_steps"),
                session_id=spec.get("session"),
            )
            # the client sends nothing after the request line, so a
            # completed read() means EOF: the client hung up
            gone = asyncio.ensure_future(reader.read())
            try:
                async for tok in stream:
                    if gone.done():
                        raise ConnectionResetError
                    writer.write(json.dumps({"token": int(tok)}).encode()
                                 + b"\n")
                    await writer.drain()
                req = stream.request
                writer.write(json.dumps({
                    "done": True, "rid": stream.rid,
                    "tokens": len(req.generated) if req else 0,
                    "cancelled": bool(req.cancelled) if req else False,
                }).encode() + b"\n")
                await writer.drain()
            except (ConnectionResetError, BrokenPipeError, OSError):
                await stream.cancel()
            finally:
                gone.cancel()
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass


# --------------------------------------------------------------------------
# simulated clients (the --server launcher/benchmark harness)
# --------------------------------------------------------------------------


async def _simulated_client(server: AsyncServer, req: Request,
                            disconnect_after: Optional[int],
                            log: List[Dict]) -> None:
    """One simulated client: stream a request, optionally hang up after
    ``disconnect_after`` tokens (the mid-flight cancellation path)."""
    stream = server.submit(
        req.prompt, req.max_new_tokens, priority=req.priority,
        eos_id=req.eos_id, deadline_steps=req.deadline_steps,
        forced_tokens=req.forced_tokens, arrival_step=req.arrival_step,
    )
    got = []
    async for tok in stream:
        got.append(tok)
        if disconnect_after is not None and len(got) >= disconnect_after:
            await stream.cancel()
            break
    final = stream.request
    log.append({
        "rid": stream.rid, "priority": req.priority, "tokens": len(got),
        "disconnected": disconnect_after is not None
        and len(got) >= disconnect_after,
        "cancelled": bool(final.cancelled) if final else None,
    })


def simulate_clients(
    scheduler: Scheduler,
    requests: Sequence[Request],
    disconnect_every: int = 3,
    disconnect_after: int = 1,
    tier_cycle: Tuple[str, ...] = ("interactive", "batch"),
    check_invariants: bool = True,
) -> Dict:
    """Drive an :class:`AsyncServer` with simulated tiered, disconnecting
    clients — the ``--server`` mode of ``launch/serve.py`` and
    ``examples/serve_llm.py``.

    Every ``disconnect_every``-th client (1-based; 0 disables) hangs up
    after ``disconnect_after`` streamed tokens, exercising mid-flight
    cancellation; tiers rotate through ``tier_cycle``.  Requests keep
    their trace ``arrival_step``s (the scheduler clock gates admission).
    Returns ``server.stats()`` plus a ``clients`` log.
    """

    async def main() -> Dict:
        server = AsyncServer(scheduler, check_invariants=check_invariants)
        log: List[Dict] = []
        clients = []
        for i, req in enumerate(requests):
            req.priority = tier_cycle[i % len(tier_cycle)]
            cut = (disconnect_after if disconnect_every
                   and (i + 1) % disconnect_every == 0 else None)
            clients.append(_simulated_client(server, req, cut, log))
        pump = asyncio.ensure_future(server.run())
        await asyncio.gather(*clients)
        await server.drain()
        server.close()
        await pump
        stats = server.stats()
        stats["clients"] = sorted(log, key=lambda e: e["rid"])
        return stats

    return asyncio.run(main())
