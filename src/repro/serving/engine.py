"""Serving engine: prefill + single-token decode (``serve_step``) for every
family, with int8 KV, ring-buffered local windows, and the MCBP BGPP sparse
path.

``make_serve_step(cfg, layout, rules)`` returns the pure function the
dry-run lowers for the decode_32k / long_500k cells:

    serve_step(params, cache, tokens (B,1)) -> (logits (B,1,V), cache')

Positions are per slot: ``cache["pos"]`` is a ``(B,)`` vector, and every
decode path (RoPE, ring-buffer slots, causal/window masks, BGPP round-0
masking) indexes it per batch row, so staggered requests share one batch
(continuous batching).  ``prefill`` builds a fresh whole-batch cache;
``prefill_into_slot`` admits one prompt into a single slot of a live cache.

Decode loops over layers in python (tiny per-layer op count; heterogeneous
caches), indexing the stacked parameter pytrees with static layer ids.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import attention, bgpp as bgpp_mod, bitslice
from repro.distributed import sharding as sh
from repro.models import layers, mamba2, moe, transformer
from repro.serving import kv_cache as kvc

Tree = Dict[str, Any]
NEG_INF = attention.NEG_INF


# --------------------------------------------------------------------------
# attention decode over the cache stacks
# --------------------------------------------------------------------------


def _split_heads(x, B, H, Dh):
    return x.reshape(B, H, Dh)


def _decode_attend(
    q,  # (B, Hq, Dh)
    entry: Tree,  # cache stack slices for this layer — heads-major (B,Hk,S,D)
    valid,  # (B, S) bool
    cfg,
    fmt: str,
    head_mask=None,  # (B, Hk, S) BGPP alive sets
):
    """Decode attention over the heads-major cache.

    Heads-major layout (A1) avoids cache transposes; the int8 format runs
    the paper-faithful 8-bit QK^T (A2) and 8-bit PV (A3) as int8 MXU dots,
    so the cache is consumed directly with no dequantized copies.
    """
    B, Hq, Dh = q.shape
    Hk = cfg.num_kv_heads
    g = Hq // Hk
    scale = Dh**-0.5
    qg = q.reshape(B, Hk, g, Dh).astype(jnp.float32)

    if fmt == "bf16":
        logits = jnp.einsum(
            "bhgd,bhsd->bhgs", qg, entry["k"].astype(jnp.float32)
        ) * scale
        mask = valid[:, None, None, :]
        if head_mask is not None:
            mask = mask & head_mask[:, :, None, :]
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgs,bhsd->bhgd", probs, entry["v"].astype(jnp.float32))
        return out.reshape(B, Hq, Dh)

    # paper §2.2 formal compute, 8-bit QK^T: quantize q per (b,h,g) row and
    # run an int8×int8 MXU dot with int32 accumulation — no dequantized f32
    # copy of the key cache is ever materialized (§Perf iteration A2).
    q_scale = jnp.maximum(jnp.max(jnp.abs(qg), axis=-1, keepdims=True), 1e-8) / 127.0
    q_q = jnp.clip(jnp.round(qg / q_scale), -127, 127).astype(jnp.int8)
    logits_i = jnp.einsum(
        "bhgd,bhsd->bhgs", q_q, entry["k"], preferred_element_type=jnp.int32
    )
    logits = (
        logits_i.astype(jnp.float32)
        * q_scale
        * entry["k_scale"][:, :, None, :]
        * scale
    )
    mask = valid[:, None, None, :]
    if head_mask is not None:
        mask = mask & head_mask[:, :, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)

    # paper's 8-bit PV (§Perf iteration A3): fold the per-key v_scale into
    # the probs, quantize the weighted probs per (b,h,g) row to int8, and
    # keep V int8 in the dot (f32 accumulation on the MXU).
    w = probs * entry["v_scale"][:, :, None, :]  # (B,Hk,g,S)
    w_scale = jnp.maximum(jnp.max(w, axis=-1, keepdims=True), 1e-20) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale), 0, 127).astype(jnp.int8)
    out = jnp.einsum(
        "bhgs,bhsd->bhgd", w_q, entry["v"], preferred_element_type=jnp.float32
    )
    out = out * w_scale
    return out.reshape(B, Hq, Dh)


def _bgpp_decode_attend(q, entry, valid, cfg):
    """BGPP progressive *gather* decode (paper §3.3 + §4.5, TPU-adapted;
    §Perf iteration C1).

    Round 0 scores the magnitude MSB plane of every valid key; each later
    round fetches (gathers) the next plane for the surviving half only —
    a static-shape realization of the paper's early termination whose HBM
    traffic is the packed bytes of survivors, not the whole cache.  The
    final candidate set (k_max = keep_ratio·S) is gathered once at full
    precision and consumed by the exact int8 formal compute (A2/A3).

    entry: heads-major bgpp stack slices — k_planes (NBITS,B,Hk,S,D/8),
    k_sign/(B,Hk,S,D/8), k_scale/v_scale (B,Hk,S), v (B,Hk,S,D).
    q: (B, Hq, Dh).
    """
    mo = cfg.mcbp
    B, Hq, Dh = q.shape
    Hk = cfg.num_kv_heads
    g = Hq // Hk
    S = valid.shape[1]

    # quantize the query (paper: 4-bit MSB precompute)
    qg = q.reshape(B, Hk, g, Dh).astype(jnp.float32)
    dq = jnp.maximum(jnp.max(jnp.abs(qg), axis=-1, keepdims=True), 1e-8) / 127.0
    q_int = jnp.clip(jnp.round(qg / dq), -127, 127).astype(jnp.int32)
    q_int = bgpp_mod._truncate_query(q_int, kvc.NBITS, bgpp_mod.DEFAULT_QUERY_BITS)
    qf = q_int.astype(jnp.float32)  # (B,Hk,g,D)

    rounds = max(1, min(mo.bgpp_rounds, kvc.NBITS))
    k_max = max(1, min(S, int(math.ceil(mo.bgpp_keep_ratio * S))))

    def plane_scores(plane_bits, sign_bits, qf_):
        """signed plane contribution: (..., S', D) bits -> (B,Hk,g,S')."""
        signed = jnp.where(sign_bits.astype(bool), -1.0, 1.0) * plane_bits
        return jnp.einsum("bhgd,bhsd->bhgs", qf_, signed)

    # ---- round 0: MSB plane of every valid key ---------------------------
    p0 = kvc.NBITS - 1
    plane = bitslice.unpack_bits(entry["k_planes"][p0], axis=-1).astype(jnp.float32)
    sign = bitslice.unpack_bits(entry["k_sign"], axis=-1)
    partial = plane_scores(plane, sign, qf) * float(2**p0)  # (B,Hk,g,S)
    score_h = jnp.max(partial, axis=2)  # GQA union
    score_h = jnp.where(valid[:, None, :], score_h, NEG_INF)

    # ---- progressive rounds: halve the candidate set, gather next plane --
    # pure-gather formulation: cur_idx tracks the global ids of survivors;
    # scores/partials shrink with the set, nothing is scattered back
    cur_idx = None  # None = all S keys
    for r in range(1, rounds):
        k_r = max(k_max, S >> r)
        _, li = jax.lax.top_k(score_h, k_r)  # local ids in the current set
        cur_idx = li if cur_idx is None else jnp.take_along_axis(cur_idx, li, axis=2)
        partial = jnp.take_along_axis(partial, li[:, :, None, :], axis=3)
        take = lambda x, i=cur_idx: jnp.take_along_axis(x, i[..., None], axis=2)
        p_r = kvc.NBITS - 1 - r
        plane_g = bitslice.unpack_bits(
            take(entry["k_planes"][p_r]), axis=-1
        ).astype(jnp.float32)  # (B,Hk,k_r,D)
        sign_g = bitslice.unpack_bits(take(entry["k_sign"]), axis=-1)
        partial = partial + plane_scores(plane_g, sign_g, qf) * float(2**p_r)
        score_h = jnp.max(partial, axis=2)
        score_h = jnp.where(
            jnp.take_along_axis(
                jnp.broadcast_to(valid[:, None, :], (B, Hk, S)), cur_idx, axis=2
            ),
            score_h, NEG_INF,
        )

    # ---- formal compute on the final k_max set ----------------------------
    _, li = jax.lax.top_k(score_h, k_max)
    idx = li if cur_idx is None else jnp.take_along_axis(cur_idx, li, axis=2)
    take = lambda x: jnp.take_along_axis(x, idx[..., None], axis=2)
    planes_g = jnp.stack(
        [take(entry["k_planes"][pp]) for pp in range(kvc.NBITS)], axis=0
    )  # (NBITS,B,Hk,k,D/8)
    sign_g = take(entry["k_sign"])
    k_q = kvc.bitplanes_to_k(planes_g, sign_g).astype(jnp.int8)  # (B,Hk,k,D)
    gathered = {
        "k": k_q,
        "k_scale": jnp.take_along_axis(entry["k_scale"], idx, axis=2),
        "v": take(entry["v"]),
        "v_scale": jnp.take_along_axis(entry["v_scale"], idx, axis=2),
    }
    idx_valid = jnp.take_along_axis(
        jnp.broadcast_to(valid[:, None, :], (B, Hk, S)), idx, axis=2
    )
    # int8 formal compute with per-(b,h) candidate masks
    return _decode_attend(
        q, gathered,
        valid=jnp.ones((B, k_max), bool), cfg=cfg, fmt="int8",
        head_mask=idx_valid,
    )


# --------------------------------------------------------------------------
# per-layer decode bodies
# --------------------------------------------------------------------------


def _attn_decode_layer(p, cfg, layout, cache, x, pos, layer_idx, theta, rules):
    """x: (B, 1, D), pos: per-slot (B,) int32.  Returns (out (B,1,D), cache).

    Every batch row carries its own position: RoPE angles, the KV write
    target, and the causal/window valid mask are all computed per slot, so
    requests admitted at different times decode together in one batch.
    """
    B = x.shape[0]
    fmt = layout.kv_format
    h = layers.apply_norm(x, p["attn_norm"], cfg.norm) if "attn_norm" in p else x
    positions = pos[:, None].astype(jnp.int32)  # (B, 1)
    use_rope = cfg.family != "hybrid"
    q, k, v = layers.qkv_project(
        p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
        positions if use_rope else None, theta, qk_norm=cfg.qk_norm,
    )
    kind, w = cfg.layer_attn_window(layer_idx)
    is_local = layer_idx in layout.local_layers
    pos_c = pos[:, None]  # (B, 1) for masks against (B, S) position grids

    if is_local:
        li = layout.local_layers.index(layer_idx)
        slot = jnp.mod(pos, layout.local_window)  # (B,) per-slot ring index
        store = kvc.write_token(cache["local"], li, k, v, slot)
        store["abs_pos"] = store["abs_pos"].at[li, jnp.arange(B), slot].set(pos)
        cache["local"] = store
        abs_pos = store["abs_pos"][li]  # (B, W)
        if kind == "chunked":
            valid = (abs_pos >= 0) & (abs_pos // w == pos_c // w) & (abs_pos <= pos_c)
        else:
            valid = (abs_pos >= 0) & (pos_c - abs_pos < w) & (abs_pos <= pos_c)
        entry = {n: store[n][li] for n in store if n != "abs_pos"}
        fmt_l = "int8" if "k_scale" in store else "bf16"
        out = _decode_attend(q[:, 0], entry, valid, cfg, fmt_l)
    else:
        gi = layout.global_layers.index(layer_idx)
        cache["global"] = kvc.write_token(cache["global"], gi, k, v, pos)
        store = cache["global"]
        valid = jnp.arange(layout.max_seq)[None, :] <= pos_c  # (B, S)
        entry = {n: store[n][gi] for n in store}
        if fmt == "bgpp":
            out = _bgpp_decode_attend(q[:, 0], entry, valid, cfg)
        else:
            out = _decode_attend(q[:, 0], entry, valid, cfg, fmt)

    out = out.reshape(B, 1, -1) @ p["attn"]["wo"]
    if cfg.post_norms and "post_attn_norm" in p:
        out = layers.apply_norm(out, p["post_attn_norm"], cfg.norm)
    return out, cache


def _ffn_decode_layer(p, cfg, x, rules=None):
    h = layers.apply_norm(x, p["mlp_norm"] if "mlp_norm" in p else p["norm2"], cfg.norm)
    if "moe" in p:
        # dropless routing at decode: GShard capacity is pooled across the
        # batch dim, so capacity drops would couple co-scheduled slots — a
        # slot's logits must never depend on its batch neighbors (the
        # continuous-batching isolation invariant).  capacity_factor=E
        # clamps capacity to Tg*k exactly, and at S=1 the buffer is tiny.
        out, _ = moe.moe_apply(
            p["moe"], h, cfg, capacity_factor=float(cfg.num_experts),
            rules=rules,
        )
    else:
        out = layers.mlp_apply(p["mlp"], h, cfg.activation)
    if cfg.post_norms and "post_mlp_norm" in p:
        out = layers.apply_norm(out, p["post_mlp_norm"], cfg.norm)
    return out


def _mamba_decode_layer(p, cfg, layout, cache, x, layer_idx, rules=None):
    mi = layout.mamba_layers.index(layer_idx)
    h = layers.apply_norm(x, p["norm1"], cfg.norm)
    state = {
        "h": cache["mamba"]["h"][mi],
        "conv": cache["mamba"]["conv"][mi],
    }
    out, new_state = mamba2.mixer_decode_step(p["mamba"], cfg, h, state, rules)
    h_new = new_state["h"]
    if rules is not None:
        # pin the (B, heads, P, N) state update: the outer-product einsum
        # otherwise drops the head (model) sharding and every one of
        # jamba's 63 mamba layers materializes an unsharded ~1 GB temp
        h_new = sh.constrain(h_new, rules, (sh.BATCH, sh.FF, None, None))
    cache["mamba"]["h"] = cache["mamba"]["h"].at[mi].set(h_new)
    cache["mamba"]["conv"] = cache["mamba"]["conv"].at[mi].set(
        new_state["conv"].astype(cache["mamba"]["conv"].dtype)
    )
    return out, cache


def _sinusoid_at(pos, dim: int) -> jax.Array:
    """Per-slot sinusoidal embedding: (B,) positions -> (B, dim) (avoids a
    (max_seq, D) constant)."""
    div = jnp.exp(
        jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim)
    )
    ang = pos.astype(jnp.float32)[:, None] * div  # (B, dim/2)
    out = jnp.zeros(pos.shape + (dim,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    return out.at[..., 1::2].set(jnp.cos(ang))


# --------------------------------------------------------------------------
# serve_step builders
# --------------------------------------------------------------------------


def make_serve_step(cfg, layout: kvc.CacheLayout, rules=sh.ShardingRules()):
    dtype = layers._dtype(cfg.dtype)
    thetas = transformer.layer_thetas(cfg) if cfg.family != "ssm" else None

    def serve_step(params, cache, tokens):
        pos = cache["pos"]  # per-slot (B,) int32 positions
        B = tokens.shape[0]
        x = params["embed"][tokens[:, :1]].astype(dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
        x = sh.constrain(x, rules, (sh.BATCH, None, None))

        if cfg.family in ("dense", "moe", "vlm"):
            for i in range(cfg.num_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                a, cache = _attn_decode_layer(
                    p, cfg, layout, cache, x, pos, i, float(thetas[i]), rules
                )
                x = x + a
                x = x + _ffn_decode_layer(p, cfg, x, rules)
        elif cfg.family == "ssm":
            for i in range(cfg.num_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                m, cache = _mamba_decode_layer(
                    {"norm1": p["norm"], "mamba": p["mixer"]}, cfg, layout,
                    cache, x, i, rules,
                )
                x = x + m
        elif cfg.family == "hybrid":
            period = cfg.attn_every
            for i in range(cfg.num_layers):
                b, j = divmod(i, period)
                p = jax.tree.map(lambda a: a[b], params["blocks"][f"pos{j}"])
                if cfg.layer_is_attention(i):
                    pa = {"attn_norm": p["norm1"], "attn": p["attn"]}
                    a, cache = _attn_decode_layer(
                        pa, cfg, layout, cache, x, pos, i, cfg.rope_theta, rules
                    )
                    x = x + a
                else:
                    m, cache = _mamba_decode_layer(p, cfg, layout, cache, x, i, rules)
                    x = x + m
                x = x + _ffn_decode_layer(p, cfg, x, rules)
        elif cfg.family == "enc_dec":
            x = x + _sinusoid_at(pos, cfg.d_model).astype(dtype)[:, None, :]
            for i in range(cfg.num_layers):
                p = jax.tree.map(lambda a: a[i], params["decoder"])
                pa = {"attn_norm": p["norm1"], "attn": p["attn"]}
                a, cache = _attn_decode_layer(
                    pa, cfg, layout, cache, x, pos, i, cfg.rope_theta, rules
                )
                x = x + a
                # cross attention over the (precomputed) encoder memory
                h = layers.apply_norm(x, p["norm_x"], cfg.norm)
                q = (h @ p["xattn"]["wq"]).reshape(
                    B, cfg.num_heads, cfg.head_dim
                )
                out = _decode_attend(
                    q,
                    {"k": cache["cross_k"][i], "v": cache["cross_v"][i]},
                    jnp.ones((B, cfg.encoder_seq), bool),
                    cfg,
                    "bf16",
                )
                x = x + out.reshape(B, 1, -1) @ p["xattn"]["wo"]
                h = layers.apply_norm(x, p["norm2"], cfg.norm)
                x = x + layers.mlp_apply(p["mlp"], h, "gelu")
        else:
            raise ValueError(cfg.family)

        x = layers.apply_norm(x, params["final_norm"], cfg.norm)
        head = params.get("lm_head")
        logits = x @ (head if head is not None else params["embed"].T.astype(dtype))
        logits = sh.constrain(logits, rules, (sh.BATCH, None, sh.VOCAB))
        cache["pos"] = pos + 1
        return logits, cache

    return serve_step


# --------------------------------------------------------------------------
# prefill (build the cache from a prompt) — transformer families
# --------------------------------------------------------------------------


def prefill(params, cfg, layout: kvc.CacheLayout, tokens, rules=sh.ShardingRules(),
            **fw_kw):
    """Runs the forward pass, returning (last_logits, populated cache).

    Transformer families only (mamba/hybrid prefill state capture is in the
    per-family paths of the examples); decode cells of the dry-run take the
    cache as an *input spec*, so this is the serving-path utility.
    """
    assert cfg.family in ("dense", "moe", "vlm")
    logits, _, kvs = transformer.forward(
        params, cfg, tokens, rules, return_kv=True, **fw_kw
    )
    k_all, v_all = kvs  # (L, B, S, Hk, Dh)
    cache, _ = kvc.init_cache(cfg, layout)
    B, S = tokens.shape

    for gi, layer in enumerate(layout.global_layers):
        cache["global"] = kvc.write_prefill(
            cache["global"], gi, k_all[layer], v_all[layer]
        )
    for li, layer in enumerate(layout.local_layers):
        cache["local"] = kvc.write_prefill_local(
            cache["local"], li, k_all[layer], v_all[layer], layout.local_window
        )

    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits[:, -1:], cache


def prefill_into_slot(params, cfg, layout: kvc.CacheLayout, cache, slot: int,
                      prompt, rules=sh.ShardingRules(), **fw_kw):
    """Prefill ONE prompt into batch row ``slot`` of a *live* cache.

    This is the admission path of the continuous-batching scheduler: the
    forward pass runs at B=1, the slot is reset (stale KV, ring positions,
    mamba state), and the prompt's quantized/bit-planed KV is written into
    that single batch index without touching live neighbors.  Returns
    ``(last_logits (1, 1, V), cache)`` — the logits sample the request's
    first token.

    prompt: (S,) or (1, S) int32 tokens, S < layout.max_seq (a prompt that
    fills the cache leaves no index for the first decoded token's KV —
    out-of-bounds scatters drop silently, corrupting logits).

    Admission runs eagerly: reset + per-layer writes each copy the stacked
    store, so a production-size cache wants this jitted with the cache
    donated (needs prompt-length bucketing to bound recompiles — planned
    alongside the paged cache).
    """
    assert cfg.family in ("dense", "moe", "vlm")
    tokens = prompt[None] if prompt.ndim == 1 else prompt
    assert tokens.shape[0] == 1, "one prompt per admission"
    S = tokens.shape[1]
    assert S < layout.max_seq, (
        f"prompt len {S} needs at least one decode slot below max_seq "
        f"{layout.max_seq}"
    )
    logits, _, (k_all, v_all) = transformer.forward(
        params, cfg, tokens, rules, return_kv=True, **fw_kw
    )
    cache = kvc.reset_slot(cache, layout, slot)
    for gi, layer in enumerate(layout.global_layers):
        cache["global"] = kvc.write_prefill(
            cache["global"], gi, k_all[layer], v_all[layer], slot=slot
        )
    for li, layer in enumerate(layout.local_layers):
        cache["local"] = kvc.write_prefill_local(
            cache["local"], li, k_all[layer], v_all[layer],
            layout.local_window, slot=slot,
        )
    cache["pos"] = cache["pos"].at[slot].set(S)
    return logits[:, -1:], cache
