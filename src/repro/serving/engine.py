"""Serving engine: prefill + single-token decode (``serve_step``) for every
family, with int8 KV, ring-buffered local windows, and the MCBP BGPP sparse
path.

``make_serve_step(cfg, layout, rules)`` returns the pure function the
dry-run lowers for the decode_32k / long_500k cells:

    serve_step(params, cache, tokens (B,1)) -> (logits (B,1,V), cache')

Positions are per slot: ``cache["pos"]`` is a ``(B,)`` vector, and every
decode path (RoPE, ring-buffer slots, causal/window masks, BGPP round-0
masking) indexes it per batch row, so staggered requests share one batch
(continuous batching).  ``prefill`` builds a fresh whole-batch cache;
``prefill_into_slot`` admits one prompt into a single slot of a live cache.

Decode loops over layers in python (tiny per-layer op count; heterogeneous
caches), indexing the stacked parameter pytrees with static layer ids.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention, bgpp as bgpp_mod, bitslice
from repro.distributed import sharding as sh
from repro.models import layers, mamba2, moe, transformer
from repro.serving import kernel_decode, kv_cache as kvc, weights as swt

Tree = Dict[str, Any]
NEG_INF = attention.NEG_INF


# --------------------------------------------------------------------------
# attention decode over the cache stacks
# --------------------------------------------------------------------------


def _split_heads(x, B, H, Dh):
    return x.reshape(B, H, Dh)


def _cache_attend(
    q,  # (B, Q, Hq, Dh) — Q query tokens per batch row
    entry: Tree,  # cache stack slices for this layer — heads-major (B,Hk,S,D)
    valid,  # (B, Q, S) bool per-query key masks
    cfg,
    fmt: str,
    head_mask=None,  # (B, Hk, S) BGPP alive sets
):
    """Attention over the heads-major cache for Q query tokens per row.

    Heads-major layout (A1) avoids cache transposes; the int8 format runs
    the paper-faithful 8-bit QK^T (A2) and 8-bit PV (A3) as int8 MXU dots,
    so the cache is consumed directly with no dequantized copies.  Decode
    calls it with Q=1; chunked prefill with Q=chunk — the key axis is the
    full ``S`` stack either way, so per-query reductions are shape-stable
    (the chunked-admission bit-exactness contract).  Returns f32
    ``(B, Q, Hq, Dh)``.
    """
    B, Q, Hq, Dh = q.shape
    # GQA group size from the config RATIO, head count from the operand:
    # under shard_map (the paged BGPP decode's "model" routing) q carries
    # only this device's head shard, and the ratio is shard-invariant
    if cfg.num_heads % cfg.num_kv_heads:
        raise ValueError(
            f"_cache_attend: num_heads={cfg.num_heads} not a multiple of "
            f"num_kv_heads={cfg.num_kv_heads} — GQA grouping needs an "
            f"integral ratio"
        )
    g = cfg.num_heads // cfg.num_kv_heads
    if Hq % g:
        raise ValueError(
            f"_cache_attend: operand carries Hq={Hq} heads, not a multiple "
            f"of the GQA group size g={g} — a head shard must keep whole "
            f"(kv-head, group) blocks together"
        )
    Hk = Hq // g
    scale = Dh**-0.5
    qg = q.reshape(B, Q, Hk, g, Dh).transpose(0, 2, 3, 1, 4).astype(jnp.float32)

    mask = valid[:, None, None]  # (B, 1, 1, Q, S)
    if head_mask is not None:
        mask = mask & head_mask[:, :, None, None, :]

    if fmt == "bf16":
        logits = jnp.einsum(
            "bhgqd,bhsd->bhgqs", qg, entry["k"].astype(jnp.float32)
        ) * scale
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqs,bhsd->bhgqd", probs, entry["v"].astype(jnp.float32))
        return out.transpose(0, 3, 1, 2, 4).reshape(B, Q, Hq, Dh)

    # paper §2.2 formal compute, 8-bit QK^T: quantize q per (b,h,g,q) row
    # and run an int8×int8 MXU dot with int32 accumulation — no dequantized
    # f32 copy of the key cache is ever materialized (§Perf iteration A2).
    q_scale = jnp.maximum(jnp.max(jnp.abs(qg), axis=-1, keepdims=True), 1e-8) / 127.0
    q_q = jnp.clip(jnp.round(qg / q_scale), -127, 127).astype(jnp.int8)
    logits_i = jnp.einsum(
        "bhgqd,bhsd->bhgqs", q_q, entry["k"], preferred_element_type=jnp.int32
    )
    logits = (
        logits_i.astype(jnp.float32)
        * q_scale
        * entry["k_scale"][:, :, None, None, :]
        * scale
    )
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)

    # paper's 8-bit PV (§Perf iteration A3): fold the per-key v_scale into
    # the probs, quantize the weighted probs per (b,h,g,q) row to int8, and
    # keep V int8 in the dot (f32 accumulation on the MXU).
    w = probs * entry["v_scale"][:, :, None, None, :]  # (B,Hk,g,Q,S)
    w_scale = jnp.maximum(jnp.max(w, axis=-1, keepdims=True), 1e-20) / 127.0
    w_q = jnp.clip(jnp.round(w / w_scale), 0, 127).astype(jnp.int8)
    out = jnp.einsum(
        "bhgqs,bhsd->bhgqd", w_q, entry["v"], preferred_element_type=jnp.float32
    )
    out = out * w_scale
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Q, Hq, Dh)


def _decode_attend(q, entry, valid, cfg, fmt, head_mask=None):
    """Single-token wrapper: q (B, Hq, Dh), valid (B, S) -> (B, Hq, Dh)."""
    return _cache_attend(
        q[:, None], entry, valid[:, None], cfg, fmt, head_mask=head_mask
    )[:, 0]


def _bgpp_quant_query(q, cfg):
    """Quantize + MSB-truncate the decode query for bit-plane scoring.

    q ``(B, Hq, Dh)`` -> f32 ``(B, Hk, g, Dh)`` (paper: 4-bit MSB query
    precompute, shared by the slot and paged BGPP decode paths).
    """
    B, Hq, Dh = q.shape
    # ratio from the config, count from the operand (shard_map-local safe)
    if cfg.num_heads % cfg.num_kv_heads:
        raise ValueError(
            f"_bgpp_quant_query: num_heads={cfg.num_heads} not a multiple "
            f"of num_kv_heads={cfg.num_kv_heads} — GQA grouping needs an "
            f"integral ratio"
        )
    g = cfg.num_heads // cfg.num_kv_heads
    if Hq % g:
        raise ValueError(
            f"_bgpp_quant_query: operand carries Hq={Hq} heads, not a "
            f"multiple of the GQA group size g={g} — a head shard must "
            f"keep whole (kv-head, group) blocks together"
        )
    Hk = Hq // g
    qg = q.reshape(B, Hk, g, Dh).astype(jnp.float32)
    dq = jnp.maximum(jnp.max(jnp.abs(qg), axis=-1, keepdims=True), 1e-8) / 127.0
    q_int = jnp.clip(jnp.round(qg / dq), -127, 127).astype(jnp.int32)
    q_int = bgpp_mod._truncate_query(q_int, kvc.NBITS, bgpp_mod.DEFAULT_QUERY_BITS)
    return q_int.astype(jnp.float32)  # (B,Hk,g,D)


def _bgpp_topk_indices(qf, plane0, sign_full, plane_at, valid, cfg):
    """Progressive MSB-first top-k prediction (paper §3.3 early termination)
    — phase 1 of BGPP decode, shared by the slot and paged layouts.

    Round 0 scores the magnitude MSB plane of every valid key; each later
    round fetches the next plane for the surviving half only — a
    static-shape realization of the paper's early termination whose HBM
    traffic is the packed bytes of survivors, not the whole cache.

    qf: ``(B, Hk, g, D)`` quantized query (:func:`_bgpp_quant_query`);
    plane0: ``(B, Hk, S, D/8)`` packed MSB plane of EVERY key; sign_full:
    ``(B, Hk, S, D/8)``; ``plane_at(p, idx)``: packed plane ``p`` at
    logical indices ``(B, Hk, k)`` -> ``(B, Hk, k, D/8)`` — the slot
    layout takes from its dense row, the paged layout gathers survivor
    pool rows directly, and both return identical VALUES, which is what
    keeps the selected sets (and hence the final logits) identical across
    layouts.

    Returns ``(idx (B, Hk, k_max) logical ids, idx_valid (B, Hk, k_max))``
    with ``k_max = ceil(bgpp_keep_ratio * S)``.
    """
    B, Hk, g, Dh = qf.shape
    S = valid.shape[1]
    # the plan IS the accounting: the same tuple prices decode_read_bytes
    rounds, k_max, survivors = kvc.bgpp_decode_plan(S, cfg)

    def plane_scores(plane_bits, sign_bits, qf_):
        """signed plane contribution: (..., S', D) bits -> (B,Hk,g,S')."""
        signed = jnp.where(sign_bits.astype(bool), -1.0, 1.0) * plane_bits
        return jnp.einsum("bhgd,bhsd->bhgs", qf_, signed)

    # ---- round 0: MSB plane of every valid key ---------------------------
    p0 = kvc.NBITS - 1
    plane = bitslice.unpack_bits(plane0, axis=-1).astype(jnp.float32)
    sign = bitslice.unpack_bits(sign_full, axis=-1)
    partial = plane_scores(plane, sign, qf) * float(2**p0)  # (B,Hk,g,S)
    score_h = jnp.max(partial, axis=2)  # GQA union
    score_h = jnp.where(valid[:, None, :], score_h, NEG_INF)

    # ---- progressive rounds: halve the candidate set, gather next plane --
    # pure-gather formulation: cur_idx tracks the global ids of survivors;
    # scores/partials shrink with the set, nothing is scattered back
    cur_idx = None  # None = all S keys
    for r in range(1, rounds):
        k_r = survivors[r]
        _, li = jax.lax.top_k(score_h, k_r)  # local ids in the current set
        cur_idx = li if cur_idx is None else jnp.take_along_axis(cur_idx, li, axis=2)
        partial = jnp.take_along_axis(partial, li[:, :, None, :], axis=3)
        p_r = kvc.NBITS - 1 - r
        plane_g = bitslice.unpack_bits(
            plane_at(p_r, cur_idx), axis=-1
        ).astype(jnp.float32)  # (B,Hk,k_r,D)
        sign_g = bitslice.unpack_bits(
            jnp.take_along_axis(sign_full, cur_idx[..., None], axis=2), axis=-1
        )
        partial = partial + plane_scores(plane_g, sign_g, qf) * float(2**p_r)
        score_h = jnp.max(partial, axis=2)
        score_h = jnp.where(
            jnp.take_along_axis(
                jnp.broadcast_to(valid[:, None, :], (B, Hk, S)), cur_idx, axis=2
            ),
            score_h, NEG_INF,
        )

    # ---- the final k_max candidate set -----------------------------------
    _, li = jax.lax.top_k(score_h, k_max)
    idx = li if cur_idx is None else jnp.take_along_axis(cur_idx, li, axis=2)
    idx_valid = jnp.take_along_axis(
        jnp.broadcast_to(valid[:, None, :], (B, Hk, S)), idx, axis=2
    )
    return idx, idx_valid


def _bgpp_formal_attend(q, gathered, idx_valid, cfg):
    """Phase 2 of BGPP decode: exact int8 formal compute (A2/A3) over the
    compacted candidate set.

    gathered: ``{k_planes (NBITS, B, Hk, k, D/8), k_sign (B, Hk, k, D/8),
    k_scale (B, Hk, k), v (B, Hk, k, D), v_scale (B, Hk, k)}`` — the
    surviving tokens' full-precision rows, from either layout's gather.
    ``idx_valid`` masks candidate lanes that top-k filled from invalid
    cache positions (their gathered values are garbage, but NEG_INF logits
    zero their probability mass exactly, so they cannot leak into the
    output).
    """
    B = q.shape[0]
    k_max = idx_valid.shape[-1]
    k_q = kvc.bitplanes_to_k(
        gathered["k_planes"], gathered["k_sign"]
    ).astype(jnp.int8)  # (B,Hk,k,D)
    entry = {
        "k": k_q,
        "k_scale": gathered["k_scale"],
        "v": gathered["v"],
        "v_scale": gathered["v_scale"],
    }
    # int8 formal compute with per-(b,h) candidate masks
    return _decode_attend(
        q, entry,
        valid=jnp.ones((B, k_max), bool), cfg=cfg, fmt="int8",
        head_mask=idx_valid,
    )


def _bgpp_decode_attend(q, entry, valid, cfg):
    """BGPP progressive decode over a FULL heads-major entry (paper §3.3 +
    §4.5, TPU-adapted; §Perf iteration C1) — the slot-layout path and the
    reference the two-phase paged path is tested bit-identical against.

    The final candidate set (k_max = keep_ratio·S) is gathered once at
    full precision and consumed by the exact int8 formal compute (A2/A3).

    entry: heads-major bgpp stack slices — k_planes (NBITS,B,Hk,S,D/8),
    k_sign/(B,Hk,S,D/8), k_scale/v_scale (B,Hk,S), v (B,Hk,S,D).
    q: (B, Hq, Dh).
    """
    qf = _bgpp_quant_query(q, cfg)
    idx, idx_valid = _bgpp_topk_indices(
        qf, entry["k_planes"][kvc.NBITS - 1], entry["k_sign"],
        lambda p, i: jnp.take_along_axis(
            entry["k_planes"][p], i[..., None], axis=2
        ),
        valid, cfg,
    )
    take = lambda x: jnp.take_along_axis(x, idx[..., None], axis=2)
    gathered = {
        "k_planes": jnp.stack(
            [take(entry["k_planes"][pp]) for pp in range(kvc.NBITS)], axis=0
        ),  # (NBITS,B,Hk,k,D/8)
        "k_sign": take(entry["k_sign"]),
        "k_scale": jnp.take_along_axis(entry["k_scale"], idx, axis=2),
        "v": take(entry["v"]),
        "v_scale": jnp.take_along_axis(entry["v_scale"], idx, axis=2),
    }
    return _bgpp_formal_attend(q, gathered, idx_valid, cfg)


def _bgpp_paged_decode_attend(q, store, gi, phys, valid, cfg):
    """Two-phase BGPP decode on the paged pool — the access-reduced path.

    Unlike every other paged attend, this never materializes the slot's
    full row (:func:`repro.serving.kv_cache.paged_entry`): phase 1 gathers
    only the cheap bit-slice planes — the MSB magnitude plane and the sign
    plane at full width, then one further plane per progressive round for
    the surviving candidates only — and runs the shared top-k prediction;
    phase 2 translates the surviving logical indices through the page
    table and gathers ONLY those ``ceil(keep_ratio·S)`` tokens'
    full-precision rows into a compacted ``(B, Hk, K, ...)`` buffer for
    the exact int8 formal compute.  Selection sees the same plane values
    as the full-entry path, so the logits are bit-identical to
    :func:`_bgpp_decode_attend` on the gathered view
    (tests/test_bgpp_gather.py) — the reads shrink, the math doesn't.
    """
    qf = _bgpp_quant_query(q, cfg)
    idx, idx_valid = _bgpp_topk_indices(
        qf,
        kvc.paged_plane(store, gi, kvc.NBITS - 1, phys),
        kvc.paged_sign(store, gi, phys),
        lambda p, i: kvc.paged_plane_rows(
            store, gi, p, kvc.paged_rows_at(phys, i)
        ),
        valid, cfg,
    )
    gathered = kvc.paged_topk_entry(store, gi, kvc.paged_rows_at(phys, idx))
    # materialize the compacted survivor rows before the formal compute so
    # the pool gather can't fuse into the attend (sharding-stable lowering,
    # same reasoning as the dense paged_entry barrier in the decode layer)
    gathered = jax.lax.optimization_barrier(gathered)
    return _bgpp_formal_attend(q, gathered, idx_valid, cfg)


def _bgpp_paged_decode_attend_sharded(q, store, gi, phys, valid, cfg, layout,
                                      rules):
    """Route the two-phase paged BGPP decode device-local per head shard.

    Left to GSPMD, the progressive plane gathers and ``top_k`` selections
    of phase 1 get partitioned by REPLICATING the head axis — all-gathers
    of the plane pools across ``"model"`` on every round, exactly the
    cross-shard traffic the two-phase split exists to avoid.  With a mesh
    attached this wraps the whole attend in ``shard_map``: each device runs
    phase 1 + top-k + the phase-2 survivor gather on its own head shard of
    the pool (batch likewise over ``"data"``), introducing no collective at
    all — the head outputs rejoin at the decode layer's attend-reduction
    all-gather like every other format.  tests/test_multidevice.py pins
    this structurally (no collective in the compiled body).

    Falls back to the plain call when there is no mesh, the model axis is
    trivial, or the head counts don't divide it (the same divisibility
    fallback the cache placement applies — the pool is then replicated and
    there is nothing to keep local).
    """
    mesh = getattr(rules, "mesh", None)
    run = lambda q_, store_, phys_, valid_: _bgpp_paged_decode_attend(
        q_, store_, gi, phys_, valid_, cfg
    )
    if mesh is None:
        return run(q, store, phys, valid)
    m = dict(mesh.shape).get(rules.model_axis, 1)
    if m <= 1 or cfg.num_kv_heads % m or cfg.num_heads % m:
        return run(q, store, phys, valid)
    from jax.experimental.shard_map import shard_map

    spec = lambda axes, x: rules.spec_for_shape(mesh, axes, x.shape)
    store_spec = jax.tree.map(
        lambda axes, x: spec(tuple(axes), x),
        kvc.cache_specs(cfg, layout)["global"], store,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return shard_map(
        run, mesh=mesh,
        in_specs=(
            spec((sh.BATCH, sh.HEADS, None), q),
            store_spec,
            spec((sh.BATCH, None), phys),
            spec((sh.BATCH, None), valid),
        ),
        out_specs=spec((sh.BATCH, sh.HEADS, None), q),
        check_rep=False,
    )(q, store, phys, valid)


# --------------------------------------------------------------------------
# per-layer decode bodies
# --------------------------------------------------------------------------


def _paged_kw(layout):
    return dict(page_size=layout.page_size, max_seq=layout.max_seq)


def _attn_decode_layer(p, cfg, layout, cache, x, pos, layer_idx, theta, rules,
                       phys=None, decode_mode="jnp"):
    """x: (B, 1, D), pos: per-slot (B,) int32.  Returns (out (B,1,D), cache).

    Every batch row carries its own position: RoPE angles, the KV write
    target, and the causal/window valid mask are all computed per slot, so
    requests admitted at different times decode together in one batch.

    ``phys`` (paged layouts): the precomputed ``(B, S_max)`` logical->pool
    gather map — global writes translate through the page table and the
    attend consumes the gathered heads-major view, which holds exactly the
    slot layout's values (bit-identical decode).

    ``decode_mode`` (resolved once at :func:`make_serve_step` build time by
    :mod:`repro.serving.kernel_decode`): ``"jnp"`` keeps the legacy engine
    attends; ``"interpret"``/``"compiled"`` route the GLOBAL-layer decode
    attend through the Pallas paged-attention kernel families (local ring
    windows and cross-attention stay jnp — their ring/memory layouts are
    not paged).  The kernel call may decline (mesh the heads don't divide),
    in which case the jnp path below runs unchanged.
    """
    B = x.shape[0]
    fmt = layout.kv_format
    h = layers.apply_norm(x, p["attn_norm"], cfg.norm) if "attn_norm" in p else x
    positions = pos[:, None].astype(jnp.int32)  # (B, 1)
    use_rope = cfg.family != "hybrid"
    q, k, v = layers.qkv_project(
        p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
        positions if use_rope else None, theta, qk_norm=cfg.qk_norm,
    )
    # heads-parallel decode: q/k/v shard over "model" so the cache write
    # and the whole attend stay device-local per head shard (no-op off-mesh)
    q = sh.constrain(q, rules, (sh.BATCH, None, sh.HEADS, None))
    k = sh.constrain(k, rules, (sh.BATCH, None, sh.KV_HEADS, None))
    v = sh.constrain(v, rules, (sh.BATCH, None, sh.KV_HEADS, None))
    kind, w = cfg.layer_attn_window(layer_idx)
    is_local = layer_idx in layout.local_layers
    pos_c = pos[:, None]  # (B, 1) for masks against (B, S) position grids

    if is_local:
        li = layout.local_layers.index(layer_idx)
        slot = jnp.mod(pos, layout.local_window)  # (B,) per-slot ring index
        store = kvc.write_token(cache["local"], li, k, v, slot)
        store["abs_pos"] = store["abs_pos"].at[li, jnp.arange(B), slot].set(pos)
        cache["local"] = store
        abs_pos = store["abs_pos"][li]  # (B, W)
        if kind == "chunked":
            valid = (abs_pos >= 0) & (abs_pos // w == pos_c // w) & (abs_pos <= pos_c)
        else:
            valid = (abs_pos >= 0) & (pos_c - abs_pos < w) & (abs_pos <= pos_c)
        entry = {n: store[n][li] for n in store if n != "abs_pos"}
        fmt_l = "int8" if "k_scale" in store else "bf16"
        out = _decode_attend(q[:, 0], entry, valid, cfg, fmt_l)
    else:
        gi = layout.global_layers.index(layer_idx)
        valid = jnp.arange(layout.max_seq)[None, :] <= pos_c  # (B, S)
        if layout.layout == "paged":
            cache["global"] = kvc.write_token(
                cache["global"], gi, k, v, pos,
                page_table=cache["page_table"], **_paged_kw(layout),
            )
            out = None
            if decode_mode != "jnp":
                out = kernel_decode.decode_attend(
                    q[:, 0], cache["global"], gi, pos, cfg, layout, rules,
                    decode_mode, phys=phys, page_table=cache["page_table"],
                )
            if out is None and fmt == "bgpp":
                # two-phase attend: bit-planes first, then only the top-k
                # survivors' full rows — never the whole paged row; on a
                # mesh the whole thing runs shard_map'd per head shard
                out = _bgpp_paged_decode_attend_sharded(
                    q[:, 0], cache["global"], gi, phys, valid, cfg,
                    layout, rules,
                )
            elif out is None:
                entry = kvc.paged_entry(cache["global"], gi, phys)
                # pin the gathered view as a materialization point: without
                # it XLA fuses the page gather INTO the attend, and the
                # fused lowering's float reduction order shifts once any
                # program input is sharded — the barrier keeps sharded and
                # single-device decode bit-identical (sharding-parity fuzz)
                entry = jax.lax.optimization_barrier(entry)
                out = _decode_attend(q[:, 0], entry, valid, cfg, fmt)
        else:
            cache["global"] = kvc.write_token(cache["global"], gi, k, v, pos)
            out = None
            if decode_mode != "jnp":
                out = kernel_decode.decode_attend(
                    q[:, 0], cache["global"], gi, pos, cfg, layout, rules,
                    decode_mode,
                )
            if out is None:
                store = cache["global"]
                entry = {n: store[n][gi] for n in store}
                if fmt == "bgpp":
                    out = _bgpp_decode_attend(q[:, 0], entry, valid, cfg)
                else:
                    out = _decode_attend(q[:, 0], entry, valid, cfg, fmt)

    # the attend reduction's ONLY collective: all-gather the per-head f32
    # outputs across "model" before the replicated wo contraction.  Pure
    # data movement (no psum splits a float reduction), so sharded decode
    # stays bit-exact vs single-device — this is the priced interconnect
    # term in kv_cache._interconnect_decode.
    out = sh.constrain(out.reshape(B, 1, -1), rules, (sh.BATCH, None, None))
    out = layers.wdot(out, p["attn"]["wo"])
    if cfg.post_norms and "post_attn_norm" in p:
        out = layers.apply_norm(out, p["post_attn_norm"], cfg.norm)
    return out, cache


def _ffn_decode_layer(p, cfg, x, rules=None):
    h = layers.apply_norm(x, p["mlp_norm"] if "mlp_norm" in p else p["norm2"], cfg.norm)
    if "moe" in p:
        # dropless routing at decode: GShard capacity is pooled across the
        # batch dim, so capacity drops would couple co-scheduled slots — a
        # slot's logits must never depend on its batch neighbors (the
        # continuous-batching isolation invariant).  capacity_factor=E
        # clamps capacity to Tg*k exactly, and at S=1 the buffer is tiny.
        out, _ = moe.moe_apply(
            p["moe"], h, cfg, capacity_factor=float(cfg.num_experts),
            rules=rules,
        )
    else:
        out = layers.mlp_apply(p["mlp"], h, cfg.activation)
    if cfg.post_norms and "post_mlp_norm" in p:
        out = layers.apply_norm(out, p["post_mlp_norm"], cfg.norm)
    return out


def _mamba_decode_layer(p, cfg, layout, cache, x, layer_idx, rules=None):
    mi = layout.mamba_layers.index(layer_idx)
    h = layers.apply_norm(x, p["norm1"], cfg.norm)
    state = {
        "h": cache["mamba"]["h"][mi],
        "conv": cache["mamba"]["conv"][mi],
    }
    out, new_state = mamba2.mixer_decode_step(p["mamba"], cfg, h, state, rules)
    h_new = new_state["h"]
    if rules is not None:
        # pin the (B, heads, P, N) state update: the outer-product einsum
        # otherwise drops the head (model) sharding and every one of
        # jamba's 63 mamba layers materializes an unsharded ~1 GB temp
        h_new = sh.constrain(h_new, rules, (sh.BATCH, sh.FF, None, None))
    cache["mamba"]["h"] = cache["mamba"]["h"].at[mi].set(h_new)
    cache["mamba"]["conv"] = cache["mamba"]["conv"].at[mi].set(
        new_state["conv"].astype(cache["mamba"]["conv"].dtype)
    )
    return out, cache


def _sinusoid_at(pos, dim: int) -> jax.Array:
    """Per-slot sinusoidal embedding: (B,) positions -> (B, dim) (avoids a
    (max_seq, D) constant)."""
    div = jnp.exp(
        jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim)
    )
    ang = pos.astype(jnp.float32)[:, None] * div  # (B, dim/2)
    out = jnp.zeros(pos.shape + (dim,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    return out.at[..., 1::2].set(jnp.cos(ang))


# --------------------------------------------------------------------------
# serve_step builders
# --------------------------------------------------------------------------


def make_serve_step(cfg, layout: kvc.CacheLayout, rules=sh.ShardingRules()):
    """Build the pure batched decode step for one (cfg, layout, rules):

        serve_step(params, cache, tokens (B, 1)) -> (logits (B, 1, V), cache')

    One call decodes ONE token for every batch slot at its own
    ``cache["pos"]``; the scheduler jits it once and drives it for every
    live mix of staggered requests.  Paged layouts hoist one
    logical->pool gather map (:func:`repro.serving.kv_cache.phys_table`)
    per step; ``kv_format="bgpp"`` global layers then attend two-phase —
    bit-plane prediction first, full-precision gather only for the
    surviving top-k (:func:`_bgpp_paged_decode_attend`).

    Rollback contract (speculative decoding relies on this): the step is
    write-then-attend with per-slot validity masks (``arange <= pos``) and
    out-of-range scatter indices dropping, so a position's contents are
    only ever observed in a step that has ALREADY rewritten them from the
    fed token.  Rewinding ``cache["pos"]`` after speculative steps is
    therefore sufficient to un-happen them on slot layouts — global
    layers only; sliding-window rings physically overwrite window lanes,
    which is why ``spec_decode`` refuses local-layer stacks — and paged
    layouts additionally rewind the page allocator so freed pages can't
    service a later prefix hit (``PageAllocator.rewind_slot``).
    """
    dtype = layers._dtype(cfg.dtype)
    thetas = transformer.layer_thetas(cfg) if cfg.family != "ssm" else None
    cspecs = kvc.cache_specs(cfg, layout)
    # decode_kernel knob, resolved ONCE per built step (env > config >
    # backend): "jnp" keeps every legacy path bit-for-bit; kernel modes
    # route global-layer decode attends through repro.kernels families
    decode_mode = kernel_decode.resolve(cfg)
    if decode_mode != "jnp" and layout.global_layers:
        kernel_decode.validate(cfg, layout)
    # weight_format knob, resolved ONCE per built step exactly like
    # decode_kernel (env > config): "bf16" leaves every contraction
    # byte-for-byte the raw-leaf path; int8/bstc require the quantized
    # records weights.prepare_serve_params builds (the scheduler feeds
    # them) and layers.wdot dequantizes at trace time
    weight_format = swt.resolve(cfg)
    if weight_format != "bf16":
        swt.validate(cfg)

    def serve_step(params, cache, tokens):
        """One batched decode token for every slot at its own position."""
        if weight_format != "bf16":
            swt.check_serve_params(params, cfg, weight_format)
        pos = cache["pos"]  # per-slot (B,) int32 positions
        B = tokens.shape[0]
        # paged: one logical->pool gather map serves every global layer
        phys = kvc.phys_table(
            cache["page_table"], layout.page_size, layout.max_seq
        ) if layout.layout == "paged" and layout.global_layers else None
        if phys is not None:
            # the table is replicated; batch-shard the derived gather map so
            # paged reads split over "data" like slot stacks do
            phys = sh.constrain(phys, rules, (sh.BATCH, None))
        x = params["embed"][tokens[:, :1]].astype(dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
        x = sh.constrain(x, rules, (sh.BATCH, None, None))

        if cfg.family in ("dense", "moe", "vlm"):
            for i in range(cfg.num_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                a, cache = _attn_decode_layer(
                    p, cfg, layout, cache, x, pos, i, float(thetas[i]), rules,
                    phys=phys, decode_mode=decode_mode,
                )
                x = x + a
                x = x + _ffn_decode_layer(p, cfg, x, rules)
        elif cfg.family == "ssm":
            for i in range(cfg.num_layers):
                p = jax.tree.map(lambda a: a[i], params["layers"])
                m, cache = _mamba_decode_layer(
                    {"norm1": p["norm"], "mamba": p["mixer"]}, cfg, layout,
                    cache, x, i, rules,
                )
                x = x + m
        elif cfg.family == "hybrid":
            period = cfg.attn_every
            for i in range(cfg.num_layers):
                b, j = divmod(i, period)
                p = jax.tree.map(lambda a: a[b], params["blocks"][f"pos{j}"])
                if cfg.layer_is_attention(i):
                    pa = {"attn_norm": p["norm1"], "attn": p["attn"]}
                    a, cache = _attn_decode_layer(
                        pa, cfg, layout, cache, x, pos, i, cfg.rope_theta,
                        rules, phys=phys, decode_mode=decode_mode,
                    )
                    x = x + a
                else:
                    m, cache = _mamba_decode_layer(p, cfg, layout, cache, x, i, rules)
                    x = x + m
                x = x + _ffn_decode_layer(p, cfg, x, rules)
        elif cfg.family == "enc_dec":
            x = x + _sinusoid_at(pos, cfg.d_model).astype(dtype)[:, None, :]
            for i in range(cfg.num_layers):
                p = jax.tree.map(lambda a: a[i], params["decoder"])
                pa = {"attn_norm": p["norm1"], "attn": p["attn"]}
                a, cache = _attn_decode_layer(
                    pa, cfg, layout, cache, x, pos, i, cfg.rope_theta, rules,
                    phys=phys, decode_mode=decode_mode,
                )
                x = x + a
                # cross attention over the (precomputed) encoder memory
                h = layers.apply_norm(x, p["norm_x"], cfg.norm)
                q = (h @ p["xattn"]["wq"]).reshape(
                    B, cfg.num_heads, cfg.head_dim
                )
                out = _decode_attend(
                    q,
                    {"k": cache["cross_k"][i], "v": cache["cross_v"][i]},
                    jnp.ones((B, cfg.encoder_seq), bool),
                    cfg,
                    "bf16",
                )
                x = x + out.reshape(B, 1, -1) @ p["xattn"]["wo"]
                h = layers.apply_norm(x, p["norm2"], cfg.norm)
                x = x + layers.mlp_apply(p["mlp"], h, "gelu")
        else:
            raise ValueError(cfg.family)

        x = layers.apply_norm(x, params["final_norm"], cfg.norm)
        head = params.get("lm_head")
        if head is None:  # tied: non-bf16 serve params carry an explicit record
            head = params["embed"].T.astype(dtype)
        logits = layers.wdot(x, head)
        logits = sh.constrain(logits, rules, (sh.BATCH, None, sh.VOCAB))
        cache["pos"] = pos + 1
        # pin output placements so donated cache buffers are reused in
        # place across steps instead of drifting to whatever the
        # partitioner last inferred (no-op without a mesh)
        cache = kvc.constrain_cache(cache, cspecs, rules)
        return logits, cache

    return serve_step


# --------------------------------------------------------------------------
# prefill (build the cache from a prompt) — transformer families
# --------------------------------------------------------------------------


def prefill(params, cfg, layout: kvc.CacheLayout, tokens, rules=sh.ShardingRules(),
            **fw_kw):
    """Runs the forward pass, returning (last_logits, populated cache).

    Transformer families only (mamba/hybrid prefill state capture is in the
    per-family paths of the examples); decode cells of the dry-run take the
    cache as an *input spec*, so this is the serving-path utility.
    """
    assert cfg.family in ("dense", "moe", "vlm")
    logits, _, kvs = transformer.forward(
        params, cfg, tokens, rules, return_kv=True, **fw_kw
    )
    k_all, v_all = kvs  # (L, B, S, Hk, Dh)
    cache, _ = kvc.init_cache(cfg, layout)
    B, S = tokens.shape

    paged_kw = {}
    if layout.layout == "paged":
        # whole-batch prefill maps every slot's row slot-major (no
        # allocator in the loop); the scheduler path syncs its own table
        cache["page_table"] = kvc.identity_page_table(layout)
        paged_kw = dict(page_table=cache["page_table"], **_paged_kw(layout))
    for gi, layer in enumerate(layout.global_layers):
        cache["global"] = kvc.write_prefill(
            cache["global"], gi, k_all[layer], v_all[layer], **paged_kw
        )
    for li, layer in enumerate(layout.local_layers):
        cache["local"] = kvc.write_prefill_local(
            cache["local"], li, k_all[layer], v_all[layer], layout.local_window
        )

    cache["pos"] = jnp.full((B,), S, jnp.int32)
    return logits[:, -1:], cache


def prefill_into_slot(params, cfg, layout: kvc.CacheLayout, cache, slot: int,
                      prompt, rules=sh.ShardingRules(), **fw_kw):
    """Prefill ONE prompt into batch row ``slot`` of a *live* cache.

    This is the admission path of the continuous-batching scheduler: the
    forward pass runs at B=1, the slot is reset (stale KV, ring positions,
    mamba state), and the prompt's quantized/bit-planed KV is written into
    that single batch index without touching live neighbors.  Returns
    ``(last_logits (1, 1, V), cache)`` — the logits sample the request's
    first token.

    prompt: (S,) or (1, S) int32 tokens, S < layout.max_seq (a prompt that
    fills the cache leaves no index for the first decoded token's KV —
    out-of-bounds scatters drop silently, corrupting logits).

    Paged layouts: the caller must have mapped pages covering ``[0, S)``
    of the slot's row in ``cache["page_table"]`` first (the scheduler's
    ``PageAllocator.ensure_range``) — writes through unmapped pages drop.

    This is the *eager reference* admission path: one arbitrary-length
    forward per prompt, recompiling per length and copying the stacked
    store per layer.  Production admission is :class:`ChunkedPrefill` —
    fixed-shape ``(1, C)`` chunks, jitted once per bucket width with the
    cache donated — which the scheduler interleaves with batched decode.
    """
    assert cfg.family in ("dense", "moe", "vlm")
    tokens = prompt[None] if prompt.ndim == 1 else prompt
    assert tokens.shape[0] == 1, "one prompt per admission"
    S = tokens.shape[1]
    assert S < layout.max_seq, (
        f"prompt len {S} needs at least one decode slot below max_seq "
        f"{layout.max_seq}"
    )
    logits, _, (k_all, v_all) = transformer.forward(
        params, cfg, tokens, rules, return_kv=True, **fw_kw
    )
    cache = kvc.reset_slot(cache, layout, slot)
    paged_kw = dict(
        page_table=cache["page_table"], **_paged_kw(layout)
    ) if layout.layout == "paged" else {}
    for gi, layer in enumerate(layout.global_layers):
        cache["global"] = kvc.write_prefill(
            cache["global"], gi, k_all[layer], v_all[layer], slot=slot,
            **paged_kw
        )
    for li, layer in enumerate(layout.local_layers):
        cache["local"] = kvc.write_prefill_local(
            cache["local"], li, k_all[layer], v_all[layer],
            layout.local_window, slot=slot,
        )
    cache["pos"] = cache["pos"].at[slot].set(S)
    return logits[:, -1:], cache


# --------------------------------------------------------------------------
# chunked, bucketed prefill — the jitted admission path
# --------------------------------------------------------------------------
#
# A chunk step runs a fixed-shape (1, C) forward for one slot of a live
# cache at an arbitrary token offset.  Two ingredients make the composition
# of chunks BIT-IDENTICAL (bf16) to a single whole-prompt chunk:
#
#   * global layers write the chunk's KV into the cache FIRST and then
#     attend over the full (S_max,) stack row with per-query causal masks —
#     the key axis has one fixed shape and one fixed value layout no matter
#     how the prompt was chunked, so per-query reductions associate
#     identically;
#   * local (ring) layers attend per query over a gathered fixed-width
#     window (lane r of query p always holds position p - W + 1 + r), so
#     lane placement is chunking-invariant too.  Ring writes happen after
#     the attend (a chunk write would evict window entries its own earlier
#     queries still need).
#
# Padded lanes beyond ``length`` carry garbage queries (their logits are
# never read) and their KV writes scatter to kvc.OOB_INDEX (dropped).


def _chunk_attend_local(cfg, layout, store, li, slot, q, k, v, qpos, offset,
                        kind, w):
    """Fixed-width gathered-window attention for a ring-buffered local layer.

    q/k/v: fresh chunk projections ``(1, C, H, Dh)``; qpos ``(C,)`` global
    positions; the ring row holds positions ``< offset``.  Query at position
    p attends lanes holding positions ``p-W+1 .. p`` gathered from
    [ring (position-ordered) | fresh chunk], masked by presence + the
    sliding/chunked window rule.  Returns f32 ``(1, C, Hq, Dh)``.
    """
    B, C, Hq, Dh = q.shape
    Hk = cfg.num_kv_heads
    g = Hq // Hk
    W = layout.local_window

    if "k_scale" in store:
        kr = store["k"][li, slot].astype(jnp.float32) \
            * store["k_scale"][li, slot][..., None]
        vr = store["v"][li, slot].astype(jnp.float32) \
            * store["v_scale"][li, slot][..., None]
    else:
        kr = store["k"][li, slot].astype(jnp.float32)
        vr = store["v"][li, slot].astype(jnp.float32)
    ap = store["abs_pos"][li, slot]  # (W,)

    # reorder ring lanes to ascending position: lane j holds offset-W+j
    order = jnp.mod(offset - W + jnp.arange(W), W)
    kr, vr, ap = kr[:, order], vr[:, order], ap[order]
    ring_pos = jnp.where((ap >= 0) & (ap < offset), ap, -(1 << 30))

    buf_k = jnp.concatenate([kr, jnp.swapaxes(k[0], 0, 1).astype(jnp.float32)],
                            axis=1)  # (Hk, W+C, D)
    buf_v = jnp.concatenate([vr, jnp.swapaxes(v[0], 0, 1).astype(jnp.float32)],
                            axis=1)
    buf_pos = jnp.concatenate([ring_pos, qpos])  # (W+C,)

    # query i gathers buffer lanes i+1 .. i+W == positions qpos[i]-W+1..qpos[i]
    idx = jnp.arange(C)[:, None] + 1 + jnp.arange(W)[None, :]  # (C, W)
    gk = buf_k[:, idx]  # (Hk, C, W, D)
    gv = buf_v[:, idx]
    expect = qpos[:, None] - W + 1 + jnp.arange(W)[None, :]  # (C, W)
    valid = (buf_pos[idx] == expect) & (expect >= 0)
    if kind == "chunked":
        cw = jnp.maximum(w, 1)
        valid = valid & (expect // cw == qpos[:, None] // cw)
    else:  # sliding; no-op when w == W, real when W was clamped to max_seq
        valid = valid & (qpos[:, None] - expect < w)

    qg = q[0].reshape(C, Hk, g, Dh).transpose(1, 2, 0, 3).astype(jnp.float32)
    logits = jnp.einsum("hgqd,hqwd->hgqw", qg, gk) * Dh**-0.5
    logits = jnp.where(valid[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hgqw,hqwd->hgqd", probs, gv)
    return out.transpose(2, 0, 1, 3).reshape(1, C, Hq, Dh)


def _attn_chunk_layer(p, cfg, layout, cache, x, slot, offset, length,
                      layer_idx, theta, rules, phys=None):
    """One attention layer of the chunk forward.  x: (1, C, D).
    ``phys`` (paged): the slot's ``(1, S_max)`` logical->pool gather map,
    hoisted once per chunk step."""
    B, C, _ = x.shape
    fmt = layout.kv_format
    h = layers.apply_norm(x, p["attn_norm"], cfg.norm) if "attn_norm" in p else x
    qpos = offset + jnp.arange(C, dtype=jnp.int32)  # (C,) global positions
    q, k, v = layers.qkv_project(
        p["attn"], h, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
        qpos[None], theta, qk_norm=cfg.qk_norm,
    )
    # B=1 keeps "data" replicated here; heads still shard over "model"
    q = sh.constrain(q, rules, (sh.BATCH, None, sh.HEADS, None))
    k = sh.constrain(k, rules, (sh.BATCH, None, sh.KV_HEADS, None))
    v = sh.constrain(v, rules, (sh.BATCH, None, sh.KV_HEADS, None))
    kind, w = cfg.layer_attn_window(layer_idx)

    if layer_idx in layout.local_layers:
        li = layout.local_layers.index(layer_idx)
        out = _chunk_attend_local(
            cfg, layout, cache["local"], li, slot, q, k, v, qpos, offset,
            kind, w,
        )
        cache["local"] = kvc.write_prefill_local(
            cache["local"], li, k, v, layout.local_window,
            slot=slot, offset=offset, length=length,
        )
    else:
        gi = layout.global_layers.index(layer_idx)
        # write first: chunk keys are read back from the stack, keeping the
        # key axis (S_max,) for every bucket width
        if layout.layout == "paged":
            cache["global"] = kvc.write_prefill(
                cache["global"], gi, k, v, slot=slot, offset=offset,
                length=length, page_table=cache["page_table"],
                **_paged_kw(layout),
            )
            view = kvc.paged_entry(cache["global"], gi, phys)
            # same materialization pin as the decode layer: stop the page
            # gather fusing into the chunk attend (sharding-stable lowering)
            view = jax.lax.optimization_barrier(view)
        else:
            cache["global"] = kvc.write_prefill(
                cache["global"], gi, k, v, slot=slot, offset=offset,
                length=length,
            )
            store = cache["global"]
            view = {
                n: (store[n][gi][:, slot][:, None] if n == "k_planes"
                    else store[n][gi, slot][None])
                for n in store
            }
        S = layout.max_seq
        valid = (jnp.arange(S)[None, :] <= qpos[:, None])[None]  # (1, C, S)
        if fmt == "bgpp":
            # prefill attends the full causal context: reconstruct the exact
            # int8 K from the bit planes (BGPP's progressive prediction is a
            # decode-time saving; there is nothing to skip at prefill)
            entry = {
                "k": kvc.bitplanes_to_k(
                    view["k_planes"], view["k_sign"]
                ).astype(jnp.int8),
                "k_scale": view["k_scale"],
                "v": view["v"],
                "v_scale": view["v_scale"],
            }
            out = _cache_attend(q, entry, valid, cfg, "int8")
        else:
            out = _cache_attend(q, view, valid, cfg, fmt)

    # all-gather the head outputs across "model" before the replicated wo
    # (same bit-exact attend-reduction boundary as the decode layer)
    out = sh.constrain(out.astype(x.dtype).reshape(B, C, -1), rules,
                       (sh.BATCH, None, None))
    out = out @ p["attn"]["wo"]
    if cfg.post_norms and "post_attn_norm" in p:
        out = layers.apply_norm(out, p["post_attn_norm"], cfg.norm)
    return out, cache


def make_prefill_chunk(cfg, layout: kvc.CacheLayout, rules=sh.ShardingRules()):
    """Builds the pure chunk step for one (cfg, layout):

        prefill_chunk(params, cache, tokens (1, C), slot, offset, length)
            -> (logits (1, C, V), cache')

    ``slot``/``offset``/``length`` are traced int32 scalars, so one jit
    compilation per chunk width ``C`` covers every slot, token offset, and
    padding amount.  The chunk's KV lands at positions
    ``[offset, offset+length)`` of row ``slot`` and ``cache['pos'][slot]``
    is set to ``offset + length`` (absolute, so interleaved decode steps of
    other slots can never drift a prefilling row's position).
    """
    assert cfg.family in ("dense", "moe", "vlm"), (
        "chunked admission covers transformer families; ssm/hybrid/enc-dec"
        " decode through make_serve_step directly"
    )
    dtype = layers._dtype(cfg.dtype)
    thetas = transformer.layer_thetas(cfg)
    cspecs = kvc.cache_specs(cfg, layout)

    def prefill_chunk(params, cache, tokens, slot, offset, length):
        """One fixed-shape (1, C) prefill chunk against the live cache."""
        # paged: this slot's logical->pool gather row, hoisted once for
        # every global layer (the serve_step pattern)
        phys = jnp.take(
            kvc.phys_table(
                cache["page_table"], layout.page_size, layout.max_seq
            ),
            slot, axis=0,
        )[None] if layout.layout == "paged" and layout.global_layers else None
        x = params["embed"][tokens].astype(dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
        x = sh.constrain(x, rules, (sh.BATCH, None, None))
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["layers"])
            a, cache = _attn_chunk_layer(
                p, cfg, layout, cache, x, slot, offset, length, i,
                float(thetas[i]), rules, phys=phys,
            )
            x = x + a
            # dropless MoE (capacity_factor=E): padded garbage lanes can
            # never steal expert capacity from valid prompt tokens
            x = x + _ffn_decode_layer(p, cfg, x, rules)
        x = layers.apply_norm(x, params["final_norm"], cfg.norm)
        head = params.get("lm_head")
        logits = x @ (head if head is not None else params["embed"].T.astype(dtype))
        logits = sh.constrain(logits, rules, (sh.BATCH, None, sh.VOCAB))
        cache["pos"] = cache["pos"].at[slot].set(offset + length)
        cache = kvc.constrain_cache(cache, cspecs, rules)
        return logits, cache

    return prefill_chunk


def default_buckets(chunk_budget: int) -> Tuple[int, ...]:
    """Bucket widths for a token budget: the budget itself plus one half-
    size tail bucket (fewer wasted pad lanes on the last chunk of a prompt,
    at the cost of one extra compile)."""
    budget = max(1, int(chunk_budget))
    return tuple(sorted({budget, max(4, budget // 2)} - {0}))


class ChunkedPrefill:
    """Jitted, bucketed chunk-prefill engine for one (cfg, layout, rules).

    Owns two donated-cache jits: the chunk step (compiled once per bucket
    width — assert via :attr:`num_compiles`) and the slot reset.  The
    scheduler drives it chunk-by-chunk; :meth:`admit` runs a whole prompt
    (used by tests/benchmarks as the whole-prompt reference: with a bucket
    >= the prompt length it is a single fixed-shape forward).
    """

    def __init__(self, cfg, layout: kvc.CacheLayout,
                 rules: sh.ShardingRules = sh.ShardingRules(),
                 buckets: Tuple[int, ...] = (8, 16)):
        self.cfg = cfg
        self.layout = layout
        self.buckets = tuple(sorted({int(b) for b in buckets}))
        assert self.buckets and self.buckets[0] >= 1
        self._chunk = jax.jit(
            make_prefill_chunk(cfg, layout, rules), donate_argnums=(1,)
        )
        self._reset = jax.jit(
            lambda cache, slot: kvc.reset_slot(cache, layout, slot),
            donate_argnums=(0,),
        )

    def bucket_for(self, n: int) -> int:
        """Smallest bucket >= n, or the largest bucket (caller chunks)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    @property
    def num_compiles(self) -> int:
        """Compiled chunk variants — the donate/bucketing contract says this
        never exceeds ``len(self.buckets)``."""
        return self._chunk._cache_size()

    def reset(self, cache, slot: int):
        """Donated-cache slot scrub (the first step of every admission)."""
        return self._reset(cache, int(slot))

    def run_chunk(self, params, cache, slot: int, chunk_tokens, offset: int):
        """One fixed-shape chunk step: pads ``chunk_tokens`` (1-D, length
        n <= largest bucket) to its bucket and runs the jitted step.
        Returns ``(logits (1, C, V), cache, n)``."""
        toks = np.asarray(chunk_tokens, np.int32).reshape(-1)
        n = toks.shape[0]
        C = self.bucket_for(n)
        assert n <= C, f"chunk of {n} tokens exceeds largest bucket {C}"
        if n < C:
            toks = np.pad(toks, (0, C - n))
        logits, cache = self._chunk(
            params, cache, jnp.asarray(toks[None]), int(slot), int(offset),
            int(n),
        )
        return logits, cache, n

    def admit(self, params, cache, slot: int, prompt, *,
              max_chunk: Optional[int] = None, reset: bool = True):
        """Whole-prompt admission through the chunk path: reset the slot,
        then consume the prompt in <= ``max_chunk``-token chunks (default:
        the largest bucket).  Returns ``(last_logits (1, 1, V), cache)`` —
        same contract as :func:`prefill_into_slot`."""
        toks = np.asarray(prompt, np.int32).reshape(-1)
        S = toks.shape[0]
        assert 0 < S < self.layout.max_seq
        step = min(self.buckets[-1], max_chunk or self.buckets[-1])
        if reset:
            cache = self.reset(cache, slot)
        off = 0
        logits, n = None, 0
        while off < S:
            logits, cache, n = self.run_chunk(
                params, cache, slot, toks[off:off + step], off
            )
            off += n
        return logits[:, n - 1:n], cache
