"""Continuous-batching request scheduler over one live per-slot KV cache.

The scheduler owns the cache, a FIFO admission queue, and ``layout.batch``
slots.  Each engine step it (1) admits arrived requests into EMPTY slots via
``engine.prefill_into_slot`` — a B=1 forward whose KV lands in exactly one
batch row, (2) runs ONE batched ``serve_step`` for every slot (per-slot
``cache["pos"]`` keeps staggered requests position-correct), and (3) evicts
finished slots with ``kv_cache.reset_slot`` so the next queued request can
take the row without touching live neighbors.

Greedy sampling by default; pass ``sample_fn`` for anything richer.  The
scheduler is deliberately host-side python around jitted device steps —
the same split a production server uses (device graph static, scheduling
dynamic).
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.serving import engine, kv_cache as kvc
from repro.serving.request import Request, Slot, SlotState


def greedy_sample(logits: np.ndarray) -> np.ndarray:
    """(B, V) logits -> (B,) int32 argmax tokens."""
    return np.argmax(logits, axis=-1).astype(np.int32)


class Scheduler:
    """Slot-level continuous batching on top of the MCBP serving engine."""

    def __init__(
        self,
        params,
        cfg,
        layout: kvc.CacheLayout,
        rules: sh.ShardingRules = sh.ShardingRules(),
        sample_fn: Callable[[np.ndarray], np.ndarray] = greedy_sample,
        prefill_kw: Optional[dict] = None,
    ):
        assert cfg.family in ("dense", "moe", "vlm"), (
            "the scheduler admits via transformer prefill; ssm/hybrid/enc-dec"
            " decode through make_serve_step directly (tests/test_serving.py)"
        )
        self.params = params
        self.cfg = cfg
        self.layout = layout
        self.rules = rules
        self.sample_fn = sample_fn
        self.prefill_kw = dict(prefill_kw or {})

        self.cache = kvc.init_cache_arrays(cfg, layout)
        self.slots: List[Slot] = [Slot(i) for i in range(layout.batch)]
        self.queue: Deque[Request] = collections.deque()
        self.serve_step = jax.jit(engine.make_serve_step(cfg, layout, rules))
        # next-token feed per slot; EMPTY rows decode token 0 into garbage
        # that per-slot valid masks keep invisible to live rows
        self.tokens = np.zeros((layout.batch, 1), np.int32)

        self.step_count = 0
        self.finished: List[Request] = []
        self.occupancy: List[float] = []  # live slots / slots, per step
        self.decoded_tokens = 0

    # ------------------------------------------------------------------
    # queue / admission
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> None:
        # reject oversized prompts at the API boundary: admission would
        # otherwise die mid-loop and take every in-flight request with it
        if request.prompt_len >= self.layout.max_seq:
            raise ValueError(
                f"request {request.rid}: prompt_len {request.prompt_len} "
                f"needs at least one decode slot below max_seq "
                f"{self.layout.max_seq}"
            )
        request.submit_time = time.perf_counter()
        self.queue.append(request)

    @property
    def num_pending(self) -> int:
        return len(self.queue) + sum(1 for s in self.slots if s.live)

    def _next_arrived(self) -> Optional[Request]:
        for i, req in enumerate(self.queue):
            if req.arrival_step <= self.step_count:
                del self.queue[i]
                return req
        return None

    def admit(self) -> List[Request]:
        """Fill EMPTY slots from the queue (FIFO among arrived requests)."""
        admitted = []
        for slot in self.slots:
            if slot.state is not SlotState.EMPTY:
                continue
            req = self._next_arrived()
            if req is None:
                break
            slot.state = SlotState.PREFILLING
            slot.request = req
            logits, self.cache = engine.prefill_into_slot(
                self.params, self.cfg, self.layout, self.cache, slot.index,
                jnp.asarray(req.prompt, jnp.int32), self.rules,
                **self.prefill_kw,
            )
            first = int(self.sample_fn(np.asarray(logits[:, -1]))[0])
            req.generated.append(first)
            req.admitted_step = self.step_count
            req.admit_time = time.perf_counter()
            self.tokens[slot.index, 0] = first
            slot.state = SlotState.DECODING
            admitted.append(req)
            if self._hit_limit(slot, req):
                self._finish(slot)
        return admitted

    # ------------------------------------------------------------------
    # decode / eviction
    # ------------------------------------------------------------------

    def _hit_limit(self, slot: Slot, req: Request) -> bool:
        if len(req.generated) >= req.max_new_tokens:
            return True
        # the next decode step writes its KV at index prompt_len + decode
        # steps so far (== device pos[slot], tracked host-side to avoid a
        # sync); at max_seq the slot is out of cache room
        if req.prompt_len + len(req.generated) - 1 >= self.layout.max_seq:
            return True
        return (req.eos_id is not None and bool(req.generated)
                and req.generated[-1] == req.eos_id)

    def _finish(self, slot: Slot) -> None:
        req = slot.request
        req.finished_step = self.step_count
        req.finish_time = time.perf_counter()
        slot.state = SlotState.DONE
        self.finished.append(req)
        # eviction is logical only: the physical row reset (an O(cache)
        # copy) happens once, at the next admission — prefill_into_slot
        # always reset_slot's first, and per-slot valid masks keep the
        # stale row invisible to live neighbors in the meantime.  Call
        # kv_cache.reset_slot yourself to scrub a row eagerly.
        self.tokens[slot.index, 0] = 0
        slot.request = None
        slot.state = SlotState.EMPTY

    def step(self) -> bool:
        """Admit, run one batched decode step, harvest, evict.

        Returns False when there was nothing to do (no live slot and no
        admissible request) — the caller's idle/termination signal.
        """
        self.admit()
        live = [s for s in self.slots if s.state is SlotState.DECODING]
        self.occupancy.append(len(live) / len(self.slots))
        if not live:
            self.step_count += 1
            return False
        logits, self.cache = self.serve_step(
            self.params, self.cache, jnp.asarray(self.tokens)
        )
        nxt = self.sample_fn(np.asarray(logits[:, -1]))
        self.step_count += 1
        self.decoded_tokens += len(live)
        for slot in live:
            req = slot.request
            tok = int(nxt[slot.index])
            req.generated.append(tok)
            self.tokens[slot.index, 0] = tok
            if self._hit_limit(slot, req):
                self._finish(slot)
        return True

    def run(self, max_steps: Optional[int] = None) -> Dict:
        """Drive steps until every submitted request finished (or the step
        budget runs out); returns :meth:`stats`."""
        t0 = time.perf_counter()
        while self.num_pending:
            if max_steps is not None and self.step_count >= max_steps:
                break
            self.step()
        return self.stats(time.perf_counter() - t0)

    def stats(self, wall_s: Optional[float] = None) -> Dict:
        occ = [o for o in self.occupancy if o > 0] or self.occupancy
        out = {
            "finished_requests": len(self.finished),
            "decoded_tokens": self.decoded_tokens,
            "steps": self.step_count,
            "mean_occupancy": float(np.mean(occ)) if occ else 0.0,
            "requests": [r.trace_record() for r in self.finished],
        }
        if wall_s is not None:
            out["wall_s"] = round(wall_s, 3)
            out["tokens_per_s"] = round(self.decoded_tokens / wall_s, 2) \
                if wall_s > 0 else None
        return out
