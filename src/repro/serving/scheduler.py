"""Continuous-batching request scheduler over one live per-slot KV cache.

The scheduler owns the cache, a FIFO admission queue, and ``layout.batch``
slots.  Each engine step it (1) spends at most ``chunk_budget`` prompt
tokens advancing ONE admitting request through the jitted, bucketed
``ChunkedPrefill`` path (fixed-shape ``(1, C)`` chunks against the live
cache, cache donated), (2) runs ONE batched ``serve_step`` for every
DECODING slot (per-slot ``cache["pos"]`` keeps staggered requests
position-correct), and (3) evicts finished slots with
``kv_cache.reset_slot`` so the next queued request can take the row without
touching live neighbors.  Interleaving (1) and (2) bounds how long a long
prompt can stall in-flight decoders: never more than one chunk budget of
prefill tokens runs between consecutive batched decode steps.

``admission="eager"`` keeps the PR-2 behavior (one arbitrary-length B=1
forward per prompt, decode stalls until it finishes) as the reference /
benchmark baseline.

Paged layouts (``layout_for(..., layout="paged")``) add a host-side
:class:`~repro.serving.paging.PageAllocator` to the loop: pages are mapped
just ahead of every chunk/decode write, the device page table is synced
whenever the host copy changes, and eviction decrefs the slot's pages —
zeroing (on device) only those whose refcount hit zero.  When an admitted
slot first advances, its prompt is hashed against the prefix index; a hit
adopts the resident requests' full prompt pages (refcount++) and skips
straight to the first un-reused token, so shared system prompts prefill
once.  (Adoption waits for the first advance rather than assignment so a
queued-behind adopter never holds shared pages at device pos 0, where the
batched decode's garbage writes would land.)  Reuse is offered
for global-only attention stacks (sliding-window rings discard the prefix
positions a reused slot would need); everything else about paged serving —
including every logit — is bit-identical to the slot layout, which is how
the fuzz oracle checks it.

The front-door hooks (``repro.serving.server`` is the asyncio transport
over them):

  * ``cancel(rid)`` pulls a request out at ANY lifecycle state — queued
    (dequeue), PREFILLING (state-aware eviction: pages freed, no bogus
    TTFT/ITL rows recorded), or DECODING (eviction mid-stream).  Survivor
    slots are untouched: eviction is the same logical evict + page decref
    the DONE path uses, which the fuzz oracle pins bit-exact.
  * ``Request.priority`` tiers (``interactive`` > ``batch``): the
    admission queue is priority-ordered FIFO, and the chunked-prefill
    advance picks the highest-priority admitting slot each step — an
    interactive arrival preempts an in-progress batch prefill's chunk
    budget (the batch slot's ``prefill_pos`` freezes; it resumes at that
    exact offset when nothing above it is admitting).  Decodes already
    running are never killed by priority.
  * ``Request.deadline_steps``: SLO-aware admission — a request still
    queued that many steps past arrival is shed (cancelled unstarted)
    instead of admitted late.
  * ``Request.on_token`` / ``on_finish`` stream tokens and completion to
    the caller per scheduler step (the server bridges them onto asyncio
    queues); ``Request.keep_prefix_resident`` pins the finished turn's
    page-aligned history so a chat session's next turn hits the prefix
    index (release with ``unpin_pages``).

Greedy sampling by default; pass ``sample_fn`` for anything richer, or set
``Request.forced_tokens`` to teacher-force a response (serving oracles).
The scheduler is deliberately host-side python around jitted device steps —
the same split a production server uses (device graph static, scheduling
dynamic).

Speculative decoding (``spec_decode=True`` / ``REPRO_SPEC_DECODE=on``, see
``repro.serving.spec_decode``) replaces each batched decode step with one
draft → verify → accept/rollback round (:meth:`Scheduler._spec_round`):
``draft_gamma`` truncated-bit-plane serve_steps propose draft tokens per
DECODING slot, up to ``gamma + 1`` full-precision serve_steps verify them
(the scheduler's ordinary ``_pick_token`` — forced or greedy over exact
logits — is the verifier, so speculative output is BIT-identical to
non-speculative decode), and every slot rolls back to its accepted
frontier: per-slot ``pos`` rewind, allocator page invalidation
(``PageAllocator.rewind_slot`` — generation counters + prefix-index
deregistration), and a device scrub of the garbage tail rows across every
store leaf.  ``stats()["spec"]`` reports accepted-tokens/step and kv +
weight bytes per *accepted* token next to ``kv_read``/``weight_read``.
"""

from __future__ import annotations

import collections
import time
from typing import Callable, Deque, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.serving import engine, kv_cache as kvc
from repro.serving import sharded as shd
from repro.serving import spec_decode as spd
from repro.serving import weights as swt
from repro.serving.paging import PageAllocator
from repro.serving.request import (Request, Slot, SlotState, priority_rank)


def greedy_sample(logits: np.ndarray) -> np.ndarray:
    """(B, V) logits -> (B,) int32 argmax tokens."""
    return np.argmax(logits, axis=-1).astype(np.int32)


def _percentiles(samples) -> Dict[str, Optional[float]]:
    a = np.asarray(list(samples), np.float64)
    if a.size == 0:
        return {"p50": None, "p95": None}
    return {"p50": round(float(np.percentile(a, 50)), 6),
            "p95": round(float(np.percentile(a, 95)), 6)}


class Scheduler:
    """Slot-level continuous batching on top of the MCBP serving engine."""

    def __init__(
        self,
        params,
        cfg,
        layout: kvc.CacheLayout,
        rules: sh.ShardingRules = sh.ShardingRules(),
        sample_fn: Callable[[np.ndarray], np.ndarray] = greedy_sample,
        admission: str = "chunked",
        chunk_budget: int = 16,
        buckets=None,
        prefill_kw: Optional[dict] = None,
        record_logits: bool = False,
        shared_fns: Optional[dict] = None,
        param_specs=None,
        spec_decode: Optional[bool] = None,
        draft_gamma: Optional[int] = None,
        draft_planes: Optional[int] = None,
        draft_fn: Optional[Callable[[Request, int], int]] = None,
    ):
        assert cfg.family in ("dense", "moe", "vlm"), (
            "the scheduler admits via transformer prefill; ssm/hybrid/enc-dec"
            " decode through make_serve_step directly (tests/test_serving.py)"
        )
        assert admission in ("chunked", "eager"), admission
        self.params = params
        self.cfg = cfg
        self.layout = layout
        self.rules = rules
        self.sample_fn = sample_fn
        self.admission = admission
        self.chunk_budget = int(chunk_budget)
        self.prefill_kw = dict(prefill_kw or {})  # eager-path forward kwargs
        self.record_logits = record_logits

        self.cache = kvc.init_cache_arrays(cfg, layout)
        # mesh placement (tentpole): KV pools/stacks heads-parallel on
        # "model" (slot stacks also batch-parallel on "data"), weights under
        # the bit-exact serving policy, page table + allocator host-side and
        # replicated.  Everything below is identity without a mesh.
        self.mesh_shape = shd.mesh_shape(rules)
        if rules.mesh is not None:
            self.cache = shd.shard_cache(self.cache, cfg, layout, rules)
            if param_specs is None:
                from repro.models import model_zoo
                try:
                    param_specs = model_zoo.param_specs(cfg)
                except Exception:
                    param_specs = None  # unknown tree: replicate (still exact)
            self.params = shd.shard_params(params, param_specs, rules)
        # serve-time weight format (the once-dead knob): resolved ONCE here
        # (env > config, same contract as decode_kernel), projections
        # converted AFTER sharding so the int8/bstc records inherit the
        # raw leaves' placement (quantization is elementwise + an in-axis
        # max, both order-insensitive).  Decode steps consume
        # ``serve_params``; BOTH prefill paths keep the raw ``params``
        # tree, so admission stays bit-for-bit the bf16 path in every
        # format.  With fmt="bf16" serve_params IS params (untouched).
        self.weight_format = swt.resolve(cfg)
        self.serve_params, self.weight_plan = swt.prepare_serve_params(
            self.params, cfg, layout, self.weight_format
        )
        self.pager: Optional[PageAllocator] = None
        # a paged layout with no global stack has no pools to manage
        if layout.layout == "paged" and layout.global_layers:
            self.pager = PageAllocator(layout)
            self._page_bytes = kvc.page_bytes(
                self.cache["global"], layout.page_size
            )
            pool_specs = kvc.cache_specs(cfg, layout)["global"]
            self._zero_pages = jax.jit(
                lambda store, ids: kvc.constrain_cache(
                    kvc.zero_pages(store, ids, layout.page_size),
                    pool_specs, rules,
                ),
                donate_argnums=(0,),
            )
        self.slots: List[Slot] = [Slot(i) for i in range(layout.batch)]
        self.queue: Deque[Request] = collections.deque()
        if shared_fns is not None:
            # reuse another scheduler's compiled steps (same cfg/layout/rules)
            assert shared_fns.get("layout") in (None, layout), (
                "shared_fns were compiled for a different cache layout: "
                f"{shared_fns.get('layout')} vs {layout}"
            )
            self.serve_step = shared_fns["serve_step"]
            self.chunked = shared_fns.get("chunked")
        else:
            self.serve_step = jax.jit(engine.make_serve_step(cfg, layout, rules))
            self.chunked = None
        if admission == "chunked" and self.chunked is None:
            # shared_fns came from an eager scheduler (or none given)
            self.chunked = engine.ChunkedPrefill(
                cfg, layout, rules,
                buckets=buckets or engine.default_buckets(self.chunk_budget),
            )
        # next-token feed per slot; EMPTY/PREFILLING rows decode token 0 into
        # garbage that per-slot valid masks + chunk overwrites keep invisible
        self.tokens = np.zeros((layout.batch, 1), np.int32)

        # speculative decoding (repro.serving.spec_decode): kwarg > env >
        # config, with env-driven enables soft-disabling on local-layer
        # stacks (rings are not rollback-safe) and explicit ones raising
        self.spec = spd.validate(
            cfg, layout, spd.resolve(cfg, spec_decode, draft_gamma,
                                     draft_planes)
        )
        self.draft_fn = draft_fn
        self.draft_params = None
        self._scrub_tokens = None
        if self.spec.enabled:
            if draft_fn is None and self.spec.planes < 7:
                # truncated-plane draft weights, converted through the SAME
                # weight-format path as the real ones so the compiled
                # serve_step executable is reused as the draft forward
                self.draft_params, _ = swt.prepare_serve_params(
                    spd.truncate_plane_params(self.params, self.spec.planes),
                    cfg, layout, self.weight_format,
                )
            else:
                # planes >= 7 keeps full int8 precision: the real serve
                # weights ARE the (perfect) draft model
                self.draft_params = self.serve_params
            if layout.global_layers:
                g_specs = kvc.cache_specs(cfg, layout)["global"]
                if layout.layout == "paged":
                    self._scrub_tokens = jax.jit(
                        lambda store, tpos, table: kvc.constrain_cache(
                            kvc.zero_token_range(
                                store, tpos, page_table=table,
                                page_size=layout.page_size,
                                max_seq=layout.max_seq,
                            ), g_specs, rules,
                        ),
                        donate_argnums=(0,),
                    )
                else:
                    self._scrub_tokens = jax.jit(
                        lambda store, tpos: kvc.constrain_cache(
                            kvc.zero_token_range(
                                store, tpos, max_seq=layout.max_seq,
                            ), g_specs, rules,
                        ),
                        donate_argnums=(0,),
                    )
        # spec-decode counters (stats()["spec"]): rounds run, drafts
        # proposed/accepted, physical draft/verify steps, per-slot round
        # participations (each round's first token is the free corrected
        # one), best single-round accept
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_draft_steps = 0
        self.spec_verify_steps = 0
        self.spec_slot_rounds = 0
        self.spec_max_accept = 0

        self.step_count = 0
        self.finished: List[Request] = []
        self.cancelled: List[Request] = []  # cancel() + deadline sheds
        self.preemptions = 0  # chunk budgets reclaimed by a higher tier
        # slot index whose prefill the advance loop worked on last step —
        # the reference point for counting budget preemptions
        self._advancing: Optional[int] = None
        self.occupancy: List[float] = []  # busy slots / slots, per step
        self.decoded_tokens = 0
        # KV-read accounting: host-side mirrors of the jitted steps' static
        # gather shapes (kv_cache.decode_read_bytes / chunk_read_bytes),
        # accumulated once per executed decode / chunk step.  For bgpp
        # this is the two-phase plan — bit-planes plus at most
        # ceil(keep_ratio·S) full-precision rows per (slot, layer) — the
        # counter stats()["kv_read"] and the serving benchmarks report.
        self._decode_read = kvc.decode_read_bytes(layout, cfg, self.mesh_shape)
        self._chunk_read = kvc.chunk_read_bytes(layout, cfg, self.mesh_shape)
        # chunk interconnect scales with the chunk's lane count; price per
        # valid lane (chunk_width=1) and multiply by tokens consumed
        self._chunk_ic_per_lane = kvc.chunk_read_bytes(
            layout, cfg, self.mesh_shape, chunk_width=1
        )["interconnect"]["total"]
        self.decode_steps = 0
        self.kv_bytes_read = {"decode": 0.0, "prefill": 0.0,
                              "interconnect": 0.0}
        # weight-read accounting, kv_read's mirror: the jitted serve_step
        # contracts every converted projection once per batched decode
        # step, priced from the WeightPlan's coded layout (measured BSTC
        # stream bytes for fmt="bstc"); prefill reads the raw-dtype tree
        self._weight_read = self.weight_plan.decode_read_bytes(
            layout, cfg, self.mesh_shape
        )
        self.weight_bytes_read = {"decode": 0.0, "prefill": 0.0}
        # audit trail for the chunk-budget contract: valid prompt tokens
        # prefilled between this step's admission and its decode
        self.prefill_tokens_per_step: List[int] = []
        # prefix-reuse accounting (paged layouts)
        self.prompt_tokens_admitted = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0

    # ------------------------------------------------------------------
    # paged-layout page lifecycle (host allocator <-> device table)
    # ------------------------------------------------------------------

    def _sync_pages(self) -> None:
        """Push the host page table to the (replicated) device copy if it
        changed — the allocator itself never leaves the host."""
        if self.pager is not None and self.pager.dirty:
            self.cache["page_table"] = shd.replicated(
                self.pager.table, self.rules
            )
            self.pager.dirty = False

    def _ensure_pages(self, slot: int, lo: int, hi: int) -> None:
        if self.pager is not None:
            self.pager.ensure_range(slot, lo, hi)
            self._sync_pages()

    def _release_pages(self, slot: int) -> None:
        """Evict a slot's pages: decref all, zero (on device) the ones
        whose refcount hit zero — prefix sharers keep theirs."""
        if self.pager is None:
            return
        freed = self.pager.release_slot(slot)
        if freed:
            ids = np.full(self.layout.pages_per_slot, -1, np.int32)
            ids[:len(freed)] = freed
            self.cache["global"] = self._zero_pages(
                self.cache["global"], jnp.asarray(ids)
            )
        self._sync_pages()

    def _try_prefix_reuse(self, slot: Slot, req: Request) -> None:
        """Adopt resident prompt pages matching this prompt's head.  Only
        global-only stacks qualify: ring layers would need the reused
        positions' window contents, which nothing retains.

        Called at the slot's FIRST chunk advance, not at assignment: the
        batched ``serve_step`` garbage-writes every row at its device pos,
        which is harmless only while the row maps no pages (writes drop) or
        only its own (the next chunk re-covers the frontier).  A waiting
        slot holding adopted pages at pos 0 would let that garbage corrupt
        the donor's shared prompt KV.  The advancing slot always moves past
        the reused region in the same scheduler step, so its own garbage
        writes stay on private pages."""
        if self.pager is None or self.layout.local_layers:
            return
        n, ids = self.pager.lookup_prefix(req.prompt)
        if n:
            self.pager.adopt_prefix(slot.index, ids)
            slot.prefill_pos = n
            req.prefix_reused_tokens = n
            self.prefix_hits += 1
            self.prefix_hit_tokens += n

    def shared_fns(self) -> dict:
        """Compiled steps, reusable by another Scheduler on the same
        (cfg, layout, rules) — e.g. an oracle's alone-runs."""
        return {"serve_step": self.serve_step, "chunked": self.chunked,
                "layout": self.layout}

    # ------------------------------------------------------------------
    # queue / admission
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> None:
        """Queue one request (FIFO among arrived; see ``Request.arrival_step``)."""
        # reject malformed prompts at the API boundary: admission would
        # otherwise die mid-loop and take every in-flight request with it
        if not 0 < request.prompt_len < self.layout.max_seq:
            raise ValueError(
                f"request {request.rid}: prompt_len {request.prompt_len} "
                f"must be >= 1 and leave at least one decode slot below "
                f"max_seq {self.layout.max_seq}"
            )
        request.submit_time = time.perf_counter()
        self.queue.append(request)

    @property
    def num_pending(self) -> int:
        """Requests not yet finished: queued plus live in a slot."""
        return len(self.queue) + sum(1 for s in self.slots if s.live)

    def _next_arrived(self) -> Optional[Request]:
        """Highest-priority arrived request, FIFO within a tier."""
        best = None
        for i, req in enumerate(self.queue):
            if req.arrival_step > self.step_count:
                continue
            if best is None or (priority_rank(req.priority)
                                < priority_rank(best[1].priority)):
                best = (i, req)
        if best is None:
            return None
        del self.queue[best[0]]
        return best[1]

    def _shed_expired(self) -> None:
        """SLO-aware admission: cancel (shed) queued requests whose
        admission deadline has passed — serving them late would only burn
        chunk budget that on-SLO requests need."""
        expired = [r for r in self.queue
                   if r.deadline_steps is not None
                   and self.step_count - r.arrival_step > r.deadline_steps]
        for req in expired:
            self.queue.remove(req)
            self._record_cancel(req, "queued", shed=True)

    def _pick_token(self, req: Request, logits_row: np.ndarray) -> int:
        """Next response token: forced (teacher-forced oracles) or sampled."""
        t = len(req.generated)
        if req.forced_tokens is not None and t < len(req.forced_tokens):
            tok = int(req.forced_tokens[t])
        else:
            tok = int(self.sample_fn(logits_row[None])[0])
        if self.record_logits:
            if req.logit_rows is None:
                req.logit_rows = []
            req.logit_rows.append(np.asarray(logits_row, np.float32))
        return tok

    def _emit_first_token(self, slot: Slot, logits_row: np.ndarray) -> None:
        req = slot.request
        first = self._pick_token(req, logits_row)
        req.generated.append(first)
        now = time.perf_counter()
        req.first_token_step = self.step_count
        req.first_token_time = now
        req.token_times.append(now)
        self.tokens[slot.index, 0] = first
        slot.state = SlotState.DECODING
        if req.on_token is not None:
            req.on_token(req, first)
        if self._hit_limit(slot, req):
            self._finish(slot)

    def admit(self) -> List[Request]:
        """Eagerly fill EMPTY slots from the queue (FIFO among arrived):
        one whole-prompt B=1 forward per request (``admission="eager"``)."""
        admitted = []
        for slot in self.slots:
            if slot.state is not SlotState.EMPTY:
                continue
            req = self._next_arrived()
            if req is None:
                break
            slot.state = SlotState.PREFILLING
            slot.request = req
            req.admitted_step = self.step_count
            req.admit_time = time.perf_counter()
            self.prompt_tokens_admitted += req.prompt_len
            self._ensure_pages(slot.index, 0, req.prompt_len)
            logits, self.cache = engine.prefill_into_slot(
                self.params, self.cfg, self.layout, self.cache, slot.index,
                jnp.asarray(req.prompt, jnp.int32), self.rules,
                **self.prefill_kw,
            )
            self._emit_first_token(slot, np.asarray(logits[0, -1], np.float32))
            self.weight_bytes_read["prefill"] += self.weight_plan.bf16_bytes
            admitted.append(req)
        return admitted

    def _advance_admission(self) -> int:
        """Chunked admission: assign arrived requests to every EMPTY slot
        (reserve the row + reset it — cheap, no token work), then spend at
        most ``chunk_budget`` prompt tokens advancing the OLDEST admitting
        request.  Exactly one prompt advances per step, so the budget is
        also the bound on prefill tokens between consecutive batched decode
        steps — the contract the chunk tests audit.  Returns the number of
        prompt tokens consumed."""
        for s in self.slots:
            if s.state is not SlotState.EMPTY:
                continue
            req = self._next_arrived()
            if req is None:
                break
            s.state = SlotState.PREFILLING
            s.request = req
            s.prefill_pos = 0
            req.admitted_step = self.step_count
            req.admit_time = time.perf_counter()
            self.cache = self.chunked.reset(self.cache, s.index)
            self.prompt_tokens_admitted += req.prompt_len
        admitting = [s for s in self.slots if s.state is SlotState.PREFILLING]
        if not admitting:
            self._advancing = None
            return 0
        # highest tier first, then oldest admission: an interactive
        # arrival preempts an in-progress batch prefill's chunk budget
        slot = min(admitting, key=lambda s: (
            priority_rank(s.request.priority), s.request.admitted_step,
            s.index,
        ))
        prev = self._advancing
        if prev is not None and prev != slot.index:
            ps = self.slots[prev]
            if (ps.state is SlotState.PREFILLING
                    and priority_rank(ps.request.priority)
                    > priority_rank(slot.request.priority)):
                # the budget that would have advanced ps goes to slot;
                # ps.prefill_pos freezes and resumes at the same offset
                ps.request.preemptions += 1
                self.preemptions += 1
        req = slot.request
        if slot.prefill_pos == 0:
            # first advance of this slot: safe point for prefix adoption
            # (see _try_prefix_reuse on why assignment time is not)
            self._try_prefix_reuse(slot, req)
        spent = 0
        logits, n = None, 0
        while spent < self.chunk_budget and slot.prefill_pos < req.prompt_len:
            take = min(req.prompt_len - slot.prefill_pos,
                       self.chunk_budget - spent,
                       self.chunked.buckets[-1])  # custom buckets < budget
            self._ensure_pages(slot.index, slot.prefill_pos,
                               slot.prefill_pos + take)
            logits, self.cache, n = self.chunked.run_chunk(
                self.params, self.cache, slot.index,
                req.prompt[slot.prefill_pos:slot.prefill_pos + take],
                slot.prefill_pos,
            )
            self.kv_bytes_read["prefill"] += self._chunk_read["total"]
            self.kv_bytes_read["interconnect"] += self._chunk_ic_per_lane * n
            # chunk forwards read the raw-dtype tree once per chunk step
            self.weight_bytes_read["prefill"] += self.weight_plan.bf16_bytes
            slot.prefill_pos += n
            spent += n
        if self.pager is not None and not self.layout.local_layers:
            # every page-aligned prompt prefix now fully written becomes a
            # reuse candidate for later admissions
            self.pager.register_prefix(slot.index, req.prompt,
                                       slot.prefill_pos)
        if slot.prefill_pos >= req.prompt_len:
            self._emit_first_token(slot, np.asarray(logits[0, n - 1], np.float32))
            self._advancing = None
        else:
            self._advancing = slot.index
        return spent

    # ------------------------------------------------------------------
    # decode / eviction
    # ------------------------------------------------------------------

    def _hit_limit(self, slot: Slot, req: Request) -> bool:
        if len(req.generated) >= req.max_new_tokens:
            return True
        # the next decode step writes its KV at index prompt_len + decode
        # steps so far (== device pos[slot], tracked host-side to avoid a
        # sync); at max_seq the slot is out of cache room
        if req.prompt_len + len(req.generated) - 1 >= self.layout.max_seq:
            return True
        return (req.eos_id is not None and bool(req.generated)
                and req.generated[-1] == req.eos_id)

    def _evict(self, slot: Slot) -> None:
        """State-agnostic slot release, safe at ANY lifecycle state:
        decref the slot's pages (zeroing on device only those no sharer
        or pin still holds), reset the token feed, return the row to
        EMPTY.  Bookkeeping that depends on how far the request got —
        finish timestamps, TTFT/ITL rows — is the caller's job: the DONE
        path records them, the cancel path records only cancel fields (a
        PREFILLING cancel has produced no tokens, so writing the DONE
        fields would fabricate latency rows).

        Eviction of the KV row itself is logical only: the physical reset
        (an O(cache) copy) happens once, at the next admission — both
        admission paths always reset_slot first, and per-slot valid masks
        keep the stale row invisible to live neighbors in the meantime."""
        self._release_pages(slot.index)
        self.tokens[slot.index, 0] = 0
        if self._advancing == slot.index:
            # the in-progress prefill reference must not dangle into a
            # row that now holds a different (or no) request
            self._advancing = None
        slot.request = None
        slot.prefill_pos = 0
        slot.state = SlotState.EMPTY

    def _finish(self, slot: Slot) -> None:
        req = slot.request
        req.finished_step = self.step_count
        req.finish_time = time.perf_counter()
        slot.state = SlotState.DONE
        self.finished.append(req)
        # chat sessions: pin the written history's page-aligned prefix
        # BEFORE eviction decrefs it, so the next turn finds it resident
        self._pin_history(slot, req)
        self._evict(slot)
        if req.on_finish is not None:
            req.on_finish(req)

    def _record_cancel(self, req: Request, state: str,
                       shed: bool = False) -> None:
        req.cancelled = True
        req.shed = shed
        req.cancel_state = state
        req.cancel_step = self.step_count
        req.cancel_time = time.perf_counter()
        self.cancelled.append(req)
        if req.on_finish is not None:
            req.on_finish(req)

    def cancel(self, rid: int) -> bool:
        """Cancel request ``rid`` wherever it is in its lifecycle.

        * still queued — removed from the admission queue;
        * PREFILLING — evicted mid-chunked-prefill: pages mapped so far
          (including any adopted prefix pages) are decrefed, shared pages
          survive for their other holders, and NO first-token/ITL
          bookkeeping is recorded (the state-aware-eviction contract);
        * DECODING — evicted mid-stream, same page discipline.

        Safe to call between scheduler steps at any time (the async
        server calls it on client disconnect).  Returns True if the
        request was found live/queued, False if it already finished,
        was already cancelled, or is unknown.
        """
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                self._record_cancel(req, "queued")
                return True
        for slot in self.slots:
            if slot.live and slot.request.rid == rid:
                req = slot.request
                state = ("prefilling" if slot.state is SlotState.PREFILLING
                         else "decoding")
                self._evict(slot)
                self._record_cancel(req, state)
                return True
        return False

    def _pin_history(self, slot: Slot, req: Request) -> None:
        """``keep_prefix_resident``: index + pin the page-aligned prefix
        of this request's *written* history (prompt + generated tokens
        whose KV landed — everything but the final sampled token) so a
        chat session's next turn can adopt it via the prefix index.  The
        pin ids land in ``req.pinned_pages``; release them with
        :meth:`unpin_pages` when the session moves on."""
        if (self.pager is None or self.layout.local_layers
                or not req.keep_prefix_resident):
            return
        written = req.prompt_len + len(req.generated) - 1
        hist = np.concatenate([
            np.asarray(req.prompt, np.int32),
            np.asarray(req.generated[:-1], np.int32),
        ])
        self.pager.register_prefix(slot.index, hist, written)
        npages = written // self.layout.page_size
        ids = tuple(int(p) for p in self.pager.table[slot.index, :npages]
                    if p >= 0)
        if npages > 0 and len(ids) == npages:
            self.pager.pin_pages(ids)
            req.pinned_pages = ids

    def unpin_pages(self, ids) -> None:
        """Release session pins taken by ``keep_prefix_resident``: decref
        each page, zero + free (on device) the ones no slot or other pin
        still holds — the same hygiene eviction applies."""
        if self.pager is None or not ids:
            return
        freed = self.pager.unpin_pages(ids)
        cap = self.layout.pages_per_slot
        for lo in range(0, len(freed), cap):
            buf = np.full(cap, -1, np.int32)
            chunk = freed[lo:lo + cap]
            buf[:len(chunk)] = chunk
            self.cache["global"] = self._zero_pages(
                self.cache["global"], jnp.asarray(buf)
            )
        self._sync_pages()

    # ------------------------------------------------------------------
    # speculative decoding (draft -> verify -> accept/rollback)
    # ------------------------------------------------------------------

    def _count_decode_step(self) -> None:
        """Account one physical serve_step: the kv/weight byte prices are
        static per-step totals, so draft, verify, and plain decode steps
        all pay the same — which is exactly what keeps the accounting laws
        (``decode_bytes == decode_steps * decode_bytes_per_step``) format-
        and speculation-invariant.  The speculative *win* shows up in the
        per-accepted-token columns, not by discounting the counter."""
        self.decode_steps += 1
        self.kv_bytes_read["decode"] += self._decode_read["total"]
        self.kv_bytes_read["interconnect"] += \
            self._decode_read["interconnect"]["total"]
        self.weight_bytes_read["decode"] += self._weight_read["total"]

    def _spec_round(self, live: List[Slot]) -> None:
        """One draft -> verify -> accept/rollback round for every DECODING
        slot (replaces the single batched decode step when spec decode is
        on).

        Drafts come from ``draft_fn(request, token_index)`` when given
        (the oracles' perfect/adversarial injection point) or from a
        ``gamma``-step chain of the compiled serve_step over the
        truncated-plane ``draft_params``.  Verification feeds the draft
        tokens through the REAL serve_step and picks each slot's true
        token from the exact logits (``_pick_token`` — forced or greedy),
        so every accepted token is bit-identical to what non-speculative
        decode would have produced; a slot leaves the chain at its first
        draft mismatch, after its corrected token.  Rollback then (1)
        rewinds every row's ``pos`` (live slots to their accepted
        frontier, every other row to its pre-round position), (2) invali-
        dates paged pages past the frontier (``PageAllocator.rewind_slot``
        + device page zeroing), and (3) zeroes the garbage tail rows
        across every store leaf, so no speculative write survives
        anywhere a later step could observe it."""
        gamma = self.spec.gamma
        B = self.layout.batch
        # pre-round frontier P: this round's first write position per slot
        P = {s.index: s.request.prompt_len + len(s.request.generated) - 1
             for s in live}
        reqs = {s.index: s.request for s in live}
        if self.pager is not None:
            for slot in live:
                p = P[slot.index]
                self.pager.ensure_range(
                    slot.index, p, min(p + gamma + 1, self.layout.max_seq)
                )
            self._sync_pages()
        # ---- draft: gamma proposed tokens per live slot --------------
        drafts: Dict[int, List[int]] = {i: [] for i in P}
        draft_steps = 0
        if self.draft_fn is not None:
            for slot in live:
                req = reqs[slot.index]
                n0 = len(req.generated)
                drafts[slot.index] = [
                    int(self.draft_fn(req, n0 + j)) for j in range(gamma)
                ]
        else:
            # draft chain on the live cache: greedy argmax fed forward;
            # its writes land past every frontier and are rolled back with
            # the rest of the round's speculation
            feed = self.tokens.copy()
            for _ in range(gamma):
                dlogits, self.cache = self.serve_step(
                    self.draft_params, self.cache, jnp.asarray(feed)
                )
                drows = np.asarray(dlogits[:, -1], np.float32)
                draft_steps += 1
                self._count_decode_step()
                for slot in live:
                    tok = int(np.argmax(drows[slot.index]))
                    drafts[slot.index].append(tok)
                    feed[slot.index, 0] = tok
            # undo the draft chain's pos drift before verification: the
            # verify chain must write/attend at the same positions a
            # non-speculative decode would
            self.cache["pos"] = self.cache["pos"] - jnp.asarray(
                gamma, self.cache["pos"].dtype
            )
        # ---- verify: feed drafts, accept while they match ------------
        active = {slot.index: slot for slot in live}
        accepted = {slot.index: 0 for slot in live}
        finishes: List[Slot] = []
        C = 0
        while active and C < gamma + 1:
            logits, self.cache = self.serve_step(
                self.serve_params, self.cache, jnp.asarray(self.tokens)
            )
            rows = np.asarray(logits[:, -1], np.float32)
            j, C = C, C + 1
            self._count_decode_step()
            self.spec_verify_steps += 1
            self.decoded_tokens += len(active)
            now = time.perf_counter()
            for idx in list(active):
                slot = active[idx]
                req = reqs[idx]
                tok = self._pick_token(req, rows[idx])
                req.generated.append(tok)
                req.token_times.append(now)
                accepted[idx] += 1
                # while the drafts match, the next feed IS the draft — the
                # chain teacher-forces the speculation through serve_step
                self.tokens[idx, 0] = tok
                if req.on_token is not None:
                    req.on_token(req, tok)
                if self._hit_limit(slot, req):
                    # finish AFTER rollback: _pin_history must only ever
                    # see pages the rewind kept
                    finishes.append(slot)
                    del active[idx]
                elif j < gamma and tok != drafts[idx][j]:
                    del active[idx]  # draft diverged; corrected token kept
        # ---- rollback -----------------------------------------------
        # live rows rewind to their accepted frontier P + a; every other
        # row (EMPTY garbage rows, mid-prefill slots) returns to its
        # pre-round position
        delta = np.full(B, C, np.int32)
        for slot in live:
            delta[slot.index] = C - accepted[slot.index]
        self.cache["pos"] = self.cache["pos"] - jnp.asarray(
            delta, self.cache["pos"].dtype
        )
        if self.pager is not None:
            freed: List[int] = []
            for slot in live:
                freed += self.pager.rewind_slot(
                    slot.index, P[slot.index] + accepted[slot.index]
                )
            cap = self.layout.pages_per_slot
            for lo in range(0, len(freed), cap):
                buf = np.full(cap, -1, np.int32)
                chunk = freed[lo:lo + cap]
                buf[:len(chunk)] = chunk
                self.cache["global"] = self._zero_pages(
                    self.cache["global"], jnp.asarray(buf)
                )
            self._sync_pages()
        # zero the garbage tail rows [P+a, P+extent) across every leaf —
        # pages the allocator freed were scrubbed wholesale above; this
        # covers the slot layout and the paged frontier page's tail
        extent = max(C, gamma if draft_steps else 0)
        tpos = np.full((B, gamma + 1), kvc.OOB_INDEX, np.int32)
        dirty = False
        for slot in live:
            lo = P[slot.index] + accepted[slot.index]
            hi = min(P[slot.index] + extent, self.layout.max_seq)
            if hi > lo:
                tpos[slot.index, :hi - lo] = np.arange(lo, hi)
                dirty = True
        if dirty and self._scrub_tokens is not None:
            if self.layout.layout == "paged":
                self.cache["global"] = self._scrub_tokens(
                    self.cache["global"], jnp.asarray(tpos),
                    self.cache["page_table"],
                )
            else:
                self.cache["global"] = self._scrub_tokens(
                    self.cache["global"], jnp.asarray(tpos)
                )
        # ---- bookkeeping + deferred finishes -------------------------
        self.spec_rounds += 1
        self.spec_draft_steps += draft_steps
        for slot in live:
            a = accepted[slot.index]
            req = reqs[slot.index]
            self.spec_accepted += a
            self.spec_drafted += gamma
            self.spec_slot_rounds += 1
            self.spec_max_accept = max(self.spec_max_accept, a)
            req.spec_accepts.append(a)
            req.spec_drafted += gamma
        for slot in finishes:
            self._finish(slot)

    def step(self) -> bool:
        """Admit/advance prefill, run one batched decode step, harvest,
        evict.

        Returns False when there was nothing to do (no live slot and no
        admissible request) — the caller's idle/termination signal.
        """
        self._shed_expired()
        if self.admission == "chunked":
            spent = self._advance_admission()
        else:
            spent = sum(r.prompt_len for r in self.admit())
        self.prefill_tokens_per_step.append(spent)
        busy = [s for s in self.slots if s.live]
        live = [s for s in self.slots if s.state is SlotState.DECODING]
        self.occupancy.append(len(busy) / len(self.slots))
        if not live:
            self.step_count += 1
            return bool(busy)  # prefill progress still counts as work
        if self.spec.enabled:
            # one draft -> verify -> accept/rollback round replaces the
            # single batched decode step (same harvesting, same eviction)
            self.step_count += 1
            self._spec_round(live)
            return True
        if self.pager is not None:
            for slot in live:
                # this decode step writes slot KV at the device pos
                # (tracked host-side): prompt_len + generated - 1
                r = slot.request
                p = r.prompt_len + len(r.generated) - 1
                self.pager.ensure_range(slot.index, p, p + 1)
            self._sync_pages()
        logits, self.cache = self.serve_step(
            self.serve_params, self.cache, jnp.asarray(self.tokens)
        )
        rows = np.asarray(logits[:, -1], np.float32)
        self.step_count += 1
        self.decode_steps += 1
        self.kv_bytes_read["decode"] += self._decode_read["total"]
        self.kv_bytes_read["interconnect"] += \
            self._decode_read["interconnect"]["total"]
        self.weight_bytes_read["decode"] += self._weight_read["total"]
        self.decoded_tokens += len(live)
        now = time.perf_counter()
        for slot in live:
            req = slot.request
            tok = self._pick_token(req, rows[slot.index])
            req.generated.append(tok)
            req.token_times.append(now)
            self.tokens[slot.index, 0] = tok
            if req.on_token is not None:
                req.on_token(req, tok)
            if self._hit_limit(slot, req):
                self._finish(slot)
        return True

    def run(self, max_steps: Optional[int] = None) -> Dict:
        """Drive steps until every submitted request finished (or the step
        budget runs out); returns :meth:`stats`."""
        t0 = time.perf_counter()
        while self.num_pending:
            if max_steps is not None and self.step_count >= max_steps:
                break
            self.step()
        return self.stats(time.perf_counter() - t0)

    def _tier_stats(self) -> Dict[str, Dict]:
        """Per-priority-tier SLO columns: finished/cancelled counts,
        preemptions suffered, and TTFT/ITL percentiles — the numbers an
        SLO dashboard keys on (interactive tail vs batch tail)."""
        tiers: Dict[str, Dict] = {}
        present = ({r.priority for r in self.finished}
                   | {r.priority for r in self.cancelled})
        for tier in sorted(present, key=priority_rank):
            fin = [r for r in self.finished if r.priority == tier]
            gaps = np.concatenate(
                [r.itl_gaps_s() for r in fin]
            ) if fin else np.asarray([])
            tiers[tier] = {
                "finished": len(fin),
                "cancelled": sum(
                    1 for r in self.cancelled if r.priority == tier
                ),
                "shed": sum(
                    1 for r in self.cancelled
                    if r.priority == tier and r.shed
                ),
                "preemptions": sum(r.preemptions for r in fin),
                "ttft_s": _percentiles(
                    r.ttft_s for r in fin if r.first_token_time > 0
                ),
                "itl_s": _percentiles(gaps),
            }
        return tiers

    def stats(self, wall_s: Optional[float] = None) -> Dict:
        """Aggregate serving metrics: throughput/occupancy, TTFT/ITL
        percentiles, per-request traces, paged-pool accounting (paged
        layouts), the ``kv_read`` counter — KV bytes the executed decode /
        chunk steps gathered, with the bgpp two-phase breakdown and the
        bf16-equivalent denominator — and its mirror ``weight_read`` —
        projection-weight bytes priced from the resolved
        ``weight_format``'s coded layout."""
        occ = [o for o in self.occupancy if o > 0] or self.occupancy
        gaps = np.concatenate(
            [r.itl_gaps_s() for r in self.finished]
        ) if self.finished else np.asarray([])
        out = {
            "admission": self.admission,
            "kv_layout": self.layout.layout,
            "finished_requests": len(self.finished),
            "decoded_tokens": self.decoded_tokens,
            "steps": self.step_count,
            "mean_occupancy": float(np.mean(occ)) if occ else 0.0,
            "max_prefill_tokens_per_step":
                max(self.prefill_tokens_per_step, default=0),
            "ttft_s": _percentiles(
                r.ttft_s for r in self.finished if r.first_token_time > 0
            ),
            "itl_s": _percentiles(gaps),
            "requests": [r.trace_record() for r in self.finished],
            # front-door columns: cancellation / preemption / per-tier SLO
            "cancelled_requests": len(self.cancelled),
            "shed_requests": sum(1 for r in self.cancelled if r.shed),
            "preemptions": self.preemptions,
            "tiers": self._tier_stats(),
            "cancelled": [r.cancel_record() for r in self.cancelled],
        }
        dr = self._decode_read
        out["kv_read"] = {
            "decode_bytes": round(self.kv_bytes_read["decode"]),
            "prefill_bytes": round(self.kv_bytes_read["prefill"]),
            "decode_steps": self.decode_steps,
            "decode_bytes_per_step": round(dr["total"]),
            "decode_bf16_equiv_bytes_per_step": round(dr["bf16_equiv"]),
            "decode_bytes_reduction_vs_bf16": round(
                dr["bf16_equiv"] / dr["total"], 3) if dr["total"] else None,
            # mesh columns: each device's share of the gathers, plus the
            # explicitly priced collectives (attend all-gather, paged write
            # broadcast) — zero / equal-to-total at mesh 1x1
            "mesh": {"data": self.mesh_shape[0], "model": self.mesh_shape[1]},
            "kv_shards": dr["per_device"]["shards"],
            "decode_bytes_per_device_per_step": round(
                dr["per_device"]["total"]),
            "interconnect_bytes_per_step": round(dr["interconnect"]["total"]),
            "interconnect_bytes": round(self.kv_bytes_read["interconnect"]),
            "interconnect": {
                n: round(v) for n, v in dr["interconnect"].items()
            },
        }
        wr = self._weight_read
        out["weight_read"] = {
            "weight_format": self.weight_format,
            "decode_bytes": round(self.weight_bytes_read["decode"]),
            "prefill_bytes": round(self.weight_bytes_read["prefill"]),
            "decode_steps": self.decode_steps,
            "decode_bytes_per_step": round(wr["total"]),
            "decode_bf16_equiv_bytes_per_step": round(wr["bf16_equiv"]),
            "decode_bytes_reduction_vs_bf16": round(
                wr["bf16_equiv"] / wr["total"], 3) if wr["total"] else None,
            # closed-form reconciliation (roofline.bstc_weight_traffic on
            # the measured per-plane column sparsities): the bench gates
            # measured/modeled at 1.0 ± 10%
            "modeled_bytes_per_step": round(wr["modeled"]),
            "measured_over_modeled": round(
                wr["total"] / wr["modeled"], 4) if wr["modeled"] else None,
            "per_projection": {
                n: round(v) for n, v in wr["per_projection"].items()
            },
            "mesh": {"data": self.mesh_shape[0], "model": self.mesh_shape[1]},
            "weight_shards": wr["per_device"]["shards"],
            "decode_bytes_per_device_per_step": round(
                wr["per_device"]["total"]),
        }
        if self.spec.enabled:
            acc = self.spec_accepted
            kvb = self.kv_bytes_read["decode"]
            wb = self.weight_bytes_read["decode"]
            wr_step = wr["total"]
            # what drafting at planes/8 of the weight bytes would cost: a
            # truncated-plane draft step streams only the kept MSB planes,
            # verify steps pay full freight.  With callback drafts there
            # are zero draft steps, so modeled == measured.
            modeled = (self.spec_draft_steps * wr_step
                       * self.spec.planes / 8.0
                       + self.spec_verify_steps * wr_step)
            out["spec"] = {
                "enabled": True,
                "gamma": self.spec.gamma,
                "draft_planes": self.spec.planes,
                "draft_source": ("callback" if self.draft_fn is not None
                                 else "planes"),
                "rounds": self.spec_rounds,
                "drafted_tokens": self.spec_drafted,
                "accepted_tokens": acc,
                "draft_steps": self.spec_draft_steps,
                "verify_steps": self.spec_verify_steps,
                "max_accepted_in_round": self.spec_max_accept,
                # THE acceptance rate: true tokens per physical serve_step
                # (draft + verify); 1.0 is the non-speculative baseline
                "accepted_tokens_per_step": round(
                    acc / self.decode_steps, 4) if self.decode_steps else None,
                "accepted_tokens_per_round": round(
                    acc / self.spec_slot_rounds, 4
                ) if self.spec_slot_rounds else None,
                # drafts that survived verification (each slot-round's
                # first accepted token is the free corrected one)
                "draft_hit_rate": round(
                    (acc - self.spec_slot_rounds) / self.spec_drafted, 4
                ) if self.spec_drafted else None,
                # the ISSUE's headline columns: decode-path bytes per
                # ACCEPTED token, next to kv_read/weight_read's per-step
                # prices (bytes/accepted == bytes/step / acceptance-rate)
                "kv_bytes_per_accepted_token": round(kvb / acc)
                if acc else None,
                "weight_bytes_per_accepted_token": round(wb / acc)
                if acc else None,
                "modeled_weight_bytes_per_accepted_token": round(modeled / acc)
                if acc else None,
            }
        if "bgpp" in dr:
            out["kv_read"]["bgpp"] = {
                n: round(v) if isinstance(v, float) else v
                for n, v in dr["bgpp"].items()
            }
        if self.pager is not None:
            pb = self._page_bytes
            out["paged"] = {
                "page_size": self.layout.page_size,
                "num_pages": self.layout.num_pages,
                "pages_allocated_total": self.pager.alloc_count,
                "pages_in_use": self.pager.pages_in_use,
                "prefix_hits": self.prefix_hits,
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "prefix_hit_rate": round(
                    self.prefix_hit_tokens
                    / max(1, self.prompt_tokens_admitted), 4
                ),
                "resident_kv_bytes_peak": self.pager.peak_pages * pb,
                # what the slot layout pins resident for the same traffic:
                # every slot's full (S_max,) row, hit or miss
                "slot_resident_kv_bytes":
                    self.layout.batch * self.layout.pages_per_slot * pb,
            }
        if wall_s is not None:
            out["wall_s"] = round(wall_s, 3)
            out["tokens_per_s"] = round(self.decoded_tokens / wall_s, 2) \
                if wall_s > 0 else None
        return out
