"""Bit-plane speculative decoding: draft with truncated MSB planes, verify
batched through ``serve_step``, accept/rollback per slot.

MCBP's thesis is that MSB bit-planes are cheap, informative predictors.
BGPP already uses them to prune what decode *reads*; this module extends
the same signal to token *drafting* (ROADMAP item 3): the draft scorer is
the ordinary compiled ``serve_step`` run with **truncated-plane weights**
— every projection quantized to int8 and keeping only the top
``draft_planes`` MSB magnitude bits (:func:`truncate_plane_params`), so a
draft forward models reading ``planes/8`` of the weight bytes while
reusing the exact compiled graph (same tree structure, shapes, dtypes —
no second compilation, and it composes with every ``weight_format``).

One speculative round per scheduler step (``Scheduler._spec_round``):

  1. **draft** — ``gamma`` serve_steps with the truncated weights (greedy
     argmax fed forward) propose ``gamma`` tokens per DECODING slot, then
     the draft chain's ``pos`` drift is rewound;
  2. **verify** — up to ``gamma + 1`` serve_steps with the REAL weights,
     feeding the *draft* tokens; each step's exact logits yield the true
     token through the scheduler's ``forced_tokens``/greedy
     ``_pick_token`` path, and a slot stays in the chain while its drafts
     keep matching (accepted tokens per slot per round: 1 — the corrected
     token — up to ``gamma + 1`` — all drafts plus the bonus token);
  3. **rollback** — per-slot ``pos`` rewind to the accepted frontier,
     paged pages past it decref'd/invalidated
     (:meth:`~repro.serving.paging.PageAllocator.rewind_slot` — generation
     counters make a freed page unresurrectable by stale prefix-index
     entries), and the garbage tail rows zeroed across every store leaf
     (:func:`~repro.serving.kv_cache.zero_token_range`).

Verification is greedy-argmax over exact logits, so speculative output is
**bit-identical** to non-speculative greedy decode — the fuzz oracle
(``tests/test_serving_fuzz.py``, ``spec_decode`` axis) enforces it across
kv-format × layout × admission, with adversarially-wrong drafts.

Why rollback is safe at all: ``serve_step`` is write-then-attend with
per-slot validity masks (``arange <= pos``) and OOB-scatter-drop writes,
so a position's stale contents are always overwritten in the same step
that first makes them visible; rewinding ``pos`` is therefore sufficient
on the slot layout, and the paged layout additionally needs the allocator
rewind so a freed/partially-written page can never service a later
prefix-index hit.

Supported on **global-only attention stacks** (same legality rule as
prefix reuse): sliding-window ring layers physically overwrite window
lanes on every speculative write, which no ``pos`` rewind can undo.  The
``REPRO_SPEC_DECODE`` env value means "speculative where supported" — an
env-driven enable soft-disables on a local-layer stack (CI matrices flip
one switch for the whole zoo), while an explicit config/kwarg enable
raises.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional

import jax
import jax.numpy as jnp

ENV_ENABLE = "REPRO_SPEC_DECODE"
ENV_GAMMA = "REPRO_DRAFT_GAMMA"
ENV_PLANES = "REPRO_DRAFT_PLANES"

_TRUE = ("1", "on", "true", "yes")
_FALSE = ("0", "off", "false", "no")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Resolved speculative-decoding knobs for one Scheduler build.

    ``source`` records where the *enable* decision came from (``"kwarg"``
    / ``"env"`` / ``"config"``) — :func:`validate` soft-disables an
    env-driven enable on unsupported stacks but hard-fails an explicit
    one.
    """

    enabled: bool
    gamma: int
    planes: int
    source: str


def _env_bool(var: str) -> Optional[bool]:
    raw = os.environ.get(var, "").strip().lower()
    if not raw:
        return None
    if raw in _TRUE:
        return True
    if raw in _FALSE:
        return False
    raise ValueError(
        f"${var}={raw!r} is not a boolean (use one of {_TRUE + _FALSE})"
    )


def _env_int(var: str) -> Optional[int]:
    raw = os.environ.get(var, "").strip()
    return int(raw) if raw else None


def resolve(cfg, enabled: Optional[bool] = None, gamma: Optional[int] = None,
            planes: Optional[int] = None) -> SpecConfig:
    """Resolve the spec-decode knobs: kwarg > env > config.

    Explicit Scheduler kwargs win so oracles can pin spec on/off per run
    regardless of the CI matrix; ``REPRO_SPEC_DECODE`` /
    ``REPRO_DRAFT_GAMMA`` / ``REPRO_DRAFT_PLANES`` override the config so
    nightly matrices can flip the whole zoo without touching configs —
    the same contract as ``weights.resolve`` / ``kernel_decode.resolve``.
    """
    mo = cfg.mcbp
    if enabled is not None:
        on, source = bool(enabled), "kwarg"
    else:
        env = _env_bool(ENV_ENABLE)
        if env is not None:
            on, source = env, "env"
        else:
            on, source = bool(getattr(mo, "spec_decode", False)), "config"
    g = gamma if gamma is not None else _env_int(ENV_GAMMA)
    if g is None:
        g = getattr(mo, "draft_gamma", 4)
    p = planes if planes is not None else _env_int(ENV_PLANES)
    if p is None:
        p = getattr(mo, "draft_planes", 4)
    g, p = int(g), int(p)
    if g < 1:
        raise ValueError(f"draft_gamma={g} must be >= 1")
    if not 1 <= p <= 8:
        raise ValueError(f"draft_planes={p} must be in 1..8")
    return SpecConfig(enabled=on, gamma=g, planes=p, source=source)


def validate(cfg, layout, spec: SpecConfig) -> SpecConfig:
    """Check a resolved :class:`SpecConfig` against (cfg, layout).

    Speculative decoding needs every attention layer rollback-safe, which
    only global stacks are (ring buffers overwrite window lanes on every
    speculative write — see the module docstring).  An env-driven enable
    on a local-layer stack returns a *disabled* copy (the nightly matrix
    semantics: "speculative where supported"); an explicit config/kwarg
    enable raises with the legality rule spelled out.
    """
    if not spec.enabled:
        return spec
    if getattr(layout, "local_layers", None):
        if spec.source == "env":
            return dataclasses.replace(spec, enabled=False)
        raise ValueError(
            "spec_decode=True needs a rollback-safe cache: sliding-window "
            "ring layers overwrite window lanes on every speculative write "
            f"(layout has local layers {layout.local_layers}).  Use a "
            "global-only attention stack, or leave spec_decode off — the "
            "same legality rule as paged prefix reuse."
        )
    return spec


def truncate_plane_params(params, planes: int):
    """Truncated-bit-plane draft weights: per-tensor symmetric int8
    quantization keeping only the top ``planes`` MSB magnitude bits.

    Every floating leaf is quantized at ``scale = max|w| / 127`` (int8: 7
    magnitude bits + sign), its magnitude masked to the ``planes`` most
    significant bits (``planes >= 7`` keeps all of int8 — the tree is
    returned unchanged, a *perfect* draft model), and dequantized back to
    the leaf's dtype.  The result has the exact tree structure, shapes
    and dtypes of ``params``, so the compiled ``serve_step`` executable
    is reused as the draft forward — and
    ``weights.prepare_serve_params`` applies on top for int8/bstc
    serving, exactly as for the real weights.
    """
    planes = int(planes)
    if not 1 <= planes <= 8:
        raise ValueError(f"draft_planes={planes} must be in 1..8")
    if planes >= 7:
        return params
    shift = 7 - planes

    def trunc(w):
        if not hasattr(w, "dtype") or not jnp.issubdtype(
            jnp.asarray(w).dtype, jnp.floating
        ):
            return w
        wf = jnp.asarray(w).astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(wf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int32)
        kept = jnp.right_shift(jnp.abs(q), shift) << shift
        return (jnp.sign(q) * kept * scale).astype(w.dtype)

    return jax.tree_util.tree_map(trunc, params)
