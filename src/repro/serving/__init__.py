from repro.serving import engine, kv_cache  # noqa: F401
