from repro.serving import engine, kv_cache, request, scheduler  # noqa: F401
