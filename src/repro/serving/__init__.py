"""Serving runtime: per-slot continuous batching over the MCBP decode
engine — KV-cache containers (slot and paged layouts), the chunked-prefill
admission path, the host-side page allocator with prefix reuse, and the
request scheduler.  See docs/ARCHITECTURE.md for the data-flow map."""

from repro.serving import (  # noqa: F401
    engine,
    kv_cache,
    paging,
    request,
    scheduler,
    server,
    weights,
)
