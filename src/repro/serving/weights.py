"""Serve-time weight formats: the ``weight_format`` knob made real.

``MCBPOptions.weight_format`` selects the numerics of the decode-time
projections (``wq``/``wk``/``wv``/``wo``, the dense MLP, ``lm_head``):

* ``"bf16"`` — the default: raw parameter leaves, every op bit-for-bit
  identical to the pre-knob engine (nothing here ever touches them);
* ``"int8"`` — per-output-channel symmetric int8 quantization.  Each
  projection leaf is replaced by a ``{"q": int8, "scale": f32}`` record;
  ``repro.models.layers.wdot`` dequantizes it at trace time, so the serve
  logits are pinned to the dense-reconstruction oracle (running the bf16
  path on the dequantized weights is bit-identical);
* ``"bstc"`` — the paper's BS-sparsity two-state coding.  The SAME int8
  records serve the values (BSTC is lossless over the int8 weight —
  ``reconstruct_dense_weight`` is a property-test law), while the
  :class:`WeightPlan` prices HBM traffic from the actual coded layout
  measured by ``repro.core.bstc.encode_weight`` / the
  ``repro.kernels.bstc_matmul`` operand prep.  ``prepare_serve_params``
  round-trips one matrix through the kernel family's compressed operands
  and asserts the reconstruction matches, so serve values genuinely pass
  through the BSTC code path rather than trusting the law blindly.

Resolution happens ONCE at ``make_serve_step`` build time
(:func:`resolve`), exactly like the ``decode_kernel`` knob: the config
value, overridden by the ``REPRO_WEIGHT_FORMAT`` env var for CI matrices.
An unknown value raises with the same actionable message style.

Accounting mirrors ``kv_cache.decode_read_bytes``: the scheduler holds a
:class:`WeightPlan` and accumulates its static per-step byte totals per
executed decode step into ``Scheduler.stats()["weight_read"]`` — totals,
a bf16-equivalent denominator, a per-projection breakdown, closed-form
modeled bytes (``repro.analysis.roofline.bstc_weight_traffic``, gated
against the measured coded bytes at 1.0 ± 10%), and mesh columns reusing
``kv_cache.mesh_shard_factors`` (wq/wk/wv and the vocab-sharded lm_head
are column-parallel on ``"model"``; wo and the MLP are replicated under
the bit-exact serving placement, so every device reads them whole).
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import roofline
from repro.configs.base import WEIGHT_FORMATS
from repro.core import bstc
from repro.serving import kv_cache as kvc

Tree = Dict[str, Any]

ENV_VAR = "REPRO_WEIGHT_FORMAT"
FORMATS = WEIGHT_FORMATS

# projection leaves the serve path converts (explicit names: biases and
# norms stay raw, MoE expert banks stay bf16 — a documented limitation)
_ATTN_WEIGHTS = ("wq", "wk", "wv", "wo")
_MLP_WEIGHTS = ("gate", "up", "down")


def resolve(cfg) -> str:
    """Resolve the ``weight_format`` knob to one of :data:`FORMATS`.

    ``REPRO_WEIGHT_FORMAT`` overrides the config so CI matrices can flip
    the weight path without touching configs — same contract as
    ``kernel_decode.resolve``.  The config value itself was validated at
    construction (``MCBPOptions.__post_init__``), so only env values can
    reach the error here.
    """
    knob = os.environ.get(ENV_VAR, "").strip() or getattr(
        cfg.mcbp, "weight_format", "bf16"
    )
    if knob not in FORMATS:
        raise ValueError(
            f"weight_format={knob!r} is not one of {FORMATS} (config "
            f"mcbp.weight_format or ${ENV_VAR})"
        )
    return knob


def validate(cfg) -> None:
    """Raise an actionable config-level error for unservable combinations.

    Called once at ``make_serve_step`` build time when the resolved format
    is not ``bf16`` — the converted-record path covers the transformer
    families the scheduler serves.
    """
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(
            f"weight_format={resolve(cfg)!r} covers the transformer serve "
            f"families (dense/moe/vlm); family={cfg.family!r} decodes with "
            f"raw bf16 weights — set weight_format='bf16'"
        )


def is_record(w) -> bool:
    """True for a ``{"q", "scale"}`` quantized-weight record leaf."""
    return isinstance(w, dict) and "q" in w and "scale" in w


def quantize(w) -> Tree:
    """Per-output-channel symmetric int8 record for a ``(..., in, out)``
    weight (leading axes = stacked layer copies).

    ``scale = max|w| / 127`` over the input (contraction) axis — exact
    elementwise math, so a column-sharded input yields an identically
    valued (and identically sharded) record.
    """
    wf = jnp.asarray(w).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=-2), 1e-12) / 127.0
    q = jnp.clip(jnp.round(wf / scale[..., None, :]), -127, 127)
    return {"q": q.astype(jnp.int8), "scale": scale}


def dequantize(record: Tree, dtype=jnp.float32) -> jax.Array:
    """The dense reconstruction ``layers.wdot`` contracts against — THE
    oracle the int8/bstc serve parity tests pin to."""
    return (
        record["q"].astype(jnp.float32)
        * record["scale"][..., None, :].astype(jnp.float32)
    ).astype(dtype)


def check_serve_params(params: Tree, cfg, fmt: str) -> None:
    """Trace-time structural check inside ``serve_step``: a non-bf16 build
    must receive converted records, never raw leaves (the pre-fix bug was
    exactly this silent pass-through)."""
    lay = params.get("layers", {})
    probe = lay.get("attn", {}).get("wq") if isinstance(lay, dict) else None
    if probe is not None and not is_record(probe):
        raise ValueError(
            f"serve_step was built with weight_format={fmt!r} but received "
            f"raw weight leaves — convert them first with "
            f"repro.serving.weights.prepare_serve_params(params, cfg, "
            f"layout) (the Scheduler does this automatically)"
        )


# --------------------------------------------------------------------------
# the weight-read plan — host-side byte accounting, kv_read's mirror
# --------------------------------------------------------------------------


@dataclasses.dataclass
class WeightEntry:
    """One converted projection (all its stacked layer copies).

    ``placement`` records the bit-exact serving shard: ``"heads"``
    (column-parallel on ``"model"`` via the head-aligned last axis),
    ``"vocab"`` (lm_head columns), or ``"replicated"`` (wo / MLP — their
    model-mapped axes sit on the contraction side, which the bit-exact
    policy never splits).  Byte columns cover ALL ``copies``.
    """

    path: str
    proj: str
    copies: int
    in_dim: int
    out_dim: int
    placement: str
    coded_bytes: float
    int8_bytes: float
    bf16_bytes: float
    modeled_bytes: float
    bstc_fallback: bool = False  # dims indivisible: priced as plain int8


@dataclasses.dataclass
class WeightPlan:
    """Static per-step weight traffic of one built serve path.

    The jitted ``serve_step`` contracts every converted projection exactly
    once per batched decode step (weights are step-invariant — this is the
    memory-bound half of decode), so per-step pricing is the plan's
    per-matrix coded bytes summed; the scheduler multiplies by executed
    steps, exactly like ``kv_read``.
    """

    fmt: str
    entries: List[WeightEntry]

    def _sum(self, col: str) -> float:
        return float(sum(getattr(e, col) for e in self.entries))

    @property
    def total_bytes(self) -> float:
        """Coded bytes one decode step reads across every converted matrix."""
        return self._sum("coded_bytes")

    @property
    def bf16_bytes(self) -> float:
        """What raw-dtype leaves of the same geometry would read."""
        return self._sum("bf16_bytes")

    def decode_read_bytes(self, layout, cfg,
                          mesh_shape: Tuple[int, int] = (1, 1)) -> Dict[str, Any]:
        """Weight bytes ONE batched ``serve_step`` reads, at static shapes.

        Mirrors :func:`repro.serving.kv_cache.decode_read_bytes`: totals,
        the bf16-equivalent denominator, per-projection breakdown, the
        closed-form modeled bytes, and mesh columns.  Sharding reuses
        :func:`repro.serving.kv_cache.mesh_shard_factors` — a ``"model"``
        axis splits only the column-parallel entries (heads-aligned and
        vocab-aligned last axes); replicated entries are read whole by
        every device, and weights never shard over ``"data"``.
        """
        _, m_eff = kvc.mesh_shard_factors(layout, cfg, mesh_shape)
        m = int(mesh_shape[1])
        m_vocab = m if m >= 1 and cfg.vocab_size % m == 0 else 1
        shards = {"heads": m_eff, "vocab": m_vocab, "replicated": 1}
        sharded = sum(
            e.coded_bytes for e in self.entries if shards[e.placement] > 1
        )
        replicated = self.total_bytes - sharded
        per_dev = sum(
            e.coded_bytes / shards[e.placement] for e in self.entries
        )
        per_proj: Dict[str, float] = {}
        for e in self.entries:
            per_proj[e.proj] = per_proj.get(e.proj, 0.0) + e.coded_bytes
        out: Dict[str, Any] = {
            "format": self.fmt,
            "total": self.total_bytes,
            "bf16_equiv": self.bf16_bytes,
            "int8_equiv": self._sum("int8_bytes"),
            "modeled": self._sum("modeled_bytes"),
            "per_projection": per_proj,
            "per_device": {
                "sharded": sharded / max(m_eff, m_vocab, 1),
                "replicated": replicated,
                "total": per_dev,
                "shards": m_eff,
            },
        }
        # exact per-placement split (the accounting-law surface): summing
        # per_device_by_placement[p] * shards[p] over placements recovers
        # the total, whatever mix of sharded/replicated entries exists
        out["per_device_by_placement"] = {
            p: sum(
                e.coded_bytes / shards[p]
                for e in self.entries if e.placement == p
            )
            for p in ("heads", "vocab", "replicated")
        }
        out["shards_by_placement"] = shards
        return out


# --------------------------------------------------------------------------
# serve-params preparation
# --------------------------------------------------------------------------


def _iter_targets(params: Tree) -> Iterator[Tuple[Tuple[str, ...], str, str]]:
    """Yield ``(path, proj_name, placement)`` for every convertible leaf
    present in the tree (explicit names only — biases/norms/MoE stay raw)."""
    lay = params.get("layers")
    if isinstance(lay, dict):
        attn = lay.get("attn")
        if isinstance(attn, dict):
            for n in _ATTN_WEIGHTS:
                if n in attn:
                    yield (("layers", "attn", n), n,
                           "replicated" if n == "wo" else "heads")
        mlp = lay.get("mlp")
        if isinstance(mlp, dict):
            for n in _MLP_WEIGHTS:
                if n in mlp:
                    yield (("layers", "mlp", n), n, "replicated")
    if "lm_head" in params:
        yield (("lm_head",), "lm_head", "vocab")


def _get(tree: Tree, path: Tuple[str, ...]):
    for k in path:
        tree = tree[k]
    return tree


def _set(tree: Tree, path: Tuple[str, ...], value) -> Tree:
    """Copy-on-write set: shallow-copies only the dicts along ``path`` so
    the caller's raw params tree is never mutated."""
    out = dict(tree)
    node = out
    for k in path[:-1]:
        node[k] = dict(node[k])
        node = node[k]
    node[path[-1]] = value
    return out


def _dtype_bytes(cfg) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def _bstc_matrix_bytes(q_np: np.ndarray, scale_np: np.ndarray,
                       cfg) -> Optional[Tuple[float, float]]:
    """Measured + modeled coded bytes of ONE ``(in, out)`` int8 matrix.

    Encodes the transposed ``(out, in)`` weight channel-major (scale is
    per output channel) with the paper's group size ``m``.  Returns
    ``None`` when the dims don't divide the coding grid (``out % m`` or
    ``in % 8``) — the caller prices that matrix as plain int8 instead of
    asserting, so odd smoke geometries still serve.
    """
    in_dim, out_dim = q_np.shape
    m = int(cfg.mcbp.group_size)
    if out_dim % m or in_dim % 8:
        return None
    bw = bstc.encode_weight(
        q_np.T.astype(np.int8), scale_np, m=m,
        threshold=float(cfg.mcbp.bstc_threshold),
    )
    coded = math.ceil(bw.encoded_bits / 8) + 4.0 * out_dim  # + f32 scales
    col_sparsity = [
        None if e is None else 1.0 - float(e.nnz.sum()) / e.bitmap.size
        for e in bw.encoded
    ]
    modeled = roofline.bstc_weight_traffic(
        in_dim, out_dim, m=m, nbits=bw.nbits, col_sparsity=col_sparsity,
        dtype_bytes=_dtype_bytes(cfg),
    )["bstc_bytes"]
    return float(coded), float(modeled)


def _kernel_roundtrip_check(q_np: np.ndarray, scale_np: np.ndarray,
                            cfg) -> None:
    """Round-trip ONE matrix through the ``bstc_matmul`` kernel family's
    compressed operands and assert the lossless reconstruction — pins the
    served values to the actual BSTC code path (the dense-reconstruction
    law, exercised on the real weights rather than assumed)."""
    from repro.kernels.bstc_matmul.ops import (
        prepare_bstc_matmul_operands, reconstruct_dense_weight,
    )

    in_dim, out_dim = q_np.shape
    m = int(cfg.mcbp.group_size)
    if out_dim % m or in_dim % 8:
        return
    ops = prepare_bstc_matmul_operands(
        q_np.T.astype(np.int8), scale_np, m=m, tile_k=in_dim,
        threshold=float(cfg.mcbp.bstc_threshold),
    )
    rebuilt = np.asarray(reconstruct_dense_weight(ops)).astype(np.int8)
    if not np.array_equal(rebuilt, q_np.T.astype(np.int8)):
        raise AssertionError(
            "BSTC round-trip mismatch: reconstruct_dense_weight did not "
            "recover the int8 weight — the coded layout cannot serve"
        )


def prepare_serve_params(params: Tree, cfg, layout,
                         fmt: Optional[str] = None) -> Tuple[Tree, WeightPlan]:
    """Convert decode-time projection leaves for ``fmt`` and price them.

    Returns ``(serve_params, plan)``.  ``fmt=None`` resolves from the
    config/env.  ``"bf16"`` returns the params object UNTOUCHED (the
    default path stays bit-for-bit) with a plan priced at raw-dtype bytes.
    ``"int8"``/``"bstc"`` replace each projection with a quantized record
    (elementwise jnp math, so sharded inputs keep their placement); tied
    embeddings get an explicit ``lm_head`` record derived from
    ``embed.T``, matching the engine's tied head read.  ``"bstc"`` serves
    the SAME records (lossless coding) but prices the measured coded
    layout, round-tripping the first matrix through the kernel operands.
    """
    fmt = resolve(cfg) if fmt is None else fmt
    if fmt not in FORMATS:
        raise ValueError(
            f"weight_format={fmt!r} is not one of {FORMATS} (config "
            f"mcbp.weight_format or ${ENV_VAR})"
        )
    dt = _dtype_bytes(cfg)
    entries: List[WeightEntry] = []
    tied_head = fmt != "bf16" and "lm_head" not in params \
        and "embed" in params
    serve = params
    checked_roundtrip = False

    targets = list(_iter_targets(params))
    for path, proj, placement in targets:
        w = _get(params, path)
        shape = tuple(w.shape)
        copies = int(np.prod(shape[:-2], dtype=np.int64)) if len(shape) > 2 else 1
        in_dim, out_dim = int(shape[-2]), int(shape[-1])
        bf16_b = float(dt * in_dim * out_dim * copies)
        int8_b = float((in_dim * out_dim + 4 * out_dim) * copies)
        if fmt == "bf16":
            entries.append(WeightEntry(
                path="/".join(path), proj=proj, copies=copies,
                in_dim=in_dim, out_dim=out_dim, placement=placement,
                coded_bytes=bf16_b, int8_bytes=int8_b, bf16_bytes=bf16_b,
                modeled_bytes=bf16_b,
            ))
            continue
        rec = quantize(w)
        serve = _set(serve, path, rec)
        coded_b, modeled_b, fell_back = int8_b, int8_b, False
        if fmt == "bstc":
            q_np = np.asarray(rec["q"]).reshape(copies, in_dim, out_dim)
            s_np = np.asarray(rec["scale"]).reshape(copies, out_dim)
            coded_b, modeled_b = 0.0, 0.0
            for c in range(copies):
                mb = _bstc_matrix_bytes(q_np[c], s_np[c], cfg)
                if mb is None:
                    coded_b += int8_b / copies
                    modeled_b += int8_b / copies
                    fell_back = True
                    continue
                coded_b += mb[0]
                modeled_b += mb[1]
                if not checked_roundtrip:
                    _kernel_roundtrip_check(q_np[c], s_np[c], cfg)
                    checked_roundtrip = True
        entries.append(WeightEntry(
            path="/".join(path), proj=proj, copies=copies,
            in_dim=in_dim, out_dim=out_dim, placement=placement,
            coded_bytes=float(coded_b), int8_bytes=int8_b,
            bf16_bytes=bf16_b, modeled_bytes=float(modeled_b),
            bstc_fallback=fell_back,
        ))

    # tied embeddings: the engine reads embed.T as the head — price it in
    # every format, and materialize a record for it on the quantized paths
    if "lm_head" not in params and "embed" in params:
        V, D = (int(s) for s in params["embed"].shape)
        bf16_b = float(dt * V * D)
        int8_b = float(V * D + 4 * V)
        if fmt == "bf16":
            entries.append(WeightEntry(
                path="embed.T", proj="lm_head", copies=1, in_dim=D,
                out_dim=V, placement="vocab", coded_bytes=bf16_b,
                int8_bytes=int8_b, bf16_bytes=bf16_b, modeled_bytes=bf16_b,
            ))
        elif tied_head:
            head = jnp.swapaxes(jnp.asarray(params["embed"]), -1, -2)
            rec = quantize(head)
            serve = _set(serve, ("lm_head",), rec)
            coded_b, modeled_b, fell_back = int8_b, int8_b, False
            if fmt == "bstc":
                mb = _bstc_matrix_bytes(
                    np.asarray(rec["q"]), np.asarray(rec["scale"]), cfg
                )
                if mb is not None:
                    coded_b, modeled_b = mb
                else:
                    fell_back = True
            entries.append(WeightEntry(
                path="embed.T", proj="lm_head", copies=1, in_dim=D,
                out_dim=V, placement="vocab", coded_bytes=float(coded_b),
                int8_bytes=int8_b, bf16_bytes=bf16_b,
                modeled_bytes=float(modeled_b), bstc_fallback=fell_back,
            ))

    return serve, WeightPlan(fmt=fmt, entries=entries)
