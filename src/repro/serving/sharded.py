"""Mesh placement for the serving stack — bit-exact by construction.

The serving oracle (tests/test_serving_fuzz.py) pins sharded runs to the
single-device trace *bit-exactly* for bf16 caches.  That rules out the
classic megatron placement wholesale: any weight whose model-mapped logical
axis sits on the *contraction* side of its matmul (``wo``'s heads, the MLP
down-projection's ff) would split a float reduction into a psum of shard
partials, and float addition is not associative.  What remains safe is pure
data movement:

* **output-side (column-parallel) weights** — ``wq``/``wk``/``wv`` carry
  HEADS/KV_HEADS on their *last* axis: each device computes its head slice
  with the full-width d_model contraction, bit-identical to the unsharded
  column.  Likewise ``lm_head``'s vocab columns.
* **gather-side tables** — the embedding's vocab axis: a sharded token
  lookup is a masked gather + an exact ``x + 0`` combine.
* **the attend itself** — with q/k/v and the KV pools sharded on the same
  head axis, every score/softmax/weighted-sum stays device-local per head;
  the per-head outputs are *all-gathered* (concatenated, never summed)
  across ``"model"`` before the replicated ``wo``.

Everything else — ``wo``, the MLP stack, norms — stays replicated.  This
module derives that placement from the model zoo's logical specs
(:func:`serving_param_specs`) and owns the host→device placement of params
and cache (:func:`shard_params` / :func:`shard_cache`) plus the mesh
bookkeeping the scheduler's kv-read accounting reports.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as sh
from repro.serving import kv_cache as kvc

# logical axes that may shard a weight only when they are the OUTPUT axis
# (last dim) of their matmul — contraction-side occurrences must replicate
_OUTPUT_ONLY = (sh.HEADS, sh.KV_HEADS)
# logical axes that are safe wherever they appear (gather / output side)
_ALWAYS = (sh.VOCAB,)


def mesh_shape(rules: sh.ShardingRules) -> Tuple[int, int]:
    """``(data, model)`` sizes of the rules' mesh (``(1, 1)`` when none)."""
    if rules is None or rules.mesh is None:
        return (1, 1)
    sizes = dict(rules.mesh.shape)
    data = 1
    for a in rules.batch_axes:
        data *= sizes.get(a, 1)
    return data, sizes.get(rules.model_axis, 1)


def serving_param_specs(specs):
    """Restrict a logical param-spec tree to the bit-exact serving subset.

    Keeps VOCAB anywhere and HEADS/KV_HEADS only on a leaf's last axis
    (column-parallel); every other logical axis is dropped to replicated.
    The result feeds :meth:`ShardingRules.tree_shardings`, whose
    divisibility fallback (with a :class:`~repro.distributed.sharding
    .ShardingFallbackWarning`) still applies per leaf.
    """

    def fix(axes):
        axes = tuple(axes)
        last = len(axes) - 1
        return tuple(
            a if a in _ALWAYS or (a in _OUTPUT_ONLY and i == last) else None
            for i, a in enumerate(axes)
        )

    return jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, tuple))


def shard_params(params, specs, rules: sh.ShardingRules):
    """Place params on the rules' mesh under the serving policy.

    ``specs`` is the model zoo's logical tree (``model_zoo.param_specs``);
    ``None`` replicates everything (still a valid, if traffic-heavy,
    bit-exact placement).  No-op without a mesh.
    """
    mesh = rules.mesh
    if mesh is None:
        return params
    if specs is None:
        rep = NamedSharding(mesh, P())
        return jax.device_put(params, jax.tree.map(lambda _: rep, params))
    shardings = rules.tree_shardings(
        mesh, serving_param_specs(specs), struct_tree=params
    )
    return jax.device_put(params, shardings)


def cache_shardings(cache, cfg, layout, rules: sh.ShardingRules):
    """NamedShardings for a serving cache: KV pools/stacks heads-parallel
    on ``"model"`` (batch over ``"data"`` for slot stacks), page table and
    ``pos`` replicated/host-synced.  ``None`` without a mesh."""
    if rules.mesh is None:
        return None
    return rules.tree_shardings(
        rules.mesh, kvc.cache_specs(cfg, layout), struct_tree=cache
    )


def shard_cache(cache, cfg, layout, rules: sh.ShardingRules):
    """Place a live cache onto the rules' mesh per ``cache_shardings``
    (identity when the rules carry no mesh)."""
    shardings = cache_shardings(cache, cfg, layout, rules)
    if shardings is None:
        return cache
    return jax.device_put(cache, shardings)


def replicated(x, rules: sh.ShardingRules):
    """Host value → mesh-replicated device array.  Always copies (callers
    hand in live, host-mutated buffers like the allocator's page table)."""
    if rules is None or rules.mesh is None:
        return jnp.asarray(np.asarray(x))
    return jax.device_put(np.asarray(x), NamedSharding(rules.mesh, P()))


def make_mesh(data: int, model: int) -> Mesh:
    """A ``("data", "model")`` mesh over the first ``data*model`` devices."""
    n = data * model
    avail = jax.device_count()
    if n > avail:
        raise ValueError(
            f"mesh {data}x{model} needs {n} devices, have {avail} — on CPU "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before importing jax"
        )
    devs = np.asarray(jax.devices()[:n]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


def parse_mesh_arg(arg: Optional[str]) -> Tuple[int, int]:
    """``"2,4"`` (or ``"2x4"``) → ``(2, 4)``; ``None``/empty → ``(1, 1)``."""
    if not arg:
        return (1, 1)
    parts = str(arg).replace("x", ",").split(",")
    if len(parts) != 2:
        raise ValueError(f"--mesh expects DATA,MODEL (got {arg!r})")
    d, m = int(parts[0]), int(parts[1])
    if d < 1 or m < 1:
        raise ValueError(f"--mesh sizes must be >= 1 (got {arg!r})")
    return d, m


def rules_for(data: int, model: int) -> sh.ShardingRules:
    """Serving rules over a fresh ``(data, model)`` debug mesh."""
    return sh.rules_for_mesh(make_mesh(data, model))
