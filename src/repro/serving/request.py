"""Request/slot dataclasses for the continuous-batching serving runtime.

A :class:`Request` is one user prompt plus its decode budget and the
timing/trace fields the scheduler fills in as the request moves through its
lifecycle.  A :class:`Slot` is one batch index of the live cache; its state
machine is

    EMPTY -> PREFILLING -> DECODING -> DONE -> (evicted) EMPTY
                  |             |
                  +-- cancel ---+--> CANCELLED -> (evicted) EMPTY

With chunked admission PREFILLING is a real multi-step state: the slot
stays in it while the scheduler feeds the prompt through fixed-shape
prefill chunks between batched decode steps, ``Slot.prefill_pos`` tracking
how many prompt tokens have been consumed.  Eager admission passes through
PREFILLING synchronously inside one ``admit()`` call.

``Scheduler.cancel(rid)`` can pull a request out at ANY lifecycle state —
still queued, mid-chunked-prefill, or decoding — releasing its pages and
recording only the bookkeeping its state actually produced (a PREFILLING
cancel has no first token, so no TTFT/ITL rows).  Requests also carry a
``priority`` tier (``interactive`` before ``batch``): the admission queue
is priority-ordered FIFO, and the chunked-prefill advance always picks the
highest-priority admitting slot, preempting an in-progress lower-tier
prefill (its ``prefill_pos`` freezes; it resumes at the same offset once
nothing above it is admitting).  ``deadline_steps`` is the SLO-aware
admission knob: a request still queued that many steps after arrival is
shed instead of admitted.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional

import numpy as np

# SLO tiers, best first: the admission queue and the chunked-prefill
# advance order both sort by PRIORITIES.index(request.priority)
PRIORITIES = ("interactive", "batch")


def priority_rank(priority: str) -> int:
    """Admission rank of a tier name (lower admits/advances first)."""
    try:
        return PRIORITIES.index(priority)
    except ValueError:
        raise ValueError(
            f"unknown priority {priority!r}: expected one of {PRIORITIES}"
        ) from None


class SlotState(enum.Enum):
    """Slot lifecycle states (EMPTY -> PREFILLING -> DECODING -> DONE,
    with cancellation folding any live state back to EMPTY)."""

    EMPTY = "empty"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"


@dataclasses.dataclass(eq=False)  # identity equality: prompt is an ndarray
class Request:
    """One serving request: a prompt and a max-new-tokens budget."""

    rid: int
    prompt: np.ndarray  # (S,) int32 token ids
    max_new_tokens: int
    arrival_step: int = 0  # scheduler step at which the request "arrives"
    eos_id: Optional[int] = None  # stop decoding on this token (after 1 tok)
    # teacher-forcing hook: when set, token t of the response is
    # forced_tokens[t] instead of the sampled token (logits are still
    # produced/recorded) — the serving oracles compare quantized formats
    # like-for-like per position without greedy compounding
    forced_tokens: Optional[np.ndarray] = None
    # SLO class: admission order and the per-tier stats() bucket
    priority: str = "interactive"
    # SLO-aware admission: shed (cancel unstarted) if still queued this
    # many steps after arrival.  None = wait forever.
    deadline_steps: Optional[int] = None
    # streaming hooks (the async server's transport): on_token fires once
    # per generated token, on_finish exactly once per request — at DONE
    # *or* at cancellation/shedding (check ``cancelled``)
    on_token: Optional[Callable[["Request", int], None]] = None
    on_finish: Optional[Callable[["Request"], None]] = None
    # chat sessions: at DONE, pin the page-aligned prefix of this
    # request's written history (prompt + generated KV) so the next turn's
    # prompt can adopt it from the sha1 prefix index (paged, global-only
    # layouts; the pin ids land in ``pinned_pages``)
    keep_prefix_resident: bool = False

    # --- filled in by the scheduler -----------------------------------
    generated: List[int] = dataclasses.field(default_factory=list)
    # prompt tokens whose KV was adopted from resident shared pages at
    # admission instead of prefilled (paged layouts with prefix reuse)
    prefix_reused_tokens: int = 0
    admitted_step: int = -1  # step at which a slot started prefilling this
    first_token_step: int = -1  # step at which prefill finished (token 1)
    finished_step: int = -1
    submit_time: float = -1.0  # wall-clock seconds (scheduler clock)
    admit_time: float = -1.0
    first_token_time: float = -1.0
    finish_time: float = -1.0
    token_times: List[float] = dataclasses.field(default_factory=list)
    # per-token logits rows (np.float32 (V,)), when Scheduler(record_logits=True)
    logit_rows: Optional[List[np.ndarray]] = None
    # cancellation bookkeeping (Scheduler.cancel / deadline shedding)
    cancelled: bool = False
    shed: bool = False  # cancelled by the admission deadline, never ran
    cancel_step: int = -1
    cancel_time: float = -1.0
    # lifecycle state at the moment of cancellation ("queued" /
    # "prefilling" / "decoding") — the fuzz oracle's coverage audit
    cancel_state: Optional[str] = None
    # times this request's in-progress chunked prefill lost the budget to
    # a higher-priority admitting slot
    preemptions: int = 0
    # page ids pinned at DONE for keep_prefix_resident (release with
    # Scheduler.unpin_pages when the session closes)
    pinned_pages: tuple = ()
    # speculative decoding: tokens accepted in each round this request
    # took part in (each entry in 1..gamma+1 — the corrected token alone
    # up to every draft plus the bonus token) and total drafts proposed
    spec_accepts: List[int] = dataclasses.field(default_factory=list)
    spec_drafted: int = 0

    @property
    def prompt_len(self) -> int:
        """Prompt length in tokens."""
        return int(len(self.prompt))

    @property
    def queue_wait_steps(self) -> int:
        """Scheduler steps spent queued between arrival and admission."""
        return self.admitted_step - self.arrival_step

    @property
    def latency_steps(self) -> int:
        """Scheduler steps from arrival to the last generated token."""
        return self.finished_step - self.arrival_step

    @property
    def ttft_s(self) -> float:
        """Submit -> first generated token (includes queue wait + prefill)."""
        return self.first_token_time - self.submit_time

    def itl_gaps_s(self) -> np.ndarray:
        """Inter-token latency samples (seconds between consecutive tokens)."""
        return np.diff(np.asarray(self.token_times, np.float64))

    def trace_record(self) -> dict:
        """JSON-serializable per-request trace entry (``--trace-out``)."""
        wall = self.finish_time - self.admit_time
        gaps = self.itl_gaps_s()
        return {
            "rid": self.rid,
            "priority": self.priority,
            "preemptions": self.preemptions,
            "prompt_len": self.prompt_len,
            "prefix_reused_tokens": self.prefix_reused_tokens,
            "new_tokens": len(self.generated),
            "arrival_step": self.arrival_step,
            "admitted_step": self.admitted_step,
            "first_token_step": self.first_token_step,
            "finished_step": self.finished_step,
            "queue_wait_steps": self.queue_wait_steps,
            "latency_steps": self.latency_steps,
            "queue_wait_s": round(self.admit_time - self.submit_time, 6),
            "ttft_s": round(self.ttft_s, 6),
            "mean_itl_s": round(float(np.mean(gaps)), 6) if gaps.size else None,
            "latency_s": round(self.finish_time - self.submit_time, 6),
            "tokens_per_s": round(len(self.generated) / wall, 3)
            if wall > 0 else None,
            "spec_rounds": len(self.spec_accepts),
            "spec_accepted_tokens": int(sum(self.spec_accepts)),
            "spec_drafted_tokens": self.spec_drafted,
        }

    def cancel_record(self) -> dict:
        """JSON-serializable trace entry for a cancelled/shed request."""
        return {
            "rid": self.rid,
            "priority": self.priority,
            "prompt_len": self.prompt_len,
            "tokens_before_cancel": len(self.generated),
            "cancel_state": self.cancel_state,
            "shed": self.shed,
            "arrival_step": self.arrival_step,
            "cancel_step": self.cancel_step,
        }


@dataclasses.dataclass
class Slot:
    """One batch index of the live cache."""

    index: int
    state: SlotState = SlotState.EMPTY
    request: Optional[Request] = None
    prefill_pos: int = 0  # prompt tokens consumed while PREFILLING

    @property
    def live(self) -> bool:
        """Whether the slot holds an admitted request (occupied capacity)."""
        return self.state in (SlotState.PREFILLING, SlotState.DECODING)


def poisson_trace(rng: np.random.Generator, n: int, vocab: int, max_new: int,
                  arrival_rate: float = 2.0, min_new: int = 2,
                  max_prompt: int = 23,
                  shared_prefix: int = 0) -> List[Request]:
    """Poisson-ish request trace shared by the launcher and the throughput
    benchmark: exponential inter-arrival gaps (in decode steps), prompt
    lengths ``min(8, max_prompt)..max_prompt``, decode budgets
    ``min(min_new, max_new)..max_new``.  Cap ``max_prompt`` below the
    cache's ``max_seq`` so every request is admissible.

    ``shared_prefix > 0`` prepends the same ``shared_prefix`` random tokens
    to every prompt — the shared-system-prompt workload the paged cache's
    prefix reuse targets (each request still gets its own random tail)."""
    lo = max(1, min(min_new, max_new))
    plo = max(1, min(8, max_prompt))
    prefix = rng.integers(0, vocab, (shared_prefix,)).astype(np.int32)
    reqs, step = [], 0
    for rid in range(n):
        step += int(rng.exponential(arrival_rate))
        tail = rng.integers(
            0, vocab, (int(rng.integers(plo, max_prompt + 1)),)
        ).astype(np.int32)
        reqs.append(Request(
            rid=rid,
            prompt=np.concatenate([prefix, tail]) if shared_prefix else tail,
            max_new_tokens=int(rng.integers(lo, max_new + 1)),
            arrival_step=step,
        ))
    return reqs
