"""Value-level top-k attention-sparsity prediction — the paper's baseline
(§2.2, Fig. 3): Pre-compute with 4-bit MSB keys, Top-k sort, Formal compute.

Implemented for the Fig. 5(g)/Fig. 17 comparisons and as the accelerator-
agnostic fallback path of the serving engine.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ValueTopKStats(NamedTuple):
    predict_bytes: jax.Array
    predict_ops: jax.Array


def quantize_msb(x: jax.Array, bits: int = 4, nbits: int = 8) -> jax.Array:
    """Keep the top ``bits`` of an int8-range tensor (drop low bits)."""
    shift = nbits - 1 - bits  # int8: 7 magnitude bits
    if shift <= 0:
        return x.astype(jnp.int32)
    x = x.astype(jnp.int32)
    return jnp.sign(x) * ((jnp.abs(x) >> shift) << shift)


def value_topk_predict(
    q: jax.Array,  # (D,) int
    k: jax.Array,  # (S, D) int8 keys
    k_keep: int,
    estimate_bits: int = 4,
) -> Tuple[jax.Array, jax.Array, ValueTopKStats]:
    """Estimate scores from ``estimate_bits``-MSB keys, select top-k indices.

    Traffic model: the estimate fetches all S keys at ``estimate_bits`` wide.
    Returns (indices (k_keep,), est scores (S,), stats).
    """
    S, D = k.shape
    k_est = quantize_msb(k, estimate_bits)
    est = (k_est @ q.astype(jnp.int32)).astype(jnp.float32)
    _, idx = jax.lax.top_k(est, k_keep)
    stats = ValueTopKStats(
        predict_bytes=jnp.asarray(S * D * estimate_bits / 8.0, jnp.float32),
        predict_ops=jnp.asarray(S * D, jnp.int32),
    )
    return idx, est, stats


def topk_mask(est: jax.Array, k_keep: int) -> jax.Array:
    """Boolean mask keeping the k largest entries along the last axis."""
    k_keep = min(k_keep, est.shape[-1])
    kth = jnp.sort(est, axis=-1)[..., -k_keep]
    return est >= kth[..., None]
