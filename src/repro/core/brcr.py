"""BRCR — BS-Repetitiveness-enabled Computation Reduction (paper §3.1).

Factorizes each m-row group of each weight bit-plane as ``W_g @ X = E @ (I @ X)``:

* ``I @ X`` (*merging*): every column of the group matrix is an m-bit pattern;
  columns sharing a pattern c have their activations accumulated into entry c
  of the Merged Activation Vector (MAV) ``Z`` (length 2**m).  A segment-sum —
  at most ``H × (1 - bs)`` adds; pattern-0 columns are free (zero bits).
* ``E @ Z`` (*reconstruction*): the enumeration matrix E (m × 2**m,
  ``E[j,c] = bit j of c``) rebuilds the m row results — at most
  ``m × 2**(m-1)`` adds, amortized across the whole H dimension.

Signs are handled by the disjoint split ``W = W⁺ − W⁻`` (see
``bitslice.signed_plane_split``); the merge-stage add count matches the ASIC's
signed-slice scheme exactly.

On TPU the MAV accumulation is expressed as a one-hot contraction so the MXU
plays the role of the paper's CAM + addition-merge units (DESIGN.md §2); the
Pallas kernel ``repro.kernels.brcr_gemm`` implements the tiled HBM→VMEM
version.  This module is the reference/composable implementation plus the
analytical-and-measured cost model used by the paper-figure benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitslice

DEFAULT_GROUP_SIZE = 4  # paper §5.2: m=4 balances CPR and CR
DEFAULT_NBITS = bitslice.WEIGHT_MAG_BITS


def merged_activation_vector(group_idx: jax.Array, x: jax.Array, m: int) -> jax.Array:
    """The I @ X step: scatter-accumulate activations by column pattern.

    group_idx: (G, H) int32 patterns in [0, 2**m);  x: (H, N).
    returns Z: (G, 2**m, N) with Z[g, c] = sum over {h : idx[g,h]=c} of x[h].
    """
    onehot = jax.nn.one_hot(group_idx, 2**m, dtype=x.dtype)  # (G, H, 2**m)
    return jnp.einsum("ghc,hn->gcn", onehot, x)


def reconstruct(z: jax.Array, m: int) -> jax.Array:
    """The E @ Z step: (G, 2**m, N) -> (G, m, N)."""
    e = bitslice.enumeration_matrix(m, dtype=z.dtype)  # (m, 2**m)
    return jnp.einsum("jc,gcn->gjn", e, z)


def _plane_matmul(mag: jax.Array, x: jax.Array, m: int, nbits: int) -> jax.Array:
    """Sum over bit planes of a non-negative magnitude matrix via BRCR."""
    planes = bitslice.bitplanes(mag, nbits)  # (k, M, H)
    M, H = mag.shape
    idx = bitslice.group_indices(planes, m)  # (k, M//m, H)
    k = nbits
    idx2 = idx.reshape(k * (M // m), H)
    z = merged_activation_vector(idx2, x, m)  # (k*G, 2**m, N)
    y = reconstruct(z, m)  # (k*G, m, N)
    y = y.reshape(k, M // m, m, x.shape[-1]).reshape(k, M, x.shape[-1])
    weights = jnp.asarray(2 ** np.arange(k), dtype=y.dtype).reshape(k, 1, 1)
    return jnp.sum(y * weights, axis=0)


def brcr_matmul(
    w_q: jax.Array,
    x: jax.Array,
    m: int = DEFAULT_GROUP_SIZE,
    nbits: int = DEFAULT_NBITS,
) -> jax.Array:
    """Exact ``w_q @ x`` computed through the BRCR factorization.

    w_q: (M, H) int8 (SM-representable, |w| < 2**nbits); x: (H, N) int or float.
    Bit-for-bit equal to the dense product when x is integer-valued.
    """
    pos, neg = bitslice.signed_plane_split(w_q)
    xf = x.astype(jnp.float32) if not jnp.issubdtype(x.dtype, jnp.floating) else x
    y = _plane_matmul(pos.astype(jnp.uint8), xf, m, nbits) - _plane_matmul(
        neg.astype(jnp.uint8), xf, m, nbits
    )
    if jnp.issubdtype(x.dtype, jnp.integer):
        return jnp.round(y).astype(jnp.int32)
    return y


# ---------------------------------------------------------------------------
# Cost model (paper §3.1 closed forms + measured counts from actual planes).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BRCRCost:
    """Operation counts for one (M, H) x (H, N) GEMM, per the paper's metric
    (additions; value-level INT8 MACs for the dense baseline)."""

    adds_merge: int  # measured: nonzero columns across groups/planes (x N)
    adds_reconstruct: int  # measured: E@Z adds over non-empty bins (x N)
    adds_total: int
    adds_bsc_baseline: int  # sparsity-aware bit-serial: k*H*m*(1-bs) per group
    macs_dense: int  # dense value-level INT8
    adds_value_sparse: int  # value-sparsity scheme: H*m*k*(1-vs)
    bit_sparsity: float
    value_sparsity: float
    reduction_vs_bsc: float
    reduction_vs_dense: float


def brcr_cost(
    w_q: jax.Array,
    n_cols: int = 1,
    m: int = DEFAULT_GROUP_SIZE,
    nbits: int = DEFAULT_NBITS,
) -> BRCRCost:
    """Measured op counts of BRCR on an actual weight matrix.

    Counting convention (paper Fig. 4/7): merging charges one ADD per nonzero
    column pattern; reconstruction charges ``popcount(E row ∩ non-empty bins)``
    adds per group row; everything scales linearly with the activation width N.
    """
    w = np.asarray(w_q).astype(np.int64)
    M, H = w.shape
    pos = np.maximum(w, 0).astype(np.uint8)
    neg = np.maximum(-w, 0).astype(np.uint8)

    adds_merge = 0
    adds_recon = 0
    nz_bits = 0
    for part in (pos, neg):
        for p in range(nbits):
            plane = (part >> p) & 1  # (M, H)
            nz_bits += int(plane.sum())
            grp = plane.reshape(M // m, m, H)
            patt = (grp * (1 << np.arange(m))[None, :, None]).sum(axis=1)  # (G,H)
            nz_cols = patt != 0
            adds_merge += int(nz_cols.sum())
            # non-empty bins per group -> reconstruction adds
            for g in range(M // m):
                bins = np.bincount(patt[g][nz_cols[g]], minlength=2**m) > 0
                e = ((np.arange(2**m)[None, :] >> np.arange(m)[:, None]) & 1).astype(
                    bool
                )
                hits = (e & bins[None, :]).sum(axis=1)
                adds_recon += int(np.maximum(hits - 1, 0).sum() + (hits > 0).sum())

    total_bits = 2 * nbits * M * H  # pos+neg planes
    bs = 1.0 - nz_bits / total_bits
    # Paper-comparable sparsity figures are on SM planes (not the split):
    mag_planes = np.stack([(np.abs(w) >> p) & 1 for p in range(nbits)])
    bs_sm = 1.0 - mag_planes.mean()
    vs = float((w == 0).mean())

    adds_bsc = int(round(nbits * H * m * (1.0 - bs_sm))) * (M // m)
    macs_dense = M * H
    adds_value = int(round(M * H * (1.0 - vs)))
    total = adds_merge + adds_recon
    return BRCRCost(
        adds_merge=adds_merge * n_cols,
        adds_reconstruct=adds_recon * n_cols,
        adds_total=total * n_cols,
        adds_bsc_baseline=adds_bsc * n_cols,
        macs_dense=macs_dense * n_cols,
        adds_value_sparse=adds_value * n_cols,
        bit_sparsity=float(bs_sm),
        value_sparsity=vs,
        reduction_vs_bsc=1.0 - total / max(adds_bsc, 1),
        reduction_vs_dense=1.0 - total / max(macs_dense, 1),
    )


def brcr_cost_closed_form(
    H: int, m: int, nbits: int, bit_sparsity: float
) -> Dict[str, float]:
    """Paper's closed form for an H×H GEMV: kH²/m·(1−bs) + kH·2^(m−1)."""
    merge = nbits * H * H / m * (1.0 - bit_sparsity)
    recon = nbits * H * (2 ** (m - 1))
    return {
        "adds_merge": merge,
        "adds_reconstruct": recon,
        "adds_total": merge + recon,
        "adds_bsc_baseline": nbits * H * H * (1.0 - bit_sparsity),
        "macs_dense": float(H * H),
    }


def optimal_group_size(
    H: int, nbits: int, bit_sparsity: float, m_range=range(1, 9)
) -> int:
    """DSE over m (paper Fig. 18): argmin of the closed-form total adds."""
    costs = {
        m: brcr_cost_closed_form(H, m, nbits, bit_sparsity)["adds_total"]
        for m in m_range
    }
    return min(costs, key=costs.get)
