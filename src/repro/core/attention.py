"""Attention primitives shared by the model zoo.

Supports the mask families the assigned architectures need:
  * causal                        (all decoder LMs)
  * sliding-window causal         (gemma3 local layers, mixtral SWA)
  * chunked-local causal          (llama4-scout iRoPE local layers)
  * bidirectional / cross         (whisper encoder + cross-attn, vlm prefix)
plus the MCBP sparse path: attention restricted to a predicted key set
(mask- or gather-based), used with BGPP/value-top-k predictors.

All softmaxes run in float32 regardless of input dtype (paper keeps softmax
in FP16; f32 is the TPU-native equivalent).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # avoids NaN from (-inf) - (-inf) in fully-masked rows


def causal_mask(s_q: int, s_k: int, offset: int = 0) -> jax.Array:
    """(s_q, s_k) bool; query i attends keys j <= i + offset."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_k)[None, :]
    return kj <= qi


def sliding_window_mask(s_q: int, s_k: int, window: int, offset: int = 0) -> jax.Array:
    """Causal ∧ (i + offset − j < window)."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_k)[None, :]
    return (kj <= qi) & (qi - kj < window)


def chunked_mask(s_q: int, s_k: int, chunk: int, offset: int = 0) -> jax.Array:
    """Causal within aligned chunks (llama4 local attention)."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_k)[None, :]
    return (kj <= qi) & (qi // chunk == kj // chunk)


def make_mask(kind: str, s_q: int, s_k: int, window: int = 0, offset: int = 0):
    if kind == "causal" or (kind in ("sliding", "chunked") and window <= 0):
        return causal_mask(s_q, s_k, offset)
    if kind == "sliding":
        return sliding_window_mask(s_q, s_k, window, offset)
    if kind == "chunked":
        return chunked_mask(s_q, s_k, window, offset)
    if kind == "full":
        return jnp.ones((s_q, s_k), bool)
    raise ValueError(f"unknown mask kind {kind!r}")


def prefix_causal_mask(s_q: int, s_k: int, prefix: int, offset: int = 0) -> jax.Array:
    """VLM mask: full attention within the (image) prefix, causal after."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_k)[None, :]
    return (kj <= qi) | ((qi < prefix) & (kj < prefix))


def attend(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hk, D)
    v: jax.Array,  # (B, Sk, Hk, D)
    mask: Optional[jax.Array] = None,  # broadcastable to (B, Hq, Sq, Sk)
    scale: Optional[float] = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    """GQA dot-product attention with f32 softmax. Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Hk = k.shape[2]
    group = Hq // Hk
    scale = (D**-0.5) if scale is None else scale

    qg = q.reshape(B, Sq, Hk, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if logit_softcap > 0.0:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        elif mask.ndim == 3:  # (B, Sq, Sk)
            mask = mask[:, None, None]
        elif mask.ndim == 4:  # (B, Hq, Sq, Sk) -> (B, Hk, group, Sq, Sk)
            mask = mask.reshape(B, Hk, group, Sq, -1)
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def blocked_attend(
    q: jax.Array,  # (B, Sq, Hq, D)
    k: jax.Array,  # (B, Sk, Hk, D)
    v: jax.Array,  # (B, Sk, Hk, D)
    *,
    mask_kind: str = "causal",
    window=0,  # int or traced scalar; 0 disables (also chunk size / prefix len)
    q_offset: int = 0,
    block_q: int = 1024,
    block_k: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax (flash-equivalent) attention in pure JAX.

    Never materializes the (Sq, Sk) logits: scans KV blocks with a running
    (max, denom, acc) carry, vmapped over Q blocks.  FLOPs/bytes match the
    Pallas kernel, so dry-run rooflines are faithful; real-TPU runs swap in
    ``repro.kernels.flash_attention``.  ``window`` may be a traced scalar so
    heterogeneous local/global layer stacks can share one compiled body.
    """
    B, Sq0, Hq, D = q.shape
    _, Sk0, Hk, _ = k.shape
    group = Hq // Hk
    scale = (D**-0.5) if scale is None else scale
    block_q = min(block_q, Sq0)
    block_k = min(block_k, Sk0)
    # pad to block multiples; padded keys are masked out, padded queries cut
    pq = (-Sq0) % block_q
    pk = (-Sk0) % block_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq, Sk = Sq0 + pq, Sk0 + pk
    nq, nk = Sq // block_q, Sk // block_k
    w = jnp.asarray(window, jnp.int32)

    # GQA: repeat K/V to the full query-head count UP FRONT.  Splitting Hq
    # into (Hk, group) inside the einsums breaks the sharded head dim (e.g.
    # 48 -> (8, 6) cannot carry a 16-way "model" sharding and GSPMD falls
    # back to replication + per-block all-reduces — §Perf iteration B1).
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    # (B, Hq, 1, nq, block_q, D) — the '1' keeps the carry structure below
    qg = q.reshape(B, nq, block_q, Hq, 1, D).transpose(0, 3, 4, 1, 2, 5)
    qg = qg.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def mask_fn(qi, kj):
        causal = kj <= qi
        if mask_kind == "full":
            return jnp.ones_like(causal)
        if mask_kind == "causal":
            return causal
        if mask_kind == "sliding":
            return causal & ((w <= 0) | (qi - kj < w))
        if mask_kind == "chunked":
            cw = jnp.maximum(w, 1)
            return causal & ((w <= 0) | (qi // cw == kj // cw))
        if mask_kind == "prefix_causal":
            return causal | ((qi < w) & (kj < w))
        raise ValueError(mask_kind)

    def kv_step(carry, ik):
        m_prev, l_prev, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kf, ik * block_k, block_k, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vf, ik * block_k, block_k, axis=1)
        # s: (B, Hk, group, nq, block_q, block_k)
        s = jnp.einsum("bhgnqd,bkhd->bhgnqk", qg, kb)
        qi = (
            q_offset
            + (jnp.arange(nq)[:, None] * block_q + jnp.arange(block_q)[None, :])
        )  # (nq, block_q)
        kj = ik * block_k + jnp.arange(block_k)  # (block_k,)
        msk = mask_fn(qi[..., None], kj[None, None, :])  # (nq, bq, bk)
        msk = msk & (kj < Sk0)[None, None, :]  # padded keys never attend
        s = jnp.where(msk[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum("bhgnqk,bkhd->bhgnqd", p, vb)
        return (m_new, l_new, acc), None

    init = (
        jnp.full((B, Hq, 1, nq, block_q, 1), NEG_INF, jnp.float32),
        jnp.zeros((B, Hq, 1, nq, block_q, 1), jnp.float32),
        jnp.zeros((B, Hq, 1, nq, block_q, D), jnp.float32),
    )
    step = jax.checkpoint(kv_step, prevent_cse=False)
    (m_f, l_f, acc), _ = jax.lax.scan(step, init, jnp.arange(nk))
    out = acc / jnp.maximum(l_f, 1e-30)
    out = out.transpose(0, 3, 4, 1, 2, 5).reshape(B, Sq, Hq, D)
    return out[:, :Sq0].astype(q.dtype)


def decode_attend(
    q: jax.Array,  # (B, Hq, D) single-step query
    k_cache: jax.Array,  # (B, S, Hk, D)
    v_cache: jax.Array,  # (B, S, Hk, D)
    valid: jax.Array,  # (B, S) bool — filled cache slots (∧ predicted set)
    scale: Optional[float] = None,
    logit_softcap: float = 0.0,
    head_mask: Optional[jax.Array] = None,  # (B, Hk, S) e.g. BGPP alive sets
) -> jax.Array:
    """One-token decode attention over a (possibly sparsified) KV cache."""
    out = attend(
        q[:, None],
        k_cache,
        v_cache,
        mask=_decode_mask(valid, head_mask, q.shape[1]),
        scale=scale,
        logit_softcap=logit_softcap,
    )
    return out[:, 0]


def _decode_mask(valid, head_mask, hq):
    B, S = valid.shape
    if head_mask is None:
        return valid[:, None, None, :]
    hk = head_mask.shape[1]
    group = hq // hk
    m = head_mask & valid[:, None, :]
    m = jnp.repeat(m, group, axis=1)  # (B, Hq, S)
    return m[:, :, None, :]  # (B, Hq, 1, S)


def gather_attend(
    q: jax.Array,  # (B, Hq, D)
    k_cache: jax.Array,  # (B, S, Hk, D)
    v_cache: jax.Array,  # (B, S, Hk, D)
    idx: jax.Array,  # (B, Hk, kmax) predicted key indices
    idx_valid: jax.Array,  # (B, Hk, kmax)
    scale: Optional[float] = None,
) -> jax.Array:
    """Formal-compute stage on a static-size gathered key set (paper Fig. 3).

    This is the real-savings path: only ``kmax`` K/V rows are touched.
    """
    B, Hq, D = q.shape
    Hk = k_cache.shape[2]
    group = Hq // Hk
    scale = (D**-0.5) if scale is None else scale

    bidx = jnp.arange(B)[:, None, None]
    # (B, Hk, kmax, D) gathered per kv head
    kg = k_cache[bidx, idx, jnp.arange(Hk)[None, :, None]]
    vg = v_cache[bidx, idx, jnp.arange(Hk)[None, :, None]]

    qg = q.reshape(B, Hk, group, D).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, kg.astype(jnp.float32)) * scale
    logits = jnp.where(idx_valid[:, :, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", probs, vg.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)
