"""Bit-slice (BS) decomposition utilities — the substrate of MCBP.

Terminology follows the paper: an INT-quantized k-bit tensor decomposes into k
1-bit *bit-slice* (plane) tensors.  Weights use **sign-magnitude (SM)** format
(paper §3.2) so the high-order magnitude planes expose their natural sparsity;
two's-complement planes of negative values would be dense (sign extension).

Plane numbering: plane ``p`` holds bit ``p`` of the magnitude, so plane 0 is the
LSB ("1st BS" in the paper) and plane ``nbits-1`` is the highest magnitude bit
("7th BS"); the sign is carried separately ("8th BS").
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# INT8 symmetric quantization range used throughout (paper clips to [-127,127]
# so magnitudes fit 7 bits).
WEIGHT_MAG_BITS = 7
INT8_MAX = 127


def to_sign_magnitude(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8/int32 tensor -> (sign, magnitude); sign is 1 where w < 0."""
    w = w.astype(jnp.int32)
    sign = (w < 0).astype(jnp.uint8)
    mag = jnp.abs(w).astype(jnp.uint8)
    return sign, mag


def from_sign_magnitude(sign: jax.Array, mag: jax.Array) -> jax.Array:
    return jnp.where(sign.astype(bool), -mag.astype(jnp.int32), mag.astype(jnp.int32))


def bitplanes(mag: jax.Array, nbits: int = WEIGHT_MAG_BITS) -> jax.Array:
    """Magnitude tensor -> stacked 1-bit planes, shape (nbits, *mag.shape).

    plane[p] = bit p of mag (LSB = plane 0).  dtype uint8 in {0,1}.
    """
    mag = mag.astype(jnp.uint8)
    shifts = jnp.arange(nbits, dtype=jnp.uint8).reshape((nbits,) + (1,) * mag.ndim)
    return (jnp.right_shift(mag[None], shifts) & jnp.uint8(1)).astype(jnp.uint8)


def from_bitplanes(planes: jax.Array) -> jax.Array:
    """Inverse of :func:`bitplanes`: (nbits, ...) planes -> magnitude."""
    nbits = planes.shape[0]
    weights = (2 ** np.arange(nbits)).astype(np.int32)
    weights = jnp.asarray(weights).reshape((nbits,) + (1,) * (planes.ndim - 1))
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=0)


def signed_plane_split(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Split an integer tensor into disjoint non-negative parts: w = pos - neg.

    This is the TPU-friendly realization of the paper's sign-decision unit:
    BRCR/BSTC operate on the two {0,1}-plane tensors independently and the
    results are subtracted.  The dominant (merge-stage) add count is identical
    to the ASIC's signed-slice scheme because the parts have disjoint support
    (DESIGN.md §2).
    """
    w = w.astype(jnp.int32)
    return jnp.maximum(w, 0), jnp.maximum(-w, 0)


def bit_sparsity(planes: jax.Array) -> jax.Array:
    """Fraction of zero bits per plane, shape (nbits,)."""
    nbits = planes.shape[0]
    flat = planes.reshape(nbits, -1)
    return 1.0 - jnp.mean(flat.astype(jnp.float32), axis=1)


def value_sparsity(w: jax.Array) -> jax.Array:
    return jnp.mean((w == 0).astype(jnp.float32))


def average_bit_sparsity(w_q: jax.Array, nbits: int = WEIGHT_MAG_BITS) -> jax.Array:
    """Paper's bs~: mean bit sparsity across magnitude planes (SM format)."""
    _, mag = to_sign_magnitude(w_q)
    return jnp.mean(bit_sparsity(bitplanes(mag, nbits)))


# ---------------------------------------------------------------------------
# Bit packing along an axis (bit-planar storage for the KV cache / weights).
# ---------------------------------------------------------------------------


def pack_bits(bits: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a {0,1} uint8 tensor 8:1 into uint8 along ``axis``.

    The axis length must be a multiple of 8.  Bit i of an output byte is
    element ``8*j + i`` of the input (little-endian within the byte).
    """
    axis = axis % bits.ndim
    n = bits.shape[axis]
    if n % 8 != 0:
        raise ValueError(f"pack_bits axis length {n} not a multiple of 8")
    moved = jnp.moveaxis(bits, axis, -1).astype(jnp.uint8)
    grouped = moved.reshape(moved.shape[:-1] + (n // 8, 8))
    weights = jnp.asarray([1, 2, 4, 8, 16, 32, 64, 128], dtype=jnp.uint8)
    packed = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32).astype(jnp.uint8)
    return jnp.moveaxis(packed, -1, axis)


def unpack_bits(packed: jax.Array, axis: int = -1) -> jax.Array:
    """Inverse of :func:`pack_bits` (uint8 -> 8x {0,1} uint8 along ``axis``)."""
    axis = axis % packed.ndim
    moved = jnp.moveaxis(packed, axis, -1)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (jnp.right_shift(moved[..., None], shifts) & jnp.uint8(1)).astype(jnp.uint8)
    bits = bits.reshape(moved.shape[:-1] + (moved.shape[-1] * 8,))
    return jnp.moveaxis(bits, -1, axis)


@dataclasses.dataclass(frozen=True)
class BitPlanarTensor:
    """A k-bit integer tensor stored as packed sign + magnitude bit planes.

    This is the storage format MCBP uses for the KV cache so BGPP rounds can
    fetch one plane at a time (MSB first).  ``mag_planes`` has shape
    ``(nbits, *shape[:-1], shape[-1]//8)`` uint8; ``sign`` likewise packed.
    """

    mag_planes: jax.Array
    sign: jax.Array
    nbits: int

    @property
    def plane_nbytes(self) -> int:
        return int(np.prod(self.mag_planes.shape[1:]))

    @classmethod
    def from_int(cls, w: jax.Array, nbits: int = WEIGHT_MAG_BITS) -> "BitPlanarTensor":
        sign, mag = to_sign_magnitude(w)
        planes = bitplanes(mag, nbits)
        return cls(
            mag_planes=pack_bits(planes, axis=-1),
            sign=pack_bits(sign, axis=-1),
            nbits=nbits,
        )

    def plane(self, p: int) -> jax.Array:
        """Unpacked {0,1} plane p (LSB = 0)."""
        return unpack_bits(self.mag_planes[p], axis=-1)

    def to_int(self) -> jax.Array:
        planes = unpack_bits(self.mag_planes, axis=-1)
        mag = from_bitplanes(planes)
        sign = unpack_bits(self.sign, axis=-1)
        return from_sign_magnitude(sign, mag)


def group_indices(planes: jax.Array, m: int) -> jax.Array:
    """Read m-row bit-plane groups as integer column patterns.

    planes: (..., M, H) {0,1} with M % m == 0.
    returns (..., M//m, H) int32 in [0, 2**m): pattern of each column where
    row j within the group contributes bit j.
    """
    *lead, M, H = planes.shape
    if M % m != 0:
        raise ValueError(f"rows {M} not divisible by group size {m}")
    g = planes.reshape(*lead, M // m, m, H).astype(jnp.int32)
    weights = (2 ** jnp.arange(m, dtype=jnp.int32)).reshape((m, 1))
    return jnp.sum(g * weights, axis=-2)


def enumeration_matrix(m: int, dtype=jnp.float32) -> jax.Array:
    """Paper's E: (m, 2**m) with E[j, c] = bit j of c."""
    c = np.arange(2**m, dtype=np.int64)
    e = ((c[None, :] >> np.arange(m)[:, None]) & 1).astype(np.float32)
    return jnp.asarray(e, dtype=dtype)
