"""MCBP core algorithms (paper's contribution as composable JAX modules).

- :mod:`repro.core.bitslice` — bit-slice decomposition / SM format / packing
- :mod:`repro.core.quantization` — W8A8 per-channel/per-tensor INT schemes
- :mod:`repro.core.brcr` — BS-repetitiveness GEMM reduction (§3.1)
- :mod:`repro.core.bstc` — two-state bit-plane weight coding (§3.2)
- :mod:`repro.core.bgpp` — bit-grained progressive top-k prediction (§3.3)
- :mod:`repro.core.topk` — value-level top-k baseline (§2.2)
- :mod:`repro.core.attention` — mask families + sparse attention paths
"""

from repro.core import attention, bgpp, bitslice, brcr, bstc, quantization, topk

__all__ = [
    "attention",
    "bgpp",
    "bitslice",
    "brcr",
    "bstc",
    "quantization",
    "topk",
]
