"""Integer quantization per the paper (§4.1, Fig. 11).

Weights: per-channel *symmetric* INT8 (scale only, clipped to [-127, 127] so
magnitudes fit 7 bits / SM format).  Activations: per-tensor *asymmetric*
(scale + zero point).  Output: ``Y = Scale ⊙ (W_q X_q) + Bias`` where the
zero-point correction folds into a per-output-channel bias computed from the
weight row sums (pre-known from calibration, Fig. 11b).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


class QuantizedWeight(NamedTuple):
    """Per-channel symmetric INT8 weight. ``q`` int8 (out, in); ``scale`` (out,)."""

    q: jax.Array
    scale: jax.Array

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale[:, None]


class QuantizedActivation(NamedTuple):
    """Per-tensor asymmetric INT8 activation: x_f ~= (q - zero_point) * scale."""

    q: jax.Array
    scale: jax.Array
    zero_point: jax.Array

    def dequantize(self) -> jax.Array:
        return (self.q.astype(jnp.float32) - self.zero_point) * self.scale


def quantize_weight(w: jax.Array, eps: float = 1e-8) -> QuantizedWeight:
    """Per-channel (dim 0 = output channel) symmetric INT8 quantization."""
    absmax = jnp.max(jnp.abs(w), axis=tuple(range(1, w.ndim)))
    scale = jnp.maximum(absmax, eps) / INT8_MAX
    q = jnp.clip(
        jnp.round(w / scale.reshape((-1,) + (1,) * (w.ndim - 1))),
        -INT8_MAX,
        INT8_MAX,
    ).astype(jnp.int8)
    return QuantizedWeight(q=q, scale=scale)


def quantize_activation(
    x: jax.Array,
    amin: Optional[jax.Array] = None,
    amax: Optional[jax.Array] = None,
    eps: float = 1e-8,
) -> QuantizedActivation:
    """Per-tensor asymmetric INT8.  (amin, amax) may come from calibration."""
    amin = jnp.min(x) if amin is None else amin
    amax = jnp.max(x) if amax is None else amax
    amin = jnp.minimum(amin, 0.0)  # keep 0 exactly representable
    amax = jnp.maximum(amax, 0.0)
    scale = jnp.maximum(amax - amin, eps) / 255.0
    zero_point = jnp.round(-amin / scale) - 128.0
    q = jnp.clip(jnp.round(x / scale + zero_point), -128, 127).astype(jnp.int8)
    return QuantizedActivation(q=q, scale=scale, zero_point=zero_point)


def int_matmul(w_q: jax.Array, x_q: jax.Array) -> jax.Array:
    """Exact INT32 GEMM of int8 operands: (M,K) @ (K,N) -> (M,N) int32."""
    return jax.lax.dot_general(
        w_q,
        x_q,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def quantized_linear(
    w: QuantizedWeight,
    x: QuantizedActivation,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Fig. 11b: Y_f = w_scale ⊙ x_scale · (W_q @ (X_q - Z_x)) [+ bias].

    The zero-point term W_q @ (Z_x · 1) = row_sum(W_q) · Z_x is a rank-1 bias.
    x.q is (K, N); returns (M, N) float32.
    """
    acc = int_matmul(w.q, x.q).astype(jnp.float32)
    row_sum = jnp.sum(w.q.astype(jnp.int32), axis=1).astype(jnp.float32)
    acc = acc - row_sum[:, None] * x.zero_point
    y = acc * (w.scale[:, None] * x.scale)
    if bias is not None:
        y = y + bias[:, None]
    return y


def fake_quantized_linear(w: jax.Array, x: jax.Array) -> jax.Array:
    """Quantize-dequantize reference (W8A8) for accuracy-fidelity benchmarks."""
    wq = quantize_weight(w)
    xq = quantize_activation(x)
    return quantized_linear(wq, xq)


def quantization_error(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(max_abs_err, rel_fro_err) of per-channel symmetric INT8 round-trip."""
    wq = quantize_weight(w)
    wd = wq.dequantize()
    err = jnp.abs(wd - w)
    rel = jnp.linalg.norm(wd - w) / jnp.maximum(jnp.linalg.norm(w), 1e-8)
    return jnp.max(err), rel
