"""BGPP — Bit-Grained Progressive Prediction (paper §3.3, Fig. 9).

Predicts the top-k attention-sparsity set with *bit-serial, MSB-first* scoring
of Keys and per-key early termination, so low-order Key bit-planes of
already-rejected Keys are never fetched from HBM.

Round r (r = 0 is the magnitude MSB):
  1. fetch plane ``nbits-1-r`` of the still-alive Keys (+ sign plane once);
  2. partial score  Â_r += 2^(nbits-1-r) · (q · signed_plane);
  3. threshold      θ_r = max_alive(Â_r) − α_r · radius      (paper Eq. 1)
     on the softmax-logit scale; keys with Â_r < θ_r are dropped and their
     remaining planes are never fetched (the early termination);
  4. clock-gate analogue (paper §4.5): if θ_r falls below the alive minimum
     the clipping step is skipped for the round (nothing would be pruned) and
     the filter proceeds to the next round.

Accounting mirrors the paper's IO model: prediction traffic is the bytes of
the fetched planes of alive keys only; the value-level baseline (§2.2, Fig. 3)
fetches a 4-bit MSB estimate of *every* key.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bitslice

DEFAULT_RADIUS = 3.0  # paper: "we empirically set the default radius to 3"
DEFAULT_ALPHA = 0.55  # paper §6: alpha in [0.5, 0.6]
DEFAULT_QUERY_BITS = 4  # paper precompute uses 4-bit MSB queries


class BGPPStats(NamedTuple):
    """Per-call traffic/ops accounting (summed over rounds)."""

    alive_per_round: jax.Array  # (nbits,) int32 (entries past `rounds` are 0)
    predict_bytes: jax.Array  # bytes fetched by the progressive filter
    value_topk_bytes: jax.Array  # value-level 4-bit baseline bytes
    full_bytes: jax.Array  # fetching every key at 8 bit
    predict_ops: jax.Array  # adder-tree adds executed


@dataclasses.dataclass(frozen=True)
class BGPPConfig:
    rounds: int = 4
    # target alpha; per-round alphas anneal 1.0 -> alpha (early partial
    # estimates are noisy, so early rounds prune conservatively — the
    # paper's per-round α_r control, §3.3)
    alpha: float = DEFAULT_ALPHA
    alpha_schedule: Optional[Tuple[float, ...]] = None  # overrides annealing
    radius: float = DEFAULT_RADIUS
    query_bits: int = DEFAULT_QUERY_BITS
    nbits: int = bitslice.WEIGHT_MAG_BITS
    # keep at least this many keys regardless of the threshold (0 = pure Eq.1)
    min_keys: int = 0

    def alphas(self, rounds: int) -> Tuple[float, ...]:
        if self.alpha_schedule is not None:
            s = tuple(self.alpha_schedule)
            return (s + (s[-1],) * rounds)[:rounds]
        start = max(1.0, self.alpha)
        if rounds == 1:
            return (self.alpha,)
        return tuple(
            start + (self.alpha - start) * r / (rounds - 1) for r in range(rounds)
        )


def _truncate_query(q: jax.Array, nbits: int, query_bits: int) -> jax.Array:
    """Keep the top ``query_bits`` magnitude bits of an int query (paper: 4b)."""
    shift = max(nbits - query_bits, 0)
    sign = jnp.sign(q)
    mag = (jnp.abs(q) >> shift) << shift
    return sign * mag


def bgpp_predict(
    q: jax.Array,
    k_planes: jax.Array,
    k_sign: jax.Array,
    cfg: BGPPConfig = BGPPConfig(),
    logit_scale: float | jax.Array = 1.0,
    valid: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, BGPPStats]:
    """Progressive bit-grained filter for one query against S keys.

    q:        (D,) int32 quantized query (full sign).
    k_planes: (nbits, S, D) uint8 magnitude planes of the quantized keys.
    k_sign:   (S, D) uint8.
    logit_scale: Δq·Δk/√d — converts integer partial scores to logit scale so
        the radius threshold (Eq. 1) operates on softmax-relevant units.
    valid:    optional (S,) bool mask of usable cache slots.

    Returns (alive_mask (S,), est_scores (S,) float32 logits, stats).
    """
    nbits, S, D = k_planes.shape
    rounds = min(cfg.rounds, nbits)
    qt = _truncate_query(q.astype(jnp.int32), cfg.nbits, cfg.query_bits)
    k_signed = jnp.where(k_sign.astype(bool), -1, 1).astype(jnp.int32)  # (S, D)
    plane_bytes = D / 8.0  # bit-planar packed storage: D bits per key-plane
    sign_bytes = S * D / 8.0  # sign plane fetched once for all keys

    alive0 = jnp.ones((S,), bool) if valid is None else valid.astype(bool)
    alphas = jnp.asarray(cfg.alphas(rounds), jnp.float32)

    def round_body(r, carry):
        alive, partial, bytes_acc, ops_acc, alive_hist = carry
        p = nbits - 1 - r
        plane = jnp.take(k_planes, p, axis=0).astype(jnp.int32) * k_signed
        contrib = (plane @ qt) * (2**p)  # (S,)
        partial = jnp.where(alive, partial + contrib, partial)
        n_alive = jnp.sum(alive)
        bytes_acc = bytes_acc + n_alive.astype(jnp.float32) * plane_bytes
        ops_acc = ops_acc + n_alive * D
        logits = partial.astype(jnp.float32) * logit_scale
        masked = jnp.where(alive, logits, -jnp.inf)
        theta = jnp.max(masked) - alphas[r] * cfg.radius
        min_alive = jnp.min(jnp.where(alive, logits, jnp.inf))
        gate = theta <= min_alive  # clock-gate: clipping skipped this round
        new_alive = jnp.where(gate, alive, alive & (logits >= theta))
        alive_hist = alive_hist.at[r].set(jnp.sum(new_alive))
        return (new_alive, partial, bytes_acc, ops_acc, alive_hist)

    carry = (
        alive0,
        jnp.zeros((S,), jnp.int32),
        jnp.asarray(sign_bytes, jnp.float32),
        jnp.asarray(S * D, jnp.int32),
        jnp.zeros((nbits,), jnp.int32),
    )
    alive, partial, bytes_acc, ops_acc, alive_hist = jax.lax.fori_loop(
        0, rounds, round_body, carry
    )

    est = partial.astype(jnp.float32) * logit_scale
    if cfg.min_keys:
        # never return fewer than min_keys candidates (accuracy floor)
        masked = jnp.where(alive0, est, -jnp.inf)
        kth = jnp.sort(masked)[-min(cfg.min_keys, S)]
        alive = alive | (masked >= kth)
    alive = alive & alive0

    stats = BGPPStats(
        alive_per_round=alive_hist,
        predict_bytes=bytes_acc,
        value_topk_bytes=jnp.asarray(S * D * 0.5, jnp.float32),  # 4-bit all keys
        full_bytes=jnp.asarray(S * D * 1.0, jnp.float32),
        predict_ops=ops_acc,
    )
    return alive, est, stats


def bgpp_predict_batched(
    q: jax.Array,  # (B, Hq, D) int32
    k_planes: jax.Array,  # (nbits, B, S, Hk, D)
    k_sign: jax.Array,  # (B, S, Hk, D)
    cfg: BGPPConfig = BGPPConfig(),
    logit_scale: float | jax.Array = 1.0,
    valid: Optional[jax.Array] = None,  # (B, S)
) -> Tuple[jax.Array, jax.Array]:
    """Batched decode-time predictor with GQA head sharing.

    Returns (alive (B, Hk, S) bool, est_scores (B, Hq, S)).  Query heads in the
    same KV group OR their alive sets (a key kept by any query head is kept —
    the conservative union the paper's per-head predictor implies for GQA).
    """
    B, Hq, D = q.shape
    nbits, _, S, Hk, _ = k_planes.shape
    group = Hq // Hk
    if valid is None:
        valid = jnp.ones((B, S), bool)

    def per_batch(qb, planes_b, sign_b, valid_b):
        # planes_b: (nbits, S, Hk, D) -> per-head (nbits, S, D)
        planes_h = jnp.transpose(planes_b, (2, 0, 1, 3))  # (Hk, nbits, S, D)
        sign_h = jnp.transpose(sign_b, (1, 0, 2))  # (Hk, S, D)
        qg = qb.reshape(Hk, group, D)

        def per_kv_head(qg_h, pl, sg):
            alive, est = jax.vmap(
                lambda qq: bgpp_predict(qq, pl, sg, cfg, logit_scale, valid_b)[:2]
            )(qg_h)
            return jnp.any(alive, axis=0), est  # union over the GQA group

        return jax.vmap(per_kv_head)(qg, planes_h, sign_h)

    alive, est = jax.vmap(per_batch, in_axes=(0, 1, 0, 0))(q, k_planes, k_sign, valid)
    return alive, est.reshape(B, Hq, S)


def alive_to_topk_indices(
    alive: jax.Array, est: jax.Array, k_max: int
) -> Tuple[jax.Array, jax.Array]:
    """Static-shape gather set: top ``k_max`` of the alive keys by est score.

    Returns (indices (..., k_max), validity mask).  Used by the serving engine
    so the formal-compute gather has a static shape.
    """
    masked = jnp.where(alive, est, -jnp.inf)
    vals, idx = jax.lax.top_k(masked, k_max)
    return idx, jnp.isfinite(vals)
