"""BSTC — BS-Sparsity-enabled Two-state Coding (paper §3.2).

Lossless compression of weight bit-planes in sign-magnitude format.  Each
m-bit *column* of a bit-plane group (the same m used by BRCR, so decode feeds
compute with no re-layout) is encoded as:

    all-zero column  ->  1'b0
    non-zero column  ->  {1'b1, m bits of the column pattern}

Encoded size of one (m × H) group-plane = ``H + m·nnz_cols`` bits vs ``m·H``
raw; CR > 1 whenever column sparsity is high enough (paper: bit sparsity
≳ 65%, true of magnitude planes 3–7 of INT8 LLM weights).  Planes whose
measured sparsity is below the threshold stay raw, as does the sign plane.

TPU adaptation (DESIGN.md §2): the ASIC's serial SIPO decoder becomes a
bitmap + prefix-sum + gather, which is fully vectorizable; offline encoding is
host-side numpy (the paper also compresses offline).  ``repro.kernels.
bstc_decode`` is the Pallas tile decompressor.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitslice

DEFAULT_SPARSITY_THRESHOLD = 0.65  # paper Fig. 8(b): CR>1 needs SR > ~65%


# ---------------------------------------------------------------------------
# Host-side (offline) encoding.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EncodedPlane:
    """One bit-plane of an (M, H) magnitude tensor, grouped into M//m rows.

    bitmap:   (M//m, H) uint8 {0,1} — the two-state indicators.
    patterns: (M//m, cap) uint8 — non-zero column patterns, row-padded to the
              max nnz across group rows (static shape for JAX decode).
    nnz:      (M//m,) int32 — valid prefix length per group row.
    """

    bitmap: np.ndarray
    patterns: np.ndarray
    nnz: np.ndarray
    m: int
    encoded_bits: int  # exact stream length: sum(H + m*nnz_g)
    raw_bits: int

    @property
    def storage_bits(self) -> int:
        """Bits of the padded on-device representation (bitmap + patterns)."""
        return self.bitmap.size + self.patterns.size * self.m


def encode_plane(plane: np.ndarray, m: int) -> EncodedPlane:
    """plane: (M, H) {0,1}.  Groups m rows; encodes columns two-state."""
    M, H = plane.shape
    if M % m:
        raise ValueError(f"rows {M} not divisible by m={m}")
    grp = plane.reshape(M // m, m, H).astype(np.uint8)
    patt = (grp * (1 << np.arange(m, dtype=np.uint32))[None, :, None]).sum(
        axis=1
    )  # (G, H) patterns
    bitmap = (patt != 0).astype(np.uint8)
    nnz = bitmap.sum(axis=1).astype(np.int32)
    cap = max(int(nnz.max()), 1)
    patterns = np.zeros((M // m, cap), dtype=np.uint8)
    for g in range(M // m):
        vals = patt[g][bitmap[g] != 0]
        patterns[g, : len(vals)] = vals
    encoded_bits = int(bitmap.size + m * nnz.sum())
    return EncodedPlane(
        bitmap=bitmap,
        patterns=patterns,
        nnz=nnz,
        m=m,
        encoded_bits=encoded_bits,
        raw_bits=M * H,
    )


def expand_patterns(patt: jax.Array, m: int) -> jax.Array:
    """(G, H) m-bit group patterns -> (G*m, H) {0,1} uint8 plane rows.

    Bit j of the pattern for group g is row ``g*m + j`` — the single place
    that encodes the group-row bit order (decode_plane, the kernel ref
    paths, and the round-trip property tests all share it).
    """
    G, H = patt.shape
    shifts = jnp.arange(m, dtype=jnp.int32).reshape(1, m, 1)
    patt = jnp.asarray(patt).astype(jnp.int32)
    bits = (jnp.right_shift(patt[:, None, :], shifts) & 1).astype(jnp.uint8)
    return bits.reshape(G * m, H)


def decode_plane(enc: EncodedPlane) -> jax.Array:
    """JAX-traceable inverse of :func:`encode_plane` -> (M, H) uint8 planes.

    prefix-sum addressing: position of column h's pattern in the packed
    stream is ``cumsum(bitmap)[h] - 1``; zero columns gather slot 0 and are
    masked out.  This is the vectorized form of the SIPO decoder.
    """
    bitmap = jnp.asarray(enc.bitmap)  # (G, H)
    patterns = jnp.asarray(enc.patterns)  # (G, cap)
    pos = jnp.cumsum(bitmap.astype(jnp.int32), axis=1) - 1
    pos = jnp.clip(pos, 0, patterns.shape[1] - 1)
    vals = jnp.take_along_axis(patterns, pos.astype(jnp.int32), axis=1)
    patt = jnp.where(bitmap != 0, vals, 0).astype(jnp.int32)  # (G, H)
    return expand_patterns(patt, enc.m)


# ---------------------------------------------------------------------------
# Whole-weight container.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BSTCWeight:
    """A per-channel-symmetric INT8 weight stored bit-slice-first.

    Magnitude planes are individually either BSTC-encoded (sparse high-order
    planes) or raw packed bits; the sign plane is always raw (paper Fig. 8:
    planes 1–2 and sign stay uncompressed).
    """

    shape: Tuple[int, int]
    m: int
    nbits: int
    scale: np.ndarray  # (M,) float32 per-channel scale
    encoded: List[Optional[EncodedPlane]]  # per plane; None => raw
    raw_planes: List[Optional[np.ndarray]]  # packed uint8 (M, H//8) when raw
    sign: np.ndarray  # packed uint8 (M, H//8)
    plane_sparsity: np.ndarray  # (nbits,) measured SM bit sparsity

    @property
    def raw_bits(self) -> int:
        return 8 * self.shape[0] * self.shape[1]

    @property
    def encoded_bits(self) -> int:
        bits = self.shape[0] * self.shape[1]  # sign plane
        for p in range(self.nbits):
            enc = self.encoded[p]
            bits += enc.encoded_bits if enc is not None else self.shape[0] * self.shape[1]
        return bits

    @property
    def compression_ratio(self) -> float:
        return self.raw_bits / self.encoded_bits

    @property
    def hbm_bytes(self) -> int:
        """Bytes of the actual on-device arrays (padded representation)."""
        b = self.sign.size
        for p in range(self.nbits):
            enc = self.encoded[p]
            if enc is None:
                b += self.raw_planes[p].size
            else:
                b += enc.bitmap.size // 8 + enc.patterns.size  # bitmap packable 8:1
        return b


def encode_weight(
    w_q: np.ndarray,
    scale: np.ndarray,
    m: int = 4,
    nbits: int = bitslice.WEIGHT_MAG_BITS,
    threshold: float = DEFAULT_SPARSITY_THRESHOLD,
    force_planes: Optional[List[int]] = None,
) -> BSTCWeight:
    """Offline BSTC compression of an int8 (M, H) weight.

    ``force_planes`` pins the compressed set (paper default: planes 2..6,
    i.e. "bits 3–7"); otherwise a plane is compressed iff doing so actually
    shrinks it (encoded_bits < raw_bits) *and* its bit sparsity clears
    ``threshold`` — the paper's Fig. 8 rule, made robust to distributions
    where 65% bit sparsity still yields too few all-zero columns.
    """
    w = np.asarray(w_q).astype(np.int32)
    M, H = w.shape
    sign = (w < 0).astype(np.uint8)
    mag = np.abs(w).astype(np.uint8)
    planes = np.stack([(mag >> p) & 1 for p in range(nbits)]).astype(np.uint8)
    sparsity = 1.0 - planes.reshape(nbits, -1).mean(axis=1)

    encoded: List[Optional[EncodedPlane]] = []
    raw_planes: List[Optional[np.ndarray]] = []
    for p in range(nbits):
        if force_planes is not None:
            enc = encode_plane(planes[p], m) if p in force_planes else None
        elif sparsity[p] >= threshold:
            enc = encode_plane(planes[p], m)
            if enc.encoded_bits >= enc.raw_bits:  # would expand: keep raw
                enc = None
        else:
            enc = None
        encoded.append(enc)
        raw_planes.append(None if enc is not None else _pack8(planes[p]))
    return BSTCWeight(
        shape=(M, H),
        m=m,
        nbits=nbits,
        scale=np.asarray(scale, dtype=np.float32),
        encoded=encoded,
        raw_planes=raw_planes,
        sign=_pack8(sign),
        plane_sparsity=sparsity.astype(np.float32),
    )


def decode_weight(bw: BSTCWeight) -> jax.Array:
    """JAX-traceable exact reconstruction -> int8 (M, H)."""
    M, H = bw.shape
    planes = []
    for p in range(bw.nbits):
        if bw.encoded[p] is not None:
            planes.append(decode_plane(bw.encoded[p]))
        else:
            planes.append(bitslice.unpack_bits(jnp.asarray(bw.raw_planes[p]), axis=-1))
    mag = bitslice.from_bitplanes(jnp.stack(planes))
    sign = bitslice.unpack_bits(jnp.asarray(bw.sign), axis=-1)
    return bitslice.from_sign_magnitude(sign, mag).astype(jnp.int8)


def _pack8(bits: np.ndarray) -> np.ndarray:
    """numpy 8:1 bit packing along the last axis (little-endian)."""
    *lead, n = bits.shape
    assert n % 8 == 0, n
    b = bits.reshape(*lead, n // 8, 8).astype(np.uint32)
    return (b * (1 << np.arange(8, dtype=np.uint32))).sum(axis=-1).astype(np.uint8)


def compression_ratio_closed_form(m: int, col_sparsity: float) -> float:
    """CR = mH / (H + m·nnz) with nnz = (1-sc)·H  (paper Fig. 8b curves)."""
    return m / (1.0 + m * (1.0 - col_sparsity))


def expected_column_sparsity(bit_sparsity: float, m: int) -> float:
    """Under independent bits, P(column of m bits all zero) = bs**m."""
    return bit_sparsity**m
