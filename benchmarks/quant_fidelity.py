"""Paper Table 2 (proxy): numerical fidelity of the MCBP pipeline vs FP.

No pretrained checkpoints ship in this container, so accuracy is proxied by
output-error metrics the paper's lossless claims imply:

  * BRCR/BSTC are *exact* on INT8 (bit-for-bit) — verified here end-to-end;
  * W8A8 per-channel/per-tensor quantized linear vs FP32 relative error;
  * BGPP standard config: top-k recall + attention-output error on a
    synthetic attention task (the component the paper measures as <=1%
    accuracy delta under the aggressive config).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import attention, bgpp, brcr, bstc, quantization
from repro.utils.synthetic import synthetic_llm_weight, synthetic_llm_weight_int8


def run():
    rng = np.random.default_rng(6)

    # lossless path: dense INT8 == BRCR(BSTC(w))
    w_q, scale = synthetic_llm_weight_int8(rng, (32, 1024))
    bw = bstc.encode_weight(w_q, scale)
    w_rt = np.asarray(bstc.decode_weight(bw))
    exact_codec = bool((w_rt == w_q).all())
    x = jnp.asarray(rng.integers(-50, 50, size=(1024, 4)), jnp.int32)
    y_brcr = brcr.brcr_matmul(jnp.asarray(w_q), x, m=4)
    y_ref = np.asarray(w_q, np.int64) @ np.asarray(x, np.int64)
    exact_brcr = bool((np.asarray(y_brcr, np.int64) == y_ref).all())
    emit("tab2_lossless_bstc_roundtrip", 0.0, f"exact={exact_codec}")
    emit("tab2_lossless_brcr_gemm", 0.0, f"exact={exact_brcr}")

    # W8A8 linear fidelity
    w = jnp.asarray(synthetic_llm_weight(rng, (256, 512)))
    xf = jnp.asarray(rng.normal(size=(512, 64)), jnp.float32)
    y_fp = w @ xf
    y_q = quantization.quantized_linear(
        quantization.quantize_weight(w), quantization.quantize_activation(xf)
    )
    rel = float(
        jnp.linalg.norm(y_q - y_fp) / jnp.maximum(jnp.linalg.norm(y_fp), 1e-9)
    )
    emit("tab2_w8a8_linear_rel_err", 0.0, f"rel={rel:.4f}")

    # BGPP attention-output error at the paper's standard alpha
    B, S, H, D = 1, 512, 4, 64
    kf = rng.normal(size=(B, S, H, D)).astype(np.float32)
    vf = rng.normal(size=(B, S, H, D)).astype(np.float32)
    qf = rng.normal(size=(B, 1, H, D)).astype(np.float32)
    out_full = attention.attend(
        jnp.asarray(qf), jnp.asarray(kf), jnp.asarray(vf)
    )

    k_int = np.clip(np.round(kf * 40), -127, 127).astype(np.int32)
    q_int = jnp.asarray(np.clip(np.round(qf[0, 0] * 40), -127, 127), jnp.int32)
    errs, keeps = [], []
    for h in range(H):
        sign = jnp.asarray((k_int[0, :, h] < 0).astype(np.uint8))
        mag = np.abs(k_int[0, :, h]).astype(np.uint8)
        planes = jnp.asarray(
            np.stack([(mag >> p) & 1 for p in range(7)]).astype(np.uint8)
        )
        alive, _, _ = bgpp.bgpp_predict(
            q_int[h], planes, sign,
            bgpp.BGPPConfig(rounds=4, alpha=0.55),
            logit_scale=1.0 / (40 * 40 * np.sqrt(D)),
        )
        mask = np.asarray(alive)
        keeps.append(mask.mean())
        logits = (qf[0, 0, h] @ kf[0, :, h].T) / np.sqrt(D)
        logits_m = np.where(mask, logits, -1e30)
        p_f = np.exp(logits - logits.max()); p_f /= p_f.sum()
        p_m = np.exp(logits_m - logits_m.max()); p_m /= p_m.sum()
        errs.append(np.abs(p_m @ vf[0, :, h] - p_f @ vf[0, :, h]).max())
    emit(
        "tab2_bgpp_attention_err", 0.0,
        f"max_abs={max(errs):.4f};kept_frac={np.mean(keeps):.3f};alpha=0.55",
    )
