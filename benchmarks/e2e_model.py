"""Paper Fig. 20/21 (modeled): end-to-end speedup/efficiency breakdown.

The paper's throughput/energy wins are ASIC-vs-GPU numbers; on the TPU
target we report the same *structure* — per-technique multiplier stack —
using measured algorithm statistics plugged into the v5e roofline:

  speedup(prefill) = add-reduction headroom (BRCR)        [compute-bound]
  speedup(decode)  = weight-CR (BSTC) ∘ KV-alive (BGPP)   [memory-bound]

plus a measured wall-clock of the real serving engine on the smoke config
(CPU; relative before/after enabling the MCBP KV path).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core import bgpp, brcr, bstc
from repro.models import model_zoo
from repro.serving import engine, kv_cache as kvc
from repro.utils.synthetic import synthetic_llm_weight_int8


def run():
    rng = np.random.default_rng(7)

    # modeled multiplier stack (paper Fig. 21 analogue)
    w_q, scale = synthetic_llm_weight_int8(rng, (64, 2048))
    cost = brcr.brcr_cost(jnp.asarray(w_q), m=4)
    cr = bstc.encode_weight(w_q, scale).compression_ratio
    S, D = 2048, 128
    k = np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127).astype(np.int32)
    sign = jnp.asarray((k < 0).astype(np.uint8))
    mag = np.abs(k).astype(np.uint8)
    planes = jnp.asarray(np.stack([(mag >> p) & 1 for p in range(7)]).astype(np.uint8))
    q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
    alive, _, _ = bgpp.bgpp_predict(
        q, planes, sign, bgpp.BGPPConfig(rounds=4, alpha=0.55),
        logit_scale=1.0 / np.sqrt(D) / 900.0,
    )
    alive_frac = float(jnp.mean(alive.astype(jnp.float32)))
    emit("fig21_brcr_compute_multiplier", 0.0,
         f"{cost.adds_bsc_baseline/cost.adds_total:.2f}x_op_reduction")
    emit("fig21_bstc_weight_multiplier", 0.0, f"{cr:.2f}x_weight_traffic")
    emit("fig21_bgpp_kv_multiplier", 0.0, f"{1/max(alive_frac,1e-3):.2f}x_kv_traffic")
    emit("fig20_decode_modeled_speedup", 0.0,
         f"{(0.6*cr + 0.4/max(alive_frac,1e-3)):.2f}x_weighted(w=0.6kv=0.4)")

    # measured serve_step wall-clock, int8 vs bgpp cache (smoke config)
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    tok = jnp.zeros((2, 1), jnp.int32)
    for fmt in ("int8", "bgpp"):
        layout = kvc.layout_for(cfg, 2, 128, kv_format=fmt)
        cache, _ = kvc.init_cache(cfg, layout)
        step = jax.jit(engine.make_serve_step(cfg, layout))
        us = time_fn(lambda c=cache: step(params, c, tok)[0], iters=5)
        emit(f"fig20_serve_step_{fmt}_smoke_cpu", us, "wall_clock_smoke")
