"""Kernel micro-benchmarks (interpret-mode wall clock is NOT TPU time; the
derived column carries the structural numbers that transfer: HBM bytes
moved, compression ratios, op counts)."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.brcr_gemm import brcr_gemm, prepare_brcr_operands
from repro.kernels.bstc_matmul import bstc_matmul, prepare_bstc_matmul_operands
from repro.kernels.bgpp_paged_attend import bgpp_paged_attend
from repro.kernels.paged_flash_decode import paged_flash_decode
from repro.serving import kv_cache as kvc
from repro.utils.synthetic import synthetic_llm_weight_int8


def run():
    rng = np.random.default_rng(8)
    M, H, N = 64, 1024, 32
    w_q, scale = synthetic_llm_weight_int8(rng, (M, H))
    x = jnp.asarray(rng.normal(size=(H, N)), jnp.float32)

    ops_brcr = prepare_brcr_operands(w_q, m=4)
    us = time_fn(
        lambda: brcr_gemm(ops_brcr, x, tile_m=32, tile_k=256, tile_n=32,
                          interpret=True),
        iters=3, warmup=1,
    )
    idx_bytes = ops_brcr.group_idx.size
    emit("kernel_brcr_gemm_interp", us,
         f"M{M}xH{H}xN{N};idx_bytes={idx_bytes}")

    ops_bstc = prepare_bstc_matmul_operands(w_q, scale, tile_k=512)
    us = time_fn(
        lambda: bstc_matmul(ops_bstc, x, tile_m=32, tile_n=32, interpret=True),
        iters=3, warmup=1,
    )
    emit(
        "kernel_bstc_matmul_interp", us,
        f"hbm_bytes={ops_bstc.hbm_bytes};dense_bytes={ops_bstc.dense_bytes};"
        f"CR={ops_bstc.compression_ratio:.3f}",
    )

    # ISSUE-7 paged-attention families: interpret-mode kernel vs the jnp
    # oracle on identical operands (the structural derived numbers — bytes
    # per head, keep budget — are what transfer to TPU, not CPU emulation
    # wall clock).
    B, Hk, g, Dh, S, page = 2, 2, 3, 32, 64, 8
    k = jnp.asarray(rng.normal(size=(B * S, Hk, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B * S, Hk, Dh)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, Hk, g, Dh)), jnp.float32)
    table = jnp.asarray(
        rng.permutation(B * S // page).reshape(B, S // page).astype(np.int32)
    )
    pos = jnp.full((B,), S - 1, jnp.int32)
    k_q, ks = kvc.quantize_kv(k)
    v_q, vs = kvc.quantize_kv(v)
    for mode, tag in (("interpret", "interp"), ("ref", "ref")):
        us = time_fn(
            lambda m=mode: paged_flash_decode(
                q, k_q, v_q, table, pos, page_size=page,
                k_scale=ks, v_scale=vs, mode=m,
            ),
            iters=3, warmup=1,
        )
        emit(f"kernel_paged_flash_decode_int8_{tag}", us,
             f"B{B}xHk{Hk}xg{g}xD{Dh};S={S};page={page}")

    planes, sign = kvc.k_to_bitplanes(k_q)
    phys = jnp.asarray(
        rng.permutation(B * S).reshape(B, S).astype(np.int32)
    )
    rounds, keep = 4, 0.25
    k_max = max(1, int(np.ceil(keep * S)))
    survivors = (S,) + tuple(max(k_max, S >> r) for r in range(1, rounds))
    for mode, tag in (("interpret", "interp"), ("ref", "ref")):
        us = time_fn(
            lambda m=mode: bgpp_paged_attend(
                q, planes, sign, ks, v_q, vs, phys, pos,
                rounds=rounds, k_max=k_max, survivors=survivors, mode=m,
            ),
            iters=3, warmup=1,
        )
        emit(f"kernel_bgpp_paged_attend_{tag}", us,
             f"B{B}xHk{Hk}xg{g}xD{Dh};S={S};rounds={rounds};k_max={k_max}")
