"""Kernel micro-benchmarks (interpret-mode wall clock is NOT TPU time; the
derived column carries the structural numbers that transfer: HBM bytes
moved, compression ratios, op counts)."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.brcr_gemm import brcr_gemm, prepare_brcr_operands
from repro.kernels.bstc_matmul import bstc_matmul, prepare_bstc_matmul_operands
from repro.utils.synthetic import synthetic_llm_weight_int8


def run():
    rng = np.random.default_rng(8)
    M, H, N = 64, 1024, 32
    w_q, scale = synthetic_llm_weight_int8(rng, (M, H))
    x = jnp.asarray(rng.normal(size=(H, N)), jnp.float32)

    ops_brcr = prepare_brcr_operands(w_q, m=4)
    us = time_fn(
        lambda: brcr_gemm(ops_brcr, x, tile_m=32, tile_k=256, tile_n=32,
                          interpret=True),
        iters=3, warmup=1,
    )
    idx_bytes = ops_brcr.group_idx.size
    emit("kernel_brcr_gemm_interp", us,
         f"M{M}xH{H}xN{N};idx_bytes={idx_bytes}")

    ops_bstc = prepare_bstc_matmul_operands(w_q, scale, tile_k=512)
    us = time_fn(
        lambda: bstc_matmul(ops_bstc, x, tile_m=32, tile_n=32, interpret=True),
        iters=3, warmup=1,
    )
    emit(
        "kernel_bstc_matmul_interp", us,
        f"hbm_bytes={ops_bstc.hbm_bytes};dense_bytes={ops_bstc.dense_bytes};"
        f"CR={ops_bstc.compression_ratio:.3f}",
    )
