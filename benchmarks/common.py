"""Shared benchmark utilities: timing + CSV emission per the repo contract
(``name,us_per_call,derived``)."""

from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def emit_header():
    print("name,us_per_call,derived")
