"""Serving throughput: chunked vs eager admission vs lockstep decode under a
Poisson-ish arrival trace, for the three KV formats (bf16 / int8 / bgpp),
plus the paged KV cache under a shared-system-prompt trace.

    PYTHONPATH=src python benchmarks/serving_throughput.py \\
        [--arch phi4-mini-3.8b] [--slots 2] [--requests 6] [--seed 0] \\
        [--kv-formats bf16,int8,bgpp] [--chunk-budget 8] [--quick] \\
        [--server-sim] \\
        [--page-size 8] [--shared-prefix 16] \\
        [--bgpp-rounds 4] [--bgpp-keep-ratio 0.25] [--mesh 2,4] \\
        [--decode-kernel auto|jnp|interpret|kernel] \\
        [--weight-format bf16|int8|bstc] \\
        [--baseline BENCH_serving.json] [--out BENCH_serving.json]

All runtimes drive the SAME jitted serve_step and the same seeded request
trace (staggered arrivals, varying prompt lengths and decode budgets):

  chunked  — the production scheduler: bucketed fixed-shape prefill chunks
             (jitted once per bucket, cache donated) interleaved with the
             batched decode step, at most --chunk-budget prefill tokens
             between consecutive decode steps.
  eager    — the PR-2 baseline: whole-prompt B=1 admission the moment a
             slot frees up; decode stalls for the full prefill.
  lockstep — the pre-ISSUE-2 baseline: groups of ``slots`` requests padded
             to a common length, prefilled together, decoded until the
             LONGEST budget in the group finishes.

Reported per (format, runtime): tokens/s (useful tokens only), mean busy
occupancy (slots holding an admitted request — PREFILLING or DECODING —
over total slots: a reserved row is occupied capacity even while its
prompt waits its turn to chunk), TTFT and ITL p50/p95, per-request queue
waits, and ``decode_kv_bytes_per_step`` — the KV bytes one batched decode
step gathers (``Scheduler.stats()["kv_read"]``).  The bgpp format decodes
two-phase (bit-plane prediction + top-``--bgpp-keep-ratio`` full-precision
gather, ``--bgpp-rounds`` progressive rounds), so its bytes-read must land
WELL under the bf16 row — that ordering is part of the gate.  Runs on CPU
via interpret-mode kernel dispatch (auto-detected off-TPU).  CSV on stdout
per the benchmark contract; ``--out`` writes the JSON consumed as the
BENCH_serving baseline.

``--weight-format`` flips the serve-time WEIGHT path (the knob
``repro.serving.weights`` resolves once per built step): every scheduler
row then carries ``weight_format`` / ``decode_weight_bytes_per_step``
columns from ``stats()["weight_read"]``, and the baseline gains a
``weight_read`` section pricing all three formats statically.  Two weight
gates run in EVERY invocation including ``--quick``: bstc coded bytes
must be <= bf16/2, and the measured coded stream must reconcile with the
closed-form model (``roofline.bstc_weight_traffic``) at 1.0 +- 10%.

``--mesh DATA,MODEL`` runs every scheduler sharded over a device mesh (KV
pools heads-parallel on ``model``, slots on ``data``; needs data*model
visible devices) and the emitted rows gain per-device and interconnect
kv-bytes columns.  With or without the flag, each format's baseline entry
carries ``kv_read_mesh`` — the static per-mesh decode-read pricing for
1x1 / 2x1 / 1x4 / 2x4 (total, per-device share, attend all-gather + paged
write-broadcast interconnect) — plus a ``sharded_smoke`` section pinning
the single-device occupancy the CI meshed launcher smoke is gated on.

  paged    — the chunked scheduler on the paged KV layout (pooled pages +
             page table + hash-based prefix reuse), driven by a trace whose
             requests share a ``--shared-prefix``-token system prompt.
             Reports prefix-hit rate and peak resident KV bytes next to the
             slot layout's dense allocation for the same traffic.

Full (non ``--quick``) runs also emit a ``serving_<fmt>_spec`` row: the
SAME chunked trace re-run with bit-plane speculative decoding on
(``spec_decode=True``, the config's gamma/planes).  The row carries the
acceptance economics — ``accepted_tokens_per_step`` (accepted tokens per
*physical* serve_step, draft + verify) and the per-accepted-token
kv/weight byte prices — and the run fails if the speculative trace's
generated tokens differ from the chunked row's in a single position
(speculation may only move wall clock, never tokens).

``--server-sim`` additionally replays the trace through the asyncio front
door (``repro.serving.server.simulate_clients``: tiered rotating clients,
every 3rd disconnecting after one token) on the paged layout and emits an
informational ``serving_<fmt>_server`` row — cancels, sheds, preemptions,
per-tier ITL.  The row is never gated against baselines (its wall clock
includes event-loop overhead), but the per-step page-leak check is armed
and a non-empty pool at the end fails the run.

``--quick`` runs one format with chunked+eager only and exits nonzero if
chunked admission shows lower occupancy than eager OR a worse decode-tail
ITL p95 (the stall chunking exists to remove) — the CI regression gate
for the admission path.  ``--baseline`` (usually the committed
BENCH_serving.json) tightens the gate against the recorded numbers with
stated tolerances: chunked occupancy may not drop more than
``OCC_TOLERANCE`` (absolute — occupancy is step-deterministic), and the
chunked/eager decode-tail ITL p95 *ratio* may not exceed the baseline's
ratio by more than ``ITL_RATIO_FACTOR``x (a ratio, so CI-runner speed
cancels out).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

try:  # python -m benchmarks.serving_throughput
    from benchmarks.common import emit, emit_header
except ImportError:  # python benchmarks/serving_throughput.py
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, emit_header

from repro.configs import (  # noqa: E402
    ARCH_REGISTRY, WEIGHT_FORMATS, apply_bgpp_overrides,
    apply_decode_kernel_override, apply_weight_format_override, get_config,
)
from repro.models import model_zoo  # noqa: E402
from repro.serving import engine, kernel_decode, kv_cache as kvc  # noqa: E402
from repro.serving import sharded as shd  # noqa: E402
from repro.serving import weights as swt  # noqa: E402
from repro.serving.request import poisson_trace  # noqa: E402
from repro.serving.scheduler import Scheduler  # noqa: E402


# stated regression-gate tolerances (--baseline):
OCC_TOLERANCE = 0.02  # absolute mean-occupancy drop allowed vs baseline
ITL_RATIO_FACTOR = 4.0  # chunked/eager itl_p95 ratio growth allowed

# mesh points priced in every baseline (static — the kv-read counter IS the
# gather plan, so no devices are needed to price a mesh shape)
MESH_POINTS = ((1, 1), (2, 1), (1, 4), (2, 4))


def mesh_kv_entries(layout, cfg):
    """Per-mesh decode-read breakdown: total, per-device share, and the
    interconnect bytes (attend all-gather + paged write broadcast) a sharded
    serve_step moves per decode step."""
    out = {}
    for d, m in MESH_POINTS:
        r = kvc.decode_read_bytes(layout, cfg, (d, m))
        out[f"{d}x{m}"] = {
            "decode_bytes_per_step": round(r["total"]),
            "per_device_bytes_per_step": round(r["per_device"]["total"]),
            "kv_shards": r["per_device"]["shards"],
            "interconnect": {k: round(v)
                             for k, v in r["interconnect"].items()},
        }
    return out


def run_scheduler(params, cfg, layout, reqs, admission, chunk_budget,
                  shared=None, rules=None, sched_kw=None, sink=None):
    kw = {} if rules is None else {"rules": rules}
    kw |= sched_kw or {}
    sched = Scheduler(params, cfg, layout, admission=admission,
                      chunk_budget=chunk_budget,
                      prefill_kw=dict(block_q=16, block_k=32),
                      shared_fns=shared, **kw)
    for r in reqs:
        sched.submit(r)
    t0 = time.perf_counter()
    sched.run(max_steps=10_000)
    wall = time.perf_counter() - t0
    stats = sched.stats(wall)
    out = {
        "tokens_per_s": stats["tokens_per_s"],
        "mean_occupancy": stats["mean_occupancy"],
        "decoded_tokens": stats["decoded_tokens"],
        "wall_s": stats["wall_s"],
        "ttft_s_p50": stats["ttft_s"]["p50"],
        "ttft_s_p95": stats["ttft_s"]["p95"],
        "itl_s_p50": stats["itl_s"]["p50"],
        "itl_s_p95": stats["itl_s"]["p95"],
        "max_prefill_tokens_per_step": stats["max_prefill_tokens_per_step"],
        "mean_queue_wait_steps": float(np.mean(
            [r["queue_wait_steps"] for r in stats["requests"]])),
    }
    kv = stats["kv_read"]
    out |= {
        "decode_kv_bytes_per_step": kv["decode_bytes_per_step"],
        "decode_kv_bytes_reduction_vs_bf16":
            kv["decode_bytes_reduction_vs_bf16"],
        "kv_shards": kv["kv_shards"],
        "decode_kv_bytes_per_device_per_step":
            kv["decode_bytes_per_device_per_step"],
        "interconnect_bytes_per_step": kv["interconnect_bytes_per_step"],
        "interconnect_bytes": kv["interconnect_bytes"],
    }
    wr = stats["weight_read"]
    out |= {
        "weight_format": wr["weight_format"],
        "decode_weight_bytes_per_step": wr["decode_bytes_per_step"],
        "decode_weight_bytes_reduction_vs_bf16":
            wr["decode_bytes_reduction_vs_bf16"],
        "weight_measured_over_modeled": wr["measured_over_modeled"],
        "decode_weight_bytes_per_device_per_step":
            wr["decode_bytes_per_device_per_step"],
    }
    if "bgpp" in kv:
        out["bgpp_full_rows_per_slot"] = kv["bgpp"]["full_rows_per_slot"]
    if "paged" in stats:
        pg = stats["paged"]
        out |= {
            "prefix_hit_rate": pg["prefix_hit_rate"],
            "prefix_hit_tokens": pg["prefix_hit_tokens"],
            "resident_kv_bytes_peak": pg["resident_kv_bytes_peak"],
            "slot_resident_kv_bytes": pg["slot_resident_kv_bytes"],
        }
    if "spec" in stats:
        sp = stats["spec"]
        out |= {
            "spec_gamma": sp["gamma"],
            "spec_draft_planes": sp["draft_planes"],
            "accepted_tokens_per_step": sp["accepted_tokens_per_step"],
            "accepted_tokens_per_round": sp["accepted_tokens_per_round"],
            "draft_hit_rate": sp["draft_hit_rate"],
            "kv_bytes_per_accepted_token": sp["kv_bytes_per_accepted_token"],
            "weight_bytes_per_accepted_token":
                sp["weight_bytes_per_accepted_token"],
            "modeled_weight_bytes_per_accepted_token":
                sp["modeled_weight_bytes_per_accepted_token"],
        }
    if sink is not None:
        sink["generated"] = {r.rid: [int(t) for t in r.generated]
                             for r in sched.finished}
    return out, sched.shared_fns()


def run_lockstep(params, cfg, layout, reqs, serve_step=None):
    """Fixed-budget group decode (the old launch/serve.py skeleton): pad a
    group to one width, prefill together, decode until the group's longest
    budget; admission only at group boundaries."""
    if serve_step is None:
        serve_step = jax.jit(engine.make_serve_step(cfg, layout))
    slots = layout.batch
    queue = list(reqs)
    step_now = 0
    occupancy, decoded, waits = [], 0, []
    t0 = time.perf_counter()
    while queue:
        arrived = [r for r in queue if r.arrival_step <= step_now]
        if not arrived:  # idle until the next arrival (no device work)
            step_now = min(r.arrival_step for r in queue)
            continue
        group = arrived[:slots]
        queue = [r for r in queue if r not in group]
        waits.extend(step_now - r.arrival_step for r in group)
        width = max(r.prompt_len for r in group)
        prompts = jnp.stack([
            jnp.pad(jnp.asarray(r.prompt), (width - r.prompt_len, 0))
            for r in group
        ])
        if len(group) < slots:
            prompts = jnp.pad(prompts, ((0, slots - len(group)), (0, 0)))
        logits, cache = engine.prefill(params, cfg, layout, prompts,
                                       block_q=16, block_k=32)
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        # prefill samples token 1; the group decodes until its longest
        # budget even though shorter requests finished — the lockstep waste
        T = max(r.max_new_tokens for r in group) - 1
        T = min(T, layout.max_seq - width)
        for t in range(T):
            live = sum(1 for r in group if t < r.max_new_tokens - 1)
            occupancy.append(live / slots)
            decoded += live
            logits, cache = serve_step(params, cache, cur)
            cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        step_now += T
    wall = time.perf_counter() - t0
    return {
        "tokens_per_s": round(decoded / wall, 2) if wall > 0 else None,
        "mean_occupancy": float(np.mean(occupancy)) if occupancy else 0.0,
        "decoded_tokens": decoded,
        "wall_s": round(wall, 3),
        "mean_queue_wait_steps": float(np.mean(waits)) if waits else 0.0,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="phi4-mini-3.8b",
                    choices=sorted(ARCH_REGISTRY))
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--chunk-budget", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-formats", default="bf16,int8,bgpp")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page for the paged runtime")
    ap.add_argument("--shared-prefix", type=int, default=16,
                    help="shared system-prompt tokens in the paged trace")
    ap.add_argument("--bgpp-rounds", type=int, default=4,
                    help="bgpp progressive-prediction rounds")
    ap.add_argument("--bgpp-keep-ratio", type=float, default=0.25,
                    help="fraction of keys the bgpp decode fetches at "
                         "full precision")
    ap.add_argument("--decode-kernel", default=None,
                    choices=sorted(kernel_decode.MODES),
                    help="global-layer decode attend routing (auto = "
                         "compiled Pallas kernel on TPU, legacy jnp "
                         "elsewhere); every serving row carries the "
                         "resolved mode as a decode_kernel column")
    ap.add_argument("--weight-format", default=None,
                    choices=sorted(WEIGHT_FORMATS),
                    help="serve-time weight numerics for the decode "
                         "projections (bf16 = raw leaves, bit-for-bit; "
                         "int8/bstc = quantized records priced by the "
                         "weight_read counter)")
    ap.add_argument("--quick", action="store_true",
                    help="one format, chunked+eager only — the CI gate")
    ap.add_argument("--server-sim", action="store_true",
                    help="also replay the trace through the asyncio front "
                         "door (tiered clients, every 3rd disconnecting) "
                         "on the paged layout: an informational "
                         "serving_<fmt>_server row, never baseline-gated, "
                         "but the per-step page-leak check is armed and "
                         "the pool must drain")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH JSON to gate regressions against "
                         f"(occupancy -{OCC_TOLERANCE} absolute, itl-p95 "
                         f"ratio x{ITL_RATIO_FACTOR})")
    ap.add_argument("--out", default=None,
                    help="write the JSON baseline (e.g. BENCH_serving.json)")
    ap.add_argument("--mesh", default=None,
                    help="DATA,MODEL mesh shape (e.g. 2,4): run the "
                         "schedulers sharded over a device mesh (needs "
                         "data*model devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8).  "
                         "Static per-mesh kv-read entries are priced in "
                         "the baseline regardless of this flag")
    args = ap.parse_args()
    rules = None
    if args.mesh:
        mesh_dm = shd.parse_mesh_arg(args.mesh)
        rules = shd.rules_for(*mesh_dm)

    cfg = apply_bgpp_overrides(
        get_config(args.arch, smoke=True),
        rounds=args.bgpp_rounds, keep_ratio=args.bgpp_keep_ratio,
    )
    cfg = apply_decode_kernel_override(cfg, args.decode_kernel)
    cfg = apply_weight_format_override(cfg, args.weight_format)
    dk_mode = kernel_decode.resolve(cfg)
    wf_mode = swt.resolve(cfg)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    formats = args.kv_formats.split(",")
    if args.quick:
        # one format, but the SAME trace parameters as the full run, so the
        # --baseline gate compares like for like
        formats = formats[:1]

    results = {"config": vars(args) | {"arch_resolved": cfg.name}}
    emit_header()
    ok = True
    for fmt in formats:
        layout = kvc.layout_for(cfg, args.slots, args.max_seq, kv_format=fmt)
        entry = {"decode_kernel": dk_mode, "weight_format": wf_mode,
                 "kv_read_mesh": mesh_kv_entries(layout, cfg)}
        shared = None
        chunk_sink = {}
        runtimes = ["chunked", "eager"] + ([] if args.quick else ["lockstep"])
        for runtime in runtimes:
            rng = np.random.default_rng(args.seed)  # identical trace
            reqs = poisson_trace(rng, args.requests, cfg.vocab_size,
                                 args.max_new, arrival_rate=3.0,
                                 min_new=max(2, args.max_new // 3),
                                 max_prompt=min(23, args.max_seq - 2))
            if runtime == "lockstep":
                # lockstep prefills an unsharded cache itself, so never
                # feed it a mesh-jitted serve_step
                entry[runtime] = run_lockstep(
                    params, cfg, layout, reqs,
                    serve_step=shared["serve_step"]
                    if shared and rules is None else None,
                )
            else:
                entry[runtime], shared = run_scheduler(
                    params, cfg, layout, reqs, runtime, args.chunk_budget,
                    shared=shared, rules=rules,
                    sink=chunk_sink if runtime == "chunked" else None,
                )
            r = entry[runtime]
            us = 1e6 / r["tokens_per_s"] if r["tokens_per_s"] else 0.0
            extra = ""
            if runtime != "lockstep":
                extra = (f";ttft_p95={r['ttft_s_p95']}"
                         f";itl_p95={r['itl_s_p95']}"
                         f";kv_step={r['decode_kv_bytes_per_step']}"
                         f";weight_format={r['weight_format']}"
                         f";w_step={r['decode_weight_bytes_per_step']}")
                if rules is not None:
                    extra += (f";kv_dev={r['decode_kv_bytes_per_device_per_step']}"
                              f";ic_step={r['interconnect_bytes_per_step']}")
            emit(f"serving_{fmt}_{runtime}", us,
                 f"occ={r['mean_occupancy']:.3f};tok_s={r['tokens_per_s']}"
                 f";decode_kernel={dk_mode}" + extra)
        delta = entry["chunked"]["mean_occupancy"] \
            - entry["eager"]["mean_occupancy"]
        entry["chunked_vs_eager_occupancy"] = round(delta, 4)
        itl_c = entry["chunked"]["itl_s_p95"]
        itl_e = entry["eager"]["itl_s_p95"]
        if itl_c is not None and itl_e is not None and itl_c > itl_e:
            # chunking exists to bound the decode-tail stall; a p95 ITL
            # regression against eager admission fails the gate even if
            # occupancy still reads fine
            ok = False
        if "lockstep" in entry:
            gain = entry["eager"]["mean_occupancy"] \
                - entry["lockstep"]["mean_occupancy"]
            entry["occupancy_gain"] = round(gain, 4)
        results[fmt] = entry
        print(f"# {fmt}: chunked occupancy "
              f"{entry['chunked']['mean_occupancy']:.3f} vs eager "
              f"{entry['eager']['mean_occupancy']:.3f} "
              f"({'+' if delta >= 0 else ''}{delta:.3f})"
              + (f", eager vs lockstep "
                 f"{entry['lockstep']['mean_occupancy']:.3f}"
                 if "lockstep" in entry else ""))
        if delta < -1e-9:
            ok = False
        if "lockstep" in entry and entry["occupancy_gain"] <= 0:
            ok = False
        if rules is not None:
            # the live counter must agree with the static per-mesh pricing:
            # a mesh the pricing says moves interconnect bytes (model-axis
            # head shards) must report them from the actual run
            want_ic = entry["kv_read_mesh"][
                f"{mesh_dm[0]}x{mesh_dm[1]}"]["interconnect"]["total"]
            got_ic = entry["chunked"]["interconnect_bytes"]
            if (want_ic > 0) != (got_ic > 0):
                print(f"# REGRESSION {fmt}: static mesh pricing says "
                      f"{want_ic} interconnect B/step but the live run "
                      f"counted {got_ic} B total")
                ok = False

        if not args.quick and dk_mode == "jnp" and rules is None:
            # jnp-vs-kernel comparison row: the SAME chunked trace with the
            # decode attend routed through the Pallas kernel family in
            # interpret mode.  On CPU CI this is kernel EMULATION — the
            # wall clock is flagged and never gated; the row exists so the
            # baseline records both paths side by side (on TPU the compiled
            # row replaces it).
            cfg_k = apply_decode_kernel_override(cfg, "interpret")
            rng = np.random.default_rng(args.seed)
            kreqs = poisson_trace(rng, args.requests, cfg.vocab_size,
                                  args.max_new, arrival_rate=3.0,
                                  min_new=max(2, args.max_new // 3),
                                  max_prompt=min(23, args.max_seq - 2))
            entry["chunked_interpret"], _ = run_scheduler(
                params, cfg_k, layout, kreqs, "chunked", args.chunk_budget,
            )
            entry["chunked_interpret"]["note"] = (
                "decode_kernel=interpret on CPU: Pallas interpret-mode "
                "emulation wall clock, NOT TPU kernel time — parity/bytes "
                "columns transfer, us_per_call does not"
            )
            rk = entry["chunked_interpret"]
            us = 1e6 / rk["tokens_per_s"] if rk["tokens_per_s"] else 0.0
            emit(f"serving_{fmt}_chunked_interpret", us,
                 f"occ={rk['mean_occupancy']:.3f};tok_s={rk['tokens_per_s']}"
                 f";decode_kernel=interpret;flag=cpu_interpret_emulation"
                 f";kv_step={rk['decode_kv_bytes_per_step']}")
            # routing must not change WHAT the step gathers: the kv-read
            # counter prices the plan, not the executor
            if rk["decode_kv_bytes_per_step"]                     != entry["chunked"]["decode_kv_bytes_per_step"]:
                print(f"# REGRESSION {fmt}: kernel-routed decode reads "
                      f"{rk['decode_kv_bytes_per_step']} B/step vs jnp "
                      f"{entry['chunked']['decode_kv_bytes_per_step']}")
                ok = False

        if not args.quick:
            # paged layout under a shared-system-prompt trace: later
            # requests must adopt the resident prompt pages (hit rate > 0)
            # and the pool must stay under the slot layout's dense rows
            rng = np.random.default_rng(args.seed)
            p_max_prompt = min(23, args.max_seq - 2 - args.shared_prefix)
            assert p_max_prompt >= 1, "--shared-prefix leaves no prompt room"
            preqs = poisson_trace(rng, args.requests, cfg.vocab_size,
                                  args.max_new, arrival_rate=3.0,
                                  min_new=max(2, args.max_new // 3),
                                  max_prompt=p_max_prompt,
                                  shared_prefix=args.shared_prefix)
            layout_p = kvc.layout_for(cfg, args.slots, args.max_seq,
                                      kv_format=fmt, layout="paged",
                                      page_size=args.page_size)
            entry["paged"], _ = run_scheduler(
                params, cfg, layout_p, preqs, "chunked", args.chunk_budget,
                rules=rules,
            )
            entry["paged"]["kv_read_mesh"] = mesh_kv_entries(layout_p, cfg)
            r = entry["paged"]
            us = 1e6 / r["tokens_per_s"] if r["tokens_per_s"] else 0.0
            emit(f"serving_{fmt}_paged", us,
                 f"occ={r['mean_occupancy']:.3f};tok_s={r['tokens_per_s']}"
                 f";decode_kernel={dk_mode}"
                 f";prefix_hit_rate={r['prefix_hit_rate']}"
                 f";resident_kv_peak={r['resident_kv_bytes_peak']}"
                 f";slot_resident={r['slot_resident_kv_bytes']}")
            print(f"# {fmt}: paged prefix hit rate "
                  f"{r['prefix_hit_rate']:.3f}, resident KV peak "
                  f"{r['resident_kv_bytes_peak']} B vs slot "
                  f"{r['slot_resident_kv_bytes']} B")
            if r["prefix_hit_rate"] <= 0:
                ok = False
            if r["resident_kv_bytes_peak"] >= r["slot_resident_kv_bytes"]:
                ok = False

        if not args.quick and not layout.local_layers:
            # speculative decoding over the SAME chunked trace (global-only
            # stacks — local ring layers overwrite what rollback needs):
            # wall clock may move, tokens may not.  The row carries the
            # acceptance economics next to the chunked baseline.
            rng = np.random.default_rng(args.seed)
            qreqs = poisson_trace(rng, args.requests, cfg.vocab_size,
                                  args.max_new, arrival_rate=3.0,
                                  min_new=max(2, args.max_new // 3),
                                  max_prompt=min(23, args.max_seq - 2))
            spec_sink = {}
            entry["spec"], _ = run_scheduler(
                params, cfg, layout, qreqs, "chunked", args.chunk_budget,
                shared=shared, rules=rules,
                sched_kw={"spec_decode": True}, sink=spec_sink,
            )
            r = entry["spec"]
            us = 1e6 / r["tokens_per_s"] if r["tokens_per_s"] else 0.0
            emit(f"serving_{fmt}_spec", us,
                 f"occ={r['mean_occupancy']:.3f};tok_s={r['tokens_per_s']}"
                 f";decode_kernel={dk_mode}"
                 f";gamma={r['spec_gamma']};planes={r['spec_draft_planes']}"
                 f";acc_step={r['accepted_tokens_per_step']}"
                 f";acc_round={r['accepted_tokens_per_round']}"
                 f";kv_per_accepted={r['kv_bytes_per_accepted_token']}"
                 f";w_per_accepted={r['weight_bytes_per_accepted_token']}")
            print(f"# {fmt}: spec accepted/step "
                  f"{r['accepted_tokens_per_step']:.3f} "
                  f"({r['accepted_tokens_per_round']:.2f}/round, draft hit "
                  f"rate {r['draft_hit_rate']:.2f}); kv "
                  f"{r['kv_bytes_per_accepted_token']} B and weight "
                  f"{r['weight_bytes_per_accepted_token']} B per accepted "
                  f"token")
            if spec_sink["generated"] != chunk_sink["generated"]:
                print(f"# REGRESSION {fmt}: speculative decode changed the "
                      f"generated tokens vs the chunked run")
                ok = False

    if args.server_sim:
        # the same trace replayed through the asyncio front door
        # (repro.serving.server.simulate_clients): tiered rotating clients,
        # every 3rd hanging up after one token.  The wall clock includes
        # event-loop overhead, so the row is informational — never gated
        # against baselines — but the per-step PageAllocator.check leak
        # gate is armed and the pool must end empty.
        from repro.serving.server import simulate_clients
        fmt = formats[0]
        slayout = kvc.layout_for(cfg, args.slots, args.max_seq,
                                 kv_format=fmt, layout="paged",
                                 page_size=args.page_size)
        rng = np.random.default_rng(args.seed)
        sreqs = poisson_trace(rng, args.requests, cfg.vocab_size,
                              args.max_new, arrival_rate=3.0,
                              min_new=max(2, args.max_new // 3),
                              max_prompt=min(23, args.max_seq - 2))
        ssched = Scheduler(params, cfg, slayout, admission="chunked",
                           chunk_budget=args.chunk_budget,
                           **({"rules": rules} if rules is not None else {}))
        t0 = time.perf_counter()
        sv = simulate_clients(ssched, sreqs)
        wall = time.perf_counter() - t0
        tok_s = round(sv["decoded_tokens"] / wall, 1) if wall else 0.0
        us = 1e6 / tok_s if tok_s else 0.0
        tiers = ";".join(
            f"{tier}_itl_p50={t['itl_s']['p50']}"
            for tier, t in sorted(sv["tiers"].items()))
        emit(f"serving_{fmt}_server", us,
             f"occ={sv['mean_occupancy']:.3f};tok_s={tok_s}"
             f";cancelled={sv['cancelled_requests']}"
             f";shed={sv['shed_requests']}"
             f";preemptions={sv['preemptions']}"
             f";pages_in_use={sv['paged']['pages_in_use']}"
             f";{tiers};flag=informational_not_gated")
        results[f"{fmt}_server"] = {
            "note": "async front door replay: informational, not gated",
            "tokens_per_s": tok_s,
            "mean_occupancy": sv["mean_occupancy"],
            "cancelled_requests": sv["cancelled_requests"],
            "shed_requests": sv["shed_requests"],
            "preemptions": sv["preemptions"],
            "tiers": sv["tiers"],
            "disconnects": sum(c["disconnected"] for c in sv["clients"]),
        }
        print(f"# {fmt}: server sim cancelled "
              f"{sv['cancelled_requests']}/{len(sreqs)}, preemptions "
              f"{sv['preemptions']}, pool drained "
              f"({sv['paged']['pages_in_use']} pages in use)")
        if sv["paged"]["pages_in_use"] != 0:
            print("# REGRESSION: server sim leaked pages")
            ok = False

    # the tentpole's bytes ordering: bgpp's two-phase decode (bit-planes +
    # top-k full rows) must read WELL under the dense bf16 row — at least
    # 2x at the default keep ratio (8x at rounds=4, keep=0.25).  Formats
    # not driven live (--quick trims to bf16) are priced from their static
    # layouts — identical numbers, since the counter IS the gather plan —
    # so this gate also fires in the --quick CI run.
    def _step_bytes(fmt):
        live = results.get(fmt, {}).get("chunked")
        if live is not None:
            return live["decode_kv_bytes_per_step"]
        return round(kvc.decode_read_bytes(
            kvc.layout_for(cfg, args.slots, args.max_seq, kv_format=fmt), cfg
        )["total"])

    b_bytes, f_bytes = _step_bytes("bgpp"), _step_bytes("bf16")
    print(f"# kv bytes/decode-step: bgpp {b_bytes} vs bf16 {f_bytes} "
          f"({f_bytes / b_bytes:.2f}x reduction)")
    if 2 * b_bytes > f_bytes:
        print("# REGRESSION: bgpp decode reads are not well under bf16's")
        ok = False

    # the weight-format mirror of the bgpp ordering gate (fires in --quick
    # too): every format priced statically from the same params — identical
    # to the live counter, since the plan IS the counter — then (1) BSTC
    # coded bytes <= bf16/2 and (2) the measured coded stream reconciles
    # with the closed-form model (roofline.bstc_weight_traffic on measured
    # per-plane column sparsities) at 1.0 +- 10%
    wlayout = kvc.layout_for(cfg, args.slots, args.max_seq,
                             kv_format=formats[0])
    weight_entry = {"weight_format": wf_mode}
    for wf in WEIGHT_FORMATS:
        _, plan = swt.prepare_serve_params(
            params, apply_weight_format_override(cfg, wf), wlayout, wf)
        wrd = plan.decode_read_bytes(wlayout, cfg)
        weight_entry[wf] = {
            "decode_bytes_per_step": round(wrd["total"]),
            "modeled_bytes_per_step": round(wrd["modeled"]),
            "measured_over_modeled": round(wrd["total"] / wrd["modeled"], 4),
            "per_projection": {n: round(v)
                               for n, v in wrd["per_projection"].items()},
        }
    results["weight_read"] = weight_entry
    wb = weight_entry["bstc"]["decode_bytes_per_step"]
    wf16 = weight_entry["bf16"]["decode_bytes_per_step"]
    print(f"# weight bytes/decode-step: bstc {wb} vs bf16 {wf16} "
          f"({wf16 / wb:.2f}x reduction)")
    if 2 * wb > wf16:
        print("# REGRESSION: bstc coded weights are not <= bf16/2")
        ok = False
    mm = weight_entry["bstc"]["measured_over_modeled"]
    if not 0.9 <= mm <= 1.1:
        print(f"# REGRESSION: bstc measured/modeled weight bytes {mm} "
              f"outside 1.0 +- 10%")
        ok = False
    # the live schedulers ran with wf_mode: their counter must equal the
    # static pricing (weights are layout-independent)
    for fmt in formats:
        live = results[fmt]["chunked"]["decode_weight_bytes_per_step"]
        want = weight_entry[wf_mode]["decode_bytes_per_step"]
        if live != want:
            print(f"# REGRESSION {fmt}: live weight counter {live} B/step "
                  f"!= static {wf_mode} pricing {want}")
            ok = False

    if not args.quick:
        # committed single-device reference for the CI sharded-serving
        # launcher smoke: the exact trace launch/serve.py runs at
        # --arch deepseek-7b --mesh 2,4 (the smoke arch whose head counts
        # divide model=4).  Occupancy is host-side scheduling, so it is
        # mesh-invariant — CI pins the meshed launcher run to this number
        # within OCC_TOLERANCE — and the static 2x4 entry prices the
        # interconnect bytes that run must report as > 0.
        scfg = get_config("deepseek-7b", smoke=True)
        sparams, _ = model_zoo.init(jax.random.key(0), scfg)
        slayout = kvc.layout_for(scfg, 4, 128, kv_format="bf16")
        rng = np.random.default_rng(args.seed)
        sreqs = poisson_trace(rng, 4, scfg.vocab_size, 8, 2.0,
                              max_prompt=23)
        smoke, _ = run_scheduler(sparams, scfg, slayout, sreqs,
                                 "chunked", 16)
        results["sharded_smoke"] = {
            "arch": "deepseek-7b", "kv_format": "bf16", "kv_layout": "slot",
            "slots": 4, "requests": 4, "max_new": 8, "max_seq": 128,
            "chunk_budget": 16, "arrival_rate": 2.0, "seed": args.seed,
            "mean_occupancy": smoke["mean_occupancy"],
            "kv_read_mesh": mesh_kv_entries(slayout, scfg),
        }
        sm = results["sharded_smoke"]["kv_read_mesh"]["2x4"]
        print(f"# sharded_smoke (deepseek-7b, 4 slots, bf16 slot): "
              f"occupancy {smoke['mean_occupancy']:.3f}; 2x4 = "
              f"{sm['per_device_bytes_per_step']} B/device/step over "
              f"{sm['kv_shards']} shards + {sm['interconnect']['total']} "
              f"interconnect B/step")

    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        for fmt in formats:
            if fmt not in base:
                print(f"# baseline has no {fmt} entry; skipping gate")
                continue
            b, cur = base[fmt], results[fmt]
            occ_b = b["chunked"]["mean_occupancy"]
            occ_c = cur["chunked"]["mean_occupancy"]
            if occ_c < occ_b - OCC_TOLERANCE:
                print(f"# REGRESSION {fmt}: chunked occupancy {occ_c:.3f} "
                      f"< baseline {occ_b:.3f} - {OCC_TOLERANCE}")
                ok = False

            def _ratio(e):
                c, g = e["chunked"]["itl_s_p95"], e["eager"]["itl_s_p95"]
                return c / g if c and g else None

            rb, rc = _ratio(b), _ratio(cur)
            if rb is not None and rc is not None \
                    and rc > max(rb, 1.0) * ITL_RATIO_FACTOR:
                print(f"# REGRESSION {fmt}: chunked/eager itl_p95 ratio "
                      f"{rc:.3f} > baseline {rb:.3f} x {ITL_RATIO_FACTOR}")
                ok = False

    print(f"# chunked >= eager occupancy, chunked itl_p95 <= eager, paged "
          f"prefix reuse + resident-KV win, bstc weights <= bf16/2 + "
          f"measured/modeled reconciliation, spec tokens identical"
          f"{', baseline gate' if args.baseline else ''}: {ok}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# baseline -> {args.out}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
