"""Paper Fig. 17(b): normalized decoding-stage memory access.

Weight traffic under: raw INT8 / BSTC two-state coding (paper) — plus the
paper's value-level Huffman-like baseline proxy (run-length on zero values,
as FuseKNA) — and KV traffic under value-level top-k vs BGPP progressive
prediction.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import bgpp, bstc, topk
from repro.utils.synthetic import synthetic_llm_weight_int8


def _value_rle_bits(w: np.ndarray) -> int:
    """FuseKNA-style value-level run-length coding proxy: 8b literal +
    run-length byte for zero runs."""
    flat = w.reshape(-1)
    bits = 0
    run = 0
    for v in flat:
        if v == 0:
            run += 1
            if run == 255:
                bits += 16
                run = 0
        else:
            if run:
                bits += 16
                run = 0
            bits += 8
    if run:
        bits += 16
    return bits


def run():
    rng = np.random.default_rng(1)
    w_q, _ = synthetic_llm_weight_int8(rng, (256, 1024))

    raw_bits = w_q.size * 8
    bw = bstc.encode_weight(w_q, np.ones(256, np.float32))
    rle_bits = _value_rle_bits(w_q[:16])  # sampled rows (slow python loop)
    rle_bits = rle_bits * (w_q.shape[0] // 16)

    emit("fig17b_weight_raw_int8", 0.0, f"bits={raw_bits}")
    emit("fig17b_weight_value_rle", 0.0,
         f"bits={rle_bits};ratio={raw_bits/max(rle_bits,1):.3f}")
    emit("fig17b_weight_bstc", 0.0,
         f"bits={bw.encoded_bits};CR={bw.compression_ratio:.3f}")

    # KV prediction traffic: value top-k vs BGPP (paper Fig. 5g)
    S, D = 1024, 128
    k = np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127).astype(np.int32)
    sign = jnp.asarray((k < 0).astype(np.uint8))
    mag = np.abs(k).astype(np.uint8)
    planes = jnp.asarray(np.stack([(mag >> p) & 1 for p in range(7)]).astype(np.uint8))
    q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
    scale = 1.0 / np.sqrt(D) / 900.0

    _, _, vstats = topk.value_topk_predict(q, jnp.asarray(k, jnp.int8), k_keep=64)
    alive, _, bstats = bgpp.bgpp_predict(
        q, planes, sign, bgpp.BGPPConfig(rounds=4, alpha=0.55), logit_scale=scale
    )
    vb = float(vstats.predict_bytes)
    bb = float(bstats.predict_bytes)
    emit("fig17b_kv_value_topk_predict", 0.0, f"bytes={vb:.0f}")
    emit("fig17b_kv_bgpp_predict", 0.0,
         f"bytes={bb:.0f};saving={100*(1-bb/vb):.1f}%;alive={int(alive.sum())}/{S}")
