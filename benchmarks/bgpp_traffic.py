"""Paper Fig. 5(g) + Fig. 24(a): BGPP KV-traffic reduction vs alpha, and the
sparsity/recall trade-off that motivates alpha in [0.5, 0.6]."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import bgpp


def run():
    rng = np.random.default_rng(4)
    S, D = 2048, 128
    k = np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127).astype(np.int32)
    sign = jnp.asarray((k < 0).astype(np.uint8))
    mag = np.abs(k).astype(np.uint8)
    planes = jnp.asarray(np.stack([(mag >> p) & 1 for p in range(7)]).astype(np.uint8))
    q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
    scale = 1.0 / np.sqrt(D) / 900.0
    true_scores = k @ np.asarray(q)
    top32 = set(np.argsort(true_scores)[-32:].tolist())

    # kernel-path traffic model (paper Fig. 5g analogue on the TPU target)
    from repro.analysis.roofline import bgpp_kernel_traffic

    for keep in (0.125, 0.25, 0.5):
        kt = bgpp_kernel_traffic(32768, 128, rounds=4, keep_ratio=keep)
        emit(
            f"fig5g_kernel_traffic_keep{keep}", 0.0,
            f"bytes={kt['bgpp_kernel_bytes']:.0f};dense={kt['dense_int8_bytes']:.0f};"
            f"reduction={kt['reduction']:.2f}x",
        )

    full_bytes = S * D  # 8-bit fetch of every key
    for alpha in (0.3, 0.4, 0.5, 0.55, 0.6, 0.8):
        alive, _, stats = bgpp.bgpp_predict(
            q, planes, sign,
            bgpp.BGPPConfig(rounds=4, alpha=alpha), logit_scale=scale,
        )
        kept = np.flatnonzero(np.asarray(alive))
        recall = len(top32 & set(kept.tolist())) / 32
        sparsity = 1 - len(kept) / S
        traffic = float(stats.predict_bytes) / full_bytes
        emit(
            f"fig24a_alpha{alpha}", 0.0,
            f"sparsity={sparsity:.3f};top32_recall={recall:.3f};"
            f"predict_traffic_frac={traffic:.3f}",
        )
