"""Paper Fig. 5(g) + Fig. 24(a): BGPP KV-traffic reduction vs alpha, and the
sparsity/recall trade-off that motivates alpha in [0.5, 0.6] — now reported
NEXT TO the measured kv-bytes-read counter of the serving runtime.

Three sections:

  fig5g_kernel_traffic_*  — analytic per-(query, kv-head) bytes of the
                            Pallas kernel path (roofline model);
  fig24a_alpha*           — the alpha sweep on the jnp predictor;
  bgpp_serving_measured   — a LIVE paged bgpp scheduler run: the
                            ``Scheduler.stats()["kv_read"]`` counter
                            (two-phase decode: sign + progressive planes +
                            top-k full rows, at the engine's static
                            shapes) side by side with the analytic model
                            evaluated at the same (S, D, rounds, keep).

Modeled and measured agree by construction: both price sign + shrinking
survivor planes plus, per surviving token, the full bgpp row (packed
planes + sign + scales + int8 V) that ``kv_cache._token_row_bytes``
charges.  The emitted ``measured_over_modeled`` ratio is gated at
1.0 ± 10% — the f32 output write the kernel also performs is reported by
the model as a separate ``output_write_bytes`` column, outside the gate.

    PYTHONPATH=src python benchmarks/bgpp_traffic.py \\
        [--bgpp-rounds 4] [--bgpp-keep-ratio 0.25]
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp

try:  # python -m benchmarks.bgpp_traffic
    from benchmarks.common import emit, emit_header
except ImportError:  # python benchmarks/bgpp_traffic.py
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from common import emit, emit_header

from repro.core import bgpp  # noqa: E402


def _measured_serving_traffic(rounds: int, keep_ratio: float):
    """Drive a small paged bgpp scheduler and read the kv-bytes counter."""
    from repro.configs import apply_bgpp_overrides, get_config
    from repro.models import model_zoo
    from repro.serving import kv_cache as kvc
    from repro.serving.request import poisson_trace
    from repro.serving.scheduler import Scheduler

    cfg = apply_bgpp_overrides(
        get_config("phi4-mini-3.8b", smoke=True),
        rounds=rounds, keep_ratio=keep_ratio,
    )
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    slots, max_seq = 2, 64
    layout = kvc.layout_for(cfg, slots, max_seq, kv_format="bgpp",
                            layout="paged", page_size=8)
    sched = Scheduler(params, cfg, layout, chunk_budget=8)
    rng = np.random.default_rng(0)
    for r in poisson_trace(rng, 4, cfg.vocab_size, 6, max_prompt=20):
        sched.submit(r)
    sched.run(max_steps=2_000)
    kv = sched.stats()["kv_read"]
    n_rows = slots * len(layout.global_layers)  # (slot, layer) pairs/step
    return cfg, layout, kv, n_rows, max_seq


def run(bgpp_rounds: int = 4, bgpp_keep_ratio: float = 0.25):
    rng = np.random.default_rng(4)
    S, D = 2048, 128
    k = np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127).astype(np.int32)
    sign = jnp.asarray((k < 0).astype(np.uint8))
    mag = np.abs(k).astype(np.uint8)
    planes = jnp.asarray(np.stack([(mag >> p) & 1 for p in range(7)]).astype(np.uint8))
    q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
    scale = 1.0 / np.sqrt(D) / 900.0
    true_scores = k @ np.asarray(q)
    top32 = set(np.argsort(true_scores)[-32:].tolist())

    # kernel-path traffic model (paper Fig. 5g analogue on the TPU target)
    from repro.analysis.roofline import bgpp_kernel_traffic

    for keep in (0.125, 0.25, 0.5):
        kt = bgpp_kernel_traffic(32768, 128, rounds=4, keep_ratio=keep)
        emit(
            f"fig5g_kernel_traffic_keep{keep}", 0.0,
            f"bytes={kt['bgpp_kernel_bytes']:.0f};dense={kt['dense_int8_bytes']:.0f};"
            f"reduction={kt['reduction']:.2f}x",
        )

    full_bytes = S * D  # 8-bit fetch of every key
    for alpha in (0.3, 0.4, 0.5, 0.55, 0.6, 0.8):
        alive, _, stats = bgpp.bgpp_predict(
            q, planes, sign,
            bgpp.BGPPConfig(rounds=4, alpha=alpha), logit_scale=scale,
        )
        kept = np.flatnonzero(np.asarray(alive))
        recall = len(top32 & set(kept.tolist())) / 32
        sparsity = 1 - len(kept) / S
        traffic = float(stats.predict_bytes) / full_bytes
        emit(
            f"fig24a_alpha{alpha}", 0.0,
            f"sparsity={sparsity:.3f};top32_recall={recall:.3f};"
            f"predict_traffic_frac={traffic:.3f}",
        )

    # ---- modeled vs MEASURED: the serving counter next to the model ------
    cfg, layout, kv, n_rows, max_seq = _measured_serving_traffic(
        bgpp_rounds, bgpp_keep_ratio
    )
    Hk = cfg.num_kv_heads
    # measured bytes one (slot, layer, kv-head) fetches per decode step —
    # the same unit the analytic kernel model prices
    measured_ph = kv["decode_bytes_per_step"] / n_rows / Hk
    model = bgpp_kernel_traffic(max_seq, cfg.head_dim, rounds=bgpp_rounds,
                                keep_ratio=bgpp_keep_ratio)
    emit(
        "bgpp_serving_measured", 0.0,
        f"S={max_seq};rounds={bgpp_rounds};keep={bgpp_keep_ratio};"
        f"measured_bytes_per_head={measured_ph:.0f};"
        f"modeled_bytes_per_head={model['bgpp_kernel_bytes']:.0f};"
        f"measured_over_modeled={measured_ph / model['bgpp_kernel_bytes']:.2f};"
        f"full_rows_per_slot={kv['bgpp']['full_rows_per_slot']};"
        f"reduction_vs_bf16={kv['decode_bytes_reduction_vs_bf16']}x",
    )
    ratio = measured_ph / model["bgpp_kernel_bytes"]
    if not 0.9 <= ratio <= 1.1:
        raise SystemExit(
            f"bgpp_traffic: measured_over_modeled={ratio:.3f} outside "
            f"[0.9, 1.1] — the serving kv_read counter and "
            f"roofline.bgpp_kernel_traffic have drifted apart"
        )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bgpp-rounds", type=int, default=4)
    ap.add_argument("--bgpp-keep-ratio", type=float, default=0.25)
    args = ap.parse_args()
    emit_header()
    run(bgpp_rounds=args.bgpp_rounds, bgpp_keep_ratio=args.bgpp_keep_ratio)


if __name__ == "__main__":
    main()
