"""Paper Fig. 19: ablation of BRCR / BSTC / BGPP latency contributions.

CPU has no TPU clock, so latency is modeled through the roofline terms the
techniques move (the same accounting as EXPERIMENTS.md §Roofline):

  baseline    : dense INT8 compute + raw weight bytes + full KV fetch
  +BRCR       : compute term × measured add-reduction (prefill-bound)
  +BSTC       : weight bytes ÷ measured CR           (decode weight-bound)
  +BGPP       : KV bytes × measured alive fraction   (decode KV-bound)

Reported per the paper's two regimes: long-prompt summarization (prefill-
dominant) and generation (decode-dominant).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.analysis.roofline import V5E
from repro.core import bgpp, brcr, bstc
from repro.utils.synthetic import synthetic_llm_weight_int8


def run():
    rng = np.random.default_rng(5)
    w_q, scale = synthetic_llm_weight_int8(rng, (64, 2048))
    cost = brcr.brcr_cost(jnp.asarray(w_q), m=4)
    add_reduction = cost.adds_total / cost.adds_bsc_baseline
    bw = bstc.encode_weight(w_q, scale)
    cr = bw.compression_ratio

    S, D = 2048, 128
    k = np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127).astype(np.int32)
    sign = jnp.asarray((k < 0).astype(np.uint8))
    mag = np.abs(k).astype(np.uint8)
    planes = jnp.asarray(np.stack([(mag >> p) & 1 for p in range(7)]).astype(np.uint8))
    q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
    alive, _, stats = bgpp.bgpp_predict(
        q, planes, sign, bgpp.BGPPConfig(rounds=4, alpha=0.55),
        logit_scale=1.0 / np.sqrt(D) / 900.0,
    )
    alive_frac = float(jnp.mean(alive.astype(jnp.float32)))
    predict_frac = float(stats.predict_bytes) / (S * D)

    # toy 7B-ish single-chip model: per-token decode, per-seq prefill
    n_params = 7e9
    seq = 4096
    t_prefill_compute = 2 * n_params * seq / V5E.peak_flops
    t_decode_weights = n_params / V5E.hbm_bw  # int8 bytes/token
    t_decode_kv = 32 * S * 2 * 8 * D / V5E.hbm_bw  # 32L × K+V × 8kv × D int8

    base = t_prefill_compute + seq / 8 * (t_decode_weights + t_decode_kv)
    brcr_t = t_prefill_compute * add_reduction + seq / 8 * (
        t_decode_weights + t_decode_kv
    )
    bstc_t = t_prefill_compute * add_reduction + seq / 8 * (
        t_decode_weights / cr + t_decode_kv
    )
    bgpp_t = t_prefill_compute * add_reduction + seq / 8 * (
        t_decode_weights / cr + t_decode_kv * (alive_frac + predict_frac / 8)
    )
    emit("fig19_baseline", 0.0, f"model_s={base:.4f}")
    emit("fig19_plus_brcr", 0.0,
         f"model_s={brcr_t:.4f};speedup={base/brcr_t:.2f}x;adds_ratio={add_reduction:.3f}")
    emit("fig19_plus_bstc", 0.0,
         f"model_s={bstc_t:.4f};speedup={base/bstc_t:.2f}x;CR={cr:.2f}")
    emit("fig19_plus_bgpp", 0.0,
         f"model_s={bgpp_t:.4f};speedup={base/bgpp_t:.2f}x;alive={alive_frac:.3f}")
