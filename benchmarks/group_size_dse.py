"""Paper Fig. 18: design-space exploration of the group size m.

Sweeps m over 1..8 and reports computation reduction (CPR, vs dense) and
compression rate (CR) on LLM-statistics weights.  The paper finds CPR peaks
around m=5 and CR around m=4, and picks m=4.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import brcr, bstc
from repro.utils.synthetic import synthetic_llm_weight_int8


def run():
    rng = np.random.default_rng(2)
    w_q, scale = synthetic_llm_weight_int8(rng, (64, 2048))
    w_j = jnp.asarray(w_q)

    best_cpr, best_cr = None, None
    for m in range(1, 9):
        M = (w_q.shape[0] // m) * m
        cost = brcr.brcr_cost(w_j[:M], m=m)
        cpr = cost.macs_dense / max(cost.adds_total, 1)
        bw = bstc.encode_weight(w_q[:M], scale[:M], m=m)
        cr = bw.compression_ratio
        emit(f"fig18_m{m}", 0.0, f"CPR={cpr:.3f};CR={cr:.3f}")
        if best_cpr is None or cpr > best_cpr[1]:
            best_cpr = (m, cpr)
        if best_cr is None or cr > best_cr[1]:
            best_cr = (m, cr)
    emit("fig18_best", 0.0,
         f"CPR_peak_m={best_cpr[0]};CR_peak_m={best_cr[0]};paper_picks_m=4")
