"""Paper Fig. 8(b,c): BSTC compression ratio vs sparsity and per-plane
sparsity profile of quantized LLM-like weights."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import bitslice, bstc, quantization
from repro.utils.synthetic import synthetic_llm_weight


def run():
    # Fig 8(b): closed-form CR vs bit sparsity for m in {2,4,8}
    for m in (2, 4, 8):
        pts = []
        for bs in (0.5, 0.65, 0.8, 0.9, 0.95):
            cs = bstc.expected_column_sparsity(bs, m)
            pts.append(f"bs{bs}:CR={bstc.compression_ratio_closed_form(m, cs):.2f}")
        emit(f"fig8b_cr_curve_m{m}", 0.0, ";".join(pts))

    # Fig 8(c): per-plane sparsity of an actual quantized weight
    rng = np.random.default_rng(3)
    w = synthetic_llm_weight(rng, (512, 1024))
    qw = quantization.quantize_weight(jnp.asarray(w))
    _, mag = bitslice.to_sign_magnitude(qw.q)
    sp = np.asarray(bitslice.bit_sparsity(bitslice.bitplanes(mag)))
    emit(
        "fig8c_plane_sparsity", 0.0,
        ";".join(f"bit{p+1}={s:.3f}" for p, s in enumerate(sp))
        + f";planes3to7_all_ge_0.65={bool((sp[2:] > 0.65).all())}",
    )
    bw = bstc.encode_weight(np.asarray(qw.q), np.asarray(qw.scale))
    compressed = [p + 1 for p in range(7) if bw.encoded[p] is not None]
    emit("fig8c_compressed_planes", 0.0,
         f"bits={compressed};CR={bw.compression_ratio:.3f}")
