"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (wall-clock is CPU/interpret-mode;
the derived column carries the paper-comparable statistics).
"""

from __future__ import annotations

import sys
import traceback

from benchmarks.common import emit_header


def main() -> None:
    from benchmarks import (
        ablation_latency,
        bgpp_traffic,
        bstc_compression,
        computation_reduction,
        e2e_model,
        group_size_dse,
        kernel_bench,
        memory_access,
        quant_fidelity,
    )

    modules = [
        ("fig17a", computation_reduction),
        ("fig17b", memory_access),
        ("fig18", group_size_dse),
        ("fig8", bstc_compression),
        ("fig24a", bgpp_traffic),
        ("fig19", ablation_latency),
        ("tab2", quant_fidelity),
        ("fig20", e2e_model),
        ("kernels", kernel_bench),
    ]
    emit_header()
    failed = []
    for name, mod in modules:
        try:
            mod.run()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
