"""Paper Fig. 17(a): normalized GEMM computation across schemes.

Compares op counts (the paper's metric) for a prefill-stage GEMM on
LLM-statistics weights:

  dense INT8 MACs / value-sparse adds / bit-serial (BSC) adds / BRCR adds

and reports the BRCR reduction ratio.  The paper reports ~72.4% average
reduction (their fig includes attention sparsity; our GEMM-only number is
the BRCR row of the ablation).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import brcr
from repro.utils.synthetic import synthetic_llm_weight_int8

import jax.numpy as jnp


def run():
    rng = np.random.default_rng(0)
    # a representative H×H tile of an LLM projection (paper: H ~ 4k)
    M, H, N = 64, 2048, 8
    w_q, _ = synthetic_llm_weight_int8(rng, (M, H))
    x = jnp.asarray(rng.integers(-50, 50, size=(H, N)), jnp.float32)

    cost = brcr.brcr_cost(jnp.asarray(w_q), n_cols=N, m=4)
    us = time_fn(lambda: brcr.brcr_matmul(jnp.asarray(w_q), x, m=4), iters=3)

    dense = cost.macs_dense
    emit("fig17a_dense_int8_macs", 0.0, f"ops={dense}")
    emit("fig17a_value_sparse_adds", 0.0,
         f"ops={cost.adds_value_sparse};vs={cost.value_sparsity:.3f}")
    emit("fig17a_bsc_bitserial_adds", 0.0,
         f"ops={cost.adds_bsc_baseline};bs={cost.bit_sparsity:.3f}")
    emit("fig17a_brcr_adds", us,
         f"ops={cost.adds_total};reduction_vs_bsc={cost.reduction_vs_bsc:.3f}")
    red = 1.0 - cost.adds_total / cost.adds_bsc_baseline
    emit("fig17a_brcr_reduction_pct", 0.0, f"{100*red:.1f}%_vs_bitserial")
