"""Continuous-batching scheduler: slot lifecycle, eviction/reuse isolation,
admission ordering, and trace/stats plumbing."""

import json

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving.request import Request, SlotState
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

ARCH = "phi4-mini-3.8b"
MAX_SEQ = 64


@pytest.fixture(scope="module")
def served():
    cfg = get_config(ARCH, smoke=True)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    return cfg, params


def make_sched(cfg, params, slots=2, kv_format="int8", admission="chunked"):
    layout = kvc.layout_for(cfg, slots, MAX_SEQ, kv_format=kv_format)
    return Scheduler(params, cfg, layout, admission=admission, chunk_budget=8,
                     prefill_kw=dict(block_q=8, block_k=8))


def make_requests(cfg, n, rng, max_new=4, stagger=2):
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (int(rng.integers(6, 14)),))
            .astype(np.int32),
            max_new_tokens=max_new,
            arrival_step=i * stagger,
        )
        for i in range(n)
    ]


class TestLifecycle:
    def test_all_requests_finish_and_slots_recycle(self, served):
        cfg, params = served
        rng = np.random.default_rng(0)
        sched = make_sched(cfg, params, slots=2)
        reqs = make_requests(cfg, 5, rng)  # 5 requests > 2 slots => reuse
        for r in reqs:
            sched.submit(r)
        stats = sched.run(max_steps=200)

        assert stats["finished_requests"] == 5
        assert all(s.state is SlotState.EMPTY for s in sched.slots)
        assert all(len(r.generated) == r.max_new_tokens for r in sched.finished)
        # FIFO admission among arrived requests
        assert [r.rid for r in sorted(sched.finished,
                                      key=lambda r: r.admitted_step)] == [
            r.rid for r in sorted(sched.finished, key=lambda r: r.arrival_step)
        ]
        for r in sched.finished:
            assert r.queue_wait_steps >= 0
            assert r.latency_steps >= len(r.generated) - 1
        # EMPTY slots keep stepping their pos harmlessly (their rows are
        # garbage by design); eviction + the next admission reset them
        for s in sched.slots:
            sched.cache = kvc.reset_slot(sched.cache, sched.layout, s.index)
        assert np.all(np.asarray(sched.cache["pos"]) == 0)
        json.dumps(stats)  # trace must be JSON-serializable

    def test_occupancy_tracked(self, served):
        cfg, params = served
        rng = np.random.default_rng(1)
        sched = make_sched(cfg, params, slots=2)
        for r in make_requests(cfg, 4, rng, max_new=3, stagger=0):
            sched.submit(r)
        stats = sched.run(max_steps=100)
        assert 0.0 < stats["mean_occupancy"] <= 1.0
        # with 4 back-to-back requests on 2 slots the busy steps are full
        assert stats["mean_occupancy"] > 0.5

    def test_max_seq_clamps_decode(self, served):
        cfg, params = served
        rng = np.random.default_rng(2)
        sched = make_sched(cfg, params, slots=1)
        prompt = rng.integers(0, cfg.vocab_size, (MAX_SEQ - 3,)).astype(np.int32)
        sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=64))
        stats = sched.run(max_steps=100)
        assert stats["finished_requests"] == 1
        (req,) = sched.finished
        # prompt_len + generated - 1 never reaches max_seq
        assert req.prompt_len + len(req.generated) - 1 <= MAX_SEQ
        assert len(req.generated) < 64

    def test_rejects_malformed_prompts(self, served):
        cfg, params = served
        sched = make_sched(cfg, params, slots=1)
        with pytest.raises(ValueError):  # empty prompt: no logits to sample
            sched.submit(Request(rid=0, prompt=np.zeros((0,), np.int32),
                                 max_new_tokens=2))
        with pytest.raises(ValueError):  # no decode slot left below max_seq
            sched.submit(Request(
                rid=1, prompt=np.zeros((MAX_SEQ,), np.int32),
                max_new_tokens=2))

    def test_buckets_smaller_than_budget(self, served):
        """Custom buckets below chunk_budget: admission chunks at the
        largest bucket instead of overrunning it."""
        cfg, params = served
        rng = np.random.default_rng(7)
        layout = kvc.layout_for(cfg, 1, MAX_SEQ, kv_format="int8")
        sched = Scheduler(params, cfg, layout, admission="chunked",
                          chunk_budget=16, buckets=(4,))
        sched.submit(Request(
            rid=0, prompt=rng.integers(0, cfg.vocab_size, (9,))
            .astype(np.int32), max_new_tokens=2))
        sched.run(max_steps=100)
        assert len(sched.finished) == 1
        assert max(sched.prefill_tokens_per_step) <= 16

    def test_chunked_from_eager_shared_fns(self, served):
        """shared_fns from an eager scheduler lack a ChunkedPrefill; a
        chunked scheduler must build its own instead of crashing."""
        cfg, params = served
        rng = np.random.default_rng(8)
        eager = make_sched(cfg, params, slots=1, admission="eager")
        sched = Scheduler(params, cfg, eager.layout, admission="chunked",
                          chunk_budget=8, shared_fns=eager.shared_fns())
        sched.submit(Request(
            rid=0, prompt=rng.integers(0, cfg.vocab_size, (10,))
            .astype(np.int32), max_new_tokens=2))
        sched.run(max_steps=100)
        assert len(sched.finished) == 1

    def test_eos_stops_decode(self, served):
        cfg, params = served
        rng = np.random.default_rng(3)
        sched = make_sched(cfg, params, slots=1)
        eos = 7

        def eos_after_two(logits):
            # deterministic stand-in sampler: emit eos from the 2nd token on
            return np.full((logits.shape[0],), eos, np.int32)

        sched.sample_fn = eos_after_two
        prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
        sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=16,
                             eos_id=eos))
        stats = sched.run(max_steps=50)
        assert stats["finished_requests"] == 1
        assert sched.finished[0].generated[-1] == eos
        assert len(sched.finished[0].generated) < 16


class TestEagerAdmission:
    """The PR-2 whole-prompt admission path stays available as the
    reference/baseline (``admission="eager"``)."""

    def test_lifecycle_and_trace(self, served):
        cfg, params = served
        rng = np.random.default_rng(5)
        sched = make_sched(cfg, params, slots=2, admission="eager")
        for r in make_requests(cfg, 4, rng, max_new=3):
            sched.submit(r)
        stats = sched.run(max_steps=200)
        assert stats["admission"] == "eager"
        assert stats["finished_requests"] == 4
        # eager admission spends whole prompts in one step — the budget
        # audit records it (that's exactly what chunked admission bounds)
        assert stats["max_prefill_tokens_per_step"] >= 6
        json.dumps(stats)

    def test_matches_chunked_admission_logits(self, served):
        """Chunked and eager admission are different prefill numerics of
        the same math: teacher-forced per-token logits must agree tightly
        (bf16, no greedy compounding)."""
        cfg, params = served
        rng = np.random.default_rng(6)
        reqs = [
            (rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32),
             rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32))
            for n in (9, 14)
        ]

        def run(admission):
            sched = make_sched(cfg, params, slots=2, kv_format="bf16",
                               admission=admission)
            sched.record_logits = True
            for rid, (prompt, forced) in enumerate(reqs):
                sched.submit(Request(rid=rid, prompt=prompt,
                                     max_new_tokens=4, arrival_step=rid,
                                     forced_tokens=forced))
            sched.run(max_steps=100)
            return {r.rid: r.logit_rows for r in sched.finished}

        chunked, eager = run("chunked"), run("eager")
        for rid in chunked:
            for t, (g, e) in enumerate(zip(chunked[rid], eager[rid])):
                err = float(np.max(np.abs(g - e)))
                assert err < 5e-3, f"rid {rid} token {t}: |d|={err}"


class TestSlotIsolation:
    @pytest.mark.parametrize("admission", ["chunked", "eager"])
    def test_concurrent_greedy_matches_alone(self, served, admission):
        """Greedy decodes of a request must be identical whether it shares
        the batch with others (incl. slot reuse after eviction) or runs
        with every other slot EMPTY."""
        cfg, params = served
        rng = np.random.default_rng(4)
        prompts = [
            rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
            for n in (9, 13, 7)
        ]

        shared = {}

        def run(selected):
            sched = make_sched(cfg, params, slots=2, kv_format="bf16",
                               admission=admission)
            if shared:
                sched.serve_step = shared["serve_step"]
                sched.chunked = shared["chunked"]
            shared.update(sched.shared_fns())
            for i in selected:
                sched.submit(Request(rid=i, prompt=prompts[i],
                                     max_new_tokens=5, arrival_step=2 * i))
            sched.run(max_steps=100)
            return {r.rid: r.generated for r in sched.finished}

        joint = run([0, 1, 2])  # request 2 reuses the slot request 0 held
        for rid in range(3):
            alone = run([rid])
            assert joint[rid] == alone[rid], f"request {rid} not isolated"
