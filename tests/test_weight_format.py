"""Serve-time ``weight_format`` knob: config validation, env resolution,
record conversion, and scheduler-level serve parity.

The parity suite pins the quantized path to its dense-reconstruction
oracle: a plain bf16-built serve_step fed ``weights.dequantize`` of the
SAME records must produce bit-identical logits — ``layers.wdot``'s record
branch computes exactly ``x @ dequantize(rec).astype(x.dtype)``.  The
bf16 default stays byte-for-byte the old path (``prepare_serve_params``
returns the params object untouched), and ``bstc`` serves the identical
records as ``int8`` (the two-state coding is lossless; only the
``weight_read`` pricing differs).
"""

import numpy as np
import pytest

import jax

from repro.configs import (WEIGHT_FORMATS, apply_weight_format_override,
                           get_config)
from repro.configs.base import MCBPOptions
from repro.models import layers, model_zoo
from repro.serving import kv_cache as kvc
from repro.serving import weights as swt
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

SLOTS = 2
MAX_SEQ = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    return cfg, params


def _layout(cfg):
    return kvc.layout_for(cfg, SLOTS, MAX_SEQ, kv_format="bf16")


def _requests(cfg, n=2, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(4, 12)),)).astype(np.int32),
            max_new_tokens=6,
            arrival_step=0,
        )
        for rid in range(n)
    ]


def _run_sched(cfg, params, reqs, serve_params_override=None):
    sched = Scheduler(params, cfg, _layout(cfg), chunk_budget=6,
                      record_logits=True)
    if serve_params_override is not None:
        # decode-only override: prefill still reads sched.params (raw), so
        # the oracle run prefills identically to the run under test
        sched.serve_params = serve_params_override
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=500)
    assert len(sched.finished) == len(reqs), "trace did not drain"
    return sched, {r.rid: r for r in sched.finished}


def _dequantized_tree(tree, dtype):
    if swt.is_record(tree):
        return swt.dequantize(tree, dtype)
    if isinstance(tree, dict):
        return {k: _dequantized_tree(v, dtype) for k, v in tree.items()}
    return tree


# --------------------------------------------------------------------------
# config-time validation + deprecation shim
# --------------------------------------------------------------------------


class TestConfigKnob:
    def test_rejects_unknown_format_at_config_time(self):
        with pytest.raises(ValueError, match="weight_format"):
            MCBPOptions(weight_format="fp4")

    def test_accepts_every_registered_format(self):
        for fmt in WEIGHT_FORMATS:
            assert MCBPOptions(weight_format=fmt).weight_format == fmt

    def test_bstc_weights_shim_warns_and_maps(self):
        with pytest.warns(DeprecationWarning, match="bstc_weights"):
            opt = MCBPOptions(bstc_weights=True)
        assert opt.weight_format == "bstc"

    def test_explicit_non_bf16_format_wins_over_shim(self):
        with pytest.warns(DeprecationWarning):
            opt = MCBPOptions(bstc_weights=True, weight_format="int8")
        assert opt.weight_format == "int8"

    def test_apply_override(self, model):
        cfg, _ = model
        assert apply_weight_format_override(cfg, None) is cfg
        assert apply_weight_format_override(
            cfg, "bstc").mcbp.weight_format == "bstc"
        with pytest.raises(ValueError, match="weight_format"):
            apply_weight_format_override(cfg, "fp8")


class TestResolve:
    def test_config_value(self, model):
        cfg, _ = model
        assert swt.resolve(cfg) == "bf16"
        assert swt.resolve(apply_weight_format_override(cfg, "bstc")) == "bstc"

    def test_env_overrides_config(self, model, monkeypatch):
        cfg, _ = model
        monkeypatch.setenv(swt.ENV_VAR, "int8")
        assert swt.resolve(cfg) == "int8"

    def test_invalid_env_raises(self, model, monkeypatch):
        cfg, _ = model
        monkeypatch.setenv(swt.ENV_VAR, "fp4")
        with pytest.raises(ValueError, match="weight_format"):
            swt.resolve(cfg)

    def test_validate_rejects_non_transformer_family(self):
        cfg = apply_weight_format_override(
            get_config("mamba2-1.3b", smoke=True), "int8")
        with pytest.raises(ValueError, match="family"):
            swt.validate(cfg)


# --------------------------------------------------------------------------
# record conversion
# --------------------------------------------------------------------------


class TestPrepareServeParams:
    def test_bf16_leaves_params_untouched(self, model):
        cfg, params = model
        sp, plan = swt.prepare_serve_params(params, cfg, _layout(cfg), "bf16")
        assert sp is params, "bf16 must be byte-for-byte the old path"
        assert plan.fmt == "bf16"

    def test_quantized_build_converts_projection_leaves(self, model):
        cfg, params = model
        sp, plan = swt.prepare_serve_params(
            params, apply_weight_format_override(cfg, "int8"),
            _layout(cfg), "int8")
        assert swt.is_record(sp["layers"]["attn"]["wq"])
        assert swt.is_record(sp["layers"]["mlp"]["down"])
        # tied embeddings get an explicit lm_head record at serve time
        assert swt.is_record(sp["lm_head"])
        # ... but the raw leaves the prefill path reads are untouched
        assert sp["embed"] is params["embed"]
        assert plan.fmt == "int8"
        swt.check_serve_params(sp, cfg, "int8")  # records pass the probe

    def test_bstc_serves_identical_records_to_int8(self, model):
        cfg, params = model
        sp_i, _ = swt.prepare_serve_params(
            params, apply_weight_format_override(cfg, "int8"),
            _layout(cfg), "int8")
        sp_b, _ = swt.prepare_serve_params(
            params, apply_weight_format_override(cfg, "bstc"),
            _layout(cfg), "bstc")
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)),
            sp_i, sp_b,
        )

    def test_raw_params_rejected_by_quantized_build(self, model):
        cfg, params = model
        with pytest.raises(ValueError, match="raw weight leaves"):
            swt.check_serve_params(params, cfg, "bstc")


# --------------------------------------------------------------------------
# serve parity: the scheduler end-to-end, pinned to the oracle
# --------------------------------------------------------------------------


class TestServeParity:
    @pytest.mark.parametrize("fmt", ["int8", "bstc"])
    def test_quantized_serve_matches_dense_reconstruction(self, model, fmt):
        cfg, params = model
        cfg_fmt = apply_weight_format_override(cfg, fmt)
        _, got = _run_sched(cfg_fmt, params, _requests(cfg))

        sp, _plan = swt.prepare_serve_params(params, cfg_fmt,
                                             _layout(cfg_fmt), fmt)
        oracle = _dequantized_tree(sp, layers._dtype(cfg.dtype))
        _, want = _run_sched(cfg, params, _requests(cfg),
                             serve_params_override=oracle)

        for rid in got:
            g, w = got[rid], want[rid]
            assert g.generated == w.generated, (
                f"{fmt} rid {rid}: greedy tokens diverge from the dense "
                f"reconstruction oracle")
            assert len(g.logit_rows) == len(w.logit_rows)
            for t, (a, b) in enumerate(zip(g.logit_rows, w.logit_rows)):
                assert np.array_equal(a, b), (
                    f"{fmt} rid {rid} token {t}: quantized serve logits "
                    f"not bit-identical to the dense reconstruction "
                    f"(max |d| {np.max(np.abs(a - b))})")

    def test_bstc_run_bit_identical_to_int8_run(self, model):
        cfg, params = model
        _, got_i = _run_sched(apply_weight_format_override(cfg, "int8"),
                              params, _requests(cfg))
        _, got_b = _run_sched(apply_weight_format_override(cfg, "bstc"),
                              params, _requests(cfg))
        for rid in got_i:
            assert got_i[rid].generated == got_b[rid].generated
            for a, b in zip(got_i[rid].logit_rows, got_b[rid].logit_rows):
                assert np.array_equal(a, b)

    def test_explicit_bf16_bit_identical_to_default(self, model):
        cfg, params = model
        _, got = _run_sched(apply_weight_format_override(cfg, "bf16"),
                            params, _requests(cfg))
        _, want = _run_sched(cfg, params, _requests(cfg))
        for rid in got:
            assert got[rid].generated == want[rid].generated
            for a, b in zip(got[rid].logit_rows, want[rid].logit_rows):
                assert np.array_equal(a, b)

    def test_scheduler_env_override(self, model, monkeypatch):
        cfg, params = model
        monkeypatch.setenv(swt.ENV_VAR, "bstc")
        sched = Scheduler(params, cfg, _layout(cfg))
        assert sched.weight_format == "bstc"
        assert swt.is_record(sched.serve_params["layers"]["attn"]["wq"])

    def test_weight_read_counter_accounts_for_steps(self, model):
        cfg, params = model
        sched, _ = _run_sched(apply_weight_format_override(cfg, "bstc"),
                              params, _requests(cfg))
        wr = sched.stats()["weight_read"]
        assert wr["weight_format"] == "bstc"
        assert wr["decode_bytes"] == (
            wr["decode_steps"] * wr["decode_bytes_per_step"])
        assert wr["decode_bytes_per_step"] <= (
            wr["decode_bf16_equiv_bytes_per_step"] / 2
        ), "bstc coded weight traffic must be <= half the bf16 bytes"
        assert 0.9 <= wr["measured_over_modeled"] <= 1.1
