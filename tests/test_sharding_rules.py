"""ShardingRules: logical-axis mapping, divisibility safety, FSDP/seq modes."""

import warnings

import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as sh

jax.config.update("jax_platform_name", "cpu")


def fake_mesh(shape, names):
    class M:
        pass

    m = M()
    m.shape = dict(zip(names, shape))
    m.axis_names = names
    return m


class TestRules:
    def test_default_tp_mapping(self):
        r = sh.ShardingRules()
        assert r.spec((sh.VOCAB, sh.D_MODEL)) == P("model", None)
        assert r.spec((sh.D_MODEL, sh.HEADS)) == P(None, "model")
        assert r.spec((sh.BATCH, None, None)) == P("data", None, None)

    def test_multipod_batch_axes(self):
        r = sh.ShardingRules(batch_axes=("pod", "data"))
        assert r.spec((sh.BATCH, None)) == P(("pod", "data"), None)

    def test_axis_used_once(self):
        r = sh.ShardingRules()
        # two model-mapped logical axes: second one must drop
        assert r.spec((sh.HEADS, sh.KV_HEADS)) == P("model", None)

    def test_fsdp_axes(self):
        r = sh.ShardingRules(fsdp_axes=(sh.D_MODEL,))
        assert r.spec((sh.D_MODEL, sh.FF)) == P(("data",), "model")

    def test_seq_shard_mode(self):
        r = sh.ShardingRules(seq_shard=True)
        # long-context: KV seq over data, batch replicated
        assert r.spec((sh.LAYERS, sh.BATCH, sh.SEQ, sh.KV_HEADS, None)) == P(
            None, None, ("data",), "model", None
        )

    def test_seq_unsharded_by_default(self):
        r = sh.ShardingRules()
        assert r.spec((sh.BATCH, sh.SEQ, None)) == P("data", None, None)


class TestDivisibilitySafety:
    def test_drops_nondividing_axis(self):
        mesh = fake_mesh((16, 16), ("data", "model"))
        r = sh.ShardingRules()
        # kv_heads=1 cannot shard over model=16
        spec = r.spec_for_shape(mesh, (sh.LAYERS, sh.BATCH, sh.SEQ, sh.KV_HEADS, None),
                                (4, 128, 32768, 1, 256))
        assert spec == P(None, "data", None, None, None)

    def test_keeps_dividing_axis(self):
        mesh = fake_mesh((16, 16), ("data", "model"))
        r = sh.ShardingRules()
        spec = r.spec_for_shape(mesh, (sh.D_MODEL, sh.HEADS), (4096, 8192))
        assert spec == P(None, "model")

    def test_batch_of_one_replicates(self):
        mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
        r = sh.ShardingRules(batch_axes=("pod", "data"))
        spec = r.spec_for_shape(mesh, (sh.BATCH, None), (1, 1))
        assert spec == P(None, None)

    def test_tuple_axis_product(self):
        mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
        r = sh.ShardingRules(batch_axes=("pod", "data"))
        # batch 64 divisible by 32 (pod*data)
        assert r.spec_for_shape(mesh, (sh.BATCH, None), (64, 8)) == P(
            ("pod", "data"), None
        )
        # batch 16 NOT divisible by 32
        assert r.spec_for_shape(mesh, (sh.BATCH, None), (16, 8)) == P(None, None)


class TestFallbackWarning:
    """Silent-replication fallback must not stay silent: a real size
    mismatch warns ShardingFallbackWarning; legitimate no-op cases
    (dim 1, duplicate mesh axis) stay quiet."""

    def test_warns_on_nondividing_dim(self):
        mesh = fake_mesh((16, 16), ("data", "model"))
        r = sh.ShardingRules()
        with pytest.warns(sh.ShardingFallbackWarning,
                          match="kv_heads.*dim 6.*not.*divisible"):
            spec = r.spec_for_shape(mesh, (sh.KV_HEADS, None), (6, 64))
        assert spec == P(None, None)  # behaviour unchanged: replicated

    def test_warns_on_nondividing_tuple_axis(self):
        mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
        r = sh.ShardingRules(batch_axes=("pod", "data"))
        with pytest.warns(sh.ShardingFallbackWarning, match="size 32"):
            r.spec_for_shape(mesh, (sh.BATCH, None), (16, 8))

    def test_silent_on_dim_one(self):
        # dim 1 = "nothing to shard" (B=1 chunks, squeezed axes) — not a
        # misconfiguration, must not spam
        mesh = fake_mesh((16, 16), ("data", "model"))
        r = sh.ShardingRules()
        with warnings.catch_warnings():
            warnings.simplefilter("error", sh.ShardingFallbackWarning)
            spec = r.spec_for_shape(mesh, (sh.KV_HEADS, sh.BATCH), (1, 32))
        assert spec == P(None, "data")

    def test_silent_on_duplicate_axis(self):
        # a later logical dim losing "model" to an earlier one is the
        # documented at-most-once rule, not a fallback
        mesh = fake_mesh((16, 16), ("data", "model"))
        r = sh.ShardingRules()
        with warnings.catch_warnings():
            warnings.simplefilter("error", sh.ShardingFallbackWarning)
            spec = r.spec_for_shape(mesh, (sh.HEADS, sh.KV_HEADS), (32, 32))
        assert spec == P("model", None)


class TestRulesForMesh:
    def test_detects_pod_axis(self):
        devs = np.asarray(jax.devices()[:1])
        mesh = Mesh(devs.reshape(1, 1, 1), ("pod", "data", "model"))
        r = sh.rules_for_mesh(mesh)
        assert r.batch_axes == ("pod", "data")
        mesh2 = Mesh(devs.reshape(1, 1), ("data", "model"))
        r2 = sh.rules_for_mesh(mesh2)
        assert r2.batch_axes == ("data",)
