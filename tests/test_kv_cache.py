"""KV-cache container invariants: slot eviction must scrub EVERY store
leaf of the slot row — k/v bodies, int8 scales, BGPP bit/sign planes, ring
``abs_pos`` — without touching live neighbors."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.serving import kv_cache as kvc

jax.config.update("jax_platform_name", "cpu")

EXPECTED_LEAVES = {
    "bf16": {"k", "v"},
    "int8": {"k", "v", "k_scale", "v_scale"},
    "bgpp": {"k_planes", "k_sign", "k_scale", "v", "v_scale"},
}


def _filled_cache(cfg, layout):
    """Every leaf nonzero so a missed reset is visible."""
    cache = kvc.init_cache_arrays(cfg, layout)
    return jax.tree.map(lambda a: jnp.full_like(a, 3), cache)


@pytest.mark.parametrize("fmt", ["bf16", "int8", "bgpp"])
def test_reset_slot_clears_every_leaf(fmt):
    # gemma3 has both a sliding-window ring stack and a global stack, so
    # every store family of the format is exercised
    cfg = get_config("gemma3-4b", smoke=True)
    layout = kvc.layout_for(cfg, 3, 32, kv_format=fmt)
    assert layout.local_layers and layout.global_layers
    cache = _filled_cache(cfg, layout)

    # the allocation actually contains the leaves this test claims to cover
    assert set(cache["global"].keys()) == EXPECTED_LEAVES[fmt]
    local_fmt = "int8" if fmt == "bgpp" else fmt
    assert set(cache["local"].keys()) == EXPECTED_LEAVES[local_fmt] | {"abs_pos"}

    slot = 1
    cache = kvc.reset_slot(cache, layout, slot)

    for stack in ("global", "local"):
        for name, arr in cache[stack].items():
            a = np.asarray(arr)
            bdim = kvc._batch_dim(stack, name)
            row = np.take(a, slot, axis=bdim)
            fill = -1 if name == "abs_pos" else 0
            assert np.all(row == fill), f"{stack}/{name}: slot row not cleared"
            for other in (0, 2):  # live neighbors untouched (still 3)
                keep = np.take(a, other, axis=bdim)
                assert np.all(keep == 3), f"{stack}/{name}: slot {other} touched"
    assert int(np.asarray(cache["pos"])[slot]) == 0
    assert np.all(np.asarray(cache["pos"])[[0, 2]] == 3)


def test_reset_slot_covers_mamba_and_cross():
    cfg = get_config("whisper-medium", smoke=True)
    layout = kvc.layout_for(cfg, 2, 16, kv_format="int8")
    cache = _filled_cache(cfg, layout)
    cache = kvc.reset_slot(cache, layout, 0)
    for name in ("cross_k", "cross_v"):
        a = np.asarray(cache[name])
        assert np.all(a[:, 0] == 0) and np.all(a[:, 1] == 3)

    cfg = get_config("mamba2-1.3b", smoke=True)
    layout = kvc.layout_for(cfg, 2, 16)
    cache = _filled_cache(cfg, layout)
    cache = kvc.reset_slot(cache, layout, 1)
    for name in ("h", "conv"):
        a = np.asarray(cache["mamba"][name])
        assert np.all(a[:, 1] == 0) and np.all(a[:, 0] == 3)
