"""KV-cache container invariants: slot eviction must scrub EVERY store
leaf of the slot row — k/v bodies, int8 scales, BGPP bit/sign planes, ring
``abs_pos`` — without touching live neighbors.  Paged layouts: writes
through the page table must land on exactly the pool rows the gather view
reads back (value-identical to the slot layout), and ``reset_slot`` must
leave the shared pool and the page table alone (the allocator owns them)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.serving import kv_cache as kvc

jax.config.update("jax_platform_name", "cpu")

EXPECTED_LEAVES = {
    "bf16": {"k", "v"},
    "int8": {"k", "v", "k_scale", "v_scale"},
    "bgpp": {"k_planes", "k_sign", "k_scale", "v", "v_scale"},
}


def _filled_cache(cfg, layout):
    """Every leaf nonzero so a missed reset is visible."""
    cache = kvc.init_cache_arrays(cfg, layout)
    return jax.tree.map(lambda a: jnp.full_like(a, 3), cache)


@pytest.mark.parametrize("fmt", ["bf16", "int8", "bgpp"])
def test_reset_slot_clears_every_leaf(fmt):
    # gemma3 has both a sliding-window ring stack and a global stack, so
    # every store family of the format is exercised
    cfg = get_config("gemma3-4b", smoke=True)
    layout = kvc.layout_for(cfg, 3, 32, kv_format=fmt)
    assert layout.local_layers and layout.global_layers
    cache = _filled_cache(cfg, layout)

    # the allocation actually contains the leaves this test claims to cover
    assert set(cache["global"].keys()) == EXPECTED_LEAVES[fmt]
    local_fmt = "int8" if fmt == "bgpp" else fmt
    assert set(cache["local"].keys()) == EXPECTED_LEAVES[local_fmt] | {"abs_pos"}

    slot = 1
    cache = kvc.reset_slot(cache, layout, slot)

    for stack in ("global", "local"):
        for name, arr in cache[stack].items():
            a = np.asarray(arr)
            bdim = kvc._batch_dim(stack, name)
            row = np.take(a, slot, axis=bdim)
            fill = -1 if name == "abs_pos" else 0
            assert np.all(row == fill), f"{stack}/{name}: slot row not cleared"
            for other in (0, 2):  # live neighbors untouched (still 3)
                keep = np.take(a, other, axis=bdim)
                assert np.all(keep == 3), f"{stack}/{name}: slot {other} touched"
    assert int(np.asarray(cache["pos"])[slot]) == 0
    assert np.all(np.asarray(cache["pos"])[[0, 2]] == 3)


@pytest.mark.parametrize("fmt", ["bf16", "int8", "bgpp"])
def test_paged_writes_match_slot_layout(fmt):
    """Every write path (decode token, padded chunk, contiguous slot and
    whole-batch prefill) must produce a gather view value-identical to the
    dense row the slot layout stores."""
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    B, S, ps = 2, 32, 8
    Hk, Dh = cfg.num_kv_heads, cfg.head_dim
    ls = kvc.layout_for(cfg, B, S, kv_format=fmt)
    lp = kvc.layout_for(cfg, B, S, kv_format=fmt, layout="paged", page_size=ps)
    dense = kvc.init_cache_arrays(cfg, ls)["global"]
    paged = kvc.init_cache_arrays(cfg, lp)["global"]
    pt = kvc.identity_page_table(lp)
    pkw = dict(page_table=pt, page_size=ps, max_seq=S)
    rng = np.random.default_rng(0)

    def rnd(shape):
        return jnp.asarray(rng.standard_normal(shape), jnp.float32)

    k1, v1 = rnd((B, 1, Hk, Dh)), rnd((B, 1, Hk, Dh))
    dense = kvc.write_token(dense, 0, k1, v1, jnp.asarray([3, 17]))
    paged = kvc.write_token(paged, 0, k1, v1, jnp.asarray([3, 17]), **pkw)

    kc, vc = rnd((1, 6, Hk, Dh)), rnd((1, 6, Hk, Dh))
    dense = kvc.write_prefill(dense, 0, kc, vc, slot=1, offset=5, length=4)
    paged = kvc.write_prefill(paged, 0, kc, vc, slot=1, offset=5, length=4,
                              **pkw)

    kp, vp = rnd((1, 12, Hk, Dh)), rnd((1, 12, Hk, Dh))
    dense = kvc.write_prefill(dense, 1, kp, vp, slot=0)
    paged = kvc.write_prefill(paged, 1, kp, vp, slot=0, **pkw)

    kb, vb = rnd((B, 9, Hk, Dh)), rnd((B, 9, Hk, Dh))
    dense = kvc.write_prefill(dense, 2, kb, vb)
    paged = kvc.write_prefill(paged, 2, kb, vb, **pkw)

    phys = kvc.phys_table(pt, ps, S)
    for gi in range(3):
        view = kvc.paged_entry(paged, gi, phys)
        for n in dense:
            # dense layer slice and paged gather view share one shape:
            # (B, Hk, S, ...) — and must share every value
            assert np.array_equal(np.asarray(dense[n][gi]),
                                  np.asarray(view[n])), (fmt, gi, n)


def test_paged_unmapped_pages_drop_writes():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    lp = kvc.layout_for(cfg, 2, 32, kv_format="bf16", layout="paged",
                        page_size=8)
    store = kvc.init_cache_arrays(cfg, lp)["global"]
    pt = jnp.full((2, 4), -1, jnp.int32).at[0, 0].set(2)  # one mapped page
    k = jnp.ones((2, 1, cfg.num_kv_heads, cfg.head_dim), jnp.float32)
    # slot 0 writes pos 3 (mapped -> page 2 row 3); slot 1 pos 9 (unmapped)
    store = kvc.write_token(store, 0, k, k, jnp.asarray([3, 9]),
                            page_table=pt, page_size=8, max_seq=32)
    body = np.asarray(store["k"][0])
    assert np.all(body[2 * 8 + 3] == 1)
    assert np.count_nonzero(body) == body[2 * 8 + 3].size, \
        "write through an unmapped page leaked into the pool"


def test_paged_reset_slot_leaves_pool_and_table_alone():
    cfg = get_config("gemma3-4b", smoke=True)
    layout = kvc.layout_for(cfg, 3, 32, kv_format="int8", layout="paged",
                            page_size=8)
    assert layout.local_layers and layout.global_layers
    cache = _filled_cache(cfg, layout)
    cache["page_table"] = kvc.identity_page_table(layout)
    cache = kvc.reset_slot(cache, layout, 1)
    for n, a in cache["global"].items():
        assert np.all(np.asarray(a) == 3), f"pool leaf {n} touched"
    assert np.array_equal(np.asarray(cache["page_table"]),
                          np.asarray(kvc.identity_page_table(layout)))
    # slot-major state still resets: local ring row + pos
    for n, a in cache["local"].items():
        row = np.take(np.asarray(a), 1, axis=kvc._batch_dim("local", n))
        assert np.all(row == (-1 if n == "abs_pos" else 0)), f"local/{n}"
    assert int(np.asarray(cache["pos"])[1]) == 0


def test_reset_slot_covers_mamba_and_cross():
    cfg = get_config("whisper-medium", smoke=True)
    layout = kvc.layout_for(cfg, 2, 16, kv_format="int8")
    cache = _filled_cache(cfg, layout)
    cache = kvc.reset_slot(cache, layout, 0)
    for name in ("cross_k", "cross_v"):
        a = np.asarray(cache[name])
        assert np.all(a[:, 0] == 0) and np.all(a[:, 1] == 3)

    cfg = get_config("mamba2-1.3b", smoke=True)
    layout = kvc.layout_for(cfg, 2, 16)
    cache = _filled_cache(cfg, layout)
    cache = kvc.reset_slot(cache, layout, 1)
    for name in ("h", "conv"):
        a = np.asarray(cache["mamba"][name])
        assert np.all(a[:, 1] == 0) and np.all(a[:, 0] == 3)
