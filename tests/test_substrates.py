"""Substrate tests: optimizer (fp32/int8), data pipeline determinism,
checkpoint roundtrip + elastic reshard, fault-tolerant loop, gradient
compression, train_step integration."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.data import Prefetcher, SyntheticLMDataset
from repro.distributed.compression import (
    compressed_psum_mean,
    make_compressed_dp_grad_fn,
)
from repro.models import model_zoo
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.runtime import Heartbeat, StragglerMonitor, run_resilient
from repro.training import make_train_step

jax.config.update("jax_platform_name", "cpu")


def quadratic_params(rng):
    return {
        "w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32),
    }


class TestAdamW:
    @pytest.mark.parametrize("state_dtype", ["fp32", "int8"])
    def test_converges_on_quadratic(self, state_dtype):
        rng = np.random.default_rng(0)
        params = quadratic_params(rng)
        target = quadratic_params(np.random.default_rng(1))
        cfg = AdamWConfig(
            peak_lr=0.05, weight_decay=0.0, state_dtype=state_dtype,
            warmup_steps=5, decay_steps=300,
        )
        state = adamw_init(params, cfg)

        def loss_fn(p):
            return sum(
                jnp.sum(jnp.square(p[k] - target[k])) for k in p
            )

        @jax.jit
        def step(params, state):
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, state, _ = adamw_update(params, grads, state, cfg)
            return params, state, loss

        losses = []
        for _ in range(300):
            params, state, loss = step(params, state)
            losses.append(float(loss))
        assert losses[-1] < 0.05 * losses[0], losses[-1]

    def test_int8_state_is_8bit(self):
        params = {"w": jnp.ones((16, 8), jnp.float32)}
        cfg = AdamWConfig(state_dtype="int8")
        st = adamw_init(params, cfg)
        assert st["m"]["w"]["q"].dtype == jnp.int8
        assert st["v"]["w"]["q"].dtype == jnp.int8

    def test_grad_clip_applied(self):
        params = {"w": jnp.zeros((4,), jnp.float32)}
        cfg = AdamWConfig(peak_lr=1.0, grad_clip=1e-3, warmup_steps=0,
                          weight_decay=0.0)
        st = adamw_init(params, cfg)
        huge = {"w": jnp.full((4,), 1e6, jnp.float32)}
        new, _, metrics = adamw_update(params, huge, st, cfg)
        assert float(metrics["grad_norm"]) > 1e5
        assert float(jnp.max(jnp.abs(new["w"]))) < 10.0


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        ds = SyntheticLMDataset(1000, 32, 4, seed=7)
        b1 = ds.batch(13)
        b2 = SyntheticLMDataset(1000, 32, 4, seed=7).batch(13)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])

    def test_labels_are_next_tokens(self):
        ds = SyntheticLMDataset(1000, 16, 2, seed=0)
        b = ds.batch(0)
        assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)

    def test_tokens_in_range_and_hot_ids(self):
        ds = SyntheticLMDataset(500, 256, 8, seed=1)
        b = ds.batch(3)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 500
        # zipf => some tokens repeat a lot
        _, counts = np.unique(b["tokens"], return_counts=True)
        assert counts.max() > 5

    def test_prefetcher_orders_steps(self):
        ds = SyntheticLMDataset(100, 8, 2, seed=2)
        pf = Prefetcher(ds, depth=2, start_step=5)
        s0, b0 = pf.next()
        s1, b1 = pf.next()
        pf.close()
        assert (s0, s1) == (5, 6)
        np.testing.assert_array_equal(b0["tokens"], ds.batch(5)["tokens"])


class TestCheckpointer:
    def test_roundtrip_and_retention(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=2, async_save=False)
        state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
                 "opt": {"step": jnp.asarray(4, jnp.int32)}}
        for s in (1, 2, 3):
            ckpt.save(s, state, metadata={"note": "t"})
        assert ckpt.all_steps() == [2, 3]
        step, restored = ckpt.restore(state)
        assert step == 3
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
        )

    def test_async_save_then_restore(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), async_save=True)
        state = {"w": jnp.ones((4, 4))}
        ckpt.save(10, state)
        ckpt.wait()
        step, restored = ckpt.restore(state)
        assert step == 10

    def test_elastic_reshard_on_restore(self, tmp_path):
        """Save unsharded, restore onto a different mesh sharding."""
        if jax.device_count() < 2:
            pytest.skip("needs >1 device")  # pragma: no cover
        ckpt = Checkpointer(str(tmp_path), async_save=False)
        state = {"w": jnp.arange(16.0).reshape(4, 4)}
        ckpt.save(1, state)
        mesh = Mesh(np.asarray(jax.devices()[:2]).reshape(2), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        _, restored = ckpt.restore(state, shardings=sh)
        assert restored["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))


class TestFaultTolerance:
    def test_heartbeat_liveness(self, tmp_path):
        hb = Heartbeat(str(tmp_path / "hb.json"), interval_s=0.05)
        hb.start()
        import time

        time.sleep(0.15)
        hb.stop()
        assert Heartbeat.is_alive(str(tmp_path / "hb.json"), timeout_s=5.0)
        assert not Heartbeat.is_alive(str(tmp_path / "missing.json"), 1.0)

    def test_straggler_monitor_flags(self):
        mon = StragglerMonitor(threshold=2.0, min_steps=4)
        for i in range(8):
            assert not mon.record(i, 0.1)
        assert mon.record(8, 0.5)  # 5x median
        assert mon.flags == [8]

    def test_run_resilient_restores_and_replays(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), async_save=False)
        executed = []
        state = {"x": jnp.zeros(())}
        ckpt.save(0, state)
        fail_at = {3}

        def step_fn(step):
            if step in fail_at:
                fail_at.discard(step)  # fail once
                raise RuntimeError("simulated node failure")
            executed.append(step)
            ckpt.save(step + 1, {"x": jnp.asarray(float(step + 1))})

        def restore_fn():
            return ckpt.latest_step()

        failures = run_resilient(step_fn, 0, 6, restore_fn, max_failures=2)
        assert failures == 1
        assert executed == [0, 1, 2, 3, 4, 5]

    def test_run_resilient_gives_up(self, tmp_path):
        def step_fn(step):
            raise RuntimeError("permanent failure")

        with pytest.raises(RuntimeError):
            run_resilient(step_fn, 0, 3, lambda: 0, max_failures=2,
                          backoff_s=0.0)


class TestGradientCompression:
    def _mesh(self, n):
        if jax.device_count() < n:
            pytest.skip("needs forced host devices")  # pragma: no cover
        return Mesh(np.asarray(jax.devices()[:n]).reshape(n), ("data",))

    def test_compressed_psum_close_to_exact(self):
        mesh = self._mesh(2)
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(2, 64)), jnp.float32)

        f = compat.shard_map(
            lambda x: compressed_psum_mean({"g": x}, "data")["g"],
            mesh=mesh, in_specs=P("data"), out_specs=P("data"),
            check_vma=False,
        )
        out = np.asarray(f(g))
        want = np.broadcast_to(np.asarray(g).mean(0, keepdims=True) * 0 + np.asarray(g), g.shape)
        # each shard receives the mean of both shards
        mean = np.asarray(g).mean(axis=0)
        rel = np.abs(out - mean[None]).max() / (np.abs(mean).max() + 1e-9)
        assert rel < 0.05, rel

    def test_dp_grad_fn_matches_uncompressed(self):
        mesh = self._mesh(2)
        rng = np.random.default_rng(1)
        params = {"w": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}
        batch = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)

        def loss_fn(p, x):
            return jnp.mean(jnp.square(x @ p["w"]))

        f = make_compressed_dp_grad_fn(loss_fn, mesh)
        loss_c, grads_c = f(params, batch)
        loss_e, grads_e = jax.value_and_grad(loss_fn)(params, batch)
        assert abs(float(loss_c) - float(loss_e)) < 1e-5
        rel = float(
            jnp.max(jnp.abs(grads_c["w"] - grads_e["w"]))
            / (jnp.max(jnp.abs(grads_e["w"])) + 1e-9)
        )
        assert rel < 0.05, rel


class TestTrainStepIntegration:
    def test_loss_decreases_on_tiny_model(self):
        cfg = get_config("deepseek-7b", smoke=True)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        opt_cfg = AdamWConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=50,
                              weight_decay=0.0)
        from repro.distributed import sharding as sh

        step_fn = jax.jit(
            make_train_step(cfg, sh.ShardingRules(), opt_cfg,
                            fwd_kwargs=dict(block_q=16, block_k=16))
        )
        ds = SyntheticLMDataset(cfg.vocab_size, 16, 4, seed=0)
        state = {"params": params,
                 "opt": __import__("repro.optim", fromlist=["adamw_init"]).adamw_init(params, opt_cfg)}
        losses = []
        for i in range(8):
            b = {k: jnp.asarray(v) for k, v in ds.batch(i % 2).items()}
            state, metrics = step_fn(state, b)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0], losses

    def test_grad_accum_matches_full_batch(self):
        cfg = get_config("deepseek-7b", smoke=True)
        params, _ = model_zoo.init(jax.random.key(1), cfg)
        opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=0, weight_decay=0.0)
        from repro.distributed import sharding as sh
        from repro.optim import adamw_init

        ds = SyntheticLMDataset(cfg.vocab_size, 16, 8, seed=3)
        batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}
        s1 = {"params": params, "opt": adamw_init(params, opt_cfg)}
        s2 = jax.tree.map(lambda x: x, s1)
        f1 = jax.jit(make_train_step(cfg, sh.ShardingRules(), opt_cfg,
                                     fwd_kwargs=dict(block_q=16, block_k=16)))
        f4 = jax.jit(make_train_step(cfg, sh.ShardingRules(), opt_cfg,
                                     fwd_kwargs=dict(block_q=16, block_k=16),
                                     grad_accum=4))
        s1, m1 = f1(s1, batch)
        s2, m2 = f4(s2, batch)
        w1 = s1["params"]["layers"]["attn"]["wq"]
        w2 = s2["params"]["layers"]["attn"]["wq"]
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=2e-4)
