"""Multi-device behaviours (gradient compression, elastic reshard, dry-run
cell) — run in subprocesses with forced host devices, since the main test
session keeps the default single device per the repo contract."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestCompressedGradSync:
    def test_int8_allreduce_matches_exact(self):
        out = run_py(
            """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.compression import make_compressed_dp_grad_fn
mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("data",))
rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
batch = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
loss_fn = lambda p, x: jnp.mean(jnp.square(x @ p["w"]))
lc, gc = make_compressed_dp_grad_fn(loss_fn, mesh)(params, batch)
le, ge = jax.value_and_grad(loss_fn)(params, batch)
rel = float(jnp.max(jnp.abs(gc["w"] - ge["w"])) / (jnp.max(jnp.abs(ge["w"])) + 1e-9))
assert abs(float(lc) - float(le)) < 1e-5, (lc, le)
assert rel < 0.05, rel
print("OK", rel)
"""
        )
        assert "OK" in out


class TestElasticReshard:
    def test_checkpoint_restores_onto_new_mesh(self, tmp_path):
        out = run_py(
            f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import Checkpointer
ck = Checkpointer({str(tmp_path)!r}, async_save=False)
# "save" under a 4-way sharding
mesh4 = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("data",))
w = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                   NamedSharding(mesh4, P("data", None)))
ck.save(1, {{"w": w}})
# "restart" with only 2 devices (elastic downscale)
mesh2 = Mesh(np.asarray(jax.devices()[:2]).reshape(2), ("data",))
sh = {{"w": NamedSharding(mesh2, P("data", None))}}
_, restored = ck.restore({{"w": w}}, shardings=sh)
assert restored["w"].sharding == sh["w"]
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
print("OK")
"""
        )
        assert "OK" in out


class TestDryRunCell:
    """One real dry-run cell end-to-end (the cheapest arch×shape) — proves
    the 512-device lower+compile machinery from inside the test suite."""

    @pytest.mark.slow
    def test_gemma1b_decode_cell_compiles(self, tmp_path):
        out = run_py(
            f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
r = run_cell("gemma3-1b", "decode_32k", out_dir={str(tmp_path)!r}, verbose=False)
assert r["status"] == "ok", r
assert r["device_flops"] > 0 and r["collective_bytes"] > 0
assert r["memory_analysis"]["fits_16gb"], r["memory_analysis"]
print("OK", r["bottleneck"], round(r["roofline_fraction"], 4))
""",
            devices=512,
            timeout=900,
        )
        assert "OK" in out
