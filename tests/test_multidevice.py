"""Multi-device behaviours (gradient compression, elastic reshard, dry-run
cell) — run in subprocesses with forced host devices, since the main test
session keeps the default single device per the repo contract."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestCompressedGradSync:
    def test_int8_allreduce_matches_exact(self):
        out = run_py(
            """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed.compression import make_compressed_dp_grad_fn
mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("data",))
rng = np.random.default_rng(0)
params = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
batch = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
loss_fn = lambda p, x: jnp.mean(jnp.square(x @ p["w"]))
lc, gc = make_compressed_dp_grad_fn(loss_fn, mesh)(params, batch)
le, ge = jax.value_and_grad(loss_fn)(params, batch)
rel = float(jnp.max(jnp.abs(gc["w"] - ge["w"])) / (jnp.max(jnp.abs(ge["w"])) + 1e-9))
assert abs(float(lc) - float(le)) < 1e-5, (lc, le)
assert rel < 0.05, rel
print("OK", rel)
"""
        )
        assert "OK" in out


class TestElasticReshard:
    def test_checkpoint_restores_onto_new_mesh(self, tmp_path):
        out = run_py(
            f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import Checkpointer
ck = Checkpointer({str(tmp_path)!r}, async_save=False)
# "save" under a 4-way sharding
mesh4 = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("data",))
w = jax.device_put(jnp.arange(32.0).reshape(8, 4),
                   NamedSharding(mesh4, P("data", None)))
ck.save(1, {{"w": w}})
# "restart" with only 2 devices (elastic downscale)
mesh2 = Mesh(np.asarray(jax.devices()[:2]).reshape(2), ("data",))
sh = {{"w": NamedSharding(mesh2, P("data", None))}}
_, restored = ck.restore({{"w": w}}, shardings=sh)
assert restored["w"].sharding == sh["w"]
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
print("OK")
"""
        )
        assert "OK" in out


class TestShardedPagedPools:
    """Sharded serving cache behaviours (heads-parallel KV pools on a
    ("data", "model") mesh) — subprocesses with 8 forced host devices."""

    def test_page_table_translation_head_sharded(self):
        """phys_table + paged_entry on a HEAD-SHARDED pool must read exactly
        the rows a host-side numpy translation of the page table picks."""
        out = run_py(
            """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.serving import kv_cache as kvc, sharded as shd

cfg = get_config("deepseek-7b", smoke=True)
lay = kvc.layout_for(cfg, 4, 48, kv_format="bf16", layout="paged", page_size=8)
rules = shd.rules_for(2, 4)
cache = shd.shard_cache(kvc.init_cache_arrays(cfg, lay), cfg, lay, rules)
rng = np.random.default_rng(0)
pool = {n: jnp.asarray(rng.normal(size=a.shape), a.dtype)
        for n, a in cache["global"].items()}
pool = shd.shard_cache({"global": pool, "page_table": cache["page_table"],
                        "pos": cache["pos"]}, cfg, lay, rules)["global"]
# a scrambled but valid table: every slot maps a random disjoint page set
perm = rng.permutation(lay.num_pages)[: 4 * lay.pages_per_slot]
table = np.asarray(perm, np.int32).reshape(4, lay.pages_per_slot)
pt = shd.replicated(table, rules)
phys = kvc.phys_table(pt, lay.page_size, lay.max_seq)
entry = jax.jit(lambda p, ph: kvc.paged_entry(p, 1, ph))(pool, phys)
# host reference: logical position t of slot b lives in pool row
# table[b, t // page] * page + t % page
rows = (table[:, np.arange(lay.max_seq) // lay.page_size] * lay.page_size
        + np.arange(lay.max_seq) % lay.page_size)
np.testing.assert_array_equal(np.asarray(phys), rows)
for n in ("k", "v"):
    want = np.asarray(pool[n])[1][rows]          # (B, S, Hk, D)
    got = np.moveaxis(np.asarray(entry[n]), 1, 2)  # back to (B, S, Hk, D)
    np.testing.assert_array_equal(got, want, err_msg=n)
print("OK")
""",
            devices=8,
        )
        assert "OK" in out

    def test_zero_pages_and_reset_slot_touch_every_shard(self):
        """zero_pages on a sharded pool and reset_slot on a sharded slot
        stack must zero the target rows on EVERY leaf of every shard and
        leave everything else bit-intact."""
        out = run_py(
            """
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.serving import kv_cache as kvc, sharded as shd

cfg = get_config("deepseek-7b", smoke=True)
rules = shd.rules_for(2, 4)
rng = np.random.default_rng(0)

# paged pool: zero pages {1, 5} through the constrained jitted path
lay = kvc.layout_for(cfg, 4, 48, kv_format="int8", layout="paged", page_size=8)
cache = kvc.init_cache_arrays(cfg, lay)
cache["global"] = {n: jnp.asarray(rng.normal(size=a.shape) + 1.0, jnp.float32)
                   .astype(a.dtype) if a.dtype != jnp.int8
                   else jnp.asarray(rng.integers(1, 100, a.shape), jnp.int8)
                   for n, a in cache["global"].items()}
cache = shd.shard_cache(cache, cfg, lay, rules)
specs = kvc.cache_specs(cfg, lay)["global"]
ids = jnp.asarray(np.asarray([1, 5] + [-1] * 6, np.int32))
zeroed = jax.jit(lambda s, i: kvc.constrain_cache(
    kvc.zero_pages(s, i, lay.page_size), specs, rules))(cache["global"], ids)
tok = np.concatenate([np.arange(8, 16), np.arange(40, 48)])
for n, a in zeroed.items():
    host, before = np.asarray(a), np.asarray(cache["global"][n])
    td = 1  # token dim of every pool leaf after the layer dim
    if n == "k_planes":
        td = 2
    sel = [slice(None)] * host.ndim
    sel[td] = tok
    assert not np.any(host[tuple(sel)]), n
    keep = np.ones(host.shape[td], bool); keep[tok] = False
    sel[td] = keep
    np.testing.assert_array_equal(host[tuple(sel)], before[tuple(sel)],
                                  err_msg=n)
    assert len(a.sharding.device_set) == 8, (n, a.sharding)

# slot stack: reset_slot(2) zeroes exactly row 2 of every stack leaf
lay_s = kvc.layout_for(cfg, 4, 48, kv_format="bf16", layout="slot")
cache_s = kvc.init_cache_arrays(cfg, lay_s)
cache_s["global"] = {n: jnp.asarray(rng.normal(size=a.shape) + 1.0, a.dtype)
                     for n, a in cache_s["global"].items()}
cache_s["pos"] = jnp.asarray([3, 4, 5, 6], jnp.int32)
cache_s = shd.shard_cache(cache_s, cfg, lay_s, rules)
reset = jax.jit(lambda c: kvc.constrain_cache(
    kvc.reset_slot(c, lay_s, 2), kvc.cache_specs(cfg, lay_s), rules))(cache_s)
for n, a in reset["global"].items():
    host, before = np.asarray(a), np.asarray(cache_s["global"][n])
    assert not np.any(host[:, 2]), n
    mask = np.ones(host.shape[1], bool); mask[2] = False
    np.testing.assert_array_equal(host[:, mask], before[:, mask], err_msg=n)
assert np.asarray(reset["pos"]).tolist() == [3, 4, 0, 6]
print("OK")
""",
            devices=8,
        )
        assert "OK" in out

    def test_prefix_adoption_refcounts_mesh_invariant(self):
        """The host allocator never sees the mesh: an identical shared-prefix
        trace must leave IDENTICAL page tables, refcounts, and allocation
        counters at mesh 1x1 and 2x4."""
        out = run_py(
            """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import MCBPOptions
from repro.models import model_zoo
from repro.serving import kv_cache as kvc, sharded as shd
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

cfg = get_config("deepseek-7b", smoke=True)
cfg = dataclasses.replace(cfg, mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=1.0))
params, _ = model_zoo.init(jax.random.key(0), cfg)
rng = np.random.default_rng(3)
prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
def reqs():
    return [Request(rid=i,
                    prompt=np.concatenate([prefix, rng.integers(
                        0, cfg.vocab_size, (3 + i,)).astype(np.int32)]),
                    max_new_tokens=3 + i, arrival_step=[0, 6, 6, 9][i])
            for i in range(4)]
rng_state = rng.bit_generator.state

def run(rules):
    global rng
    rng.bit_generator.state = rng_state
    lay = kvc.layout_for(cfg, 4, 48, kv_format="bf16", layout="paged",
                         page_size=8)
    kw = {} if rules is None else {"rules": rules}
    s = Scheduler(params, cfg, lay, chunk_budget=6, **kw)
    for r in reqs():
        s.submit(r)
    s.run(max_steps=500)
    assert len(s.finished) == 4
    s.pager.check()
    return s

a, b = run(None), run(shd.rules_for(2, 4))
assert a.prefix_hit_tokens == b.prefix_hit_tokens > 0
np.testing.assert_array_equal(a.pager.table, b.pager.table)
np.testing.assert_array_equal(a.pager.refcount, b.pager.refcount)
assert a.pager.alloc_count == b.pager.alloc_count
assert a.pager.peak_pages == b.pager.peak_pages
assert a.pager.pages_in_use == b.pager.pages_in_use == 0
print("OK", a.prefix_hit_tokens)
""",
            devices=8,
        )
        assert "OK" in out

    def test_bgpp_phase1_no_cross_model_collectives(self):
        """Structural: the shard_map-routed two-phase BGPP paged attend
        (phase-1 plane gathers + top-k + the phase-2 survivor gather)
        compiles to ZERO collectives on a 2x4 mesh — every step is local to
        its head shard; the only cross-shard hop of the whole decode layer
        is the attend-reduction all-gather outside it."""
        out = run_py(
            """
import dataclasses, re
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.configs.base import MCBPOptions
from repro.serving import engine, kv_cache as kvc, sharded as shd

cfg = get_config("deepseek-7b", smoke=True)
cfg = dataclasses.replace(cfg, mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=0.5))
lay = kvc.layout_for(cfg, 4, 48, kv_format="bgpp", layout="paged", page_size=8)
rules = shd.rules_for(2, 4)
cache = shd.shard_cache(kvc.init_cache_arrays(cfg, lay), cfg, lay, rules)
rng = np.random.default_rng(0)
q = jax.device_put(
    jnp.asarray(rng.normal(size=(4, cfg.num_heads, cfg.head_dim)), jnp.float32),
    NamedSharding(rules.mesh, P("data", "model", None)))
pt = jax.device_put(kvc.identity_page_table(lay), NamedSharding(rules.mesh, P()))
valid = jax.device_put(jnp.ones((4, lay.max_seq), bool),
                       NamedSharding(rules.mesh, P("data", None)))

def attend(q, store, pt, valid):
    phys = kvc.phys_table(pt, lay.page_size, lay.max_seq)
    return engine._bgpp_paged_decode_attend_sharded(
        q, store, 0, phys, valid, cfg, lay, rules)

txt = jax.jit(attend).lower(q, cache["global"], pt, valid).compile().as_text()
hits = sorted(set(re.findall(
    r"all-reduce|all-gather|all-to-all|collective-permute", txt)))
assert not hits, hits
out = jax.jit(attend)(q, cache["global"], pt, valid)
assert out.shape == (4, cfg.num_heads, cfg.head_dim)
print("OK")
""",
            devices=8,
        )
        assert "OK" in out


class TestDryRunCell:
    """One real dry-run cell end-to-end (the cheapest arch×shape) — proves
    the 512-device lower+compile machinery from inside the test suite."""

    @pytest.mark.slow
    def test_gemma1b_decode_cell_compiles(self, tmp_path):
        out = run_py(
            f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
r = run_cell("gemma3-1b", "decode_32k", out_dir={str(tmp_path)!r}, verbose=False)
assert r["status"] == "ok", r
assert r["device_flops"] > 0 and r["collective_bytes"] > 0
assert r["memory_analysis"]["fits_16gb"], r["memory_analysis"]
print("OK", r["bottleneck"], round(r["roofline_fraction"], 4))
""",
            devices=512,
            timeout=900,
        )
        assert "OK" in out
