"""All five kernel families through the dispatch layer, × {interpret, ref},
against their ref.py oracles — the acceptance gate for the substrate."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bstc
from repro.kernels import dispatch
from repro.kernels.bgpp_score import bgpp_score_round
from repro.kernels.bgpp_score.ref import bgpp_score_round_ref
from repro.kernels.brcr_gemm import brcr_gemm, prepare_brcr_operands
from repro.kernels.brcr_gemm.ref import dense_ref
from repro.kernels.bstc_decode import (
    bstc_decode_patterns,
    prepare_encoded_plane,
)
from repro.kernels.bstc_matmul import (
    bstc_matmul,
    prepare_bstc_matmul_operands,
)
from repro.kernels.bstc_matmul.ref import bstc_matmul_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref

jax.config.update("jax_platform_name", "cpu")

MODES = ("interpret", "ref")


def pack8(bits: np.ndarray) -> np.ndarray:
    from repro.core.bitslice import pack_bits

    return np.asarray(pack_bits(jnp.asarray(bits)))


class TestModeResolution:
    def test_explicit_mode_wins(self):
        assert dispatch.resolve_mode("ref", interpret=True) == "ref"

    def test_legacy_interpret_flag_maps_to_interpret(self):
        assert dispatch.resolve_mode(None, interpret=True) == "interpret"

    def test_default_mode_override(self):
        with dispatch.dispatch_mode("ref"):
            assert dispatch.resolve_mode() == "ref"

    def test_env_var_override(self, monkeypatch):
        prev = dispatch.get_default_mode()
        dispatch.set_default_mode(None)
        try:
            monkeypatch.setenv(dispatch.ENV_VAR, "ref")
            assert dispatch.resolve_mode() == "ref"
            monkeypatch.setenv(dispatch.ENV_VAR, "nonsense")
            with pytest.raises(ValueError, match="nonsense"):
                dispatch.resolve_mode()
        finally:
            dispatch.set_default_mode(prev)

    def test_backend_detection_on_cpu(self, monkeypatch):
        prev = dispatch.get_default_mode()
        dispatch.set_default_mode(None)
        try:
            monkeypatch.delenv(dispatch.ENV_VAR, raising=False)
            assert dispatch.resolve_mode() == "interpret"
        finally:
            dispatch.set_default_mode(prev)

    def test_compiled_on_cpu_raises(self):
        x = jnp.ones((1, 8, 2, 8), jnp.float32)
        with pytest.raises(RuntimeError, match="compiled dispatch"):
            flash_attention(x, x, x, mode="compiled")


class TestBRCRDispatch:
    @pytest.mark.parametrize("mode", MODES)
    def test_matches_dense_oracle(self, mode, rng):
        M, H, N = 16, 128, 8
        w = np.round(np.clip(rng.normal(size=(M, H)) * 40, -127, 127)).astype(
            np.int8
        )
        x = jnp.asarray(rng.integers(-50, 50, size=(H, N)), jnp.float32)
        ops = prepare_brcr_operands(w, m=4)
        y = brcr_gemm(ops, x, tile_m=M, tile_k=H, tile_n=N, mode=mode)
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(dense_ref(jnp.asarray(w), x))
        )


class TestBSTCDecodeDispatch:
    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("density", [0.02, 0.3])
    def test_matches_plane_oracle(self, mode, density, rng):
        plane = (rng.random((16, 512)) < density).astype(np.uint8)
        enc = bstc.encode_plane(plane, m=4)
        ops = prepare_encoded_plane(enc)
        patt = bstc_decode_patterns(ops, tile_g=4, mode=mode)
        rows = np.asarray(bstc.expand_patterns(patt, m=4))
        np.testing.assert_array_equal(rows, plane)


class TestBSTCMatmulDispatch:
    @pytest.mark.parametrize("mode", MODES)
    def test_matches_dense_oracle(self, mode, rng):
        M, H, N = 16, 512, 8
        w = np.round(np.clip(rng.normal(size=(M, H)) * 30, -127, 127)).astype(
            np.int8
        )
        scale = rng.uniform(0.5, 2.0, size=(M,)).astype(np.float32)
        x = jnp.asarray(rng.normal(size=(H, N)), jnp.float32)
        ops = prepare_bstc_matmul_operands(w, scale=scale, m=4)
        y = bstc_matmul(
            ops, x, tile_m=M, tile_n=N, apply_scale=True, mode=mode
        )
        want = bstc_matmul_ref(jnp.asarray(w), x, jnp.asarray(scale))
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(want), rtol=1e-5, atol=1e-4
        )


class TestBGPPScoreDispatch:
    @pytest.mark.parametrize("mode", MODES)
    def test_matches_score_oracle(self, mode, rng):
        S, D = 128, 64
        q = jnp.asarray(rng.integers(-8, 8, size=(D,)), jnp.int32)
        plane = (rng.random((S, D)) < 0.3).astype(np.uint8)
        sign = (rng.random((S, D)) < 0.5).astype(np.uint8)
        alive = jnp.asarray(rng.random(S) < 0.8)
        got = bgpp_score_round(
            q,
            jnp.asarray(pack8(plane)),
            jnp.asarray(pack8(sign)),
            alive,
            tile_s=64,
            mode=mode,
        )
        want = bgpp_score_round_ref(
            q, jnp.asarray(plane), jnp.asarray(sign), alive
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestFlashAttentionDispatch:
    @pytest.mark.parametrize("mode", MODES)
    @pytest.mark.parametrize("mask_kind", ["causal", "sliding", "full"])
    def test_matches_attend_oracle(self, mode, mask_kind, rng):
        B, S, Hq, Hk, D = 1, 64, 4, 2, 16
        window = 16 if mask_kind == "sliding" else 0
        q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hk, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hk, D)), jnp.float32)
        got = flash_attention(
            q, k, v, mask_kind=mask_kind, window=window,
            tile_q=32, tile_k=32, mode=mode,
        )
        want = flash_attention_ref(q, k, v, mask_kind=mask_kind, window=window)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
        )


class TestAutouseDispatchFixture:
    def test_default_mode_is_interpret_on_cpu_ci(self):
        """The conftest autouse fixture pins interpret mode on TPU-less
        hosts (unless REPRO_KERNEL_DISPATCH overrides it)."""
        if os.environ.get(dispatch.ENV_VAR):
            pytest.skip("explicit env override active")
        assert dispatch.resolve_mode() == "interpret"

    def test_kernel_call_without_interpret_flag_runs(self, rng):
        """Call sites that never pass interpret= must work on CPU now."""
        x = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
        out = flash_attention(x, x, x, tile_q=16, tile_k=16)
        assert out.shape == x.shape
