"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (the brief's (f) item)."""

import jax
import jax.numpy as jnp
import zlib

import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY, get_config
from repro.models import model_zoo

jax.config.update("jax_platform_name", "cpu")

ARCHS = sorted(ARCH_REGISTRY)
B, S = 2, 32


def make_batch(cfg, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_vision)), jnp.float32
        )
    if cfg.family == "enc_dec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_audio)), jnp.float32
        )
    return batch


def fwd_kwargs(cfg):
    kw = dict(block_q=16, block_k=16)
    if cfg.family == "ssm":
        return dict(chunk=16)
    if cfg.family == "hybrid":
        kw["ssd_chunk"] = 16
    return kw


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = get_config(arch, smoke=True)
        rng = np.random.default_rng(zlib.crc32(arch.encode()) % 2**31)
        params, specs = model_zoo.init(jax.random.key(0), cfg)
        # every param leaf has a matching logical-axis spec
        pl = jax.tree_util.tree_leaves_with_path(params)
        assert jax.tree.structure(
            jax.tree.map(lambda _: 0, params)
        ) == jax.tree.structure(
            jax.tree.map(
                lambda _: 0, specs, is_leaf=lambda x: isinstance(x, tuple)
            )
        )
        batch = make_batch(cfg, rng)
        logits, aux = jax.jit(
            lambda p, b: model_zoo.forward(p, cfg, b, **fwd_kwargs(cfg))
        )(params, batch)
        S_out = S + (cfg.vision_tokens if cfg.family == "vlm" else 0)
        assert logits.shape == (B, S_out, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any()), "NaN logits"
        assert not bool(jnp.isnan(aux)), "NaN aux loss"

    def test_train_step_decreases_loss(self, arch):
        """One SGD step on the smoke config must produce finite grads and
        a finite (typically reduced) loss."""
        cfg = get_config(arch, smoke=True)
        rng = np.random.default_rng(zlib.crc32(arch.encode()) % 2**31 + 1)
        params, _ = model_zoo.init(jax.random.key(1), cfg)
        batch = make_batch(cfg, rng)
        labels = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
        )

        def loss_fn(p):
            logits, aux = model_zoo.forward(p, cfg, batch, **fwd_kwargs(cfg))
            logits = logits[:, -S:]  # drop VLM prefix positions
            ll = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
            return nll + 0.01 * aux

        loss0, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert np.isfinite(float(loss0))
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0
        params2 = jax.tree.map(
            lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads
        )
        loss1 = jax.jit(loss_fn)(params2)
        assert np.isfinite(float(loss1))
        assert float(loss1) < float(loss0) + 1.0  # no blow-up
