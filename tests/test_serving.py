"""Serving engine: prefill+decode must reproduce the teacher-forced forward
pass (the gold consistency test for KV caches, ring buffers, int8/bgpp)."""

import jax
import jax.numpy as jnp
import zlib

import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model_zoo
from repro.serving import engine, kv_cache as kvc

jax.config.update("jax_platform_name", "cpu")

B, S_PROMPT, S_DEC = 2, 24, 8
S_MAX = 64


def greedy(logits):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def run_decode_matches_forward(arch, kv_format, atol, mcbp=None, err_quantile=1.0):
    """Prefill + step-wise decode over a FIXED continuation must match the
    teacher-forced forward on the same tokens (no greedy compounding, so
    quantized paths are compared like-for-like per position).

    ``err_quantile < 1`` bounds that quantile of |Δlogits| instead of the
    max: MoE archs route through a discrete top-k, so bounded KV-quant
    noise can flip a near-tie expert choice on random-init routers and
    shift whole logit rows (with routing forced dense the same int8 path
    stays within 0.1).  The bulk of the distribution plus the greedy-
    agreement check is the sound oracle there; an absolute max is not."""
    import dataclasses

    cfg = get_config(arch, smoke=True)
    if mcbp is not None:
        cfg = dataclasses.replace(cfg, mcbp=mcbp)
    rng = np.random.default_rng(zlib.crc32(f"{arch}/{kv_format}".encode()) % 2**31)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_PROMPT)), jnp.int32)
    cont = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_DEC)), jnp.int32)

    layout = kvc.layout_for(cfg, B, S_MAX, kv_format=kv_format)
    last_logits, cache = engine.prefill(
        params, cfg, layout, tokens, block_q=8, block_k=8
    )
    serve_step = jax.jit(engine.make_serve_step(cfg, layout))

    logits_dec = [last_logits]
    for t in range(S_DEC):
        lg, cache = serve_step(params, cache, cont[:, t : t + 1])
        logits_dec.append(lg)

    full = jnp.concatenate([tokens, cont], axis=1)
    logits_full, _ = model_zoo.forward(
        params, cfg, {"tokens": full}, block_q=8, block_k=8
    )
    got = jnp.concatenate(logits_dec, axis=1)
    want = logits_full[:, S_PROMPT - 1 :]
    if err_quantile < 1.0:
        err = float(np.quantile(np.abs(np.asarray(got - want)), err_quantile))
    else:
        err = float(jnp.max(jnp.abs(got - want)))
    assert err < atol, f"{arch}/{kv_format}: decode diverges from forward by {err}"
    # per-position argmax agreement (quantized paths may flip near-ties on
    # random-init logits)
    agree = np.mean(
        np.asarray(jnp.argmax(got, -1)) == np.asarray(jnp.argmax(want, -1))
    )
    if kv_format == "bf16":
        assert agree == 1.0, agree
    else:
        assert agree >= 0.8, f"{arch}/{kv_format}: greedy agreement {agree}"


class TestDecodeConsistency:
    @pytest.mark.parametrize("arch", ["deepseek-7b", "phi4-mini-3.8b"])
    def test_dense_bf16_exactish(self, arch):
        run_decode_matches_forward(arch, "bf16", atol=2e-3)

    def test_gemma3_ring_buffer_local_global(self):
        run_decode_matches_forward("gemma3-4b", "bf16", atol=2e-3)

    def test_mixtral_swa_int8(self):
        # p95 bound: discrete MoE routing flips under int8 KV noise shift
        # a few whole logit rows (see run_decode_matches_forward docstring)
        run_decode_matches_forward(
            "mixtral-8x22b", "int8", atol=0.35, err_quantile=0.95
        )

    def test_llama4_chunked(self):
        run_decode_matches_forward("llama4-scout-17b-a16e", "bf16", atol=2e-2)

    def test_int8_kv_quantization_small_drift(self):
        run_decode_matches_forward("deepseek-7b", "int8", atol=0.35)

    def test_bgpp_cache_format_exact_at_full_keep(self):
        """BGPP gather machinery (bit-planar reconstruct, progressive
        top-k gathers, int8 formal compute) must be numerically equivalent
        to the plain int8 path when keep_ratio=1.0 keeps every key.  The
        lossy keep_ratio<1 trade-off is characterized separately on
        concentrated attention (examples/bgpp_sparse_attention.py and the
        fig24a benchmark) — random-init smoke nets have near-uniform
        attention where forced top-k scrambles argmax by construction."""
        from repro.configs.base import MCBPOptions

        run_decode_matches_forward(
            "phi4-mini-3.8b", "bgpp", atol=0.4,
            mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=1.0),
        )


def run_staggered(cfg, layout, params, reqs, total_steps):
    """Drive a shared 2-slot cache with teacher-forced continuations.

    reqs: list of (slot, admit_step, prompt (S,), cont (T,)).  Returns
    {slot: [np logits]} — the prefill last-logits plus one entry per decode
    step while the request is live.  Requests admitted at different steps
    share every serve_step, which is exactly what the per-slot position
    vector must make invisible.
    """
    cache = kvc.init_cache_arrays(cfg, layout)
    serve_step = jax.jit(engine.make_serve_step(cfg, layout))
    toks = np.zeros((layout.batch, 1), np.int32)
    out = {slot: [] for slot, _, _, _ in reqs}
    fed = {slot: 0 for slot, _, _, _ in reqs}
    for t in range(total_steps):
        for slot, t0, prompt, cont in reqs:
            if t0 == t:
                lg, cache = engine.prefill_into_slot(
                    params, cfg, layout, cache, slot, prompt,
                    block_q=8, block_k=8,
                )
                out[slot].append(np.asarray(lg[0, -1], np.float32))
                toks[slot, 0] = cont[0]
                fed[slot] = 1
        lg, cache = serve_step(params, cache, jnp.asarray(toks))
        for slot, t0, prompt, cont in reqs:
            if t0 <= t and fed[slot] < len(cont):
                out[slot].append(np.asarray(lg[slot, 0], np.float32))
                toks[slot, 0] = cont[fed[slot]]
                fed[slot] += 1
    return out


def run_staggered_oracle(arch, kv_format, exact, mcbp=None, atol=1e-5):
    """THE gold test for position vectorization: two requests admitted at
    different steps into one batch must produce logits identical to each
    decoded alone (same batch shape, other slot EMPTY) — bit-for-bit in
    bf16, within ``atol`` for the quantized formats."""
    import dataclasses

    cfg = get_config(arch, smoke=True)
    if mcbp is not None:
        cfg = dataclasses.replace(cfg, mcbp=mcbp)
    rng = np.random.default_rng(zlib.crc32(f"stag/{arch}/{kv_format}".encode()))
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    layout = kvc.layout_for(cfg, 2, S_MAX, kv_format=kv_format)
    # prompt A shorter than the local window, B longer (both prefill paths)
    pA = jnp.asarray(rng.integers(0, cfg.vocab_size, (11,)), jnp.int32)
    pB = jnp.asarray(rng.integers(0, cfg.vocab_size, (19,)), jnp.int32)
    cA = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)
    cB = rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32)

    joint = run_staggered(cfg, layout, params,
                          [(0, 0, pA, cA), (1, 3, pB, cB)], 10)
    alone_a = run_staggered(cfg, layout, params, [(0, 0, pA, cA)], 10)
    alone_b = run_staggered(cfg, layout, params, [(1, 0, pB, cB)], 10)

    assert len(joint[0]) == len(alone_a[0]) == 6
    assert len(joint[1]) == len(alone_b[1]) == 6
    for got, want in [(joint[0], alone_a[0]), (joint[1], alone_b[1])]:
        for t, (g, w) in enumerate(zip(got, want)):
            if exact:
                assert np.array_equal(g, w), (
                    f"{arch}/{kv_format} step {t}: staggered decode is not "
                    f"bit-identical to the alone run "
                    f"(max |d| {np.max(np.abs(g - w))})"
                )
            else:
                err = np.max(np.abs(g - w))
                assert err < atol, f"{arch}/{kv_format} step {t}: |d|={err}"


class TestPerSlotOracle:
    """Slot isolation under continuous batching (ISSUE 2 acceptance)."""

    def test_dense_bf16_bit_for_bit(self):
        run_staggered_oracle("deepseek-7b", "bf16", exact=True)

    def test_gemma3_swa_bf16_bit_for_bit(self):
        # local ring buffers + a global layer: per-slot ring slots and
        # abs_pos windows must not alias across staggered requests
        run_staggered_oracle("gemma3-4b", "bf16", exact=True)

    def test_mixtral_moe_swa_bf16_bit_for_bit(self):
        # MoE routing runs dropless at decode so expert capacity cannot
        # couple co-scheduled slots
        run_staggered_oracle("mixtral-8x22b", "bf16", exact=True)

    def test_mixtral_moe_int8(self):
        run_staggered_oracle("mixtral-8x22b", "int8", exact=False)

    def test_bgpp_per_slot(self):
        from repro.configs.base import MCBPOptions

        run_staggered_oracle(
            "phi4-mini-3.8b", "bgpp", exact=False,
            mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=1.0),
        )


class TestCacheLayoutEdges:
    def test_layout_for_chunked_windows(self):
        cfg = get_config("llama4-scout-17b-a16e", smoke=True)
        layout = kvc.layout_for(cfg, 2, 64, kv_format="int8")
        # 3 chunked-local : 1 global, ring window = the chunk size
        assert layout.local_window == cfg.chunk_attention
        for i in layout.local_layers:
            kind, w = cfg.layer_attn_window(i)
            assert kind == "chunked" and w == cfg.chunk_attention
        for i in layout.global_layers:
            assert cfg.layer_attn_window(i)[0] == "causal"
        assert set(layout.local_layers) | set(layout.global_layers) == set(
            range(cfg.num_layers)
        )

    def test_layout_clamps_window_to_max_seq(self):
        cfg = get_config("gemma3-4b", smoke=True)  # sliding_window=16
        layout = kvc.layout_for(cfg, 1, 8, kv_format="bf16")
        assert layout.local_window == 8

    @pytest.mark.parametrize("s_prompt", [9, 24])  # < and > local_window=16
    def test_prefill_ring_contents(self, s_prompt):
        cfg = get_config("gemma3-4b", smoke=True)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        rng = np.random.default_rng(s_prompt)
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, s_prompt)), jnp.int32
        )
        layout = kvc.layout_for(cfg, B, S_MAX, kv_format="bf16")
        W = layout.local_window
        _, cache = engine.prefill(params, cfg, layout, tokens,
                                  block_q=8, block_k=8)
        abs_pos = np.asarray(cache["local"]["abs_pos"])
        take = min(W, s_prompt)
        want = np.full((W,), -1, np.int32)
        pos_abs = np.arange(s_prompt - take, s_prompt)
        want[pos_abs % W] = pos_abs
        for li in range(abs_pos.shape[0]):
            for b in range(B):
                assert np.array_equal(abs_pos[li, b], want)
        assert np.all(np.asarray(cache["pos"]) == s_prompt)

    def test_prefill_into_slot_matches_batch_prefill(self):
        """Admitting each prompt slot-by-slot into a live cache must build
        the same per-row state as the whole-batch prefill (same valid
        logits at the next decode step)."""
        cfg = get_config("gemma3-4b", smoke=True)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        rng = np.random.default_rng(7)
        S = 20
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        layout = kvc.layout_for(cfg, B, S_MAX, kv_format="bf16")
        serve_step = jax.jit(engine.make_serve_step(cfg, layout))
        cont = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)

        _, cache_batch = engine.prefill(params, cfg, layout, tokens,
                                        block_q=8, block_k=8)
        cache_slot = kvc.init_cache_arrays(cfg, layout)
        for b in range(B):
            _, cache_slot = engine.prefill_into_slot(
                params, cfg, layout, cache_slot, b, tokens[b],
                block_q=8, block_k=8,
            )
        lg_batch, _ = serve_step(params, cache_batch, cont)
        lg_slot, _ = serve_step(params, cache_slot, cont)
        np.testing.assert_allclose(
            np.asarray(lg_batch, np.float32), np.asarray(lg_slot, np.float32),
            atol=2e-3, rtol=0,
        )


class TestSSMHybridDecode:
    @pytest.mark.parametrize("arch", ["mamba2-1.3b"])
    def test_mamba2_decode_runs(self, arch):
        cfg = get_config(arch, smoke=True)
        rng = np.random.default_rng(0)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        layout = kvc.layout_for(cfg, B, S_MAX)
        cache, _ = kvc.init_cache(cfg, layout)
        serve_step = jax.jit(engine.make_serve_step(cfg, layout))
        cur = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        for _ in range(4):
            lg, cache = serve_step(params, cache, cur)
            assert lg.shape == (B, 1, cfg.vocab_size)
            assert not bool(jnp.isnan(lg).any())
            cur = greedy(lg)[:, None]
        assert np.all(np.asarray(cache["pos"]) == 4)  # per-slot positions

    def test_jamba_decode_runs(self):
        cfg = get_config("jamba-1.5-large-398b", smoke=True)
        rng = np.random.default_rng(1)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        layout = kvc.layout_for(cfg, B, S_MAX, kv_format="int8")
        assert layout.mamba_layers and layout.global_layers
        cache, _ = kvc.init_cache(cfg, layout)
        serve_step = jax.jit(engine.make_serve_step(cfg, layout))
        cur = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        for _ in range(3):
            lg, cache = serve_step(params, cache, cur)
            assert not bool(jnp.isnan(lg).any())
            cur = greedy(lg)[:, None]

    def test_whisper_decode_runs(self):
        cfg = get_config("whisper-medium", smoke=True)
        rng = np.random.default_rng(2)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        from repro.models import whisper

        frames = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_audio)), jnp.float32
        )
        memory = whisper.encode(params, cfg, frames)
        layout = kvc.layout_for(cfg, B, S_MAX, kv_format="int8")
        cache, _ = kvc.init_cache(cfg, layout)
        # populate cross-attention memory K/V
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["decoder"])
            km = (memory @ p["xattn"]["wk"]).reshape(
                B, -1, cfg.num_kv_heads, cfg.head_dim
            )
            vm = (memory @ p["xattn"]["wv"]).reshape(
                B, -1, cfg.num_kv_heads, cfg.head_dim
            )
            cache["cross_k"] = cache["cross_k"].at[i].set(
                jnp.swapaxes(km, 1, 2).astype(cache["cross_k"].dtype))
            cache["cross_v"] = cache["cross_v"].at[i].set(
                jnp.swapaxes(vm, 1, 2).astype(cache["cross_v"].dtype))
        serve_step = jax.jit(engine.make_serve_step(cfg, layout))
        cur = jnp.zeros((B, 1), jnp.int32)
        for _ in range(3):
            lg, cache = serve_step(params, cache, cur)
            assert not bool(jnp.isnan(lg).any())
            cur = greedy(lg)[:, None]
