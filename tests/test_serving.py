"""Serving engine: prefill+decode must reproduce the teacher-forced forward
pass (the gold consistency test for KV caches, ring buffers, int8/bgpp)."""

import jax
import jax.numpy as jnp
import zlib

import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model_zoo
from repro.serving import engine, kv_cache as kvc

jax.config.update("jax_platform_name", "cpu")

B, S_PROMPT, S_DEC = 2, 24, 8
S_MAX = 64


def greedy(logits):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def run_decode_matches_forward(arch, kv_format, atol, mcbp=None, err_quantile=1.0):
    """Prefill + step-wise decode over a FIXED continuation must match the
    teacher-forced forward on the same tokens (no greedy compounding, so
    quantized paths are compared like-for-like per position).

    ``err_quantile < 1`` bounds that quantile of |Δlogits| instead of the
    max: MoE archs route through a discrete top-k, so bounded KV-quant
    noise can flip a near-tie expert choice on random-init routers and
    shift whole logit rows (with routing forced dense the same int8 path
    stays within 0.1).  The bulk of the distribution plus the greedy-
    agreement check is the sound oracle there; an absolute max is not."""
    import dataclasses

    cfg = get_config(arch, smoke=True)
    if mcbp is not None:
        cfg = dataclasses.replace(cfg, mcbp=mcbp)
    rng = np.random.default_rng(zlib.crc32(f"{arch}/{kv_format}".encode()) % 2**31)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_PROMPT)), jnp.int32)
    cont = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_DEC)), jnp.int32)

    layout = kvc.layout_for(cfg, B, S_MAX, kv_format=kv_format)
    last_logits, cache = engine.prefill(
        params, cfg, layout, tokens, block_q=8, block_k=8
    )
    serve_step = jax.jit(engine.make_serve_step(cfg, layout))

    logits_dec = [last_logits]
    for t in range(S_DEC):
        lg, cache = serve_step(params, cache, cont[:, t : t + 1])
        logits_dec.append(lg)

    full = jnp.concatenate([tokens, cont], axis=1)
    logits_full, _ = model_zoo.forward(
        params, cfg, {"tokens": full}, block_q=8, block_k=8
    )
    got = jnp.concatenate(logits_dec, axis=1)
    want = logits_full[:, S_PROMPT - 1 :]
    if err_quantile < 1.0:
        err = float(np.quantile(np.abs(np.asarray(got - want)), err_quantile))
    else:
        err = float(jnp.max(jnp.abs(got - want)))
    assert err < atol, f"{arch}/{kv_format}: decode diverges from forward by {err}"
    # per-position argmax agreement (quantized paths may flip near-ties on
    # random-init logits)
    agree = np.mean(
        np.asarray(jnp.argmax(got, -1)) == np.asarray(jnp.argmax(want, -1))
    )
    if kv_format == "bf16":
        assert agree == 1.0, agree
    else:
        assert agree >= 0.8, f"{arch}/{kv_format}: greedy agreement {agree}"


class TestDecodeConsistency:
    @pytest.mark.parametrize("arch", ["deepseek-7b", "phi4-mini-3.8b"])
    def test_dense_bf16_exactish(self, arch):
        run_decode_matches_forward(arch, "bf16", atol=2e-3)

    def test_gemma3_ring_buffer_local_global(self):
        run_decode_matches_forward("gemma3-4b", "bf16", atol=2e-3)

    def test_mixtral_swa_int8(self):
        # p95 bound: discrete MoE routing flips under int8 KV noise shift
        # a few whole logit rows (see run_decode_matches_forward docstring)
        run_decode_matches_forward(
            "mixtral-8x22b", "int8", atol=0.35, err_quantile=0.95
        )

    def test_llama4_chunked(self):
        run_decode_matches_forward("llama4-scout-17b-a16e", "bf16", atol=2e-2)

    def test_int8_kv_quantization_small_drift(self):
        run_decode_matches_forward("deepseek-7b", "int8", atol=0.35)

    def test_bgpp_cache_format_exact_at_full_keep(self):
        """BGPP gather machinery (bit-planar reconstruct, progressive
        top-k gathers, int8 formal compute) must be numerically equivalent
        to the plain int8 path when keep_ratio=1.0 keeps every key.  The
        lossy keep_ratio<1 trade-off is characterized separately on
        concentrated attention (examples/bgpp_sparse_attention.py and the
        fig24a benchmark) — random-init smoke nets have near-uniform
        attention where forced top-k scrambles argmax by construction."""
        from repro.configs.base import MCBPOptions

        run_decode_matches_forward(
            "phi4-mini-3.8b", "bgpp", atol=0.4,
            mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=1.0),
        )


class TestSSMHybridDecode:
    @pytest.mark.parametrize("arch", ["mamba2-1.3b"])
    def test_mamba2_decode_runs(self, arch):
        cfg = get_config(arch, smoke=True)
        rng = np.random.default_rng(0)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        layout = kvc.layout_for(cfg, B, S_MAX)
        cache, _ = kvc.init_cache(cfg, layout)
        serve_step = jax.jit(engine.make_serve_step(cfg, layout))
        cur = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        for _ in range(4):
            lg, cache = serve_step(params, cache, cur)
            assert lg.shape == (B, 1, cfg.vocab_size)
            assert not bool(jnp.isnan(lg).any())
            cur = greedy(lg)[:, None]
        assert int(cache["pos"]) == 4

    def test_jamba_decode_runs(self):
        cfg = get_config("jamba-1.5-large-398b", smoke=True)
        rng = np.random.default_rng(1)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        layout = kvc.layout_for(cfg, B, S_MAX, kv_format="int8")
        assert layout.mamba_layers and layout.global_layers
        cache, _ = kvc.init_cache(cfg, layout)
        serve_step = jax.jit(engine.make_serve_step(cfg, layout))
        cur = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        for _ in range(3):
            lg, cache = serve_step(params, cache, cur)
            assert not bool(jnp.isnan(lg).any())
            cur = greedy(lg)[:, None]

    def test_whisper_decode_runs(self):
        cfg = get_config("whisper-medium", smoke=True)
        rng = np.random.default_rng(2)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        from repro.models import whisper

        frames = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_audio)), jnp.float32
        )
        memory = whisper.encode(params, cfg, frames)
        layout = kvc.layout_for(cfg, B, S_MAX, kv_format="int8")
        cache, _ = kvc.init_cache(cfg, layout)
        # populate cross-attention memory K/V
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], params["decoder"])
            km = (memory @ p["xattn"]["wk"]).reshape(
                B, -1, cfg.num_kv_heads, cfg.head_dim
            )
            vm = (memory @ p["xattn"]["wv"]).reshape(
                B, -1, cfg.num_kv_heads, cfg.head_dim
            )
            cache["cross_k"] = cache["cross_k"].at[i].set(
                jnp.swapaxes(km, 1, 2).astype(cache["cross_k"].dtype))
            cache["cross_v"] = cache["cross_v"].at[i].set(
                jnp.swapaxes(vm, 1, 2).astype(cache["cross_v"].dtype))
        serve_step = jax.jit(engine.make_serve_step(cfg, layout))
        cur = jnp.zeros((B, 1), jnp.int32)
        for _ in range(3):
            lg, cache = serve_step(params, cache, cur)
            assert not bool(jnp.isnan(lg).any())
            cur = greedy(lg)[:, None]
