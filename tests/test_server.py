"""Async streaming front door: AsyncServer / ChatSession / TCPFrontDoor.

Stdlib-only asyncio tests (``asyncio.run`` inside sync test functions — no
pytest-asyncio in the pinned environment).  Each test drives a real
scheduler on the phi4 smoke model with the per-step ``PageAllocator.check``
leak gate armed, so every streaming/cancel/session path is also a pool
hygiene proof.
"""

import asyncio
import json

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving.scheduler import Scheduler
from repro.serving.server import AsyncServer, TCPFrontDoor, simulate_clients
from repro.serving.request import poisson_trace

jax.config.update("jax_platform_name", "cpu")

MAX_SEQ = 64
PAGE_SIZE = 8


@pytest.fixture(scope="module")
def served():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    return cfg, params


_SHARED = {}


def make_sched(cfg, params, slots=2):
    layout = kvc.layout_for(cfg, slots, MAX_SEQ, kv_format="bf16",
                            layout="paged", page_size=PAGE_SIZE)
    sched = Scheduler(params, cfg, layout, admission="chunked",
                      chunk_budget=6, shared_fns=_SHARED.get(slots))
    _SHARED[slots] = sched.shared_fns()
    return sched


def prompt_of(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)


def drive(sched, body):
    """Run ``body(server)`` against a pumped AsyncServer; close + drain on
    the way out and verify the page pool ended empty."""

    async def main():
        server = AsyncServer(sched, check_invariants=True)
        pump = asyncio.ensure_future(server.run())
        try:
            return await body(server)
        finally:
            server.close()
            await pump
            sched.pager.check()
            assert sched.pager.pages_in_use == 0, "server leaked pages"

    return asyncio.run(main())


class TestStreaming:
    def test_tokens_stream_incrementally(self, served):
        cfg, params = served
        rng = np.random.default_rng(0)
        sched = make_sched(cfg, params)

        async def body(server):
            stream = server.submit(prompt_of(rng, cfg, 9), 4)
            toks = [t async for t in stream]
            assert len(toks) == 4
            req = stream.request
            assert req is not None and not req.cancelled
            assert toks == req.generated  # stream IS the generated sequence
            return server.stats()

        stats = drive(sched, body)
        assert stats["finished_requests"] == 1
        assert stats["server"]["open_streams"] == 0

    def test_two_streams_interleave(self, served):
        cfg, params = served
        rng = np.random.default_rng(1)
        sched = make_sched(cfg, params)

        async def body(server):
            s1 = server.submit(prompt_of(rng, cfg, 7), 5)
            s2 = server.submit(prompt_of(rng, cfg, 11), 3)
            r1, r2 = await asyncio.gather(
                asyncio.ensure_future(_collect(s1)),
                asyncio.ensure_future(_collect(s2)),
            )
            assert len(r1) == 5 and len(r2) == 3

        drive(sched, body)

    def test_invalid_priority_rejected_at_submit(self, served):
        cfg, params = served
        rng = np.random.default_rng(2)
        sched = make_sched(cfg, params)

        async def body(server):
            with pytest.raises(ValueError, match="priority"):
                server.submit(prompt_of(rng, cfg, 5), 2, priority="vip")

        drive(sched, body)


async def _collect(stream):
    return [t async for t in stream]


class TestCancellation:
    def test_cancel_mid_stream_spares_neighbor(self, served):
        """Disconnect one client after two tokens; the other stream must
        finish its full budget and the pool must drain."""
        cfg, params = served
        rng = np.random.default_rng(3)
        sched = make_sched(cfg, params)

        async def body(server):
            victim = server.submit(prompt_of(rng, cfg, 9), 32)
            other = server.submit(prompt_of(rng, cfg, 8), 6)
            got = []
            async for t in victim:
                got.append(t)
                if len(got) == 2:
                    await victim.cancel()
                    break
            assert victim.request.cancelled
            assert victim.request.cancel_state in ("prefilling", "decoding")
            survivor = await _collect(other)
            assert len(survivor) == 6
            return server.stats()

        stats = drive(sched, body)
        assert stats["cancelled_requests"] == 1
        assert stats["finished_requests"] == 1

    def test_cancel_while_queued(self, served):
        cfg, params = served
        rng = np.random.default_rng(4)
        sched = make_sched(cfg, params, slots=1)

        async def body(server):
            busy = server.submit(prompt_of(rng, cfg, 8), 8)
            queued = server.submit(prompt_of(rng, cfg, 8), 4)
            await queued.cancel()
            assert queued.request.cancelled
            assert queued.request.cancel_state == "queued"
            assert len(await _collect(queued)) == 0
            assert len(await _collect(busy)) == 8

        drive(sched, body)

    def test_deadline_shed_closes_stream(self, served):
        """A queued request whose SLO deadline lapses is shed: its stream
        ends with zero tokens and the shed flag set."""
        cfg, params = served
        rng = np.random.default_rng(5)
        sched = make_sched(cfg, params, slots=1)

        async def body(server):
            busy = server.submit(prompt_of(rng, cfg, 8), 12)
            doomed = server.submit(prompt_of(rng, cfg, 8), 4,
                                   deadline_steps=2)
            assert await _collect(doomed) == []
            assert doomed.request.shed
            assert len(await _collect(busy)) == 12
            return server.stats()

        stats = drive(sched, body)
        assert stats["shed_requests"] == 1

    def test_close_cancels_outstanding(self, served):
        cfg, params = served
        rng = np.random.default_rng(6)
        sched = make_sched(cfg, params)

        async def body(server):
            stream = server.submit(prompt_of(rng, cfg, 9), 48)
            async for _ in stream:
                break  # client walks away without cancelling
            server.close()
            # the close path cancelled it; the stream observes the end
            rest = await _collect(stream)
            assert stream.request is not None and stream.request.cancelled
            assert isinstance(rest, list)

        drive(sched, body)


class TestPriorities:
    def test_interactive_preempts_batch_prefill(self, served):
        """An interactive arrival one step after a long batch prompt
        started chunking steals the budget: the batch request records the
        preemption and the interactive one gets its first token first."""
        cfg, params = served
        rng = np.random.default_rng(7)
        sched = make_sched(cfg, params)

        async def body(server):
            batch = server.submit(prompt_of(rng, cfg, 20), 4,
                                  priority="batch")
            inter = server.submit(prompt_of(rng, cfg, 8), 3,
                                  priority="interactive", arrival_step=1)
            b, i = await asyncio.gather(
                asyncio.ensure_future(_collect(batch)),
                asyncio.ensure_future(_collect(inter)),
            )
            assert len(b) == 4 and len(i) == 3
            assert (inter.request.first_token_step
                    < batch.request.first_token_step)
            assert batch.request.preemptions >= 1
            return server.stats()

        stats = drive(sched, body)
        assert stats["preemptions"] >= 1
        tiers = stats["tiers"]
        assert tiers["batch"]["preemptions"] >= 1
        assert tiers["interactive"]["itl_s"]["p50"] is not None


class TestChatSessions:
    def test_second_turn_hits_prefix_index(self, served):
        """Turn 2's prompt (history + new user tokens) must adopt the
        pinned pages of turn 1's written history via the sha1 index, and
        closing the session must drain the pool."""
        cfg, params = served
        rng = np.random.default_rng(8)
        sched = make_sched(cfg, params)

        async def body(server):
            t1 = server.chat("s", prompt_of(rng, cfg, 17), 3)
            await _collect(t1)
            sess = server.sessions["s"]
            assert sess.turns == 1 and len(sess.pinned) >= 1
            # turn 1 wrote 17 + 3 - 1 = 19 KV positions -> 2 full pages
            assert len(sess.pinned) == 2
            assert t1.request.pinned_pages == sess.pinned

            t2 = server.chat("s", prompt_of(rng, cfg, 5), 3)
            toks = await _collect(t2)
            assert len(toks) == 3
            assert sched.prefix_hits >= 1
            assert t2.request.prefix_reused_tokens == 16  # both full pages
            # pin handoff: the new pin covers the grown history
            assert server.sessions["s"].pinned == t2.request.pinned_pages
            server.close_session("s")
            sched.pager.check()
            assert sched.pager.pages_in_use == 0

        drive(sched, body)

    def test_cancelled_turn_preserves_session(self, served):
        """A turn cancelled mid-stream must not advance the history or
        disturb the previous turn's pins."""
        cfg, params = served
        rng = np.random.default_rng(9)
        sched = make_sched(cfg, params)

        async def body(server):
            t1 = server.chat("s", prompt_of(rng, cfg, 17), 3)
            await _collect(t1)
            sess = server.sessions["s"]
            hist_len, pins = len(sess.history), sess.pinned

            t2 = server.chat("s", prompt_of(rng, cfg, 5), 16)
            async for _ in t2:
                await t2.cancel()
                break
            assert t2.request.cancelled
            assert len(sess.history) == hist_len and sess.pinned == pins
            server.close_session("s")

        drive(sched, body)


class TestTCPFrontDoor:
    def test_roundtrip_and_disconnect(self, served):
        """One client streams to completion over a real socket; a second
        hangs up mid-stream and must be cancelled server-side."""
        cfg, params = served
        rng = np.random.default_rng(10)
        sched = make_sched(cfg, params)

        async def body(server):
            door = TCPFrontDoor(server)
            await door.start()
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", door.port)
            writer.write(json.dumps({
                "prompt": prompt_of(rng, cfg, 9).tolist(),
                "max_new_tokens": 4, "priority": "batch",
            }).encode() + b"\n")
            await writer.drain()
            msgs = []
            while True:
                msg = json.loads(await reader.readline())
                msgs.append(msg)
                if msg.get("done"):
                    break
            writer.close()
            assert len(msgs) == 5  # 4 {"token": t} lines + the done line
            assert all("token" in m for m in msgs[:-1])
            assert msgs[-1]["done"] and msgs[-1]["tokens"] == 4
            assert not msgs[-1]["cancelled"]

            r2, w2 = await asyncio.open_connection("127.0.0.1", door.port)
            w2.write(json.dumps({
                "prompt": prompt_of(rng, cfg, 9).tolist(),
                "max_new_tokens": 32,
            }).encode() + b"\n")
            await w2.drain()
            await r2.readline()  # first streamed token
            w2.close()  # disconnect mid-stream
            for _ in range(500):
                await asyncio.sleep(0)
                if sched.cancelled:
                    break
            assert len(sched.cancelled) == 1
            await server.drain()
            await door.stop()
            return server.stats()

        stats = drive(sched, body)
        assert stats["cancelled_requests"] == 1
        assert stats["finished_requests"] == 1


class TestSimulatedClients:
    def test_harness_cancels_and_reports_tiers(self, served):
        """The --server launcher harness: tiered rotating clients, every
        3rd disconnecting after one token — at least one real cancel,
        both tiers in stats, pool drained."""
        cfg, params = served
        sched = make_sched(cfg, params)
        reqs = poisson_trace(np.random.default_rng(11), 6, cfg.vocab_size,
                             6, max_prompt=14)
        stats = simulate_clients(sched, reqs, disconnect_every=3,
                                 disconnect_after=1)
        assert stats["cancelled_requests"] >= 1
        assert {"interactive", "batch"} <= set(stats["tiers"])
        assert stats["paged"]["pages_in_use"] == 0
        assert len(stats["clients"]) == 6
        assert sum(c["disconnected"] for c in stats["clients"]) == 2
