"""The runnable examples must actually run (subprocess smoke)."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_example(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable] + args,
        cwd=REPO, env=env, capture_output=True, text=True, timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestExamples:
    def test_quickstart(self):
        out = run_example(["examples/quickstart.py"])
        assert "BRCR exact: True" in out
        assert "BSTC lossless: True" in out

    def test_train_llm_short(self):
        out = run_example([
            "examples/train_llm.py", "--steps", "25", "--d-model", "64",
            "--layers", "2", "--seq-len", "64", "--batch", "2",
            "--vocab", "512", "--ckpt-every", "10",
        ])
        assert "improved" in out and "NOT improved" not in out

    def test_serve_llm_short(self):
        out = run_example([
            "examples/serve_llm.py", "--steps", "6", "--batch", "2",
            "--prompt-len", "16",
        ])
        assert "admission=chunked" in out
        assert "ttft_s p50=" in out  # serving metrics are always reported

    def test_bgpp_example(self):
        out = run_example(["examples/bgpp_sparse_attention.py"])
        assert "per-round alive counts" in out


class TestLaunchers:
    def test_train_launcher(self, tmp_path):
        out = run_example([
            "-m", "repro.launch.train", "--steps", "20", "--batch", "2",
            "--seq-len", "32", "--ckpt-every", "10",
            "--ckpt-dir", str(tmp_path / "ck"),
            "--heartbeat", str(tmp_path / "hb.json"),
        ])
        assert "done (0 failures survived)" in out

    def test_serve_launcher(self):
        out = run_example([
            "-m", "repro.launch.serve", "--requests", "2", "--slots", "2",
            "--max-new", "4",
        ])
        assert "2/2 requests" in out
