"""Property tests for the paged-KV page allocator and pool hygiene.

The three laws the paged layout's safety rests on:

  * alloc/free/refcount round-trips never double-free or leak — after any
    op sequence every page is exactly one of {free, mapped}, refcounts
    equal table reachability, and the free list is duplicate-free
    (``PageAllocator.check``);
  * freed pages are re-zeroed across EVERY store leaf — k/v bodies, int8
    scales, bgpp bit/sign planes — before they can be remapped;
  * no physical page is ever reachable from two slots whose requests do
    not share the page-aligned token prefix covering it (prefix reuse is
    the only legal sharing channel).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.serving import kv_cache as kvc
from repro.serving.paging import PageAllocator
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")


def _layout(batch=3, max_seq=32, fmt="int8", page_size=8, num_pages=None):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    return cfg, kvc.layout_for(cfg, batch, max_seq, kv_format=fmt,
                               layout="paged", page_size=page_size,
                               num_pages=num_pages)


# --------------------------------------------------------------------------
# allocator bookkeeping
# --------------------------------------------------------------------------


def test_alloc_release_round_trip_never_leaks(rng):
    _, layout = _layout()
    pager = PageAllocator(layout)
    for _ in range(200):
        slot = int(rng.integers(0, layout.batch))
        if rng.random() < 0.6:
            hi = int(rng.integers(1, layout.max_seq + 1))
            lo = int(rng.integers(0, hi))
            pager.ensure_range(slot, lo, hi)
        else:
            pager.release_slot(slot)
        pager.check()
    for slot in range(layout.batch):
        pager.release_slot(slot)
        # releasing an already-empty slot is a no-op, not a double free
        pager.release_slot(slot)
    pager.check()
    assert pager.pages_in_use == 0


def test_refcount_sharing_round_trip():
    _, layout = _layout()
    pager = PageAllocator(layout)
    prompt = np.arange(24, dtype=np.int32)
    pager.ensure_range(0, 0, 24)
    pager.register_prefix(0, prompt, upto=24)
    n, ids = pager.lookup_prefix(np.concatenate([prompt, [99]]).astype(np.int32))
    assert n == 24 and len(ids) == 3
    pager.adopt_prefix(1, ids)
    pager.check()
    assert all(pager.refcount[p] == 2 for p in ids)
    # releasing the donor keeps the sharer's pages alive (refcount 2 -> 1)
    assert pager.release_slot(0) == []
    pager.check()
    assert all(pager.refcount[p] == 1 for p in ids)
    # releasing the last holder frees them
    freed = pager.release_slot(1)
    assert sorted(freed) == sorted(ids)
    pager.check()
    assert pager.pages_in_use == 0


def test_lookup_caps_reuse_below_full_prompt():
    # the last prompt token must still run through prefill to produce the
    # first-token logits, so an exact whole-prompt match reuses one page
    # less than the match
    _, layout = _layout()
    pager = PageAllocator(layout)
    prompt = np.arange(16, dtype=np.int32)
    pager.ensure_range(0, 0, 16)
    pager.register_prefix(0, prompt, upto=16)
    n, _ = pager.lookup_prefix(prompt)
    assert n == 8  # one of the two matched pages


def test_stale_prefix_entries_never_resurrect_freed_pages():
    _, layout = _layout()
    pager = PageAllocator(layout)
    prompt = np.arange(16, dtype=np.int32)
    pager.ensure_range(0, 0, 16)
    pager.register_prefix(0, prompt, upto=16)
    pager.release_slot(0)  # frees the pages; generations move on
    longer = np.concatenate([prompt, prompt]).astype(np.int32)
    assert pager.lookup_prefix(longer) == (0, ())
    # ... even if another slot re-acquires the same physical pages
    pager.ensure_range(1, 0, 16)
    assert pager.lookup_prefix(longer) == (0, ())
    pager.check()


def test_pool_exhaustion_is_loud():
    _, layout = _layout(batch=2, max_seq=32, num_pages=2)
    pager = PageAllocator(layout)
    pager.ensure_range(0, 0, 16)
    with pytest.raises(RuntimeError, match="exhausted"):
        pager.ensure_range(1, 0, 16)


# --------------------------------------------------------------------------
# rewind: speculative-decode rollback (pos frontier moves backwards)
# --------------------------------------------------------------------------


def test_rewind_deregisters_prefix_entries_past_keep():
    """Regression (spec-decode satellite): rolling a slot back across a
    page boundary must deregister every sha1 prefix-index entry covering
    now-invalid pages.  Before the fix a rewound slot's stale 16/24-token
    entries would still hit for a later prompt and adopt pages whose tail
    tokens were never (re)written."""
    _, layout = _layout()
    pager = PageAllocator(layout)
    prompt = np.arange(24, dtype=np.int32)
    pager.ensure_range(0, 0, 24)
    pager.register_prefix(0, prompt, upto=24)
    probe = np.concatenate([prompt, [99]]).astype(np.int32)
    n, ids = pager.lookup_prefix(probe)
    assert n == 24 and len(ids) == 3
    # rewind to keep 10 tokens: page 2 (tokens 16..24) frees outright,
    # page 1 (8..16) is the partially-kept frontier — the 16- and
    # 24-token boundary digests it carries must BOTH dereg, while the
    # wholly-kept page-0 boundary survives
    freed = pager.rewind_slot(0, 10)
    pager.check()
    assert freed == [int(ids[2])]
    assert int(pager.table[0, 2]) == -1
    n, hit = pager.lookup_prefix(probe)
    assert n == 8 and list(hit) == [int(ids[0])], (
        f"rewound prefix entries must miss: matched {n} tokens")


def test_rewind_page_aligned_keeps_covered_boundaries():
    _, layout = _layout()
    pager = PageAllocator(layout)
    prompt = np.arange(24, dtype=np.int32)
    pager.ensure_range(0, 0, 24)
    pager.register_prefix(0, prompt, upto=24)
    probe = np.concatenate([prompt, [99]]).astype(np.int32)
    # keep == a page boundary: pages 0/1 stay fully written, so their
    # 8- and 16-token boundaries remain legal adoption targets
    freed = pager.rewind_slot(0, 16)
    pager.check()
    assert len(freed) == 1
    n, hit = pager.lookup_prefix(probe)
    assert n == 16 and len(hit) == 2


def test_rewind_bumps_generation_against_resurrection():
    """A page freed by rewind must be unresurrectable: even if another
    slot re-acquires the same physical page, pre-rewind index entries
    (had any survived) die at the generation check, and re-registering
    after the rewind starts from the rewound frontier."""
    _, layout = _layout()
    pager = PageAllocator(layout)
    prompt = np.arange(16, dtype=np.int32)
    pager.ensure_range(0, 0, 16)
    pager.register_prefix(0, prompt, upto=16)
    freed = pager.rewind_slot(0, 0)  # rewind everything away
    pager.check()
    assert len(freed) == 2 and pager.pages_in_use == 0
    probe = np.concatenate([prompt, [99]]).astype(np.int32)
    assert pager.lookup_prefix(probe) == (0, ())
    pager.ensure_range(1, 0, 16)  # same physical pages, new generation
    assert pager.lookup_prefix(probe) == (0, ())
    pager.check()
    # the rewound slot itself re-registers from scratch
    pager.ensure_range(0, 0, 16)
    pager.register_prefix(0, prompt, upto=16)
    n, _ = pager.lookup_prefix(probe)
    assert n == 16


def test_rewind_refuses_to_corrupt_shared_frontier():
    """The frontier page can never legally be shared (adopted pages cover
    at most prompt_len - 1 < keep tokens), so a shared-frontier rewind is
    allocator corruption and must be loud."""
    _, layout = _layout()
    pager = PageAllocator(layout)
    prompt = np.arange(24, dtype=np.int32)
    pager.ensure_range(0, 0, 24)
    pager.register_prefix(0, prompt, upto=24)
    _, ids = pager.lookup_prefix(np.concatenate([prompt, [99]])
                                 .astype(np.int32))
    pager.adopt_prefix(1, ids)
    with pytest.raises(AssertionError, match="shared page"):
        pager.rewind_slot(0, 12)  # page 1 shared AND partially kept
    # page-aligned rewinds around the shared region stay legal
    pager.rewind_slot(0, 24)
    pager.check()


# --------------------------------------------------------------------------
# pins: residency held by no slot (chat-session keep-alives)
# --------------------------------------------------------------------------


def test_pin_survives_slot_release():
    """A pinned prefix outlives its slot: release_slot decrefs but the pin
    keeps the pages (and their index entries) resident and adoptable."""
    _, layout = _layout()
    pager = PageAllocator(layout)
    prompt = np.arange(16, dtype=np.int32)
    pager.ensure_range(0, 0, 16)
    pager.register_prefix(0, prompt, upto=16)
    ids = [int(p) for p in pager.table[0, :2]]
    pager.pin_pages(ids)
    pager.check()
    assert pager.release_slot(0) == []  # pin holds them: nothing freed
    pager.check()
    assert pager.pages_in_use == 0 or all(pager.refcount[p] == 1
                                          for p in ids)
    # still resident + still indexed: a longer prompt adopts them
    longer = np.concatenate([prompt, prompt]).astype(np.int32)
    n, hit = pager.lookup_prefix(longer)
    assert n == 16 and list(hit) == ids
    pager.adopt_prefix(1, hit)
    pager.check()
    # sharer releases, then the pin: only the unpin frees
    assert pager.release_slot(1) == []
    assert sorted(pager.unpin_pages(ids)) == sorted(ids)
    pager.check()
    assert pager.lookup_prefix(longer) == (0, ()), "unpin left the index"


def test_unpin_is_exact_inverse_and_loud():
    _, layout = _layout()
    pager = PageAllocator(layout)
    pager.ensure_range(0, 0, 8)
    (p,) = [int(q) for q in pager.table[0, :1]]
    pager.pin_pages([p])
    pager.pin_pages([p])  # pins stack like refcounts
    pager.check()
    assert pager.refcount[p] == 3 and pager.pins[p] == 2
    assert pager.unpin_pages([p]) == []
    pager.release_slot(0)
    pager.check()
    assert pager.unpin_pages([p]) == [p]  # last holder frees
    pager.check()
    with pytest.raises(AssertionError, match="not pinned"):
        pager.unpin_pages([p])
    with pytest.raises(AssertionError, match="freed"):
        pager.pin_pages([p])  # pins extend residency, never resurrect


def test_check_catches_pin_refcount_drift():
    _, layout = _layout()
    pager = PageAllocator(layout)
    pager.ensure_range(0, 0, 8)
    pager.pins[int(pager.table[0, 0])] += 1  # pin without the refcount
    with pytest.raises(AssertionError, match="refcount drift"):
        pager.check()


# --------------------------------------------------------------------------
# freed pages are re-zeroed across every store leaf
# --------------------------------------------------------------------------


EXPECTED_POOL_LEAVES = {
    "bf16": {"k", "v"},
    "int8": {"k", "v", "k_scale", "v_scale"},
    "bgpp": {"k_planes", "k_sign", "k_scale", "v", "v_scale"},
}


@pytest.mark.parametrize("fmt", ["bf16", "int8", "bgpp"])
def test_zero_pages_scrubs_every_leaf(fmt):
    cfg, layout = _layout(fmt=fmt)
    cache = kvc.init_cache_arrays(cfg, layout)
    assert set(cache["global"].keys()) == EXPECTED_POOL_LEAVES[fmt]
    filled = {n: jnp.full_like(a, 3) for n, a in cache["global"].items()}
    ids = jnp.asarray([1, 3, -1, -1], jnp.int32)  # -1 padding must drop
    zeroed = kvc.zero_pages(dict(filled), ids, layout.page_size)
    ps = layout.page_size
    for n, a in zeroed.items():
        tok = np.moveaxis(np.asarray(a), kvc._tok_dim(n), 1)
        for p in (1, 3):
            assert np.all(tok[:, p * ps:(p + 1) * ps] == 0), f"{n}: page {p}"
        for p in (0, 2):
            assert np.all(tok[:, p * ps:(p + 1) * ps] == 3), \
                f"{n}: page {p} touched"


@pytest.mark.parametrize("fmt", ["bf16", "int8", "bgpp"])
def test_scheduler_eviction_zeroes_freed_pages(fmt):
    """Drive a real request through the paged scheduler; after it finishes
    every pool leaf must be all-zero again (its pages were freed and
    scrubbed on device)."""
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    from repro.models import model_zoo
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    layout = kvc.layout_for(cfg, 2, 32, kv_format=fmt, layout="paged",
                            page_size=8)
    sched = Scheduler(params, cfg, layout, chunk_budget=6)
    rng = np.random.default_rng(0)
    sched.submit(Request(
        rid=0, prompt=rng.integers(0, cfg.vocab_size, (11,)).astype(np.int32),
        max_new_tokens=3,
    ))
    sched.run(max_steps=200)
    assert len(sched.finished) == 1
    sched.pager.check()
    assert sched.pager.pages_in_use == 0
    for n, a in sched.cache["global"].items():
        assert not np.any(np.asarray(a)), f"{n}: stale bytes survived eviction"


# --------------------------------------------------------------------------
# sharing legitimacy: only identical page-aligned prefixes may share
# --------------------------------------------------------------------------


def _assert_sharing_legit(sched):
    """Any page mapped by >1 slot must back the same logical page index of
    requests whose prompts agree on every token that page covers."""
    pager = sched.pager
    owners = {}
    for b in range(pager.table.shape[0]):
        for pi in range(pager.table.shape[1]):
            p = int(pager.table[b, pi])
            if p >= 0:
                owners.setdefault(p, []).append((b, pi))
    for p, lst in owners.items():
        if len(lst) < 2:
            continue
        assert len({pi for _, pi in lst}) == 1, \
            f"page {p} mapped at different logical indices: {lst}"
        n = (lst[0][1] + 1) * pager.page_size
        prompts = []
        for b, _ in lst:
            req = sched.slots[b].request
            assert req is not None, f"page {p} shared with an empty slot {b}"
            assert req.prompt_len >= n
            prompts.append(np.asarray(req.prompt[:n]))
        for q in prompts[1:]:
            assert np.array_equal(prompts[0], q), \
                f"page {p} shared across unrelated prompts"


def _drive(reqs, fmt="int8"):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    from repro.models import model_zoo
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    layout = kvc.layout_for(cfg, 2, 48, kv_format=fmt, layout="paged",
                            page_size=8)
    sched = Scheduler(params, cfg, layout, chunk_budget=6)
    for r in reqs:
        sched.submit(r)
    shared_seen = 0
    for _ in range(500):
        if not sched.num_pending:
            break
        sched.step()
        sched.pager.check()
        _assert_sharing_legit(sched)
        shared_seen += int(np.any(sched.pager.refcount > 1))
    assert len(sched.finished) == len(reqs), "trace did not drain"
    return sched, shared_seen


def test_unrelated_prompts_never_share_pages(rng):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    # distinct leading tokens => no page-aligned common prefix exists
    reqs = [Request(
        rid=i,
        prompt=np.concatenate([[i], rng.integers(
            0, cfg.vocab_size, (int(rng.integers(8, 20)),))]).astype(np.int32),
        max_new_tokens=3, arrival_step=2 * i,
    ) for i in range(4)]
    sched, shared_seen = _drive(reqs)
    assert shared_seen == 0
    assert sched.prefix_hit_tokens == 0


def test_eager_admission_paged_matches_slot(rng):
    """The eager (whole-prompt B=1) admission path also supports paged
    layouts — admit() maps the pages, prefill_into_slot writes through the
    table — and must stay bit-identical to the slot layout (no other suite
    drives eager × paged)."""
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    from repro.models import model_zoo
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    reqs = [Request(
        rid=i,
        prompt=rng.integers(0, cfg.vocab_size, (int(rng.integers(5, 12)),))
        .astype(np.int32),
        max_new_tokens=3, arrival_step=2 * i,
    ) for i in range(2)]
    out = {}
    for lay in ("slot", "paged"):
        layout = kvc.layout_for(cfg, 2, 32, kv_format="int8", layout=lay,
                                page_size=8)
        sched = Scheduler(params, cfg, layout, admission="eager",
                          record_logits=True,
                          prefill_kw=dict(block_q=16, block_k=32))
        for r in reqs:
            sched.submit(Request(rid=r.rid, prompt=r.prompt,
                                 max_new_tokens=r.max_new_tokens,
                                 arrival_step=r.arrival_step))
        sched.run(max_steps=200)
        assert len(sched.finished) == 2
        if sched.pager is not None:
            sched.pager.check()
            assert sched.pager.pages_in_use == 0
        out[lay] = {r.rid: r for r in sched.finished}
    for rid in out["slot"]:
        a, b = out["slot"][rid], out["paged"][rid]
        assert a.generated == b.generated
        for x, y in zip(a.logit_rows, b.logit_rows):
            assert np.array_equal(x, y)


def test_shared_prefix_sharing_is_prefix_aligned(rng):
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    prefix = rng.integers(0, cfg.vocab_size, (16,)).astype(np.int32)
    reqs = [Request(
        rid=i,
        prompt=np.concatenate([prefix, rng.integers(
            0, cfg.vocab_size, (int(rng.integers(3, 8)),))]).astype(np.int32),
        max_new_tokens=8, arrival_step=6 * i,
    ) for i in range(3)]
    sched, shared_seen = _drive(reqs)
    # sharing must actually have happened (the per-step asserts above
    # proved every instance was prefix-aligned)
    assert shared_seen > 0
    assert sched.prefix_hit_tokens >= 16
