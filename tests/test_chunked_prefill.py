"""Chunked, bucketed prefill admission: exactness of chunk composition,
the one-compile-per-bucket contract, and the scheduler's chunk-budget
bound between batched decode steps."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model_zoo
from repro.serving import engine, kv_cache as kvc
from repro.serving.request import Request, SlotState
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

MAX_SEQ = 64
# off-bucket (5 pads into the 8-bucket), bucket-exact (16), > one chunk (21)
PROMPT_LENS = (5, 16, 21)


@pytest.fixture(scope="module")
def dense():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def swa():
    cfg = get_config("gemma3-4b", smoke=True)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    return cfg, params


def _compose_vs_whole(cfg, params, kv_format, s_prompt, rng):
    """Returns (whole-prompt logits, composed logits, caches) for one prompt:
    whole = a single fixed-shape chunk covering the prompt, composed = the
    (8, 16) bucket walk."""
    layout = kvc.layout_for(cfg, 2, MAX_SEQ, kv_format=kv_format)
    prompt = rng.integers(0, cfg.vocab_size, (s_prompt,)).astype(np.int32)
    whole = engine.ChunkedPrefill(cfg, layout, buckets=(32,))
    lg_w, cache_w = whole.admit(
        params, kvc.init_cache_arrays(cfg, layout), 1, prompt
    )
    comp = engine.ChunkedPrefill(cfg, layout, buckets=(8, 16))
    lg_c, cache_c = comp.admit(
        params, kvc.init_cache_arrays(cfg, layout), 1, prompt,
        max_chunk=8,
    )
    return (np.asarray(lg_w, np.float32), np.asarray(lg_c, np.float32),
            cache_w, cache_c, layout, prompt)


def _assert_cache_equal(cache_a, cache_b):
    for a, b in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


class TestChunkComposition:
    """Satellite: chunk composition must reproduce whole-prompt admission."""

    @pytest.mark.parametrize("s_prompt", PROMPT_LENS)
    def test_dense_bf16_bit_identical(self, dense, s_prompt, rng):
        cfg, params = dense
        lg_w, lg_c, cw, cc, _, _ = _compose_vs_whole(
            cfg, params, "bf16", s_prompt, rng
        )
        assert np.array_equal(lg_w, lg_c), (
            f"S={s_prompt}: chunk composition diverged "
            f"(max |d| {np.max(np.abs(lg_w - lg_c))})"
        )
        _assert_cache_equal(cw, cc)

    @pytest.mark.parametrize("s_prompt", PROMPT_LENS)
    def test_swa_bf16_bit_identical(self, swa, s_prompt, rng):
        # ring-buffered local layers: the gathered fixed-width window keeps
        # lane placement chunking-invariant, so SWA is bit-exact too
        cfg, params = swa
        lg_w, lg_c, cw, cc, _, _ = _compose_vs_whole(
            cfg, params, "bf16", s_prompt, rng
        )
        assert np.array_equal(lg_w, lg_c)
        _assert_cache_equal(cw, cc)

    def test_dense_int8_bit_identical(self, dense, rng):
        # every key is read back from the quantized stack regardless of
        # which chunk wrote it, so even int8 composition is bit-stable
        cfg, params = dense
        lg_w, lg_c, cw, cc, _, _ = _compose_vs_whole(cfg, params, "int8", 21, rng)
        assert np.array_equal(lg_w, lg_c)
        _assert_cache_equal(cw, cc)

    def test_dense_bgpp_bit_identical(self, dense, rng):
        cfg, params = dense
        lg_w, lg_c, cw, cc, _, _ = _compose_vs_whole(cfg, params, "bgpp", 21, rng)
        assert np.array_equal(lg_w, lg_c)
        _assert_cache_equal(cw, cc)

    def test_swa_int8_close(self, swa, rng):
        # int8 rings hold quantized pre-chunk context while in-chunk keys
        # are fresh, so composition differs from whole-prompt by bounded
        # quantization noise (not bit-exact by construction)
        cfg, params = swa
        lg_w, lg_c, _, _, _, _ = _compose_vs_whole(cfg, params, "int8", 21, rng)
        assert float(np.max(np.abs(lg_w - lg_c))) < 5e-2

    @pytest.mark.parametrize("kv_format,atol", [("bf16", 1e-4), ("int8", 0.3)])
    def test_matches_eager_reference(self, dense, kv_format, atol, rng):
        """The jitted chunk path and the eager whole-prompt forward are the
        same math up to blocked-softmax reassociation (bf16) and fresh-vs-
        quantized prompt self-attention (int8)."""
        cfg, params = dense
        lg_w, _, cache_w, _, layout, prompt = _compose_vs_whole(
            cfg, params, kv_format, 20, rng
        )
        lg_e, cache_e = engine.prefill_into_slot(
            params, cfg, layout, kvc.init_cache_arrays(cfg, layout), 1,
            jnp.asarray(prompt), block_q=8, block_k=8,
        )
        assert float(np.max(np.abs(lg_w - np.asarray(lg_e, np.float32)))) < atol
        assert np.all(
            np.asarray(cache_w["pos"]) == np.asarray(cache_e["pos"])
        )


class TestRecompileBound:
    """Satellite: admitting many distinct prompt lengths compiles at most
    once per configured bucket (the donate/bucketing contract)."""

    def test_one_compile_per_bucket(self, dense, rng):
        cfg, params = dense
        layout = kvc.layout_for(cfg, 2, MAX_SEQ, kv_format="int8")
        chunked = engine.ChunkedPrefill(cfg, layout, buckets=(4, 8, 16))
        cache = kvc.init_cache_arrays(cfg, layout)
        for s in range(1, 23):  # 22 distinct lengths, alternating slots
            prompt = rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32)
            _, cache = chunked.admit(params, cache, s % 2, prompt)
        assert chunked.num_compiles <= len(chunked.buckets), (
            f"{chunked.num_compiles} chunk compiles for buckets "
            f"{chunked.buckets}"
        )
        assert chunked._reset._cache_size() == 1

    def test_scheduler_compiles_bounded(self, dense, rng):
        cfg, params = dense
        layout = kvc.layout_for(cfg, 2, MAX_SEQ, kv_format="bf16")
        sched = Scheduler(params, cfg, layout, admission="chunked",
                          chunk_budget=8)
        for rid, s in enumerate((3, 7, 8, 11, 15, 19)):
            sched.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, (s,)).astype(np.int32),
                max_new_tokens=2,
            ))
        sched.run(max_steps=500)
        assert len(sched.finished) == 6
        assert sched.chunked.num_compiles <= len(sched.chunked.buckets)


class TestChunkBudgetContract:
    """Acceptance: never more than chunk_budget prefill tokens between
    consecutive batched decode steps, and in-flight decoders keep making
    progress while a long prompt admits."""

    def test_budget_and_decode_interleaving(self, dense, rng):
        cfg, params = dense
        layout = kvc.layout_for(cfg, 2, MAX_SEQ, kv_format="bf16")
        budget = 4
        sched = Scheduler(params, cfg, layout, admission="chunked",
                          chunk_budget=budget)
        short = Request(
            rid=0, prompt=rng.integers(0, cfg.vocab_size, (5,))
            .astype(np.int32), max_new_tokens=6,
        )
        long = Request(
            rid=1, prompt=rng.integers(0, cfg.vocab_size, (33,))
            .astype(np.int32), max_new_tokens=2, arrival_step=2,
        )
        sched.submit(short)
        sched.submit(long)
        sched.run(max_steps=500)
        assert len(sched.finished) == 2
        assert max(sched.prefill_tokens_per_step) <= budget
        # the 33-token prompt needs ceil(33/4) chunk steps; the short
        # request must keep decoding through them, not stall
        prefill_steps = long.first_token_step - long.admitted_step
        assert prefill_steps >= 33 // budget
        assert short.finished_step < long.first_token_step
        assert all(s.state is SlotState.EMPTY for s in sched.slots)

    def test_whole_prompt_budget_admits_in_one_step(self, dense, rng):
        cfg, params = dense
        layout = kvc.layout_for(cfg, 2, MAX_SEQ, kv_format="bf16")
        sched = Scheduler(params, cfg, layout, admission="chunked",
                          chunk_budget=32)
        sched.submit(Request(
            rid=0, prompt=rng.integers(0, cfg.vocab_size, (20,))
            .astype(np.int32), max_new_tokens=3,
        ))
        sched.run(max_steps=100)
        (req,) = sched.finished
        assert req.first_token_step == req.admitted_step
