"""BRCR GEMM kernel vs oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.brcr_gemm import brcr_gemm, prepare_brcr_operands
from repro.kernels.brcr_gemm.ref import brcr_gemm_ref, dense_ref
from repro.utils.synthetic import synthetic_llm_weight_int8

jax.config.update("jax_platform_name", "cpu")


def run_case(rng, M, H, N, m=4, x_int=True, tiles=(128, 256, 128)):
    w_q, _ = synthetic_llm_weight_int8(rng, (M, H))
    if x_int:
        x = jnp.asarray(rng.integers(-50, 50, size=(H, N)), jnp.float32)
    else:
        x = jnp.asarray(rng.normal(size=(H, N)), jnp.float32)
    ops = prepare_brcr_operands(w_q, m=m)
    tm, tk, tn = tiles
    y = brcr_gemm(
        ops, x, tile_m=min(tm, M), tile_k=min(tk, H), tile_n=min(tn, N),
        interpret=True,
    )
    ref = dense_ref(jnp.asarray(w_q), x)
    return np.asarray(y), np.asarray(ref), ops, x


class TestBRCRKernel:
    @pytest.mark.parametrize(
        "M,H,N",
        [(8, 128, 8), (16, 256, 16), (32, 512, 8), (128, 256, 128)],
    )
    def test_matches_dense_int_inputs(self, M, H, N):
        rng = np.random.default_rng(M + H + N)
        y, ref, _, _ = run_case(rng, M, H, N, tiles=(8, 128, 8))
        np.testing.assert_allclose(y, ref, rtol=0, atol=0)

    @pytest.mark.parametrize("m", [2, 4])
    def test_group_sizes(self, m):
        rng = np.random.default_rng(m)
        y, ref, _, _ = run_case(rng, 16, 128, 8, m=m, tiles=(16, 128, 8))
        np.testing.assert_allclose(y, ref, rtol=0, atol=0)

    def test_float_activations_close(self):
        rng = np.random.default_rng(7)
        y, ref, _, _ = run_case(
            rng, 16, 256, 8, x_int=False, tiles=(16, 128, 8)
        )
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-2)

    def test_matches_factorization_oracle(self):
        rng = np.random.default_rng(9)
        _, _, ops, x = run_case(rng, 16, 128, 8, tiles=(16, 128, 8))
        ref2 = brcr_gemm_ref(ops.group_idx, ops.plane_weights, x, ops.m)
        ref1 = brcr_gemm(ops, x, tile_m=16, tile_k=128, tile_n=8, interpret=True)
        np.testing.assert_allclose(np.asarray(ref1), np.asarray(ref2), atol=1e-3)

    def test_n_padding(self):
        rng = np.random.default_rng(11)
        y, ref, _, _ = run_case(rng, 16, 128, 5, tiles=(16, 128, 8))
        assert y.shape == ref.shape == (16, 5)
        np.testing.assert_allclose(y, ref, atol=0)

    def test_multi_tile_grid(self):
        rng = np.random.default_rng(13)
        y, ref, _, _ = run_case(rng, 64, 512, 32, tiles=(32, 128, 16))
        np.testing.assert_allclose(y, ref, atol=0)

    def test_all_zero_weight_tiles_skipped_result_zero(self):
        # zero weights -> tile_any all zero -> output must still be exact (0)
        w_q = np.zeros((16, 128), np.int8)
        ops = prepare_brcr_operands(w_q)
        x = jnp.ones((128, 8), jnp.float32)
        y = brcr_gemm(ops, x, tile_m=16, tile_k=128, tile_n=8, interpret=True)
        np.testing.assert_array_equal(np.asarray(y), 0.0)
