"""Deterministic cancellation / preemption edge cases for the front door.

The random cancellation fuzz (tests/test_serving_fuzz.py) sweeps the state
space; these tests pin the specific corners the satellite checklist names:

  * cancel during the admission steps that register prefix-index entries —
    the index must not retain a dangling entry for the freed pages;
  * cancel a DONOR whose prompt pages a survivor prefix-shares — refcounts
    decrement without zeroing the shared pages (proven bit-exactly: the
    survivor's remaining decode reads that KV);
  * a higher-tier arrival preempts an in-progress chunked prefill, which
    later RESUMES at the exact frozen token offset (proven bit-exactly
    against an uncontended run);
  * state-aware eviction (the satellite bugfix): a PREFILLING cancel must
    not fabricate first-token/ITL bookkeeping, and a DECODING cancel must
    not land in the finished list;
  * SLO deadline shedding of queued requests.
"""

import json

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving.request import Request, SlotState
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

MAX_SEQ = 64
PAGE_SIZE = 8
CHUNK_BUDGET = 6


@pytest.fixture(scope="module")
def served():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    params, _ = model_zoo.init(jax.random.key(0), cfg)
    return cfg, params


def make_sched(cfg, params, slots=2, layout="paged", shared=None,
               record_logits=False):
    lay = kvc.layout_for(cfg, slots, MAX_SEQ, kv_format="bf16",
                         layout=layout, page_size=PAGE_SIZE)
    return Scheduler(params, cfg, lay, admission="chunked",
                     chunk_budget=CHUNK_BUDGET, record_logits=record_logits,
                     shared_fns=shared)


def prompt_of(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)


class TestCancelDuringPrefixRegistration:
    def test_no_dangling_index_entry(self, served):
        """Cancel mid-chunked-prefill AFTER page boundaries were indexed:
        the freed pages must prune their index entries, so an identical
        later prompt gets no (stale) prefix hit and still runs clean."""
        cfg, params = served
        rng = np.random.default_rng(0)
        sched = make_sched(cfg, params)
        prompt = prompt_of(rng, cfg, 18)
        sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
        sched.step()
        sched.step()  # prefill_pos = 12 -> page 0 (tokens 0..7) is indexed
        slot = sched.slots[0]
        assert slot.state is SlotState.PREFILLING and slot.prefill_pos == 12
        assert sched.pager.lookup_prefix(prompt)[0] == 8

        assert sched.cancel(0)
        sched.pager.check()
        assert sched.pager.pages_in_use == 0, "cancel leaked prefill pages"
        assert sched.pager.lookup_prefix(prompt) == (0, ()), (
            "prefix index retained a dangling entry for freed pages"
        )
        # an identical prompt admitted now must prefill from scratch
        sched.submit(Request(rid=1, prompt=prompt, max_new_tokens=2))
        sched.run(max_steps=100)
        assert len(sched.finished) == 1
        assert sched.prefix_hits == 0
        sched.pager.check()
        assert sched.pager.pages_in_use == 0

    def test_cancel_between_every_chunk_step(self, served):
        """Sweep the cancel point across every prefill chunk boundary —
        each point must drain the pool completely."""
        cfg, params = served
        rng = np.random.default_rng(1)
        prompt = prompt_of(rng, cfg, 20)
        for steps in range(1, 5):
            sched = make_sched(cfg, params)
            sched.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
            for _ in range(steps):
                sched.step()
            sched.cancel(0)
            sched.pager.check()
            assert sched.pager.pages_in_use == 0, f"leak at chunk {steps}"


class TestCancelSharedPrefixDonor:
    def test_survivor_keeps_shared_pages(self, served):
        """rid 0 prefills a 32-token system prompt and keeps decoding;
        rid 1 adopts those 4 pages via the prefix index; rid 0 is then
        cancelled.  The shared pages must drop to refcount 1 WITHOUT
        being zeroed — proven end-to-end: rid 1's remaining decode reads
        that KV and must stay bit-identical to an uncontended run."""
        cfg, params = served
        rng = np.random.default_rng(2)
        prefix = prompt_of(rng, cfg, 32)
        pa = np.concatenate([prefix, prompt_of(rng, cfg, 4)])
        pb = np.concatenate([prefix, prompt_of(rng, cfg, 3)])

        sched = make_sched(cfg, params, record_logits=True)
        sched.submit(Request(rid=0, prompt=pa, max_new_tokens=12))
        sched.submit(Request(rid=1, prompt=pb, max_new_tokens=6,
                             arrival_step=8))
        survivor = sched.queue[-1]
        for _ in range(100):
            sched.step()
            sched.pager.check()
            if survivor.prefix_reused_tokens:
                break
        assert survivor.prefix_reused_tokens == 32, "adoption never happened"
        shared = [int(p) for p in sched.pager.table[1, :4]]
        assert all(sched.pager.refcount[p] == 2 for p in shared)

        assert sched.cancel(0)
        sched.pager.check()
        assert all(sched.pager.refcount[p] == 1 for p in shared), (
            "donor cancel must decref shared pages, not free them"
        )
        assert all(int(sched.pager.table[1, i]) == p
                   for i, p in enumerate(shared)), "survivor lost its pages"
        while sched.num_pending:
            sched.step()
            sched.pager.check()
        assert [r.rid for r in sched.finished] == [1]
        assert sched.pager.pages_in_use == 0

        # uncontended reference: same request alone on the same layout
        alone = make_sched(cfg, params, shared=sched.shared_fns(),
                           record_logits=True)
        alone.submit(Request(rid=1, prompt=pb, max_new_tokens=6))
        alone.run(max_steps=100)
        want = alone.finished[0]
        got = sched.finished[0]
        assert got.generated == want.generated
        for t, (g, w) in enumerate(zip(got.logit_rows, want.logit_rows)):
            assert np.array_equal(g, w), (
                f"token {t}: shared pages were perturbed by the donor cancel"
            )


class TestPreemptThenResume:
    def test_batch_prefill_resumes_at_frozen_offset(self, served):
        """A batch-tier 20-token prompt starts chunking; an interactive
        arrival steals the chunk budget (preemption) and the batch
        prefill's offset freezes; once the interactive prompt finishes
        prefilling, the batch one resumes AT THAT OFFSET — proven by
        bit-exact logits vs an uncontended run of the same request."""
        cfg, params = served
        rng = np.random.default_rng(3)
        long_prompt = prompt_of(rng, cfg, 20)
        sched = make_sched(cfg, params, record_logits=True)
        batch_req = Request(rid=0, prompt=long_prompt, max_new_tokens=4,
                            priority="batch")
        inter_req = Request(rid=1, prompt=prompt_of(rng, cfg, 8),
                            max_new_tokens=3, priority="interactive",
                            arrival_step=1)
        sched.submit(batch_req)
        sched.submit(inter_req)

        sched.step()  # batch slot advances to 6
        assert sched.slots[0].prefill_pos == 6
        frozen = []
        while inter_req.first_token_step < 0:
            sched.step()
            if sched.slots[1].state is SlotState.PREFILLING:
                frozen.append(sched.slots[0].prefill_pos)
        # every step the interactive prompt chunked, the batch offset froze
        assert frozen and all(p == 6 for p in frozen)
        assert sched.preemptions >= 1
        assert batch_req.preemptions >= 1
        sched.run(max_steps=200)
        assert len(sched.finished) == 2
        assert inter_req.first_token_step < batch_req.first_token_step

        alone = make_sched(cfg, params, shared=sched.shared_fns(),
                           record_logits=True)
        alone.submit(Request(rid=0, prompt=long_prompt, max_new_tokens=4,
                             priority="batch"))
        alone.run(max_steps=100)
        want = alone.finished[0]
        assert batch_req.generated == want.generated
        for t, (g, w) in enumerate(zip(batch_req.logit_rows,
                                       want.logit_rows)):
            assert np.array_equal(g, w), (
                f"token {t}: preempted prefill resumed at a wrong offset"
            )
        # per-tier columns carry the preemption + both tiers' latencies
        tiers = sched.stats()["tiers"]
        assert tiers["batch"]["preemptions"] >= 1
        assert tiers["interactive"]["ttft_s"]["p50"] is not None

    def test_priority_jumps_admission_queue(self, served):
        """With one slot busy, a later interactive arrival must be
        admitted before earlier-queued batch requests."""
        cfg, params = served
        rng = np.random.default_rng(4)
        sched = make_sched(cfg, params, slots=1)
        sched.submit(Request(rid=0, prompt=prompt_of(rng, cfg, 6),
                             max_new_tokens=8, priority="batch"))
        sched.submit(Request(rid=1, prompt=prompt_of(rng, cfg, 6),
                             max_new_tokens=2, priority="batch",
                             arrival_step=1))
        sched.submit(Request(rid=2, prompt=prompt_of(rng, cfg, 6),
                             max_new_tokens=2, priority="interactive",
                             arrival_step=2))
        sched.run(max_steps=200)
        by_rid = {r.rid: r for r in sched.finished}
        assert by_rid[2].admitted_step < by_rid[1].admitted_step


class TestStateAwareEviction:
    def test_prefilling_cancel_records_no_latency(self, served):
        """The satellite bugfix: evicting a PREFILLING slot must not run
        the DONE path's bookkeeping — no first-token timestamp, no ITL
        rows, no finished entry — while still freeing its pages."""
        cfg, params = served
        rng = np.random.default_rng(5)
        sched = make_sched(cfg, params)
        req = Request(rid=0, prompt=prompt_of(rng, cfg, 18),
                      max_new_tokens=4)
        sched.submit(req)
        sched.step()
        assert sched.slots[0].state is SlotState.PREFILLING
        assert sched.cancel(0)
        assert req.cancelled and req.cancel_state == "prefilling"
        assert req.first_token_step == -1 and req.first_token_time < 0
        assert req.token_times == [] and req.finished_step == -1
        assert sched.finished == [] and sched.cancelled == [req]
        assert sched.pager.pages_in_use == 0
        stats = sched.stats()
        assert stats["requests"] == []  # no fabricated latency rows
        assert stats["cancelled_requests"] == 1
        assert stats["cancelled"][0]["cancel_state"] == "prefilling"
        assert stats["ttft_s"]["p50"] is None
        json.dumps(stats)

    def test_decoding_cancel_keeps_partial_tokens_out_of_finished(
            self, served):
        cfg, params = served
        rng = np.random.default_rng(6)
        sched = make_sched(cfg, params)
        req = Request(rid=0, prompt=prompt_of(rng, cfg, 6),
                      max_new_tokens=32)
        sched.submit(req)
        while len(req.generated) < 2:
            sched.step()
        assert sched.cancel(0)
        assert req.cancel_state == "decoding"
        assert len(req.generated) >= 2  # streamed tokens stay with the req
        assert req.finished_step == -1 and sched.finished == []
        assert sched.pager.pages_in_use == 0
        rec = sched.stats()["cancelled"][0]
        assert rec["tokens_before_cancel"] == len(req.generated)

    def test_cancel_unknown_or_finished_is_false(self, served):
        cfg, params = served
        rng = np.random.default_rng(7)
        sched = make_sched(cfg, params)
        req = Request(rid=0, prompt=prompt_of(rng, cfg, 6),
                      max_new_tokens=2)
        sched.submit(req)
        sched.run(max_steps=100)
        assert len(sched.finished) == 1
        assert not sched.cancel(0)  # already finished
        assert not sched.cancel(99)  # never existed
        assert not sched.cancelled

    def test_slot_reusable_after_prefilling_cancel(self, served):
        """The evicted row must admit the next request cleanly (the
        logical-evict + reset-at-admission contract holds for cancels)."""
        cfg, params = served
        rng = np.random.default_rng(8)
        sched = make_sched(cfg, params, slots=1)
        sched.submit(Request(rid=0, prompt=prompt_of(rng, cfg, 18),
                             max_new_tokens=4))
        sched.step()
        sched.cancel(0)
        sched.submit(Request(rid=1, prompt=prompt_of(rng, cfg, 9),
                             max_new_tokens=3))
        sched.run(max_steps=100)
        assert [r.rid for r in sched.finished] == [1]
        assert len(sched.finished[0].generated) == 3


class TestDeadlineShedding:
    def test_queued_past_deadline_is_shed(self, served):
        """SLO-aware admission: a queued request whose deadline lapses is
        shed (never admitted), while the slotless wait of one WITHIN its
        deadline still ends in admission."""
        cfg, params = served
        rng = np.random.default_rng(9)
        sched = make_sched(cfg, params, slots=1)
        sched.submit(Request(rid=0, prompt=prompt_of(rng, cfg, 6),
                             max_new_tokens=12))
        sched.submit(Request(rid=1, prompt=prompt_of(rng, cfg, 6),
                             max_new_tokens=2, deadline_steps=3))
        sched.submit(Request(rid=2, prompt=prompt_of(rng, cfg, 6),
                             max_new_tokens=2, deadline_steps=200))
        stats = sched.run(max_steps=300)
        assert [r.rid for r in sorted(sched.finished,
                                      key=lambda r: r.rid)] == [0, 2]
        (shed,) = sched.cancelled
        assert shed.rid == 1 and shed.shed
        assert shed.cancel_state == "queued" and shed.admitted_step == -1
        assert stats["shed_requests"] == 1
        assert stats["tiers"]["interactive"]["shed"] == 1
        assert sched.pager.pages_in_use == 0
