"""BSTC decode + fused matmul kernels vs oracles (interpret mode sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bstc
from repro.kernels.bstc_decode import bstc_decode_patterns, prepare_encoded_plane
from repro.kernels.bstc_decode.ref import decode_patterns_ref
from repro.kernels.bstc_matmul import bstc_matmul, prepare_bstc_matmul_operands
from repro.kernels.bstc_matmul.ref import bstc_matmul_ref
from repro.utils.synthetic import synthetic_llm_weight_int8

jax.config.update("jax_platform_name", "cpu")


class TestBSTCDecodeKernel:
    @pytest.mark.parametrize("density", [0.02, 0.2, 0.7])
    @pytest.mark.parametrize("shape", [(16, 512), (32, 1024)])
    def test_decode_matches_encode(self, density, shape):
        rng = np.random.default_rng(int(density * 100) + shape[1])
        M, H = shape
        plane = (rng.random((M, H)) < density).astype(np.uint8)
        enc = bstc.encode_plane(plane, m=4)
        ops = prepare_encoded_plane(enc, tile_k=256)
        patt = np.asarray(bstc_decode_patterns(ops, tile_g=4, interpret=True))
        # oracle: reference prefix-sum decode of the padded representation
        ref = np.asarray(
            decode_patterns_ref(jnp.asarray(enc.bitmap), jnp.asarray(ops.patterns))
        )
        np.testing.assert_array_equal(patt, ref)
        # and the patterns expand back to the original plane
        grp = plane.reshape(M // 4, 4, H)
        want = (grp * (1 << np.arange(4))[None, :, None]).sum(1)
        np.testing.assert_array_equal(patt, want)

    def test_all_zero_plane(self):
        plane = np.zeros((8, 512), np.uint8)
        enc = bstc.encode_plane(plane, m=4)
        ops = prepare_encoded_plane(enc, tile_k=256)
        patt = np.asarray(bstc_decode_patterns(ops, interpret=True))
        np.testing.assert_array_equal(patt, 0)


class TestBSTCMatmulKernel:
    @pytest.mark.parametrize(
        "M,H,N", [(16, 512, 8), (32, 512, 16), (128, 1024, 128)]
    )
    def test_matches_dense(self, M, H, N):
        rng = np.random.default_rng(M + H + N)
        w_q, scale = synthetic_llm_weight_int8(rng, (M, H))
        x = jnp.asarray(rng.integers(-50, 50, size=(H, N)), jnp.float32)
        ops = prepare_bstc_matmul_operands(w_q, scale, tile_k=256)
        assert ops.enc_planes, "synthetic LLM weights must trigger compression"
        y = bstc_matmul(
            ops, x, tile_m=min(16, M), tile_n=min(8, N), interpret=True
        )
        ref = bstc_matmul_ref(jnp.asarray(w_q), x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=0)

    def test_scale_applied(self):
        rng = np.random.default_rng(0)
        w_q, scale = synthetic_llm_weight_int8(rng, (16, 512))
        x = jnp.asarray(rng.normal(size=(512, 8)), jnp.float32)
        ops = prepare_bstc_matmul_operands(w_q, scale, tile_k=256)
        y = bstc_matmul(ops, x, tile_m=16, tile_n=8, apply_scale=True, interpret=True)
        ref = bstc_matmul_ref(jnp.asarray(w_q), x, jnp.asarray(scale))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5)

    def test_compression_reduces_hbm_bytes(self):
        rng = np.random.default_rng(1)
        w_q, scale = synthetic_llm_weight_int8(rng, (128, 1024))
        ops = prepare_bstc_matmul_operands(w_q, scale)
        assert ops.hbm_bytes < ops.dense_bytes, (ops.hbm_bytes, ops.dense_bytes)

    def test_uniform_weights_all_raw_still_exact(self):
        rng = np.random.default_rng(2)
        w_q = rng.integers(-127, 128, size=(16, 512)).astype(np.int8)
        x = jnp.asarray(rng.integers(-20, 20, size=(512, 8)), jnp.float32)
        ops = prepare_bstc_matmul_operands(w_q, tile_k=256)
        y = bstc_matmul(ops, x, tile_m=16, tile_n=8, interpret=True)
        ref = bstc_matmul_ref(jnp.asarray(w_q), x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=0)
