"""Docs hygiene checks (stdlib-only, so the CI lint job can run this file
directly with ``python tests/test_docs.py`` before deps are installed).

Two gates:

* every repo-relative path referenced by ``docs/ARCHITECTURE.md`` exists —
  the doc is a map, and maps that point at moved modules are worse than no
  map;
* the public surfaces of ``src/repro/serving/`` carry docstrings — the
  ast-level mirror of the ruff ``D`` subset the lint job enforces
  (D100/D101/D102/D103/D105/D419), so the gate also runs on hosts without
  ruff.
"""

from __future__ import annotations

import ast
import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parent.parent
ARCH_DOC = REPO / "docs" / "ARCHITECTURE.md"
SERVING = REPO / "src" / "repro" / "serving"

# backtick-quoted repo paths: src/..., benchmarks/..., tests/...,
# examples/..., docs/... — with an optional trailing / for packages
_PATH_RE = re.compile(
    r"`((?:src|benchmarks|tests|examples|docs)/[A-Za-z0-9_./-]+?)/?`"
)


def _referenced_paths():
    return sorted(set(_PATH_RE.findall(ARCH_DOC.read_text())))


def check_architecture_paths():
    """Every path ARCHITECTURE.md references must exist in the repo."""
    assert ARCH_DOC.exists(), "docs/ARCHITECTURE.md is missing"
    paths = _referenced_paths()
    assert len(paths) >= 20, (
        f"suspiciously few path references parsed ({len(paths)}) — did the "
        f"doc format change under the regex?"
    )
    missing = [p for p in paths if not (REPO / p).exists()]
    assert not missing, (
        f"docs/ARCHITECTURE.md references paths that do not exist: {missing}"
    )


def _missing_docstrings(path: pathlib.Path):
    """Public surfaces of one module lacking docstrings (ruff-D mirror:
    module, public classes, public functions/methods, non-empty)."""
    tree = ast.parse(path.read_text())
    missing = []
    if not (ast.get_docstring(tree) or "").strip():
        missing.append(f"{path.name}: module")

    def walk(node, prefix=""):
        for n in ast.iter_child_nodes(node):
            if isinstance(n, ast.ClassDef) and not n.name.startswith("_"):
                if not (ast.get_docstring(n) or "").strip():
                    missing.append(f"{path.name}: class {prefix}{n.name}")
                walk(n, prefix + n.name + ".")
            elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                public = not n.name.startswith("_") or (
                    n.name.startswith("__") and n.name.endswith("__")
                    and n.name != "__init__"
                )
                if public and not (ast.get_docstring(n) or "").strip():
                    missing.append(f"{path.name}: def {prefix}{n.name}")

    walk(tree)
    return missing


def check_serving_docstrings():
    """The serving package's public surfaces must all carry docstrings."""
    missing = []
    for f in sorted(SERVING.glob("*.py")):
        missing += _missing_docstrings(f)
    assert not missing, (
        "public serving surfaces without docstrings (the layout/legality "
        "contracts live there — see ISSUE 5 satellite): " + "; ".join(missing)
    )


# pytest entry points
def test_architecture_doc_paths_exist():
    check_architecture_paths()


def test_serving_public_surfaces_documented():
    check_serving_docstrings()


if __name__ == "__main__":
    check_architecture_paths()
    check_serving_docstrings()
    print(f"docs checks OK ({len(_referenced_paths())} referenced paths, "
          f"{len(list(SERVING.glob('*.py')))} serving modules)")
