"""Unit + property tests for bit-slice decomposition and quantization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypcompat import given, settings, st

from repro.core import bitslice, quantization

jax.config.update("jax_platform_name", "cpu")


def rand_int8(rng, shape, lo=-127, hi=127):
    return jnp.asarray(rng.integers(lo, hi + 1, size=shape, dtype=np.int64), jnp.int8)


class TestSignMagnitude:
    def test_roundtrip(self):
        rng = np.random.default_rng(0)
        w = rand_int8(rng, (16, 32))
        s, m = bitslice.to_sign_magnitude(w)
        np.testing.assert_array_equal(
            np.asarray(bitslice.from_sign_magnitude(s, m)), np.asarray(w, np.int32)
        )

    def test_planes_roundtrip(self):
        rng = np.random.default_rng(1)
        mag = jnp.asarray(rng.integers(0, 128, size=(8, 24)), jnp.uint8)
        planes = bitslice.bitplanes(mag)
        assert planes.shape == (7, 8, 24)
        np.testing.assert_array_equal(
            np.asarray(bitslice.from_bitplanes(planes)), np.asarray(mag, np.int32)
        )

    @given(st.integers(min_value=-127, max_value=127))
    @settings(max_examples=50, deadline=None)
    def test_scalar_roundtrip(self, v):
        w = jnp.asarray([[v]], jnp.int8)
        s, m = bitslice.to_sign_magnitude(w)
        planes = bitslice.bitplanes(m)
        rec = bitslice.from_sign_magnitude(s, bitslice.from_bitplanes(planes))
        assert int(rec[0, 0]) == v

    def test_signed_split_disjoint(self):
        rng = np.random.default_rng(2)
        w = rand_int8(rng, (8, 16))
        pos, neg = bitslice.signed_plane_split(w)
        assert int(jnp.max(pos * neg)) == 0  # disjoint support
        np.testing.assert_array_equal(np.asarray(pos - neg), np.asarray(w, np.int32))


class TestBitPacking:
    def test_pack_unpack(self):
        rng = np.random.default_rng(3)
        bits = jnp.asarray(rng.integers(0, 2, size=(5, 7, 64)), jnp.uint8)
        packed = bitslice.pack_bits(bits, axis=-1)
        assert packed.shape == (5, 7, 8)
        np.testing.assert_array_equal(
            np.asarray(bitslice.unpack_bits(packed, axis=-1)), np.asarray(bits)
        )

    def test_pack_other_axis(self):
        rng = np.random.default_rng(4)
        bits = jnp.asarray(rng.integers(0, 2, size=(16, 3)), jnp.uint8)
        packed = bitslice.pack_bits(bits, axis=0)
        assert packed.shape == (2, 3)
        np.testing.assert_array_equal(
            np.asarray(bitslice.unpack_bits(packed, axis=0)), np.asarray(bits)
        )

    def test_bad_length(self):
        with pytest.raises(ValueError):
            bitslice.pack_bits(jnp.zeros((5,), jnp.uint8))

    def test_bitplanar_tensor_roundtrip(self):
        rng = np.random.default_rng(5)
        w = rand_int8(rng, (4, 6, 16))
        bp = bitslice.BitPlanarTensor.from_int(w)
        np.testing.assert_array_equal(np.asarray(bp.to_int()), np.asarray(w, np.int32))
        assert bp.mag_planes.shape == (7, 4, 6, 2)


class TestGrouping:
    def test_group_indices_values(self):
        # rows [1,0,1,1] (LSB=row0) in one column -> 1 + 4 + 8 = 13
        planes = jnp.asarray([[1], [0], [1], [1]], jnp.uint8)
        idx = bitslice.group_indices(planes, 4)
        assert idx.shape == (1, 1) and int(idx[0, 0]) == 13

    def test_enumeration_matrix(self):
        e = np.asarray(bitslice.enumeration_matrix(3))
        assert e.shape == (3, 8)
        for c in range(8):
            val = sum(int(e[j, c]) << j for j in range(3))
            assert val == c

    def test_sparsity_stats(self):
        planes = jnp.zeros((3, 4, 4), jnp.uint8).at[0].set(1)
        sp = np.asarray(bitslice.bit_sparsity(planes))
        np.testing.assert_allclose(sp, [0.0, 1.0, 1.0])


class TestQuantization:
    def test_weight_roundtrip_small_error(self):
        rng = np.random.default_rng(6)
        w = jnp.asarray(rng.normal(size=(32, 64)) * 0.1, jnp.float32)
        qw = quantization.quantize_weight(w)
        assert qw.q.dtype == jnp.int8
        err = np.abs(np.asarray(qw.dequantize()) - np.asarray(w))
        # max error bounded by scale/2 per channel
        bound = np.asarray(qw.scale)[:, None] * 0.5 + 1e-7
        assert (err <= bound).all()

    def test_activation_zero_exact(self):
        x = jnp.asarray([[0.0, 1.0, -3.0, 2.5]], jnp.float32)
        qa = quantization.quantize_activation(x)
        deq = np.asarray(qa.dequantize())
        assert abs(deq[0, 0]) < 1e-6  # zero stays exactly representable

    def test_quantized_linear_matches_float(self):
        rng = np.random.default_rng(7)
        w = jnp.asarray(rng.normal(size=(16, 32)) * 0.2, jnp.float32)
        x = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
        y_ref = w @ x
        y_q = quantization.quantized_linear(
            quantization.quantize_weight(w), quantization.quantize_activation(x)
        )
        rel = np.linalg.norm(np.asarray(y_q) - np.asarray(y_ref)) / np.linalg.norm(
            np.asarray(y_ref)
        )
        assert rel < 0.02, rel

    def test_int_matmul_exact(self):
        rng = np.random.default_rng(8)
        a = rand_int8(rng, (8, 16))
        b = rand_int8(rng, (16, 4))
        np.testing.assert_array_equal(
            np.asarray(quantization.int_matmul(a, b)),
            np.asarray(a, np.int64) @ np.asarray(b, np.int64),
        )

    @given(st.integers(min_value=0, max_value=2**32 - 1))
    @settings(max_examples=20, deadline=None)
    def test_weight_quant_error_bound_property(self, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
        _, rel = quantization.quantization_error(w)
        assert float(rel) < 0.02


class TestHighOrderPlaneSparsity:
    """The paper's core observation: LLM-like weights → sparse high planes.

    Uses the outlier-channel synthetic generator calibrated to the paper's
    Fig. 8(c) profile (see repro.utils.synthetic).
    """

    def test_llm_weights_high_plane_sparsity(self):
        from repro.utils.synthetic import synthetic_llm_weight

        rng = np.random.default_rng(9)
        w = synthetic_llm_weight(rng, (256, 256))
        qw = quantization.quantize_weight(jnp.asarray(w))
        _, mag = bitslice.to_sign_magnitude(qw.q)
        sp = np.asarray(bitslice.bit_sparsity(bitslice.bitplanes(mag)))
        # paper Fig. 8c: planes 3-7 (idx 2..6) all exceed 65% sparsity
        assert (sp[2:] > 0.55).all() and (sp[4:] > 0.65).all(), sp
        avg_bs = float(np.mean(sp))
        vs = float((np.asarray(qw.q) == 0).mean())
        assert avg_bs > 0.65  # paper: bs~ ≈ 0.70
        assert avg_bs > 5 * vs  # paper Fig. 5d: bit sparsity ~10x value sparsity
