"""Randomized admission-trace oracle for the continuous-batching scheduler.

N requests with random prompt lengths, arrival steps, and decode budgets
are driven through chunked admission (fixed-shape prefill chunks
interleaved with batched decode), then each request is re-run ALONE through
a slot-layout scheduler and compared token-for-token / logit-row-for-row:

  * bf16   — greedy decode, generated tokens AND per-token logits must be
             bit-identical (slot isolation + chunk determinism);
  * int8 / bgpp — teacher-forced continuations (so quantized near-tie
             argmax flips can't compound), per-token logits within 1e-5.

The joint run is parametrized over ``layout`` ∈ {slot, paged}: the paged
joint trace (pooled KV pages, page-table translation, prefix reuse) is
checked against *slot-layout* alone runs, which is the cross-layout
bit-exactness contract of the paged cache.  The shared-prefix tests force
prefix reuse (deterministic arrival overlap) and assert both that reuse
happened and that logits still match the slot oracle exactly.

The cancellation axis (TestCancellationFuzz) injects random mid-flight
cancels/disconnects into the joint run: ``PageAllocator.check()`` must
hold after every step, the pool must drain to zero pages, and every
SURVIVING request must still match its alone run to the same bars.

The spec_decode axis (TestSpecDecodeFuzz) runs the joint trace through
speculative decoding — random ``gamma``, random draft quality including
adversarially-wrong drafts — and compares against NON-speculative alone
runs: speculation may only change how many serve_steps were spent, never
a single token or logit bit, and every draft/verify/rollback round must
leave the page allocator clean (``check()`` between steps, zero pages
leaked at the end).

The seed comes from the ``rng_seed`` fixture (stable per test node id) and
can be pinned via ``REPRO_FUZZ_SEED`` — CI runs the kv-format × layout
matrix with a fixed seed; the nightly workflow runs the ``slow`` suite
with a date-derived seed and, on failure, uploads the JSON trace each
oracle dumps to ``REPRO_FUZZ_TRACE_DIR`` for offline replay.
"""

import contextlib
import dataclasses
import json
import math
import os

import numpy as np
import pytest

import jax

from repro.configs import apply_weight_format_override, get_config
from repro.configs.base import MCBPOptions
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving import sharded as shd
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

ARCHS = {"dense": "phi4-mini-3.8b", "swa": "gemma3-4b",
         # deepseek smoke is the sharding-parity arch: the only smoke dense
         # config whose 4 q/kv heads divide the 4-way "model" axis
         "mesh": "deepseek-7b"}
MAX_SEQ = 48
SLOTS = 2
# sharded parity runs 4 slots: at mesh (2, 4) the per-device attend then
# keeps b=2 — XLA CPU's attend lowering is only bit-stable against the
# single-device program while neither per-device leading dim collapses to
# (b=1 AND h=1), the mesh analogue of the fixed-batch-shape caveat on
# _compare_to_alone_runs
MESH_SLOTS = 4
MESHES = [(1, 1), (2, 1), (1, 4), (2, 4)]
PAGE_SIZE = 8
CHUNK_BUDGET = 6  # buckets (4, 6): lengths 3..20 hit off-bucket/exact/multi

_MODELS = {}


def _model(key):
    if key not in _MODELS:
        cfg = get_config(ARCHS[key], smoke=True)
        # keep-all BGPP: the progressive gather machinery runs but selects
        # every key, so the oracle isn't confounded by forced sparsity on
        # near-uniform random-init attention (same stance as test_serving)
        cfg = dataclasses.replace(
            cfg, mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=1.0)
        )
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        _MODELS[key] = (cfg, params)
    return _MODELS[key]


def _layout_for(cfg, kv_format, layout, slots=SLOTS):
    return kvc.layout_for(cfg, slots, MAX_SEQ, kv_format=kv_format,
                          layout=layout, page_size=PAGE_SIZE)


def _random_requests(rng, cfg, n, teacher_forced):
    reqs = []
    for rid in range(n):
        max_new = int(rng.integers(2, 6))
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(
                0, cfg.vocab_size, (int(rng.integers(3, 21)),)
            ).astype(np.int32),
            max_new_tokens=max_new,
            arrival_step=int(rng.integers(0, 9)),
            forced_tokens=rng.integers(0, cfg.vocab_size, (max_new,))
            .astype(np.int32) if teacher_forced else None,
        ))
    return reqs


def _clone(req, arrival_step):
    return Request(rid=req.rid, prompt=req.prompt,
                   max_new_tokens=req.max_new_tokens,
                   arrival_step=arrival_step,
                   forced_tokens=req.forced_tokens)


@contextlib.contextmanager
def _dump_failing_trace(meta, reqs):
    """On oracle failure, write a replayable JSON trace (prompts, budgets,
    arrivals, seed) to REPRO_FUZZ_TRACE_DIR — the nightly workflow uploads
    that directory as a run artifact."""
    try:
        yield
    except AssertionError:
        out_dir = os.environ.get("REPRO_FUZZ_TRACE_DIR")
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            trace = dict(meta)
            trace["requests"] = [{
                "rid": r.rid,
                "prompt": np.asarray(r.prompt).tolist(),
                "max_new_tokens": r.max_new_tokens,
                "arrival_step": r.arrival_step,
                "forced_tokens": None if r.forced_tokens is None
                else np.asarray(r.forced_tokens).tolist(),
            } for r in reqs]
            name = "-".join(str(v) for v in meta.values()) + ".json"
            with open(os.path.join(out_dir, name), "w") as f:
                json.dump(trace, f, indent=2)
        raise


def _run(cfg, params, layout, reqs, shared=None, admission="chunked",
         rules=None, sched_kw=None):
    kw = {} if rules is None else {"rules": rules}
    kw.update(sched_kw or {})
    sched = Scheduler(
        params, cfg, layout, admission=admission, chunk_budget=CHUNK_BUDGET,
        record_logits=True, shared_fns=shared,
        prefill_kw=dict(block_q=16, block_k=32) if admission == "eager" else None,
        **kw,
    )
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=2000)
    assert len(sched.finished) == len(reqs), "trace did not drain"
    if admission == "chunked":
        assert max(sched.prefill_tokens_per_step, default=0) <= CHUNK_BUDGET, (
            "chunk budget violated between decode steps"
        )
    if sched.pager is not None:
        sched.pager.check()
    # the kv-read counter must account exactly for the executed steps
    stats = sched.stats()
    kv = stats["kv_read"]
    assert kv["decode_bytes"] == kv["decode_steps"] * kv["decode_bytes_per_step"]
    # ... and so must the weight-read counter (same step count, static
    # per-step price from the serve-time weight plan)
    wr = stats["weight_read"]
    assert wr["decode_bytes"] == wr["decode_steps"] * wr["decode_bytes_per_step"]
    if layout.kv_format == "bgpp":
        assert kv["bgpp"]["full_rows_per_slot"] <= math.ceil(
            cfg.mcbp.bgpp_keep_ratio * layout.max_seq
        ), "bgpp decode may not fetch more full rows than the keep ratio"
    return sched, {r.rid: r for r in sched.finished}


def _compare_to_alone_runs(cfg, params, reqs, joint, arch_key, kv_format,
                           layout, joint_shared=None, slots=SLOTS,
                           admission="chunked", alone_kw=None):
    """Re-run each request alone on the SLOT layout and compare — the slot
    path is the oracle for both layouts.  ``joint_shared``: the joint
    scheduler's compiled fns, reusable only when the joint run itself was
    the slot layout.  ``slots`` must match the joint run's batch: XLA
    reductions are only bit-stable at a fixed batch shape.  ``admission``
    must match the joint run's too — eager (whole-forward) and chunked
    (cache-attend) prefills produce their first-token logits through
    different float paths, so each admission mode oracles against itself.
    ``alone_kw``: extra Scheduler kwargs for the alone runs — the spec
    axis pins them non-speculative regardless of REPRO_SPEC_DECODE."""
    exact = kv_format == "bf16"
    slot_layout = _layout_for(cfg, kv_format, "slot", slots=slots)
    shared = joint_shared
    for r in reqs:
        alone_sched, alone = _run(cfg, params, slot_layout, [_clone(r, 0)],
                                  shared=shared, admission=admission,
                                  sched_kw=alone_kw)
        shared = alone_sched.shared_fns()
        got, want = joint[r.rid], alone[r.rid]
        assert len(got.generated) == len(want.generated)
        assert len(got.logit_rows) == len(want.logit_rows)
        for t, (g, w) in enumerate(zip(got.logit_rows, want.logit_rows)):
            if exact:
                assert np.array_equal(g, w), (
                    f"{arch_key}/{kv_format}/{layout} rid {r.rid} token {t}: "
                    f"staggered logits not bit-identical to the slot-layout "
                    f"alone run (max |d| {np.max(np.abs(g - w))})"
                )
            else:
                err = float(np.max(np.abs(g - w)))
                assert err <= 1e-5, (
                    f"{arch_key}/{kv_format}/{layout} rid {r.rid} "
                    f"token {t}: |d|={err}"
                )
        if exact:
            assert got.generated == want.generated, (
                f"{arch_key}/{kv_format}/{layout} rid {r.rid}: greedy "
                f"tokens diverge"
            )


def _fuzz_oracle(arch_key, kv_format, seed, n_requests, layout="slot",
                 admission="chunked", weight_format=None):
    seed = int(os.environ.get("REPRO_FUZZ_SEED", seed))
    rng = np.random.default_rng(seed)
    cfg, params = _model(arch_key)
    if weight_format is not None:
        cfg = apply_weight_format_override(cfg, weight_format)
    reqs = _random_requests(rng, cfg, n_requests,
                            teacher_forced=kv_format != "bf16")
    meta = {"oracle": "fuzz", "arch": arch_key, "kv_format": kv_format,
            "layout": layout, "admission": admission, "seed": seed}
    if weight_format is not None:
        meta["weight_format"] = weight_format
    with _dump_failing_trace(meta, reqs):
        joint_sched, joint = _run(
            cfg, params, _layout_for(cfg, kv_format, layout),
            [_clone(r, r.arrival_step) for r in reqs],
            admission=admission,
        )
        _compare_to_alone_runs(
            cfg, params, reqs, joint, arch_key, kv_format, layout,
            joint_shared=joint_sched.shared_fns()
            if layout == "slot" else None,
            admission=admission,
        )


def _shared_prefix_oracle(kv_format, seed):
    """Deterministic arrival overlap on THREE slots: request 0 prefills a
    32-token system prompt (4 pages) and keeps decoding; requests 1/2
    arrive the SAME step while it is resident, so both are assigned slots
    together and one queues behind the other with its adoption pending —
    the regression shape for the batched decode's garbage writes (a
    waiting slot must hold no shared pages, or the donor's prompt KV gets
    corrupted at its device pos).  Both must adopt the pages AND still
    match the slot-layout alone runs exactly."""
    seed = int(os.environ.get("REPRO_FUZZ_SEED", seed))
    rng = np.random.default_rng(seed)
    cfg, params = _model("dense")
    teacher = kv_format != "bf16"
    prefix = rng.integers(0, cfg.vocab_size, (32,)).astype(np.int32)

    def req(rid, tail_len, max_new, arrival):
        return Request(
            rid=rid,
            prompt=np.concatenate([prefix, rng.integers(
                0, cfg.vocab_size, (tail_len,)).astype(np.int32)]),
            max_new_tokens=max_new,
            arrival_step=arrival,
            forced_tokens=rng.integers(0, cfg.vocab_size, (max_new,))
            .astype(np.int32) if teacher else None,
        )

    # rid 0: resident past step 8 (prefill ~6 steps + 10 decode steps);
    # rid 1/2 arrive together at step 8 with its 4 prompt pages registered
    reqs = [req(0, 4, 10, 0), req(1, 5, 4, 8), req(2, 3, 3, 8)]
    meta = {"oracle": "shared-prefix", "arch": "dense",
            "kv_format": kv_format, "layout": "paged", "seed": seed}
    with _dump_failing_trace(meta, reqs):
        # pinned non-speculative: the scenario's residency window assumes
        # one token per decode step (rid 0 must still hold its pages when
        # rid 2 advances); the spec × adoption interplay has its own
        # long-donor scenario in TestSpecDecodeFuzz
        joint_sched, joint = _run(
            cfg, params, _layout_for(cfg, kv_format, "paged", slots=3),
            [_clone(r, r.arrival_step) for r in reqs],
            sched_kw={"spec_decode": False},
        )
        assert joint_sched.prefix_hit_tokens >= 64, (
            f"both late requests must adopt the 32-token prefix: "
            f"{joint_sched.prefix_hit_tokens} tokens adopted"
        )
        _compare_to_alone_runs(cfg, params, reqs, joint, "dense", kv_format,
                               "paged", slots=3)


@pytest.mark.parametrize("layout", ["slot", "paged"])
class TestFuzzOracle:
    def test_dense_bf16(self, rng_seed, layout):
        _fuzz_oracle("dense", "bf16", rng_seed, 4, layout=layout)

    def test_dense_int8(self, rng_seed, layout):
        _fuzz_oracle("dense", "int8", rng_seed, 4, layout=layout)

    def test_dense_bgpp(self, rng_seed, layout):
        _fuzz_oracle("dense", "bgpp", rng_seed, 4, layout=layout)

    def test_dense_bgpp_eager(self, rng_seed, layout):
        # eager whole-prompt admission over the two-phase paged decode:
        # phase-1 selection sees KV written by the B=1 prefill path, and
        # the logits must still match slot-layout EAGER alone runs (each
        # admission mode oracles itself — eager and chunked prefill
        # produce first-token logits through different float paths)
        _fuzz_oracle("dense", "bgpp", rng_seed, 3, layout=layout,
                     admission="eager")

    @pytest.mark.slow
    def test_dense_bf16_eager(self, rng_seed, layout):
        _fuzz_oracle("dense", "bf16", rng_seed, 3, layout=layout,
                     admission="eager")

    def test_swa_bf16(self, rng_seed, layout):
        # gemma3 mixes ring + global stacks: paged pools behind the rings
        # (prefix reuse stays off — rings can't skip prefill)
        _fuzz_oracle("swa", "bf16", rng_seed, 4, layout=layout)

    @pytest.mark.slow
    def test_swa_int8(self, rng_seed, layout):
        _fuzz_oracle("swa", "int8", rng_seed, 4, layout=layout)

    @pytest.mark.slow
    def test_swa_bgpp(self, rng_seed, layout):
        _fuzz_oracle("swa", "bgpp", rng_seed, 4, layout=layout)

    @pytest.mark.slow
    def test_dense_bf16_heavy(self, rng_seed, layout):
        _fuzz_oracle("dense", "bf16", rng_seed + 1, 7, layout=layout)


@pytest.mark.parametrize("weight_format", ["int8", "bstc"])
class TestWeightFormatOracle:
    """weight_format axis of the fuzz matrix: the quantized serve-time
    weight path must be scheduling-invariant.  Joint staggered runs and
    alone runs derive IDENTICAL records from the same raw params, so with
    bf16 KV every logit row is bit-exact between them — any divergence
    means the weight path leaks scheduling state (slot order, admission
    interleaving) into the projections."""

    def test_dense_slot(self, rng_seed, weight_format):
        _fuzz_oracle("dense", "bf16", rng_seed, 4,
                     weight_format=weight_format)

    def test_dense_paged(self, rng_seed, weight_format):
        _fuzz_oracle("dense", "bf16", rng_seed, 4, layout="paged",
                     weight_format=weight_format)

    @pytest.mark.slow
    def test_dense_int8_kv(self, rng_seed, weight_format):
        # both axes quantized at once: int8 KV fuzz tolerance still holds
        # with the weight path quantized identically on both sides
        _fuzz_oracle("dense", "int8", rng_seed, 4,
                     weight_format=weight_format)

    @pytest.mark.slow
    def test_swa_slot(self, rng_seed, weight_format):
        _fuzz_oracle("swa", "bf16", rng_seed, 4,
                     weight_format=weight_format)


# --------------------------------------------------------------------------
# spec_decode axis: speculative greedy must be BIT-identical to non-spec
# --------------------------------------------------------------------------


def _run_spec(cfg, params, layout, reqs, sched_kw, admission="chunked"):
    """Joint speculative run with the leak gates armed between steps:
    ``PageAllocator.check()`` after EVERY draft/verify/rollback round, a
    fully drained pool at the end, and the byte-accounting laws intact
    (every physical serve_step — draft or verify — pays the full static
    per-step price)."""
    sched = Scheduler(params, cfg, layout, admission=admission,
                      chunk_budget=CHUNK_BUDGET, record_logits=True,
                      prefill_kw=dict(block_q=16, block_k=32)
                      if admission == "eager" else None,
                      **sched_kw)
    assert sched.spec.enabled, "spec axis requires an enabled scheduler"
    for r in reqs:
        sched.submit(r)
    for _ in range(2000):
        if not sched.num_pending:
            break
        sched.step()
        if sched.pager is not None:
            sched.pager.check()
    assert not sched.num_pending, "trace did not drain"
    assert len(sched.finished) == len(reqs)
    stats = sched.stats()
    kv, wr, sp = stats["kv_read"], stats["weight_read"], stats["spec"]
    assert kv["decode_bytes"] == kv["decode_steps"] * kv["decode_bytes_per_step"]
    assert wr["decode_bytes"] == wr["decode_steps"] * wr["decode_bytes_per_step"]
    assert sp["rounds"] > 0, "the trace never actually speculated"
    assert sp["accepted_tokens"] == stats["decoded_tokens"]
    if sched.pager is not None:
        sched.pager.check()
        assert sched.pager.pages_in_use == 0, "spec rollback leaked pages"
    return sched, {r.rid: r for r in sched.finished}


def _spec_fuzz_oracle(arch_key, kv_format, seed, n_requests, layout,
                      draft="planes", admission="chunked"):
    """The tentpole oracle: a speculative joint run (random gamma, random
    draft quality — truncated planes, perfect, or adversarially wrong) is
    compared against NON-speculative slot-layout alone runs, to the same
    bars as the base oracle (bit-exact bf16 / 1e-5 teacher-forced).
    Wrong drafts may only cost steps, never change a single logit."""
    seed = int(os.environ.get("REPRO_FUZZ_SEED", seed))
    rng = np.random.default_rng(seed)
    cfg, params = _model(arch_key)
    teacher = kv_format != "bf16"
    reqs = _random_requests(rng, cfg, n_requests, teacher_forced=teacher)
    gamma = int(rng.integers(1, 5))
    sched_kw = {"spec_decode": True, "draft_gamma": gamma}
    if draft == "planes":
        # planes >= 7 makes the serve weights the (perfect) draft model,
        # so the random range also covers high-acceptance rounds
        sched_kw["draft_planes"] = int(rng.integers(1, 9))
    elif draft == "adversarial":
        drng = np.random.default_rng(seed + 1)
        sched_kw["draft_fn"] = \
            lambda req, t: int(drng.integers(0, cfg.vocab_size))
    elif draft == "perfect":
        assert teacher, "perfect drafts read the teacher-forced tail"
        sched_kw["draft_fn"] = lambda req, t: (
            int(req.forced_tokens[t]) if t < len(req.forced_tokens) else 0)
    else:
        raise ValueError(draft)
    meta = {"oracle": "spec-fuzz", "arch": arch_key, "kv_format": kv_format,
            "layout": layout, "draft": draft, "gamma": gamma,
            "planes": sched_kw.get("draft_planes", 0), "seed": seed,
            "admission": admission}
    with _dump_failing_trace(meta, reqs):
        joint_sched, joint = _run_spec(
            cfg, params, _layout_for(cfg, kv_format, layout),
            [_clone(r, r.arrival_step) for r in reqs], sched_kw,
            admission=admission)
        sp = joint_sched.stats()["spec"]
        if draft == "perfect":
            for r in joint.values():
                assert all(a == gamma + 1 for a in r.spec_accepts[:-1]), \
                    (r.rid, r.spec_accepts)
        for r in joint.values():
            assert all(1 <= a <= gamma + 1 for a in r.spec_accepts)
            assert sum(r.spec_accepts) == len(r.generated) - 1
        assert sp["drafted_tokens"] == gamma * joint_sched.spec_slot_rounds
        # the alone runs are pinned NON-speculative (kwarg beats any
        # REPRO_SPEC_DECODE in the environment): spec vs non-spec IS the
        # comparison, on top of joint-vs-alone scheduling invariance
        _compare_to_alone_runs(
            cfg, params, reqs, joint, arch_key, kv_format, layout,
            joint_shared=joint_sched.shared_fns()
            if layout == "slot" else None,
            admission=admission,
            alone_kw={"spec_decode": False},
        )


def _spec_prefix_oracle(seed):
    """Speculation over ADOPTED pages: request 0 prefills a 32-token
    prefix and keeps speculating long enough (24 decode tokens, so >= 6
    rounds even at full gamma+1 acceptance) that requests 1/2 arrive and
    adopt its prompt pages while its rollback path is live.  Every
    ``rewind_slot`` in the trace therefore runs against a pool holding
    shared pages — the frontier-sharing guard and the digest dereg must
    leave the adopted prefix intact, both late requests must hit it, and
    the logits must still match non-speculative alone runs exactly."""
    seed = int(os.environ.get("REPRO_FUZZ_SEED", seed))
    rng = np.random.default_rng(seed)
    cfg, params = _model("dense")
    prefix = rng.integers(0, cfg.vocab_size, (32,)).astype(np.int32)

    def req(rid, tail_len, max_new, arrival):
        return Request(
            rid=rid,
            prompt=np.concatenate([prefix, rng.integers(
                0, cfg.vocab_size, (tail_len,)).astype(np.int32)]),
            max_new_tokens=max_new,
            arrival_step=arrival,
        )

    reqs = [req(0, 4, 24, 0), req(1, 5, 4, 8), req(2, 3, 3, 8)]
    sched_kw = {"spec_decode": True, "draft_gamma": 3, "draft_planes": 4}
    meta = {"oracle": "spec-prefix", "arch": "dense", "kv_format": "bf16",
            "layout": "paged", "draft": "planes", "gamma": 3, "planes": 4,
            "seed": seed}
    with _dump_failing_trace(meta, reqs):
        joint_sched, joint = _run_spec(
            cfg, params, _layout_for(cfg, "bf16", "paged", slots=3),
            [_clone(r, r.arrival_step) for r in reqs], sched_kw)
        assert joint_sched.prefix_hit_tokens >= 64, (
            f"both late requests must adopt the 32-token prefix: "
            f"{joint_sched.prefix_hit_tokens} tokens adopted"
        )
        _compare_to_alone_runs(cfg, params, reqs, joint, "dense", "bf16",
                               "paged", slots=3,
                               alone_kw={"spec_decode": False})


@pytest.mark.parametrize("layout", ["slot", "paged"])
class TestSpecDecodeFuzz:
    """spec_decode axis of the fuzz matrix (tentpole acceptance): the
    speculative scheduler's output must be bit-identical to
    non-speculative greedy decode on bf16 (<= 1e-5 teacher-forced on
    int8/bgpp), across layouts and draft qualities, with the page
    allocator clean after every round and zero pages leaked."""

    def test_spec_dense_bf16_planes(self, rng_seed, layout):
        _spec_fuzz_oracle("dense", "bf16", rng_seed, 4, layout)

    def test_spec_dense_bf16_adversarial(self, rng_seed, layout):
        _spec_fuzz_oracle("dense", "bf16", rng_seed, 4, layout,
                          draft="adversarial")

    def test_spec_dense_int8_perfect(self, rng_seed, layout):
        _spec_fuzz_oracle("dense", "int8", rng_seed, 4, layout,
                          draft="perfect")

    def test_spec_dense_bgpp_adversarial(self, rng_seed, layout):
        _spec_fuzz_oracle("dense", "bgpp", rng_seed, 4, layout,
                          draft="adversarial")

    def test_spec_dense_bf16_eager(self, rng_seed, layout):
        # eager (whole-forward) admission: speculation only touches decode
        # rounds, so it must be transparent under either prefill path
        _spec_fuzz_oracle("dense", "bf16", rng_seed, 4, layout,
                          admission="eager")

    @pytest.mark.slow
    def test_spec_dense_int8_planes(self, rng_seed, layout):
        _spec_fuzz_oracle("dense", "int8", rng_seed, 4, layout)

    @pytest.mark.slow
    def test_spec_dense_bgpp_perfect(self, rng_seed, layout):
        _spec_fuzz_oracle("dense", "bgpp", rng_seed, 4, layout,
                          draft="perfect")

    @pytest.mark.slow
    def test_spec_dense_bf16_heavy(self, rng_seed, layout):
        _spec_fuzz_oracle("dense", "bf16", rng_seed + 1, 7, layout)


class TestSpecPrefixAdoption:
    """Rollback-heavy speculation while other slots share the donor's
    prompt pages (paged layout only — adoption is a page concept)."""

    def test_spec_prefix_reuse_paged_bf16(self, rng_seed):
        _spec_prefix_oracle(rng_seed)


# --------------------------------------------------------------------------
# sharding parity: identical traces at mesh 1x1 vs (data, model) shards
# --------------------------------------------------------------------------

_MESH_BASE = {}


def _mesh_base_run(kv_format, layout, seed):
    """The single-device joint trace every mesh compares against, cached per
    (format, layout, seed) — compiled fns are NEVER shared across rules."""
    key = (kv_format, layout, seed)
    if key not in _MESH_BASE:
        cfg, params = _model("mesh")
        rng = np.random.default_rng(seed)
        reqs = _random_requests(rng, cfg, 6,
                                teacher_forced=kv_format != "bf16")
        _, joint = _run(cfg, params,
                        _layout_for(cfg, kv_format, layout, slots=MESH_SLOTS),
                        [_clone(r, r.arrival_step) for r in reqs])
        _MESH_BASE[key] = (reqs, joint)
    return _MESH_BASE[key]


def _sharded_parity_oracle(kv_format, layout, mesh, seed,
                           check_alone_runs=False):
    """Run the SAME request trace through a (data, model)-meshed scheduler
    and through a single-device one, and demand the joint traces match —
    bit-exactly for bf16 caches, within 1e-5 for int8/bgpp (teacher-forced,
    as in the base oracle).  Also audits the mesh columns of the kv_read
    counter: interconnect bytes are zero exactly at 1x1, positive whenever
    the heads actually shard, and the per-device column recombines to the
    single-device total."""
    d, m = mesh
    if jax.device_count() < d * m:
        pytest.skip(f"mesh {d}x{m} needs {d * m} host devices; set "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    seed = int(os.environ.get("REPRO_FUZZ_SEED", seed))
    cfg, params = _model("mesh")
    exact = kv_format == "bf16"
    reqs, want = _mesh_base_run(kv_format, layout, seed)
    meta = {"oracle": "sharded-parity", "arch": "mesh",
            "kv_format": kv_format, "layout": layout,
            "mesh": f"{d}x{m}", "seed": seed}
    with _dump_failing_trace(meta, reqs):
        rules = shd.rules_for(d, m)
        sched, got = _run(
            cfg, params, _layout_for(cfg, kv_format, layout,
                                     slots=MESH_SLOTS),
            [_clone(r, r.arrival_step) for r in reqs], rules=rules,
        )
        for r in reqs:
            g, w = got[r.rid], want[r.rid]
            assert len(g.logit_rows) == len(w.logit_rows)
            for t, (a, b) in enumerate(zip(g.logit_rows, w.logit_rows)):
                if exact:
                    assert np.array_equal(a, b), (
                        f"{kv_format}/{layout}@{d}x{m} rid {r.rid} token "
                        f"{t}: sharded logits not bit-identical to the "
                        f"1x1 run (max |d| {np.max(np.abs(a - b))})"
                    )
                else:
                    err = float(np.max(np.abs(a - b)))
                    assert err <= 1e-5, (
                        f"{kv_format}/{layout}@{d}x{m} rid {r.rid} "
                        f"token {t}: |d|={err}"
                    )
            if exact:
                assert g.generated == w.generated, (
                    f"{kv_format}/{layout}@{d}x{m} rid {r.rid}: greedy "
                    f"tokens diverge under sharding"
                )
        kv = sched.stats()["kv_read"]
        assert kv["mesh"] == {"data": d, "model": m}
        per_dev = kv["decode_bytes_per_device_per_step"] * kv["kv_shards"]
        assert abs(per_dev - kv["decode_bytes_per_step"]) <= kv["kv_shards"]
        if (d, m) == (1, 1):
            assert kv["interconnect_bytes_per_step"] == 0
            assert kv["interconnect_bytes"] == 0
        elif m > 1:  # heads actually shard: the attend all-gather is priced
            assert kv["interconnect_bytes_per_step"] > 0
            assert kv["interconnect_bytes"] > 0
        if check_alone_runs:
            # the satellite contract: the SHARDED joint trace itself is
            # also pinned to single-device slot-layout alone runs
            _compare_to_alone_runs(cfg, params, reqs, got, "mesh",
                                   kv_format, layout, slots=MESH_SLOTS)


@pytest.mark.parametrize("layout", ["slot", "paged"])
class TestShardedParity:
    """Mesh (1,1)/(2,1)/(1,4)/(2,4) x layout x kv-format parity (tentpole
    acceptance).  Above-1x1 meshes skip unless the host exposes enough
    devices (the sharded-serving CI job forces 8)."""

    @pytest.mark.parametrize("mesh", MESHES,
                             ids=[f"{d}x{m}" for d, m in MESHES])
    def test_sharded_bf16(self, layout, mesh):
        _sharded_parity_oracle("bf16", layout, mesh, 0,
                               check_alone_runs=mesh == (2, 4))

    def test_sharded_int8_2x4(self, layout):
        _sharded_parity_oracle("int8", layout, (2, 4), 0)

    def test_sharded_bgpp_2x4(self, layout):
        _sharded_parity_oracle("bgpp", layout, (2, 4), 0)

    @pytest.mark.slow
    def test_sharded_int8_1x4(self, layout):
        _sharded_parity_oracle("int8", layout, (1, 4), 0)

    @pytest.mark.slow
    def test_sharded_bgpp_2x1(self, layout):
        _sharded_parity_oracle("bgpp", layout, (2, 1), 0)


# --------------------------------------------------------------------------
# cancellation axis: random disconnects must leak nothing and perturb nobody
# --------------------------------------------------------------------------


def _cancel_plan(rng, reqs):
    """Random cancel/disconnect plan over roughly half the trace.  Token
    triggers model a client hanging up after k streamed tokens (guaranteed
    to fire while the request is still DECODING when ``k <
    max_new_tokens``); step triggers land at arbitrary scheduler steps,
    catching requests queued, mid-chunked-prefill, decoding — or already
    gone (cancel() idempotence).  The first victim is always a token
    trigger so every plan produces at least one live cancel."""
    plan = {}
    victims = list(rng.permutation(len(reqs))[:max(1, len(reqs) // 2)])
    sure = max(range(len(reqs)), key=lambda i: reqs[i].max_new_tokens)
    if sure not in victims:
        victims[0] = sure
    for idx in victims:
        r = reqs[idx]
        if idx == sure or rng.random() < 0.5:
            # v <= max_new - 2: the prefill-completion step can bank TWO
            # tokens at once (first token + same-step decode), so two
            # tokens of headroom guarantee the cancel lands while live
            plan[r.rid] = ("tokens",
                           int(rng.integers(1, r.max_new_tokens - 1)))
        else:
            plan[r.rid] = ("step", int(rng.integers(1, 30)))
    return plan


def _run_with_cancels(cfg, params, layout, reqs, plan):
    """Joint chunked run with mid-flight cancels injected between steps —
    ``PageAllocator.check()`` after EVERY step is the leak gate, and the
    pool must fully drain once the trace ends."""
    sched = Scheduler(params, cfg, layout, admission="chunked",
                      chunk_budget=CHUNK_BUDGET, record_logits=True)
    by_rid = {r.rid: r for r in reqs}
    for r in reqs:
        sched.submit(r)
    pending = dict(plan)
    for _ in range(2000):
        if not sched.num_pending:
            break
        sched.step()
        for rid, (kind, v) in list(pending.items()):
            r = by_rid[rid]
            if ((kind == "tokens" and len(r.generated) >= v)
                    or (kind == "step" and sched.step_count >= v)):
                sched.cancel(rid)  # False when already finished: idempotent
                del pending[rid]
        if sched.pager is not None:
            sched.pager.check()
    assert not sched.num_pending, "trace did not drain"
    assert len(sched.finished) + len(sched.cancelled) == len(reqs)
    assert max(sched.prefill_tokens_per_step, default=0) <= CHUNK_BUDGET
    if sched.pager is not None:
        sched.pager.check()
        assert sched.pager.pages_in_use == 0, "cancellation leaked pages"
    return sched, {r.rid: r for r in sched.finished}


def _cancel_fuzz_oracle(arch_key, kv_format, seed, n_requests, layout):
    seed = int(os.environ.get("REPRO_FUZZ_SEED", seed))
    rng = np.random.default_rng(seed)
    cfg, params = _model(arch_key)
    reqs = _random_requests(rng, cfg, n_requests,
                            teacher_forced=kv_format != "bf16")
    for r in reqs:
        # the token-trigger guarantee in _cancel_plan needs >= 3 decode
        # tokens of budget; pad the teacher-forced tail to match
        if r.max_new_tokens < 3:
            extra = 3 - r.max_new_tokens
            r.max_new_tokens = 3
            if r.forced_tokens is not None:
                r.forced_tokens = np.concatenate([
                    r.forced_tokens,
                    rng.integers(0, cfg.vocab_size, (extra,))
                    .astype(np.int32),
                ])
    clones = [_clone(r, r.arrival_step) for r in reqs]
    for c in clones:  # stir priority scheduling into the fuzzed order too
        c.priority = "interactive" if rng.random() < 0.5 else "batch"
    plan = _cancel_plan(rng, clones)
    meta = {"oracle": "cancel-fuzz", "arch": arch_key,
            "kv_format": kv_format, "layout": layout, "seed": seed,
            "plan": ",".join(f"{r}@{k}{v}" for r, (k, v) in plan.items())}
    with _dump_failing_trace(meta, reqs):
        sched, joint = _run_with_cancels(
            cfg, params, _layout_for(cfg, kv_format, layout), clones, plan)
        assert len(sched.cancelled) >= 1, "plan produced no live cancel"
        survivors = [r for r in reqs if r.rid in joint]
        assert survivors, "every request was cancelled; nothing to oracle"
        _compare_to_alone_runs(cfg, params, survivors, joint, arch_key,
                               kv_format, layout)


@pytest.mark.parametrize("layout", ["slot", "paged"])
class TestCancellationFuzz:
    """Front-door cancellation axis of the fuzz matrix: random cancels and
    disconnects at arbitrary lifecycle points must leak zero pages and
    leave every surviving request's logits exactly what an alone run
    produces (bit-exact bf16 / 1e-5 teacher-forced elsewhere)."""

    def test_dense_bf16_cancel(self, rng_seed, layout):
        _cancel_fuzz_oracle("dense", "bf16", rng_seed, 5, layout)

    def test_dense_int8_cancel(self, rng_seed, layout):
        _cancel_fuzz_oracle("dense", "int8", rng_seed, 4, layout)

    @pytest.mark.slow
    def test_dense_bgpp_cancel(self, rng_seed, layout):
        _cancel_fuzz_oracle("dense", "bgpp", rng_seed, 4, layout)

    @pytest.mark.slow
    def test_swa_bf16_cancel(self, rng_seed, layout):
        _cancel_fuzz_oracle("swa", "bf16", rng_seed, 4, layout)

    @pytest.mark.slow
    def test_dense_bf16_cancel_heavy(self, rng_seed, layout):
        _cancel_fuzz_oracle("dense", "bf16", rng_seed + 1, 8, layout)


class TestSharedPrefixReuse:
    # "paged" in the names keys these into the paged half of the CI
    # kv-format × layout fuzz matrix
    def test_prefix_reuse_paged_bf16(self, rng_seed):
        _shared_prefix_oracle("bf16", rng_seed)

    @pytest.mark.slow
    def test_prefix_reuse_paged_int8(self, rng_seed):
        _shared_prefix_oracle("int8", rng_seed)

    @pytest.mark.slow
    def test_prefix_reuse_paged_bgpp(self, rng_seed):
        _shared_prefix_oracle("bgpp", rng_seed)
