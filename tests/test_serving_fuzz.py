"""Randomized admission-trace oracle for the continuous-batching scheduler.

N requests with random prompt lengths, arrival steps, and decode budgets
are driven through chunked admission (fixed-shape prefill chunks
interleaved with batched decode), then each request is re-run ALONE through
an identical scheduler and compared token-for-token / logit-row-for-row:

  * bf16   — greedy decode, generated tokens AND per-token logits must be
             bit-identical (slot isolation + chunk determinism);
  * int8 / bgpp — teacher-forced continuations (so quantized near-tie
             argmax flips can't compound), per-token logits within 1e-5.

The seed comes from the ``rng_seed`` fixture (stable per test node id) and
can be pinned via ``REPRO_FUZZ_SEED`` — CI runs the kv-format matrix with a
fixed seed.  Heavier traces sit behind the ``slow`` marker.
"""

import dataclasses
import os

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.configs.base import MCBPOptions
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

ARCHS = {"dense": "phi4-mini-3.8b", "swa": "gemma3-4b"}
MAX_SEQ = 48
SLOTS = 2
CHUNK_BUDGET = 6  # buckets (4, 6): lengths 3..20 hit off-bucket/exact/multi

_MODELS = {}


def _model(key):
    if key not in _MODELS:
        cfg = get_config(ARCHS[key], smoke=True)
        # keep-all BGPP: the progressive gather machinery runs but selects
        # every key, so the oracle isn't confounded by forced sparsity on
        # near-uniform random-init attention (same stance as test_serving)
        cfg = dataclasses.replace(
            cfg, mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=1.0)
        )
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        _MODELS[key] = (cfg, params)
    return _MODELS[key]


def _random_requests(rng, cfg, n, teacher_forced):
    reqs = []
    for rid in range(n):
        max_new = int(rng.integers(2, 6))
        reqs.append(Request(
            rid=rid,
            prompt=rng.integers(
                0, cfg.vocab_size, (int(rng.integers(3, 21)),)
            ).astype(np.int32),
            max_new_tokens=max_new,
            arrival_step=int(rng.integers(0, 9)),
            forced_tokens=rng.integers(0, cfg.vocab_size, (max_new,))
            .astype(np.int32) if teacher_forced else None,
        ))
    return reqs


def _clone(req, arrival_step):
    return Request(rid=req.rid, prompt=req.prompt,
                   max_new_tokens=req.max_new_tokens,
                   arrival_step=arrival_step,
                   forced_tokens=req.forced_tokens)


def _run(cfg, params, layout, reqs, shared=None):
    sched = Scheduler(
        params, cfg, layout, admission="chunked", chunk_budget=CHUNK_BUDGET,
        record_logits=True, shared_fns=shared,
    )
    for r in reqs:
        sched.submit(r)
    sched.run(max_steps=2000)
    assert len(sched.finished) == len(reqs), "trace did not drain"
    assert max(sched.prefill_tokens_per_step, default=0) <= CHUNK_BUDGET, (
        "chunk budget violated between decode steps"
    )
    return sched, {r.rid: r for r in sched.finished}


def _fuzz_oracle(arch_key, kv_format, seed, n_requests):
    seed = int(os.environ.get("REPRO_FUZZ_SEED", seed))
    rng = np.random.default_rng(seed)
    cfg, params = _model(arch_key)
    layout = kvc.layout_for(cfg, SLOTS, MAX_SEQ, kv_format=kv_format)
    exact = kv_format == "bf16"
    reqs = _random_requests(rng, cfg, n_requests, teacher_forced=not exact)

    joint_sched, joint = _run(
        cfg, params, layout, [_clone(r, r.arrival_step) for r in reqs]
    )
    shared = joint_sched.shared_fns()
    for r in reqs:
        _, alone = _run(cfg, params, layout, [_clone(r, 0)], shared=shared)
        got, want = joint[r.rid], alone[r.rid]
        assert len(got.generated) == len(want.generated)
        assert len(got.logit_rows) == len(want.logit_rows)
        for t, (g, w) in enumerate(zip(got.logit_rows, want.logit_rows)):
            if exact:
                assert np.array_equal(g, w), (
                    f"{arch_key}/{kv_format} rid {r.rid} token {t}: staggered "
                    f"logits not bit-identical to the alone run "
                    f"(max |d| {np.max(np.abs(g - w))})"
                )
            else:
                err = float(np.max(np.abs(g - w)))
                assert err <= 1e-5, (
                    f"{arch_key}/{kv_format} rid {r.rid} token {t}: |d|={err}"
                )
        if exact:
            assert got.generated == want.generated, (
                f"{arch_key}/{kv_format} rid {r.rid}: greedy tokens diverge"
            )


class TestFuzzOracle:
    def test_dense_bf16(self, rng_seed):
        _fuzz_oracle("dense", "bf16", rng_seed, n_requests=4)

    def test_dense_int8(self, rng_seed):
        _fuzz_oracle("dense", "int8", rng_seed, n_requests=4)

    def test_dense_bgpp(self, rng_seed):
        _fuzz_oracle("dense", "bgpp", rng_seed, n_requests=4)

    def test_swa_bf16(self, rng_seed):
        _fuzz_oracle("swa", "bf16", rng_seed, n_requests=4)

    @pytest.mark.slow
    def test_swa_int8(self, rng_seed):
        _fuzz_oracle("swa", "int8", rng_seed, n_requests=4)

    @pytest.mark.slow
    def test_swa_bgpp(self, rng_seed):
        _fuzz_oracle("swa", "bgpp", rng_seed, n_requests=4)

    @pytest.mark.slow
    def test_dense_bf16_heavy(self, rng_seed):
        _fuzz_oracle("dense", "bf16", rng_seed + 1, n_requests=7)
