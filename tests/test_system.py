"""End-to-end behaviour tests for the paper's system.

Covers the full MCBP pipeline (§ Fig. 6): offline BSTC weight compression →
load/decompress → BRCR GEMM; and the serving flow: prefill → BGPP-filtered
decode; plus the fault-tolerance story: checkpointed training survives a
simulated failure with exact data replay.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core import brcr, bstc
from repro.data import SyntheticLMDataset
from repro.distributed import sharding as sh
from repro.models import model_zoo
from repro.optim import AdamWConfig, adamw_init
from repro.runtime import run_resilient
from repro.serving import engine, kv_cache as kvc
from repro.training import make_train_step
from repro.utils.synthetic import synthetic_llm_weight_int8

jax.config.update("jax_platform_name", "cpu")


class TestMCBPPipeline:
    """Paper Fig. 6 execution flow: compress offline -> decompress -> BRCR."""

    def test_offline_compress_online_compute_exact(self):
        rng = np.random.default_rng(0)
        w_q, scale = synthetic_llm_weight_int8(rng, (32, 1024))
        # offline: BSTC-compress the weight (bit-slice-first storage)
        bw = bstc.encode_weight(w_q, scale)
        assert bw.compression_ratio > 1.0
        # online: decompress and run the BRCR GEMM
        w_dec = bstc.decode_weight(bw)
        x = jnp.asarray(rng.integers(-50, 50, size=(1024, 8)), jnp.int32)
        y = brcr.brcr_matmul(w_dec, x, m=4)
        ref = np.asarray(w_q, np.int64) @ np.asarray(x, np.int64)
        np.testing.assert_array_equal(np.asarray(y, np.int64), ref)

    def test_serving_with_full_mcbp_stack(self):
        """prefill -> BGPP bit-planar decode on a smoke model, finite logits
        and a growing cache position."""
        cfg = get_config("deepseek-7b", smoke=True)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        rng = np.random.default_rng(1)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
        layout = kvc.layout_for(cfg, 2, 48, kv_format="bgpp")
        logits, cache = engine.prefill(
            params, cfg, layout, prompts, block_q=8, block_k=8
        )
        step = jax.jit(engine.make_serve_step(cfg, layout))
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        for i in range(4):
            logits, cache = step(params, cache, cur)
            assert bool(jnp.isfinite(logits).all())
            cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        assert np.all(np.asarray(cache["pos"]) == 16 + 4)  # per-slot positions


class TestResilientTraining:
    def test_training_survives_failure_and_replays_data(self, tmp_path):
        cfg = get_config("deepseek-7b", smoke=True)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=2, weight_decay=0.0)
        step_fn = jax.jit(
            make_train_step(cfg, sh.ShardingRules(), opt_cfg,
                            fwd_kwargs=dict(block_q=16, block_k=16))
        )
        ds = SyntheticLMDataset(cfg.vocab_size, 16, 4, seed=0)
        ckpt = Checkpointer(str(tmp_path), keep=3, async_save=False)

        state = {"params": params, "opt": adamw_init(params, opt_cfg)}
        ckpt.save(0, state)
        holder = {"state": state}
        seen = []
        fail_once = {2}

        def train_one(step):
            if step in fail_once:
                fail_once.discard(step)
                raise RuntimeError("simulated preemption")
            batch = {k: jnp.asarray(v) for k, v in ds.batch(step).items()}
            holder["state"], m = step_fn(holder["state"], batch)
            seen.append(step)
            ckpt.save(step + 1, holder["state"])

        def restore():
            s, holder["state"] = ckpt.restore(holder["state"])
            return s

        failures = run_resilient(train_one, 0, 5, restore, max_failures=2)
        assert failures == 1
        assert seen == [0, 1, 2, 3, 4]  # exact replay after restore
        assert ckpt.latest_step() == 5

    def test_restored_state_bitwise_identical(self, tmp_path):
        """Determinism: (train 2 steps) == (train 1, checkpoint, restore,
        train 1) — the fault-tolerance correctness contract."""
        cfg = get_config("deepseek-7b", smoke=True)
        params, _ = model_zoo.init(jax.random.key(2), cfg)
        opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, weight_decay=0.0)
        step_fn = jax.jit(
            make_train_step(cfg, sh.ShardingRules(), opt_cfg,
                            fwd_kwargs=dict(block_q=16, block_k=16))
        )
        ds = SyntheticLMDataset(cfg.vocab_size, 16, 4, seed=7)
        batches = [
            {k: jnp.asarray(v) for k, v in ds.batch(i).items()} for i in range(2)
        ]

        s_direct = {"params": params, "opt": adamw_init(params, opt_cfg)}
        for b in batches:
            s_direct, _ = step_fn(s_direct, b)

        ckpt = Checkpointer(str(tmp_path), async_save=False)
        s2 = {"params": params, "opt": adamw_init(params, opt_cfg)}
        s2, _ = step_fn(s2, batches[0])
        ckpt.save(1, s2)
        _, s2r = ckpt.restore(s2)
        s2r, _ = step_fn(s2r, batches[1])

        for a, b in zip(jax.tree.leaves(s_direct), jax.tree.leaves(s2r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
