"""Unit + integration tests for bit-plane speculative decoding.

Covers the pieces under ``Scheduler._spec_round`` individually — knob
resolution (kwarg > env > config) and layout legality
(``repro.serving.spec_decode``), truncated-plane draft weights, the
cross-leaf token scrub (``kv_cache.zero_token_range``) — plus two
scheduler-level contracts:

  * ``forced_tokens`` teacher-forcing alone (no speculation) is
    bit-identical to free-running greedy decode fed the same tokens, on
    slot AND paged layouts — the verify chain's correctness rests on the
    forced path being a faithful replay channel;
  * speculative greedy decode is bit-identical to non-speculative greedy
    decode (the small deterministic version of the fuzz oracle's
    ``spec_decode`` axis in tests/test_serving_fuzz.py), with
    ``stats()["spec"]`` satisfying the accounting identities.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import apply_spec_decode_overrides, get_config
from repro.models import model_zoo
from repro.serving import kv_cache as kvc
from repro.serving import spec_decode as spd
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")

_MODELS = {}


def _model(arch="phi4-mini-3.8b"):
    if arch not in _MODELS:
        cfg = get_config(arch, smoke=True)
        params, _ = model_zoo.init(jax.random.key(0), cfg)
        _MODELS[arch] = (cfg, params)
    return _MODELS[arch]


def _requests(cfg, n=3, seed=0, max_new=(3, 8)):
    rng = np.random.default_rng(seed)
    return [Request(
        rid=i,
        prompt=rng.integers(
            0, cfg.vocab_size, (int(rng.integers(4, 18)),)
        ).astype(np.int32),
        max_new_tokens=int(rng.integers(*max_new)),
        arrival_step=3 * i,
    ) for i in range(n)]


def _drive(sched, reqs, check_pager=True):
    for r in reqs:
        sched.submit(r)
    for _ in range(1000):
        if not sched.num_pending:
            break
        sched.step()
        if check_pager and sched.pager is not None:
            sched.pager.check()
    assert not sched.num_pending, "trace did not drain"
    return {r.rid: r for r in sched.finished}


# --------------------------------------------------------------------------
# knob resolution and layout legality
# --------------------------------------------------------------------------


class TestResolveValidate:
    CFG = get_config("phi4-mini-3.8b", smoke=True)

    def test_defaults_off(self, monkeypatch):
        monkeypatch.delenv(spd.ENV_ENABLE, raising=False)
        spec = spd.resolve(self.CFG)
        assert not spec.enabled and spec.source == "config"
        assert spec.gamma == 4 and spec.planes == 4

    def test_env_beats_config(self, monkeypatch):
        monkeypatch.setenv(spd.ENV_ENABLE, "on")
        monkeypatch.setenv(spd.ENV_GAMMA, "2")
        monkeypatch.setenv(spd.ENV_PLANES, "6")
        spec = spd.resolve(self.CFG)
        assert spec.enabled and spec.source == "env"
        assert spec.gamma == 2 and spec.planes == 6

    def test_kwarg_beats_env(self, monkeypatch):
        # oracles pin spec per run regardless of the CI matrix env
        monkeypatch.setenv(spd.ENV_ENABLE, "on")
        monkeypatch.setenv(spd.ENV_GAMMA, "2")
        spec = spd.resolve(self.CFG, enabled=False, gamma=3)
        assert not spec.enabled and spec.source == "kwarg"
        assert spec.gamma == 3

    def test_config_override_helper(self):
        cfg = apply_spec_decode_overrides(
            self.CFG, enabled=True, gamma=2, planes=5)
        assert cfg.mcbp.spec_decode and cfg.mcbp.draft_gamma == 2
        assert cfg.mcbp.draft_planes == 5
        assert spd.resolve(cfg).enabled
        # None keeps the incoming config values
        same = apply_spec_decode_overrides(cfg)
        assert same.mcbp == cfg.mcbp

    def test_bad_env_is_loud(self, monkeypatch):
        monkeypatch.setenv(spd.ENV_ENABLE, "maybe")
        with pytest.raises(ValueError, match="not a boolean"):
            spd.resolve(self.CFG)

    @pytest.mark.parametrize("kw", [{"gamma": 0}, {"planes": 0},
                                    {"planes": 9}])
    def test_knob_validation(self, kw):
        with pytest.raises(ValueError, match="draft_"):
            spd.resolve(self.CFG, **kw)

    def test_env_enable_soft_disables_on_local_layers(self, monkeypatch):
        # nightly-matrix semantics: env=on means "speculative where
        # supported" — ring stacks run, just without speculation
        monkeypatch.setenv(spd.ENV_ENABLE, "on")
        cfg, _ = _model("gemma3-4b")
        layout = kvc.layout_for(cfg, 2, 32, kv_format="bf16")
        assert layout.local_layers
        spec = spd.validate(cfg, layout, spd.resolve(cfg))
        assert not spec.enabled

    def test_explicit_enable_on_local_layers_raises(self):
        cfg, _ = _model("gemma3-4b")
        layout = kvc.layout_for(cfg, 2, 32, kv_format="bf16")
        with pytest.raises(ValueError, match="rollback-safe"):
            spd.validate(cfg, layout, spd.resolve(cfg, enabled=True))


# --------------------------------------------------------------------------
# truncated-plane draft weights
# --------------------------------------------------------------------------


class TestTruncatePlaneParams:
    def _params(self):
        rng = np.random.default_rng(0)
        return {
            "w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16),
            "ids": jnp.arange(4, dtype=jnp.int32),
        }

    def test_planes_ge_7_is_identity(self):
        params = self._params()
        assert spd.truncate_plane_params(params, 7) is params
        assert spd.truncate_plane_params(params, 8) is params

    def test_structure_shapes_dtypes_preserved(self):
        params = self._params()
        out = spd.truncate_plane_params(params, 3)
        assert set(out) == set(params)
        for n in params:
            assert out[n].shape == params[n].shape, n
            assert out[n].dtype == params[n].dtype, n

    def test_int_leaves_untouched(self):
        params = self._params()
        out = spd.truncate_plane_params(params, 2)
        np.testing.assert_array_equal(np.asarray(out["ids"]),
                                      np.asarray(params["ids"]))

    def test_error_monotone_in_dropped_planes(self):
        params = self._params()
        w = np.asarray(params["w"])
        errs = [float(np.max(np.abs(
            np.asarray(spd.truncate_plane_params(params, p)["w"]) - w
        ))) for p in (1, 3, 6)]
        assert errs[0] >= errs[1] >= errs[2]
        assert errs[0] > 0  # one plane genuinely truncates
        # 6 of 7 magnitude bits: error bounded by the dropped LSB's weight
        scale = float(np.max(np.abs(w))) / 127.0
        assert errs[2] <= 2 * scale + scale  # quantization + 1 dropped bit

    def test_kept_values_are_plane_aligned(self):
        params = self._params()
        planes = 3
        out = np.asarray(spd.truncate_plane_params(params, planes)["w"])
        w = np.asarray(params["w"])
        scale = max(float(np.max(np.abs(w))), 1e-12) / 127.0
        q = np.abs(np.rint(out / scale)).astype(np.int64)
        # every surviving magnitude is a multiple of 2^(7-planes)
        assert np.all(q % (1 << (7 - planes)) == 0)


# --------------------------------------------------------------------------
# cross-leaf token scrub (the rollback's device half)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["bf16", "int8", "bgpp"])
class TestZeroTokenRange:
    def test_slot_layout_scrubs_every_leaf(self, fmt):
        cfg, _ = _model()
        layout = kvc.layout_for(cfg, 2, 32, kv_format=fmt)
        cache = kvc.init_cache_arrays(cfg, layout)
        filled = {n: jnp.full_like(a, 3) for n, a in cache["global"].items()}
        tpos = np.full((2, 3), kvc.OOB_INDEX, np.int32)
        tpos[0, :2] = [5, 9]
        tpos[1, 0] = 40  # >= max_seq: must drop, not wrap
        out = kvc.zero_token_range(dict(filled), jnp.asarray(tpos),
                                   max_seq=layout.max_seq)
        for n, a in out.items():
            arr = np.asarray(a)
            # slot stacks: (L, B, Hk, S, ...) with k_planes carrying an
            # extra leading NBITS dim -> batch at bdim, tokens at bdim + 2
            bdim = 2 if n == "k_planes" else 1
            tok = np.moveaxis(np.moveaxis(arr, bdim, 0),
                              bdim + 2, 1)  # (B, S, ...)
            assert np.all(tok[0, [5, 9]] == 0), f"{n}: target rows survive"
            keep = np.delete(tok[0], [5, 9], axis=0)
            assert np.all(keep == 3), f"{n}: slot 0 overreach"
            assert np.all(tok[1] == 3), f"{n}: OOB scrub leaked into slot 1"

    def test_paged_layout_scrubs_through_the_table(self, fmt):
        cfg, _ = _model()
        layout = kvc.layout_for(cfg, 2, 32, kv_format=fmt, layout="paged",
                                page_size=8)
        cache = kvc.init_cache_arrays(cfg, layout)
        filled = {n: jnp.full_like(a, 3) for n, a in cache["global"].items()}
        table = np.full((2, layout.pages_per_slot), -1, np.int32)
        table[0, 0], table[0, 1] = 2, 0  # logical pages 0,1 -> phys 2,0
        tpos = np.full((2, 4), kvc.OOB_INDEX, np.int32)
        # token 5 -> phys row 2*8+5; token 9 -> phys row 0*8+1;
        # token 21 maps to an unmapped page (pid -1): must drop
        tpos[0, :3] = [5, 9, 21]
        out = kvc.zero_token_range(
            dict(filled), jnp.asarray(tpos), page_table=jnp.asarray(table),
            page_size=layout.page_size, max_seq=layout.max_seq)
        zeroed = {2 * 8 + 5, 0 * 8 + 1}
        for n, a in out.items():
            tok = np.moveaxis(np.asarray(a), kvc._tok_dim(n), 0)
            for row in range(tok.shape[0]):
                want = 0 if row in zeroed else 3
                assert np.all(tok[row] == want), f"{n}: phys row {row}"


# --------------------------------------------------------------------------
# satellite: forced_tokens teacher-forcing == free-running decode
# --------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["slot", "paged"])
class TestForcedTokensTeacherForcing:
    """``forced_tokens`` must be a faithful replay channel: forcing the
    exact tokens a free-running greedy run produced yields bit-identical
    logits on every step, across layouts.  The speculative verify chain
    picks tokens through this same ``_pick_token`` path, so this is the
    spec oracle's foundation."""

    def test_forced_matches_free_running(self, layout):
        cfg, params = _model()
        lay = kvc.layout_for(cfg, 2, 48, kv_format="bf16", layout=layout,
                             page_size=8)
        reqs = _requests(cfg, n=3, seed=5)
        free_sched = Scheduler(params, cfg, lay, chunk_budget=6,
                               record_logits=True)
        free = _drive(free_sched, [Request(
            rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
            arrival_step=r.arrival_step) for r in reqs])
        forced_sched = Scheduler(params, cfg, lay, chunk_budget=6,
                                 record_logits=True,
                                 shared_fns=free_sched.shared_fns())
        forced = _drive(forced_sched, [Request(
            rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
            arrival_step=r.arrival_step,
            forced_tokens=np.asarray(free[r.rid].generated, np.int32),
        ) for r in reqs])
        for rid in free:
            assert forced[rid].generated == free[rid].generated
            assert len(forced[rid].logit_rows) == len(free[rid].logit_rows)
            for t, (a, b) in enumerate(zip(forced[rid].logit_rows,
                                           free[rid].logit_rows)):
                assert np.array_equal(a, b), (layout, rid, t)


# --------------------------------------------------------------------------
# scheduler-level speculative decode
# --------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["slot", "paged"])
class TestSpecSchedulerEquivalence:
    def _baseline(self, cfg, params, lay, reqs):
        sched = Scheduler(params, cfg, lay, chunk_budget=6,
                          record_logits=True, spec_decode=False)
        return sched, _drive(sched, [Request(
            rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
            arrival_step=r.arrival_step) for r in reqs])

    def test_spec_bit_identical_and_leak_free(self, layout):
        cfg, params = _model()
        lay = kvc.layout_for(cfg, 2, 48, kv_format="bf16", layout=layout,
                             page_size=8)
        reqs = _requests(cfg, n=3, seed=9)
        base_sched, want = self._baseline(cfg, params, lay, reqs)
        sched = Scheduler(params, cfg, lay, chunk_budget=6,
                          record_logits=True, spec_decode=True,
                          draft_gamma=2, draft_planes=4,
                          shared_fns=base_sched.shared_fns())
        got = _drive(sched, [Request(
            rid=r.rid, prompt=r.prompt, max_new_tokens=r.max_new_tokens,
            arrival_step=r.arrival_step) for r in reqs])
        for rid in want:
            assert got[rid].generated == want[rid].generated, (layout, rid)
            for t, (a, b) in enumerate(zip(got[rid].logit_rows,
                                           want[rid].logit_rows)):
                assert np.array_equal(a, b), (layout, rid, t)
        sp = sched.stats()["spec"]
        assert sp["rounds"] > 0 and sp["accepted_tokens"] > 0
        if sched.pager is not None:
            assert sched.pager.pages_in_use == 0

    def test_spec_stats_identities(self, layout):
        cfg, params = _model()
        lay = kvc.layout_for(cfg, 2, 48, kv_format="bf16", layout=layout,
                             page_size=8)
        sched = Scheduler(params, cfg, lay, chunk_budget=6,
                          spec_decode=True, draft_gamma=3, draft_planes=8)
        _drive(sched, _requests(cfg, n=3, seed=11))
        stats = sched.stats()
        sp, kv, wr = stats["spec"], stats["kv_read"], stats["weight_read"]
        assert sp["enabled"] and sp["gamma"] == 3
        # every decode-path token was produced by a verify step
        assert sp["accepted_tokens"] == stats["decoded_tokens"]
        assert kv["decode_steps"] == sp["draft_steps"] + sp["verify_steps"]
        # bytes/accepted-token == bytes/step / acceptance-rate, exactly
        np.testing.assert_allclose(
            kv["decode_bytes"] / sp["accepted_tokens"],
            kv["decode_bytes_per_step"]
            * kv["decode_steps"] / sp["accepted_tokens"])
        assert sp["kv_bytes_per_accepted_token"] == round(
            kv["decode_bytes"] / sp["accepted_tokens"])
        assert sp["weight_bytes_per_accepted_token"] == round(
            wr["decode_bytes"] / sp["accepted_tokens"])
        # planes=8 drafts with the REAL serve weights: greedy drafts are
        # perfect, so acceptance beats 1 token/round strictly
        assert sp["draft_source"] == "planes"
        assert sp["accepted_tokens_per_round"] > 1.0
        # per-request rows reconcile with the global counters
        fins = stats["requests"]
        assert sum(r["spec_accepted_tokens"] for r in fins) \
            == sp["accepted_tokens"]
        assert sum(r["spec_drafted_tokens"] for r in fins) \
            == sp["drafted_tokens"]


class TestSpecEnvPlumbing:
    def test_env_enables_whole_scheduler(self, monkeypatch):
        monkeypatch.setenv(spd.ENV_ENABLE, "on")
        monkeypatch.setenv(spd.ENV_GAMMA, "2")
        cfg, params = _model()
        lay = kvc.layout_for(cfg, 2, 48, kv_format="bf16")
        sched = Scheduler(params, cfg, lay, chunk_budget=6)
        assert sched.spec.enabled and sched.spec.gamma == 2
        # explicit kwarg still wins over the env (alone-run pinning)
        pinned = Scheduler(params, cfg, lay, chunk_budget=6,
                           spec_decode=False,
                           shared_fns=sched.shared_fns())
        assert not pinned.spec.enabled

    def test_env_on_ring_stack_runs_without_speculation(self, monkeypatch):
        monkeypatch.setenv(spd.ENV_ENABLE, "on")
        cfg, params = _model("gemma3-4b")
        lay = kvc.layout_for(cfg, 2, 32, kv_format="bf16")
        sched = Scheduler(params, cfg, lay, chunk_budget=6)
        assert not sched.spec.enabled
        out = _drive(sched, _requests(cfg, n=1, seed=3, max_new=(2, 4)))
        assert out and "spec" not in sched.stats()

    def test_kwarg_on_ring_stack_raises(self):
        cfg, params = _model("gemma3-4b")
        lay = kvc.layout_for(cfg, 2, 32, kv_format="bf16")
        with pytest.raises(ValueError, match="rollback-safe"):
            Scheduler(params, cfg, lay, chunk_budget=6, spec_decode=True)
