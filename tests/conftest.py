"""Shared test configuration: src/ on sys.path, markers, dispatch mode.

Keeps ``PYTHONPATH=src`` optional (an editable install makes it moot, but
the suite must also collect from a bare checkout), registers the ``slow``
marker for configs without pyproject's ini options, and pins kernel
dispatch to interpret mode on hosts without a TPU so every kernel call
site — including ones that never pass ``interpret=`` — stays runnable.
"""

from __future__ import annotations

import os
import sys

import numpy as np
import pytest

_SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")
if os.path.isdir(_SRC) and os.path.abspath(_SRC) not in map(
    os.path.abspath, sys.path
):
    sys.path.insert(0, os.path.abspath(_SRC))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running multi-process tests (deselect with -m 'not slow')",
    )


@pytest.fixture(autouse=True, scope="session")
def _interpret_dispatch_without_tpu():
    """Force interpret-mode kernel dispatch when no TPU is present.

    An explicit ``REPRO_KERNEL_DISPATCH`` (e.g. ``ref`` for a fast oracle
    sweep, or ``compiled`` on a real TPU host) wins over this default.
    """
    from repro import compat
    from repro.kernels import dispatch

    if os.environ.get(dispatch.ENV_VAR) or compat.is_tpu_backend():
        yield
        return
    dispatch.set_default_mode(dispatch.MODE_INTERPRET)
    yield
    dispatch.set_default_mode(None)


@pytest.fixture
def rng_seed(request) -> int:
    """Stable per-test RNG seed derived from the test's node id."""
    import zlib

    return zlib.crc32(request.node.nodeid.encode()) % 2**31


@pytest.fixture
def rng(rng_seed) -> np.random.Generator:
    """Per-test numpy Generator seeded from the node id."""
    return np.random.default_rng(rng_seed)
