"""Parity sweeps for the ISSUE-7 paged-attention kernel families.

``paged_flash_decode`` (bf16 + int8) and ``bgpp_paged_attend`` (fused
two-phase plane-scan / top-k / int8 attend) must agree with their pure-jnp
``ref.py`` oracles BIT-for-bit in interpret mode — the dispatch wrappers
jit both paths, and under jit the kernel body and the oracle lower to the
same reduction orders (the eager paths can drift by one f32 ulp in fused
softmax chains, which is why every assertion here goes through the public
jitted wrappers).

Swept: page-boundary position spans, deliberately shuffled (non-identity)
page tables / phys maps so logical->physical translation is actually
exercised, cache fills below / at / above the bgpp keep budget, and GQA
ratios including Hq == Hk.  A second class checks the kernel family
against the ENGINE's legacy jnp attend on real caches (the contract
``serving.kernel_decode`` relies on when routing the serve_step), and a
third pins the actionable build-time validation errors.
"""

import dataclasses
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import MCBPOptions
from repro.kernels.bgpp_paged_attend import bgpp_paged_attend
from repro.kernels.paged_flash_decode import paged_flash_decode
from repro.serving import engine, kernel_decode, kv_cache as kvc

jax.config.update("jax_platform_name", "cpu")

D = 16  # head_dim — a multiple of 8 so bgpp planes pack bytewise
PAGE = 8


def _plan(S, rounds=4, keep=0.25):
    """The serving plan's arithmetic (kv_cache.bgpp_decode_plan) without a
    config object — synthetic sweeps pick keep ratios per test."""
    k_max = max(1, min(S, math.ceil(keep * S)))
    survivors = (S,) + tuple(max(k_max, S >> r) for r in range(1, rounds))
    return rounds, k_max, survivors


def _dense_pools(rng, n_tok, Hk, fmt):
    kf = jnp.asarray(rng.normal(size=(n_tok, Hk, D)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(n_tok, Hk, D)), jnp.float32)
    if fmt == "bf16":
        return kf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16), {}
    k_q, ks = kvc.quantize_kv(kf)
    v_q, vs = kvc.quantize_kv(vf)
    return k_q, v_q, {"k_scale": ks, "v_scale": vs}


def _bgpp_pools(rng, n_tok, Hk):
    kf = jnp.asarray(rng.normal(size=(n_tok, Hk, D)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(n_tok, Hk, D)), jnp.float32)
    k_q, k_scale = kvc.quantize_kv(kf)
    v_q, v_scale = kvc.quantize_kv(vf)
    planes, sign = kvc.k_to_bitplanes(k_q)
    return planes, sign, k_scale, v_q, v_scale


class TestPagedFlashDecodeParity:
    # pos 7 ends page 0, pos 8 starts page 1: both page-boundary sides
    @pytest.mark.parametrize("fmt", ["bf16", "int8"])
    @pytest.mark.parametrize("g", [1, 3])
    @pytest.mark.parametrize("pos_val", [0, 7, 8, 13])
    def test_interpret_matches_ref_on_shuffled_pages(self, fmt, g, pos_val):
        B, Hk, S = 2, 2, 16
        pp = S // PAGE
        rng = np.random.default_rng(
            1000 * (fmt == "int8") + 100 * g + pos_val
        )
        n_pages = B * pp + 2  # spare pages: garbage rows behind the table
        k, v, scales = _dense_pools(rng, n_pages * PAGE, Hk, fmt)
        # non-identity table: a kernel that forgot to translate pages
        # reads the wrong tokens (and possibly the spare garbage pages)
        table = jnp.asarray(
            rng.permutation(n_pages)[: B * pp].reshape(B, pp).astype(np.int32)
        )
        q = jnp.asarray(rng.normal(size=(B, Hk, g, D)), jnp.float32)
        pos = jnp.asarray([pos_val, max(0, pos_val - 1)], jnp.int32)

        out_i = paged_flash_decode(
            q, k, v, table, pos, page_size=PAGE, mode="interpret", **scales
        )
        out_r = paged_flash_decode(
            q, k, v, table, pos, page_size=PAGE, mode="ref", **scales
        )
        assert out_i.shape == (B, Hk, g, D)
        assert np.array_equal(np.asarray(out_i), np.asarray(out_r)), (
            f"{fmt} g={g} pos={pos_val}: interpret kernel diverges from the "
            f"jnp oracle (max |d| "
            f"{np.max(np.abs(np.asarray(out_i, np.float32) - np.asarray(out_r, np.float32)))})"
        )

    def test_unmapped_pages_never_contribute(self):
        """Lanes behind -1 page-table entries clamp to row 0 and are
        position-masked: poisoning the pool's unreached rows with huge
        values must not move the output."""
        B, Hk, g, S = 1, 2, 2, 16
        rng = np.random.default_rng(7)
        k, v, _ = _dense_pools(rng, 4 * PAGE, Hk, "bf16")
        table = jnp.asarray([[1, -1]], jnp.int32)  # page 1 live, page 2 unmapped
        q = jnp.asarray(rng.normal(size=(B, Hk, g, D)), jnp.float32)
        pos = jnp.asarray([PAGE - 1], jnp.int32)  # only page 1's lanes valid
        base = paged_flash_decode(
            q, k, v, table, pos, page_size=PAGE, mode="interpret"
        )
        k_p = k.at[2 * PAGE:].set(jnp.asarray(1e4, k.dtype))
        v_p = v.at[2 * PAGE:].set(jnp.asarray(1e4, v.dtype))
        poisoned = paged_flash_decode(
            q, k_p, v_p, table, pos, page_size=PAGE, mode="interpret"
        )
        assert np.array_equal(np.asarray(base), np.asarray(poisoned))


class TestBgppPagedAttendParity:
    # keep=0.5 at S=16 -> k_max=8: fills below / at / above the budget
    @pytest.mark.parametrize("g", [1, 2, 3])
    @pytest.mark.parametrize("s_ctx", [3, 8, 13, 16])
    def test_interpret_matches_ref_on_shuffled_phys(self, g, s_ctx):
        B, Hk, S = 2, 2, 16
        rng = np.random.default_rng(100 * g + s_ctx)
        n_tok = B * S + PAGE  # spare rows the shuffled map skips
        planes, sign, ks, v, vs = _bgpp_pools(rng, n_tok, Hk)
        phys = jnp.asarray(
            rng.permutation(n_tok)[: B * S].reshape(B, S).astype(np.int32)
        )
        q = jnp.asarray(rng.normal(size=(B, Hk, g, D)), jnp.float32)
        pos = jnp.asarray([s_ctx - 1, max(0, s_ctx - 2)], jnp.int32)
        rounds, k_max, survivors = _plan(S, rounds=4, keep=0.5)

        args = (q, planes, sign, ks, v, vs, phys, pos)
        kw = dict(rounds=rounds, k_max=k_max, survivors=survivors)
        out_i = bgpp_paged_attend(*args, mode="interpret", **kw)
        out_r = bgpp_paged_attend(*args, mode="ref", **kw)
        assert out_i.shape == (B, Hk, g, D)
        assert np.array_equal(np.asarray(out_i), np.asarray(out_r)), (
            f"g={g} s_ctx={s_ctx}: fused bgpp kernel diverges from the jnp "
            f"oracle (max |d| {np.max(np.abs(np.asarray(out_i - out_r)))})"
        )

    @pytest.mark.parametrize("keep", [0.25, 1.0])
    def test_plan_sweep(self, keep):
        """rounds/keep variations (k_max = S at keep=1.0 degenerates the
        top-k to 'everything survives') stay oracle-exact."""
        B, Hk, g, S = 1, 2, 3, 32
        rng = np.random.default_rng(int(keep * 100))
        planes, sign, ks, v, vs = _bgpp_pools(rng, B * S, Hk)
        phys = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
        q = jnp.asarray(rng.normal(size=(B, Hk, g, D)), jnp.float32)
        pos = jnp.asarray([S - 2], jnp.int32)
        rounds, k_max, survivors = _plan(S, rounds=4, keep=keep)
        args = (q, planes, sign, ks, v, vs, phys, pos)
        kw = dict(rounds=rounds, k_max=k_max, survivors=survivors)
        out_i = bgpp_paged_attend(*args, mode="interpret", **kw)
        out_r = bgpp_paged_attend(*args, mode="ref", **kw)
        assert np.array_equal(np.asarray(out_i), np.asarray(out_r))


# -------------------------------------------------------------------------
# engine-path parity on REAL caches (the kernel_decode routing contract)
# -------------------------------------------------------------------------

B_ENG, S_MAX = 2, 32
KEEP = 0.25


def _cfg():
    cfg = get_config("phi4-mini-3.8b", smoke=True)
    return dataclasses.replace(
        cfg, mcbp=MCBPOptions(bgpp_rounds=4, bgpp_keep_ratio=KEEP)
    )


def _filled(cfg, fmt, s_ctx, seed, s_max=S_MAX):
    """Same random K/V in a shuffled-table paged cache and a slot cache."""
    rng = np.random.default_rng(seed)
    lp = kvc.layout_for(cfg, B_ENG, s_max, kv_format=fmt, layout="paged",
                        page_size=PAGE)
    ls = kvc.layout_for(cfg, B_ENG, s_max, kv_format=fmt)
    paged = kvc.init_cache_arrays(cfg, lp)
    slot = kvc.init_cache_arrays(cfg, ls)
    tbl = np.full((B_ENG, lp.pages_per_slot), -1, np.int32)
    perm = rng.permutation(lp.num_pages)
    npg = -(-s_ctx // PAGE)
    for b in range(B_ENG):
        tbl[b, :npg] = perm[b * lp.pages_per_slot:b * lp.pages_per_slot + npg]
    paged["page_table"] = jnp.asarray(tbl)
    Hk, Dh = cfg.num_kv_heads, cfg.head_dim
    k = jnp.asarray(rng.normal(size=(B_ENG, s_ctx, Hk, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B_ENG, s_ctx, Hk, Dh)), jnp.float32)
    for b in range(B_ENG):
        paged["global"] = kvc.write_prefill(
            paged["global"], 0, k[b:b + 1], v[b:b + 1], slot=b,
            page_table=paged["page_table"], page_size=PAGE, max_seq=s_max,
        )
        slot["global"] = kvc.write_prefill(
            slot["global"], 0, k[b:b + 1], v[b:b + 1], slot=b,
        )
    q = jnp.asarray(
        rng.normal(size=(B_ENG, cfg.num_heads, Dh)), jnp.float32
    )
    return lp, ls, paged, slot, q


class TestEnginePathParity:
    @pytest.mark.parametrize("s_ctx", [5, 13, 30])
    def test_bgpp_kernel_matches_engine_two_phase(self, s_ctx):
        cfg = _cfg()
        lp, _, paged, _, q = _filled(cfg, "bgpp", s_ctx, seed=s_ctx)
        phys = kvc.phys_table(paged["page_table"], PAGE, S_MAX)
        valid = jnp.arange(S_MAX)[None, :] < s_ctx
        eng = jax.jit(
            lambda q_, st, ph: engine._bgpp_paged_decode_attend(
                q_, st, 0, ph, valid, cfg
            )
        )(q, paged["global"], phys)

        pos = jnp.full((B_ENG,), s_ctx - 1, jnp.int32)
        ker = kernel_decode.decode_attend(
            q, paged["global"], 0, pos, cfg, lp, _no_mesh_rules(),
            "interpret", phys=phys, page_table=paged["page_table"],
        )
        assert ker is not None
        assert np.array_equal(np.asarray(eng), np.asarray(ker)), (
            f"s_ctx={s_ctx}: kernel-routed bgpp attend diverges from the "
            f"engine's two-phase path "
            f"(max |d| {np.max(np.abs(np.asarray(eng - ker)))})"
        )

    @pytest.mark.parametrize("fmt", ["bf16", "int8"])
    def test_dense_kernel_matches_engine_paged_entry(self, fmt):
        cfg, s_ctx = _cfg(), 13
        lp, _, paged, _, q = _filled(cfg, fmt, s_ctx, seed=3)
        phys = kvc.phys_table(paged["page_table"], PAGE, S_MAX)
        valid = jnp.arange(S_MAX)[None, :] < s_ctx
        eng = jax.jit(
            lambda q_, st, ph: engine._decode_attend(
                q_, kvc.paged_entry(st, 0, ph), valid, cfg, fmt
            )
        )(q, paged["global"], phys)

        pos = jnp.full((B_ENG,), s_ctx - 1, jnp.int32)
        ker = kernel_decode.decode_attend(
            q, paged["global"], 0, pos, cfg, lp, _no_mesh_rules(),
            "interpret", phys=phys, page_table=paged["page_table"],
        )
        assert ker is not None
        assert np.array_equal(np.asarray(eng), np.asarray(ker)), (
            f"{fmt}: kernel-routed paged attend diverges from the engine's "
            f"paged_entry path "
            f"(max |d| {np.max(np.abs(np.asarray(eng - ker)))})"
        )

    @pytest.mark.parametrize("fmt", ["bgpp", "int8"])
    def test_ragged_max_seq_matches_engine(self, fmt):
        """max_seq=30 with page_size=8: the tail page is only partially
        addressable.  serve_llm derives max_seq=prompt+steps+slack, which
        is rarely a page multiple — the kernel path must accept it and
        stay bit-identical (the flash kernel masks the page-tail lanes
        past pos; the bgpp phys map is row-level, no page walking)."""
        cfg, s_max, s_ctx = _cfg(), 30, 21
        lp, _, paged, _, q = _filled(cfg, fmt, s_ctx, seed=7, s_max=s_max)
        phys = kvc.phys_table(paged["page_table"], PAGE, s_max)
        valid = jnp.arange(s_max)[None, :] < s_ctx
        if fmt == "bgpp":
            eng = jax.jit(
                lambda q_, st, ph: engine._bgpp_paged_decode_attend(
                    q_, st, 0, ph, valid, cfg
                )
            )(q, paged["global"], phys)
        else:
            eng = jax.jit(
                lambda q_, st, ph: engine._decode_attend(
                    q_, kvc.paged_entry(st, 0, ph), valid, cfg, fmt
                )
            )(q, paged["global"], phys)
        pos = jnp.full((B_ENG,), s_ctx - 1, jnp.int32)
        ker = kernel_decode.decode_attend(
            q, paged["global"], 0, pos, cfg, lp, _no_mesh_rules(),
            "interpret", phys=phys, page_table=paged["page_table"],
        )
        assert ker is not None
        assert np.array_equal(np.asarray(eng), np.asarray(ker)), (
            f"{fmt}: ragged max_seq={s_max} kernel attend diverges from "
            f"the engine "
            f"(max |d| {np.max(np.abs(np.asarray(eng - ker)))})"
        )

    @pytest.mark.parametrize("fmt", ["bf16", "int8", "bgpp"])
    def test_slot_pool_views_match_paged(self, fmt):
        """The slot layout's pool-ified stacks (transposes + identity maps)
        feed the SAME kernel as the paged layout — identical cache contents
        must produce identical outputs across layouts."""
        cfg, s_ctx = _cfg(), 13
        lp, ls, paged, slot, q = _filled(cfg, fmt, s_ctx, seed=11)
        phys = kvc.phys_table(paged["page_table"], PAGE, S_MAX)
        pos = jnp.full((B_ENG,), s_ctx - 1, jnp.int32)
        rules = _no_mesh_rules()
        out_p = kernel_decode.decode_attend(
            q, paged["global"], 0, pos, cfg, lp, rules, "interpret",
            phys=phys, page_table=paged["page_table"],
        )
        out_s = kernel_decode.decode_attend(
            q, slot["global"], 0, pos, cfg, ls, rules, "interpret",
        )
        assert out_p is not None and out_s is not None
        assert np.array_equal(np.asarray(out_p), np.asarray(out_s)), (
            f"{fmt}: slot pool-ification diverges from the paged pools "
            f"(max |d| {np.max(np.abs(np.asarray(out_p - out_s)))})"
        )


def _no_mesh_rules():
    """Minimal stand-in for ShardingRules off-mesh: decode_attend only
    reads ``.mesh`` (None -> unsharded local call)."""
    return type("R", (), {"mesh": None})()


# -------------------------------------------------------------------------
# build-time validation: actionable errors, not Pallas lowering failures
# -------------------------------------------------------------------------


class TestValidationErrors:
    def _bgpp_args(self):
        rng = np.random.default_rng(0)
        B, Hk, g, S = 1, 2, 2, 16
        planes, sign, ks, v, vs = _bgpp_pools(rng, B * S, Hk)
        phys = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
        q = jnp.zeros((B, Hk, g, D), jnp.float32)
        pos = jnp.zeros((B,), jnp.int32)
        return q, planes, sign, ks, v, vs, phys, pos

    def test_flash_rejects_ungrouped_query(self):
        rng = np.random.default_rng(0)
        k, v, _ = _dense_pools(rng, 16, 2, "bf16")
        with pytest.raises(ValueError, match="grouped \\(B, Hk, g, D\\)"):
            paged_flash_decode(
                jnp.zeros((1, 4, D)), k, v,
                jnp.zeros((1, 2), jnp.int32), jnp.zeros((1,), jnp.int32),
                page_size=PAGE,
            )

    def test_flash_rejects_head_shard_mismatch(self):
        rng = np.random.default_rng(0)
        k, v, _ = _dense_pools(rng, 16, 2, "bf16")
        with pytest.raises(ValueError, match="device-local head shard"):
            paged_flash_decode(
                jnp.zeros((1, 4, 1, D)), k, v,
                jnp.zeros((1, 2), jnp.int32), jnp.zeros((1,), jnp.int32),
                page_size=PAGE,
            )

    def test_flash_rejects_ragged_pages(self):
        rng = np.random.default_rng(0)
        k, v, _ = _dense_pools(rng, 20, 2, "bf16")  # 20 rows, page 8
        with pytest.raises(ValueError, match="whole number of pages"):
            paged_flash_decode(
                jnp.zeros((1, 2, 1, D)), k, v,
                jnp.zeros((1, 2), jnp.int32), jnp.zeros((1,), jnp.int32),
                page_size=PAGE,
            )

    def test_flash_rejects_lone_scale(self):
        rng = np.random.default_rng(0)
        k, v, scales = _dense_pools(rng, 16, 2, "int8")
        with pytest.raises(ValueError, match="BOTH k_scale and v_scale"):
            paged_flash_decode(
                jnp.zeros((1, 2, 1, D)), k, v,
                jnp.zeros((1, 2), jnp.int32), jnp.zeros((1,), jnp.int32),
                page_size=PAGE, k_scale=scales["k_scale"],
            )

    def test_bgpp_rejects_bad_survivor_plan(self):
        q, planes, sign, ks, v, vs, phys, pos = self._bgpp_args()
        with pytest.raises(ValueError, match="survivor widths"):
            bgpp_paged_attend(q, planes, sign, ks, v, vs, phys, pos,
                              rounds=2, k_max=4, survivors=(16, 8, 8))
        with pytest.raises(ValueError, match="survivors\\[0\\]"):
            bgpp_paged_attend(q, planes, sign, ks, v, vs, phys, pos,
                              rounds=2, k_max=4, survivors=(8, 8))
        with pytest.raises(ValueError, match="non-increasing"):
            bgpp_paged_attend(q, planes, sign, ks, v, vs, phys, pos,
                              rounds=2, k_max=4, survivors=(16, 17))
        with pytest.raises(ValueError, match="k_max"):
            bgpp_paged_attend(q, planes, sign, ks, v, vs, phys, pos,
                              rounds=2, k_max=12, survivors=(16, 8))

    def test_bgpp_rejects_unpacked_planes(self):
        q, planes, sign, ks, v, vs, phys, pos = self._bgpp_args()
        with pytest.raises(ValueError, match="packed magnitude planes"):
            bgpp_paged_attend(q, planes[:3], sign, ks, v, vs, phys, pos,
                              rounds=2, k_max=4, survivors=(16, 8))

    def test_kernel_decode_validate_gqa(self):
        cfg = dataclasses.replace(_cfg(), num_heads=7)
        lp = kvc.layout_for(_cfg(), B_ENG, S_MAX, kv_format="bgpp",
                            layout="paged", page_size=PAGE)
        with pytest.raises(ValueError, match="GQA group size"):
            kernel_decode.validate(cfg, lp)

    def test_kernel_decode_validate_accepts_ragged_max_seq(self):
        # max_seq need not be page-aligned (serve_llm derives it from
        # prompt+steps+slack); correctness is pinned end-to-end by
        # TestEnginePathParity.test_ragged_max_seq_matches_engine
        cfg = _cfg()
        lp = kvc.layout_for(cfg, B_ENG, S_MAX, kv_format="bgpp",
                            layout="paged", page_size=PAGE)
        kernel_decode.validate(cfg, dataclasses.replace(lp, max_seq=30))
