"""The compat shim must resolve every drifted symbol on the pinned JAX."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat

jax.config.update("jax_platform_name", "cpu")


class TestVersionFloor:
    def test_running_jax_meets_floor(self):
        assert compat.jax_version() >= compat.MIN_JAX_VERSION

    def test_jax_version_parses_dev_suffixes(self):
        # the parser must not choke on '0.5.0.dev20250101'-style strings
        assert isinstance(compat.jax_version(), tuple)
        assert all(isinstance(p, int) for p in compat.jax_version())

    def test_require_min_jax_raises_with_explicit_floor(self):
        with pytest.raises(RuntimeError, match=r"requires JAX >= 99\.0\.0"):
            compat.require_min_jax("testing", (99, 0, 0))


class TestCompilerParams:
    def test_resolves_on_pinned_jax(self):
        params = compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")
        )
        assert params.dimension_semantics == ("parallel", "arbitrary")

    def test_matches_a_pallas_tpu_class(self):
        from jax.experimental.pallas import tpu as pltpu

        cls = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams"
        )
        assert isinstance(compat.tpu_compiler_params(), cls)


class TestAbstractMesh:
    def test_none_outside_any_mesh_context(self):
        assert compat.get_abstract_mesh() is None

    def test_ambient_mesh_is_discovered(self):
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        with mesh:
            found = compat.get_abstract_mesh()
        assert found is not None
        assert "data" in found.axis_names

    def test_constrain_is_noop_without_mesh(self):
        from repro.distributed import sharding as sh

        x = jnp.ones((4, 8))
        rules = sh.ShardingRules()
        out = sh.constrain(x, rules, (sh.BATCH, None))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(x))

    def test_constrain_inside_jit_under_ambient_mesh(self):
        from repro.distributed import sharding as sh

        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        rules = sh.ShardingRules()

        @jax.jit
        def f(x):
            return sh.constrain(x, rules, (sh.BATCH, None)) * 2.0

        with mesh:
            out = f(jnp.ones((4, 8)))
        np.testing.assert_array_equal(np.asarray(out), np.full((4, 8), 2.0))


class TestShardMap:
    def test_check_vma_kwarg_translates(self):
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        f = compat.shard_map(
            lambda x: x * 2.0,
            mesh=mesh,
            in_specs=P("data"),
            out_specs=P("data"),
            check_vma=False,
        )
        out = f(jnp.ones((1, 4)))
        np.testing.assert_array_equal(np.asarray(out), np.full((1, 4), 2.0))


class TestCostAnalysis:
    def test_returns_flat_dict(self):
        compiled = jax.jit(lambda x: x @ x).lower(jnp.ones((8, 8))).compile()
        cost = compat.cost_analysis_dict(compiled)
        assert isinstance(cost, dict)
        assert float(cost.get("flops", 0.0)) > 0

    def test_tolerates_objects_without_cost_analysis(self):
        class Broken:
            def cost_analysis(self):
                raise RuntimeError("unsupported backend")

        assert compat.cost_analysis_dict(Broken()) == {}


class TestBackendDetection:
    def test_cpu_host_reports_interpret_default(self):
        assert compat.default_backend() == "cpu"
        assert not compat.is_tpu_backend()
        assert compat.interpret_default()
