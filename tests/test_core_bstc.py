"""BSTC: lossless two-state coding roundtrips + compression-ratio behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypcompat import given, settings, st

from repro.core import bstc, quantization

jax.config.update("jax_platform_name", "cpu")


def sparse_plane(rng, m_rows, h, density):
    return (rng.random((m_rows, h)) < density).astype(np.uint8)


class TestPlaneCodec:
    @pytest.mark.parametrize("density", [0.0, 0.05, 0.3, 0.9, 1.0])
    def test_roundtrip(self, density):
        rng = np.random.default_rng(int(density * 100))
        plane = sparse_plane(rng, 16, 64, density)
        enc = bstc.encode_plane(plane, m=4)
        dec = np.asarray(bstc.decode_plane(enc))
        np.testing.assert_array_equal(dec, plane)

    def test_encoded_bits_formula(self):
        rng = np.random.default_rng(1)
        plane = sparse_plane(rng, 8, 32, 0.1)
        enc = bstc.encode_plane(plane, m=4)
        # H indicators per group row + m bits per nonzero column
        grp = plane.reshape(2, 4, 32)
        patt = (grp * (1 << np.arange(4))[None, :, None]).sum(1)
        nnz = int((patt != 0).sum())
        assert enc.encoded_bits == 2 * 32 + 4 * nnz

    def test_paper_example(self):
        # {0000} -> {0} and {0001} -> {10001}: 1 zero col + 1 nonzero col
        plane = np.zeros((4, 2), np.uint8)
        plane[0, 1] = 1  # column 1 pattern = 0001
        enc = bstc.encode_plane(plane, m=4)
        assert enc.encoded_bits == 2 + 4  # two indicators + one 4b pattern

    @given(st.integers(0, 2**31 - 1), st.floats(0.01, 0.5))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_property(self, seed, density):
        rng = np.random.default_rng(seed)
        plane = sparse_plane(rng, 8, 24, density)
        enc = bstc.encode_plane(plane, m=4)
        np.testing.assert_array_equal(np.asarray(bstc.decode_plane(enc)), plane)


class TestWeightCodec:
    def test_weight_roundtrip_lossless(self):
        rng = np.random.default_rng(2)
        w = np.clip(np.round(rng.normal(size=(32, 64)) * 20), -127, 127).astype(
            np.int8
        )
        bw = bstc.encode_weight(w, scale=np.ones(32, np.float32))
        dec = np.asarray(bstc.decode_weight(bw))
        np.testing.assert_array_equal(dec, w)

    def test_llm_weight_compresses(self):
        from repro.utils.synthetic import synthetic_llm_weight

        rng = np.random.default_rng(3)
        w_f = synthetic_llm_weight(rng, (128, 256))
        qw = quantization.quantize_weight(jnp.asarray(w_f))
        bw = bstc.encode_weight(np.asarray(qw.q), np.asarray(qw.scale))
        # paper reports higher CR on real checkpoints (correlated zeros);
        # uncorrelated synthetic stats land around 1.2-1.3x — still >1.
        assert bw.compression_ratio > 1.15, bw.compression_ratio
        # high-order planes got compressed, low-order stayed raw
        assert bw.encoded[6] is not None and bw.encoded[0] is None
        np.testing.assert_array_equal(np.asarray(bstc.decode_weight(bw)), np.asarray(qw.q))

    def test_force_planes_matches_paper_default(self):
        rng = np.random.default_rng(4)
        w = np.clip(np.round(rng.normal(size=(16, 32)) * 30), -127, 127).astype(
            np.int8
        )
        bw = bstc.encode_weight(
            w, scale=np.ones(16, np.float32), force_planes=[2, 3, 4, 5, 6]
        )
        assert [e is not None for e in bw.encoded] == [False, False, True, True, True, True, True]
        np.testing.assert_array_equal(np.asarray(bstc.decode_weight(bw)), w)

    def test_dense_weight_does_not_compress(self):
        rng = np.random.default_rng(5)
        w = rng.integers(-127, 128, size=(16, 32)).astype(np.int8)  # uniform: dense planes
        bw = bstc.encode_weight(w, scale=np.ones(16, np.float32))
        # uniform weights have ~50% bit sparsity -> nothing above threshold
        assert all(e is None for e in bw.encoded[:5])
        np.testing.assert_array_equal(np.asarray(bstc.decode_weight(bw)), w)


class TestCRClosedForm:
    def test_cr_positive_above_threshold(self):
        # paper Fig 8(b): CR > 1 once BIT sparsity exceeds ~65% (m=4)
        hi = bstc.expected_column_sparsity(0.80, 4)
        lo = bstc.expected_column_sparsity(0.55, 4)
        assert bstc.compression_ratio_closed_form(4, hi) > 1.0
        assert bstc.compression_ratio_closed_form(4, lo) < 1.0

    def test_m1_never_compresses(self):
        # m=1: 1 indicator per bit -> CR = 1/(1 + (1-sc)) <= 1
        for sc in (0.1, 0.5, 0.99):
            assert bstc.compression_ratio_closed_form(1, sc) <= 1.0

    def test_cr_m_tradeoff(self):
        # larger m amortizes indicators but reduces all-zero column probability
        bs = 0.85
        crs = {
            m: bstc.compression_ratio_closed_form(
                m, bstc.expected_column_sparsity(bs, m)
            )
            for m in (1, 2, 4, 8, 16)
        }
        best = max(crs, key=crs.get)
        assert best in (2, 4, 8)  # interior optimum, paper picks m=4
