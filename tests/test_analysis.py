"""Roofline/HLO analysis: parser correctness on synthetic HLO + validation
of the text cost model against XLA's cost_analysis on loop-free graphs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.analysis.hlo import HloModule
from repro.analysis.roofline import (
    HW,
    RooflineReport,
    collective_bytes_from_hlo,
    model_flops_for,
)
from repro.configs import get_config, shapes as shp

jax.config.update("jax_platform_name", "cpu")

SYNTH_HLO = """
HloModule test

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%cond.1 (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}, to_apply=%add.1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ni, %ar)
}

ENTRY %main (arg: f32[128,256]) -> f32[128,256] {
  %arg = f32[128,256]{1,0} parameter(0)
  %w = f32[256,512]{1,0} parameter(1)
  %d = f32[128,512]{1,0} dot(%arg, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[128,512]{1,0} all-gather(%d), replica_groups={}, dimensions={1}
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%zero, %arg)
  %loop = (s32[], f32[128,256]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%loop), index=1
}
"""


class TestHloTextModel:
    def test_collective_bytes_with_loop_multiplier(self):
        out = collective_bytes_from_hlo(SYNTH_HLO)
        # all-gather operand: 128x512 f32 = 256 KiB (x1)
        assert out["all-gather"] == 128 * 512 * 4
        # all-reduce inside the while: 128x256 f32 x 10 trips
        assert out["all-reduce"] == 128 * 256 * 4 * 10
        assert out["total"] == out["all-gather"] + out["all-reduce"]

    def test_dot_flops_and_trip_counts(self):
        mod = HloModule(SYNTH_HLO)
        assert mod.dot_flops() == 2 * 128 * 512 * 256
        assert any(abs(v - 10.0) < 1e-9 for v in mod.while_summary().values())

    def test_matches_xla_cost_analysis_loop_free(self):
        """On a loop-free jitted graph the text model's dot flops must match
        XLA's cost_analysis (the decode-graph validation)."""
        a = jnp.zeros((64, 128), jnp.float32)
        b = jnp.zeros((128, 256), jnp.float32)
        compiled = jax.jit(lambda a, b: a @ b).lower(a, b).compile()
        mod = HloModule(compiled.as_text())
        xla = compat.cost_analysis_dict(compiled)["flops"]
        assert abs(mod.dot_flops() - xla) / xla < 0.01

    def test_loop_flops_corrected_vs_xla(self):
        """With a scan, the text model must exceed XLA's (undercounted) flops
        by ~ the trip count."""
        w = jnp.zeros((8, 64, 64), jnp.float32)
        x = jnp.zeros((4, 64), jnp.float32)

        def f(w, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), None
            h, _ = jax.lax.scan(body, x, w)
            return h

        compiled = jax.jit(f).lower(w, x).compile()
        mod = HloModule(compiled.as_text())
        xla = compat.cost_analysis_dict(compiled)["flops"]
        ratio = mod.dot_flops() / max(xla, 1)
        assert 4.0 < ratio <= 9.0, ratio  # ~8 iterations


class TestRooflineReport:
    def _report(self, **kw):
        base = dict(
            arch="a", shape="train_4k", mesh="16x16", chips=256,
            device_flops=1e12, device_bytes=1e11, collective_bytes=1e9,
            collective_by_kind={}, model_flops=2.56e14, peak_memory_bytes=1e9,
        )
        base.update(kw)
        return RooflineReport(**base)

    def test_terms_and_bottleneck(self):
        r = self._report()
        assert abs(r.t_compute - 1e12 / 197e12) < 1e-12
        assert abs(r.t_memory - 1e11 / 819e9) < 1e-9
        assert abs(r.t_collective - 1e9 / 50e9) < 1e-9
        assert r.bottleneck == "memory"

    def test_useful_ratio(self):
        r = self._report()
        assert abs(r.useful_flops_ratio - 2.56e14 / (1e12 * 256)) < 1e-9

    def test_roofline_fraction_compute_bound_perfect(self):
        # all terms compute, useful == total => fraction 1
        r = self._report(
            device_flops=1e12, device_bytes=0.0, collective_bytes=0.0,
            model_flops=1e12 * 256,
        )
        assert abs(r.roofline_fraction - 1.0) < 1e-9


class TestModelFlops:
    def test_train_is_6nd(self):
        cfg = get_config("deepseek-7b")
        f = model_flops_for(cfg, shp.TRAIN_4K)
        want = 6.0 * cfg.active_params() * 256 * 4096
        assert abs(f - want) / want < 1e-9

    def test_decode_counts_kv_span(self):
        cfg = get_config("mixtral-8x22b")  # SWA window 4096
        f = model_flops_for(cfg, shp.DECODE_32K)
        # attention span capped at the window, not the 32k cache
        per_layer_kv = 2 * 2 * cfg.num_heads * cfg.head_dim * 4096
        assert f > 2.0 * cfg.active_params() * 128
        assert f < (2.0 * cfg.active_params() + 56 * per_layer_kv * 2) * 128

    def test_moe_uses_active_params(self):
        cfg = get_config("llama4-scout-17b-a16e")
        f = model_flops_for(cfg, shp.TRAIN_4K)
        assert f < 6.0 * cfg.total_params() * 256 * 4096 / 3
