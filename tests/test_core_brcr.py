"""BRCR: exactness of the enumeration-matrix factorization + cost model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypcompat import given, settings, st

from repro.core import brcr

jax.config.update("jax_platform_name", "cpu")


def rand_w(rng, m_rows, h, scale=40):
    w = np.clip(rng.normal(size=(m_rows, h)) * scale, -127, 127)
    return jnp.asarray(np.round(w), jnp.int8)


class TestBRCRExactness:
    @pytest.mark.parametrize("m", [1, 2, 4, 5])
    @pytest.mark.parametrize("shape", [(8, 32), (20, 64), (16, 128)])
    def test_matches_dense_int(self, m, shape):
        M, H = shape
        if M % m:
            M = (M // m + 1) * m
        rng = np.random.default_rng(m * 100 + H)
        w = rand_w(rng, M, H)
        x = jnp.asarray(rng.integers(-100, 100, size=(H, 8)), jnp.int32)
        y = brcr.brcr_matmul(w, x, m=m)
        ref = np.asarray(w, np.int64) @ np.asarray(x, np.int64)
        np.testing.assert_array_equal(np.asarray(y, np.int64), ref)

    def test_matches_dense_float(self):
        rng = np.random.default_rng(0)
        w = rand_w(rng, 16, 64)
        x = jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)
        y = brcr.brcr_matmul(w, x, m=4)
        ref = np.asarray(w, np.float32).astype(np.float64) @ np.asarray(
            x, np.float64
        )
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-3)

    def test_paper_example_fig4(self):
        # Fig. 4(c): LSB matrix with repeated columns; E @ (I @ X) == W @ X
        w = jnp.asarray(
            [[1, 0, 1, 0, 1], [0, 1, 0, 1, 1], [1, 1, 1, 1, 0]], jnp.int8
        )
        x = jnp.arange(5, dtype=jnp.int32).reshape(5, 1)
        # m=3 (whole matrix as one group)
        y = brcr.brcr_matmul(w, x, m=3, nbits=1)
        np.testing.assert_array_equal(
            np.asarray(y)[:, 0], np.asarray(w, np.int64) @ np.arange(5)
        )

    @given(st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_property_random(self, seed):
        rng = np.random.default_rng(seed)
        w = rand_w(rng, 8, 32, scale=60)
        x = jnp.asarray(rng.integers(-50, 50, size=(32, 3)), jnp.int32)
        y = brcr.brcr_matmul(w, x, m=4)
        ref = np.asarray(w, np.int64) @ np.asarray(x, np.int64)
        np.testing.assert_array_equal(np.asarray(y, np.int64), ref)


class TestMAV:
    def test_merged_activation_vector(self):
        # two groups of columns with identical patterns accumulate
        idx = jnp.asarray([[2, 2, 1, 0]], jnp.int32)  # G=1, H=4
        x = jnp.asarray([[1.0], [10.0], [100.0], [1000.0]])
        z = brcr.merged_activation_vector(idx, x, m=2)
        assert z.shape == (1, 4, 1)
        np.testing.assert_allclose(
            np.asarray(z[0, :, 0]), [1000.0, 100.0, 11.0, 0.0]
        )

    def test_reconstruct_is_E_times_Z(self):
        rng = np.random.default_rng(1)
        z = jnp.asarray(rng.normal(size=(2, 16, 3)), jnp.float32)
        y = brcr.reconstruct(z, m=4)
        e = np.asarray(
            ((np.arange(16)[None] >> np.arange(4)[:, None]) & 1), np.float32
        )
        ref = np.einsum("jc,gcn->gjn", e, np.asarray(z))
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-6)


class TestCostModel:
    def test_cost_reduction_on_sparse_weights(self):
        from repro.utils.synthetic import synthetic_llm_weight_int8

        rng = np.random.default_rng(2)
        # H >> 2^m so reconstruction amortizes (paper's regime: H ~ 4k-12k)
        w_q, _ = synthetic_llm_weight_int8(rng, (32, 2048))
        cost = brcr.brcr_cost(jnp.asarray(w_q), m=4)
        assert cost.adds_total < cost.adds_bsc_baseline
        assert cost.bit_sparsity > 0.6
        assert cost.reduction_vs_bsc > 0.2

    def test_closed_form_sweet_spot(self):
        # paper Fig. 18: optimum m around 4-5 for H~4k, bs~0.7
        m_star = brcr.optimal_group_size(4096, 7, 0.70)
        assert m_star in (4, 5, 6)

    def test_closed_form_monotonic_pieces(self):
        c1 = brcr.brcr_cost_closed_form(4096, 1, 7, 0.7)["adds_total"]
        c5 = brcr.brcr_cost_closed_form(4096, 5, 7, 0.7)["adds_total"]
        c11 = brcr.brcr_cost_closed_form(4096, 11, 7, 0.7)["adds_total"]
        assert c5 < c1  # grouping helps
        assert c5 < c11  # 2^m reconstruction blowup hurts for large m

    def test_measured_cost_scales_with_n(self):
        rng = np.random.default_rng(3)
        w = rand_w(rng, 16, 64)
        c1 = brcr.brcr_cost(w, n_cols=1)
        c8 = brcr.brcr_cost(w, n_cols=8)
        assert c8.adds_total == 8 * c1.adds_total
