"""Property-based tests of system invariants (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypcompat import given, settings, st

from repro.analysis.roofline import bgpp_kernel_traffic, bstc_weight_traffic
from repro.configs import apply_weight_format_override, get_config
from repro.configs.base import ModelConfig
from repro.core import attention, bstc
from repro.models import model_zoo, moe
from repro.serving import kv_cache as kvc
from repro.serving import weights as swt
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler

jax.config.update("jax_platform_name", "cpu")


def moe_cfg(E, k, cf=8.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, head_dim=8, d_ff=32, vocab_size=64,
        num_experts=E, experts_per_token=k, moe_capacity_factor=cf,
        dtype="float32",
    )


class TestMoEDispatchInvariants:
    @given(
        st.integers(0, 2**31 - 1),
        st.sampled_from([(4, 1), (4, 2), (8, 2)]),
    )
    @settings(max_examples=12, deadline=None)
    def test_dropless_capacity_is_exact_weighted_sum(self, seed, ek):
        """With dropless capacity, the MoE output equals the explicit
        dense-expert weighted sum — no token lost, duplicated or misrouted."""
        E, k = ek
        cfg = moe_cfg(E, k, cf=float(E))  # capacity >= all tokens
        rng = np.random.default_rng(seed)
        params, _ = moe.moe_init(jax.random.key(seed % 1000), cfg, jnp.float32)
        x = jnp.asarray(rng.normal(size=(2, 4, cfg.d_model)), jnp.float32)

        y, _ = moe.moe_apply(params, x, cfg)

        # dense reference: run every expert on every token, combine by gate
        xt = x.reshape(-1, cfg.d_model)
        logits = xt @ params["router"]
        probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
        gv, ei = jax.lax.top_k(probs, k)
        if k > 1:
            gv = gv / jnp.sum(gv, -1, keepdims=True)
        outs = []
        for e in range(E):
            g = xt @ params["gate"][e]
            u = xt @ params["up"][e]
            outs.append((jax.nn.silu(g) * u) @ params["down"][e])
        outs = jnp.stack(outs, 1)  # (T, E, D)
        ref = jnp.zeros_like(xt)
        for j in range(k):
            ref = ref + gv[:, j : j + 1] * jnp.take_along_axis(
                outs, ei[:, j : j + 1, None], axis=1
            )[:, 0]
        np.testing.assert_allclose(
            np.asarray(y).reshape(-1, cfg.d_model), np.asarray(ref),
            rtol=2e-4, atol=2e-4,
        )

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_capacity_drop_only_shrinks(self, seed):
        """Dropping tokens (small capacity) must never create output where
        the dropless version had none, and dropped tokens output ~0 from
        the routed component."""
        cfg_full = moe_cfg(4, 1, cf=4.0)
        cfg_tight = moe_cfg(4, 1, cf=0.25)
        rng = np.random.default_rng(seed)
        params, _ = moe.moe_init(jax.random.key(1), cfg_full, jnp.float32)
        x = jnp.asarray(rng.normal(size=(1, 8, cfg_full.d_model)), jnp.float32)
        y_full, _ = moe.moe_apply(params, x, cfg_full)
        y_tight, _ = moe.moe_apply(params, x, cfg_tight)
        nf = np.linalg.norm(np.asarray(y_full).reshape(8, -1), axis=1)
        nt = np.linalg.norm(np.asarray(y_tight).reshape(8, -1), axis=1)
        assert (nt <= nf + 1e-4).all()


class TestBlockedAttendEquivalence:
    @given(
        st.integers(0, 2**31 - 1),
        st.sampled_from(["causal", "sliding", "chunked", "full"]),
        st.sampled_from([(8, 8), (16, 4), (4, 16)]),
    )
    @settings(max_examples=12, deadline=None)
    def test_matches_dense_attend(self, seed, kind, blocks):
        rng = np.random.default_rng(seed)
        B, S, Hq, Hk, D = 1, 32, 4, 2, 8
        q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hk, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hk, D)), jnp.float32)
        w = 8 if kind in ("sliding", "chunked") else 0
        bq, bk = blocks
        got = attention.blocked_attend(
            q, k, v, mask_kind=kind, window=w, block_q=bq, block_k=bk
        )
        mask = attention.make_mask(kind, S, S, w)
        want = attention.attend(q, k, v, mask=mask)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
        )

    def test_nondivisible_lengths(self):
        rng = np.random.default_rng(0)
        B, Sq, Sk, H, D = 1, 21, 37, 2, 8
        q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Sk, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Sk, H, D)), jnp.float32)
        got = attention.blocked_attend(
            q, k, v, mask_kind="full", block_q=8, block_k=16
        )
        want = attention.attend(q, k, v, mask=jnp.ones((Sq, Sk), bool))
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
        )


class TestBGPPKernelTrafficModel:
    @given(st.sampled_from([1024, 32768]), st.floats(0.05, 0.9))
    @settings(max_examples=20, deadline=None)
    def test_reduction_bounds(self, S, keep):
        out = bgpp_kernel_traffic(S, 128, rounds=4, keep_ratio=keep)
        assert out["bgpp_kernel_bytes"] > 0
        # bounded above by prediction + full-precision refetch of the keeps
        # (at keep→1 BGPP costs MORE than dense — the paper's sparsity
        # premise is what makes it pay), and saves >=1.5x at paper settings
        assert out["bgpp_kernel_bytes"] < 3.6 * S * 128
        if keep <= 0.25:
            assert out["reduction"] > 1.5

    def test_monotone_in_keep_ratio(self):
        r = [
            bgpp_kernel_traffic(32768, 128, keep_ratio=k)["reduction"]
            for k in (0.125, 0.25, 0.5, 0.9)
        ]
        assert r[0] > r[1] > r[2] > r[3]


class TestKVReadAccountingLaws:
    """Laws of the mesh columns in the kv-read accounting
    (kv_cache.decode_read_bytes / chunk_read_bytes): per-device shares
    recombine to the single-device totals, interconnect bytes vanish
    exactly at mesh 1x1, and the attend all-gather grows monotonically
    with the "model" size."""

    # deepseek smoke: 4 q / 4 kv heads — divisible by every model size here
    CFG = get_config("deepseek-7b", smoke=True)

    def _layout(self, fmt, layout, slots=4):
        return kvc.layout_for(self.CFG, slots, 48, kv_format=fmt,
                              layout=layout, page_size=8)

    @given(
        st.sampled_from(["bf16", "int8", "bgpp"]),
        st.sampled_from(["slot", "paged"]),
        st.sampled_from([(1, 1), (2, 1), (1, 2), (1, 4), (2, 4), (4, 2)]),
    )
    @settings(max_examples=24, deadline=None)
    def test_per_device_times_shards_is_total(self, fmt, layout, mesh):
        lay = self._layout(fmt, layout)
        out = kvc.decode_read_bytes(lay, self.CFG, mesh)
        pd = out["per_device"]
        assert pd["shards"] == mesh[0] * mesh[1]  # all dims divide here
        np.testing.assert_allclose(pd["total"] * pd["shards"], out["total"])
        np.testing.assert_allclose(
            pd["global"] + pd["local"], pd["total"])
        ck = kvc.chunk_read_bytes(lay, self.CFG, mesh)
        np.testing.assert_allclose(
            ck["per_device"]["total"] * ck["per_device"]["shards"],
            ck["total"])

    @given(st.sampled_from(["bf16", "int8", "bgpp"]),
           st.sampled_from(["slot", "paged"]))
    @settings(max_examples=6, deadline=None)
    def test_interconnect_zero_at_1x1(self, fmt, layout):
        lay = self._layout(fmt, layout)
        for reader in (kvc.decode_read_bytes, kvc.chunk_read_bytes):
            ic = reader(lay, self.CFG, (1, 1))["interconnect"]
            assert ic["total"] == 0.0, (reader.__name__, ic)

    @given(st.sampled_from(["bf16", "int8", "bgpp"]),
           st.sampled_from(["slot", "paged"]))
    @settings(max_examples=6, deadline=None)
    def test_attend_allgather_monotone_in_model(self, fmt, layout):
        """The attend reduction's all-gather moves (m_eff - 1)/m_eff of the
        head outputs — strictly more bytes at every larger dividing model
        size."""
        lay = self._layout(fmt, layout)
        ag = [kvc.decode_read_bytes(lay, self.CFG, (1, m))["interconnect"]
              ["attend_allgather"] for m in (1, 2, 4)]
        assert ag[0] == 0.0
        assert ag[0] < ag[1] < ag[2]

    def test_indivisible_shapes_fall_back_to_replication(self):
        # phi4 smoke: 6 q / 2 kv heads — neither divides model=4, so the
        # model factor must collapse to 1 (pool replicated, no interconnect)
        cfg = get_config("phi4-mini-3.8b", smoke=True)
        lay = kvc.layout_for(cfg, 3, 48, kv_format="bf16", layout="slot")
        d_eff, m_eff = kvc.mesh_shard_factors(lay, cfg, (2, 4))
        assert m_eff == 1
        assert d_eff == 1  # batch 3 does not divide data=2 either
        out = kvc.decode_read_bytes(lay, cfg, (2, 4))
        assert out["per_device"]["shards"] == 1
        assert out["interconnect"]["total"] == 0.0

    def test_chunk_paged_write_broadcast_is_zero(self):
        """B=1 prefill chunks are replicated over "data": every replica
        computes the chunk and writes its own pool copy, so the paged
        write broadcast term prices nothing (unlike decode, whose batch
        rows live on distinct data shards)."""
        lay = self._layout("int8", "paged")
        for mesh in ((2, 1), (2, 4), (4, 2)):
            ck = kvc.chunk_read_bytes(lay, self.CFG, mesh)
            assert ck["interconnect"]["paged_write_bcast"] == 0.0
            dk = kvc.decode_read_bytes(lay, self.CFG, mesh)
            assert dk["interconnect"]["paged_write_bcast"] > 0.0


class TestWeightReadAccountingLaws:
    """Laws of the serve-time weight-read plan (repro.serving.weights):
    per-placement device shares recombine to the single-device total on
    every mesh, the bf16 plan prices the raw dense bytes exactly, bstc
    coding halves (better) the bf16 traffic at the paper's bit-level
    sparsity, and the closed-form traffic model is the measured stream's
    formula (the bench's ±10% reconciliation gate rides on that)."""

    _CACHE = {}

    @classmethod
    def _plan(cls, fmt):
        if fmt not in cls._CACHE:
            # deepseek smoke: 4 q / 4 kv heads — divisible by every model
            # size below (same geometry the kv-read laws lean on)
            cfg = apply_weight_format_override(
                get_config("deepseek-7b", smoke=True), fmt)
            params, _ = model_zoo.init(jax.random.key(0), cfg)
            lay = kvc.layout_for(cfg, 4, 48, kv_format="bf16")
            _, plan = swt.prepare_serve_params(params, cfg, lay, fmt)
            cls._CACHE[fmt] = (cfg, lay, plan)
        return cls._CACHE[fmt]

    @given(
        st.sampled_from(["bf16", "int8", "bstc"]),
        st.sampled_from([(1, 1), (2, 1), (1, 2), (1, 4), (2, 4), (4, 2)]),
    )
    @settings(max_examples=18, deadline=None)
    def test_per_device_times_shards_is_total(self, fmt, mesh):
        cfg, lay, plan = self._plan(fmt)
        out = plan.decode_read_bytes(lay, cfg, mesh)
        recomposed = sum(
            out["per_device_by_placement"][p] * out["shards_by_placement"][p]
            for p in out["per_device_by_placement"]
        )
        np.testing.assert_allclose(recomposed, out["total"])
        np.testing.assert_allclose(out["total"], plan.total_bytes)
        np.testing.assert_allclose(
            sum(out["per_projection"].values()), out["total"])

    def test_bf16_plan_prices_dense_bytes_exactly(self):
        cfg, _, plan = self._plan("bf16")
        db = 2 if cfg.dtype == "bfloat16" else 4
        for e in plan.entries:
            want = db * e.copies * e.in_dim * e.out_dim
            assert e.coded_bytes == e.bf16_bytes == want, e.path

    def test_bstc_coded_at_most_half_of_bf16(self):
        cfg, lay, plan = self._plan("bstc")
        assert plan.total_bytes <= plan.bf16_bytes / 2, (
            "BSTC coding must at least halve bf16 weight traffic at the "
            "paper's bit-level sparsity")
        out = plan.decode_read_bytes(lay, cfg, (1, 1))
        assert 0.9 <= out["total"] / out["modeled"] <= 1.1, (
            "measured coded stream must reconcile with the closed form")

    @given(st.sampled_from([0.7, 0.8, 0.95]), st.sampled_from([2, 4, 8]))
    @settings(max_examples=9, deadline=None)
    def test_traffic_model_matches_closed_form(self, sc, m):
        nbits, in_dim, out_dim = 7, 128, 64
        out = bstc_weight_traffic(
            in_dim, out_dim, m=m, nbits=nbits, col_sparsity=[sc] * nbits)
        n = in_dim * out_dim
        bits = n + nbits * n / bstc.compression_ratio_closed_form(m, sc)
        np.testing.assert_allclose(out["bstc_bytes"], bits / 8 + 4 * out_dim)

    def test_traffic_model_monotone_in_sparsity(self):
        vals = [
            bstc_weight_traffic(128, 64, col_sparsity=[s] * 7)["bstc_bytes"]
            for s in (0.65, 0.8, 0.95)
        ]
        assert vals[0] > vals[1] > vals[2]
        # raw pricing (no sparsity) is plain int8 + scales
        raw = bstc_weight_traffic(128, 64)
        np.testing.assert_allclose(raw["bstc_bytes"], raw["int8_bytes"])


class TestSpecDecodeAccountingLaws:
    """Laws of the speculative-decoding counters (Scheduler._spec_round):
    per-slot-round acceptance is bounded by gamma + 1, per-request rows
    reconcile with the global counters and with the kv/weight byte
    totals, bytes-per-accepted-token is exactly bytes-per-step divided by
    the acceptance rate, perfect drafts bank gamma + 1 tokens every full
    round, and adversarially-wrong drafts degrade to exactly the one
    corrected token per round — never worse, never silently better."""

    GAMMA = 3
    _CACHE = {}

    @classmethod
    def _runs(cls):
        """One reference (non-spec) run plus three speculative runs over
        the SAME deterministic trace: perfect drafts (callback feeding the
        reference's own tokens), adversarial drafts (always wrong), and
        truncated-plane drafts — cached; every law reads these."""
        if not cls._CACHE:
            cfg = get_config("phi4-mini-3.8b", smoke=True)
            params, _ = model_zoo.init(jax.random.key(0), cfg)
            lay = kvc.layout_for(cfg, 2, 48, kv_format="bf16")
            rng = np.random.default_rng(0)
            protos = [Request(
                rid=i,
                prompt=rng.integers(
                    0, cfg.vocab_size, (int(rng.integers(4, 14)),)
                ).astype(np.int32),
                # budgets straddle multiples of gamma + 1 so perfect
                # drafts produce both full and truncated final rounds
                max_new_tokens=[8, 9, 5][i],
                arrival_step=3 * i,
            ) for i in range(3)]

            def clones():
                return [Request(rid=r.rid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens,
                                arrival_step=r.arrival_step)
                        for r in protos]

            def drive(sched, reqs):
                for r in reqs:
                    sched.submit(r)
                sched.run(max_steps=1000)
                assert len(sched.finished) == len(reqs)
                return sched, {r.rid: r for r in sched.finished}

            ref, truth = drive(
                Scheduler(params, cfg, lay, chunk_budget=6,
                          spec_decode=False), clones())
            tokens = {rid: list(r.generated) for rid, r in truth.items()}
            shared = ref.shared_fns()
            g = cls.GAMMA

            def perfect(req, t):
                seq = tokens[req.rid]
                return seq[t] if t < len(seq) else 0

            def adversarial(req, t):
                seq = tokens[req.rid]
                true = seq[t] if t < len(seq) else 0
                return (true + 1) % cfg.vocab_size

            cls._CACHE["truth"] = tokens
            for name, kw in (
                ("perfect", {"draft_fn": perfect}),
                ("adversarial", {"draft_fn": adversarial}),
                ("planes", {"draft_planes": 2}),
            ):
                sched, fin = drive(
                    Scheduler(params, cfg, lay, chunk_budget=6,
                              spec_decode=True, draft_gamma=g,
                              shared_fns=shared, **kw), clones())
                cls._CACHE[name] = (sched, fin)
        return cls._CACHE

    def test_outputs_bit_identical_to_reference(self):
        runs = self._runs()
        for name in ("perfect", "adversarial", "planes"):
            _, fin = runs[name]
            for rid, seq in runs["truth"].items():
                assert fin[rid].generated == seq, (name, rid)

    def test_accepted_bounded_by_gamma_plus_one(self):
        runs = self._runs()
        for name in ("perfect", "adversarial", "planes"):
            _, fin = runs[name]
            for r in fin.values():
                assert r.spec_accepts, (name, r.rid)
                assert all(1 <= a <= self.GAMMA + 1
                           for a in r.spec_accepts), (name, r.rid,
                                                      r.spec_accepts)

    def test_per_request_rows_reconcile_with_globals(self):
        runs = self._runs()
        for name in ("perfect", "adversarial", "planes"):
            sched, fin = runs[name]
            reqs = list(fin.values())
            assert sum(sum(r.spec_accepts) for r in reqs) \
                == sched.spec_accepted, name
            assert sum(len(r.spec_accepts) for r in reqs) \
                == sched.spec_slot_rounds, name
            assert sum(r.spec_drafted for r in reqs) == sched.spec_drafted
            assert sched.spec_drafted \
                == self.GAMMA * sched.spec_slot_rounds, name
            for r in reqs:
                # every decode-path token was accepted in some round (the
                # first token comes from prefill, not from decode)
                assert sum(r.spec_accepts) == len(r.generated) - 1, \
                    (name, r.rid)

    def test_counters_reconcile_with_byte_totals(self):
        runs = self._runs()
        for name in ("perfect", "adversarial", "planes"):
            sched, _ = runs[name]
            stats = sched.stats()
            sp, kv, wr = stats["spec"], stats["kv_read"], \
                stats["weight_read"]
            assert sp["accepted_tokens"] == stats["decoded_tokens"], name
            assert kv["decode_steps"] \
                == sp["draft_steps"] + sp["verify_steps"], name
            assert kv["decode_bytes"] \
                == kv["decode_steps"] * kv["decode_bytes_per_step"], name
            if name != "planes":  # callback drafts run no device steps
                assert sp["draft_steps"] == 0, name
                np.testing.assert_allclose(
                    sp["modeled_weight_bytes_per_accepted_token"],
                    sp["weight_bytes_per_accepted_token"], atol=1)

    def test_bytes_per_accepted_is_per_step_over_acceptance_rate(self):
        runs = self._runs()
        for name in ("perfect", "adversarial", "planes"):
            sched, _ = runs[name]
            stats = sched.stats()
            sp, kv, wr = stats["spec"], stats["kv_read"], \
                stats["weight_read"]
            rate = sp["accepted_tokens"] / kv["decode_steps"]
            np.testing.assert_allclose(
                kv["decode_bytes"] / sp["accepted_tokens"],
                kv["decode_bytes_per_step"] / rate, rtol=1e-12)
            np.testing.assert_allclose(
                wr["decode_bytes"] / sp["accepted_tokens"],
                wr["decode_bytes_per_step"] / rate, rtol=1e-12)

    def test_perfect_drafts_accept_gamma_plus_one_per_full_round(self):
        _, fin = self._runs()["perfect"]
        for r in fin.values():
            # every round except the request's last banks gamma + 1; the
            # final round is truncated only by the decode budget
            assert all(a == self.GAMMA + 1 for a in r.spec_accepts[:-1]), \
                (r.rid, r.spec_accepts)
            assert sum(r.spec_accepts) == len(r.generated) - 1
        sched, _ = self._runs()["perfect"]
        assert sched.spec_max_accept == self.GAMMA + 1

    def test_adversarial_drafts_accept_exactly_one_per_round(self):
        sched, fin = self._runs()["adversarial"]
        for r in fin.values():
            assert all(a == 1 for a in r.spec_accepts), (r.rid,
                                                         r.spec_accepts)
        sp = sched.stats()["spec"]
        assert sp["accepted_tokens_per_round"] == 1.0
        assert sp["draft_hit_rate"] == 0.0


class TestDispatchRoundTripLaws:
    """Round-trip laws for the compat-routed kernel dispatch paths.

    Small shapes + few examples keep these inside the tier-1 budget; the
    exhaustive tiling sweeps live in tests/test_kernel_*.py and
    tests/test_kernel_dispatch.py.
    """

    @given(st.integers(0, 2**31 - 1), st.sampled_from([0.05, 0.3, 0.8]))
    @settings(max_examples=6, deadline=None)
    def test_bstc_encode_decode_identity(self, seed, density):
        """BSTC encode -> dispatch-routed decode is the identity on group
        patterns, in both interpret and ref modes."""
        from repro.kernels.bstc_decode import (
            bstc_decode_patterns, prepare_encoded_plane,
        )

        rng = np.random.default_rng(seed)
        plane = (rng.random((8, 512)) < density).astype(np.uint8)
        enc = bstc.encode_plane(plane, m=4)
        ops = prepare_encoded_plane(enc)
        want = np.asarray(bstc.decode_plane(enc))
        for mode in ("interpret", "ref"):
            patt = bstc_decode_patterns(ops, tile_g=4, mode=mode)
            rows = np.asarray(bstc.expand_patterns(patt, m=4))
            np.testing.assert_array_equal(rows, want, err_msg=f"mode={mode}")

    @given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]))
    @settings(max_examples=6, deadline=None)
    def test_brcr_factorization_matches_dense_gemm(self, seed, m):
        """BRCR's enumeration factorization == dense GEMM, exactly, on int
        inputs — through the dispatch layer in interpret and ref modes."""
        from repro.kernels.brcr_gemm import brcr_gemm, prepare_brcr_operands

        rng = np.random.default_rng(seed)
        M, H, N = 16, 128, 8
        w = np.round(np.clip(rng.normal(size=(M, H)) * 40, -127, 127)).astype(
            np.int8
        )
        x = jnp.asarray(rng.integers(-100, 100, size=(H, N)), jnp.float32)
        ops = prepare_brcr_operands(w, m=m)
        ref = np.asarray(w, np.int64) @ np.asarray(x, np.int64)
        for mode in ("interpret", "ref"):
            y = brcr_gemm(
                ops, x, tile_m=M, tile_k=H, tile_n=N, mode=mode
            )
            np.testing.assert_array_equal(
                np.asarray(y, np.int64), ref, err_msg=f"mode={mode}"
            )

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=4, deadline=None)
    def test_bstc_matmul_weight_reconstruction_roundtrip(self, seed):
        """prepare -> reconstruct_dense_weight is the identity on int8
        weights (the ref dispatch path's premise)."""
        from repro.kernels.bstc_matmul import prepare_bstc_matmul_operands
        from repro.kernels.bstc_matmul.ops import reconstruct_dense_weight

        rng = np.random.default_rng(seed)
        w = np.round(
            np.clip(rng.normal(size=(8, 512)) * 30, -127, 127)
        ).astype(np.int8)
        ops = prepare_bstc_matmul_operands(w, m=4)
        got = np.asarray(reconstruct_dense_weight(ops))
        np.testing.assert_array_equal(got, w.astype(np.int32))
