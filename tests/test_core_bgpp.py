"""BGPP: progressive prediction recall, traffic accounting, batched/GQA path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention, bgpp, bitslice, topk

jax.config.update("jax_platform_name", "cpu")


def make_keys(rng, S, D, nbits=7):
    k = np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127).astype(np.int32)
    sign = (k < 0).astype(np.uint8)
    mag = np.abs(k).astype(np.uint8)
    planes = np.stack([(mag >> p) & 1 for p in range(nbits)]).astype(np.uint8)
    return k, jnp.asarray(planes), jnp.asarray(sign)


class TestBGPPPredict:
    def test_exact_scores_with_all_rounds_full_precision_query(self):
        rng = np.random.default_rng(0)
        S, D = 32, 16
        k, planes, sign = make_keys(rng, S, D)
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        cfg = bgpp.BGPPConfig(rounds=7, alpha=1e9, radius=1.0, query_bits=7)
        alive, est, stats = bgpp.bgpp_predict(q, planes, sign, cfg)
        ref = k @ np.asarray(q)
        np.testing.assert_allclose(np.asarray(est), ref.astype(np.float32))
        assert bool(jnp.all(alive))  # huge alpha -> nothing pruned

    def test_top_scoring_key_always_survives(self):
        rng = np.random.default_rng(1)
        S, D = 64, 32
        k, planes, sign = make_keys(rng, S, D)
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        scale = 1.0 / np.sqrt(D) / 900.0  # roughly logit scale
        cfg = bgpp.BGPPConfig(rounds=4, alpha=0.55)
        alive, est, _ = bgpp.bgpp_predict(q, planes, sign, cfg, logit_scale=scale)
        true_best = int(np.argmax(k @ np.asarray(q)))
        assert bool(alive[true_best])

    def test_pruning_reduces_traffic(self):
        rng = np.random.default_rng(2)
        S, D = 128, 32
        k, planes, sign = make_keys(rng, S, D)
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        scale = 1.0 / np.sqrt(D) / 900.0
        # annealed alphas (default): conservative early rounds, bounded by
        # sign + 4 planes of every key, and < the full 8-bit fetch
        cfg = bgpp.BGPPConfig(rounds=4, alpha=0.4)
        alive, _, stats = bgpp.bgpp_predict(q, planes, sign, cfg, logit_scale=scale)
        upper = S * D / 8.0 * (4 + 1)
        assert float(stats.predict_bytes) <= upper + 1e-6
        assert float(stats.predict_bytes) < float(stats.full_bytes)
        # flat (paper Eq.1 fixed-alpha) schedule prunes from round 0 and
        # beats the value-level 4-bit baseline when pruning bites
        cfg2 = bgpp.BGPPConfig(rounds=4, alpha=0.4, alpha_schedule=(0.4,))
        alive2, _, stats2 = bgpp.bgpp_predict(q, planes, sign, cfg2, logit_scale=scale)
        if int(jnp.sum(alive2)) < S // 2:
            assert float(stats2.predict_bytes) < float(stats2.value_topk_bytes)

    def test_alive_counts_monotone_nonincreasing(self):
        rng = np.random.default_rng(3)
        S, D = 64, 16
        _, planes, sign = make_keys(rng, S, D)
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        cfg = bgpp.BGPPConfig(rounds=5, alpha=0.5)
        _, _, stats = bgpp.bgpp_predict(
            q, planes, sign, cfg, logit_scale=1.0 / (16 * 900)
        )
        counts = np.asarray(stats.alive_per_round)[:5]
        assert (np.diff(counts) <= 0).all()

    def test_min_keys_floor(self):
        rng = np.random.default_rng(4)
        S, D = 64, 16
        _, planes, sign = make_keys(rng, S, D)
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        cfg = bgpp.BGPPConfig(rounds=6, alpha=0.01, min_keys=8)
        alive, _, _ = bgpp.bgpp_predict(q, planes, sign, cfg, logit_scale=1e-5)
        assert int(jnp.sum(alive)) >= 8

    def test_valid_mask_respected(self):
        rng = np.random.default_rng(5)
        S, D = 32, 16
        _, planes, sign = make_keys(rng, S, D)
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        valid = jnp.arange(S) < 20
        alive, _, _ = bgpp.bgpp_predict(
            q, planes, sign, bgpp.BGPPConfig(rounds=3), valid=valid
        )
        assert not bool(jnp.any(alive[20:]))


class TestBGPPRecall:
    def test_recall_of_true_topk(self):
        """Keys kept by BGPP should cover most of the true top-k set."""
        rng = np.random.default_rng(6)
        S, D = 256, 64
        k, planes, sign = make_keys(rng, S, D)
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        scale = 1.0 / np.sqrt(D) / 900.0
        cfg = bgpp.BGPPConfig(rounds=4, alpha=0.6)
        alive, _, _ = bgpp.bgpp_predict(q, planes, sign, cfg, logit_scale=scale)
        true_scores = k @ np.asarray(q)
        top8 = np.argsort(true_scores)[-8:]
        recall = np.asarray(alive)[top8].mean()
        assert recall >= 0.75, recall


class TestBatched:
    def test_batched_shapes_and_gqa_union(self):
        rng = np.random.default_rng(7)
        B, S, Hk, Hq, D, nbits = 2, 32, 2, 4, 16, 7
        k = np.clip(np.round(rng.normal(size=(B, S, Hk, D)) * 30), -127, 127).astype(
            np.int32
        )
        sign = jnp.asarray((k < 0).astype(np.uint8))
        mag = np.abs(k).astype(np.uint8)
        planes = jnp.asarray(
            np.stack([(mag >> p) & 1 for p in range(nbits)]).astype(np.uint8)
        )
        q = jnp.asarray(rng.integers(-60, 60, size=(B, Hq, D)), jnp.int32)
        alive, est = bgpp.bgpp_predict_batched(
            q, planes, sign, bgpp.BGPPConfig(rounds=3), logit_scale=1.0 / (D * 900)
        )
        assert alive.shape == (B, Hk, S)
        assert est.shape == (B, Hq, S)

    def test_topk_indices_static_shape(self):
        alive = jnp.asarray([[True, False, True, True]])
        est = jnp.asarray([[1.0, 9.0, 3.0, 2.0]])
        idx, valid = bgpp.alive_to_topk_indices(alive, est, k_max=3)
        assert idx.shape == (1, 3)
        kept = set(np.asarray(idx[0])[np.asarray(valid[0])].tolist())
        assert kept == {0, 2, 3} - set()  # masked-out key 1 never selected


class TestValueTopKBaseline:
    def test_value_topk_selects_true_top(self):
        rng = np.random.default_rng(8)
        S, D = 128, 32
        k = jnp.asarray(
            np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127), jnp.int8
        )
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        idx, est, stats = topk.value_topk_predict(q, k, k_keep=16)
        true = np.argsort(np.asarray(k, np.int64) @ np.asarray(q))[-4:]
        assert len(set(true) & set(np.asarray(idx).tolist())) >= 3
        assert float(stats.predict_bytes) == S * D * 0.5
