"""bgpp_score + flash_attention kernels vs oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import zlib

import numpy as np
import pytest

from repro.core import bitslice
from repro.kernels.bgpp_score import bgpp_score_round
from repro.kernels.bgpp_score.ref import bgpp_score_round_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref

jax.config.update("jax_platform_name", "cpu")


class TestBGPPScoreKernel:
    @pytest.mark.parametrize("S,D", [(64, 64), (256, 128), (512, 64)])
    def test_matches_ref(self, S, D):
        rng = np.random.default_rng(S + D)
        k = np.clip(np.round(rng.normal(size=(S, D)) * 30), -127, 127).astype(
            np.int32
        )
        sign = (k < 0).astype(np.uint8)
        mag = np.abs(k).astype(np.uint8)
        p = 5
        plane = ((mag >> p) & 1).astype(np.uint8)
        q = jnp.asarray(rng.integers(-60, 60, size=(D,)), jnp.int32)
        alive = jnp.asarray(rng.random(S) < 0.6)
        got = bgpp_score_round(
            q,
            bitslice.pack_bits(jnp.asarray(plane), axis=-1),
            bitslice.pack_bits(jnp.asarray(sign), axis=-1),
            alive,
            tile_s=64,
            interpret=True,
        )
        ref = bgpp_score_round_ref(q, jnp.asarray(plane), jnp.asarray(sign), alive)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    def test_dead_tiles_zero(self):
        rng = np.random.default_rng(0)
        S, D = 128, 64
        plane = jnp.asarray(rng.integers(0, 2, size=(S, D)), jnp.uint8)
        sign = jnp.zeros((S, D), jnp.uint8)
        q = jnp.ones((D,), jnp.int32)
        alive = jnp.zeros((S,), bool).at[:64].set(True)
        got = bgpp_score_round(
            q,
            bitslice.pack_bits(plane, axis=-1),
            bitslice.pack_bits(sign, axis=-1),
            alive,
            tile_s=64,
            interpret=True,
        )
        assert not np.any(np.asarray(got[64:]))
        assert np.any(np.asarray(got[:64]))


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("mask_kind,window", [
        ("causal", 0), ("sliding", 64), ("chunked", 64), ("full", 0),
    ])
    def test_matches_ref_masks(self, mask_kind, window):
        rng = np.random.default_rng(zlib.crc32(mask_kind.encode()) % 1000)
        B, S, Hq, Hk, D = 1, 256, 2, 2, 64
        q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hk, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hk, D)), jnp.float32)
        got = flash_attention(
            q, k, v, mask_kind=mask_kind, window=window,
            tile_q=64, tile_k=64, interpret=True,
        )
        ref = flash_attention_ref(q, k, v, mask_kind=mask_kind, window=window)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), rtol=2e-3, atol=2e-3
        )

    def test_gqa_grouping(self):
        rng = np.random.default_rng(1)
        B, S, Hq, Hk, D = 2, 128, 4, 2, 32
        q = jnp.asarray(rng.normal(size=(B, S, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hk, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hk, D)), jnp.float32)
        got = flash_attention(q, k, v, tile_q=64, tile_k=64, interpret=True)
        ref = flash_attention_ref(q, k, v)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), rtol=2e-3, atol=2e-3
        )

    def test_q_offset_decode_continuation(self):
        """Chunked prefill: second half with q_offset must equal full pass."""
        rng = np.random.default_rng(2)
        B, S, H, D = 1, 256, 2, 32
        q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
        full = flash_attention(q, k, v, tile_q=64, tile_k=64, interpret=True)
        part = flash_attention(
            q[:, 128:], k, v, q_offset=128, tile_q=64, tile_k=64, interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(part), np.asarray(full[:, 128:]), rtol=2e-3, atol=2e-3
        )

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(3)
        B, S, H, D = 1, 128, 2, 64
        mk = lambda: jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
        q, k, v = mk(), mk(), mk()
        got = flash_attention(q, k, v, tile_q=64, tile_k=64, interpret=True)
        ref = flash_attention_ref(q, k, v)
        assert got.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(ref, np.float32),
            rtol=2e-2,
            atol=2e-2,
        )
